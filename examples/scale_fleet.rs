//! Fleet-scale round loop: hundreds of simulated clients per round on the
//! thread-pooled coordinator.
//!
//! The paper's headline compression (×3531–×37208 upstream) matters at
//! fleet scale, so the simulator must sweep large client counts at
//! wall-clock speeds bounded by the codec, not the harness. This example
//! runs one SBC training at a configurable client count twice — serial
//! and pooled — verifies the two runs are **bit-identical**, and reports
//! the speedup.
//!
//!     cargo run --release --example scale_fleet
//!     SBC_FLEET_CLIENTS=256 SBC_FLEET_THREADS=8 cargo run --release --example scale_fleet
//!
//! See `benches/scale_clients.rs` for the full clients × threads sweep
//! (and `BENCH_scale.json`).

use sbc::compression::registry::MethodConfig;
use sbc::coordinator::schedule::LrSchedule;
use sbc::coordinator::trainer::{TrainConfig, Trainer};
use sbc::sgd::NativeMlpBackend;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() {
    let clients = env_usize("SBC_FLEET_CLIENTS", 128);
    let threads = env_usize(
        "SBC_FLEET_THREADS",
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4),
    );
    let iterations = env_usize("SBC_FLEET_ITERS", 50);

    println!("== Fleet scenario: {clients} clients, SBC(p=0.01,n=5), {threads} threads ==\n");
    let run = |parallelism: usize| {
        let method = MethodConfig::sbc(0.01, 5);
        let mut cfg = TrainConfig::new("digits16", method, iterations, LrSchedule::constant(0.1));
        cfg.clients = clients;
        cfg.parallelism = parallelism;
        cfg.eval_every_rounds = 1_000_000; // final eval only
        cfg.eval_batches = 4;
        let mut backend = NativeMlpBackend::digits_small(cfg.clients, cfg.seed);
        let start = std::time::Instant::now();
        let r = Trainer::new(&mut backend, cfg).run();
        (r, start.elapsed().as_secs_f64())
    };

    let (serial, t_serial) = run(1);
    let (pooled, t_pooled) = run(threads);

    assert_eq!(
        serial.final_params, pooled.final_params,
        "pooled round loop must be bit-identical to serial"
    );
    println!("serial  ({} clients, 1 thread):  {t_serial:.2}s", clients);
    println!("pooled  ({} clients, {threads} threads): {t_pooled:.2}s", clients);
    println!(
        "speedup x{:.2}   accuracy {:.3}   compression x{:.0}   (bit-identical: yes)",
        t_serial / t_pooled.max(1e-9),
        pooled.log.final_metric,
        pooled.log.compression,
    );
}
