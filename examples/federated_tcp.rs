//! Federated training over real TCP sockets on localhost: a
//! `FederatedServer` bound to an ephemeral 127.0.0.1 port plus four
//! client sessions, each training the small synthetic-digits MLP. The
//! run asserts the transport's headline invariant — the federated weight
//! digest is bit-identical to the in-process trainer's — then prints the
//! measured wire traffic.
//!
//! Run with:
//!
//!     cargo run --release --example federated_tcp
//!
//! `SBC_FED_ITERS` overrides the iteration budget (default 200).

use std::sync::Arc;

use sbc::compression::registry::MethodConfig;
use sbc::coordinator::schedule::LrSchedule;
use sbc::coordinator::trainer::{TrainConfig, Trainer};
use sbc::sgd::NativeMlpBackend;
use sbc::transport::session::run_federated;
use sbc::transport::tcp::{TcpAcceptor, TcpConnector};
use sbc::transport::{weight_digest, Connector};

fn main() -> anyhow::Result<()> {
    let iterations: usize =
        std::env::var("SBC_FED_ITERS").ok().and_then(|s| s.parse().ok()).unwrap_or(200);
    let mut cfg =
        TrainConfig::new("mlp-small", MethodConfig::sbc2(), iterations, LrSchedule::constant(0.1));
    cfg.eval_every_rounds = usize::MAX; // reference run: final eval only
    cfg.eval_batches = 2;

    // the reference: the exact same training entirely in-process
    let reference = {
        let mut be = NativeMlpBackend::digits_small(cfg.clients, 1);
        Trainer::new(&mut be, cfg.clone()).run()
    };

    let acceptor = Arc::new(TcpAcceptor::bind("127.0.0.1:0", &cfg.transport)?);
    let addr = acceptor.local_addr();
    println!(
        "== federated {} on {addr}: {} clients, {} rounds ==",
        cfg.method.label(),
        cfg.clients,
        (cfg.iterations / cfg.method.delay).max(1),
    );
    let connectors: Vec<Box<dyn Connector>> = (0..cfg.clients)
        .map(|_| Box::new(TcpConnector::new(addr, &cfg.transport)) as Box<dyn Connector>)
        .collect();
    let (fed, outcomes) =
        run_federated(&cfg, acceptor, connectors, |_| NativeMlpBackend::digits_small(4, 1))?;

    let want = weight_digest(&reference.final_params);
    assert_eq!(fed.digest, want, "federated weights diverged from the in-process trainer");
    for out in &outcomes {
        assert_eq!(out.digest, want, "a client session diverged");
    }
    println!("digest {:016x} — bit-identical to the in-process trainer", fed.digest);
    println!(
        "rounds {}, compression x{:.0}, payload {:.3} MB up, framing {:.4} MB, sim comm {:.2}s",
        fed.rounds,
        fed.comm.compression_rate(),
        fed.comm.upstream_bits as f64 / 8e6,
        fed.comm.frame_overhead_bits as f64 / 8e6,
        fed.net.total_comm_time_s,
    );
    Ok(())
}
