//! End-to-end validation driver (DESIGN.md §5 row E2E): a multi-million-
//! parameter decoder-only transformer (TinyGPT, ~10M params) trained for a
//! few hundred distributed steps on the Shakespeare-style character corpus
//! with SBC compression, through the full stack:
//!
//!   Pallas kernels  -> lowered into ->  JAX train-step HLO
//!   Rust coordinator -> PJRT executes the HLO, compresses updates with
//!   SBC, Golomb-encodes them onto the (simulated) wire, aggregates.
//!
//! The loss curve is printed and written to results/e2e_transformer.csv —
//! the record referenced by EXPERIMENTS.md.
//!
//!     make artifacts && cargo run --release --example e2e_transformer
//!     env: SBC_E2E_ITERS (default 300), SBC_E2E_MODEL (default tinygpt)

use sbc::compression::registry::MethodConfig;
use sbc::coordinator::schedule::LrSchedule;
use sbc::coordinator::trainer::{TrainConfig, Trainer};
use sbc::model::manifest::Manifest;
use sbc::runtime::PjrtBackend;
use sbc::util::timer::TIMERS;

fn main() -> anyhow::Result<()> {
    let iterations: usize =
        std::env::var("SBC_E2E_ITERS").ok().and_then(|s| s.parse().ok()).unwrap_or(300);
    let model = std::env::var("SBC_E2E_MODEL").unwrap_or_else(|_| "tinygpt".into());
    let manifest = Manifest::load("artifacts")?;

    let method = MethodConfig::sbc2(); // delay 10, p = 1%
    let mut cfg = TrainConfig::new(&model, method, iterations, LrSchedule::constant(3e-4));
    cfg.eval_every_rounds = 2;
    cfg.eval_batches = 2;
    cfg.verbose = true;

    let mut backend = PjrtBackend::load(&manifest, &model, cfg.clients, cfg.seed)?;
    println!(
        "== e2e: {} ({:.1}M params) x {} clients x {} iterations, {} ==",
        model,
        backend.spec.n_params as f64 / 1e6,
        cfg.clients,
        iterations,
        cfg.method.label()
    );

    let r = Trainer::new(&mut backend, cfg.clone()).run();

    std::fs::create_dir_all("results")?;
    let csv = "results/e2e_transformer.csv";
    let _ = std::fs::remove_file(csv);
    r.log.append_csv(csv)?;

    let first = r.log.points.first().unwrap();
    let last = r.log.points.last().unwrap();
    println!("\nloss curve: {} points written to {csv}", r.log.points.len());
    println!(
        "train loss {:.3} -> {:.3} | eval ppl {:.1} -> {:.1} | compression x{:.0} | upstream {:.2} MB/client | wall {:.0}s",
        first.train_loss,
        last.train_loss,
        first.metric,
        last.metric,
        r.log.compression,
        last.client_up_bits as f64 / 8e6,
        r.log.wall_s
    );
    eprint!("{}", TIMERS.report());
    assert!(
        last.train_loss < first.train_loss,
        "transformer failed to learn: {} -> {}",
        first.train_loss,
        last.train_loss
    );
    println!("E2E OK — all three layers compose.");
    Ok(())
}
