//! Data-parallel cluster training (paper §I first scenario): 8 workers on
//! a 10G fabric running high-frequency DSGD. At cluster scale the question
//! is whether per-round communication fits in the compute shadow; this
//! example measures round sizes and simulated comm time per method at
//! delay 1 (the latency-critical regime) using the MLP artifacts.
//!
//!     make artifacts && cargo run --release --example datacenter_cluster

use sbc::compression::registry::MethodConfig;
use sbc::config::presets;
use sbc::coordinator::trainer::Trainer;
use sbc::metrics::render_table;
use sbc::model::manifest::Manifest;
use sbc::netsim::Link;
use sbc::runtime::PjrtBackend;

fn main() -> anyhow::Result<()> {
    let iterations: usize =
        std::env::var("SBC_DC_ITERS").ok().and_then(|s| s.parse().ok()).unwrap_or(100);
    let manifest = Manifest::load("artifacts")?;

    println!("== Datacenter scenario: MLP, 8 workers, 10G fabric, delay 1 ==\n");
    let methods = vec![
        MethodConfig::baseline(),
        MethodConfig::signsgd(1e-3),
        MethodConfig::qsgd(4),
        MethodConfig::gradient_dropping(),
        MethodConfig::sbc1(),
    ];
    let mut rows = Vec::new();
    for method in methods {
        let label = method.label();
        let mut cfg = presets::preset("mlp", method);
        cfg.iterations = iterations;
        cfg.clients = 8;
        // pool the round loop: one worker per simulated cluster node
        // (bit-identical to serial; PJRT backends fall back serially)
        cfg.parallelism = 8;
        cfg.eval_every_rounds = 1_000_000;
        cfg.uplink = Link::datacenter_10g();
        cfg.downlink = Link::datacenter_10g();
        let mut backend = PjrtBackend::load(&manifest, "mlp", cfg.clients, cfg.seed)?;
        let r = Trainer::new(&mut backend, cfg).run();
        let per_round_bits = r.comm.upstream_bits as f64 / r.comm.messages.max(1) as f64;
        rows.push(vec![
            label,
            format!("{:.3}", r.log.final_metric),
            format!("x{:.0}", r.log.compression),
            format!("{:.1}", per_round_bits / 8e3),
            format!("{:.1}", r.net.total_comm_time_s * 1e3),
        ]);
    }
    println!(
        "{}",
        render_table(
            &["method", "accuracy", "compression", "msg KB", "total comm ms"],
            &rows
        )
    );
    println!("(delay-1 regime: SBC(1) ~ Gradient Dropping accuracy at ~4x fewer bits)");
    Ok(())
}
