//! Quickstart: train a small model distributedly with and without Sparse
//! Binary Compression, and compare accuracy + measured communication.
//!
//!     cargo run --release --example quickstart
//!
//! Uses the pure-Rust backend so it runs in seconds with no artifacts;
//! see `examples/federated_edge.rs` for the PJRT (AOT-artifact) path and
//! `examples/scale_fleet.rs` for the thread-pooled many-client round
//! loop. Set `SBC_PARALLELISM=8` to pool this run's round loop — the
//! table is bit-identical either way.

use sbc::compression::registry::MethodConfig;
use sbc::coordinator::schedule::LrSchedule;
use sbc::coordinator::trainer::{TrainConfig, Trainer};
use sbc::metrics::render_table;
use sbc::sgd::NativeMlpBackend;

fn main() {
    println!("== SBC quickstart: 4-client DSGD on a synthetic digits task ==\n");
    let methods = vec![
        MethodConfig::baseline(),
        MethodConfig::fedavg(100),
        MethodConfig::gradient_dropping(),
        MethodConfig::sbc1(),
        MethodConfig::sbc2(),
        MethodConfig::sbc3(),
    ];

    let iterations = 400;
    let mut rows = Vec::new();
    for method in methods {
        let label = method.label();
        let mut cfg =
            TrainConfig::new("digits16", method, iterations, LrSchedule::constant(0.1));
        cfg.eval_every_rounds = 1_000_000; // final eval only
        cfg.eval_batches = 8;
        let mut backend = NativeMlpBackend::digits_small(cfg.clients, cfg.seed);
        let r = Trainer::new(&mut backend, cfg).run();
        rows.push(vec![
            label,
            format!("{:.3}", r.log.final_metric),
            format!("x{:.0}", r.log.compression),
            format!("{:.4}", r.comm.upstream_bits as f64 / 8e6 / 4.0),
            format!("{:.2}", r.log.wall_s),
        ]);
    }
    println!(
        "{}",
        render_table(&["method", "accuracy", "compression", "upstream MB/client", "wall s"], &rows)
    );
    println!("(paper: SBC trades temporal vs gradient sparsity; all methods should\n reach similar accuracy while SBC cuts upstream bits by 3-4 orders)");
}
