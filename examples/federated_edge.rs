//! Privacy-preserving federated learning on the edge (paper §I second
//! scenario): 4 mobile clients behind LTE uplinks jointly train LeNet on
//! their private shards. Reports wall-clock communication time and the
//! metered-data cost per method — the numbers that decide whether mobile
//! DSGD is feasible at all.
//!
//!     make artifacts && cargo run --release --example federated_edge
//!     (set SBC_EDGE_ITERS to change the training budget; default 300)

use sbc::compression::registry::MethodConfig;
use sbc::config::presets;
use sbc::coordinator::trainer::Trainer;
use sbc::metrics::render_table;
use sbc::model::manifest::Manifest;
use sbc::netsim::Link;
use sbc::runtime::PjrtBackend;

fn main() -> anyhow::Result<()> {
    let iterations: usize =
        std::env::var("SBC_EDGE_ITERS").ok().and_then(|s| s.parse().ok()).unwrap_or(300);
    let manifest = Manifest::load("artifacts")?;

    println!("== Federated edge scenario: LeNet, 4 clients, LTE uplink ==\n");
    let methods = vec![
        MethodConfig::baseline(),
        MethodConfig::fedavg(100),
        MethodConfig::gradient_dropping(),
        MethodConfig::sbc3(),
    ];
    let mut rows = Vec::new();
    for method in methods {
        let label = method.label();
        let mut cfg = presets::preset("lenet", method);
        cfg.iterations = iterations;
        cfg.eval_every_rounds = 1_000_000; // final eval only
        cfg.uplink = Link::mobile_lte();
        cfg.downlink = Link::wifi();
        let mut backend = PjrtBackend::load(&manifest, "lenet", cfg.clients, cfg.seed)?;
        let r = Trainer::new(&mut backend, cfg).run();
        rows.push(vec![
            label,
            format!("{:.3}", r.log.final_metric),
            format!("x{:.0}", r.log.compression),
            format!("{:.3}", r.comm.upstream_bits as f64 / 8e6 / 4.0),
            format!("{:.1}", r.net.total_comm_time_s),
            format!("${:.4}", r.net.upstream_cost_usd()),
        ]);
    }
    println!(
        "{}",
        render_table(
            &["method", "accuracy", "compression", "up MB/client", "comm s", "data cost"],
            &rows
        )
    );
    println!("(SBC makes the LTE uplink negligible; dense DSGD saturates it)");
    Ok(())
}
