//! Adaptive sparsity — the paper's §III/§V "further research" direction,
//! implemented as a first-class feature: §III observes that *temporal*
//! sparsity wins in the high-LR phase and *gradient* sparsity wins after
//! LR decay. This example runs the adaptive schedule (delay 25 + p=0.04
//! before the decay milestone, delay 5 + p=0.008 after — constant total
//! sparsity 1/625) against the two fixed configurations on the same total
//! communication budget.
//!
//!     cargo run --release --example adaptive_sparsity

use sbc::compression::registry::MethodConfig;
use sbc::coordinator::schedule::LrSchedule;
use sbc::coordinator::trainer::{TrainConfig, Trainer};
use sbc::metrics::render_table;
use sbc::sgd::NativeMlpBackend;

struct Phase {
    until_iter: usize,
    delay: usize,
    p: f64,
}

/// Run a multi-phase SBC training by chaining Trainer segments, carrying
/// the master weights forward (per-client state resets between phases —
/// the residual hand-off is the conservative choice).
fn run_phases(phases: &[Phase], total_iters: usize, lr: &LrSchedule, seed: u64) -> (f32, f64, u64) {
    let mut backend = NativeMlpBackend::digits_small(4, seed);
    let mut done = 0usize;
    let mut compression_num = 0.0f64;
    let mut up_bits = 0u64;
    let mut final_metric = 0.0f32;
    let mut baseline_bits = 0u64;
    let mut params: Option<Vec<f32>> = None;
    for ph in phases {
        let until = ph.until_iter.min(total_iters);
        if until <= done {
            continue;
        }
        let method = MethodConfig::sbc(ph.p, ph.delay);
        let mut cfg = TrainConfig::new("digits16", method, until - done, lr.clone());
        cfg.seed = seed;
        cfg.eval_every_rounds = 1_000_000;
        cfg.eval_batches = 8;
        // shift LR schedule by completed iterations
        cfg.lr = LrSchedule {
            base: lr.base,
            decay: lr.decay,
            milestones: lr.milestones.iter().map(|&m| m.saturating_sub(done)).collect(),
        };
        let mut t = Trainer::new(&mut backend, cfg);
        let r = match params.take() {
            Some(p) => t.run_from(p), // warm start from the previous phase
            None => t.run(),
        };
        final_metric = r.log.final_metric;
        up_bits += r.comm.upstream_bits;
        baseline_bits += r.comm.baseline_bits;
        compression_num = baseline_bits as f64 / up_bits.max(1) as f64;
        params = Some(r.final_params);
        done = until;
    }
    (final_metric, compression_num, up_bits)
}

fn main() {
    let total = 600usize;
    let lr = LrSchedule::step(0.1, 0.1, vec![300]);
    println!("== Adaptive sparsity (paper §III): total sparsity fixed at 1/625 ==\n");

    let fixed_temporal = [Phase { until_iter: total, delay: 25, p: 0.04 }];
    let fixed_gradient = [Phase { until_iter: total, delay: 5, p: 0.008 }];
    let adaptive = [
        Phase { until_iter: 300, delay: 25, p: 0.04 },
        Phase { until_iter: total, delay: 5, p: 0.008 },
    ];

    let mut rows = Vec::new();
    for (name, phases) in [
        ("temporal-heavy (n=25, p=4%)", &fixed_temporal[..]),
        ("gradient-heavy (n=5, p=0.8%)", &fixed_gradient[..]),
        ("adaptive (switch @ LR decay)", &adaptive[..]),
    ] {
        let (acc, comp, bits) = run_phases(phases, total, &lr, 42);
        rows.push(vec![
            name.to_string(),
            format!("{acc:.3}"),
            format!("x{comp:.0}"),
            format!("{:.4}", bits as f64 / 8e6 / 4.0),
        ]);
    }
    println!(
        "{}",
        render_table(&["schedule", "accuracy", "compression", "up MB/client"], &rows)
    );
    println!("(§III prediction: temporal sparsity helps early, gradient sparsity\n helps after the LR decay — the adaptive schedule gets both)");
}
