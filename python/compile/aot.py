"""AOT exporter: lower every model's graphs to HLO text + manifest.json.

HLO *text* (never ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly.

Usage:  cd python && python -m compile.aot --outdir ../artifacts
Env:    SBC_AOT_MODELS=mlp,lenet  overrides the exported model set.

Python runs only here, at build time; the Rust binary is self-contained
once ``artifacts/`` exists.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
from jax._src.lib import xla_client as xc

from .model import build_graphs
from .models import DEFAULT_EXPORT, REGISTRY


def to_hlo_text(fn, example_args) -> str:
    lowered = jax.jit(fn, keep_unused=True).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_model(model, outdir: str) -> dict:
    entry = {
        "n_params": model.n_params,
        "opt_size": model.opt_size,
        "optimizer": model.optimizer,
        "task": model.task,
        "x_shape": list(model.x_shape),
        "x_dtype": model.x_dtype,
        "y_shape": list(model.y_shape),
        "y_dtype": model.y_dtype,
        "meta": model.meta,
        "tensors": [{"name": t.name, "shape": list(t.shape)} for t in model.params],
        "graphs": {},
    }
    for gname, (fn, args) in build_graphs(model).items():
        t0 = time.time()
        fname = f"{model.name}.{gname}.hlo.txt"
        path = os.path.join(outdir, fname)
        text = to_hlo_text(fn, args)
        with open(path, "w") as f:
            f.write(text)
        entry["graphs"][gname] = fname
        print(
            f"  {fname:34s} {len(text)/1e6:7.2f} MB  ({time.time()-t0:5.1f}s)",
            flush=True,
        )
    return entry


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--models", default=os.environ.get("SBC_AOT_MODELS", ""))
    args = ap.parse_args()

    names = [n for n in args.models.split(",") if n] or DEFAULT_EXPORT
    os.makedirs(args.outdir, exist_ok=True)

    manifest = {"format": 1, "models": {}}
    for name in names:
        model = REGISTRY[name]
        print(f"[aot] {name}: {model.n_params/1e6:.2f}M params", flush=True)
        manifest["models"][name] = export_model(model, args.outdir)

    with open(os.path.join(args.outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"[aot] wrote manifest with {len(names)} models to {args.outdir}")


if __name__ == "__main__":
    main()
