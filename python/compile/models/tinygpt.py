"""TinyGPT — decoder-only transformer for the end-to-end driver.

Used by ``examples/e2e_transformer.rs`` to prove the full stack composes
at realistic scale: a multi-million-parameter transformer trained for a
few hundred SBC-compressed distributed steps on the character corpus.

Configurable width/depth; two presets are exported:
  tinygpt     ~9.9M params  (d=320, L=8, 8 heads)   — default e2e run
  tinygpt25m  ~25M  params  (d=512, L=8, 8 heads)   — larger, optional

Pre-LN blocks, learned positional embeddings, GELU MLP (4x), untied
output projection, AdamW-free Adam (the paper never uses weight decay).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelDef, TensorSpec, glorot, lm_xent


def _specs(vocab, seq, d, layers):
    s = [TensorSpec("wte", (vocab, d)), TensorSpec("wpe", (seq, d))]
    for l in range(layers):
        p = f"h{l}"
        s += [
            TensorSpec(f"{p}_ln1g", (d,)),
            TensorSpec(f"{p}_ln1b", (d,)),
            TensorSpec(f"{p}_attn_w", (d, 3 * d)),
            TensorSpec(f"{p}_attn_b", (3 * d,)),
            TensorSpec(f"{p}_attn_proj", (d, d)),
            TensorSpec(f"{p}_attn_projb", (d,)),
            TensorSpec(f"{p}_ln2g", (d,)),
            TensorSpec(f"{p}_ln2b", (d,)),
            TensorSpec(f"{p}_mlp_w1", (d, 4 * d)),
            TensorSpec(f"{p}_mlp_b1", (4 * d,)),
            TensorSpec(f"{p}_mlp_w2", (4 * d, d)),
            TensorSpec(f"{p}_mlp_b2", (d,)),
        ]
    s += [TensorSpec("lnf_g", (d,)), TensorSpec("lnf_b", (d,)), TensorSpec("head", (d, vocab))]
    return s


def _make_init(vocab, seq, d, layers):
    def init(key):
        tree = {}
        for spec in _specs(vocab, seq, d, layers):
            key, k = jax.random.split(key)
            n = spec.name
            if n.endswith(("ln1g", "ln2g", "lnf_g")) or n == "lnf_g":
                tree[n] = jnp.ones(spec.shape, jnp.float32)
            elif n.endswith("b") or n.endswith("_ln1b") or n.endswith("_ln2b"):
                tree[n] = jnp.zeros(spec.shape, jnp.float32)
            elif n in ("wte", "wpe"):
                tree[n] = jax.random.normal(k, spec.shape) * 0.02
            else:
                tree[n] = glorot(k, spec.shape, spec.shape[0], spec.shape[-1])
        return tree

    return init


def _ln(x, g, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = x.var(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _make_loss(vocab, seq, d, layers, heads):
    hd = d // heads
    mask = jnp.tril(jnp.ones((seq, seq), bool))

    def loss(tree, x, y):
        b, t = x.shape
        h = tree["wte"][x] + tree["wpe"][None, :, :]
        for l in range(layers):
            p = f"h{l}"
            z = _ln(h, tree[f"{p}_ln1g"], tree[f"{p}_ln1b"])
            qkv = z @ tree[f"{p}_attn_w"] + tree[f"{p}_attn_b"]
            q, k, v = jnp.split(qkv, 3, axis=-1)
            q = q.reshape(b, t, heads, hd).transpose(0, 2, 1, 3)
            k = k.reshape(b, t, heads, hd).transpose(0, 2, 1, 3)
            v = v.reshape(b, t, heads, hd).transpose(0, 2, 1, 3)
            att = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(hd)
            att = jnp.where(mask[None, None], att, -1e9)
            att = jax.nn.softmax(att, axis=-1)
            z = (att @ v).transpose(0, 2, 1, 3).reshape(b, t, d)
            h = h + z @ tree[f"{p}_attn_proj"] + tree[f"{p}_attn_projb"]
            z = _ln(h, tree[f"{p}_ln2g"], tree[f"{p}_ln2b"])
            z = jax.nn.gelu(z @ tree[f"{p}_mlp_w1"] + tree[f"{p}_mlp_b1"])
            h = h + z @ tree[f"{p}_mlp_w2"] + tree[f"{p}_mlp_b2"]
        h = _ln(h, tree["lnf_g"], tree["lnf_b"])
        logits = h @ tree["head"]
        return lm_xent(logits, y)

    return loss


def make_gpt(name, vocab=98, seq=128, d=320, layers=8, heads=8, batch=4, lr=3e-4):
    return ModelDef(
        name=name,
        params=_specs(vocab, seq, d, layers),
        loss_fn=_make_loss(vocab, seq, d, layers, heads),
        init_fn=_make_init(vocab, seq, d, layers),
        optimizer="adam",
        x_shape=(batch, seq),
        x_dtype="i32",
        y_shape=(batch, seq),
        y_dtype="i32",
        task="lm",
        meta={"vocab": vocab, "default_lr": lr, "d_model": d, "layers": layers},
    )


TINYGPT = make_gpt("tinygpt")
TINYGPT25M = make_gpt("tinygpt25m", d=512, layers=8, heads=8, batch=2)
