"""Shared L2 model framework: flat-parameter train/eval/init graph builders.

Every model in the zoo exposes the same AOT surface so the Rust runtime is
fully generic over models:

  init:     (seed i32)                                   -> (params f32[N],)
  step:     (params f32[N], opt f32[S], lr f32, t f32,
             x <model>, y <model>)                       -> (params', opt', loss)
  eval:     (params f32[N], x, y)                        -> (loss_sum, metric, count)
  compress: (delta f32[N], p f32)                        -> (dense out f32[N], t, mu, side)

Parameters and optimizer state travel as single flat f32 vectors; the
graphs unflatten/reflatten internally. ``metric`` is the correct-prediction
count for classifiers and the summed token cross-entropy for language
models (perplexity = exp(metric / count)).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp


@dataclass
class TensorSpec:
    name: str
    shape: Tuple[int, ...]

    @property
    def size(self) -> int:
        return int(math.prod(self.shape))


@dataclass
class ModelDef:
    """A model in the zoo. ``params`` fixes the flat layout (order matters:
    Rust addresses per-tensor segments of the flat vector by this order)."""

    name: str
    params: List[TensorSpec]
    # loss_fn(ptree, x, y) -> (mean_loss, metric_sum, count)
    loss_fn: Callable
    init_fn: Callable  # init_fn(key) -> dict[name, array]
    optimizer: str  # "momentum" | "adam" | "sgd"
    x_shape: Tuple[int, ...] = ()
    x_dtype: str = "f32"
    y_shape: Tuple[int, ...] = ()
    y_dtype: str = "i32"
    momentum: float = 0.9
    task: str = "classification"  # or "lm"
    meta: Dict = field(default_factory=dict)

    @property
    def n_params(self) -> int:
        return sum(t.size for t in self.params)

    @property
    def opt_size(self) -> int:
        if self.optimizer == "momentum":
            return self.n_params
        if self.optimizer == "adam":
            return 2 * self.n_params
        return 1  # plain sgd: dummy 1-element state

    # -- flat <-> pytree ---------------------------------------------------

    def unflatten(self, flat: jnp.ndarray) -> Dict[str, jnp.ndarray]:
        out, off = {}, 0
        for t in self.params:
            out[t.name] = flat[off : off + t.size].reshape(t.shape)
            off += t.size
        return out

    def flatten(self, tree: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        return jnp.concatenate([tree[t.name].reshape(-1) for t in self.params])

    # -- graph builders ----------------------------------------------------

    def build_init(self):
        def init(seed):
            key = jax.random.PRNGKey(seed)
            tree = self.init_fn(key)
            for t in self.params:
                assert tree[t.name].shape == t.shape, (
                    f"{self.name}.{t.name}: init {tree[t.name].shape} != spec {t.shape}"
                )
            return (self.flatten(tree).astype(jnp.float32),)

        return init

    def build_step(self):
        mom = self.momentum

        def step(flat, opt, lr, t_step, x, y):
            tree = self.unflatten(flat)

            def scalar_loss(tr):
                loss, _, _ = self.loss_fn(tr, x, y)
                return loss

            loss, grads = jax.value_and_grad(scalar_loss)(tree)
            g = self.flatten(grads)
            if self.optimizer == "momentum":
                v = mom * opt + g
                new_flat = flat - lr * v
                new_opt = v
            elif self.optimizer == "adam":
                n = self.n_params
                m, v = opt[:n], opt[n:]
                b1, b2, eps = 0.9, 0.999, 1e-8
                m = b1 * m + (1 - b1) * g
                v = b2 * v + (1 - b2) * g * g
                mhat = m / (1 - b1 ** (t_step + 1.0))
                vhat = v / (1 - b2 ** (t_step + 1.0))
                new_flat = flat - lr * mhat / (jnp.sqrt(vhat) + eps)
                new_opt = jnp.concatenate([m, v])
            else:  # plain sgd with global-norm clipping (Zaremba-style LM
                # training, matching the paper's LSTM setup at lr = 1.0)
                gnorm = jnp.sqrt(jnp.sum(g * g))
                clip = 5.0
                g = g * jnp.minimum(1.0, clip / jnp.maximum(gnorm, 1e-12))
                new_flat = flat - lr * g
                new_opt = opt
            return new_flat, new_opt, loss

        return step

    def build_eval(self):
        def evaluate(flat, x, y):
            tree = self.unflatten(flat)
            loss, metric, count = self.loss_fn(tree, x, y)
            return (
                loss * count,
                metric.astype(jnp.float32),
                jnp.asarray(count, jnp.float32),
            )

        return evaluate

    def example_args(self):
        """ShapeDtypeStructs for (init, step, eval) lowering."""
        dt = {"f32": jnp.float32, "i32": jnp.int32}
        f32 = jnp.float32
        x = jax.ShapeDtypeStruct(self.x_shape, dt[self.x_dtype])
        y = jax.ShapeDtypeStruct(self.y_shape, dt[self.y_dtype])
        p = jax.ShapeDtypeStruct((self.n_params,), f32)
        o = jax.ShapeDtypeStruct((self.opt_size,), f32)
        s = jax.ShapeDtypeStruct((), f32)
        seed = jax.ShapeDtypeStruct((), jnp.int32)
        return {
            "init": (seed,),
            "step": (p, o, s, s, x, y),
            "eval": (p, x, y),
        }


# ---------------------------------------------------------------------------
# Shared nn building blocks (pure jnp — used by the model zoo)
# ---------------------------------------------------------------------------


def glorot(key, shape, fan_in, fan_out):
    lim = jnp.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, jnp.float32, -lim, lim)


def he(key, shape, fan_in):
    return jax.random.normal(key, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)


def conv2d(x, w, stride=1, padding="SAME"):
    """NHWC conv with HWIO weights."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def group_norm(x, gamma, beta, groups=8, eps=1e-5):
    """GroupNorm over NHWC (stateless BatchNorm substitute — see DESIGN.md)."""
    n, h, w, c = x.shape
    g = min(groups, c)
    xg = x.reshape(n, h, w, g, c // g)
    mean = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) / jnp.sqrt(var + eps)
    return xg.reshape(n, h, w, c) * gamma + beta


def softmax_xent(logits, labels):
    """(mean loss, correct count, count) for int labels."""
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    correct = jnp.sum(jnp.argmax(logits, axis=1) == labels)
    return jnp.mean(nll), correct, logits.shape[0]


def lm_xent(logits, labels):
    """(mean token loss, summed token loss, token count) for [B,T,V] logits."""
    b, t, v = logits.shape
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=2)[..., 0]
    total = jnp.sum(nll)
    count = b * t
    return total / count, total, count
