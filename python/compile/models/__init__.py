"""Model zoo registry. Order here fixes the artifact build order."""

from .cifarcnn import MODEL as CIFARCNN
from .lenet import MODEL as LENET
from .lstm import CHARLM, WORDLM
from .mlp import MODEL as MLP
from .tinygpt import TINYGPT, TINYGPT25M

REGISTRY = {
    m.name: m
    for m in [MLP, LENET, CIFARCNN, CHARLM, WORDLM, TINYGPT, TINYGPT25M]
}

# Models exported by default by `make artifacts` (tinygpt25m is opt-in via
# SBC_AOT_MODELS to keep artifact build time reasonable).
DEFAULT_EXPORT = ["mlp", "lenet", "cifarcnn", "charlm", "wordlm", "tinygpt"]
