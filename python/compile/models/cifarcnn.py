"""CifarCNN — residual conv net standing in for the paper's ResNet32/50.

Three stages of width (16, 32, 64), each with `blocks` residual blocks
(two 3x3 convs + GroupNorm + identity/projection skip), global average
pool, linear head. GroupNorm replaces BatchNorm so the model is stateless
(flat-parameter contract; see DESIGN.md §2 substitutions). ~470k params at
depth 2 — the compression path sees the same multi-tensor conv/FC update
structure as the paper's ResNets. Momentum SGD, stepwise LR decay driven
from Rust (lr is a runtime input of the step graph).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import (
    ModelDef,
    TensorSpec,
    conv2d,
    glorot,
    group_norm,
    he,
    softmax_xent,
)

BATCH = 16
WIDTHS = [8, 16, 32]
BLOCKS = 1  # residual blocks per stage


def _specs():
    s = [TensorSpec("stem_w", (3, 3, 3, WIDTHS[0]))]
    s.append(TensorSpec("stem_g", (WIDTHS[0],)))
    s.append(TensorSpec("stem_b", (WIDTHS[0],)))
    cin = WIDTHS[0]
    for si, w in enumerate(WIDTHS):
        for bi in range(BLOCKS):
            pfx = f"s{si}b{bi}"
            s.append(TensorSpec(f"{pfx}_w1", (3, 3, cin, w)))
            s.append(TensorSpec(f"{pfx}_g1", (w,)))
            s.append(TensorSpec(f"{pfx}_b1", (w,)))
            s.append(TensorSpec(f"{pfx}_w2", (3, 3, w, w)))
            s.append(TensorSpec(f"{pfx}_g2", (w,)))
            s.append(TensorSpec(f"{pfx}_b2", (w,)))
            if cin != w:
                s.append(TensorSpec(f"{pfx}_proj", (1, 1, cin, w)))
            cin = w
    s.append(TensorSpec("head_w", (WIDTHS[-1], 10)))
    s.append(TensorSpec("head_b", (10,)))
    return s


def _init(key):
    tree = {}
    for spec in _specs():
        key, k = jax.random.split(key)
        if spec.name.endswith(("_g1", "_g2", "stem_g")) or spec.name == "stem_g":
            tree[spec.name] = jnp.ones(spec.shape, jnp.float32)
        elif spec.name.endswith(("_b1", "_b2", "head_b")) or spec.name == "stem_b":
            tree[spec.name] = jnp.zeros(spec.shape, jnp.float32)
        elif spec.name == "head_w":
            tree[spec.name] = glorot(k, spec.shape, spec.shape[0], spec.shape[1])
        else:  # conv kernels
            fan_in = spec.shape[0] * spec.shape[1] * spec.shape[2]
            tree[spec.name] = he(k, spec.shape, fan_in)
    return tree


def _loss(tree, x, y):
    h = conv2d(x, tree["stem_w"])
    h = jax.nn.relu(group_norm(h, tree["stem_g"], tree["stem_b"]))
    cin = WIDTHS[0]
    for si, w in enumerate(WIDTHS):
        for bi in range(BLOCKS):
            pfx = f"s{si}b{bi}"
            stride = 2 if (si > 0 and bi == 0) else 1
            z = conv2d(h, tree[f"{pfx}_w1"], stride=stride)
            z = jax.nn.relu(group_norm(z, tree[f"{pfx}_g1"], tree[f"{pfx}_b1"]))
            z = conv2d(z, tree[f"{pfx}_w2"])
            z = group_norm(z, tree[f"{pfx}_g2"], tree[f"{pfx}_b2"])
            if cin != w:
                skip = conv2d(h, tree[f"{pfx}_proj"], stride=stride)
            else:
                skip = h
            h = jax.nn.relu(z + skip)
            cin = w
    h = h.mean(axis=(1, 2))  # global average pool
    logits = h @ tree["head_w"] + tree["head_b"]
    return softmax_xent(logits, y)


MODEL = ModelDef(
    name="cifarcnn",
    params=_specs(),
    loss_fn=_loss,
    init_fn=_init,
    optimizer="momentum",
    x_shape=(BATCH, 32, 32, 3),
    y_shape=(BATCH,),
    task="classification",
    meta={"classes": 10, "default_lr": 0.05},
)
