"""LeNet5-Caffe (~431k params), the paper's MNIST model, trained with Adam.

Layer stack follows the Caffe prototxt the paper cites:
conv(20@5x5, VALID) - pool2 - conv(50@5x5, VALID) - pool2 - fc500 - fc10.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelDef, TensorSpec, conv2d, glorot, he, maxpool2, softmax_xent

BATCH = 16

SPECS = [
    TensorSpec("c1w", (5, 5, 1, 20)),
    TensorSpec("c1b", (20,)),
    TensorSpec("c2w", (5, 5, 20, 50)),
    TensorSpec("c2b", (50,)),
    TensorSpec("f1w", (800, 500)),
    TensorSpec("f1b", (500,)),
    TensorSpec("f2w", (500, 10)),
    TensorSpec("f2b", (10,)),
]


def _init(key):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "c1w": he(k1, (5, 5, 1, 20), 25),
        "c1b": jnp.zeros((20,), jnp.float32),
        "c2w": he(k2, (5, 5, 20, 50), 500),
        "c2b": jnp.zeros((50,), jnp.float32),
        "f1w": glorot(k3, (800, 500), 800, 500),
        "f1b": jnp.zeros((500,), jnp.float32),
        "f2w": glorot(k4, (500, 10), 500, 10),
        "f2b": jnp.zeros((10,), jnp.float32),
    }


def _loss(tree, x, y):
    h = conv2d(x, tree["c1w"], padding="VALID") + tree["c1b"]  # 24x24x20
    h = maxpool2(jax.nn.relu(h))  # 12x12x20
    h = conv2d(h, tree["c2w"], padding="VALID") + tree["c2b"]  # 8x8x50
    h = maxpool2(jax.nn.relu(h))  # 4x4x50
    h = h.reshape(h.shape[0], -1)  # 800
    h = jax.nn.relu(h @ tree["f1w"] + tree["f1b"])
    logits = h @ tree["f2w"] + tree["f2b"]
    return softmax_xent(logits, y)


MODEL = ModelDef(
    name="lenet",
    params=SPECS,
    loss_fn=_loss,
    init_fn=_init,
    optimizer="adam",
    x_shape=(BATCH, 28, 28, 1),
    y_shape=(BATCH,),
    task="classification",
    meta={"classes": 10, "default_lr": 0.001},
)
