"""MLP-300-100: the classic MNIST fully-connected baseline (~266k params).

Fast enough to drive the dense experiment grids (Fig. 3/4/9 sweeps run
hundreds of full trainings); trained with momentum SGD.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelDef, TensorSpec, glorot, softmax_xent

BATCH = 64
DIMS = [784, 300, 100, 10]


def _specs():
    out = []
    for i in range(len(DIMS) - 1):
        out.append(TensorSpec(f"w{i}", (DIMS[i], DIMS[i + 1])))
        out.append(TensorSpec(f"b{i}", (DIMS[i + 1],)))
    return out


def _init(key):
    tree = {}
    for i in range(len(DIMS) - 1):
        key, k = jax.random.split(key)
        tree[f"w{i}"] = glorot(k, (DIMS[i], DIMS[i + 1]), DIMS[i], DIMS[i + 1])
        tree[f"b{i}"] = jnp.zeros((DIMS[i + 1],), jnp.float32)
    return tree


def _loss(tree, x, y):
    h = x
    for i in range(len(DIMS) - 1):
        h = h @ tree[f"w{i}"] + tree[f"b{i}"]
        if i < len(DIMS) - 2:
            h = jax.nn.relu(h)
    return softmax_xent(h, y)


MODEL = ModelDef(
    name="mlp",
    params=_specs(),
    loss_fn=_loss,
    init_fn=_init,
    optimizer="momentum",
    x_shape=(BATCH, 784),
    y_shape=(BATCH,),
    task="classification",
    meta={"classes": 10, "default_lr": 0.1},
)
