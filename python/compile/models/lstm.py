"""Two-layer LSTM language models (CharLM / WordLM), paper §IV-A.

Mirrors the Zaremba et al. seq-to-seq LM the paper uses: embedding,
two LSTM layers run with ``lax.scan``, linear vocab projection, plain SGD
(the paper trains its LMs with vanilla gradient descent + decay).
Hidden sizes are scaled to the sandbox (see DESIGN.md §2): CharLM keeps
the paper's 200 units; WordLM uses 256 units over a 1k vocab.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ModelDef, TensorSpec, glorot, lm_xent


def _lstm_specs(name, vocab, embed, hidden):
    s = [TensorSpec("embed", (vocab, embed))]
    for l, in_dim in enumerate([embed, hidden]):
        s.append(TensorSpec(f"l{l}_wx", (in_dim, 4 * hidden)))
        s.append(TensorSpec(f"l{l}_wh", (hidden, 4 * hidden)))
        s.append(TensorSpec(f"l{l}_b", (4 * hidden,)))
    s.append(TensorSpec("proj_w", (hidden, vocab)))
    s.append(TensorSpec("proj_b", (vocab,)))
    return s


def _make_init(vocab, embed, hidden):
    def init(key):
        ks = jax.random.split(key, 8)
        tree = {"embed": jax.random.normal(ks[0], (vocab, embed)) * 0.1}
        for l, in_dim in enumerate([embed, hidden]):
            tree[f"l{l}_wx"] = glorot(ks[1 + 2 * l], (in_dim, 4 * hidden), in_dim, 4 * hidden)
            tree[f"l{l}_wh"] = glorot(ks[2 + 2 * l], (hidden, 4 * hidden), hidden, 4 * hidden)
            # forget-gate bias = 1 for stable early training
            b = jnp.zeros((4 * hidden,), jnp.float32).at[hidden : 2 * hidden].set(1.0)
            tree[f"l{l}_b"] = b
        tree["proj_w"] = glorot(ks[5], (hidden, vocab), hidden, vocab)
        tree["proj_b"] = jnp.zeros((vocab,), jnp.float32)
        return tree

    return init


def _lstm_layer(tree, l, xs, hidden):
    """xs: [T, B, D] -> [T, B, H] via lax.scan over time."""
    b = xs.shape[1]
    wx, wh, bias = tree[f"l{l}_wx"], tree[f"l{l}_wh"], tree[f"l{l}_b"]

    def step(carry, x_t):
        h, c = carry
        gates = x_t @ wx + h @ wh + bias
        i, f, g, o = jnp.split(gates, 4, axis=1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), h

    h0 = jnp.zeros((b, hidden), jnp.float32)
    (_, _), hs = jax.lax.scan(step, (h0, h0), xs)
    return hs


def _make_loss(hidden):
    def loss(tree, x, y):
        # x, y: [B, T] int32
        emb = tree["embed"][x]  # [B, T, E]
        h = jnp.transpose(emb, (1, 0, 2))  # [T, B, E]
        h = _lstm_layer(tree, 0, h, hidden)
        h = _lstm_layer(tree, 1, h, hidden)
        h = jnp.transpose(h, (1, 0, 2))  # [B, T, H]
        logits = h @ tree["proj_w"] + tree["proj_b"]
        return lm_xent(logits, y)

    return loss


def make_lm(name, vocab, embed, hidden, batch, seqlen, lr):
    return ModelDef(
        name=name,
        params=_lstm_specs(name, vocab, embed, hidden),
        loss_fn=_make_loss(hidden),
        init_fn=_make_init(vocab, embed, hidden),
        optimizer="sgd",
        x_shape=(batch, seqlen),
        x_dtype="i32",
        y_shape=(batch, seqlen),
        y_dtype="i32",
        task="lm",
        meta={"vocab": vocab, "default_lr": lr},
    )


CHARLM = make_lm("charlm", vocab=98, embed=64, hidden=200, batch=8, seqlen=32, lr=1.0)
WORDLM = make_lm("wordlm", vocab=1000, embed=128, hidden=256, batch=8, seqlen=20, lr=1.0)
