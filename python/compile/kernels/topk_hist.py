"""Pallas kernels, pass 1+2 of SBC compression: absmax and signed histograms.

TPU adaptation of the paper's top-k selection (see DESIGN.md
§Hardware-Adaptation): instead of a global sort (cheap on CPU/GPU,
prohibitive on TPU), the magnitude quantile is located with a log-spaced
histogram built in a single tiled pass over the gradient.

Grid layout: the flat input is padded to a multiple of ``BLOCK`` and
processed one VMEM-resident tile per grid step; the histogram output block
is mapped to the *same* block for every grid step, so the kernel
accumulates into it across the sequential grid (the canonical TPU
reduction pattern — no atomics needed because the TPU grid is sequential).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO, which is what the Rust
runtime loads. On a real TPU the same BlockSpecs compile natively.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NBINS, OCTAVES, SUBBINS

# One tile per grid step. 64k f32 = 256 KiB per input buffer: two input
# buffers + histogram scratch stay well under the ~16 MiB VMEM budget while
# amortizing grid overhead.
BLOCK = 65536


def _ceil_to_block(n: int) -> int:
    return (n + BLOCK - 1) // BLOCK * BLOCK


def pad_flat(x: jnp.ndarray) -> jnp.ndarray:
    """Zero-pad a flat vector to a multiple of BLOCK (zeros are ignored by
    every kernel because they are neither >0 nor <0)."""
    n = x.shape[0]
    m = _ceil_to_block(n)
    if m == n:
        return x
    return jnp.concatenate([x, jnp.zeros(m - n, x.dtype)])


# ---------------------------------------------------------------------------
# Pass 1: absmax
# ---------------------------------------------------------------------------


def _absmax_kernel(x_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    block_max = jnp.max(jnp.abs(x_ref[...]))
    out_ref[...] = jnp.maximum(out_ref[...], block_max)


def absmax_pallas(x: jnp.ndarray) -> jnp.ndarray:
    """max(|x|) over a flat (padded) vector; returns a (1,) f32 array."""
    n = x.shape[0]
    assert n % BLOCK == 0, "pad with pad_flat first"
    grid = (n // BLOCK,)
    return pl.pallas_call(
        _absmax_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=True,
    )(x)


# ---------------------------------------------------------------------------
# Pass 2: signed log-magnitude histograms
# ---------------------------------------------------------------------------


def _hist_kernel(x_ref, absmax_ref, hist_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    x = x_ref[...]
    # Bit-pattern binning (see ref.bit_bin_index): pure integer ops, so the
    # kernel agrees bit-for-bit with the jnp oracle and the Rust native path.
    bits_max = jax.lax.bitcast_convert_type(absmax_ref[0], jnp.int32)
    base = jnp.maximum((bits_max >> 23) - OCTAVES, 1)
    bits = jax.lax.bitcast_convert_type(jnp.abs(x), jnp.int32)
    e = bits >> 23
    sub = (bits >> 17) & (SUBBINS - 1)
    erel = e - base
    idx = jnp.clip(jnp.where(erel < 0, 0, erel * SUBBINS + sub), 0, NBINS - 1)
    pos = (x > 0).astype(jnp.float32)
    neg = (x < 0).astype(jnp.float32)
    block = jnp.zeros((2, NBINS), jnp.float32)
    block = block.at[0, idx].add(pos)
    block = block.at[1, idx].add(neg)
    hist_ref[...] += block


def signed_hist_pallas(x: jnp.ndarray, absmax: jnp.ndarray) -> jnp.ndarray:
    """(2, NBINS) histograms: row 0 over positive values, row 1 over
    |negative| values, with log-spaced bins relative to absmax."""
    n = x.shape[0]
    assert n % BLOCK == 0, "pad with pad_flat first"
    grid = (n // BLOCK,)
    return pl.pallas_call(
        _hist_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((2, NBINS), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((2, NBINS), jnp.float32),
        interpret=True,
    )(x, absmax)
