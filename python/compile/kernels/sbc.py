"""Composed SBC compression graph: Pallas passes + tiny jnp epilogue.

``sbc_compress_pallas(delta, p)`` is the L1 entry point the L2 compress
graph exports. It chains the four Pallas passes:

  P1 absmax            (topk_hist.absmax_pallas)
  P2 signed histograms (topk_hist.signed_hist_pallas)
  P3 side statistics   (binarize.side_stats_pallas)
  P4 apply binarize    (binarize.apply_binarize_pallas)

with the O(NBINS) threshold scan and the 4-scalar side decision done in
plain jnp between passes (far below kernel-launch granularity on any
backend).  Math is shared with ``ref.sbc_compress_hist``, against which the
composition is tested for exact agreement.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import ref
from .binarize import apply_binarize_pallas, side_stats_pallas
from .topk_hist import absmax_pallas, pad_flat, signed_hist_pallas


def sbc_compress_pallas(delta: jnp.ndarray, p):
    """Compress a flat f32 update with SBC (histogram top-k + binarize).

    Returns ``(out, t, mu, side_pos)`` — see ``ref.sbc_compress_exact``.
    ``p`` may be a traced scalar. ``delta`` may be any length; it is
    zero-padded internally and the output is cropped back.
    """
    n = delta.shape[0]
    k = jnp.maximum(jnp.round(p * n), 1.0)

    x = pad_flat(delta)
    absmax = absmax_pallas(x)  # (1,)
    hists = signed_hist_pallas(x, absmax)  # (2, NBINS)
    am = absmax[0]
    tpos = ref.threshold_from_hist(hists[0], k, am)
    tneg = ref.threshold_from_hist(hists[1], k, am)

    stats = side_stats_pallas(x, tpos, tneg)  # (4,)
    mupos = stats[0] / jnp.maximum(stats[1], 1.0)
    muneg = stats[2] / jnp.maximum(stats[3], 1.0)

    side_pos = mupos >= muneg
    mu = jnp.where(side_pos, mupos, muneg)
    t = jnp.where(side_pos, tpos, tneg)
    out = apply_binarize_pallas(x, t, mu, side_pos)[:n]
    return out, t, mu, side_pos
