"""Pallas kernels, pass 3+4 of SBC compression: side statistics and the
elementwise binarization (paper Algorithm 2, lines 3-8).

Pass 3 reduces (sum+, n+, sum-, n-) over the elements that survive each
side's magnitude threshold; the side decision (mu+ vs mu-) is a 4-element
jnp epilogue in the composing graph (see ``sbc.py``).  Pass 4 writes the
dense binarized update ``±mu * mask`` in one tiled elementwise sweep.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .topk_hist import BLOCK


def _stats_kernel(x_ref, t_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...]
    tpos = t_ref[0]
    tneg = t_ref[1]
    pos_mask = (x > 0) & (x >= tpos)
    neg_mask = (x < 0) & (-x >= tneg)
    spos = jnp.sum(jnp.where(pos_mask, x, 0.0))
    npos = jnp.sum(pos_mask.astype(jnp.float32))
    sneg = jnp.sum(jnp.where(neg_mask, -x, 0.0))
    nneg = jnp.sum(neg_mask.astype(jnp.float32))
    out_ref[...] += jnp.stack([spos, npos, sneg, nneg])


def side_stats_pallas(x: jnp.ndarray, tpos: jnp.ndarray, tneg: jnp.ndarray):
    """(4,) f32: (sum+, n+, sum-, n-) over threshold survivors."""
    n = x.shape[0]
    assert n % BLOCK == 0, "pad with pad_flat first"
    t = jnp.stack([jnp.reshape(tpos, ()), jnp.reshape(tneg, ())])
    grid = (n // BLOCK,)
    return pl.pallas_call(
        _stats_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((4,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((4,), jnp.float32),
        interpret=True,
    )(x, t)


def _apply_kernel(x_ref, smu_ref, out_ref):
    x = x_ref[...]
    t = smu_ref[0]
    mu = smu_ref[1]
    side_pos = smu_ref[2] > 0.5
    pos_out = jnp.where((x > 0) & (x >= t), mu, 0.0)
    neg_out = jnp.where((x < 0) & (-x >= t), -mu, 0.0)
    out_ref[...] = jnp.where(side_pos, pos_out, neg_out)


def apply_binarize_pallas(x, t, mu, side_pos):
    """Dense binarized update: mu on the surviving side, 0 elsewhere."""
    n = x.shape[0]
    assert n % BLOCK == 0, "pad with pad_flat first"
    smu = jnp.stack(
        [
            jnp.reshape(t, ()),
            jnp.reshape(mu, ()),
            jnp.reshape(side_pos, ()).astype(jnp.float32),
        ]
    )
    grid = (n // BLOCK,)
    return pl.pallas_call(
        _apply_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((3,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x, smu)
