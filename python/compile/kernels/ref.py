"""Pure-jnp reference oracles for the SBC compression kernels.

Two references are provided:

``sbc_compress_exact``
    Bit-faithful implementation of paper Algorithm 2 using a full sort:
    keep the fraction-``p`` largest positive and fraction-``p`` most
    negative entries, compute the mean of each side, zero the weaker side
    and binarize the stronger side to its mean.  This is the *semantic*
    oracle — statistically what SBC transmits.

``sbc_compress_hist``
    The TPU-adapted two-pass histogram/quantile algorithm implemented in
    plain jnp, with *identical* math to the Pallas kernels in
    ``topk_hist.py`` / ``binarize.py``.  The kernels are tested for exact
    agreement against this oracle; this oracle is in turn tested for
    statistical agreement (kept-count within bin tolerance) against
    ``sbc_compress_exact``.

All functions operate on a flat f32 vector ``delta`` and a sparsity ``p``
(fraction of elements kept *per side* before the side selection, matching
the paper's "fraction p biggest and fraction p smallest").
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Bit-pattern histogram parameters.  Magnitudes are binned directly on the
# f32 bit pattern — (biased exponent, top-6 mantissa bits) — giving
# log-spaced bins from *pure integer ops*: bit-identical across XLA fusion
# contexts, Pallas interpret mode, and the Rust native reimplementation
# (a transcendental log2 would round differently per compilation context).
# 16 octaves below the absmax x 64 sub-bins/octave = 1.1% relative
# threshold resolution; elements below absmax * 2**-16 land in bin 0 (the
# noise bucket) and are never selected.
OCTAVES = 16
SUBBINS = 64
NBINS = (OCTAVES + 1) * SUBBINS  # 1088


def topk_threshold_exact(delta: jnp.ndarray, k: int, side: str) -> jnp.ndarray:
    """Magnitude of the k-th largest positive (or most negative) entry."""
    if side == "pos":
        vals = jnp.where(delta > 0, delta, 0.0)
    else:
        vals = jnp.where(delta < 0, -delta, 0.0)
    sorted_desc = -jnp.sort(-vals)
    k = max(min(int(k), vals.shape[0]), 1)
    return sorted_desc[k - 1]


def sbc_compress_exact(delta: jnp.ndarray, p: float):
    """Paper Algorithm 2 with exact (sort-based) top-k.

    Returns ``(out, t, mu, side_pos)`` where ``out`` is the dense
    binarized update, ``t`` the magnitude threshold actually used, ``mu``
    the transmitted mean (always >= 0; the sign is implied by
    ``side_pos``), and ``side_pos`` a bool scalar.
    """
    n = delta.shape[0]
    k = max(int(round(p * n)), 1)

    tpos = topk_threshold_exact(delta, k, "pos")
    tneg = topk_threshold_exact(delta, k, "neg")

    pos_mask = (delta > 0) & (delta >= tpos) & (tpos > 0)
    neg_mask = (delta < 0) & (-delta >= tneg) & (tneg > 0)

    npos = jnp.sum(pos_mask)
    nneg = jnp.sum(neg_mask)
    mupos = jnp.sum(jnp.where(pos_mask, delta, 0.0)) / jnp.maximum(npos, 1)
    muneg = jnp.sum(jnp.where(neg_mask, -delta, 0.0)) / jnp.maximum(nneg, 1)

    side_pos = mupos >= muneg
    mu = jnp.where(side_pos, mupos, muneg)
    t = jnp.where(side_pos, tpos, tneg)
    out = jnp.where(
        side_pos,
        jnp.where(pos_mask, mupos, 0.0),
        jnp.where(neg_mask, -muneg, 0.0),
    )
    return out, t, mu, side_pos


# ---------------------------------------------------------------------------
# Histogram path (math shared with the Pallas kernels)
# ---------------------------------------------------------------------------


def exp_base(absmax: jnp.ndarray) -> jnp.ndarray:
    """Biased exponent of the lowest resolved octave (i32 scalar)."""
    bits = jax.lax.bitcast_convert_type(absmax.astype(jnp.float32), jnp.int32)
    emax = bits >> 23
    return jnp.maximum(emax - OCTAVES, 1)


def bit_bin_index(mag: jnp.ndarray, base: jnp.ndarray) -> jnp.ndarray:
    """Map magnitudes (>= 0) to bit-pattern bin indices in [0, NBINS-1].

    Bin index = (biased_exponent - base) * SUBBINS + top-6-mantissa-bits;
    monotone in magnitude because positive-f32 bit patterns are monotone.
    Everything below octave ``base`` (including zeros/denormals) lands in
    bin 0.
    """
    bits = jax.lax.bitcast_convert_type(mag.astype(jnp.float32), jnp.int32)
    e = bits >> 23
    sub = (bits >> 17) & (SUBBINS - 1)
    erel = e - base
    idx = jnp.where(erel < 0, 0, erel * SUBBINS + sub)
    return jnp.clip(idx, 0, NBINS - 1).astype(jnp.int32)


def bin_lower_edge(idx: jnp.ndarray, base: jnp.ndarray) -> jnp.ndarray:
    """Lower magnitude edge of bin ``idx`` — exact inverse of
    :func:`bit_bin_index`: mag >= edge(idx)  <=>  bin(mag) >= idx."""
    idx = jnp.asarray(idx, jnp.int32)
    e = base + idx // SUBBINS
    sub = idx % SUBBINS
    bits = (e << 23) | (sub << 17)
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def signed_histograms(delta: jnp.ndarray, absmax: jnp.ndarray):
    """Histogram of positive values and of |negative| values (jnp oracle)."""
    base = exp_base(absmax)
    idx = bit_bin_index(jnp.abs(delta), base)
    pos = (delta > 0).astype(jnp.float32)
    neg = (delta < 0).astype(jnp.float32)
    hpos = jnp.zeros(NBINS, jnp.float32).at[idx].add(pos)
    hneg = jnp.zeros(NBINS, jnp.float32).at[idx].add(neg)
    return hpos, hneg


def threshold_from_hist(hist: jnp.ndarray, k: jnp.ndarray, absmax: jnp.ndarray):
    """Smallest bin lower-edge t such that count(value >= t) >= k.

    Scans the cumulative histogram from the top.  Returns the lower edge
    of the boundary bin, so the kept count is >= k (overshoot bounded by
    the boundary-bin population, ~1.1% relative with 64 sub-bins/octave).
    If fewer than k entries exist above bin 0, falls back to the lower
    edge of the lowest populated bin above the noise bucket.
    """
    base = exp_base(absmax)
    tail = jnp.cumsum(hist[::-1])[::-1]  # tail[i] = count in bins >= i
    ge = tail[1:] >= k  # ignore the noise bucket (bin 0)
    # boundary = largest bin index i (in 1..NBINS-1) with tail[i] >= k
    idx = jnp.where(jnp.any(ge), jnp.argmax(jnp.arange(1, NBINS) * ge) + 1, 1)
    return bin_lower_edge(idx, base)


def side_stats(delta: jnp.ndarray, tpos: jnp.ndarray, tneg: jnp.ndarray):
    """(sum+, n+, sum-, n-) over the elements above each side's threshold."""
    pos_mask = (delta > 0) & (delta >= tpos)
    neg_mask = (delta < 0) & (-delta >= tneg)
    spos = jnp.sum(jnp.where(pos_mask, delta, 0.0))
    npos = jnp.sum(pos_mask).astype(jnp.float32)
    sneg = jnp.sum(jnp.where(neg_mask, -delta, 0.0))
    nneg = jnp.sum(neg_mask).astype(jnp.float32)
    return spos, npos, sneg, nneg


def apply_binarize(delta, t, mu, side_pos):
    """Elementwise binarization given the chosen side/threshold/mean."""
    pos_out = jnp.where((delta > 0) & (delta >= t), mu, 0.0)
    neg_out = jnp.where((delta < 0) & (-delta >= t), -mu, 0.0)
    return jnp.where(side_pos, pos_out, neg_out)


def sbc_compress_hist(delta: jnp.ndarray, p) :
    """TPU-adapted SBC compression: histogram-quantile top-k + binarize.

    Same return convention as :func:`sbc_compress_exact`.  ``p`` may be a
    traced scalar (it is a runtime input of the AOT-compiled graph).
    """
    n = delta.shape[0]
    k = jnp.maximum(jnp.round(p * n), 1.0)

    absmax = jnp.max(jnp.abs(delta))
    hpos, hneg = signed_histograms(delta, absmax)
    tpos = threshold_from_hist(hpos, k, absmax)
    tneg = threshold_from_hist(hneg, k, absmax)

    spos, npos, sneg, nneg = side_stats(delta, tpos, tneg)
    mupos = spos / jnp.maximum(npos, 1.0)
    muneg = sneg / jnp.maximum(nneg, 1.0)

    side_pos = mupos >= muneg
    mu = jnp.where(side_pos, mupos, muneg)
    t = jnp.where(side_pos, tpos, tneg)
    out = apply_binarize(delta, t, mu, side_pos)
    return out, t, mu, side_pos
