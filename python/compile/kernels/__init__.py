"""L1: Pallas kernels for the SBC compression hot-spot.

Public surface:
  sbc.sbc_compress_pallas   — composed 4-pass compression
  ref.sbc_compress_exact    — sort-based semantic oracle (Alg. 2)
  ref.sbc_compress_hist     — pure-jnp histogram oracle (kernel math)
"""
from . import binarize, ref, sbc, topk_hist  # noqa: F401
