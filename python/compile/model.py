"""L2 public surface: the four AOT graphs per model.

``build_graphs(model)`` returns the callables that ``aot.py`` lowers to
HLO text; the compress graph calls the L1 Pallas kernels so they lower
into the same artifact set.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.sbc import sbc_compress_pallas
from .models.common import ModelDef


def build_compress(n: int):
    """Compress graph over a flat delta of size ``n``.

    Signature: (delta f32[n], p f32[]) -> (out f32[n], t, mu, side f32).
    """

    def compress(delta, p):
        out, t, mu, side = sbc_compress_pallas(delta, p)
        return out, t, mu, side.astype(jnp.float32)

    return compress


def compress_example_args(n: int):
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((n,), f32),
        jax.ShapeDtypeStruct((), f32),
    )


def build_graphs(model: ModelDef):
    """(name -> (callable, example_args)) for all four graphs of a model."""
    ex = model.example_args()
    return {
        "init": (model.build_init(), ex["init"]),
        "step": (model.build_step(), ex["step"]),
        "eval": (model.build_eval(), ex["eval"]),
        "compress": (build_compress(model.n_params), compress_example_args(model.n_params)),
    }
