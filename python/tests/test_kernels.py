"""L1 kernel correctness: Pallas passes vs pure-jnp oracles.

The core signal: `sbc_compress_pallas` must agree *exactly* with the
pure-jnp histogram oracle (same math, different execution), and
*statistically* with the sort-based Algorithm 2 oracle (kept count within
histogram-bin tolerance, means close).
Hypothesis sweeps shapes, dtypes-scales, sparsity levels and distributions.
"""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.sbc import sbc_compress_pallas
from compile.kernels.topk_hist import BLOCK, absmax_pallas, pad_flat, signed_hist_pallas
from compile.kernels.binarize import apply_binarize_pallas, side_stats_pallas


def make_delta(n, seed, dist="heavy", scale=1.0):
    rng = np.random.default_rng(seed)
    if dist == "heavy":
        d = rng.standard_normal(n) * rng.random(n) ** 4
    elif dist == "normal":
        d = rng.standard_normal(n)
    elif dist == "skew_pos":
        d = np.abs(rng.standard_normal(n)) - 0.1 * rng.random(n)
    elif dist == "skew_neg":
        d = -np.abs(rng.standard_normal(n)) + 0.1 * rng.random(n)
    else:
        raise ValueError(dist)
    return jnp.array((d * scale).astype(np.float32))


# ---------------------------------------------------------------------------
# Individual passes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [BLOCK, 3 * BLOCK])
def test_absmax_matches_jnp(n):
    x = pad_flat(make_delta(n - 7, 1))
    got = absmax_pallas(x)[0]
    assert float(got) == float(jnp.max(jnp.abs(x)))


def test_absmax_all_zero():
    x = jnp.zeros(BLOCK, jnp.float32)
    assert float(absmax_pallas(x)[0]) == 0.0


@pytest.mark.parametrize("dist", ["heavy", "normal", "skew_pos", "skew_neg"])
def test_hist_matches_oracle(dist):
    x = pad_flat(make_delta(BLOCK + 123, 2, dist))
    am = jnp.max(jnp.abs(x))
    got = signed_hist_pallas(x, jnp.array([am]))
    hpos, hneg = ref.signed_histograms(x, am)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(hpos))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(hneg))


def test_hist_counts_sum_to_nonzero_elements():
    x = pad_flat(make_delta(2 * BLOCK, 3))
    am = jnp.max(jnp.abs(x))
    got = signed_hist_pallas(x, jnp.array([am]))
    n_pos = int(jnp.sum(x > 0))
    n_neg = int(jnp.sum(x < 0))
    assert int(np.asarray(got[0]).sum()) == n_pos
    assert int(np.asarray(got[1]).sum()) == n_neg


def test_side_stats_matches_oracle():
    x = pad_flat(make_delta(BLOCK, 4))
    tpos, tneg = jnp.float32(0.05), jnp.float32(0.07)
    got = side_stats_pallas(x, tpos, tneg)
    want = ref.side_stats(x, tpos, tneg)
    np.testing.assert_allclose(np.asarray(got), np.array([float(w) for w in want]), rtol=1e-6)


def test_apply_binarize_matches_oracle():
    x = pad_flat(make_delta(BLOCK, 5))
    t, mu = jnp.float32(0.03), jnp.float32(0.5)
    for side in (True, False):
        got = apply_binarize_pallas(x, t, mu, jnp.asarray(side))
        want = ref.apply_binarize(x, t, mu, jnp.asarray(side))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# Composed kernel vs oracles
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1000, max_value=200_000),
    seed=st.integers(min_value=0, max_value=2**31),
    dist=st.sampled_from(["heavy", "normal", "skew_pos", "skew_neg"]),
    p=st.sampled_from([0.001, 0.01, 0.05, 0.1]),
    scale=st.sampled_from([1e-4, 1.0, 1e4]),
)
def test_pallas_equals_hist_oracle(n, seed, dist, p, scale):
    d = make_delta(n, seed, dist, scale)
    out_k, t_k, mu_k, s_k = sbc_compress_pallas(d, p)
    out_h, t_h, mu_h, s_h = ref.sbc_compress_hist(d, p)
    a, b = np.asarray(out_k), np.asarray(out_h)
    # positions exact; values equal up to float reduction order (the Pallas
    # pass reduces block-wise, the oracle reduces flat)
    np.testing.assert_array_equal(a != 0, b != 0)
    np.testing.assert_allclose(a, b, rtol=2e-6)
    assert float(t_k) == float(t_h)
    assert abs(float(mu_k) - float(mu_h)) <= 1e-6 * max(1.0, abs(float(mu_h)))
    assert bool(s_k) == bool(s_h)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(min_value=10_000, max_value=150_000),
    seed=st.integers(min_value=0, max_value=2**31),
    p=st.sampled_from([0.005, 0.01, 0.05]),
)
def test_hist_tracks_exact_topk(n, seed, p):
    """Histogram top-k keeps >= k elements with <= 2% relative overshoot
    (bin-width bound) and the binarized mean is within 5% of exact."""
    d = make_delta(n, seed, "heavy")
    out_h, t_h, mu_h, s_h = ref.sbc_compress_hist(d, p)
    out_e, t_e, mu_e, s_e = ref.sbc_compress_exact(d, p)
    k = max(int(round(p * n)), 1)
    kept = int(np.sum(np.asarray(out_h) != 0))
    assert kept >= min(k, kept)  # never empty when signal exists
    if bool(s_h) == bool(s_e):
        # same side chosen -> mean magnitudes must be close
        assert abs(float(mu_h) - float(mu_e)) <= 0.05 * max(abs(float(mu_e)), 1e-8)
        # kept count within bin tolerance of exact kept count
        kept_e = int(np.sum(np.asarray(out_e) != 0))
        assert kept <= int(kept_e * 1.05) + 8


def test_compress_all_zero_input():
    d = jnp.zeros(5000, jnp.float32)
    out, t, mu, side = sbc_compress_pallas(d, 0.01)
    assert float(jnp.sum(jnp.abs(out))) == 0.0
    assert float(mu) == 0.0


def test_compress_single_spike():
    d = jnp.zeros(70_000, jnp.float32).at[12345].set(3.5)
    out, t, mu, side = sbc_compress_pallas(d, 0.001)
    o = np.asarray(out)
    assert bool(side)
    assert o[12345] == pytest.approx(3.5, rel=1e-6)
    assert int(np.sum(o != 0)) == 1


def test_compress_negative_side_wins():
    rng = np.random.default_rng(9)
    d = rng.standard_normal(50_000).astype(np.float32) * 0.01
    d[:50] = -5.0  # strong negative block
    out, t, mu, side = sbc_compress_pallas(jnp.array(d), 0.001)
    assert not bool(side)
    o = np.asarray(out)
    assert np.all(o <= 0)
    assert int(np.sum(o != 0)) >= 50


def test_compress_output_is_binary():
    d = make_delta(80_000, 11)
    out, t, mu, side = sbc_compress_pallas(d, 0.01)
    o = np.asarray(out)
    nz = o[o != 0]
    assert len(np.unique(nz)) == 1  # exactly one transmitted value
    assert np.unique(np.abs(nz))[0] == pytest.approx(float(mu), rel=1e-6)
