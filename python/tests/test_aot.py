"""AOT path: graphs lower to HLO text; compress graph works with traced p."""

import numpy as np
import jax
import jax.numpy as jnp

from compile.aot import to_hlo_text
from compile.model import build_compress, build_graphs, compress_example_args
from compile.models import REGISTRY
from compile.kernels import ref


def test_mlp_graphs_lower_to_hlo_text():
    graphs = build_graphs(REGISTRY["mlp"])
    for name, (fn, args) in graphs.items():
        text = to_hlo_text(fn, args)
        assert "ENTRY" in text and "HloModule" in text, name
        # tuple-return convention the Rust loader relies on
        assert "tuple(" in text or "(" in text.splitlines()[0]


def test_compress_graph_traced_p():
    n = 70_000
    fn = jax.jit(build_compress(n))
    rng = np.random.default_rng(3)
    d = jnp.array((rng.standard_normal(n) * rng.random(n) ** 3).astype(np.float32))
    for p in [0.001, 0.01, 0.1]:
        out, t, mu, side = fn(d, jnp.float32(p))
        out_h, t_h, mu_h, s_h = ref.sbc_compress_hist(d, p)
        a, b = np.asarray(out), np.asarray(out_h)
        # positions identical; values equal up to float reduction order
        np.testing.assert_array_equal(a != 0, b != 0)
        np.testing.assert_allclose(a, b, rtol=2e-6)
        assert float(t) == float(t_h)
        assert float(side) == float(jnp.asarray(s_h, jnp.float32))


def test_compress_hlo_has_no_custom_calls():
    """interpret=True must lower to plain HLO the CPU PJRT client can run."""
    text = to_hlo_text(build_compress(1024), compress_example_args(1024))
    assert "custom-call" not in text.lower()


def test_manifest_fields_complete():
    from compile.aot import export_model  # noqa: F401  (import check)
    m = REGISTRY["mlp"]
    ex = m.example_args()
    assert set(ex) == {"init", "step", "eval"}
    assert m.n_params == sum(t.size for t in m.params)
