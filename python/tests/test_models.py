"""L2 model zoo: shapes, flat round-trips, optimizer semantics, learning."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile.models import REGISTRY
from compile.models.common import ModelDef

SMALL = ["mlp", "lenet", "cifarcnn", "charlm", "wordlm"]


def batch_for(m: ModelDef, seed=0):
    rng = np.random.default_rng(seed)
    if m.x_dtype == "f32":
        x = jnp.array(rng.random(m.x_shape).astype(np.float32))
        y = jnp.array(rng.integers(0, m.meta.get("classes", 10), m.y_shape).astype(np.int32))
    else:
        v = m.meta["vocab"]
        x = jnp.array(rng.integers(0, v, m.x_shape).astype(np.int32))
        y = jnp.array(rng.integers(0, v, m.y_shape).astype(np.int32))
    return x, y


@pytest.mark.parametrize("name", SMALL)
def test_init_shape_and_determinism(name):
    m = REGISTRY[name]
    f1 = m.build_init()(jnp.int32(7))[0]
    f2 = m.build_init()(jnp.int32(7))[0]
    f3 = m.build_init()(jnp.int32(8))[0]
    assert f1.shape == (m.n_params,)
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
    assert not np.array_equal(np.asarray(f1), np.asarray(f3))


@pytest.mark.parametrize("name", SMALL)
def test_flat_roundtrip(name):
    m = REGISTRY[name]
    flat = m.build_init()(jnp.int32(0))[0]
    back = m.flatten(m.unflatten(flat))
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(back))


@pytest.mark.parametrize("name", SMALL)
def test_step_reduces_loss(name):
    m = REGISTRY[name]
    flat = m.build_init()(jnp.int32(0))[0]
    step = jax.jit(m.build_step())
    opt = jnp.zeros(m.opt_size, jnp.float32)
    x, y = batch_for(m)
    lr = jnp.float32(m.meta["default_lr"])
    # clipped plain-SGD LMs on uniform-random tokens move slowly; give them
    # more steps and require a smaller (but strictly monotone-ish) decrease
    steps, factor = (24, 0.995) if m.optimizer == "sgd" else (8, 0.98)
    losses = []
    for t in range(steps):
        flat, opt, loss = step(flat, opt, lr, jnp.float32(t), x, y)
        losses.append(float(loss))
    # overfitting one batch must reduce the loss
    assert losses[-1] < losses[0] * factor, losses


@pytest.mark.parametrize("name", SMALL)
def test_eval_consistent_with_loss(name):
    m = REGISTRY[name]
    flat = m.build_init()(jnp.int32(0))[0]
    x, y = batch_for(m)
    loss_sum, metric, count = jax.jit(m.build_eval())(flat, x, y)
    mean_loss, _, _ = m.loss_fn(m.unflatten(flat), x, y)
    assert float(loss_sum) == pytest.approx(float(mean_loss) * float(count), rel=1e-5)
    if m.task == "classification":
        assert 0 <= float(metric) <= float(count)
    else:
        assert float(metric) == pytest.approx(float(loss_sum), rel=1e-5)


def test_untrained_lm_perplexity_near_vocab():
    m = REGISTRY["charlm"]
    flat = m.build_init()(jnp.int32(0))[0]
    x, y = batch_for(m)
    loss_sum, _, count = jax.jit(m.build_eval())(flat, x, y)
    ppl = float(jnp.exp(loss_sum / count))
    assert 0.5 * m.meta["vocab"] < ppl < 2.0 * m.meta["vocab"]


def test_adam_state_layout():
    m = REGISTRY["lenet"]
    assert m.opt_size == 2 * m.n_params
    flat = m.build_init()(jnp.int32(0))[0]
    step = jax.jit(m.build_step())
    x, y = batch_for(m)
    _, opt1, _ = step(flat, jnp.zeros(m.opt_size), jnp.float32(1e-3), jnp.float32(0), x, y)
    mvec = np.asarray(opt1[: m.n_params])
    vvec = np.asarray(opt1[m.n_params :])
    assert np.all(vvec >= 0)  # second moment is non-negative
    assert np.any(mvec != 0)


def test_momentum_state_is_velocity():
    m = REGISTRY["mlp"]
    flat = m.build_init()(jnp.int32(0))[0]
    step = jax.jit(m.build_step())
    x, y = batch_for(m)
    lr = jnp.float32(0.1)
    p1, v1, _ = step(flat, jnp.zeros(m.opt_size), lr, jnp.float32(0), x, y)
    # w' = w - lr * v'  must hold exactly
    np.testing.assert_allclose(
        np.asarray(p1), np.asarray(flat - lr * v1), rtol=1e-6, atol=1e-7
    )


def test_tinygpt_forward_only():
    m = REGISTRY["tinygpt"]
    flat = m.build_init()(jnp.int32(0))[0]
    x, y = batch_for(m)
    loss_sum, _, count = jax.jit(m.build_eval())(flat, x, y)
    ppl = float(jnp.exp(loss_sum / count))
    assert 10 < ppl < 1000  # near-uniform over 98-char vocab
