//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no network access, so instead of the real
//! crate we vendor the small subset `sbc` actually uses: a string-backed
//! [`Error`], the [`Result`] alias, the [`anyhow!`]/[`bail!`] macros and
//! the [`Context`] extension trait. Context is concatenated eagerly
//! (`"context: cause"`), so both `{}` and `{:#}` render the full chain.

use std::fmt;

/// A string-backed error. Deliberately does **not** implement
/// `std::error::Error` so the blanket `From<E: std::error::Error>`
/// conversion below can exist (mirroring the real crate's design).
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { msg: msg.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error, mirroring `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))?;
        Ok(())
    }

    #[test]
    fn conversion_and_context() {
        let e = io_fail().context("reading file").unwrap_err();
        assert!(e.to_string().contains("reading file"));
        assert!(e.to_string().contains("gone"));
        let o: Option<u32> = None;
        assert!(o.context("missing").is_err());
    }

    #[test]
    fn macros() {
        fn inner(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x == 0 {
                bail!("zero not allowed");
            }
            Ok(x)
        }
        assert!(inner(-1).is_err());
        assert!(inner(0).is_err());
        assert_eq!(inner(3).unwrap(), 3);
        let e = anyhow!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
    }
}
