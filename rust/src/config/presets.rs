//! Paper-faithful experiment presets (Table III hyperparameters, scaled
//! to this sandbox). Each preset returns the base TrainConfig for one
//! model; benches/examples override iterations and method as needed.

use crate::compression::registry::MethodConfig;
use crate::coordinator::schedule::LrSchedule;
use crate::coordinator::trainer::TrainConfig;

/// Scaled iteration budget per model (paper budgets in parentheses):
/// lenet 2000 (2000), cifarcnn 1200 (60000), charlm 800 (16000),
/// wordlm 800 (60000), mlp 600 (—), tinygpt 300 (—).
pub fn default_iterations(model: &str) -> usize {
    match model {
        "lenet" => 2000,
        "cifarcnn" => 1200,
        "charlm" | "wordlm" => 800,
        "mlp" => 600,
        m if m.starts_with("tinygpt") => 300,
        _ => 600,
    }
}

/// Paper Table III learning rates + decay schedules, milestones rescaled
/// by the iteration-budget ratio.
pub fn lr_schedule(model: &str, iterations: usize) -> LrSchedule {
    match model {
        "lenet" => LrSchedule::constant(0.001), // Adam
        "cifarcnn" => {
            // paper: 0.1 decay at 1/2 and 5/6 of budget (30000/50000 of 60000)
            LrSchedule::step(0.05, 0.1, vec![iterations / 2, iterations * 5 / 6])
        }
        "charlm" => LrSchedule::step(1.0, 0.8, decay_points(iterations, &[5, 8, 10, 12, 14], 16)),
        "wordlm" => LrSchedule::step(1.0, 0.8, decay_points(iterations, &[4, 6, 8, 10], 12)),
        "mlp" => LrSchedule::step(0.1, 0.1, vec![iterations / 2]),
        m if m.starts_with("tinygpt") => LrSchedule::constant(3e-4),
        _ => LrSchedule::constant(0.01),
    }
}

fn decay_points(iterations: usize, numerators: &[usize], denom: usize) -> Vec<usize> {
    numerators.iter().map(|&n| iterations * n / denom).collect()
}

/// The Table II method columns.
pub fn table2_methods() -> Vec<MethodConfig> {
    vec![
        MethodConfig::baseline(),
        MethodConfig::gradient_dropping(),
        MethodConfig::fedavg(100),
        MethodConfig::sbc1(),
        MethodConfig::sbc2(),
        MethodConfig::sbc3(),
    ]
}

/// The Table II model rows (paper: 5 benchmarks; mlp is our extra).
pub fn table2_models() -> Vec<&'static str> {
    vec!["lenet", "cifarcnn", "wordlm", "charlm"]
}

/// Standard preset: model + method + paper-scaled schedule.
pub fn preset(model: &str, method: MethodConfig) -> TrainConfig {
    let iterations = default_iterations(model);
    let lr = lr_schedule(model, iterations);
    let mut cfg = TrainConfig::new(model, method, iterations, lr);
    cfg.eval_every_rounds = (iterations / cfg.method.delay / 20).max(1);
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedules_decay() {
        let s = lr_schedule("cifarcnn", 1200);
        assert!(s.at(0) > s.at(600));
        assert!(s.at(600) > s.at(1100));
        let c = lr_schedule("charlm", 1600);
        assert_eq!(c.at(0), 1.0);
        assert!(c.at(1500) < 0.4);
    }

    #[test]
    fn preset_eval_cadence() {
        let cfg = preset("lenet", MethodConfig::sbc3());
        // delay 100 over 2000 iterations -> 20 rounds, eval every round
        assert_eq!(cfg.eval_every_rounds, 1);
        let cfg2 = preset("lenet", MethodConfig::baseline());
        assert_eq!(cfg2.eval_every_rounds, 100);
    }

    #[test]
    fn table2_shape() {
        assert_eq!(table2_methods().len(), 6);
        assert_eq!(table2_models().len(), 4);
    }
}
