//! Experiment configuration: TOML files + named presets.
//!
//! `sbc-train train --config configs/foo.toml` or
//! `sbc-train train --model lenet --method sbc2 --iterations 2000`.

pub mod presets;

use anyhow::{anyhow, Result};

use crate::codec::message::PosCodec;
use crate::compression::registry::MethodConfig;
use crate::compression::{Granularity, QuantizerCfg, Selection, SelectorCfg};
use crate::coordinator::schedule::LrSchedule;
use crate::coordinator::trainer::TrainConfig;
use crate::formats::toml::{Doc, Value};
use crate::netsim::Link;

/// Parse a method name: "baseline", "fedavg", "gd"/"gradient_dropping",
/// "sbc1"/"sbc2"/"sbc3"/"sbc", "signsgd", "terngrad", "qsgd", "onebit".
pub fn parse_method(name: &str, p: f64, delay: usize) -> Result<MethodConfig> {
    Ok(match name {
        "baseline" => MethodConfig::baseline(),
        "fedavg" => MethodConfig::fedavg(delay.max(2)),
        "gd" | "gradient_dropping" | "dgc" => MethodConfig::builder()
            .select(SelectorCfg::TopK { p, strategy: Selection::Exact })
            .quantize(QuantizerCfg::F32)
            .momentum_masking(true)
            .build(),
        "sbc1" => MethodConfig::sbc1(),
        "sbc2" => MethodConfig::sbc2(),
        "sbc3" => MethodConfig::sbc3(),
        "sbc" => MethodConfig::sbc(p, delay),
        "signsgd" => MethodConfig::signsgd(1e-3),
        "terngrad" => MethodConfig::terngrad(),
        "qsgd" => MethodConfig::qsgd(4),
        "onebit" => MethodConfig::onebit(),
        other => return Err(anyhow!("unknown method '{other}'")),
    })
}

fn parse_link(name: &str) -> Result<Link> {
    Ok(match name {
        "datacenter" | "10g" => Link::datacenter_10g(),
        "wifi" => Link::wifi(),
        "lte" | "mobile" => Link::mobile_lte(),
        "3g" | "rural" => Link::rural_3g(),
        other => return Err(anyhow!("unknown link profile '{other}'")),
    })
}

/// Build a TrainConfig from a parsed TOML doc (all keys optional except
/// model; defaults follow the paper's Table III where applicable).
pub fn train_config_from_doc(doc: &Doc) -> Result<TrainConfig> {
    let model = doc
        .get("model")
        .and_then(Value::as_str)
        .ok_or_else(|| anyhow!("config needs a 'model' key"))?
        .to_string();
    let method_name = doc.str_or("compression.method", "sbc2").to_string();
    let p = doc.f64_or("compression.p", 0.01);
    let delay = doc.i64_or("compression.delay", 1) as usize;
    let mut method = parse_method(&method_name, p, delay)?;
    if let Some(v) = doc.get("compression.momentum_masking").and_then(Value::as_bool) {
        method.momentum_masking = v;
    }
    if let Some(v) = doc.get("compression.residual").and_then(Value::as_bool) {
        method.residual = Some(v);
    }
    if doc.str_or("compression.granularity", "per_tensor") == "global" {
        method.granularity = Granularity::Global;
    }
    if doc.str_or("compression.selection", "exact") == "hist" {
        method.selector = match method.selector {
            SelectorCfg::TopK { p, .. } => SelectorCfg::TopK { p, strategy: Selection::Hist },
            SelectorCfg::TwoSided { p, .. } => {
                SelectorCfg::TwoSided { p, strategy: Selection::Hist }
            }
            dense => dense,
        };
    }

    let iterations = doc.i64_or("train.iterations", 1000) as usize;
    let base_lr = doc.f64_or("train.lr", 0.0) as f32; // 0 -> model default
    let decay = doc.f64_or("train.lr_decay", 0.1) as f32;
    let milestones: Vec<usize> = doc
        .get("train.decay_at")
        .and_then(|v| match v {
            Value::Arr(a) => Some(a.iter().filter_map(Value::as_i64).map(|i| i as usize).collect()),
            _ => None,
        })
        .unwrap_or_default();
    let lr = if milestones.is_empty() {
        LrSchedule::constant(base_lr)
    } else {
        LrSchedule::step(base_lr, decay, milestones)
    };

    let mut cfg = TrainConfig::new(&model, method, iterations, lr);
    cfg.clients = doc.i64_or("train.clients", 4) as usize;
    // default: keep whatever TrainConfig::new resolved (SBC_PARALLELISM
    // env override or 1); results are bit-identical at any setting
    cfg.parallelism = doc.i64_or("train.parallelism", cfg.parallelism as i64).max(1) as usize;
    cfg.eval_every_rounds = doc.i64_or("train.eval_every_rounds", 10) as usize;
    cfg.eval_batches = doc.i64_or("train.eval_batches", 4) as usize;
    cfg.seed = doc.i64_or("seed", 42) as u64;
    cfg.verbose = doc.bool_or("train.verbose", false);
    cfg.use_pjrt_compress = doc.bool_or("compression.use_pjrt", false);
    cfg.pos_codec = match doc.str_or("compression.pos_codec", "golomb") {
        "golomb" => PosCodec::Golomb,
        "fixed16" => PosCodec::Fixed16,
        "elias" => PosCodec::Elias,
        other => return Err(anyhow!("unknown pos codec '{other}'")),
    };
    cfg.uplink = parse_link(doc.str_or("net.uplink", "wifi"))?;
    cfg.downlink = parse_link(doc.str_or("net.downlink", "wifi"))?;
    let t = &mut cfg.transport;
    t.connect_timeout = ms(doc.i64_or("transport.connect_timeout_ms", ms_i64(t.connect_timeout)));
    t.read_timeout = ms(doc.i64_or("transport.read_timeout_ms", ms_i64(t.read_timeout)));
    t.max_retries = doc.i64_or("transport.max_retries", t.max_retries as i64).max(0) as u32;
    t.retry_backoff = ms(doc.i64_or("transport.retry_backoff_ms", ms_i64(t.retry_backoff)));
    t.round_timeout = ms(doc.i64_or("transport.round_timeout_ms", ms_i64(t.round_timeout)));
    let ck = &mut cfg.checkpoint;
    ck.dir = doc.get("checkpoint.dir").and_then(Value::as_str).map(str::to_string);
    ck.every_rounds = doc.i64_or("checkpoint.every_rounds", ck.every_rounds as i64).max(1) as usize;
    ck.keep = doc.i64_or("checkpoint.keep", ck.keep as i64).max(0) as usize;
    Ok(cfg)
}

/// Settings for `sbc-train train --simulate` (the TOML `[sim]` section;
/// every key optional, CLI flags override).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SimSettings {
    /// Number of seeded schedules to sweep (`sim.schedules`).
    pub schedules: u64,
    /// Fault profile name: "none", "light", "harsh" or "mixed"
    /// (alternating light/harsh) (`sim.profile`).
    pub profile: String,
    /// Base seed for the sweep — schedule `i` runs on `seed + i`
    /// (`sim.seed`).
    pub seed: u64,
}

impl Default for SimSettings {
    fn default() -> Self {
        SimSettings { schedules: 20, profile: "mixed".into(), seed: 1 }
    }
}

/// Parse the `[sim]` section of a config doc (defaults where absent).
pub fn sim_settings_from_doc(doc: &Doc) -> SimSettings {
    let d = SimSettings::default();
    SimSettings {
        schedules: doc.i64_or("sim.schedules", d.schedules as i64).max(1) as u64,
        profile: doc.str_or("sim.profile", &d.profile).to_string(),
        seed: doc.i64_or("sim.seed", d.seed as i64).max(0) as u64,
    }
}

/// Read a TOML config file and parse its `[sim]` section.
pub fn load_sim_settings(path: &str) -> Result<SimSettings> {
    let text = std::fs::read_to_string(path)?;
    Ok(sim_settings_from_doc(&Doc::parse(&text)?))
}

/// Settings for structured-event tracing (the TOML `[trace]` section;
/// every key optional, the `--trace <path>` CLI flag overrides).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TraceSettings {
    /// JSONL output path (`trace.path`); `None` leaves tracing to the
    /// `SBC_TRACE` environment variable (or disabled).
    pub path: Option<String>,
}

/// Parse the `[trace]` section of a config doc (defaults where absent).
pub fn trace_settings_from_doc(doc: &Doc) -> TraceSettings {
    TraceSettings { path: doc.get("trace.path").and_then(Value::as_str).map(str::to_string) }
}

/// Read a TOML config file and parse its `[trace]` section.
pub fn load_trace_settings(path: &str) -> Result<TraceSettings> {
    let text = std::fs::read_to_string(path)?;
    Ok(trace_settings_from_doc(&Doc::parse(&text)?))
}

fn ms(v: i64) -> std::time::Duration {
    std::time::Duration::from_millis(v.max(0) as u64)
}

fn ms_i64(d: std::time::Duration) -> i64 {
    d.as_millis() as i64
}

/// Read and parse a TOML config file into a [`TrainConfig`].
pub fn load_train_config(path: &str) -> Result<TrainConfig> {
    let text = std::fs::read_to_string(path)?;
    train_config_from_doc(&Doc::parse(&text)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_config() {
        let doc = Doc::parse(
            r#"
            model = "lenet"
            seed = 7
            [train]
            iterations = 500
            lr = 0.001
            clients = 4
            parallelism = 8
            decay_at = [300]
            [compression]
            method = "sbc"
            p = 0.005
            delay = 20
            momentum_masking = true
            pos_codec = "elias"
            [net]
            uplink = "lte"
            "#,
        )
        .unwrap();
        let cfg = train_config_from_doc(&doc).unwrap();
        assert_eq!(cfg.model, "lenet");
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.parallelism, 8);
        assert_eq!(cfg.method.delay, 20);
        assert!(cfg.method.momentum_masking);
        assert_eq!(cfg.pos_codec, PosCodec::Elias);
        assert_eq!(cfg.method.sbc_p(), Some(0.005));
        assert!((cfg.uplink.bandwidth_bps - 12e6).abs() < 1.0);
        assert_eq!(cfg.lr.at(0), 0.001);
        assert!((cfg.lr.at(300) - 0.0001).abs() < 1e-9);
    }

    #[test]
    fn method_names() {
        assert!(parse_method("baseline", 0.0, 1).is_ok());
        assert!(parse_method("sbc3", 0.0, 1).is_ok());
        assert!(parse_method("qsgd", 0.0, 1).is_ok());
        assert!(parse_method("nope", 0.0, 1).is_err());
        assert_eq!(parse_method("fedavg", 0.0, 100).unwrap().delay, 100);
    }

    #[test]
    fn transport_keys() {
        use std::time::Duration;
        let doc = Doc::parse(
            r#"
            model = "lenet"
            [transport]
            connect_timeout_ms = 100
            read_timeout_ms = 2000
            max_retries = 5
            retry_backoff_ms = 10
            round_timeout_ms = 9000
            "#,
        )
        .unwrap();
        let cfg = train_config_from_doc(&doc).unwrap();
        assert_eq!(cfg.transport.connect_timeout, Duration::from_millis(100));
        assert_eq!(cfg.transport.read_timeout, Duration::from_secs(2));
        assert_eq!(cfg.transport.max_retries, 5);
        assert_eq!(cfg.transport.retry_backoff, Duration::from_millis(10));
        assert_eq!(cfg.transport.round_timeout, Duration::from_secs(9));
        // absent section keeps the defaults
        let plain = train_config_from_doc(&Doc::parse("model = \"lenet\"").unwrap()).unwrap();
        assert_eq!(plain.transport, crate::transport::TransportCfg::default());
    }

    #[test]
    fn sim_keys() {
        let doc = Doc::parse(
            r#"
            model = "lenet"
            [sim]
            schedules = 64
            profile = "harsh"
            seed = 9
            "#,
        )
        .unwrap();
        let sim = sim_settings_from_doc(&doc);
        assert_eq!(sim, SimSettings { schedules: 64, profile: "harsh".into(), seed: 9 });
        // absent section keeps the defaults
        let plain = sim_settings_from_doc(&Doc::parse("model = \"lenet\"").unwrap());
        assert_eq!(plain, SimSettings::default());
    }

    #[test]
    fn trace_keys() {
        let doc = Doc::parse(
            r#"
            model = "lenet"
            [trace]
            path = "run.jsonl"
            "#,
        )
        .unwrap();
        let trace = trace_settings_from_doc(&doc);
        assert_eq!(trace, TraceSettings { path: Some("run.jsonl".into()) });
        // absent section keeps the defaults
        let plain = trace_settings_from_doc(&Doc::parse("model = \"lenet\"").unwrap());
        assert_eq!(plain, TraceSettings::default());
    }

    #[test]
    fn checkpoint_keys() {
        use crate::coordinator::trainer::CheckpointCfg;
        let doc = Doc::parse(
            r#"
            model = "lenet"
            [checkpoint]
            dir = "ckpts"
            every_rounds = 5
            keep = 3
            "#,
        )
        .unwrap();
        let cfg = train_config_from_doc(&doc).unwrap();
        assert_eq!(
            cfg.checkpoint,
            CheckpointCfg { dir: Some("ckpts".into()), every_rounds: 5, keep: 3, resume: false }
        );
        // absent section keeps the defaults (checkpointing disabled)
        let plain = train_config_from_doc(&Doc::parse("model = \"lenet\"").unwrap()).unwrap();
        assert_eq!(plain.checkpoint, CheckpointCfg::default());
    }

    #[test]
    fn missing_model_fails() {
        let doc = Doc::parse("seed = 1").unwrap();
        assert!(train_config_from_doc(&doc).is_err());
    }
}
