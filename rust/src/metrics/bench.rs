//! Machine-readable benchmark artifacts.
//!
//! Every bench harness emits one `BENCH_<name>.json` at the repository
//! root (next to `Cargo.toml`, independent of the invocation CWD)
//! through [`BenchArtifact`], sharing one schema so CI can collect and
//! diff the artifacts uniformly:
//!
//! ```text
//! {
//!   "bench": "<name>",
//!   "config": "<free-form config summary>",
//!   "results": [
//!     {"label": "...", "wall_ns": 1234, "bits": 0, "digest": "00c0ffee00c0ffee", ...}
//!   ]
//! }
//! ```
//!
//! The three shared measurements are wall time (`wall_ns`), payload
//! size (`bits`, 0 when not applicable) and a bit-identity `digest`
//! (hex, 0 when not applicable); bench-specific columns ride along as
//! extra JSON fields via [`BenchRow::field`].

use std::fmt::Write as _;
use std::path::{Path, PathBuf};

/// One measured configuration in a bench artifact.
#[derive(Clone, Debug, Default)]
pub struct BenchRow {
    /// Human-readable row label (e.g. `"256 clients / 8 threads"`).
    pub label: String,
    /// Wall-clock of the measured section, nanoseconds.
    pub wall_ns: u64,
    /// Bits processed or produced by the measured section (0 if n/a).
    pub bits: u64,
    /// Bit-identity digest of the row's output (0 if n/a).
    pub digest: u64,
    extra: Vec<(String, String)>,
}

impl BenchRow {
    /// A row with the three shared measurements.
    pub fn new(label: impl Into<String>, wall_ns: u64, bits: u64, digest: u64) -> BenchRow {
        BenchRow { label: label.into(), wall_ns, bits, digest, extra: Vec::new() }
    }

    /// Attach a bench-specific field. `value` must already be rendered
    /// JSON — a bare number, `"a quoted string"`, `true` — it is
    /// embedded verbatim.
    pub fn field(mut self, key: &str, value: impl Into<String>) -> BenchRow {
        self.extra.push((key.to_string(), value.into()));
        self
    }
}

/// Collects [`BenchRow`]s and writes `BENCH_<name>.json` at the
/// repository root.
#[derive(Clone, Debug)]
pub struct BenchArtifact {
    name: String,
    config: String,
    rows: Vec<BenchRow>,
}

impl BenchArtifact {
    /// Start an artifact for bench `name` with a free-form config
    /// summary (method, sizes swept, env knobs — whatever identifies
    /// the run).
    pub fn new(name: impl Into<String>, config: impl Into<String>) -> BenchArtifact {
        BenchArtifact { name: name.into(), config: config.into(), rows: Vec::new() }
    }

    /// Append one measured row.
    pub fn push(&mut self, row: BenchRow) {
        self.rows.push(row);
    }

    /// Where the artifact lands: `BENCH_<name>.json` next to
    /// `Cargo.toml`, so `cargo bench` run from any directory produces
    /// artifacts in one collectable place.
    pub fn path(&self) -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join(format!("BENCH_{}.json", self.name))
    }

    /// Render the shared JSON schema.
    pub fn to_json(&self) -> String {
        let mut j = String::new();
        let _ = write!(
            j,
            "{{\n  \"bench\": \"{}\",\n  \"config\": \"{}\",\n  \"results\": [\n",
            esc(&self.name),
            esc(&self.config)
        );
        for (i, r) in self.rows.iter().enumerate() {
            let _ = write!(
                j,
                "    {{\"label\": \"{}\", \"wall_ns\": {}, \"bits\": {}, \"digest\": \"{:016x}\"",
                esc(&r.label),
                r.wall_ns,
                r.bits,
                r.digest
            );
            for (k, v) in &r.extra {
                let _ = write!(j, ", \"{}\": {}", esc(k), v);
            }
            j.push_str(if i + 1 == self.rows.len() { "}\n" } else { "},\n" });
        }
        j.push_str("  ]\n}\n");
        j
    }

    /// Write the artifact; returns the path written.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let path = self.path();
        std::fs::write(&path, self.to_json())?;
        Ok(path)
    }
}

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_shared_schema() {
        let mut art = BenchArtifact::new("demo", "2 rows, test config");
        art.push(BenchRow::new("first", 1_000, 64, 0xc0ffee));
        art.push(BenchRow::new("second", 2_000, 0, 0).field("speedup", "1.5"));
        let j = art.to_json();
        assert!(j.contains("\"bench\": \"demo\""));
        assert!(j.contains("\"config\": \"2 rows, test config\""));
        assert!(j.contains("\"label\": \"first\", \"wall_ns\": 1000, \"bits\": 64"));
        assert!(j.contains("\"digest\": \"0000000000c0ffee\""));
        assert!(j.contains("\"speedup\": 1.5"));
        // exactly one trailing row without a comma
        assert_eq!(j.matches("},\n").count(), 1);
    }

    #[test]
    fn path_is_repo_root_bench_json() {
        let art = BenchArtifact::new("scale", "");
        let path = art.path();
        assert!(path.ends_with("BENCH_scale.json"), "{path:?}");
        assert!(path.parent().unwrap().join("Cargo.toml").exists(), "{path:?} not at repo root");
    }

    #[test]
    fn escapes_json_metacharacters() {
        let mut art = BenchArtifact::new("x", "a \"quoted\" \\ line\nnext");
        art.push(BenchRow::new("tab\there", 1, 0, 0));
        let j = art.to_json();
        assert!(j.contains("a \\\"quoted\\\" \\\\ line\\nnext"));
        assert!(j.contains("tab\\u0009here"));
    }
}
