//! Experiment metrics: time-series logging (CSV/JSONL) + run summaries,
//! plus the shared machine-readable bench artifact writer ([`bench`]).

pub mod bench;

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// One evaluation point on the training curve.
#[derive(Clone, Debug)]
pub struct CurvePoint {
    /// Communication round index.
    pub round: usize,
    /// Local iterations completed per client.
    pub iterations: usize,
    /// Cumulative upstream bits for ONE client (paper's per-client axis).
    pub client_up_bits: u64,
    /// Mean per-client training loss of the round.
    pub train_loss: f32,
    /// Held-out loss at this point.
    pub eval_loss: f32,
    /// Accuracy for classifiers, perplexity for LMs.
    pub metric: f32,
}

/// A full training curve plus identity/config fields.
#[derive(Clone, Debug, Default)]
pub struct RunLog {
    /// Model name.
    pub model: String,
    /// Method label (see `MethodConfig::label`).
    pub method: String,
    /// Root seed of the run.
    pub seed: u64,
    /// The curve, one entry per logged evaluation.
    pub points: Vec<CurvePoint>,
    /// Final measured compression rate vs dense baseline.
    pub compression: f64,
    /// Metric of the last curve point.
    pub final_metric: f32,
    /// Total training wall-clock, seconds.
    pub wall_s: f64,
}

impl RunLog {
    /// Append one curve point.
    pub fn push(&mut self, p: CurvePoint) {
        self.points.push(p);
    }

    /// Column names matching [`RunLog::to_csv`].
    pub fn csv_header() -> &'static str {
        "model,method,seed,round,iterations,client_up_bits,train_loss,eval_loss,metric"
    }

    /// Render every curve point as CSV rows (no header). Text fields are
    /// RFC-4180-quoted when needed: method labels contain commas (e.g.
    /// `SBC(p=0.001,n=1)`), which unquoted would shift every downstream
    /// column.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        for p in &self.points {
            let _ = writeln!(
                out,
                "{},{},{},{},{},{},{:.6},{:.6},{:.6}",
                csv_field(&self.model),
                csv_field(&self.method),
                self.seed,
                p.round,
                p.iterations,
                p.client_up_bits,
                p.train_loss,
                p.eval_loss,
                p.metric
            );
        }
        out
    }

    /// Append to a CSV file (creates with header if absent).
    pub fn append_csv(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let new = !Path::new(path).exists();
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        if new {
            writeln!(f, "{}", Self::csv_header())?;
        }
        write!(f, "{}", self.to_csv())
    }
}

/// RFC-4180 field encoding: quote when the value contains a comma, quote
/// or newline; embedded quotes double.
pub fn csv_field(s: &str) -> String {
    if s.contains([',', '"', '\n']) {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// Render an aligned markdown-ish table (used by the bench harnesses to
/// print paper-table reproductions).
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = headers.len();
    let mut width: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            width[i] = width[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let line = |out: &mut String, cells: &[String]| {
        let _ = write!(out, "|");
        for (i, c) in cells.iter().enumerate().take(ncol) {
            let _ = write!(out, " {:>w$} |", c, w = width[i]);
        }
        let _ = writeln!(out);
    };
    line(&mut out, &headers.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    let _ = writeln!(
        out,
        "|{}|",
        width.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|")
    );
    for row in rows {
        line(&mut out, row);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal RFC-4180 row parser for the roundtrip assertions.
    fn parse_csv_row(line: &str) -> Vec<String> {
        let mut fields = Vec::new();
        let mut cur = String::new();
        let mut quoted = false;
        let mut chars = line.chars().peekable();
        while let Some(c) = chars.next() {
            match (quoted, c) {
                (false, ',') => fields.push(std::mem::take(&mut cur)),
                (false, '"') => quoted = true,
                (true, '"') => {
                    if chars.peek() == Some(&'"') {
                        chars.next();
                        cur.push('"');
                    } else {
                        quoted = false;
                    }
                }
                (_, c) => cur.push(c),
            }
        }
        fields.push(cur);
        fields
    }

    #[test]
    fn csv_roundtrip_fields() {
        let mut log = RunLog {
            model: "mlp".into(),
            method: "SBC(p=0.001,n=1)".into(),
            seed: 1,
            ..Default::default()
        };
        log.push(CurvePoint {
            round: 1,
            iterations: 10,
            client_up_bits: 1234,
            train_loss: 0.5,
            eval_loss: 0.6,
            metric: 0.9,
        });
        let csv = log.to_csv();
        // the comma-bearing label is quoted, so the row keeps exactly as
        // many columns as the header
        let cols = parse_csv_row(csv.trim());
        assert_eq!(cols.len(), RunLog::csv_header().split(',').count());
        assert_eq!(cols[0], "mlp");
        assert_eq!(cols[1], "SBC(p=0.001,n=1)");
        assert_eq!(&cols[2..6], ["1", "1", "10", "1234"]);
        assert_eq!(cols[6], "0.500000");
    }

    #[test]
    fn csv_field_quotes_per_rfc4180() {
        assert_eq!(csv_field("plain"), "plain");
        assert_eq!(csv_field("a,b"), "\"a,b\"");
        assert_eq!(csv_field("say \"hi\""), "\"say \"\"hi\"\"\"");
        assert_eq!(csv_field("two\nlines"), "\"two\nlines\"");
    }

    #[test]
    fn table_alignment() {
        let t = render_table(
            &["method", "acc"],
            &[vec!["SBC".into(), "0.99".into()], vec!["Baseline".into(), "0.991".into()]],
        );
        assert!(t.contains("| Baseline |"));
        assert!(t.lines().count() == 4);
    }
}
