//! Minimal JSON parser + writer (serde is not in the vendored
//! dependency set, so the two formats this repo needs are hand-rolled).
//!
//! Supports the full JSON grammar minus exotic number forms; good enough
//! for `artifacts/manifest.json` and metrics output. Numbers are f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (f64 precision).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object with sorted keys.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    /// Object member by key (`None` for non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The string payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric payload, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The numeric payload truncated to usize.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// The element slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The member map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or("unterminated string")? {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u")?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                }
                _ => {
                    // copy a run of plain bytes (UTF-8 passes through)
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(|_| "bad utf8")?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let s = r#"{"format": 1, "models": {"mlp": {"n_params": 266610,
            "x_shape": [64, 784], "meta": {"default_lr": 0.1}, "ok": true,
            "none": null, "name": "m\np"}}}"#;
        let j = Json::parse(s).unwrap();
        assert_eq!(j.get("format").unwrap().as_usize(), Some(1));
        let mlp = j.get("models").unwrap().get("mlp").unwrap();
        assert_eq!(mlp.get("n_params").unwrap().as_usize(), Some(266610));
        assert_eq!(mlp.get("x_shape").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(mlp.get("meta").unwrap().get("default_lr").unwrap().as_f64(), Some(0.1));
        assert_eq!(mlp.get("name").unwrap().as_str(), Some("m\np"));
    }

    #[test]
    fn roundtrip() {
        let s = r#"{"a":[1,2.5,-3],"b":"x\"y","c":false,"d":null}"#;
        let j = Json::parse(s).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }
}
