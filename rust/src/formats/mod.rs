//! Hand-rolled data formats (no serde in the vendored dependency set):
//! JSON (manifest, metrics) and a TOML subset (experiment configs).

pub mod json;
pub mod toml;
