//! Minimal TOML subset parser for experiment config files.
//!
//! Supported: `[section]`, `[section.sub]`, `key = value` with string,
//! integer, float, boolean and flat-array values, `#` comments. This
//! covers everything the preset configs in `configs/` use.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// A parsed TOML value (the subset the configs use).
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// Quoted string.
    Str(String),
    /// Integer literal.
    Int(i64),
    /// Float literal.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// Flat array of values.
    Arr(Vec<Value>),
}

impl Value {
    /// The string payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The integer payload, if this is an integer.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// The numeric payload (floats, and integers widened to f64).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// The boolean payload, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Flat document: keys are `section.key` (or bare `key` for the root).
#[derive(Clone, Debug, Default)]
pub struct Doc {
    /// All parsed entries, keyed by dotted path.
    pub entries: BTreeMap<String, Value>,
}

impl Doc {
    /// Parse TOML text into a flat document.
    pub fn parse(text: &str) -> Result<Doc> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(sec) = line.strip_prefix('[') {
                let sec = sec
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("line {}: bad section", lineno + 1))?;
                section = sec.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{}.{}", section, k.trim())
            };
            entries.insert(key, parse_value(v.trim()).map_err(|e| anyhow!("line {}: {e}", lineno + 1))?);
        }
        Ok(Doc { entries })
    }

    /// Look up a value by dotted key (`"train.clients"`).
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    /// String at `key`, or `default` when absent/mistyped.
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Value::as_str).unwrap_or(default)
    }

    /// Integer at `key`, or `default` when absent/mistyped.
    pub fn i64_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(Value::as_i64).unwrap_or(default)
    }

    /// Float at `key`, or `default` when absent/mistyped.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }

    /// Boolean at `key`, or `default` when absent/mistyped.
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quoted strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or_else(|| anyhow!("unterminated string"))?;
        return Ok(Value::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or_else(|| anyhow!("unterminated array"))?;
        let mut out = Vec::new();
        for part in split_top_level(inner) {
            let part = part.trim();
            if !part.is_empty() {
                out.push(parse_value(part)?);
            }
        }
        return Ok(Value::Arr(out));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value: {s}")
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_config() {
        let doc = Doc::parse(
            r#"
            # experiment config
            name = "sbc3"        # inline comment
            seed = 42
            [train]
            rounds = 100
            lr = 0.05
            clients = 4
            decay_at = [30, 60]
            verbose = false
            [compression]
            method = "sbc"
            p = 0.01
            "#,
        )
        .unwrap();
        assert_eq!(doc.str_or("name", ""), "sbc3");
        assert_eq!(doc.i64_or("seed", 0), 42);
        assert_eq!(doc.i64_or("train.rounds", 0), 100);
        assert_eq!(doc.f64_or("train.lr", 0.0), 0.05);
        assert!(!doc.bool_or("train.verbose", true));
        assert_eq!(doc.f64_or("compression.p", 0.0), 0.01);
        match doc.get("train.decay_at").unwrap() {
            Value::Arr(a) => assert_eq!(a.len(), 2),
            _ => panic!(),
        }
        // defaults
        assert_eq!(doc.i64_or("train.missing", 7), 7);
    }

    #[test]
    fn string_with_hash_and_escape() {
        let doc = Doc::parse(r#"k = "a#b\"c""#).unwrap();
        assert_eq!(doc.str_or("k", ""), "a#b\"c");
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Doc::parse("[unclosed").is_err());
        assert!(Doc::parse("novalue").is_err());
        assert!(Doc::parse("k = ").is_err());
    }
}
