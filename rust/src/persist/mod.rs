//! Durable checkpoint & crash-recovery (ARCHITECTURE.md §8).
//!
//! A std-only snapshot subsystem covering both halves of the
//! federation:
//!
//! * [`format`] — the versioned, CRC-guarded snapshot layout
//!   ([`ServerSnapshot`], [`ClientSnapshot`]) with typed
//!   [`PersistError`] load failures for truncated, corrupt,
//!   version- or config-mismatched files;
//! * [`store`] — [`CheckpointStore`]: atomic write-rename persistence
//!   into a checkpoint directory with a retained-generations policy.
//!
//! The invariant the subsystem exists to uphold: a run that crashes at
//! any snapshot barrier and resumes from disk produces weight digests
//! **bit-identical** to the uninterrupted run, with `CommStats`/`NetSim`
//! accounting reconciling exactly. Everything convergence-relevant —
//! weights, optimizer moments, error-feedback residuals, and every RNG
//! cursor — is captured; nothing is re-derived approximately.

pub mod format;
pub mod store;

pub use format::{
    decode_client, decode_server, encode_client, encode_server, peek_round, CachedReply,
    ClientSnapshot, PersistError, Role, ServerSnapshot,
};
pub use store::{atomic_write, CheckpointStore};
