//! The snapshot wire format: a versioned, CRC-guarded, length-prefixed
//! binary layout shared by server and client checkpoints.
//!
//! Layout (big-endian, mirroring the transport frame conventions):
//!
//! ```text
//! magic:          u32   0x5342_434B  (b"SBCK")
//! format version: u16   1
//! role:           u8    0 = server, 1 = client
//! reserved:       u8    0
//! client:         u32   client id (u32::MAX for server snapshots)
//! config digest:  u64   transport::config_digest of the TrainConfig
//! round:          u32   next round the snapshot resumes into
//! payload length: u32   bytes of payload that follow
//! payload:        [u8]  role-specific body (see below)
//! crc:            u32   CRC-32 over every preceding byte
//! ```
//!
//! Every load failure is a typed [`PersistError`] — a truncated file, a
//! flipped bit, a foreign config or a role/client mix-up can never panic
//! or silently resume wrong state. The CRC covers the whole file, so any
//! single-bit corruption is caught even when it lands in a length field.

use std::fmt;

use crate::transport::frame::crc32;
use crate::util::bytes::{be_u16, be_u32, be_u64};

/// Snapshot file magic (`b"SBCK"` big-endian).
pub const MAGIC: u32 = 0x5342_434B;
/// Current snapshot format version.
pub const VERSION: u16 = 1;
/// Fixed header size in bytes (everything before the payload).
pub const HEADER_BYTES: usize = 28;
/// `client` field value marking a server snapshot.
pub const SERVER_CLIENT_ID: u32 = u32::MAX;

/// Which side of the federation a snapshot belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    /// The aggregation server (or the in-process trainer's server half).
    Server,
    /// One client session.
    Client,
}

impl Role {
    fn tag(self) -> u8 {
        match self {
            Role::Server => 0,
            Role::Client => 1,
        }
    }

    fn from_tag(t: u8) -> Option<Role> {
        match t {
            0 => Some(Role::Server),
            1 => Some(Role::Client),
            _ => None,
        }
    }
}

/// Typed snapshot load/store failures. Loading never panics on hostile
/// input; every damage mode maps to one of these.
#[derive(Debug)]
pub enum PersistError {
    /// Filesystem error (open, read, write, rename, sync).
    Io(std::io::Error),
    /// The file ends before the declared layout does.
    Truncated,
    /// The leading magic is not `SBCK` — not a snapshot file.
    BadMagic,
    /// A snapshot from an unknown format version.
    BadVersion(u16),
    /// The CRC-32 trailer does not match the file contents.
    BadCrc,
    /// The snapshot was written under a different `TrainConfig`.
    ConfigMismatch {
        /// Digest the loader expected.
        expected: u64,
        /// Digest found in the file.
        found: u64,
    },
    /// The snapshot belongs to a different role or client id.
    RoleMismatch,
    /// Structurally invalid payload (bad enum tag, trailing bytes, …).
    Corrupt(&'static str),
}

impl fmt::Display for PersistError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PersistError::Io(e) => write!(f, "snapshot I/O error: {e}"),
            PersistError::Truncated => write!(f, "snapshot truncated"),
            PersistError::BadMagic => write!(f, "not a snapshot file (bad magic)"),
            PersistError::BadVersion(v) => write!(f, "unknown snapshot format version {v}"),
            PersistError::BadCrc => write!(f, "snapshot CRC mismatch (corrupt file)"),
            PersistError::ConfigMismatch { expected, found } => write!(
                f,
                "snapshot config digest {found:016x} does not match this run's {expected:016x}"
            ),
            PersistError::RoleMismatch => write!(f, "snapshot belongs to a different role/client"),
            PersistError::Corrupt(what) => write!(f, "snapshot payload corrupt: {what}"),
        }
    }
}

impl std::error::Error for PersistError {}

impl From<std::io::Error> for PersistError {
    fn from(e: std::io::Error) -> Self {
        PersistError::Io(e)
    }
}

/// The server's previous-round broadcast, persisted so a restarted
/// server can serve stragglers that re-request the round it already
/// finished (the depth-1 reply cache survives the crash).
#[derive(Clone, Debug, PartialEq)]
pub struct CachedReply {
    /// Round the cached broadcast belongs to.
    pub round: u32,
    /// Encoded broadcast bytes.
    pub bytes: Vec<u8>,
    /// Exact payload bit-length of the broadcast.
    pub bits: u64,
    /// Final weight digest, present when the cached round was the last.
    pub done: Option<u64>,
}

/// Everything the server needs to resume a run at a round barrier.
#[derive(Clone, Debug, PartialEq)]
pub struct ServerSnapshot {
    /// Next round to collect (rounds `0..round` are fully applied).
    pub round: u32,
    /// Aggregate model weights after round `round - 1`.
    pub master: Vec<f32>,
    /// `CommStats` counters, field order: upstream, messages, nonzeros,
    /// baseline, frame-overhead bits.
    pub comm: [u64; 5],
    /// Per-client `NetSim` counters: `(up_bits, down_bits,
    /// up_time_s.to_bits(), down_time_s.to_bits(), messages)`.
    pub net_clients: Vec<(u64, u64, u64, u64, u64)>,
    /// `NetSim::total_comm_time_s.to_bits()`.
    pub net_total_time_bits: u64,
    /// Per-client ledger: last round each client completed (`u32::MAX`
    /// when a client has not completed any round yet).
    pub ledger: Vec<u32>,
    /// The previous round's broadcast, for straggler re-service.
    pub cache: Option<CachedReply>,
}

/// Everything one client needs to resume its session at a round barrier.
#[derive(Clone, Debug, PartialEq)]
pub struct ClientSnapshot {
    /// Client id.
    pub client: u32,
    /// Next round to train (rounds `0..round` are fully applied).
    pub round: u32,
    /// Local model weights (empty in the in-process trainer, which
    /// shares one master vector across clients).
    pub weights: Vec<f32>,
    /// Flat optimizer state (momentum / Adam moments).
    pub opt: Vec<f32>,
    /// Error-feedback residual vector.
    pub residual: Vec<f32>,
    /// Whether error feedback is active.
    pub residual_enabled: bool,
    /// Local iterations completed (Adam bias-correction step index).
    pub iterations: u64,
    /// Payload bits this client has uploaded so far.
    pub up_bits: u64,
    /// Data-sampling RNG cursor.
    pub rng: [u64; 4],
    /// Selector-stage RNG cursor.
    pub selector_rng: [u64; 4],
    /// Quantizer-stage RNG cursor.
    pub quantizer_rng: [u64; 4],
}

// ---------------------------------------------------------------------
// payload writer / reader
// ---------------------------------------------------------------------

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn new() -> Writer {
        Writer { buf: Vec::new() }
    }

    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_be_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_be_bytes());
    }

    fn f32s(&mut self, xs: &[f32]) {
        self.u32(xs.len() as u32);
        for &x in xs {
            self.u32(x.to_bits());
        }
    }

    fn rng(&mut self, s: [u64; 4]) {
        for w in s {
            self.u64(w);
        }
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], PersistError> {
        if self.buf.len() - self.pos < n {
            return Err(PersistError::Truncated);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, PersistError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, PersistError> {
        Ok(be_u32(self.take(4)?, 0))
    }

    fn u64(&mut self) -> Result<u64, PersistError> {
        Ok(be_u64(self.take(8)?, 0))
    }

    fn f32s(&mut self) -> Result<Vec<f32>, PersistError> {
        let n = self.u32()? as usize;
        // bound the allocation by the bytes actually present
        if self.buf.len() - self.pos < n * 4 {
            return Err(PersistError::Truncated);
        }
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(f32::from_bits(self.u32()?));
        }
        Ok(v)
    }

    fn rng(&mut self) -> Result<[u64; 4], PersistError> {
        Ok([self.u64()?, self.u64()?, self.u64()?, self.u64()?])
    }

    fn finish(self) -> Result<(), PersistError> {
        if self.pos != self.buf.len() {
            return Err(PersistError::Corrupt("trailing bytes after payload"));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// encode / decode
// ---------------------------------------------------------------------

fn encode(role: Role, client: u32, round: u32, config_digest: u64, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_BYTES + payload.len() + 4);
    out.extend_from_slice(&MAGIC.to_be_bytes());
    out.extend_from_slice(&VERSION.to_be_bytes());
    out.push(role.tag());
    out.push(0); // reserved
    out.extend_from_slice(&client.to_be_bytes());
    out.extend_from_slice(&config_digest.to_be_bytes());
    out.extend_from_slice(&round.to_be_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_be_bytes());
    out.extend_from_slice(payload);
    let crc = crc32(&[&out]);
    out.extend_from_slice(&crc.to_be_bytes());
    out
}

/// The validated header of a snapshot file, minus role-specific payload.
struct Header {
    role: Role,
    client: u32,
    config_digest: u64,
    round: u32,
}

/// Validate framing + CRC and return the header and payload slice.
fn check(bytes: &[u8]) -> Result<(Header, &[u8]), PersistError> {
    if bytes.len() < HEADER_BYTES {
        return Err(PersistError::Truncated);
    }
    let magic = be_u32(bytes, 0);
    if magic != MAGIC {
        return Err(PersistError::BadMagic);
    }
    let version = be_u16(bytes, 4);
    if version != VERSION {
        return Err(PersistError::BadVersion(version));
    }
    let role = Role::from_tag(bytes[6]).ok_or(PersistError::Corrupt("unknown role tag"))?;
    let client = be_u32(bytes, 8);
    let config_digest = be_u64(bytes, 12);
    let round = be_u32(bytes, 20);
    let payload_len = be_u32(bytes, 24) as usize;
    let total = HEADER_BYTES
        .checked_add(payload_len)
        .and_then(|t| t.checked_add(4))
        .ok_or(PersistError::Corrupt("payload length overflows"))?;
    if bytes.len() < total {
        return Err(PersistError::Truncated);
    }
    if bytes.len() > total {
        return Err(PersistError::Corrupt("trailing bytes after CRC"));
    }
    let crc = be_u32(bytes, total - 4);
    if crc != crc32(&[&bytes[..total - 4]]) {
        return Err(PersistError::BadCrc);
    }
    let payload = &bytes[HEADER_BYTES..total - 4];
    Ok((Header { role, client, config_digest, round }, payload))
}

fn check_identity(
    h: &Header,
    role: Role,
    client: u32,
    config_digest: u64,
) -> Result<(), PersistError> {
    if h.role != role || h.client != client {
        return Err(PersistError::RoleMismatch);
    }
    if h.config_digest != config_digest {
        return Err(PersistError::ConfigMismatch {
            expected: config_digest,
            found: h.config_digest,
        });
    }
    Ok(())
}

/// Serialize a server snapshot under `config_digest`.
pub fn encode_server(snap: &ServerSnapshot, config_digest: u64) -> Vec<u8> {
    let mut w = Writer::new();
    w.f32s(&snap.master);
    for c in snap.comm {
        w.u64(c);
    }
    w.u32(snap.net_clients.len() as u32);
    for &(up, down, ut, dt, msgs) in &snap.net_clients {
        w.u64(up);
        w.u64(down);
        w.u64(ut);
        w.u64(dt);
        w.u64(msgs);
    }
    w.u64(snap.net_total_time_bits);
    w.u32(snap.ledger.len() as u32);
    for &r in &snap.ledger {
        w.u32(r);
    }
    match &snap.cache {
        None => w.u8(0),
        Some(c) => {
            w.u8(1);
            w.u32(c.round);
            w.u64(c.bits);
            match c.done {
                None => w.u8(0),
                Some(d) => {
                    w.u8(1);
                    w.u64(d);
                }
            }
            w.u32(c.bytes.len() as u32);
            w.buf.extend_from_slice(&c.bytes);
        }
    }
    encode(Role::Server, SERVER_CLIENT_ID, snap.round, config_digest, &w.buf)
}

/// Deserialize and validate a server snapshot written under
/// `config_digest`. Every damage mode returns a typed [`PersistError`].
pub fn decode_server(bytes: &[u8], config_digest: u64) -> Result<ServerSnapshot, PersistError> {
    let (h, payload) = check(bytes)?;
    check_identity(&h, Role::Server, SERVER_CLIENT_ID, config_digest)?;
    let mut r = Reader::new(payload);
    let master = r.f32s()?;
    let mut comm = [0u64; 5];
    for c in &mut comm {
        *c = r.u64()?;
    }
    let n = r.u32()? as usize;
    if payload.len() - r.pos < n * 8 {
        return Err(PersistError::Truncated);
    }
    let mut net_clients = Vec::with_capacity(n);
    for _ in 0..n {
        net_clients.push((r.u64()?, r.u64()?, r.u64()?, r.u64()?, r.u64()?));
    }
    let net_total_time_bits = r.u64()?;
    let m = r.u32()? as usize;
    if payload.len() - r.pos < m * 4 {
        return Err(PersistError::Truncated);
    }
    let mut ledger = Vec::with_capacity(m);
    for _ in 0..m {
        ledger.push(r.u32()?);
    }
    let cache = match r.u8()? {
        0 => None,
        1 => {
            let round = r.u32()?;
            let bits = r.u64()?;
            let done = match r.u8()? {
                0 => None,
                1 => Some(r.u64()?),
                _ => return Err(PersistError::Corrupt("bad done flag")),
            };
            let blen = r.u32()? as usize;
            let bytes = r.take(blen)?.to_vec();
            Some(CachedReply { round, bits, bytes, done })
        }
        _ => return Err(PersistError::Corrupt("bad cache flag")),
    };
    r.finish()?;
    Ok(ServerSnapshot {
        round: h.round,
        master,
        comm,
        net_clients,
        net_total_time_bits,
        ledger,
        cache,
    })
}

/// Serialize a client snapshot under `config_digest`.
pub fn encode_client(snap: &ClientSnapshot, config_digest: u64) -> Vec<u8> {
    let mut w = Writer::new();
    w.f32s(&snap.weights);
    w.f32s(&snap.opt);
    w.f32s(&snap.residual);
    w.u8(snap.residual_enabled as u8);
    w.u64(snap.iterations);
    w.u64(snap.up_bits);
    w.rng(snap.rng);
    w.rng(snap.selector_rng);
    w.rng(snap.quantizer_rng);
    encode(Role::Client, snap.client, snap.round, config_digest, &w.buf)
}

/// Deserialize and validate a client snapshot for `client` written
/// under `config_digest`.
pub fn decode_client(
    bytes: &[u8],
    client: u32,
    config_digest: u64,
) -> Result<ClientSnapshot, PersistError> {
    let (h, payload) = check(bytes)?;
    check_identity(&h, Role::Client, client, config_digest)?;
    let mut r = Reader::new(payload);
    let weights = r.f32s()?;
    let opt = r.f32s()?;
    let residual = r.f32s()?;
    let residual_enabled = match r.u8()? {
        0 => false,
        1 => true,
        _ => return Err(PersistError::Corrupt("bad residual flag")),
    };
    let iterations = r.u64()?;
    let up_bits = r.u64()?;
    let rng = r.rng()?;
    let selector_rng = r.rng()?;
    let quantizer_rng = r.rng()?;
    r.finish()?;
    Ok(ClientSnapshot {
        client: h.client,
        round: h.round,
        weights,
        opt,
        residual,
        residual_enabled,
        iterations,
        up_bits,
        rng,
        selector_rng,
        quantizer_rng,
    })
}

/// The round field of a snapshot file without decoding the payload
/// (still CRC-validated — used to find a common restorable round).
pub fn peek_round(bytes: &[u8]) -> Result<u32, PersistError> {
    let (h, _) = check(bytes)?;
    Ok(h.round)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_server() -> ServerSnapshot {
        ServerSnapshot {
            round: 7,
            master: vec![1.0, -2.5, 0.0, f32::MIN_POSITIVE],
            comm: [10, 20, 30, 40, 50],
            net_clients: vec![(1, 2, 3, 4, 5), (6, 7, 8, 9, 10)],
            net_total_time_bits: 0.25f64.to_bits(),
            ledger: vec![6, u32::MAX],
            cache: Some(CachedReply { round: 6, bits: 123, bytes: vec![9, 8, 7], done: None }),
        }
    }

    fn sample_client() -> ClientSnapshot {
        ClientSnapshot {
            client: 3,
            round: 7,
            weights: vec![0.5, -0.5],
            opt: vec![0.1; 4],
            residual: vec![0.0, 1.0],
            residual_enabled: true,
            iterations: 700,
            up_bits: 4096,
            rng: [1, 2, 3, 4],
            selector_rng: [5, 6, 7, 8],
            quantizer_rng: [9, 10, 11, 12],
        }
    }

    #[test]
    fn server_roundtrip_bit_identical() {
        let snap = sample_server();
        let bytes = encode_server(&snap, 0xDEAD);
        assert_eq!(decode_server(&bytes, 0xDEAD).unwrap(), snap);
        assert_eq!(peek_round(&bytes).unwrap(), 7);
    }

    #[test]
    fn client_roundtrip_bit_identical() {
        let snap = sample_client();
        let bytes = encode_client(&snap, 0xBEEF);
        assert_eq!(decode_client(&bytes, 3, 0xBEEF).unwrap(), snap);
    }

    #[test]
    fn identity_checks_are_typed() {
        let bytes = encode_client(&sample_client(), 0xBEEF);
        assert!(matches!(
            decode_client(&bytes, 4, 0xBEEF),
            Err(PersistError::RoleMismatch)
        ));
        assert!(matches!(
            decode_client(&bytes, 3, 0xF00D),
            Err(PersistError::ConfigMismatch { .. })
        ));
        assert!(matches!(
            decode_server(&bytes, 0xBEEF),
            Err(PersistError::RoleMismatch)
        ));
    }

    #[test]
    fn every_truncation_is_typed() {
        let bytes = encode_server(&sample_server(), 1);
        for n in 0..bytes.len() {
            let err = decode_server(&bytes[..n], 1).unwrap_err();
            assert!(
                matches!(
                    err,
                    PersistError::Truncated | PersistError::BadCrc | PersistError::Corrupt(_)
                ),
                "truncation to {n} gave {err}"
            );
        }
    }

    #[test]
    fn every_bitflip_is_typed() {
        let bytes = encode_client(&sample_client(), 1);
        for bit in 0..bytes.len() * 8 {
            let mut bad = bytes.clone();
            bad[bit / 8] ^= 1 << (bit % 8);
            assert!(decode_client(&bad, 3, 1).is_err(), "bit {bit} accepted");
        }
    }

    #[test]
    fn version_gate() {
        let mut bytes = encode_client(&sample_client(), 1);
        bytes[5] = 99; // version low byte
        // recompute CRC so only the version differs
        let len = bytes.len();
        let crc = crc32(&[&bytes[..len - 4]]);
        bytes[len - 4..].copy_from_slice(&crc.to_be_bytes());
        assert!(matches!(decode_client(&bytes, 3, 1), Err(PersistError::BadVersion(99))));
    }
}
