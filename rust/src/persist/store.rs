//! Durable checkpoint storage: atomic write-rename persistence with a
//! retained-generations policy.
//!
//! Every save goes through [`atomic_write`]: the bytes land in a
//! `.tmp-` sibling first (created with `create_new`, never truncating
//! an existing snapshot), are fsynced, and only then renamed over the
//! final name — a crash mid-save can lose the *new* generation but
//! never damage an existing one. After each save, generations beyond
//! the `keep` budget are pruned oldest-first per role.

use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

use super::format::{
    decode_client, decode_server, encode_client, encode_server, ClientSnapshot, PersistError,
    ServerSnapshot,
};

/// A checkpoint directory plus its retention policy.
#[derive(Clone, Debug)]
pub struct CheckpointStore {
    dir: PathBuf,
    keep: usize,
}

impl CheckpointStore {
    /// Open (creating if needed) a checkpoint directory. `keep` is the
    /// number of generations retained per role (`0` = keep everything).
    pub fn open(dir: impl Into<PathBuf>, keep: usize) -> Result<CheckpointStore, PersistError> {
        let dir = dir.into();
        fs::create_dir_all(&dir)?;
        Ok(CheckpointStore { dir, keep })
    }

    /// The directory this store persists into.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn server_name(round: u32) -> String {
        format!("server-r{round:08}.ckpt")
    }

    fn client_name(client: u32, round: u32) -> String {
        format!("client{client:04}-r{round:08}.ckpt")
    }

    /// Persist a server snapshot atomically; returns the final path.
    pub fn save_server(
        &self,
        snap: &ServerSnapshot,
        config_digest: u64,
    ) -> Result<PathBuf, PersistError> {
        let path = self.dir.join(Self::server_name(snap.round));
        atomic_write(&path, &encode_server(snap, config_digest))?;
        self.prune("server-r", snap.round)?;
        Ok(path)
    }

    /// Persist a client snapshot atomically; returns the final path.
    pub fn save_client(
        &self,
        snap: &ClientSnapshot,
        config_digest: u64,
    ) -> Result<PathBuf, PersistError> {
        let path = self.dir.join(Self::client_name(snap.client, snap.round));
        atomic_write(&path, &encode_client(snap, config_digest))?;
        self.prune(&format!("client{:04}-r", snap.client), snap.round)?;
        Ok(path)
    }

    /// Load the newest server snapshot, if any exists. Damage in that
    /// newest generation is a typed error, not a silent fallback.
    pub fn load_latest_server(
        &self,
        config_digest: u64,
    ) -> Result<Option<ServerSnapshot>, PersistError> {
        match self.latest("server-r")? {
            None => Ok(None),
            Some(path) => Ok(Some(decode_server(&fs::read(path)?, config_digest)?)),
        }
    }

    /// Load the newest snapshot of `client`, if any exists.
    pub fn load_latest_client(
        &self,
        client: u32,
        config_digest: u64,
    ) -> Result<Option<ClientSnapshot>, PersistError> {
        match self.latest(&format!("client{client:04}-r"))? {
            None => Ok(None),
            Some(path) => Ok(Some(decode_client(&fs::read(path)?, client, config_digest)?)),
        }
    }

    /// Load the server snapshot for an exact round, if present.
    pub fn load_server_at(
        &self,
        round: u32,
        config_digest: u64,
    ) -> Result<Option<ServerSnapshot>, PersistError> {
        let path = self.dir.join(Self::server_name(round));
        if !path.exists() {
            return Ok(None);
        }
        Ok(Some(decode_server(&fs::read(path)?, config_digest)?))
    }

    /// Load the snapshot of `client` for an exact round, if present.
    pub fn load_client_at(
        &self,
        client: u32,
        round: u32,
        config_digest: u64,
    ) -> Result<Option<ClientSnapshot>, PersistError> {
        let path = self.dir.join(Self::client_name(client, round));
        if !path.exists() {
            return Ok(None);
        }
        Ok(Some(decode_client(&fs::read(path)?, client, config_digest)?))
    }

    /// Rounds for which a snapshot with the given filename prefix exists,
    /// ascending.
    fn rounds(&self, prefix: &str) -> Result<Vec<u32>, PersistError> {
        let mut rounds = Vec::new();
        for entry in fs::read_dir(&self.dir)? {
            let name = entry?.file_name();
            let name = name.to_string_lossy();
            if let Some(rest) = name.strip_prefix(prefix) {
                if let Some(digits) = rest.strip_suffix(".ckpt") {
                    if let Ok(r) = digits.parse::<u32>() {
                        rounds.push(r);
                    }
                }
            }
        }
        rounds.sort_unstable();
        Ok(rounds)
    }

    fn latest(&self, prefix: &str) -> Result<Option<PathBuf>, PersistError> {
        Ok(self
            .rounds(prefix)?
            .last()
            .map(|r| self.dir.join(format!("{prefix}{r:08}.ckpt"))))
    }

    /// Remove generations older than the `keep` newest (never the one
    /// just written at `just_wrote`).
    fn prune(&self, prefix: &str, just_wrote: u32) -> Result<(), PersistError> {
        if self.keep == 0 {
            return Ok(());
        }
        let rounds = self.rounds(prefix)?;
        if rounds.len() <= self.keep {
            return Ok(());
        }
        for &r in &rounds[..rounds.len() - self.keep] {
            if r != just_wrote {
                let _ = fs::remove_file(self.dir.join(format!("{prefix}{r:08}.ckpt")));
            }
        }
        Ok(())
    }
}

/// Write `bytes` to `path` atomically: create a fresh temp sibling,
/// write + fsync it, then rename over the final name. The temp file
/// uses `create_new` so a concurrent or stale temp is an error rather
/// than a silent truncation.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> Result<(), PersistError> {
    let tmp = path.with_extension("ckpt.tmp");
    // remove a stale temp from a previous crashed save, then create_new
    // guarantees we never truncate a file another writer has open
    let _ = fs::remove_file(&tmp);
    let mut f = fs::OpenOptions::new().write(true).create_new(true).open(&tmp)?;
    f.write_all(bytes)?;
    f.sync_all()?;
    drop(f);
    fs::rename(&tmp, path)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::super::format::CachedReply;
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("sbc-persist-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn server_snap(round: u32) -> ServerSnapshot {
        ServerSnapshot {
            round,
            master: vec![round as f32; 3],
            comm: [1, 2, 3, 4, 5],
            net_clients: vec![(1, 1, 1, 1, 1)],
            net_total_time_bits: 0,
            ledger: vec![round.wrapping_sub(1)],
            cache: Some(CachedReply { round, bits: 8, bytes: vec![1], done: Some(42) }),
        }
    }

    #[test]
    fn save_load_and_retention() {
        let dir = tmpdir("retain");
        let store = CheckpointStore::open(&dir, 2).unwrap();
        for r in 1..=5 {
            store.save_server(&server_snap(r), 9).unwrap();
        }
        // only the 2 newest generations remain
        let names: Vec<_> = fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names.len(), 2, "{names:?}");
        let latest = store.load_latest_server(9).unwrap().unwrap();
        assert_eq!(latest, server_snap(5));
        assert_eq!(store.load_server_at(4, 9).unwrap().unwrap().round, 4);
        assert!(store.load_server_at(1, 9).unwrap().is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn keep_zero_retains_everything() {
        let dir = tmpdir("keepall");
        let store = CheckpointStore::open(&dir, 0).unwrap();
        for r in 1..=4 {
            store.save_server(&server_snap(r), 9).unwrap();
        }
        assert_eq!(store.rounds("server-r").unwrap(), vec![1, 2, 3, 4]);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_store_loads_none() {
        let dir = tmpdir("empty");
        let store = CheckpointStore::open(&dir, 1).unwrap();
        assert!(store.load_latest_server(1).unwrap().is_none());
        assert!(store.load_latest_client(0, 1).unwrap().is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn damaged_latest_fails_typed() {
        let dir = tmpdir("damaged");
        let store = CheckpointStore::open(&dir, 2).unwrap();
        let path = store.save_server(&server_snap(3), 9).unwrap();
        let mut bytes = fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        atomic_write(&path, &bytes).unwrap();
        assert!(store.load_latest_server(9).is_err());
        let _ = fs::remove_dir_all(&dir);
    }
}
