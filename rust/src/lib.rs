//! # sbc — Sparse Binary Compression for distributed deep learning
//!
//! A production-shaped reproduction of *Sattler et al., "Sparse Binary
//! Compression: Towards Distributed Deep Learning with minimal
//! Communication" (2018)* as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the distributed-training coordinator: DSGD
//!   parameter server with a **thread-pooled round loop** and **sharded
//!   aggregation** ([`coordinator`]) — per-client work runs on a scoped
//!   worker pool, bit-identical to the serial loop at any thread count —
//!   communication rounds with delay, per-client residual accumulation,
//!   and a *staged compression pipeline*
//!   (Select → Quantize → Encode, [`compression`]): every method the
//!   paper compares against — SBC, Gradient Dropping, FedAvg, signSGD,
//!   TernGrad, QSGD, 1-bit SGD — is a composition of a sparsity selector,
//!   a value quantizer and the bit-exact wire codec
//!   ([`codec::message::WireCodec`], Golomb/fixed/Elias positions), run
//!   in both directions (client updates up, broadcast aggregate down)
//!   over reusable scratch buffers so the hot loop does not allocate.
//!   Plus network simulation, metrics and a CLI launcher.
//! * **L2 (python/compile, build time)** — JAX model zoo lowered to HLO
//!   text artifacts.
//! * **L1 (python/compile/kernels, build time)** — Pallas compression
//!   kernels lowered into the same artifacts.
//!
//! Python never runs at training time: the coordinator loads
//! `artifacts/*.hlo.txt` through the PJRT C API ([`runtime`]) and drives
//! everything natively. See `README.md` for a runnable quickstart and
//! `ARCHITECTURE.md` for the module map, the round dataflow, the
//! determinism/threading invariants, and the wire format v2 layout.

#![warn(missing_docs)]

pub mod analysis;
pub mod codec;
pub mod compression;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod formats;
pub mod metrics;
pub mod model;
pub mod netsim;
pub mod persist;
pub mod runtime;
pub mod sgd;
pub mod simnet;
pub mod trace;
pub mod transport;
pub mod util;
