//! # sbc — Sparse Binary Compression for distributed deep learning
//!
//! A production-shaped reproduction of *Sattler et al., "Sparse Binary
//! Compression: Towards Distributed Deep Learning with minimal
//! Communication" (2018)* as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the distributed-training coordinator: DSGD
//!   parameter server, communication rounds with delay, per-client
//!   residual accumulation, pluggable compressors (SBC + every baseline
//!   the paper compares against), bit-exact Golomb wire encoding, network
//!   simulation, metrics and a CLI launcher.
//! * **L2 (python/compile, build time)** — JAX model zoo lowered to HLO
//!   text artifacts.
//! * **L1 (python/compile/kernels, build time)** — Pallas compression
//!   kernels lowered into the same artifacts.
//!
//! Python never runs at training time: the coordinator loads
//! `artifacts/*.hlo.txt` through the PJRT C API (`runtime`) and drives
//! everything natively. See DESIGN.md for the system inventory and
//! EXPERIMENTS.md for the paper-vs-measured record.

pub mod codec;
pub mod compression;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod formats;
pub mod metrics;
pub mod model;
pub mod netsim;
pub mod runtime;
pub mod sgd;
pub mod util;
