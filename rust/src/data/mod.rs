//! Synthetic/small datasets + client sharding (dataset substitutions —
//! see the module docs below and ARCHITECTURE.md §Module map).
//!
//! The paper trains on MNIST/CIFAR/ImageNet/PTB/Shakespeare; this sandbox
//! has no datasets, so each benchmark gets the closest generatable
//! equivalent that exercises the same code path: teacher-based image
//! classification tasks (learnable, with class structure and noise) and
//! character/word corpora (an embedded public-domain seed text expanded by
//! a Markov model, and a Zipf-bigram word stream).

pub mod shard;
pub mod synth_images;
pub mod text;

/// A batch ready for upload to a train/eval graph.
#[derive(Clone, Debug)]
pub struct Batch {
    /// Flattened x (f32) — image pixels, or token ids cast to i32 via `xi`.
    pub xf: Vec<f32>,
    /// Flattened x as token ids (text datasets; empty for images).
    pub xi: Vec<i32>,
    /// Labels (class ids, or next-token ids for LMs).
    pub y: Vec<i32>,
}

/// Common interface over datasets: draw a train batch for one client, or
/// an eval batch from held-out data.
pub trait Dataset: Send {
    /// Fill a train batch for `client` (deterministic in `rng`).
    fn train_batch(&self, client: usize, rng: &mut crate::util::rng::Rng, batch: usize) -> Batch;
    /// Fill an eval batch (held-out split).
    fn eval_batch(&self, index: usize, batch: usize) -> Batch;
    /// Number of distinct eval batches available.
    fn eval_batches(&self, batch: usize) -> usize;
    /// True for token (i32 x) datasets.
    fn is_text(&self) -> bool;
}
