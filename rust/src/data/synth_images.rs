//! Teacher-based synthetic image classification.
//!
//! Each class `c` owns a smooth random template image; a sample is an
//! affine-jittered, scaled template plus pixel noise. The task has real
//! class structure (within-class variation, between-class separation) so
//! optimizers and compressors interact with it the way they do with
//! MNIST/CIFAR — while remaining fully generatable and deterministic.

use crate::data::shard::Sharding;
use crate::data::{Batch, Dataset};
use crate::util::rng::Rng;

/// Deterministic teacher-template image classification dataset.
#[derive(Clone, Debug)]
pub struct SynthImages {
    /// Image height.
    pub h: usize,
    /// Image width.
    pub w: usize,
    /// Channels.
    pub c: usize,
    /// Number of classes.
    pub classes: usize,
    templates: Vec<Vec<f32>>, // classes × (h*w*c)
    noise: f32,
    /// held-out eval set, pre-generated
    eval_x: Vec<f32>,
    eval_y: Vec<i32>,
    eval_n: usize,
    sharding: Sharding,
}

impl SynthImages {
    /// `kind`: "mnist" (28x28x1/10) or "cifar" (32x32x3/10).
    pub fn new(kind: &str, clients: usize, seed: u64) -> Self {
        let (h, w, c) = match kind {
            "mnist" => (28, 28, 1),
            "cifar" => (32, 32, 3),
            other => panic!("unknown synth image kind {other}"),
        };
        Self::with_dims(h, w, c, 10, clients, 0.35, seed)
    }

    /// Fully parameterized construction (dimensions, classes, clients,
    /// pixel-noise level, seed).
    pub fn with_dims(
        h: usize,
        w: usize,
        c: usize,
        classes: usize,
        clients: usize,
        noise: f32,
        seed: u64,
    ) -> Self {
        let mut rng = Rng::new(seed ^ 0x5b3a_91c4);
        let templates: Vec<Vec<f32>> =
            (0..classes).map(|_| smooth_template(h, w, c, &mut rng)).collect();
        let mut ds = SynthImages {
            h,
            w,
            c,
            classes,
            templates,
            noise,
            eval_x: vec![],
            eval_y: vec![],
            eval_n: 0,
            sharding: Sharding::iid(clients, classes),
        };
        // held-out eval set: 512 samples from an independent stream
        let eval_n = 512;
        let mut erng = Rng::new(seed ^ 0x77ee_11aa);
        let px = h * w * c;
        let mut ex = vec![0.0f32; eval_n * px];
        let mut ey = vec![0i32; eval_n];
        for i in 0..eval_n {
            let y = erng.below(classes);
            ds.render(y, &mut erng, &mut ex[i * px..(i + 1) * px]);
            ey[i] = y as i32;
        }
        ds.eval_x = ex;
        ds.eval_y = ey;
        ds.eval_n = eval_n;
        ds
    }

    fn render(&self, class: usize, rng: &mut Rng, out: &mut [f32]) {
        let t = &self.templates[class];
        // per-sample brightness/contrast jitter + shift by up to ±2 px
        let gain = 0.8 + 0.4 * rng.next_f32();
        let bias = 0.1 * (rng.next_f32() - 0.5);
        let dy = rng.below(5) as isize - 2;
        let dx = rng.below(5) as isize - 2;
        let (h, w, c) = (self.h as isize, self.w as isize, self.c);
        for y in 0..h {
            for x in 0..w {
                let sy = (y + dy).clamp(0, h - 1);
                let sx = (x + dx).clamp(0, w - 1);
                for ch in 0..c {
                    let src = ((sy * w + sx) as usize) * c + ch;
                    let dst = ((y * w + x) as usize) * c + ch;
                    out[dst] = (t[src] * gain + bias + self.noise * rng.normal()).clamp(-1.0, 1.0);
                }
            }
        }
    }
}

/// Smooth random template: low-frequency cosine mixture -> class identity
/// lives in large-scale structure, like natural image classes.
fn smooth_template(h: usize, w: usize, c: usize, rng: &mut Rng) -> Vec<f32> {
    let mut out = vec![0.0f32; h * w * c];
    let kmax = 4;
    for ch in 0..c {
        // random low-frequency coefficients
        let mut coef = Vec::new();
        for ky in 0..kmax {
            for kx in 0..kmax {
                coef.push((ky, kx, rng.normal() / (1.0 + (ky + kx) as f32)));
            }
        }
        for y in 0..h {
            for x in 0..w {
                let mut v = 0.0f32;
                for &(ky, kx, a) in &coef {
                    let fy = std::f32::consts::PI * ky as f32 * (y as f32 + 0.5) / h as f32;
                    let fx = std::f32::consts::PI * kx as f32 * (x as f32 + 0.5) / w as f32;
                    v += a * fy.cos() * fx.cos();
                }
                out[(y * w + x) * c + ch] = (v * 0.5).clamp(-1.0, 1.0);
            }
        }
    }
    out
}

impl Dataset for SynthImages {
    fn train_batch(&self, client: usize, rng: &mut Rng, batch: usize) -> Batch {
        let px = self.h * self.w * self.c;
        let mut xf = vec![0.0f32; batch * px];
        let mut y = vec![0i32; batch];
        for i in 0..batch {
            let class = self.sharding.draw_class(client, rng);
            self.render(class, rng, &mut xf[i * px..(i + 1) * px]);
            y[i] = class as i32;
        }
        Batch { xf, xi: vec![], y }
    }

    fn eval_batch(&self, index: usize, batch: usize) -> Batch {
        let px = self.h * self.w * self.c;
        let start = (index * batch) % self.eval_n;
        let mut xf = vec![0.0f32; batch * px];
        let mut y = vec![0i32; batch];
        for i in 0..batch {
            let j = (start + i) % self.eval_n;
            xf[i * px..(i + 1) * px].copy_from_slice(&self.eval_x[j * px..(j + 1) * px]);
            y[i] = self.eval_y[j];
        }
        Batch { xf, xi: vec![], y }
    }

    fn eval_batches(&self, batch: usize) -> usize {
        (self.eval_n / batch).max(1)
    }

    fn is_text(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let ds = SynthImages::new("mnist", 4, 1);
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let b1 = ds.train_batch(0, &mut r1, 8);
        let b2 = ds.train_batch(0, &mut r2, 8);
        assert_eq!(b1.xf.len(), 8 * 28 * 28);
        assert_eq!(b1.y.len(), 8);
        assert_eq!(b1.xf, b2.xf);
        assert_eq!(b1.y, b2.y);
    }

    #[test]
    fn class_structure_exists() {
        // same-class samples are closer than cross-class samples on average
        let ds = SynthImages::new("cifar", 1, 2);
        let mut rng = Rng::new(3);
        let px = 32 * 32 * 3;
        let render = |class: usize, rng: &mut Rng| {
            let mut v = vec![0.0f32; px];
            ds.render(class, rng, &mut v);
            v
        };
        let a1 = render(0, &mut rng);
        let a2 = render(0, &mut rng);
        let b1 = render(1, &mut rng);
        let d_same: f32 = a1.iter().zip(&a2).map(|(x, y)| (x - y).powi(2)).sum();
        let d_diff: f32 = a1.iter().zip(&b1).map(|(x, y)| (x - y).powi(2)).sum();
        assert!(d_same < d_diff, "same {d_same} diff {d_diff}");
    }

    #[test]
    fn eval_batches_cycle() {
        let ds = SynthImages::new("mnist", 4, 1);
        assert!(ds.eval_batches(32) >= 16);
        let b = ds.eval_batch(0, 32);
        let b2 = ds.eval_batch(0, 32);
        assert_eq!(b.xf, b2.xf); // eval set is fixed
        assert!(!ds.is_text());
    }

    #[test]
    fn values_bounded() {
        let ds = SynthImages::new("cifar", 2, 7);
        let mut rng = Rng::new(1);
        let b = ds.train_batch(1, &mut rng, 4);
        assert!(b.xf.iter().all(|v| (-1.0..=1.0).contains(v)));
        assert!(b.y.iter().all(|&y| (0..10).contains(&y)));
    }
}
