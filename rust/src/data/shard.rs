//! Client data sharding. The paper splits data IID ("balanced,
//! homogeneous"); we also provide a non-IID Dirichlet split as an
//! extension knob (federated-learning realism, paper §I motivation).

use crate::util::rng::Rng;

/// Per-client class-sampling distributions (IID or Dirichlet non-IID).
#[derive(Clone, Debug)]
pub struct Sharding {
    /// Per-client class-sampling distribution (clients × classes CDF).
    cdfs: Vec<Vec<f32>>,
}

impl Sharding {
    /// Balanced IID split: every client samples classes uniformly.
    pub fn iid(clients: usize, classes: usize) -> Self {
        let uniform: Vec<f32> =
            (0..classes).map(|c| (c + 1) as f32 / classes as f32).collect();
        Sharding { cdfs: vec![uniform; clients.max(1)] }
    }

    /// Non-IID: per-client class proportions drawn from Dirichlet(alpha).
    /// Small alpha -> strongly skewed shards.
    pub fn dirichlet(clients: usize, classes: usize, alpha: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let cdfs = (0..clients.max(1))
            .map(|_| {
                // gamma(alpha) via Marsaglia-Tsang for alpha<1 boost trick
                let mut w: Vec<f64> = (0..classes).map(|_| gamma_sample(alpha, &mut rng)).collect();
                let sum: f64 = w.iter().sum::<f64>().max(1e-12);
                let mut acc = 0.0;
                for v in w.iter_mut() {
                    acc += *v / sum;
                    *v = acc;
                }
                w.iter().map(|&v| v as f32).collect()
            })
            .collect();
        Sharding { cdfs }
    }

    /// Draw a class for one client's next sample.
    pub fn draw_class(&self, client: usize, rng: &mut Rng) -> usize {
        let cdf = &self.cdfs[client % self.cdfs.len()];
        let u = rng.next_f32();
        cdf.iter().position(|&c| u < c).unwrap_or(cdf.len() - 1)
    }

    /// Number of client shards.
    pub fn clients(&self) -> usize {
        self.cdfs.len()
    }
}

fn gamma_sample(alpha: f64, rng: &mut Rng) -> f64 {
    // Marsaglia & Tsang; for alpha < 1 use the boosting identity.
    if alpha < 1.0 {
        let u = rng.next_f64().max(1e-300);
        return gamma_sample(alpha + 1.0, rng) * u.powf(1.0 / alpha);
    }
    let d = alpha - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let x = rng.normal() as f64;
        let v = (1.0 + c * x).powi(3);
        if v <= 0.0 {
            continue;
        }
        let u = rng.next_f64().max(1e-300);
        if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
            return d * v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iid_is_uniform() {
        let s = Sharding::iid(4, 10);
        let mut rng = Rng::new(1);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            counts[s.draw_class(2, &mut rng)] += 1;
        }
        for &c in &counts {
            assert!((800..1200).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn dirichlet_skews() {
        let s = Sharding::dirichlet(4, 10, 0.1, 3);
        let mut rng = Rng::new(2);
        let mut counts = [0usize; 10];
        for _ in 0..5_000 {
            counts[s.draw_class(0, &mut rng)] += 1;
        }
        let max = *counts.iter().max().unwrap();
        // strongly non-uniform: dominant class holds far above 10%
        assert!(max > 1500, "{counts:?}");
        assert_eq!(s.clients(), 4);
    }

    #[test]
    fn gamma_positive() {
        let mut rng = Rng::new(5);
        for &a in &[0.1, 0.5, 1.0, 3.0] {
            for _ in 0..100 {
                assert!(gamma_sample(a, &mut rng) > 0.0);
            }
        }
    }
}
