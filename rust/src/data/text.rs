//! Character and word corpora for the LM benchmarks.
//!
//! * [`CharCorpus`] — stands in for the paper's Shakespeare dataset: a
//!   public-domain Shakespeare seed (embedded below) expanded to an
//!   arbitrarily long stream by an order-2 character Markov chain fitted
//!   on the seed. Real character statistics, fully generatable offline.
//! * [`WordCorpus`] — stands in for PTB: a Zipf-distributed vocabulary
//!   with sparse bigram structure (each word has a small preferred
//!   successor set), so an LSTM has genuine sequential signal to learn.
//!
//! Both split the stream into `clients` contiguous subsequences exactly as
//! the paper does (§IV-A), with a held-out tail for evaluation.

use std::collections::HashMap;

use crate::data::{Batch, Dataset};
use crate::util::rng::Rng;

/// Public-domain Shakespeare seed text (Sonnet 18, Hamlet III.1 excerpt,
/// Macbeth V.5 excerpt). Used only to fit the Markov expander.
pub const SHAKESPEARE_SEED: &str = "\
Shall I compare thee to a summer's day?\n\
Thou art more lovely and more temperate:\n\
Rough winds do shake the darling buds of May,\n\
And summer's lease hath all too short a date;\n\
Sometime too hot the eye of heaven shines,\n\
And often is his gold complexion dimm'd;\n\
And every fair from fair sometime declines,\n\
By chance or nature's changing course untrimm'd;\n\
But thy eternal summer shall not fade,\n\
Nor lose possession of that fair thou ow'st;\n\
Nor shall death brag thou wander'st in his shade,\n\
When in eternal lines to time thou grow'st:\n\
So long as men can breathe or eyes can see,\n\
So long lives this, and this gives life to thee.\n\
To be, or not to be, that is the question:\n\
Whether 'tis nobler in the mind to suffer\n\
The slings and arrows of outrageous fortune,\n\
Or to take arms against a sea of troubles\n\
And by opposing end them. To die: to sleep;\n\
No more; and by a sleep to say we end\n\
The heart-ache and the thousand natural shocks\n\
That flesh is heir to, 'tis a consummation\n\
Devoutly to be wish'd. To die, to sleep;\n\
To sleep: perchance to dream: ay, there's the rub;\n\
For in that sleep of death what dreams may come\n\
When we have shuffled off this mortal coil,\n\
Must give us pause: there's the respect\n\
That makes calamity of so long life;\n\
To-morrow, and to-morrow, and to-morrow,\n\
Creeps in this petty pace from day to day\n\
To the last syllable of recorded time,\n\
And all our yesterdays have lighted fools\n\
The way to dusty death. Out, out, brief candle!\n\
Life's but a walking shadow, a poor player\n\
That struts and frets his hour upon the stage\n\
And then is heard no more: it is a tale\n\
Told by an idiot, full of sound and fury,\n\
Signifying nothing.\n";

/// Character vocabulary size (matches the paper's CharLSTM: 98 symbols).
pub const CHAR_VOCAB: usize = 98;

/// Map a byte to a char id in [0, CHAR_VOCAB).
pub fn char_id(b: u8) -> i32 {
    match b {
        32..=125 => (b - 32) as i32, // printable ASCII: 0..=93
        b'\n' => 94,
        b'\t' => 95,
        _ => 96, // everything else buckets to id 96; 97 reserved/unused
    }
}

/// Markov-expanded Shakespeare-like character corpus (CharLSTM stand-in).
pub struct CharCorpus {
    /// token streams per client + eval tail
    shards: Vec<Vec<i32>>,
    eval: Vec<i32>,
    seqlen: usize,
}

impl CharCorpus {
    /// Generate `clients` shards of `tokens_per_client` characters plus a
    /// held-out eval stream, deterministically from `seed`.
    pub fn new(clients: usize, tokens_per_client: usize, seqlen: usize, seed: u64) -> Self {
        // fit order-2 markov on the seed
        let seed_ids: Vec<i32> = SHAKESPEARE_SEED.bytes().map(char_id).collect();
        let mut table: HashMap<(i32, i32), Vec<i32>> = HashMap::new();
        for w in seed_ids.windows(3) {
            table.entry((w[0], w[1])).or_default().push(w[2]);
        }
        let mut rng = Rng::new(seed ^ 0xc0ffee);
        let gen_stream = |len: usize, rng: &mut Rng| -> Vec<i32> {
            let mut out = Vec::with_capacity(len);
            let start = rng.below(seed_ids.len().saturating_sub(2));
            let (mut a, mut b) = (seed_ids[start], seed_ids[start + 1]);
            out.push(a);
            out.push(b);
            while out.len() < len {
                let next = match table.get(&(a, b)) {
                    Some(cands) if !cands.is_empty() => cands[rng.below(cands.len())],
                    _ => {
                        // dead end: restart from a random seed position
                        let s = rng.below(seed_ids.len().saturating_sub(2));
                        seed_ids[s]
                    }
                };
                out.push(next);
                a = b;
                b = next;
            }
            out
        };
        let shards = (0..clients.max(1)).map(|_| gen_stream(tokens_per_client, &mut rng)).collect();
        let eval = gen_stream(tokens_per_client / 4 + 2 * seqlen, &mut rng);
        CharCorpus { shards, eval, seqlen }
    }
}

/// Deterministic eval batch: consecutive windows starting at `index`.
fn lm_eval_batch(stream: &[i32], index: usize, batch: usize, seqlen: usize) -> Batch {
    let span = seqlen + 1;
    let max_start = stream.len().saturating_sub(span).max(1);
    let mut xi = vec![0i32; batch * seqlen];
    let mut y = vec![0i32; batch * seqlen];
    for b in 0..batch {
        let s = (index * batch + b) * seqlen % max_start;
        for t in 0..seqlen {
            xi[b * seqlen + t] = stream[s + t];
            y[b * seqlen + t] = stream[s + t + 1];
        }
    }
    Batch { xf: vec![], xi, y }
}

fn lm_train_batch(stream: &[i32], rng: &mut Rng, batch: usize, seqlen: usize) -> Batch {
    let span = seqlen + 1;
    let max_start = stream.len().saturating_sub(span).max(1);
    let mut xi = vec![0i32; batch * seqlen];
    let mut y = vec![0i32; batch * seqlen];
    for b in 0..batch {
        let s = rng.below(max_start);
        for t in 0..seqlen {
            xi[b * seqlen + t] = stream[s + t];
            y[b * seqlen + t] = stream[s + t + 1];
        }
    }
    Batch { xf: vec![], xi, y }
}

impl Dataset for CharCorpus {
    fn train_batch(&self, client: usize, rng: &mut Rng, batch: usize) -> Batch {
        lm_train_batch(&self.shards[client % self.shards.len()], rng, batch, self.seqlen)
    }

    fn eval_batch(&self, index: usize, batch: usize) -> Batch {
        lm_eval_batch(&self.eval, index, batch, self.seqlen)
    }

    fn eval_batches(&self, batch: usize) -> usize {
        (self.eval.len() / (batch * self.seqlen)).max(1)
    }

    fn is_text(&self) -> bool {
        true
    }
}

/// Zipf-bigram word stream (PTB stand-in for the word-LM benchmark).
pub struct WordCorpus {
    shards: Vec<Vec<i32>>,
    eval: Vec<i32>,
    seqlen: usize,
    /// Vocabulary size (token ids are `0..vocab`).
    pub vocab: usize,
}

impl WordCorpus {
    /// Generate `clients` shards of `tokens_per_client` words plus a
    /// held-out eval stream, deterministically from `seed`.
    pub fn new(vocab: usize, clients: usize, tokens_per_client: usize, seqlen: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xbead);
        // Zipf CDF over ranks
        let weights: Vec<f64> = (1..=vocab).map(|r| 1.0 / (r as f64).powf(1.1)).collect();
        let total: f64 = weights.iter().sum();
        let cdf: Vec<f64> = weights
            .iter()
            .scan(0.0, |acc, w| {
                *acc += w / total;
                Some(*acc)
            })
            .collect();
        // sparse bigram structure: 8 preferred successors per word
        let succ: Vec<Vec<i32>> = (0..vocab)
            .map(|_| (0..8).map(|_| zipf_draw(&cdf, &mut rng)).collect())
            .collect();
        let gen_stream = |len: usize, rng: &mut Rng| -> Vec<i32> {
            let mut out = Vec::with_capacity(len);
            let mut cur = zipf_draw(&cdf, rng);
            out.push(cur);
            while out.len() < len {
                cur = if rng.next_f32() < 0.7 {
                    let s = &succ[cur as usize];
                    s[rng.below(s.len())]
                } else {
                    zipf_draw(&cdf, rng)
                };
                out.push(cur);
            }
            out
        };
        let shards = (0..clients.max(1)).map(|_| gen_stream(tokens_per_client, &mut rng)).collect();
        let eval = gen_stream(tokens_per_client / 4 + 2 * seqlen, &mut rng);
        WordCorpus { shards, eval, seqlen, vocab }
    }
}

fn zipf_draw(cdf: &[f64], rng: &mut Rng) -> i32 {
    let u = rng.next_f64();
    cdf.partition_point(|&c| c < u).min(cdf.len() - 1) as i32
}

impl Dataset for WordCorpus {
    fn train_batch(&self, client: usize, rng: &mut Rng, batch: usize) -> Batch {
        lm_train_batch(&self.shards[client % self.shards.len()], rng, batch, self.seqlen)
    }

    fn eval_batch(&self, index: usize, batch: usize) -> Batch {
        lm_eval_batch(&self.eval, index, batch, self.seqlen)
    }

    fn eval_batches(&self, batch: usize) -> usize {
        (self.eval.len() / (batch * self.seqlen)).max(1)
    }

    fn is_text(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn char_ids_in_vocab() {
        for b in 0u8..=255 {
            let id = char_id(b);
            assert!((0..CHAR_VOCAB as i32).contains(&id));
        }
    }

    #[test]
    fn char_corpus_batches() {
        let ds = CharCorpus::new(4, 5_000, 32, 1);
        let mut rng = Rng::new(2);
        let b = ds.train_batch(0, &mut rng, 16);
        assert_eq!(b.xi.len(), 16 * 32);
        assert_eq!(b.y.len(), 16 * 32);
        // y is x shifted by one
        assert_eq!(b.xi[1], b.y[0]);
        assert!(b.xi.iter().all(|&t| (0..98).contains(&t)));
        assert!(ds.is_text());
    }

    #[test]
    fn char_corpus_is_shakespeare_like() {
        // generated stream must reuse seed bigrams only
        let ds = CharCorpus::new(1, 2_000, 32, 3);
        let seed_ids: Vec<i32> = SHAKESPEARE_SEED.bytes().map(char_id).collect();
        let mut seen = std::collections::HashSet::new();
        for w in seed_ids.windows(2) {
            seen.insert((w[0], w[1]));
        }
        let stream = &ds.shards[0];
        let mut hits = 0usize;
        for w in stream.windows(2) {
            if seen.contains(&(w[0], w[1])) {
                hits += 1;
            }
        }
        // >95% of generated bigrams exist in the seed (dead-end restarts
        // account for the remainder)
        assert!(hits as f64 / (stream.len() - 1) as f64 > 0.95);
    }

    #[test]
    fn word_corpus_zipf_and_bigram() {
        let ds = WordCorpus::new(1000, 4, 20_000, 20, 4);
        let stream = &ds.shards[0];
        assert!(stream.iter().all(|&t| (0..1000).contains(&t)));
        // rank-0 word must be much more frequent than rank-500
        let c0 = stream.iter().filter(|&&t| t == 0).count();
        let c500 = stream.iter().filter(|&&t| t == 500).count();
        assert!(c0 > c500 * 3, "c0={c0} c500={c500}");
        let b = ds.eval_batch(0, 8);
        assert_eq!(b.xi.len(), 8 * 20);
        assert_eq!(b.xi[1], b.y[0]);
    }

    #[test]
    fn eval_batches_deterministic() {
        let ds = CharCorpus::new(2, 4_000, 32, 5);
        let a = ds.eval_batch(1, 8);
        let b = ds.eval_batch(1, 8);
        assert_eq!(a.xi, b.xi);
        assert!(ds.eval_batches(8) >= 1);
    }
}
