//! Thin typed wrapper over the `xla` crate's PJRT client.
//!
//! Interchange is HLO *text* (see DESIGN.md and /opt/xla-example/README):
//! jax >= 0.5 serializes protos with 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids. All graphs
//! are lowered with `return_tuple=True`, so every execution returns one
//! tuple buffer which we decompose on the host.

use anyhow::{anyhow, Context, Result};

/// Process-wide PJRT client (CPU). Creating one is cheap but not free;
/// share it across executables.
pub struct Runtime {
    pub client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact.
    pub fn load(&self, path: &str) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing HLO text {path}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {path}: {e:?}"))?;
        Ok(Executable { exe, path: path.to_string() })
    }
}

pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub path: String,
}

impl Executable {
    /// Execute with literal inputs; returns the decomposed output tuple.
    pub fn run(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("executing {}: {e:?}", self.path))?;
        let mut lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {}: {e:?}", self.path))?;
        lit.decompose_tuple().map_err(|e| anyhow!("decomposing result of {}: {e:?}", self.path))
    }
}

/// Literal construction helpers.
pub fn lit_f32_vec(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape f32 {shape:?}: {e:?}"))
}

pub fn lit_i32_vec(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape i32 {shape:?}: {e:?}"))
}

pub fn lit_scalar_f32(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

pub fn lit_scalar_i32(x: i32) -> xla::Literal {
    xla::Literal::scalar(x)
}

pub fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("literal to f32 vec: {e:?}"))
}

pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>().context("scalar f32 from literal")
}
