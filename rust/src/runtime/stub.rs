//! Stub PJRT backend used when the crate is built without the `pjrt`
//! feature: same API surface, but [`PjrtBackend::load`] always fails with
//! an explanation. The struct is uninstantiable (it holds an
//! [`std::convert::Infallible`]), so the trait methods are unreachable by
//! construction.

use anyhow::{anyhow, Result};

use crate::coordinator::{EvalOut, TrainBackend};
use crate::model::manifest::Manifest;
use crate::model::{ModelSpec, TensorLayout};
use crate::util::rng::Rng;

/// Uninstantiable stand-in for the PJRT backend (no-`pjrt` builds).
pub struct PjrtBackend {
    /// The loaded model's spec (unreachable: the struct cannot exist).
    pub spec: ModelSpec,
    never: std::convert::Infallible,
}

impl PjrtBackend {
    /// Always fails in this build; see the module docs.
    pub fn load(_manifest: &Manifest, model: &str, _clients: usize, _seed: u64) -> Result<Self> {
        Err(anyhow!(
            "model '{model}': this build has no PJRT runtime (enable the `pjrt` \
             cargo feature with the xla_extension toolchain, or use --backend native)"
        ))
    }

    /// PJRT platform name (unreachable in this build).
    pub fn platform(&self) -> String {
        match self.never {}
    }
}

impl TrainBackend for PjrtBackend {
    fn n_params(&self) -> usize {
        match self.never {}
    }

    fn opt_size(&self) -> usize {
        match self.never {}
    }

    fn layout(&self) -> &TensorLayout {
        match self.never {}
    }

    fn is_lm(&self) -> bool {
        match self.never {}
    }

    fn init_params(&mut self, _seed: u64) -> Vec<f32> {
        match self.never {}
    }

    #[allow(clippy::too_many_arguments)]
    fn local_steps(
        &mut self,
        _params: &[f32],
        _opt: &mut [f32],
        _steps: usize,
        _lr: f32,
        _t0: usize,
        _client: usize,
        _rng: &mut Rng,
    ) -> (Vec<f32>, f32) {
        match self.never {}
    }

    fn evaluate(&mut self, _params: &[f32], _max_batches: usize) -> EvalOut {
        match self.never {}
    }

    fn compress_pjrt(&mut self, _delta: &[f32], _p: f32) -> Option<(Vec<f32>, f32, f32, bool)> {
        match self.never {}
    }
}
