//! PJRT runtime: loads the AOT HLO-text artifacts and executes them from
//! the coordinator's hot path. Python never runs here — the artifacts are
//! self-contained XLA programs.

pub mod backend;
pub mod executable;

pub use backend::PjrtBackend;
pub use executable::{Executable, Runtime};
