//! PJRT runtime: loads the AOT HLO-text artifacts and executes them from
//! the coordinator's hot path. Python never runs here — the artifacts are
//! self-contained XLA programs.
//!
//! The real implementation binds the `xla` native crate and is gated
//! behind the `pjrt` cargo feature (the xla_extension toolchain is not
//! available everywhere). Building with the feature additionally
//! requires making the `xla` crate available as a dependency (it cannot
//! be declared in the offline manifest — see the feature note in
//! Cargo.toml). Without the feature, [`PjrtBackend::load`] returns a
//! descriptive error and the native backend remains the training
//! substrate.

#[cfg(feature = "pjrt")]
pub mod backend;
#[cfg(feature = "pjrt")]
pub mod executable;

#[cfg(feature = "pjrt")]
pub use backend::PjrtBackend;
#[cfg(feature = "pjrt")]
pub use executable::{Executable, Runtime};

#[cfg(not(feature = "pjrt"))]
mod stub;

#[cfg(not(feature = "pjrt"))]
pub use stub::PjrtBackend;
