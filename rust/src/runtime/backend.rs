//! [`PjrtBackend`]: the production training substrate — executes the AOT
//! L2 graphs (init/step/eval) and the L1 Pallas compress graph via PJRT.

use anyhow::{anyhow, Result};

use crate::coordinator::{EvalOut, TrainBackend};
use crate::data::text::{CharCorpus, WordCorpus};
use crate::data::synth_images::SynthImages;
use crate::data::{Batch, Dataset};
use crate::model::manifest::Manifest;
use crate::model::{Dtype, ModelSpec, Task, TensorLayout};
use crate::runtime::executable::{
    lit_f32_vec, lit_i32_vec, lit_scalar_f32, lit_scalar_i32, scalar_f32, to_f32_vec, Executable,
    Runtime,
};
use crate::util::rng::Rng;
use crate::util::timer::span;

pub struct PjrtBackend {
    pub spec: ModelSpec,
    runtime: Runtime,
    exe_init: Executable,
    exe_step: Executable,
    exe_eval: Executable,
    /// Compiled lazily on first use — the compress graph is only needed
    /// when `--pjrt-compress` routes SBC through the Pallas kernels, and
    /// the old XLA compiler is slow enough that eager compilation would
    /// tax every run.
    exe_compress: std::cell::OnceCell<Option<Executable>>,
    compress_path: Option<String>,
    data: Box<dyn Dataset>,
    batch: usize,
}

impl PjrtBackend {
    /// Load a model's artifacts and build its dataset (DESIGN.md §2
    /// pairing: model name -> substitute dataset).
    pub fn load(manifest: &Manifest, model: &str, clients: usize, seed: u64) -> Result<Self> {
        let spec = manifest.model(model)?.clone();
        let runtime = Runtime::cpu()?;
        let exe_init = runtime.load(&manifest.graph_path(model, "init")?)?;
        let exe_step = runtime.load(&manifest.graph_path(model, "step")?)?;
        let exe_eval = runtime.load(&manifest.graph_path(model, "eval")?)?;
        let compress_path = manifest.graph_path(model, "compress").ok();
        let data = build_dataset(&spec, clients, seed)?;
        let batch = spec.batch();
        Ok(PjrtBackend {
            spec,
            runtime,
            exe_init,
            exe_step,
            exe_eval,
            exe_compress: std::cell::OnceCell::new(),
            compress_path,
            data,
            batch,
        })
    }

    pub fn platform(&self) -> String {
        self.runtime.platform()
    }

    fn batch_literals(&self, b: &Batch) -> Result<(xla::Literal, xla::Literal)> {
        let x = match self.spec.x_dtype {
            Dtype::F32 => lit_f32_vec(&b.xf, &self.spec.x_shape)?,
            Dtype::I32 => lit_i32_vec(&b.xi, &self.spec.x_shape)?,
        };
        let y = lit_i32_vec(&b.y, &self.spec.y_shape)?;
        Ok((x, y))
    }
}

fn build_dataset(spec: &ModelSpec, clients: usize, seed: u64) -> Result<Box<dyn Dataset>> {
    let seqlen = if spec.task == Task::Lm { spec.x_shape[1] } else { 0 };
    Ok(match spec.name.as_str() {
        "mlp" | "lenet" => Box::new(SynthImages::new("mnist", clients, seed)),
        "cifarcnn" => Box::new(SynthImages::new("cifar", clients, seed)),
        "charlm" => Box::new(CharCorpus::new(clients, 60_000, seqlen, seed)),
        "wordlm" => Box::new(WordCorpus::new(spec.vocab, clients, 60_000, seqlen, seed)),
        name if name.starts_with("tinygpt") => {
            Box::new(CharCorpus::new(clients, 120_000, seqlen, seed))
        }
        other => return Err(anyhow!("no dataset mapping for model '{other}'")),
    })
}

impl TrainBackend for PjrtBackend {
    fn n_params(&self) -> usize {
        self.spec.n_params
    }

    fn opt_size(&self) -> usize {
        self.spec.opt_size
    }

    fn layout(&self) -> &TensorLayout {
        &self.spec.layout
    }

    fn is_lm(&self) -> bool {
        self.spec.task == Task::Lm
    }

    fn init_params(&mut self, seed: u64) -> Vec<f32> {
        let _t = span("pjrt_init");
        let out = self
            .exe_init
            .run(&[lit_scalar_i32(seed as i32)])
            .expect("init graph failed");
        to_f32_vec(&out[0]).expect("init output")
    }

    fn local_steps(
        &mut self,
        params: &[f32],
        opt: &mut [f32],
        steps: usize,
        lr: f32,
        t0: usize,
        client: usize,
        rng: &mut Rng,
    ) -> (Vec<f32>, f32) {
        let mut p_lit = lit_f32_vec(params, &[self.spec.n_params]).expect("params literal");
        let mut o_lit = lit_f32_vec(opt, &[self.spec.opt_size]).expect("opt literal");
        let mut loss_sum = 0.0f32;
        for s in 0..steps {
            let batch = self.data.train_batch(client, rng, self.batch);
            let (x, y) = self.batch_literals(&batch).expect("batch literals");
            let outs = {
                let _t = span("pjrt_step");
                self.exe_step
                    .run(&[
                        p_lit,
                        o_lit,
                        lit_scalar_f32(lr),
                        lit_scalar_f32((t0 + s) as f32),
                        x,
                        y,
                    ])
                    .expect("step graph failed")
            };
            let mut it = outs.into_iter();
            p_lit = it.next().expect("params out");
            o_lit = it.next().expect("opt out");
            let loss = it.next().expect("loss out");
            loss_sum += scalar_f32(&loss).expect("loss scalar");
        }
        let new_params = to_f32_vec(&p_lit).expect("params back");
        let new_opt = to_f32_vec(&o_lit).expect("opt back");
        opt.copy_from_slice(&new_opt);
        (new_params, loss_sum / steps.max(1) as f32)
    }

    fn evaluate(&mut self, params: &[f32], max_batches: usize) -> EvalOut {
        let _t = span("pjrt_eval");
        let p_lit = lit_f32_vec(params, &[self.spec.n_params]).expect("params literal");
        let nb = self.data.eval_batches(self.batch).min(max_batches.max(1));
        let (mut loss_sum, mut metric_sum, mut count) = (0.0f64, 0.0f64, 0.0f64);
        for bi in 0..nb {
            let batch = self.data.eval_batch(bi, self.batch);
            let (x, y) = self.batch_literals(&batch).expect("batch literals");
            // clone params literal by re-upload (Literal is not Clone here)
            let p = lit_f32_vec(params, &[self.spec.n_params]).expect("params literal");
            let outs = self.exe_eval.run(&[p, x, y]).expect("eval graph failed");
            loss_sum += scalar_f32(&outs[0]).expect("loss_sum") as f64;
            metric_sum += scalar_f32(&outs[1]).expect("metric") as f64;
            count += scalar_f32(&outs[2]).expect("count") as f64;
        }
        drop(p_lit);
        let loss = (loss_sum / count.max(1.0)) as f32;
        let metric = match self.spec.task {
            Task::Classification => (metric_sum / count.max(1.0)) as f32,
            Task::Lm => loss, // trainer converts to perplexity
        };
        EvalOut { loss, metric }
    }

    fn compress_pjrt(&mut self, delta: &[f32], p: f32) -> Option<(Vec<f32>, f32, f32, bool)> {
        let exe = self
            .exe_compress
            .get_or_init(|| {
                self.compress_path.as_ref().and_then(|path| self.runtime.load(path).ok())
            })
            .as_ref()?;
        let d = lit_f32_vec(delta, &[self.spec.n_params]).ok()?;
        let outs = exe.run(&[d, lit_scalar_f32(p)]).ok()?;
        let dense = to_f32_vec(&outs[0]).ok()?;
        let t = scalar_f32(&outs[1]).ok()?;
        let mu = scalar_f32(&outs[2]).ok()?;
        let side = scalar_f32(&outs[3]).ok()? > 0.5;
        Some((dense, t, mu, side))
    }
}
