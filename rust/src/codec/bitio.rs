//! MSB-first bit-level writer/reader — the substrate for every wire codec.
//!
//! The writer packs into a `Vec<u8>`; the reader walks a `&[u8]`. Both keep
//! an exact bit count so compression rates are measured on true wire size,
//! not approximations.

/// MSB-first bit stream writer over a growable byte buffer.
#[derive(Default, Clone, Debug)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Pending bits, MSB-aligned to the *low* end: the low `nacc` bits of
    /// `acc` are the not-yet-flushed tail of the stream.
    acc: u64,
    nacc: u32,
    bits: u64,
}

impl BitWriter {
    /// An empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// An empty writer with `bytes` of buffer pre-reserved.
    pub fn with_capacity(bytes: usize) -> Self {
        BitWriter { buf: Vec::with_capacity(bytes), acc: 0, nacc: 0, bits: 0 }
    }

    /// Total bits written so far.
    #[inline]
    pub fn len_bits(&self) -> u64 {
        self.bits
    }

    #[inline]
    fn flush_acc(&mut self) {
        while self.nacc >= 8 {
            self.nacc -= 8;
            self.buf.push((self.acc >> self.nacc) as u8);
        }
    }

    /// Append one bit.
    #[inline]
    pub fn put_bit(&mut self, bit: bool) {
        self.acc = (self.acc << 1) | bit as u64;
        self.nacc += 1;
        self.bits += 1;
        if self.nacc >= 8 {
            self.flush_acc();
        }
    }

    /// Write the low `n` bits of `v`, MSB first. n <= 64.
    #[inline]
    pub fn put_bits(&mut self, v: u64, n: u32) {
        debug_assert!(n <= 64);
        if n == 0 {
            return;
        }
        // the accumulator holds < 8 pending bits, so chunks of <= 56 fit
        if n > 56 {
            let hi = n - 32;
            self.put_bits(v >> 32, hi);
            self.put_bits(v & 0xFFFF_FFFF, 32);
            return;
        }
        let v = v & (u64::MAX >> (64 - n));
        self.acc = (self.acc << n) | v;
        self.nacc += n;
        self.bits += n as u64;
        self.flush_acc();
    }

    /// Unary: q ones followed by a zero.
    pub fn put_unary(&mut self, mut q: u64) {
        while q >= 32 {
            self.put_bits(0xFFFF_FFFF, 32);
            q -= 32;
        }
        // q ones then a zero, in one chunk (q + 1 <= 33 bits)
        self.put_bits(((1u64 << q) - 1) << 1, q as u32 + 1);
    }

    /// Append an f32 as its 32 raw bits.
    pub fn put_f32(&mut self, x: f32) {
        self.put_bits(x.to_bits() as u64, 32);
    }

    /// Finish and return (bytes, exact_bit_count).
    pub fn finish(mut self) -> (Vec<u8>, u64) {
        let bits = self.finalize();
        (self.buf, bits)
    }

    /// Flush and pad in place; returns the exact bit count. The buffer is
    /// readable through [`BitWriter::bytes`] and the writer is reusable
    /// after [`BitWriter::clear`] — the non-consuming counterpart of
    /// [`BitWriter::finish`] for scratch-buffer reuse across rounds.
    pub fn finalize(&mut self) -> u64 {
        self.flush_acc();
        if self.nacc > 0 {
            let pad = 8 - self.nacc;
            self.buf.push(((self.acc << pad) & 0xFF) as u8);
            self.nacc = 0;
        }
        self.bits
    }

    /// The bytes written so far (complete only after [`BitWriter::finalize`]).
    pub fn bytes(&self) -> &[u8] {
        &self.buf
    }

    /// Reset to empty, keeping the buffer allocation (scratch reuse).
    pub fn clear(&mut self) {
        self.buf.clear();
        self.acc = 0;
        self.nacc = 0;
        self.bits = 0;
    }
}

/// MSB-first bit stream reader over a borrowed byte buffer with an exact
/// bit length (padding bits past `len_bits` are unreadable).
#[derive(Clone, Debug)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: u64,
    len_bits: u64,
}

impl<'a> BitReader<'a> {
    /// A reader over the first `len_bits` bits of `buf`.
    pub fn new(buf: &'a [u8], len_bits: u64) -> Self {
        debug_assert!(len_bits <= buf.len() as u64 * 8);
        BitReader { buf, pos: 0, len_bits }
    }

    /// Bits left to read.
    #[inline]
    pub fn remaining(&self) -> u64 {
        self.len_bits - self.pos
    }

    /// Read one bit (`None` at end of stream).
    #[inline]
    pub fn get_bit(&mut self) -> Option<bool> {
        if self.pos >= self.len_bits {
            return None;
        }
        let byte = self.buf[(self.pos >> 3) as usize];
        let bit = (byte >> (7 - (self.pos & 7))) & 1 == 1;
        self.pos += 1;
        Some(bit)
    }

    /// Read `n` bits MSB-first, assembling byte-sized chunks.
    pub fn get_bits(&mut self, n: u32) -> Option<u64> {
        if self.pos + n as u64 > self.len_bits {
            self.pos = self.len_bits; // exhaust on under-run
            return if n == 0 { Some(0) } else { None };
        }
        let mut v = 0u64;
        let mut got = 0u32;
        while got < n {
            let byte_i = (self.pos >> 3) as usize;
            let bit_off = (self.pos & 7) as u32;
            let take = (8 - bit_off).min(n - got);
            let byte = self.buf[byte_i] as u64;
            let chunk = (byte >> (8 - bit_off - take)) & ((1u64 << take) - 1);
            v = (v << take) | chunk;
            self.pos += take as u64;
            got += take;
        }
        Some(v)
    }

    /// Count ones until the terminating zero (byte-at-a-time fast path).
    pub fn get_unary(&mut self) -> Option<u64> {
        let mut q = 0u64;
        loop {
            if self.pos >= self.len_bits {
                return None;
            }
            let byte_i = (self.pos >> 3) as usize;
            let bit_off = (self.pos & 7) as u32;
            // bits of this byte from the cursor on, left-aligned in a u32
            // (zero-filled below, so leading_ones stops at the byte's end)
            let window = (self.buf[byte_i] as u32) << (24 + bit_off);
            let ones = window.leading_ones().min(8 - bit_off);
            let avail = (self.len_bits - self.pos).min((8 - bit_off) as u64);
            if (ones as u64) < avail {
                // terminating zero lies inside this byte
                self.pos += ones as u64 + 1;
                return Some(q + ones as u64);
            }
            // all available bits are ones; continue into the next byte
            q += avail;
            self.pos += avail;
            if (ones as u64) > avail {
                return None; // ran past the stream without a zero
            }
        }
    }

    /// Read an f32 from 32 raw bits.
    pub fn get_f32(&mut self) -> Option<f32> {
        Some(f32::from_bits(self.get_bits(32)? as u32))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_bits() {
        let mut w = BitWriter::new();
        w.put_bits(0b1011, 4);
        w.put_bit(true);
        w.put_bits(0xDEADBEEF, 32);
        w.put_unary(5);
        w.put_f32(-1.25);
        let total = w.len_bits();
        assert_eq!(total, 4 + 1 + 32 + 6 + 32);
        let (bytes, bits) = w.finish();
        assert_eq!(bits, total);
        let mut r = BitReader::new(&bytes, bits);
        assert_eq!(r.get_bits(4), Some(0b1011));
        assert_eq!(r.get_bit(), Some(true));
        assert_eq!(r.get_bits(32), Some(0xDEADBEEF));
        assert_eq!(r.get_unary(), Some(5));
        assert_eq!(r.get_f32(), Some(-1.25));
        assert_eq!(r.remaining(), 0);
        assert_eq!(r.get_bit(), None);
    }

    #[test]
    fn zero_length_values() {
        let mut w = BitWriter::new();
        w.put_bits(0, 0);
        w.put_unary(0);
        let (bytes, bits) = w.finish();
        assert_eq!(bits, 1);
        let mut r = BitReader::new(&bytes, bits);
        assert_eq!(r.get_unary(), Some(0));
    }

    #[test]
    fn reader_stops_at_len_bits_not_byte_boundary() {
        let mut w = BitWriter::new();
        w.put_bits(0b101, 3);
        let (bytes, bits) = w.finish();
        assert_eq!(bytes.len(), 1);
        let mut r = BitReader::new(&bytes, bits);
        assert_eq!(r.get_bits(3), Some(0b101));
        assert_eq!(r.get_bit(), None); // padding bits are not readable
    }

    #[test]
    fn many_random_values() {
        let mut rng = crate::util::rng::Rng::new(11);
        let vals: Vec<(u64, u32)> =
            (0..500).map(|_| { let n = 1 + rng.below(48) as u32; (rng.next_u64() & ((1u64 << n) - 1), n) }).collect();
        let mut w = BitWriter::new();
        for &(v, n) in &vals {
            w.put_bits(v, n);
        }
        let (bytes, bits) = w.finish();
        let mut r = BitReader::new(&bytes, bits);
        for &(v, n) in &vals {
            assert_eq!(r.get_bits(n), Some(v));
        }
    }
}
