//! Wire codec stage: the bidirectional bit-exact message format.
//!
//! This is the third stage of the compression pipeline
//! (Select → Quantize → **Encode**): every [`UpdateMsg`] — client→server
//! compressed updates *and* the server→client broadcast aggregate — is
//! serialized to actual bits before it is "sent" and parsed back on the
//! receiving side, so reported compression rates and simulated link times
//! are measured on true wire size (headers included), not estimated.
//!
//! Layout (MSB-first bitstream):
//!   header:  magic u16 = 0x5BC0, version u4, round u32, ntensors u16
//!   per tensor:
//!     tag u4 (TensorUpdate discriminant), then tag-specific payload
//!     (see `encode_tensor`)
//!
//! Sparse position lists use the codec selected in [`PosCodec`]; SBC uses
//! Golomb with the eq.-5 optimal parameter derived from the *actual*
//! sparsity of the tensor (transmitted in 6 bits so the decoder needs no
//! side channel).
//!
//! The hot path uses [`WireCodec`] (a reusable encode buffer) plus
//! [`decode_into`] (reuses the output message's buffers); the allocating
//! [`encode`]/[`decode`] pair remains for cold paths and tests.

use anyhow::{anyhow, Result};

use crate::codec::bitio::{BitReader, BitWriter};
use crate::codec::{golomb, varint};
use crate::compression::{TensorUpdate, UpdateMsg};

const MAGIC: u64 = 0x5BC0;

/// Wire-format version this build writes and accepts. Public because the
/// transport handshake advertises it and the golden-bytes regression test
/// pins the encoding against it.
pub const WIRE_VERSION: u8 = 2;

// [`TensorUpdate`] wire tags (u4 on the wire). Frozen: the golden-bytes
// test pins them, `sbc-lint`'s wire-freeze rule requires each to be
// defined exactly once with exactly these values, and encode + decode
// share these definitions so the two directions cannot drift.
const TAG_DENSE: u64 = 0;
const TAG_SPARSE_F32: u64 = 1;
const TAG_SPARSE_BINARY: u64 = 2;
const TAG_SIGN: u64 = 3;
const TAG_TERNARY: u64 = 4;
const TAG_QUANTIZED: u64 = 5;
const TAG_SIGN_MEANS: u64 = 6;

/// Position-list codec (ablation: ARCHITECTURE.md §Wire format).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PosCodec {
    /// Golomb-Rice gaps at the eq.-5 optimal parameter (paper default).
    Golomb,
    /// Fixed 16-bit gaps with escape (the paper's naive comparator).
    Fixed16,
    /// Elias-gamma gaps (parameter-free universal code).
    Elias,
}

impl PosCodec {
    fn tag(self) -> u64 {
        match self {
            PosCodec::Golomb => 0,
            PosCodec::Fixed16 => 1,
            PosCodec::Elias => 2,
        }
    }

    fn from_tag(t: u64) -> Result<Self> {
        Ok(match t {
            0 => PosCodec::Golomb,
            1 => PosCodec::Fixed16,
            2 => PosCodec::Elias,
            _ => return Err(anyhow!("bad pos codec tag {t}")),
        })
    }
}

/// The reusable wire-codec stage: owns the encode buffer so steady-state
/// encoding allocates nothing. Decode goes through [`decode_into`] with a
/// caller-owned scratch message.
pub struct WireCodec {
    pos: PosCodec,
    writer: BitWriter,
}

impl WireCodec {
    /// A codec using `pos` for sparse position lists.
    pub fn new(pos: PosCodec) -> WireCodec {
        WireCodec { pos, writer: BitWriter::with_capacity(1024) }
    }

    /// The configured position-list codec.
    pub fn pos_codec(&self) -> PosCodec {
        self.pos
    }

    /// Serialize into the internal buffer; returns (bytes, exact bits).
    ///
    /// Decoding needs no codec state — position codecs are tagged on the
    /// wire — so the decode side is the free [`decode_into`].
    pub fn encode(&mut self, msg: &UpdateMsg) -> (&[u8], u64) {
        self.writer.clear();
        write_message(&mut self.writer, msg, self.pos);
        let bits = self.writer.finalize();
        (self.writer.bytes(), bits)
    }
}

fn tensor_tag(t: &TensorUpdate) -> u64 {
    match t {
        TensorUpdate::Dense(_) => TAG_DENSE,
        TensorUpdate::SparseF32 { .. } => TAG_SPARSE_F32,
        TensorUpdate::SparseBinary { .. } => TAG_SPARSE_BINARY,
        TensorUpdate::Sign { .. } => TAG_SIGN,
        TensorUpdate::Ternary { .. } => TAG_TERNARY,
        TensorUpdate::Quantized { .. } => TAG_QUANTIZED,
        TensorUpdate::SignMeans { .. } => TAG_SIGN_MEANS,
    }
}

fn write_positions(w: &mut BitWriter, idx: &[u32], n: usize, codec: PosCodec) {
    w.put_bits(codec.tag(), 2);
    w.put_bits(idx.len() as u64, 32);
    match codec {
        PosCodec::Golomb => {
            // derive b* from actual sparsity; 6 bits on the wire
            let p = (idx.len() as f64 / n.max(1) as f64).max(1e-9);
            let b = golomb::optimal_b(p);
            w.put_bits(b as u64, 6);
            golomb::encode_positions(w, idx, b);
        }
        PosCodec::Fixed16 => varint::encode_fixed(w, idx, 16),
        PosCodec::Elias => varint::encode_elias(w, idx),
    }
}

fn read_positions_into(r: &mut BitReader, out: &mut Vec<u32>) -> Result<()> {
    let codec = PosCodec::from_tag(r.get_bits(2).ok_or_else(|| anyhow!("eof"))?)?;
    let count = r.get_bits(32).ok_or_else(|| anyhow!("eof"))?;
    let ok = match codec {
        PosCodec::Golomb => {
            let b = r.get_bits(6).ok_or_else(|| anyhow!("eof"))? as u32;
            // each position costs at least b remainder bits + the unary
            // terminator — bound the declared count before decoding
            let count = bounded_count(r, count, b as u64 + 1)?;
            golomb::decode_positions_into(r, count, b, out)
        }
        PosCodec::Fixed16 => {
            let count = bounded_count(r, count, 16)?;
            varint::decode_fixed_into(r, count, 16, out)
        }
        PosCodec::Elias => {
            let count = bounded_count(r, count, 1)?;
            varint::decode_elias_into(r, count, out)
        }
    };
    ok.ok_or_else(|| anyhow!("corrupt position stream"))
}

fn encode_tensor(w: &mut BitWriter, t: &TensorUpdate, codec: PosCodec) {
    w.put_bits(tensor_tag(t), 4);
    match t {
        TensorUpdate::Dense(v) => {
            w.put_bits(v.len() as u64, 32);
            for &x in v {
                w.put_f32(x);
            }
        }
        TensorUpdate::SparseF32 { idx, val } => {
            write_positions_with_n(w, idx, codec);
            for &x in val {
                w.put_f32(x);
            }
        }
        TensorUpdate::SparseBinary { idx, mu, side_pos } => {
            write_positions_with_n(w, idx, codec);
            w.put_f32(*mu);
            w.put_bit(*side_pos);
        }
        TensorUpdate::Sign { signs } => {
            w.put_bits(signs.len() as u64, 32);
            for &s in signs {
                w.put_bit(s);
            }
        }
        TensorUpdate::SignMeans { signs, mu_pos, mu_neg } => {
            w.put_bits(signs.len() as u64, 32);
            w.put_f32(*mu_pos);
            w.put_f32(*mu_neg);
            for &s in signs {
                w.put_bit(s);
            }
        }
        TensorUpdate::Ternary { scale, vals } => {
            w.put_bits(vals.len() as u64, 32);
            w.put_f32(*scale);
            for &v in vals {
                // 2-bit code: 00 zero, 01 +1, 10 -1
                w.put_bits(
                    match v {
                        0 => 0,
                        1 => 1,
                        _ => 2,
                    },
                    2,
                );
            }
        }
        TensorUpdate::Quantized { scale, levels, vals } => {
            w.put_bits(vals.len() as u64, 32);
            w.put_f32(*scale);
            w.put_bits(*levels as u64, 8);
            for &v in vals {
                // sign bit + elias-gamma(|v|+1): the QSGD-style entropy code
                w.put_bit(v < 0);
                varint::put_elias_gamma(w, v.unsigned_abs() as u64 + 1);
            }
        }
    }
}

// The position block needs the tensor length n for Golomb b derivation;
// carry it inline (32 bits) — negligible per tensor.
fn write_positions_with_n(w: &mut BitWriter, idx: &[u32], codec: PosCodec) {
    let n = idx.iter().map(|&i| i as usize + 1).max().unwrap_or(1);
    w.put_bits(n as u64, 32);
    write_positions(w, idx, n, codec);
}

fn read_positions_with_n_into(r: &mut BitReader, out: &mut Vec<u32>) -> Result<()> {
    let _n = r.get_bits(32).ok_or_else(|| anyhow!("eof"))?;
    read_positions_into(r, out)
}

// --- decode-side slot helpers: reuse the scratch message's buffers ------

fn need<T>(v: Option<T>) -> Result<T> {
    v.ok_or_else(|| anyhow!("eof"))
}

/// Validate a count declared on the wire against the bits actually left
/// in the stream, given the minimum encoded size of one element. Frames
/// arrive from untrusted sockets: without this, a corrupt 32-bit count
/// (up to 4 billion) would drive a multi-gigabyte `reserve` before the
/// element loop ever hits end-of-stream.
fn bounded_count(r: &BitReader, n: u64, min_bits_per_elem: u64) -> Result<usize> {
    if n.saturating_mul(min_bits_per_elem) > r.remaining() {
        return Err(anyhow!(
            "declared count {n} needs over {} bits but only {} remain",
            n.saturating_mul(min_bits_per_elem),
            r.remaining()
        ));
    }
    Ok(n as usize)
}

fn decode_tensor_into(r: &mut BitReader, slot: &mut TensorUpdate) -> Result<()> {
    let tag = need(r.get_bits(4))?;
    match tag {
        TAG_DENSE => {
            let n = bounded_count(r, need(r.get_bits(32))?, 32)?;
            let v = slot.dense_slot();
            v.reserve(n);
            for _ in 0..n {
                v.push(need(r.get_f32())?);
            }
        }
        TAG_SPARSE_F32 => {
            let (idx, val) = slot.sparse_f32_slot();
            read_positions_with_n_into(r, idx)?;
            bounded_count(r, idx.len() as u64, 32)?;
            val.reserve(idx.len());
            for _ in 0..idx.len() {
                val.push(need(r.get_f32())?);
            }
        }
        TAG_SPARSE_BINARY => {
            let (idx, mu, side_pos) = slot.sparse_binary_slot();
            read_positions_with_n_into(r, idx)?;
            *mu = need(r.get_f32())?;
            *side_pos = need(r.get_bit())?;
        }
        TAG_SIGN => {
            let n = bounded_count(r, need(r.get_bits(32))?, 1)?;
            let signs = slot.sign_slot();
            signs.reserve(n);
            for _ in 0..n {
                signs.push(need(r.get_bit())?);
            }
        }
        TAG_TERNARY => {
            let n = bounded_count(r, need(r.get_bits(32))?, 2)?;
            let (scale, vals) = slot.ternary_slot();
            *scale = need(r.get_f32())?;
            vals.reserve(n);
            for _ in 0..n {
                vals.push(match need(r.get_bits(2))? {
                    0 => 0i8,
                    1 => 1,
                    2 => -1,
                    x => return Err(anyhow!("bad ternary code {x}")),
                });
            }
        }
        TAG_QUANTIZED => {
            let n = bounded_count(r, need(r.get_bits(32))?, 2)?;
            let (scale, levels, vals) = slot.quantized_slot();
            *scale = need(r.get_f32())?;
            *levels = need(r.get_bits(8))? as u8;
            vals.reserve(n);
            for _ in 0..n {
                let neg = need(r.get_bit())?;
                let mag = need(varint::get_elias_gamma(r))? - 1;
                // i8 range: magnitudes 0..=127, plus -128 on the negative side
                let limit = if neg { 128 } else { 127 };
                if mag > limit {
                    return Err(anyhow!("quantized magnitude {mag} out of i8 range"));
                }
                vals.push(if neg { (mag as i16).wrapping_neg() as i8 } else { mag as i8 });
            }
        }
        TAG_SIGN_MEANS => {
            let n = bounded_count(r, need(r.get_bits(32))?, 1)?;
            let (signs, mu_pos, mu_neg) = slot.sign_means_slot();
            *mu_pos = need(r.get_f32())?;
            *mu_neg = need(r.get_f32())?;
            signs.reserve(n);
            for _ in 0..n {
                signs.push(need(r.get_bit())?);
            }
        }
        t => return Err(anyhow!("bad tensor tag {t}")),
    }
    Ok(())
}

fn write_message(w: &mut BitWriter, msg: &UpdateMsg, codec: PosCodec) {
    w.put_bits(MAGIC, 16);
    w.put_bits(WIRE_VERSION as u64, 4);
    w.put_bits(msg.round as u64, 32);
    w.put_bits(msg.tensors.len() as u64, 16);
    for t in &msg.tensors {
        encode_tensor(w, t, codec);
    }
}

/// Serialize a message into a fresh buffer. Returns (bytes, exact bits).
/// Hot paths should prefer [`WireCodec::encode`], which reuses its buffer.
pub fn encode(msg: &UpdateMsg, codec: PosCodec) -> (Vec<u8>, u64) {
    let mut w = BitWriter::with_capacity(1024);
    write_message(&mut w, msg, codec);
    w.finish()
}

/// Parse a message into `out`, reusing `out`'s tensor buffers: a slot
/// whose variant matches the incoming tag keeps its allocations, so
/// steady-state decoding of a stable message shape allocates nothing.
pub fn decode_into(bytes: &[u8], bits: u64, out: &mut UpdateMsg) -> Result<()> {
    if bits > bytes.len() as u64 * 8 {
        return Err(anyhow!("bit count {bits} exceeds buffer ({} bytes)", bytes.len()));
    }
    let mut r = BitReader::new(bytes, bits);
    if r.get_bits(16) != Some(MAGIC) {
        return Err(anyhow!("bad magic"));
    }
    let version = need(r.get_bits(4))?;
    if version != WIRE_VERSION as u64 {
        // v1 carried 1-bit SGD as Sign + Dense[2] pairs, which would
        // silently densify to wrong values under the v2 tensor set
        return Err(anyhow!(
            "unsupported wire version {version} (this build speaks {WIRE_VERSION})"
        ));
    }
    out.round = need(r.get_bits(32))? as u32;
    let ntensors = bounded_count(&r, need(r.get_bits(16))?, 4)?;
    out.tensors.truncate(ntensors);
    while out.tensors.len() < ntensors {
        out.tensors.push(TensorUpdate::placeholder());
    }
    for slot in out.tensors.iter_mut() {
        decode_tensor_into(&mut r, slot)?;
    }
    Ok(())
}

/// Parse a message into a fresh [`UpdateMsg`] (allocating convenience).
pub fn decode(bytes: &[u8], bits: u64) -> Result<UpdateMsg> {
    let mut out = UpdateMsg::scratch();
    decode_into(bytes, bits, &mut out)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: &UpdateMsg, codec: PosCodec) {
        let (bytes, bits) = encode(msg, codec);
        let got = decode(&bytes, bits).unwrap();
        assert_eq!(&got, msg);
    }

    #[test]
    fn all_variants_roundtrip() {
        let msg = UpdateMsg {
            round: 17,
            tensors: vec![
                TensorUpdate::Dense(vec![1.0, -2.5, 0.0]),
                TensorUpdate::SparseF32 { idx: vec![3, 9, 100], val: vec![0.5, -0.25, 7.0] },
                TensorUpdate::SparseBinary { idx: vec![0, 5, 6, 1000], mu: 0.125, side_pos: false },
                TensorUpdate::Sign { signs: vec![true, false, true] },
                TensorUpdate::SignMeans {
                    signs: vec![false, true, true],
                    mu_pos: 0.5,
                    mu_neg: -1.5,
                },
                TensorUpdate::Ternary { scale: 0.3, vals: vec![-1, 0, 1, 1, 0] },
                TensorUpdate::Quantized { scale: 1.5, levels: 8, vals: vec![-8, 0, 3, 8] },
            ],
        };
        for codec in [PosCodec::Golomb, PosCodec::Fixed16, PosCodec::Elias] {
            roundtrip(&msg, codec);
        }
    }

    #[test]
    fn empty_sparse_tensor() {
        let msg = UpdateMsg {
            round: 0,
            tensors: vec![TensorUpdate::SparseBinary { idx: vec![], mu: 0.0, side_pos: true }],
        };
        roundtrip(&msg, PosCodec::Golomb);
    }

    #[test]
    fn wire_codec_reuses_buffers() {
        let msg = UpdateMsg {
            round: 3,
            tensors: vec![TensorUpdate::SparseF32 { idx: vec![1, 4], val: vec![0.5, -1.0] }],
        };
        let mut wire = WireCodec::new(PosCodec::Golomb);
        // decode into a dirty scratch holding a different variant: the
        // slot must be replaced, then reused on the second pass
        let mut scratch = UpdateMsg {
            round: 99,
            tensors: vec![TensorUpdate::Sign { signs: vec![true; 64] }],
        };
        for _ in 0..2 {
            let (bytes, bits) = wire.encode(&msg);
            let bytes = bytes.to_vec();
            decode_into(&bytes, bits, &mut scratch).unwrap();
            assert_eq!(scratch, msg);
        }
    }

    #[test]
    fn rejects_corrupt() {
        let msg = UpdateMsg { round: 1, tensors: vec![TensorUpdate::Dense(vec![1.0])] };
        let (mut bytes, bits) = encode(&msg, PosCodec::Golomb);
        bytes[0] ^= 0xFF;
        assert!(decode(&bytes, bits).is_err());
        // truncation
        let (bytes2, bits2) = encode(&msg, PosCodec::Golomb);
        assert!(decode(&bytes2[..bytes2.len() / 2], bits2 / 2).is_err());
    }

    #[test]
    fn sbc_message_is_small() {
        // 1000 random positions out of 100k at p=0.01 should take ~8.4
        // bits/position (paper eq. 5) plus tiny header
        let mut rng = crate::util::rng::Rng::new(1);
        let idx: Vec<u32> = {
            let mut v: Vec<u32> = (0..100_000u32).filter(|_| rng.next_f64() < 0.01).collect();
            v.dedup();
            v
        };
        let nnz = idx.len() as f64;
        let msg = UpdateMsg {
            round: 0,
            tensors: vec![TensorUpdate::SparseBinary { idx, mu: 0.5, side_pos: true }],
        };
        let (_, bits) = encode(&msg, PosCodec::Golomb);
        let per_pos = (bits as f64 - 150.0) / nnz; // subtract headers
        assert!(per_pos < 9.5, "bits/position {per_pos}");
    }
}
