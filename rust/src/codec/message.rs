//! Wire format for client→server update messages.
//!
//! Every message is serialized to actual bits before it is "sent" and
//! parsed back on the server side, so reported compression rates are
//! measured on true wire size (headers included), not estimated.
//!
//! Layout (MSB-first bitstream):
//!   header:  magic u16 = 0x5BC0, version u4, round u32, ntensors u16
//!   per tensor:
//!     tag u4 (TensorUpdate discriminant), nelems u32
//!     tag-specific payload (see encode_tensor)
//!
//! Sparse position lists use the codec selected in [`PosCodec`]; SBC uses
//! Golomb with the eq.-5 optimal parameter derived from the *actual*
//! sparsity of the tensor (transmitted in 6 bits so the decoder needs no
//! side channel).

use anyhow::{anyhow, Result};

use crate::codec::bitio::{BitReader, BitWriter};
use crate::codec::{golomb, varint};
use crate::compression::{TensorUpdate, UpdateMsg};

const MAGIC: u64 = 0x5BC0;
const VERSION: u64 = 1;

/// Position-list codec (ablation: DESIGN.md §7.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PosCodec {
    Golomb,
    Fixed16,
    Elias,
}

impl PosCodec {
    fn tag(self) -> u64 {
        match self {
            PosCodec::Golomb => 0,
            PosCodec::Fixed16 => 1,
            PosCodec::Elias => 2,
        }
    }

    fn from_tag(t: u64) -> Result<Self> {
        Ok(match t {
            0 => PosCodec::Golomb,
            1 => PosCodec::Fixed16,
            2 => PosCodec::Elias,
            _ => return Err(anyhow!("bad pos codec tag {t}")),
        })
    }
}

fn tensor_tag(t: &TensorUpdate) -> u64 {
    match t {
        TensorUpdate::Dense(_) => 0,
        TensorUpdate::SparseF32 { .. } => 1,
        TensorUpdate::SparseBinary { .. } => 2,
        TensorUpdate::Sign { .. } => 3,
        TensorUpdate::Ternary { .. } => 4,
        TensorUpdate::Quantized { .. } => 5,
    }
}

fn write_positions(w: &mut BitWriter, idx: &[u32], n: usize, codec: PosCodec) {
    w.put_bits(codec.tag(), 2);
    w.put_bits(idx.len() as u64, 32);
    match codec {
        PosCodec::Golomb => {
            // derive b* from actual sparsity; 6 bits on the wire
            let p = (idx.len() as f64 / n.max(1) as f64).max(1e-9);
            let b = golomb::optimal_b(p);
            w.put_bits(b as u64, 6);
            golomb::encode_positions(w, idx, b);
        }
        PosCodec::Fixed16 => varint::encode_fixed(w, idx, 16),
        PosCodec::Elias => varint::encode_elias(w, idx),
    }
}

fn read_positions(r: &mut BitReader) -> Result<Vec<u32>> {
    let codec = PosCodec::from_tag(r.get_bits(2).ok_or_else(|| anyhow!("eof"))?)?;
    let count = r.get_bits(32).ok_or_else(|| anyhow!("eof"))? as usize;
    let idx = match codec {
        PosCodec::Golomb => {
            let b = r.get_bits(6).ok_or_else(|| anyhow!("eof"))? as u32;
            golomb::decode_positions(r, count, b)
        }
        PosCodec::Fixed16 => varint::decode_fixed(r, count, 16),
        PosCodec::Elias => varint::decode_elias(r, count),
    };
    idx.ok_or_else(|| anyhow!("truncated position stream"))
}

fn encode_tensor(w: &mut BitWriter, t: &TensorUpdate, codec: PosCodec) {
    w.put_bits(tensor_tag(t), 4);
    match t {
        TensorUpdate::Dense(v) => {
            w.put_bits(v.len() as u64, 32);
            for &x in v {
                w.put_f32(x);
            }
        }
        TensorUpdate::SparseF32 { idx, val } => {
            write_positions_with_n(w, idx, codec);
            for &x in val {
                w.put_f32(x);
            }
        }
        TensorUpdate::SparseBinary { idx, mu, side_pos } => {
            write_positions_with_n(w, idx, codec);
            w.put_f32(*mu);
            w.put_bit(*side_pos);
        }
        TensorUpdate::Sign { signs } => {
            w.put_bits(signs.len() as u64, 32);
            for &s in signs {
                w.put_bit(s);
            }
        }
        TensorUpdate::Ternary { scale, vals } => {
            w.put_bits(vals.len() as u64, 32);
            w.put_f32(*scale);
            for &v in vals {
                // 2-bit code: 00 zero, 01 +1, 10 -1
                w.put_bits(match v {
                    0 => 0,
                    1 => 1,
                    _ => 2,
                }, 2);
            }
        }
        TensorUpdate::Quantized { scale, levels, vals } => {
            w.put_bits(vals.len() as u64, 32);
            w.put_f32(*scale);
            w.put_bits(*levels as u64, 8);
            for &v in vals {
                // sign bit + elias-gamma(|v|+1): the QSGD-style entropy code
                w.put_bit(v < 0);
                varint::put_elias_gamma(w, v.unsigned_abs() as u64 + 1);
            }
        }
    }
}

// The position block needs the tensor length n for Golomb b derivation;
// carry it inline (32 bits) — negligible per tensor.
fn write_positions_with_n(w: &mut BitWriter, idx: &[u32], codec: PosCodec) {
    let n = idx.iter().map(|&i| i as usize + 1).max().unwrap_or(1);
    w.put_bits(n as u64, 32);
    write_positions(w, idx, n, codec);
}

fn read_positions_with_n(r: &mut BitReader) -> Result<Vec<u32>> {
    let _n = r.get_bits(32).ok_or_else(|| anyhow!("eof"))?;
    read_positions(r)
}

fn decode_tensor(r: &mut BitReader) -> Result<TensorUpdate> {
    let tag = r.get_bits(4).ok_or_else(|| anyhow!("eof"))?;
    Ok(match tag {
        0 => {
            let n = r.get_bits(32).ok_or_else(|| anyhow!("eof"))? as usize;
            let mut v = Vec::with_capacity(n);
            for _ in 0..n {
                v.push(r.get_f32().ok_or_else(|| anyhow!("eof"))?);
            }
            TensorUpdate::Dense(v)
        }
        1 => {
            let idx = read_positions_with_n(r)?;
            let mut val = Vec::with_capacity(idx.len());
            for _ in 0..idx.len() {
                val.push(r.get_f32().ok_or_else(|| anyhow!("eof"))?);
            }
            TensorUpdate::SparseF32 { idx, val }
        }
        2 => {
            let idx = read_positions_with_n(r)?;
            let mu = r.get_f32().ok_or_else(|| anyhow!("eof"))?;
            let side_pos = r.get_bit().ok_or_else(|| anyhow!("eof"))?;
            TensorUpdate::SparseBinary { idx, mu, side_pos }
        }
        3 => {
            let n = r.get_bits(32).ok_or_else(|| anyhow!("eof"))? as usize;
            let mut signs = Vec::with_capacity(n);
            for _ in 0..n {
                signs.push(r.get_bit().ok_or_else(|| anyhow!("eof"))?);
            }
            TensorUpdate::Sign { signs }
        }
        4 => {
            let n = r.get_bits(32).ok_or_else(|| anyhow!("eof"))? as usize;
            let scale = r.get_f32().ok_or_else(|| anyhow!("eof"))?;
            let mut vals = Vec::with_capacity(n);
            for _ in 0..n {
                vals.push(match r.get_bits(2).ok_or_else(|| anyhow!("eof"))? {
                    0 => 0i8,
                    1 => 1,
                    2 => -1,
                    x => return Err(anyhow!("bad ternary code {x}")),
                });
            }
            TensorUpdate::Ternary { scale, vals }
        }
        5 => {
            let n = r.get_bits(32).ok_or_else(|| anyhow!("eof"))? as usize;
            let scale = r.get_f32().ok_or_else(|| anyhow!("eof"))?;
            let levels = r.get_bits(8).ok_or_else(|| anyhow!("eof"))? as u8;
            let mut vals = Vec::with_capacity(n);
            for _ in 0..n {
                let neg = r.get_bit().ok_or_else(|| anyhow!("eof"))?;
                let mag = varint::get_elias_gamma(r).ok_or_else(|| anyhow!("eof"))? - 1;
                vals.push(if neg { -(mag as i8) } else { mag as i8 });
            }
            TensorUpdate::Quantized { scale, levels, vals }
        }
        t => return Err(anyhow!("bad tensor tag {t}")),
    })
}

/// Serialize a message. Returns (bytes, exact bit count).
pub fn encode(msg: &UpdateMsg, codec: PosCodec) -> (Vec<u8>, u64) {
    let mut w = BitWriter::with_capacity(1024);
    w.put_bits(MAGIC, 16);
    w.put_bits(VERSION, 4);
    w.put_bits(msg.round as u64, 32);
    w.put_bits(msg.tensors.len() as u64, 16);
    for t in &msg.tensors {
        encode_tensor(&mut w, t, codec);
    }
    w.finish()
}

/// Parse a message previously produced by [`encode`].
pub fn decode(bytes: &[u8], bits: u64) -> Result<UpdateMsg> {
    if bits > bytes.len() as u64 * 8 {
        return Err(anyhow!("bit count {bits} exceeds buffer ({} bytes)", bytes.len()));
    }
    let mut r = BitReader::new(bytes, bits);
    if r.get_bits(16) != Some(MAGIC) {
        return Err(anyhow!("bad magic"));
    }
    let _version = r.get_bits(4).ok_or_else(|| anyhow!("eof"))?;
    let round = r.get_bits(32).ok_or_else(|| anyhow!("eof"))? as u32;
    let ntensors = r.get_bits(16).ok_or_else(|| anyhow!("eof"))? as usize;
    let mut tensors = Vec::with_capacity(ntensors);
    for _ in 0..ntensors {
        tensors.push(decode_tensor(&mut r)?);
    }
    Ok(UpdateMsg { round, tensors })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: &UpdateMsg, codec: PosCodec) {
        let (bytes, bits) = encode(msg, codec);
        let got = decode(&bytes, bits).unwrap();
        assert_eq!(&got, msg);
    }

    #[test]
    fn all_variants_roundtrip() {
        let msg = UpdateMsg {
            round: 17,
            tensors: vec![
                TensorUpdate::Dense(vec![1.0, -2.5, 0.0]),
                TensorUpdate::SparseF32 { idx: vec![3, 9, 100], val: vec![0.5, -0.25, 7.0] },
                TensorUpdate::SparseBinary { idx: vec![0, 5, 6, 1000], mu: 0.125, side_pos: false },
                TensorUpdate::Sign { signs: vec![true, false, true] },
                TensorUpdate::Ternary { scale: 0.3, vals: vec![-1, 0, 1, 1, 0] },
                TensorUpdate::Quantized { scale: 1.5, levels: 8, vals: vec![-8, 0, 3, 8] },
            ],
        };
        for codec in [PosCodec::Golomb, PosCodec::Fixed16, PosCodec::Elias] {
            roundtrip(&msg, codec);
        }
    }

    #[test]
    fn empty_sparse_tensor() {
        let msg = UpdateMsg {
            round: 0,
            tensors: vec![TensorUpdate::SparseBinary { idx: vec![], mu: 0.0, side_pos: true }],
        };
        roundtrip(&msg, PosCodec::Golomb);
    }

    #[test]
    fn rejects_corrupt() {
        let msg = UpdateMsg { round: 1, tensors: vec![TensorUpdate::Dense(vec![1.0])] };
        let (mut bytes, bits) = encode(&msg, PosCodec::Golomb);
        bytes[0] ^= 0xFF;
        assert!(decode(&bytes, bits).is_err());
        // truncation
        let (bytes2, bits2) = encode(&msg, PosCodec::Golomb);
        assert!(decode(&bytes2[..bytes2.len() / 2], bits2 / 2).is_err());
    }

    #[test]
    fn sbc_message_is_small() {
        // 1000 random positions out of 100k at p=0.01 should take ~8.4
        // bits/position (paper eq. 5) plus tiny header
        let mut rng = crate::util::rng::Rng::new(1);
        let idx: Vec<u32> = {
            let mut v: Vec<u32> = (0..100_000u32).filter(|_| rng.next_f64() < 0.01).collect();
            v.dedup();
            v
        };
        let nnz = idx.len() as f64;
        let msg = UpdateMsg {
            round: 0,
            tensors: vec![TensorUpdate::SparseBinary { idx, mu: 0.5, side_pos: true }],
        };
        let (_, bits) = encode(&msg, PosCodec::Golomb);
        let per_pos = (bits as f64 - 150.0) / nnz; // subtract headers
        assert!(per_pos < 9.5, "bits/position {per_pos}");
    }
}
