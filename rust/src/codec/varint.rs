//! Comparator position codecs for the ablation study (ARCHITECTURE.md
//! §Wire format):
//! fixed-width gap coding (the "naive 16-bit" scheme the paper compares
//! against) and Elias-gamma, a parameter-free universal code.

use crate::codec::bitio::{BitReader, BitWriter};

/// Fixed-width gap coding: each gap-1 in `width` bits; gaps that overflow
/// are escaped with an all-ones marker followed by 32 raw bits (rare).
pub fn encode_fixed(w: &mut BitWriter, positions: &[u32], width: u32) {
    let escape = (1u64 << width) - 1;
    let mut prev: i64 = -1;
    for &pos in positions {
        let v = (pos as i64 - prev - 1) as u64;
        if v >= escape {
            w.put_bits(escape, width);
            w.put_bits(v, 32);
        } else {
            w.put_bits(v, width);
        }
        prev = pos as i64;
    }
}

/// Decode `count` positions written by [`encode_fixed`] (allocating).
pub fn decode_fixed(r: &mut BitReader, count: usize, width: u32) -> Option<Vec<u32>> {
    let mut out = Vec::with_capacity(count);
    decode_fixed_into(r, count, width, &mut out)?;
    Some(out)
}

/// Allocation-free variant of [`decode_fixed`] for reused scratch.
pub fn decode_fixed_into(
    r: &mut BitReader,
    count: usize,
    width: u32,
    out: &mut Vec<u32>,
) -> Option<()> {
    let escape = (1u64 << width) - 1;
    out.clear();
    let mut prev: i64 = -1;
    for _ in 0..count {
        let mut v = r.get_bits(width)?;
        if v == escape {
            v = r.get_bits(32)?;
        }
        let pos = prev + v as i64 + 1;
        if pos > u32::MAX as i64 {
            return None; // corrupt gap would wrap the u32 position
        }
        out.push(pos as u32);
        prev = pos;
    }
    Some(())
}

/// Elias-gamma code for x >= 1: floor(log2 x) zeros, then x in binary.
pub fn put_elias_gamma(w: &mut BitWriter, x: u64) {
    debug_assert!(x >= 1);
    let nbits = 64 - x.leading_zeros();
    for _ in 0..nbits - 1 {
        w.put_bit(false);
    }
    w.put_bits(x, nbits);
}

/// Read one Elias-gamma value written by [`put_elias_gamma`].
pub fn get_elias_gamma(r: &mut BitReader) -> Option<u64> {
    let mut zeros = 0u32;
    loop {
        match r.get_bit()? {
            false => zeros += 1,
            true => break,
        }
    }
    if zeros >= 64 {
        return None; // corrupt stream: value would overflow u64
    }
    let rest = r.get_bits(zeros)?;
    Some((1u64 << zeros) | rest)
}

/// Elias-gamma gap coding of sorted positions (parameter-free).
pub fn encode_elias(w: &mut BitWriter, positions: &[u32]) {
    let mut prev: i64 = -1;
    for &pos in positions {
        put_elias_gamma(w, (pos as i64 - prev) as u64);
        prev = pos as i64;
    }
}

/// Decode `count` positions written by [`encode_elias`] (allocating).
pub fn decode_elias(r: &mut BitReader, count: usize) -> Option<Vec<u32>> {
    let mut out = Vec::with_capacity(count);
    decode_elias_into(r, count, &mut out)?;
    Some(out)
}

/// Allocation-free variant of [`decode_elias`] for reused scratch.
pub fn decode_elias_into(r: &mut BitReader, count: usize, out: &mut Vec<u32>) -> Option<()> {
    out.clear();
    let mut prev: i64 = -1;
    for _ in 0..count {
        let d = get_elias_gamma(r)?;
        if d > u32::MAX as u64 {
            return None; // corrupt gap would wrap the u32 position
        }
        let pos = prev + d as i64;
        if pos > u32::MAX as i64 {
            return None;
        }
        out.push(pos as u32);
        prev = pos;
    }
    Some(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn fixed_roundtrip_with_escapes() {
        let positions = vec![0u32, 3, 70_000, 70_001]; // 70_000 gap overflows 16 bits
        let mut w = BitWriter::new();
        encode_fixed(&mut w, &positions, 16);
        let (bytes, bits) = w.finish();
        let mut r = BitReader::new(&bytes, bits);
        assert_eq!(decode_fixed(&mut r, positions.len(), 16).unwrap(), positions);
    }

    #[test]
    fn elias_gamma_small_values() {
        let mut w = BitWriter::new();
        for x in 1..=64u64 {
            put_elias_gamma(&mut w, x);
        }
        let (bytes, bits) = w.finish();
        let mut r = BitReader::new(&bytes, bits);
        for x in 1..=64u64 {
            assert_eq!(get_elias_gamma(&mut r), Some(x));
        }
    }

    #[test]
    fn elias_positions_roundtrip() {
        let mut rng = Rng::new(3);
        let positions: Vec<u32> = {
            let mut v: Vec<u32> = (0..500).map(|_| rng.next_u32() % 1_000_000).collect();
            v.sort_unstable();
            v.dedup();
            v
        };
        let mut w = BitWriter::new();
        encode_elias(&mut w, &positions);
        let (bytes, bits) = w.finish();
        let mut r = BitReader::new(&bytes, bits);
        assert_eq!(decode_elias(&mut r, positions.len()).unwrap(), positions);
    }
}
