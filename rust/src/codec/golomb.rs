//! Golomb-Rice position coding — paper Algorithm 3 (encode) / 4 (decode)
//! and equation (5).
//!
//! The gaps between successive non-zero positions of a random sparsity-p
//! mask are geometrically distributed; the Golomb code with
//! `b* = 1 + floor(log2( log(phi-1) / log(1-p) ))` (phi the golden ratio)
//! is the optimal prefix code for that distribution. Gaps are encoded as
//! `d-1 = q * 2^b* + r` → q ones, a zero, then r in b* fixed bits.

use crate::codec::bitio::{BitReader, BitWriter};

/// Golden ratio φ.
pub const PHI: f64 = 1.618033988749894848;

/// Optimal Rice parameter b* for sparsity `p` (paper eq. 5, left part).
pub fn optimal_b(p: f64) -> u32 {
    let p = p.clamp(1e-12, 0.999_999);
    // log(phi - 1) / log(1 - p)  =  log_{1-p}(phi^-1)
    let ratio = (PHI - 1.0).ln() / (1.0 - p).ln();
    let b = 1 + ratio.log2().floor() as i64;
    b.clamp(0, 62) as u32
}

/// Expected bits per position, `b̄_pos = b* + 1/(1-(1-p)^{2^b*})` (eq. 5).
pub fn expected_bits_per_position(p: f64) -> f64 {
    let b = optimal_b(p);
    let m = (1u64 << b) as f64;
    b as f64 + 1.0 / (1.0 - (1.0 - p).powf(m))
}

/// Encode sorted non-zero positions as first-difference Golomb codes.
/// Positions must be strictly increasing. `b` is the Rice parameter.
pub fn encode_positions(w: &mut BitWriter, positions: &[u32], b: u32) {
    let mut prev: i64 = -1;
    for &pos in positions {
        let d = pos as i64 - prev; // gap >= 1
        debug_assert!(d >= 1, "positions must be strictly increasing");
        let v = (d - 1) as u64;
        let q = v >> b;
        let r = v & ((1u64 << b) - 1);
        w.put_unary(q);
        w.put_bits(r, b);
        prev = pos as i64;
    }
}

/// Decode `count` positions previously encoded with `encode_positions`.
pub fn decode_positions(r: &mut BitReader, count: usize, b: u32) -> Option<Vec<u32>> {
    let mut out = Vec::with_capacity(count);
    decode_positions_into(r, count, b, &mut out)?;
    Some(out)
}

/// Decode `count` positions into `out` (cleared first) — the
/// allocation-free variant for reused scratch buffers.
pub fn decode_positions_into(
    r: &mut BitReader,
    count: usize,
    b: u32,
    out: &mut Vec<u32>,
) -> Option<()> {
    out.clear();
    let mut prev: i64 = -1;
    for _ in 0..count {
        let q = r.get_unary()?;
        let rem = r.get_bits(b)?;
        // corrupt streams can carry arbitrary quotients/parameters: any
        // gap that would shift out of range or push a position past u32
        // is malformed, not a panic (b <= 63 comes off 6 wire bits)
        if b >= 64 || q > (u64::MAX >> b) {
            return None;
        }
        let v = (q << b) | rem;
        if v >= u32::MAX as u64 {
            return None;
        }
        let pos = prev + v as i64 + 1;
        if pos > u32::MAX as i64 {
            return None;
        }
        out.push(pos as u32);
        prev = pos;
    }
    Some(())
}

/// Measured encode size in bits for a gap list, without writing.
pub fn measure_positions_bits(positions: &[u32], b: u32) -> u64 {
    let mut bits = 0u64;
    let mut prev: i64 = -1;
    for &pos in positions {
        let v = (pos as i64 - prev - 1) as u64;
        bits += (v >> b) + 1 + b as u64;
        prev = pos as i64;
    }
    bits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn b_star_reference_values() {
        // The paper quotes b̄_pos = 8.38 at p = 0.01, which corresponds to
        // b* = 7; its own eq.-5 formula (which we implement) yields b* = 6
        // and b̄_pos = 8.11 — strictly fewer bits. Accept the formula value
        // and require we never exceed the paper's quoted cost.
        let b001 = expected_bits_per_position(0.01);
        assert!((b001 - 8.108).abs() < 0.01, "{b001}");
        assert!(b001 <= 8.38);
        // for p = 0.001 the paper's Table I range is 8-14 position bits
        let b = expected_bits_per_position(0.001);
        assert!(b > 11.0 && b < 14.0, "{b}");
    }

    #[test]
    fn optimal_b_monotone_in_p() {
        let mut last = u32::MAX;
        for &p in &[0.0005, 0.001, 0.01, 0.05, 0.1, 0.3] {
            let b = optimal_b(p);
            assert!(b <= last, "b must not grow with denser p");
            last = b;
        }
    }

    #[test]
    fn roundtrip_simple() {
        let positions = vec![0u32, 1, 7, 8, 100, 10_000, 10_001];
        for b in [0u32, 1, 4, 8, 12] {
            let mut w = BitWriter::new();
            encode_positions(&mut w, &positions, b);
            let (bytes, bits) = w.finish();
            assert_eq!(bits, measure_positions_bits(&positions, b));
            let mut r = BitReader::new(&bytes, bits);
            let got = decode_positions(&mut r, positions.len(), b).unwrap();
            assert_eq!(got, positions);
        }
    }

    #[test]
    fn roundtrip_random_masks() {
        let mut rng = Rng::new(5);
        for &p in &[0.001, 0.01, 0.1] {
            let n = 200_000;
            let positions: Vec<u32> =
                (0..n).filter(|_| rng.next_f64() < p).map(|i| i as u32).collect();
            if positions.is_empty() {
                continue;
            }
            let b = optimal_b(p);
            let mut w = BitWriter::new();
            encode_positions(&mut w, &positions, b);
            let (bytes, bits) = w.finish();
            let mut r = BitReader::new(&bytes, bits);
            assert_eq!(decode_positions(&mut r, positions.len(), b).unwrap(), positions);
            // measured bits/position within 15% of the analytic expectation
            let per = bits as f64 / positions.len() as f64;
            let want = expected_bits_per_position(p);
            assert!((per - want).abs() / want < 0.15, "p={p}: {per} vs {want}");
        }
    }

    #[test]
    fn golomb_beats_fixed16_at_p001() {
        // the paper's ×1.9 claim at p = 0.01 vs 16-bit distance coding
        let per = expected_bits_per_position(0.01);
        assert!(16.0 / per > 1.85, "compression vs fixed-16: {}", 16.0 / per);
    }

    #[test]
    fn degenerate_gaps() {
        // all-adjacent positions (gap 1 everywhere) and one huge gap
        let positions = vec![5u32, 6, 7, 8, 1_000_000];
        let b = optimal_b(0.0001);
        let mut w = BitWriter::new();
        encode_positions(&mut w, &positions, b);
        let (bytes, bits) = w.finish();
        let mut r = BitReader::new(&bytes, bits);
        assert_eq!(decode_positions(&mut r, positions.len(), b).unwrap(), positions);
    }
}
