//! Bit-exact wire codecs: bit I/O, Golomb (paper Alg. 3/4), comparator
//! codecs, the message format, and communication accounting (eq. 1).

pub mod accounting;
pub mod bitio;
pub mod golomb;
pub mod message;
pub mod varint;
