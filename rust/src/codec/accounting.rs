//! Communication accounting — paper equation (1) and Table I.
//!
//! Two kinds of numbers live here:
//!   * **theoretical** per-method asymptotic bit costs (Table I rows),
//!     computed from the formulas the paper uses, and
//!   * **measured** cumulative counters fed by the coordinator with the
//!     exact wire size of every encoded message.

use crate::codec::golomb;

/// Theoretical per-iteration upstream bits per parameter for a method
/// (paper eq. 1 normalized by N_iter * |W|), and the derived compression
/// rate vs. the 32-bit dense baseline.
#[derive(Clone, Debug)]
pub struct MethodCost {
    /// Method label (Table I row name).
    pub name: &'static str,
    /// Fraction of iterations with communication (1/n for delay n).
    pub temporal: f64,
    /// Fraction of gradient entries transmitted.
    pub gradient_sparsity: f64,
    /// Value bits per transmitted entry.
    pub value_bits: f64,
    /// Position bits per transmitted entry.
    pub position_bits: f64,
}

impl MethodCost {
    /// Bits per parameter per local iteration.
    pub fn bits_per_param_iter(&self) -> f64 {
        self.temporal * self.gradient_sparsity * (self.value_bits + self.position_bits)
    }

    /// Compression rate vs dense 32-bit updates every iteration.
    pub fn compression_rate(&self) -> f64 {
        32.0 / self.bits_per_param_iter()
    }
}

/// The Table I rows (theoretical asymptotic costs).
pub fn table1_rows() -> Vec<MethodCost> {
    vec![
        MethodCost { name: "Baseline", temporal: 1.0, gradient_sparsity: 1.0, value_bits: 32.0, position_bits: 0.0 },
        MethodCost { name: "signSGD", temporal: 1.0, gradient_sparsity: 1.0, value_bits: 1.0, position_bits: 0.0 },
        MethodCost { name: "TernGrad", temporal: 1.0, gradient_sparsity: 1.0, value_bits: 2.0, position_bits: 0.0 },
        MethodCost { name: "QSGD(8)", temporal: 1.0, gradient_sparsity: 1.0, value_bits: 8.0, position_bits: 0.0 },
        MethodCost { name: "GradDrop(p=.001)", temporal: 1.0, gradient_sparsity: 0.001, value_bits: 32.0, position_bits: 16.0 },
        MethodCost { name: "DGC(p=.001)", temporal: 1.0, gradient_sparsity: 0.001, value_bits: 32.0, position_bits: 16.0 },
        MethodCost { name: "FedAvg(n=100)", temporal: 0.01, gradient_sparsity: 1.0, value_bits: 32.0, position_bits: 0.0 },
        MethodCost {
            name: "SBC(p=.01,n=100)",
            temporal: 0.01,
            gradient_sparsity: 0.01,
            value_bits: 0.0, // + one f32 mean per tensor, amortized to ~0
            position_bits: golomb::expected_bits_per_position(0.01),
        },
    ]
}

/// Running measured-communication counters for one training run.
#[derive(Clone, Debug, Default)]
pub struct CommStats {
    /// Total upstream bits actually put on the wire (all clients).
    pub upstream_bits: u64,
    /// Total messages sent.
    pub messages: u64,
    /// Total non-zero elements transmitted.
    pub nonzeros: u64,
    /// What dense-f32-every-iteration would have cost (the baseline).
    pub baseline_bits: u64,
    /// Transport framing overhead (frame headers, CRCs and byte-padding
    /// around payloads — [`crate::transport::frame::overhead_bits`]) in
    /// both directions. Kept separate from `upstream_bits` so the
    /// compression rate stays a pure payload measure while
    /// [`total_wire_bits`](CommStats::total_wire_bits) reflects what the
    /// sockets actually carry.
    pub frame_overhead_bits: u64,
}

impl CommStats {
    /// Account one encoded upstream message (order-independent: the
    /// counters are pure sums, so serial and pooled coordinators record
    /// identical totals).
    pub fn record_message(&mut self, wire_bits: u64, nonzeros: u64) {
        self.upstream_bits += wire_bits;
        self.messages += 1;
        self.nonzeros += nonzeros;
    }

    /// Account one local iteration of one client against the baseline
    /// (dense 32-bit update of `n_params` every iteration).
    pub fn record_baseline_iter(&mut self, n_params: usize) {
        self.baseline_bits += 32 * n_params as u64;
    }

    /// Account transport framing overhead around one or more frames.
    pub fn record_frame_overhead(&mut self, bits: u64) {
        self.frame_overhead_bits += bits;
    }

    /// Everything the training put on the wire: payload bits plus frame
    /// overhead (headers, CRCs, byte padding).
    pub fn total_wire_bits(&self) -> u64 {
        self.upstream_bits + self.frame_overhead_bits
    }

    /// Measured compression rate vs the dense baseline.
    pub fn compression_rate(&self) -> f64 {
        if self.upstream_bits == 0 {
            return 1.0;
        }
        self.baseline_bits as f64 / self.upstream_bits as f64
    }

    /// Total upstream traffic in megabytes.
    pub fn upstream_megabytes(&self) -> f64 {
        self.upstream_bits as f64 / 8e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper_magnitudes() {
        let rows = table1_rows();
        let by_name = |n: &str| rows.iter().find(|r| r.name.starts_with(n)).unwrap().compression_rate();
        assert!((by_name("Baseline") - 1.0).abs() < 1e-9);
        assert!((by_name("signSGD") - 32.0).abs() < 1e-9);
        assert!((by_name("TernGrad") - 16.0).abs() < 1e-9);
        // paper Table I: DGC ~ x666 with 48 bits per entry at p = 0.001
        let dgc = by_name("DGC");
        assert!((660.0..=670.0).contains(&dgc), "{dgc}");
        // FedAvg at n=100 -> x100 (paper range x10-x1000)
        assert!((by_name("FedAvg") - 100.0).abs() < 1e-9);
        // SBC at p=0.01, n=100: paper's headline "up to x40000" scale
        let sbc = by_name("SBC");
        assert!(sbc > 30_000.0 && sbc < 50_000.0, "{sbc}");
    }

    #[test]
    fn comm_stats_accumulate() {
        let mut s = CommStats::default();
        for _ in 0..10 {
            s.record_baseline_iter(1000);
        }
        s.record_message(3_200, 10);
        assert_eq!(s.upstream_bits, 3_200);
        assert_eq!(s.baseline_bits, 320_000);
        assert!((s.compression_rate() - 100.0).abs() < 1e-9);
        assert!((s.upstream_megabytes() - 3_200.0 / 8e6).abs() < 1e-12);
    }

    #[test]
    fn frame_overhead_is_separate_from_payload() {
        let mut s = CommStats::default();
        s.record_baseline_iter(1000);
        s.record_message(3_200, 10);
        s.record_frame_overhead(192);
        assert_eq!(s.frame_overhead_bits, 192);
        assert_eq!(s.total_wire_bits(), 3_392);
        // the compression rate stays a pure payload measure
        assert!((s.compression_rate() - 10.0).abs() < 1e-9);
    }
}
