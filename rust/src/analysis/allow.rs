//! The suppression grammar: `// sbc-lint: allow(<rule>) -- <reason>`.
//!
//! Suppressions are deliberately expensive to write and impossible to
//! leave rotting: the reason is mandatory, a trailing comment suppresses
//! only its own line, an own-line comment only the next line, and an
//! allow that suppresses nothing is itself an error (`unused-allow`), as
//! is a comment that invokes `sbc-lint:` but fails to parse
//! (`bad-allow`). Neither of those two meta-findings can be suppressed.

use crate::analysis::lexer::Comment;
use crate::analysis::report::Finding;
use crate::analysis::rules::RULE_IDS;

/// One parsed suppression comment.
#[derive(Clone, Debug)]
pub struct Allow {
    /// 1-based line the comment sits on.
    pub line: usize,
    /// The rule id inside `allow(...)`.
    pub rule: String,
    /// The line whose findings this allow suppresses.
    pub target: usize,
}

/// Extract suppressions from a file's line comments. Returns the parsed
/// allows plus `bad-allow` findings for comments that invoke the
/// `sbc-lint:` marker but do not match the grammar.
pub fn collect(rel: &str, comments: &[Comment]) -> (Vec<Allow>, Vec<Finding>) {
    let mut allows = Vec::new();
    let mut bad = Vec::new();
    for c in comments {
        let Some(body) = c.text.strip_prefix("//") else { continue };
        let Some(directive) = body.trim_start().strip_prefix("sbc-lint:") else { continue };
        let directive = directive.trim();
        let parsed = directive
            .strip_prefix("allow(")
            .and_then(|rest| rest.split_once(')'))
            .and_then(|(rule, rest)| {
                let rest = rest.trim_start();
                let reason = rest.strip_prefix("--")?.trim();
                (!reason.is_empty() && RULE_IDS.contains(&rule.trim())).then(|| rule.trim())
            });
        match parsed {
            Some(rule) => allows.push(Allow {
                line: c.line,
                rule: rule.to_string(),
                target: if c.own_line { c.line + 1 } else { c.line },
            }),
            None => bad.push(Finding {
                file: rel.to_string(),
                line: c.line,
                rule: "bad-allow".to_string(),
                message: "malformed suppression: expected \
                          `// sbc-lint: allow(<rule>) -- <reason>`"
                    .to_string(),
            }),
        }
    }
    (allows, bad)
}

/// Apply `allows` to `findings`: drop every finding an allow covers, and
/// emit an `unused-allow` finding for each allow that covered nothing.
pub fn apply(rel: &str, allows: &[Allow], findings: Vec<Finding>) -> Vec<Finding> {
    let mut used = vec![false; allows.len()];
    let mut out: Vec<Finding> = Vec::new();
    for f in findings {
        let mut suppressed = false;
        for (k, a) in allows.iter().enumerate() {
            if a.rule == f.rule && a.target == f.line {
                used[k] = true;
                suppressed = true;
            }
        }
        if !suppressed {
            out.push(f);
        }
    }
    for (k, a) in allows.iter().enumerate() {
        if !used[k] {
            out.push(Finding {
                file: rel.to_string(),
                line: a.line,
                rule: "unused-allow".to_string(),
                message: format!("allow({}) suppresses nothing on line {}", a.rule, a.target),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;

    #[test]
    fn own_line_targets_next_line_trailing_its_own() {
        let src = "// sbc-lint: allow(no-panic) -- reason\nx();\n\
                   y(); // sbc-lint: allow(determinism) -- why\n";
        let lx = lex(src);
        let (allows, bad) = collect("f.rs", &lx.comments);
        assert!(bad.is_empty());
        assert_eq!(allows.len(), 2);
        assert_eq!((allows[0].line, allows[0].target), (1, 2));
        assert_eq!((allows[1].line, allows[1].target), (3, 3));
    }

    #[test]
    fn malformed_and_unknown_rule_are_bad_allow() {
        let src = "// sbc-lint: allow(no-panic)\n\
                   // sbc-lint: allow(nope) -- reason\n\
                   // sbc-lint: please\n";
        let lx = lex(src);
        let (allows, bad) = collect("f.rs", &lx.comments);
        assert!(allows.is_empty());
        assert_eq!(bad.len(), 3);
        assert!(bad.iter().all(|f| f.rule == "bad-allow"));
    }

    #[test]
    fn unused_allow_is_flagged_used_allow_suppresses() {
        let allows = vec![
            Allow { line: 1, rule: "no-panic".to_string(), target: 2 },
            Allow { line: 5, rule: "no-panic".to_string(), target: 6 },
        ];
        let findings = vec![Finding {
            file: "f.rs".to_string(),
            line: 2,
            rule: "no-panic".to_string(),
            message: "x".to_string(),
        }];
        let out = apply("f.rs", &allows, findings);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].rule, "unused-allow");
        assert_eq!(out[0].line, 5);
    }
}
