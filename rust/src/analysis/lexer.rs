//! A minimal token-level lexer for Rust source.
//!
//! This is not a parser: it produces a flat token stream that is just
//! structured enough for [`crate::analysis::rules`] to pattern-match
//! reliably. What it *must* get right — and what a grep gate cannot — is
//! masking: string literals (plain, raw, byte, byte-raw), character
//! literals (including the `'a'` vs `'a` lifetime ambiguity), line and
//! nested block comments, and numeric literals are consumed as single
//! tokens, so `"unwrap"` inside a string or a doc comment can never
//! trigger a rule. Line comments are additionally surfaced to the caller
//! because the suppression grammar ([`crate::analysis::allow`]) lives in
//! them.

/// Kinds of lexical tokens [`lex`] produces.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword; a raw identifier `r#ident` yields `ident`.
    Ident,
    /// String literal: plain, raw, byte or byte-raw, quotes included.
    Str,
    /// Character or byte-character literal, quotes included.
    Char,
    /// Lifetime such as `'a` or `'static`.
    Lifetime,
    /// Integer or float literal, radix prefix and suffix included.
    Num,
    /// A single punctuation character.
    Punct,
}

/// One token with its 1-based source line.
#[derive(Clone, Debug)]
pub struct Tok {
    /// What kind of token this is.
    pub kind: TokKind,
    /// The token's source text (see [`TokKind`] for what is included).
    pub text: String,
    /// 1-based line of the token's first character.
    pub line: usize,
}

/// One `//` line comment. Block comments are consumed but not surfaced:
/// the suppression grammar is line-comment only.
#[derive(Clone, Debug)]
pub struct Comment {
    /// 1-based line the comment sits on.
    pub line: usize,
    /// Full comment text including the leading `//`.
    pub text: String,
    /// True when no code token precedes the comment on its line — an
    /// own-line comment suppresses the *next* line, a trailing comment
    /// its own.
    pub own_line: bool,
}

/// Lexer output: the token stream plus the line comments.
#[derive(Debug, Default)]
pub struct Lexed {
    /// All code tokens in source order.
    pub toks: Vec<Tok>,
    /// All line comments in source order.
    pub comments: Vec<Comment>,
}

fn find_close(s: &[char], from: usize, pat: &[char]) -> Option<usize> {
    if pat.is_empty() || s.len() < pat.len() {
        return None;
    }
    (from..=s.len() - pat.len()).find(|&k| s[k..k + pat.len()] == *pat)
}

fn collect_text(s: &[char], a: usize, b: usize) -> String {
    s[a.min(s.len())..b.min(s.len())].iter().collect()
}

/// Tokenize `src`. The lexer never fails: malformed input (unterminated
/// literals, stray bytes) degrades to best-effort tokens, which is the
/// right behavior for a linter that must not crash on the tree it lints.
pub fn lex(src: &str) -> Lexed {
    let s: Vec<char> = src.chars().collect();
    let n = s.len();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1usize;
    let mut line_has_code = false;
    while i < n {
        let c = s[i];
        let peek = |k: usize| if i + k < n { s[i + k] } else { '\0' };
        if c == '\n' {
            line += 1;
            line_has_code = false;
            i += 1;
            continue;
        }
        if c == ' ' || c == '\t' || c == '\r' {
            i += 1;
            continue;
        }
        // line comment
        if c == '/' && peek(1) == '/' {
            let mut j = i;
            while j < n && s[j] != '\n' {
                j += 1;
            }
            out.comments.push(Comment {
                line,
                text: collect_text(&s, i, j),
                own_line: !line_has_code,
            });
            i = j;
            continue;
        }
        // block comment (nested)
        if c == '/' && peek(1) == '*' {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if s[j] == '/' && j + 1 < n && s[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if s[j] == '*' && j + 1 < n && s[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    if s[j] == '\n' {
                        line += 1;
                    }
                    j += 1;
                }
            }
            i = j;
            continue;
        }
        // raw string r"..." / r#"..."# and raw identifier r#ident
        if c == 'r' && (peek(1) == '"' || peek(1) == '#') {
            let mut j = i + 1;
            let mut hashes = 0usize;
            while j < n && s[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && s[j] == '"' {
                j += 1;
                let mut close: Vec<char> = vec!['"'];
                close.resize(1 + hashes, '#');
                let k = match find_close(&s, j, &close) {
                    Some(k) => k + close.len(),
                    None => n,
                };
                let start_line = line;
                line += s[i..k].iter().filter(|&&ch| ch == '\n').count();
                out.toks.push(Tok {
                    kind: TokKind::Str,
                    text: collect_text(&s, i, k),
                    line: start_line,
                });
                line_has_code = true;
                i = k;
                continue;
            }
            if hashes == 1 && j < n && (s[j].is_alphabetic() || s[j] == '_') {
                let mut k = j;
                while k < n && (s[k].is_alphanumeric() || s[k] == '_') {
                    k += 1;
                }
                out.toks.push(Tok {
                    kind: TokKind::Ident,
                    text: collect_text(&s, j, k),
                    line,
                });
                line_has_code = true;
                i = k;
                continue;
            }
            // fall through: `r` is an ordinary identifier start
        }
        // byte-char literal b'x'
        if c == 'b' && peek(1) == '\'' {
            let mut j = if peek(2) == '\\' { i + 4 } else { i + 3 };
            while j < n && s[j] != '\'' {
                j += 1;
            }
            out.toks.push(Tok {
                kind: TokKind::Char,
                text: collect_text(&s, i, j + 1),
                line,
            });
            line_has_code = true;
            i = j + 1;
            continue;
        }
        // byte string b"..." and byte-raw string br"..." / br#"..."#
        if c == 'b' && (peek(1) == '"' || (peek(1) == 'r' && (peek(2) == '"' || peek(2) == '#'))) {
            if peek(1) == '"' {
                let start_line = line;
                let mut j = i + 2;
                while j < n && s[j] != '"' {
                    if s[j] == '\\' {
                        j += 1;
                    }
                    if j < n && s[j] == '\n' {
                        line += 1;
                    }
                    j += 1;
                }
                out.toks.push(Tok {
                    kind: TokKind::Str,
                    text: collect_text(&s, i, j + 1),
                    line: start_line,
                });
                line_has_code = true;
                i = j + 1;
                continue;
            }
            let mut j = i + 2;
            let mut hashes = 0usize;
            while j < n && s[j] == '#' {
                hashes += 1;
                j += 1;
            }
            j += 1; // opening quote
            let mut close: Vec<char> = vec!['"'];
            close.resize(1 + hashes, '#');
            let k = match find_close(&s, j, &close) {
                Some(k) => k + close.len(),
                None => n,
            };
            let start_line = line;
            line += s[i..k.min(n)].iter().filter(|&&ch| ch == '\n').count();
            out.toks.push(Tok {
                kind: TokKind::Str,
                text: collect_text(&s, i, k),
                line: start_line,
            });
            line_has_code = true;
            i = k;
            continue;
        }
        // plain string
        if c == '"' {
            let start_line = line;
            let mut j = i + 1;
            while j < n && s[j] != '"' {
                if s[j] == '\\' {
                    j += 1;
                }
                if j < n && s[j] == '\n' {
                    line += 1;
                }
                j += 1;
            }
            out.toks.push(Tok {
                kind: TokKind::Str,
                text: collect_text(&s, i, j + 1),
                line: start_line,
            });
            line_has_code = true;
            i = j + 1;
            continue;
        }
        // char literal or lifetime
        if c == '\'' {
            let nc = peek(1);
            if nc == '\\' {
                let mut j = i + 3;
                while j < n && s[j] != '\'' {
                    j += 1;
                }
                out.toks.push(Tok {
                    kind: TokKind::Char,
                    text: collect_text(&s, i, j + 1),
                    line,
                });
                line_has_code = true;
                i = j + 1;
                continue;
            }
            if nc.is_alphabetic() || nc == '_' {
                if peek(2) == '\'' {
                    out.toks.push(Tok {
                        kind: TokKind::Char,
                        text: collect_text(&s, i, i + 3),
                        line,
                    });
                    line_has_code = true;
                    i += 3;
                    continue;
                }
                let mut j = i + 1;
                while j < n && (s[j].is_alphanumeric() || s[j] == '_') {
                    j += 1;
                }
                out.toks.push(Tok {
                    kind: TokKind::Lifetime,
                    text: collect_text(&s, i, j),
                    line,
                });
                line_has_code = true;
                i = j;
                continue;
            }
            // char literal holding punctuation, e.g. '(' or ' '
            let mut j = i + 1;
            while j < n && s[j] != '\'' {
                j += 1;
            }
            out.toks.push(Tok {
                kind: TokKind::Char,
                text: collect_text(&s, i, j + 1),
                line,
            });
            line_has_code = true;
            i = j + 1;
            continue;
        }
        // numeric literal
        if c.is_ascii_digit() {
            let mut j = i;
            let two: String = s[i..(i + 2).min(n)].iter().collect();
            if two == "0x" || two == "0o" || two == "0b" {
                j = i + 2;
                while j < n && (s[j].is_alphanumeric() || s[j] == '_') {
                    j += 1;
                }
            } else {
                while j < n && (s[j].is_ascii_digit() || s[j] == '_') {
                    j += 1;
                }
                if j + 1 < n && s[j] == '.' && s[j + 1].is_ascii_digit() {
                    j += 1;
                    while j < n && (s[j].is_ascii_digit() || s[j] == '_') {
                        j += 1;
                    }
                }
                if j + 1 < n
                    && (s[j] == 'e' || s[j] == 'E')
                    && (s[j + 1].is_ascii_digit() || s[j + 1] == '+' || s[j + 1] == '-')
                {
                    j += 2;
                    while j < n && s[j].is_ascii_digit() {
                        j += 1;
                    }
                }
                // type suffix (u32, f64, ...)
                while j < n && (s[j].is_alphanumeric() || s[j] == '_') {
                    j += 1;
                }
            }
            out.toks.push(Tok {
                kind: TokKind::Num,
                text: collect_text(&s, i, j),
                line,
            });
            line_has_code = true;
            i = j;
            continue;
        }
        // identifier / keyword
        if c.is_alphabetic() || c == '_' {
            let mut j = i;
            while j < n && (s[j].is_alphanumeric() || s[j] == '_') {
                j += 1;
            }
            out.toks.push(Tok {
                kind: TokKind::Ident,
                text: collect_text(&s, i, j),
                line,
            });
            line_has_code = true;
            i = j;
            continue;
        }
        out.toks.push(Tok { kind: TokKind::Punct, text: c.to_string(), line });
        line_has_code = true;
        i += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .toks
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn strings_and_comments_are_masked() {
        let src = r##"
let a = "unwrap inside a string";
// unwrap inside a comment
/* unwrap /* nested */ still comment */
let b = r#"unwrap in a raw string"#;
let c = b"unwrap bytes";
real_ident.other();
"##;
        let ids = idents(src);
        assert!(!ids.iter().any(|t| t == "unwrap"));
        assert!(ids.iter().any(|t| t == "real_ident"));
    }

    #[test]
    fn char_vs_lifetime() {
        let lx = lex("fn f<'a>(x: &'a str) { let c = 'x'; let d = '\\n'; }");
        let lifetimes: Vec<_> =
            lx.toks.iter().filter(|t| t.kind == TokKind::Lifetime).collect();
        assert_eq!(lifetimes.len(), 2);
        let chars: Vec<_> = lx.toks.iter().filter(|t| t.kind == TokKind::Char).collect();
        assert_eq!(chars.len(), 2);
    }

    #[test]
    fn lines_survive_multiline_strings() {
        let src = "let a = \"x\ny\";\nafter();";
        let lx = lex(src);
        let after = lx.toks.iter().find(|t| t.text == "after").expect("after token");
        assert_eq!(after.line, 3);
    }

    #[test]
    fn line_comments_track_own_line() {
        let src = "// own\nlet x = 1; // trailing\n";
        let lx = lex(src);
        assert_eq!(lx.comments.len(), 2);
        assert!(lx.comments[0].own_line);
        assert!(!lx.comments[1].own_line);
    }

    #[test]
    fn raw_identifiers_lex_bare() {
        let ids = idents("let r#type = 1;");
        assert!(ids.iter().any(|t| t == "type"));
    }
}
