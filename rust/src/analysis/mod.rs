//! `sbc-lint`: token-level static analysis for this repo's own
//! invariants (see `ARCHITECTURE.md` §9).
//!
//! The repo's correctness story leans on a handful of mechanical
//! invariants — decode paths never panic, wall clocks stay behind the
//! [`crate::simnet::clock::Clock`] trait, digest inputs iterate
//! deterministically, snapshots are fsynced before rename, and the
//! frozen wire constants never drift — that `cargo test` can only probe
//! pointwise and `grep` cannot check without false positives from
//! strings and comments. This module walks a source tree with a real
//! lexer ([`lexer`]), applies path-scoped rules ([`rules`]), honors
//! explicit audited suppressions ([`allow`]), and reports
//! `file:line rule message` diagnostics ([`report`]) — wired into CI as
//! the `lint` job and runnable locally via `cargo run --bin sbc-lint`.

pub mod allow;
pub mod lexer;
pub mod report;
pub mod rules;

use std::path::{Path, PathBuf};

pub use report::{render_json, render_text, Finding};

fn rust_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let rd = std::fs::read_dir(dir)
        .map_err(|e| format!("cannot read directory {}: {e}", dir.display()))?;
    let mut entries: Vec<PathBuf> = Vec::new();
    for entry in rd {
        let entry = entry.map_err(|e| format!("cannot read entry in {}: {e}", dir.display()))?;
        entries.push(entry.path());
    }
    entries.sort();
    for path in entries {
        if path.is_dir() {
            rust_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `root`. Findings come back sorted by
/// `(file, line, rule, message)`; an empty vector means the tree is
/// clean. Errors are I/O-level only (unreadable root or file) — lint
/// findings are never errors.
pub fn lint_tree(root: &Path) -> Result<Vec<Finding>, String> {
    let mut files = Vec::new();
    rust_files(root, &mut files)?;
    let mut findings = Vec::new();
    for path in &files {
        let src = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        findings.extend(lint_source(&rel, &src));
    }
    findings.sort();
    Ok(findings)
}

/// Lint a single file's source text, `rel` being its `/`-separated path
/// relative to the scan root (which is what rule scoping keys on).
pub fn lint_source(rel: &str, src: &str) -> Vec<Finding> {
    let lx = lexer::lex(src);
    let raw = rules::check_file(rel, &lx);
    let (allows, mut bad) = allow::collect(rel, &lx.comments);
    let mut out = allow::apply(rel, &allows, raw);
    out.append(&mut bad);
    out.sort();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allow_suppresses_and_registers_as_used() {
        let src = "fn f() {\n\
                   // sbc-lint: allow(no-panic) -- unit test of the suppression path\n\
                   x.unwrap();\n\
                   }\n";
        assert!(lint_source("codec/x.rs", src).is_empty());
    }

    #[test]
    fn unsuppressed_violation_and_unused_allow_both_surface() {
        let src = "fn f() {\n\
                   // sbc-lint: allow(determinism) -- wrong rule on purpose\n\
                   x.unwrap();\n\
                   }\n";
        let f = lint_source("codec/x.rs", src);
        assert_eq!(f.len(), 2, "{f:?}");
        assert_eq!(f[0].rule, "unused-allow");
        assert_eq!(f[1].rule, "no-panic");
    }
}
