//! Diagnostic type and renderers (plain text and JSON).

use std::fmt;

/// One diagnostic: where, which rule, what.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Finding {
    /// Path relative to the scan root, `/`-separated.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Rule id (one of [`crate::analysis::rules::RULE_IDS`], or the
    /// meta-rules `bad-allow` / `unused-allow`).
    pub rule: String,
    /// Human-readable description of the violation.
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{} {} {}", self.file, self.line, self.rule, self.message)
    }
}

/// Render findings one per line as `file:line rule message`.
pub fn render_text(findings: &[Finding]) -> String {
    let mut out = String::new();
    for f in findings {
        out.push_str(&f.to_string());
        out.push('\n');
    }
    out
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render findings as a JSON array (std-only, hand-rolled — stable field
/// order `file`, `line`, `rule`, `message`).
pub fn render_json(findings: &[Finding]) -> String {
    let mut out = String::from("[");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n  {{\"file\": \"{}\", \"line\": {}, \"rule\": \"{}\", \"message\": \"{}\"}}",
            json_escape(&f.file),
            f.line,
            json_escape(&f.rule),
            json_escape(&f.message)
        ));
    }
    if !findings.is_empty() {
        out.push('\n');
    }
    out.push_str("]\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<Finding> {
        vec![Finding {
            file: "a/b.rs".to_string(),
            line: 7,
            rule: "no-panic".to_string(),
            message: "`.unwrap()` in a no-panic zone".to_string(),
        }]
    }

    #[test]
    fn text_format_is_file_line_rule_message() {
        assert_eq!(render_text(&sample()), "a/b.rs:7 no-panic `.unwrap()` in a no-panic zone\n");
    }

    #[test]
    fn json_escapes_and_nests() {
        let mut f = sample();
        f[0].message = "say \"hi\"\\".to_string();
        let j = render_json(&f);
        assert!(j.contains("\\\"hi\\\""));
        assert!(j.contains("\\\\\""));
        assert!(j.starts_with('['));
        assert!(j.trim_end().ends_with(']'));
        assert_eq!(render_json(&[]), "[]\n");
    }
}
