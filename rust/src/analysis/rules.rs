//! The rule registry: what each `sbc-lint` rule checks and where.
//!
//! Every rule mechanizes an invariant the architecture document states
//! in prose (`ARCHITECTURE.md` §9):
//!
//! * **no-panic** — inside `compression/`, `codec/`, `transport/` and
//!   `persist/`, decode and durability paths must fail typed:
//!   `.unwrap()` / `.expect()`, `panic!` / `todo!` / `unimplemented!`,
//!   `partial_cmp` (NaN-propagating; use `total_cmp`) and
//!   `get_unchecked` are forbidden. `unreachable!` is deliberately *not*
//!   banned: the `TensorUpdate` slot accessors need a guarded impossible
//!   arm the borrow checker cannot see through (NLL Problem Case #3),
//!   and that is the sanctioned idiom for it.
//! * **clock-discipline** — `Instant` / `SystemTime` / `UNIX_EPOCH` may
//!   appear only in `simnet/clock.rs`; everything else threads a
//!   `&dyn Clock` so simulated runs stay virtual-time-pure.
//! * **determinism** — `HashMap` / `HashSet` are forbidden in
//!   `persist/`, `coordinator/aggregation.rs` and `transport/mod.rs`
//!   (the digest code): iteration order there feeds bytes or float
//!   reductions that must be bit-identical across runs.
//! * **durability** — in `persist/`, no bare `File::create` (snapshots
//!   go through the create-new → write → `sync_all` → rename path) and
//!   no `rename` without a preceding `sync_all` in the same function.
//! * **wire-freeze** — the frozen wire constants (frame magic, format
//!   versions, `TensorUpdate` tags) must each be defined exactly once,
//!   in their registered file, with exactly the golden-test value.
//!
//! Code under `#[test]` / `#[cfg(test)]` is exempt from every rule
//! except wire-freeze's duplicate-definition check (tests may not
//! redefine frozen constants either — they pin them as literals in
//! asserts instead).

use crate::analysis::lexer::{Lexed, Tok, TokKind};
use crate::analysis::report::Finding;

/// Rule identifiers, in the order they are documented.
pub const RULE_IDS: &[&str] =
    &["no-panic", "clock-discipline", "determinism", "durability", "wire-freeze"];

/// Top-level directories (relative to the scan root) where the no-panic
/// rule applies.
const NO_PANIC_DIRS: &[&str] = &["compression", "codec", "transport", "persist"];

/// Files (relative to the scan root) where the determinism rule applies,
/// in addition to everything under `persist/`.
const DETERMINISM_FILES: &[&str] = &["coordinator/aggregation.rs", "transport/mod.rs"];

/// The frozen wire-constant registry: `(file, const name, value)`.
/// These are the numbers the golden-bytes tests pin; changing any of
/// them is a wire break and must update this table, the constant and
/// the golden test together.
pub const WIRE_CONSTS: &[(&str, &str, u64)] = &[
    ("codec/message.rs", "MAGIC", 0x5BC0),
    ("codec/message.rs", "WIRE_VERSION", 2),
    ("codec/message.rs", "TAG_DENSE", 0),
    ("codec/message.rs", "TAG_SPARSE_F32", 1),
    ("codec/message.rs", "TAG_SPARSE_BINARY", 2),
    ("codec/message.rs", "TAG_SIGN", 3),
    ("codec/message.rs", "TAG_TERNARY", 4),
    ("codec/message.rs", "TAG_QUANTIZED", 5),
    ("codec/message.rs", "TAG_SIGN_MEANS", 6),
    ("transport/frame.rs", "MAGIC", 0xFE5B),
    ("transport/frame.rs", "PROTOCOL_VERSION", 1),
    ("persist/format.rs", "MAGIC", 0x5342_434B),
    ("persist/format.rs", "VERSION", 1),
];

/// Token index ranges (half-open) covered by `#[test]` functions or
/// `#[cfg(test)]` items: the attribute, any stacked attributes after it,
/// and the following item body up to its matching close brace (or `;`).
fn test_spans(toks: &[Tok]) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    let n = toks.len();
    let mut i = 0usize;
    while i < n {
        if !(toks[i].kind == TokKind::Punct
            && toks[i].text == "#"
            && i + 1 < n
            && toks[i + 1].text == "[")
        {
            i += 1;
            continue;
        }
        // collect the attribute's tokens up to the matching `]`
        let mut depth = 0usize;
        let mut j = i + 1;
        let mut idents: Vec<&str> = Vec::new();
        while j < n {
            let t = &toks[j];
            if t.kind == TokKind::Punct && t.text == "[" {
                depth += 1;
            } else if t.kind == TokKind::Punct && t.text == "]" {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            } else if t.kind == TokKind::Ident {
                idents.push(&t.text);
            }
            j += 1;
        }
        let is_test = idents == ["test"] || idents == ["cfg", "test"];
        if !is_test {
            i = j + 1;
            continue;
        }
        // skip stacked attributes, then consume the item body
        let mut m = j + 1;
        while m < n {
            let t = &toks[m];
            if t.kind == TokKind::Punct && t.text == "#" && m + 1 < n && toks[m + 1].text == "[" {
                let mut d2 = 0usize;
                m += 1;
                while m < n {
                    if toks[m].kind == TokKind::Punct && toks[m].text == "[" {
                        d2 += 1;
                    } else if toks[m].kind == TokKind::Punct && toks[m].text == "]" {
                        d2 -= 1;
                        if d2 == 0 {
                            break;
                        }
                    }
                    m += 1;
                }
                m += 1;
                continue;
            }
            if t.kind == TokKind::Punct && t.text == ";" {
                m += 1;
                break;
            }
            if t.kind == TokKind::Punct && t.text == "{" {
                let mut d2 = 1usize;
                m += 1;
                while m < n && d2 > 0 {
                    if toks[m].kind == TokKind::Punct && toks[m].text == "{" {
                        d2 += 1;
                    } else if toks[m].kind == TokKind::Punct && toks[m].text == "}" {
                        d2 -= 1;
                    }
                    m += 1;
                }
                break;
            }
            m += 1;
        }
        spans.push((i, m));
        i = m;
    }
    spans
}

fn in_spans(spans: &[(usize, usize)], idx: usize) -> bool {
    spans.iter().any(|&(a, b)| a <= idx && idx < b)
}

/// Parse a Rust integer literal (`23`, `0x5BC0`, `0x5342_434B`, with or
/// without a type suffix) to its value. Returns `None` for floats or
/// anything unparseable.
fn parse_int(text: &str) -> Option<u64> {
    let t: String = text.chars().filter(|&c| c != '_').collect();
    let (radix, digits) = if let Some(rest) = t.strip_prefix("0x").or_else(|| t.strip_prefix("0X"))
    {
        (16, rest)
    } else if let Some(rest) = t.strip_prefix("0o") {
        (8, rest)
    } else if let Some(rest) = t.strip_prefix("0b") {
        (2, rest)
    } else {
        (10, t.as_str())
    };
    let valid: String = digits.chars().take_while(|c| c.is_digit(radix)).collect();
    if valid.is_empty() {
        return None;
    }
    u64::from_str_radix(&valid, radix).ok()
}

/// Run every rule whose scope covers `rel` (a `/`-separated path
/// relative to the scan root) over the lexed file. Returns raw findings;
/// the caller applies suppression comments
/// ([`crate::analysis::allow::apply`]) afterwards.
pub fn check_file(rel: &str, lx: &Lexed) -> Vec<Finding> {
    let toks = &lx.toks;
    let n = toks.len();
    let spans = test_spans(toks);
    let top = rel.split('/').next().unwrap_or("");
    let no_panic = NO_PANIC_DIRS.contains(&top);
    let determinism = top == "persist" || DETERMINISM_FILES.contains(&rel);
    let clock = rel != "simnet/clock.rs";
    let durability = top == "persist";

    let mut out = Vec::new();
    let mut push = |line: usize, rule: &'static str, message: String| {
        out.push(Finding { file: rel.to_string(), line, rule: rule.to_string(), message });
    };

    let mut last_fn: isize = -1;
    let mut last_sync: isize = -1;
    // wire-freeze: definitions seen in this file, as (name, line, value)
    let mut const_defs: Vec<(&str, usize, Option<u64>)> = Vec::new();

    for idx in 0..n {
        let t = &toks[idx];
        if t.kind != TokKind::Ident {
            continue;
        }
        let word = t.text.as_str();
        let test = in_spans(&spans, idx);
        let prev_is = |p: &str| {
            idx > 0 && toks[idx - 1].kind == TokKind::Punct && toks[idx - 1].text == p
        };
        let next_is = |p: &str| {
            idx + 1 < n && toks[idx + 1].kind == TokKind::Punct && toks[idx + 1].text == p
        };
        if word == "fn" {
            last_fn = idx as isize;
        }
        if word == "sync_all" {
            last_sync = idx as isize;
        }
        if word == "const" && idx + 1 < n && toks[idx + 1].kind == TokKind::Ident {
            let name = toks[idx + 1].text.as_str();
            if let Some(&(_, w, _)) = WIRE_CONSTS.iter().find(|&&(_, w, _)| w == name) {
                // scan to `=` then the literal, stopping at `;`
                let mut value = None;
                let mut m = idx + 2;
                while m < n && toks[m].text != ";" {
                    if toks[m].kind == TokKind::Punct && toks[m].text == "=" {
                        if m + 1 < n && toks[m + 1].kind == TokKind::Num {
                            value = parse_int(&toks[m + 1].text);
                        }
                        break;
                    }
                    m += 1;
                }
                const_defs.push((w, toks[idx + 1].line, value));
            }
        }
        if no_panic && !test {
            if (word == "unwrap" || word == "expect") && prev_is(".") {
                push(t.line, "no-panic", format!("`.{word}()` in a no-panic zone"));
            }
            if (word == "panic" || word == "todo" || word == "unimplemented") && next_is("!") {
                push(t.line, "no-panic", format!("`{word}!` in a no-panic zone"));
            }
            if word == "partial_cmp" {
                push(
                    t.line,
                    "no-panic",
                    "`partial_cmp` in a no-panic zone: use `total_cmp`".to_string(),
                );
            }
            if word == "get_unchecked" || word == "get_unchecked_mut" {
                push(t.line, "no-panic", format!("`{word}` in a no-panic zone"));
            }
        }
        if clock && !test && (word == "Instant" || word == "SystemTime" || word == "UNIX_EPOCH") {
            push(
                t.line,
                "clock-discipline",
                format!("`{word}` outside simnet/clock.rs: thread a `&dyn Clock`"),
            );
        }
        if determinism && !test && (word == "HashMap" || word == "HashSet") {
            push(
                t.line,
                "determinism",
                format!("`{word}` in order-sensitive code: use BTreeMap/BTreeSet"),
            );
        }
        if durability && !test {
            if word == "create"
                && prev_is(":")
                && idx >= 3
                && toks[idx - 3].kind == TokKind::Ident
                && toks[idx - 3].text == "File"
            {
                push(
                    t.line,
                    "durability",
                    "`File::create` in persist: use create-new + sync_all + rename".to_string(),
                );
            }
            if word == "rename" && !(last_fn < last_sync && last_sync < idx as isize) {
                push(
                    t.line,
                    "durability",
                    "`rename` without a preceding `sync_all` in this function".to_string(),
                );
            }
        }
    }

    // wire-freeze per-file verdicts
    for &(file, name, expected) in WIRE_CONSTS {
        if file != rel {
            continue;
        }
        let defs: Vec<_> = const_defs.iter().filter(|&&(w, _, _)| w == name).collect();
        match defs.as_slice() {
            [] => push(
                1,
                "wire-freeze",
                format!("frozen const `{name}` missing (registry expects 0x{expected:X})"),
            ),
            [one] => match one.2 {
                Some(v) if v == expected => {}
                Some(v) => push(
                    one.1,
                    "wire-freeze",
                    format!("frozen const `{name}` = 0x{v:X}, registry expects 0x{expected:X}"),
                ),
                None => push(
                    one.1,
                    "wire-freeze",
                    format!("frozen const `{name}` must be an integer literal"),
                ),
            },
            many => {
                for d in &many[1..] {
                    push(
                        d.1,
                        "wire-freeze",
                        format!("frozen const `{name}` defined more than once in this file"),
                    );
                }
            }
        }
    }
    // a watched name defined in a file the registry does not map it to
    let registered_here: Vec<&str> = WIRE_CONSTS
        .iter()
        .filter(|&&(f, _, _)| f == rel)
        .map(|&(_, w, _)| w)
        .collect();
    for &(name, line, _) in &const_defs {
        if !registered_here.contains(&name) {
            push(
                line,
                "wire-freeze",
                format!("watched wire const `{name}` defined outside its registered home"),
            );
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;

    fn findings(rel: &str, src: &str) -> Vec<Finding> {
        check_file(rel, &lex(src))
    }

    #[test]
    fn no_panic_scope_and_test_exemption() {
        let src = "fn f(x: Option<u8>) -> u8 { x.unwrap() }\n\
                   #[cfg(test)]\nmod tests { fn g(x: Option<u8>) -> u8 { x.unwrap() } }\n";
        let f = findings("transport/x.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].line, 1);
        assert!(findings("coordinator/x.rs", src).is_empty());
    }

    #[test]
    fn clock_rule_spares_only_the_clock() {
        let src = "use std::time::Instant;\n";
        assert_eq!(findings("util/x.rs", src).len(), 1);
        assert!(findings("simnet/clock.rs", src).is_empty());
    }

    #[test]
    fn durability_needs_sync_before_rename() {
        let bad = "fn save() { std::fs::rename(a, b); }\n";
        let good = "fn save() { f.sync_all(); std::fs::rename(a, b); }\n";
        assert_eq!(findings("persist/x.rs", bad).len(), 1);
        assert!(findings("persist/x.rs", good).is_empty());
        assert!(findings("codec/x.rs", bad).is_empty());
    }

    #[test]
    fn wire_freeze_value_mismatch_and_duplicate() {
        let ok = "pub const MAGIC: u16 = 0xFE5B;\npub const PROTOCOL_VERSION: u8 = 1;\n";
        assert!(findings("transport/frame.rs", ok).is_empty());
        let wrong = "pub const MAGIC: u16 = 0xDEAD;\npub const PROTOCOL_VERSION: u8 = 1;\n";
        let f = findings("transport/frame.rs", wrong);
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("0xDEAD"));
        let dup = format!("{ok}const MAGIC: u16 = 0xFE5B;\n");
        let f = findings("transport/frame.rs", &dup);
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("more than once"));
    }

    #[test]
    fn wire_freeze_missing_and_unregistered() {
        let f = findings("persist/format.rs", "pub const MAGIC: u32 = 0x5342_434B;\n");
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].message.contains("`VERSION` missing"));
        let f = findings("netsim/x.rs", "const MAGIC: u8 = 3;\n");
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("outside its registered home"));
    }
}
