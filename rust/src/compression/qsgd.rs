//! QSGD (Alistarh et al.) — stochastic uniform quantization to `s` levels
//! with per-segment L2 scale and Elias-coded integer levels. Unbiased.

use crate::compression::{Compressor, Granularity, TensorUpdate, UpdateMsg};
use crate::model::TensorLayout;
use crate::util::rng::Rng;
use crate::util::tensor;

pub struct Qsgd {
    pub levels: u8,
    pub granularity: Granularity,
    rng: Rng,
}

impl Qsgd {
    pub fn new(levels: u8, seed: u64) -> Self {
        assert!(levels >= 1);
        Qsgd { levels, granularity: Granularity::PerTensor, rng: Rng::new(seed) }
    }

    fn compress_segment(&mut self, x: &[f32]) -> TensorUpdate {
        let norm = tensor::l2_norm(x);
        if norm == 0.0 {
            return TensorUpdate::Quantized { scale: 0.0, levels: self.levels, vals: vec![0; x.len()] };
        }
        let s = self.levels as f32;
        let vals = x
            .iter()
            .map(|&v| {
                let r = v.abs() / norm * s; // in [0, s]
                let lo = r.floor();
                let level = lo as i32 + if (self.rng.next_f32()) < r - lo { 1 } else { 0 };
                let level = level.clamp(0, s as i32) as i8;
                if v < 0.0 {
                    -level
                } else {
                    level
                }
            })
            .collect();
        TensorUpdate::Quantized { scale: norm, levels: self.levels, vals }
    }
}

impl Compressor for Qsgd {
    fn name(&self) -> &'static str {
        "qsgd"
    }

    fn compress(&mut self, acc: &[f32], layout: &TensorLayout, round: u32) -> UpdateMsg {
        let tensors = match self.granularity {
            Granularity::Global => vec![self.compress_segment(acc)],
            Granularity::PerTensor => {
                let segs: Vec<_> = layout.segments().collect();
                segs.into_iter().map(|seg| self.compress_segment(&acc[seg])).collect()
            }
        };
        UpdateMsg { round, tensors }
    }

    fn uses_residual(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unbiased_in_expectation() {
        let x = vec![0.3f32, -0.4, 0.0, 0.866];
        let layout = TensorLayout::flat(4);
        let mut c = Qsgd::new(4, 7);
        let trials = 4000;
        let mut sum = vec![0.0f64; 4];
        for r in 0..trials {
            let dense = c.compress(&x, &layout, r).to_dense(&layout, 1.0);
            for i in 0..4 {
                sum[i] += dense[i] as f64;
            }
        }
        for i in 0..4 {
            let mean = sum[i] / trials as f64;
            assert!((mean - x[i] as f64).abs() < 0.05, "i={i}: {mean} vs {}", x[i]);
        }
    }

    #[test]
    fn levels_bounded() {
        let mut rng = Rng::new(8);
        let x: Vec<f32> = (0..1000).map(|_| rng.normal()).collect();
        let mut c = Qsgd::new(8, 9);
        match c.compress_segment(&x) {
            TensorUpdate::Quantized { levels, vals, .. } => {
                assert!(vals.iter().all(|&v| v.unsigned_abs() <= levels));
            }
            other => panic!("{other:?}"),
        }
    }
}
