//! Residual accumulation (error feedback) — paper eq. (2) and Thm. II.1.
//!
//! Each client keeps `R_i`; before compression the fresh update is added
//! to the residual, and after compression the transmitted approximation is
//! subtracted, so no gradient information is ever dropped — only delayed.

use crate::util::tensor;

/// One client's error-feedback residual vector.
#[derive(Clone, Debug)]
pub struct Residual {
    r: Vec<f32>,
    enabled: bool,
}

impl Residual {
    /// A zero residual over `n` parameters (a disabled residual stays
    /// zero forever — the no-error-feedback ablation arm).
    pub fn new(n: usize, enabled: bool) -> Self {
        Residual { r: vec![0.0; n], enabled }
    }

    /// Whether error feedback is active.
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// acc = R + delta (into `acc`, which arrives holding `delta`).
    pub fn accumulate_into(&self, acc: &mut [f32]) {
        if self.enabled {
            tensor::add_assign(acc, &self.r);
        }
    }

    /// R = acc - transmitted (paper eq. 2). When disabled, R stays zero
    /// (pure lossy compression, the ablation arm).
    pub fn update(&mut self, acc: &[f32], transmitted: &[f32]) {
        if !self.enabled {
            return;
        }
        tensor::sub_into(&mut self.r, acc, transmitted);
    }

    /// L2 norm of the residual (how much error is in flight).
    pub fn norm(&self) -> f32 {
        tensor::l2_norm(&self.r)
    }

    /// The raw residual vector.
    pub fn as_slice(&self) -> &[f32] {
        &self.r
    }

    /// Overwrite the residual vector from a checkpoint. The length must
    /// match the vector this residual was created over.
    pub fn restore(&mut self, r: &[f32]) {
        assert_eq!(r.len(), self.r.len(), "residual length mismatch on restore");
        self.r.copy_from_slice(r);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conservation() {
        // over T rounds, sum(delta_t) == sum(transmitted_t) + R_T exactly
        // (the Thm II.1 bookkeeping identity)
        let n = 64;
        let mut rng = crate::util::rng::Rng::new(1);
        let mut res = Residual::new(n, true);
        let mut sum_delta = vec![0.0f64; n];
        let mut sum_tx = vec![0.0f64; n];
        for _ in 0..20 {
            let delta: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            for i in 0..n {
                sum_delta[i] += delta[i] as f64;
            }
            let mut acc = delta.clone();
            res.accumulate_into(&mut acc);
            // "compress": keep only first 8 entries
            let mut tx = vec![0.0f32; n];
            tx[..8].copy_from_slice(&acc[..8]);
            res.update(&acc, &tx);
            for i in 0..n {
                sum_tx[i] += tx[i] as f64;
            }
        }
        for i in 0..n {
            let lhs = sum_delta[i];
            let rhs = sum_tx[i] + res.as_slice()[i] as f64;
            assert!((lhs - rhs).abs() < 1e-3, "{i}: {lhs} vs {rhs}");
        }
        // entries 8.. were never sent: residual carries them entirely
        assert!(res.norm() > 0.0);
    }

    #[test]
    fn disabled_residual_stays_zero() {
        let mut res = Residual::new(4, false);
        let acc = [1.0f32, 2.0, 3.0, 4.0];
        res.update(&acc, &[0.0; 4]);
        assert_eq!(res.as_slice(), &[0.0; 4]);
        let mut buf = [5.0f32; 4];
        res.accumulate_into(&mut buf);
        assert_eq!(buf, [5.0; 4]);
    }
}
