//! Federated Averaging (McMahan et al.) — dense, uncompressed updates;
//! the compression gain comes entirely from communication delay, which the
//! coordinator provides. Also serves as the "Baseline" method at n = 1.

use crate::compression::{Compressor, Granularity, TensorUpdate, UpdateMsg};
use crate::model::TensorLayout;

pub struct DenseCompressor {
    pub granularity: Granularity,
}

impl DenseCompressor {
    pub fn new() -> Self {
        DenseCompressor { granularity: Granularity::Global }
    }
}

impl Default for DenseCompressor {
    fn default() -> Self {
        Self::new()
    }
}

impl Compressor for DenseCompressor {
    fn name(&self) -> &'static str {
        "dense"
    }

    fn compress(&mut self, acc: &[f32], layout: &TensorLayout, round: u32) -> UpdateMsg {
        let tensors = match self.granularity {
            Granularity::Global => vec![TensorUpdate::Dense(acc.to_vec())],
            Granularity::PerTensor => {
                layout.segments().map(|seg| TensorUpdate::Dense(acc[seg].to_vec())).collect()
            }
        };
        UpdateMsg { round, tensors }
    }

    // Dense transfer is lossless — no residual needed.
    fn uses_residual(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lossless_roundtrip() {
        let x = vec![1.0f32, -2.0, 3.5];
        let layout = TensorLayout::flat(3);
        let mut c = DenseCompressor::new();
        let dense = c.compress(&x, &layout, 0).to_dense(&layout, 1.0);
        assert_eq!(dense, x);
        assert!(!c.uses_residual());
    }
}
