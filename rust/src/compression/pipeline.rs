//! The staged compression pipeline: Select → Quantize over zero-copy
//! segment views, with caller-owned output scratch.
//!
//! A [`Pipeline`] walks the layout's segments (or the whole vector under
//! global granularity), runs the [`Selector`] and [`Quantizer`] stages on
//! each segment slice, and writes one [`TensorUpdate`] per segment into a
//! reusable [`UpdateMsg`]. Together with
//! [`crate::codec::message::WireCodec`] (encode) and
//! [`UpdateMsg::densify_into`] (decode side), the coordinator's hot loop
//! reuses every buffer across rounds.

use crate::compression::quantize::Quantizer;
use crate::compression::select::Selector;
use crate::compression::{Granularity, TensorUpdate, UpdateMsg};
use crate::model::TensorLayout;
use crate::simnet::clock::Clock;

/// A composed Select → Quantize pipeline over layout segments.
pub struct Pipeline {
    selector: Selector,
    quantizer: Quantizer,
    granularity: Granularity,
    /// Reused index scratch for the selector stage.
    idx: Vec<u32>,
}

impl Pipeline {
    /// Compose a pipeline from its two stages and the segmentation.
    pub fn new(selector: Selector, quantizer: Quantizer, granularity: Granularity) -> Pipeline {
        Pipeline { selector, quantizer, granularity, idx: Vec::new() }
    }

    /// The segmentation this pipeline compresses at.
    pub fn granularity(&self) -> Granularity {
        self.granularity
    }

    /// The selection stage.
    pub fn selector(&self) -> &Selector {
        &self.selector
    }

    /// The quantization stage.
    pub fn quantizer(&self) -> &Quantizer {
        &self.quantizer
    }

    /// Both stage RNG cursors `(selector, quantizer)`, for checkpointing.
    pub fn rng_states(&self) -> ([u64; 4], [u64; 4]) {
        (self.selector.rng_state(), self.quantizer.rng_state())
    }

    /// Restore the stage RNG cursors captured by [`Pipeline::rng_states`].
    pub fn restore_rng_states(&mut self, selector: [u64; 4], quantizer: [u64; 4]) {
        self.selector.restore_rng_state(selector);
        self.quantizer.restore_rng_state(quantizer);
    }

    /// Short method name derived from the stage composition (labels,
    /// logs; the human-facing label lives on `MethodConfig`).
    pub fn name(&self) -> &'static str {
        use crate::compression::quantize::QuantizerCfg as Q;
        use crate::compression::select::SelectorCfg as S;
        match (self.selector.cfg(), self.quantizer.cfg()) {
            (S::Dense, Q::F32) => "dense",
            (S::TopK { .. }, Q::F32) => "gradient_dropping",
            (S::TwoSided { .. }, Q::F32) => "two_sided_f32",
            (_, Q::BinaryMean) => "sbc",
            (_, Q::Sign { .. }) => "signsgd",
            (_, Q::Ternary) => "terngrad",
            (_, Q::Qsgd { .. }) => "qsgd",
            (_, Q::SignMeans) => "onebit",
        }
    }

    /// Compress the accumulated update `acc` into `out`, reusing `out`'s
    /// buffers (zero steady-state heap allocation).
    pub fn compress_into(
        &mut self,
        acc: &[f32],
        layout: &TensorLayout,
        round: u32,
        out: &mut UpdateMsg,
    ) {
        assert_eq!(acc.len(), layout.total, "update length must match layout");
        out.round = round;
        let nseg = self.granularity.n_segments(layout);
        out.tensors.truncate(nseg);
        while out.tensors.len() < nseg {
            out.tensors.push(TensorUpdate::placeholder());
        }
        for i in 0..nseg {
            let x = &acc[self.granularity.segment(layout, i)];
            let support = self.selector.select(x, &mut self.idx);
            self.quantizer.quantize(x, support, &self.idx, &mut out.tensors[i]);
        }
    }

    /// [`Pipeline::compress_into`] with stage-boundary observation: the
    /// select and quantize durations are summed across segments and
    /// reported to `observe` as the `"select"` / `"quantize"` stage
    /// timings ([`crate::trace::Event::Stage`] vocabulary). Only the
    /// traced round path calls this; the untraced hot path keeps the
    /// timing-free [`Pipeline::compress_into`], so disabling tracing
    /// removes every clock read. Time comes from the caller's [`Clock`]
    /// so simulated runs observe virtual durations.
    pub fn compress_into_observed(
        &mut self,
        acc: &[f32],
        layout: &TensorLayout,
        round: u32,
        out: &mut UpdateMsg,
        clock: &dyn Clock,
        observe: &mut dyn FnMut(&'static str, u64),
    ) {
        assert_eq!(acc.len(), layout.total, "update length must match layout");
        out.round = round;
        let nseg = self.granularity.n_segments(layout);
        out.tensors.truncate(nseg);
        while out.tensors.len() < nseg {
            out.tensors.push(TensorUpdate::placeholder());
        }
        let (mut select_ns, mut quantize_ns) = (0u64, 0u64);
        for i in 0..nseg {
            let x = &acc[self.granularity.segment(layout, i)];
            let t0 = clock.now();
            let support = self.selector.select(x, &mut self.idx);
            select_ns += clock.now().saturating_sub(t0).as_nanos() as u64;
            let t1 = clock.now();
            self.quantizer.quantize(x, support, &self.idx, &mut out.tensors[i]);
            quantize_ns += clock.now().saturating_sub(t1).as_nanos() as u64;
        }
        observe("select", select_ns);
        observe("quantize", quantize_ns);
    }

    /// Allocating convenience wrapper (tests, cold paths).
    pub fn compress(&mut self, acc: &[f32], layout: &TensorLayout, round: u32) -> UpdateMsg {
        let mut out = UpdateMsg::scratch();
        self.compress_into(acc, layout, round, &mut out);
        out
    }

    /// Compress a single segment (selection + quantization on one slice),
    /// bypassing the layout walk — used by the PJRT kernel
    /// cross-validation and unit tests.
    pub fn compress_segment(&mut self, x: &[f32]) -> TensorUpdate {
        let mut out = TensorUpdate::placeholder();
        let support = self.selector.select(x, &mut self.idx);
        self.quantizer.quantize(x, support, &self.idx, &mut out);
        out
    }
}

/// Server-side broadcast compression: represent the aggregated update
/// sparsely when its support is small enough that positions + f32 values
/// beat a dense block, densely otherwise. Reuses `out`'s buffers. The
/// result goes through the same [`crate::codec::message::WireCodec`] as
/// upstream messages, so downstream bits are *measured*, not estimated.
pub fn compress_broadcast_into(delta: &[f32], round: u32, out: &mut UpdateMsg) {
    out.round = round;
    out.tensors.truncate(1);
    if out.tensors.is_empty() {
        out.tensors.push(TensorUpdate::placeholder());
    }
    let nnz = delta.iter().filter(|v| **v != 0.0).count() as u64;
    // sparse cost ≈ 48 bits/entry (32-bit value + ~16-bit position)
    let slot = &mut out.tensors[0];
    if nnz * 48 + 64 < 32 * delta.len() as u64 {
        let (idx, val) = slot.sparse_f32_slot();
        for (i, &v) in delta.iter().enumerate() {
            if v != 0.0 {
                idx.push(i as u32);
                val.push(v);
            }
        }
    } else {
        let v = slot.dense_slot();
        v.extend_from_slice(delta);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::quantize::QuantizerCfg;
    use crate::compression::registry::MethodConfig;
    use crate::compression::select::{Selection, SelectorCfg};
    use crate::util::rng::Rng;

    fn heavy(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() * rng.next_f32().powi(3)).collect()
    }

    #[test]
    fn dense_pipeline_is_lossless() {
        let x = vec![1.0f32, -2.0, 3.5];
        let layout = TensorLayout::flat(3);
        let mut p = MethodConfig::baseline().build(0);
        let dense = p.compress(&x, &layout, 0).to_dense(&layout, 1.0);
        assert_eq!(dense, x);
    }

    #[test]
    fn graddrop_pipeline_keeps_exact_values() {
        let x = vec![0.0f32, -3.0, 0.5, 2.0, -0.1];
        let mut p = MethodConfig::builder()
            .select(SelectorCfg::TopK { p: 0.4, strategy: Selection::Exact })
            .quantize(QuantizerCfg::F32)
            .granularity(Granularity::Global)
            .build()
            .build(0);
        let msg = p.compress(&x, &TensorLayout::flat(5), 0);
        match &msg.tensors[0] {
            TensorUpdate::SparseF32 { idx, val } => {
                assert_eq!(idx, &vec![1, 3]);
                assert_eq!(val, &vec![-3.0, 2.0]);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn sbc_pipeline_sparsity_is_respected() {
        let x = heavy(100_000, 7);
        let mut p = MethodConfig::sbc(0.01, 1).with_granularity(Granularity::Global).build(0);
        let tu = p.compress_segment(&x);
        assert_eq!(tu.nonzeros(), 1000);
    }

    #[test]
    fn per_tensor_granularity_one_update_per_tensor() {
        let layout = TensorLayout::new(vec![("a".into(), vec![1000]), ("b".into(), vec![500])]);
        let x = heavy(1500, 9);
        let mut p = MethodConfig::sbc(0.02, 1).build(0);
        let msg = p.compress(&x, &layout, 3);
        assert_eq!(msg.tensors.len(), 2);
        assert_eq!(msg.round, 3);
        for t in &msg.tensors {
            assert!(matches!(t, TensorUpdate::SparseBinary { .. }));
        }
    }

    #[test]
    fn compress_into_reuses_slots_across_rounds() {
        let layout = TensorLayout::new(vec![("a".into(), vec![64]), ("b".into(), vec![32])]);
        let x = heavy(96, 2);
        let mut p = MethodConfig::sbc(0.1, 1).build(0);
        let mut msg = UpdateMsg::scratch();
        p.compress_into(&x, &layout, 0, &mut msg);
        let first = msg.clone();
        // second round over the same input must produce identical output
        // through the reused buffers
        p.compress_into(&x, &layout, 1, &mut msg);
        assert_eq!(msg.tensors, first.tensors);
        assert_eq!(msg.round, 1);
    }

    #[test]
    fn observed_compress_is_bit_identical_and_reports_both_stages() {
        let layout = TensorLayout::new(vec![("a".into(), vec![800]), ("b".into(), vec![200])]);
        let x = heavy(1000, 5);
        let mut plain = MethodConfig::sbc(0.05, 1).build(9);
        let mut observed = MethodConfig::sbc(0.05, 1).build(9);
        let mut msg_a = UpdateMsg::scratch();
        let mut msg_b = UpdateMsg::scratch();
        plain.compress_into(&x, &layout, 2, &mut msg_a);
        let mut stages = Vec::new();
        let clock = crate::simnet::clock::RealClock::new();
        observed.compress_into_observed(&x, &layout, 2, &mut msg_b, &clock, &mut |s, _ns| {
            stages.push(s)
        });
        assert_eq!(msg_a, msg_b);
        assert_eq!(stages, vec!["select", "quantize"]);
    }

    #[test]
    fn onebit_pipeline_means_partition() {
        let x = vec![1.0f32, 3.0, -2.0, -4.0];
        let layout = TensorLayout::flat(4);
        let mut p = MethodConfig::onebit().with_granularity(Granularity::Global).build(0);
        let dense = p.compress(&x, &layout, 0).to_dense(&layout, 1.0);
        assert_eq!(dense, vec![2.0, 2.0, -3.0, -3.0]);
    }

    #[test]
    fn signsgd_pipeline_scale_applied_on_densify() {
        let x = vec![0.5f32, -0.1, 0.0, -7.0];
        let layout = TensorLayout::flat(4);
        let cfg = MethodConfig::signsgd(0.01);
        let mut p = cfg.build(0);
        let msg = p.compress(&x, &layout, 0);
        let dense = msg.to_dense(&layout, cfg.sign_scale());
        assert_eq!(dense, vec![0.01, -0.01, 0.01, -0.01]);
    }

    #[test]
    fn broadcast_sparse_vs_dense_choice() {
        let mut sparse_delta = vec![0.0f32; 1000];
        sparse_delta[3] = 1.5;
        sparse_delta[700] = -2.5;
        let mut out = UpdateMsg::scratch();
        compress_broadcast_into(&sparse_delta, 5, &mut out);
        assert_eq!(out.round, 5);
        match &out.tensors[0] {
            TensorUpdate::SparseF32 { idx, val } => {
                assert_eq!(idx, &vec![3, 700]);
                assert_eq!(val, &vec![1.5, -2.5]);
            }
            other => panic!("{other:?}"),
        }
        let dense_delta = vec![1.0f32; 1000];
        compress_broadcast_into(&dense_delta, 6, &mut out);
        assert!(matches!(&out.tensors[0], TensorUpdate::Dense(v) if v.len() == 1000));
    }
}
