//! 1-bit SGD (Seide et al.) — dense 1-bit quantization *with* error
//! feedback: positive entries map to the mean of positives, negative to
//! the mean of negatives, and the quantization error goes to the residual.
//! Wire format reuses `Sign` plus two f32 means carried as a 2-element
//! Dense tensor appended per segment.

use crate::compression::{Compressor, Granularity, TensorUpdate, UpdateMsg};
use crate::model::TensorLayout;

pub struct OneBitSgd {
    pub granularity: Granularity,
}

impl OneBitSgd {
    pub fn new() -> Self {
        OneBitSgd { granularity: Granularity::PerTensor }
    }

    fn compress_segment(&self, x: &[f32]) -> Vec<TensorUpdate> {
        let (mut sp, mut np_, mut sn, mut nn) = (0.0f64, 0u32, 0.0f64, 0u32);
        for &v in x {
            if v >= 0.0 {
                sp += v as f64;
                np_ += 1;
            } else {
                sn += v as f64;
                nn += 1;
            }
        }
        let mu_pos = if np_ > 0 { (sp / np_ as f64) as f32 } else { 0.0 };
        let mu_neg = if nn > 0 { (sn / nn as f64) as f32 } else { 0.0 };
        vec![
            TensorUpdate::Sign { signs: x.iter().map(|&v| v >= 0.0).collect() },
            TensorUpdate::Dense(vec![mu_pos, mu_neg]),
        ]
    }

    /// Densify one segment's (sign, means) pair.
    pub fn densify_segment(signs: &[bool], mu_pos: f32, mu_neg: f32, out: &mut [f32]) {
        for (o, &s) in out.iter_mut().zip(signs) {
            *o = if s { mu_pos } else { mu_neg };
        }
    }
}

impl Default for OneBitSgd {
    fn default() -> Self {
        Self::new()
    }
}

impl Compressor for OneBitSgd {
    fn name(&self) -> &'static str {
        "onebit"
    }

    fn compress(&mut self, acc: &[f32], layout: &TensorLayout, round: u32) -> UpdateMsg {
        let mut tensors = Vec::new();
        match self.granularity {
            Granularity::Global => tensors.extend(self.compress_segment(acc)),
            Granularity::PerTensor => {
                for seg in layout.segments() {
                    tensors.extend(self.compress_segment(&acc[seg]));
                }
            }
        }
        UpdateMsg { round, tensors }
    }

    // the defining feature of 1-bit SGD is error feedback
    fn uses_residual(&self) -> bool {
        true
    }
}

/// Densify a full 1-bit message (pairs of Sign + Dense[2] per segment).
pub fn onebit_to_dense(msg: &UpdateMsg, layout: &TensorLayout, granularity: Granularity) -> Vec<f32> {
    let mut out = vec![0.0f32; layout.total];
    let segs: Vec<std::ops::Range<usize>> = match granularity {
        Granularity::Global => vec![0..layout.total],
        Granularity::PerTensor => layout.segments().collect(),
    };
    for (si, seg) in segs.into_iter().enumerate() {
        let TensorUpdate::Sign { signs } = &msg.tensors[2 * si] else { panic!("bad onebit msg") };
        let TensorUpdate::Dense(mus) = &msg.tensors[2 * si + 1] else { panic!("bad onebit msg") };
        OneBitSgd::densify_segment(signs, mus[0], mus[1], &mut out[seg]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn means_partition() {
        let x = vec![1.0f32, 3.0, -2.0, -4.0];
        let layout = TensorLayout::flat(4);
        let mut c = OneBitSgd { granularity: Granularity::Global };
        let msg = c.compress(&x, &layout, 0);
        let dense = onebit_to_dense(&msg, &layout, Granularity::Global);
        assert_eq!(dense, vec![2.0, 2.0, -3.0, -3.0]);
    }

    #[test]
    fn per_tensor_pairs() {
        let layout = TensorLayout::new(vec![("a".into(), vec![2]), ("b".into(), vec![2])]);
        let x = vec![1.0f32, -1.0, 10.0, 20.0];
        let mut c = OneBitSgd::new();
        let msg = c.compress(&x, &layout, 0);
        assert_eq!(msg.tensors.len(), 4);
        let dense = onebit_to_dense(&msg, &layout, Granularity::PerTensor);
        assert_eq!(dense, vec![1.0, -1.0, 15.0, 15.0]);
    }
}
