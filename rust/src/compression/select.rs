//! Selector stage: which coordinates of a segment survive compression.
//!
//! Three selectors cover the paper's method space:
//! * [`SelectorCfg::Dense`] — everything survives (baseline, FedAvg, and
//!   every dense quantizer);
//! * [`SelectorCfg::TopK`] — the fraction-`p` largest-magnitude entries
//!   (Gradient Dropping / DGC);
//! * [`SelectorCfg::TwoSided`] — paper Alg. 2 line 1: the fraction-`p`
//!   largest *positive* entries and the fraction-`p` most *negative*
//!   entries, as one merged candidate set. The binary-mean quantizer
//!   picks the winning side downstream.
//!
//! The threshold [`Selection`] strategy is pluggable: `Exact` quickselect,
//! DGC-style `Sampled`, or `Hist` — the bit-exact mirror of the L1 Pallas
//! kernel, used to cross-validate the PJRT compress path. The exact paths
//! run on selector-owned scratch (magnitude copy + tie list), so
//! steady-state selection performs no heap allocation.

use crate::compression::topk::{self, hist_thresholds};
use crate::util::rng::Rng;

/// Threshold-estimation strategy for the sparse selectors.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Selection {
    /// Exact quickselect threshold.
    Exact,
    /// Threshold estimated from a subsample of this many elements.
    Sampled(usize),
    /// Bit-pattern histogram quantile (kernel mirror).
    Hist,
}

/// Selector configuration — the build-time description of the stage.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SelectorCfg {
    /// Keep every coordinate.
    Dense,
    /// Keep the fraction-`p` largest entries by |x|.
    TopK {
        /// Fraction of entries kept.
        p: f64,
        /// Threshold-estimation strategy.
        strategy: Selection,
    },
    /// Keep the fraction-`p` largest positives and fraction-`p` most
    /// negative entries (SBC Alg. 2).
    TwoSided {
        /// Fraction kept per side.
        p: f64,
        /// Threshold-estimation strategy.
        strategy: Selection,
    },
}

/// What a selector produced for one segment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Support {
    /// The whole segment; the index scratch is untouched.
    All,
    /// Only the indices written to the scratch (sorted, unique).
    Sparse,
}

/// The stateful selector stage: owns the RNG for sampled thresholds and
/// the quickselect scratch buffers.
pub struct Selector {
    cfg: SelectorCfg,
    rng: Rng,
    /// Reused magnitude copy for quickselect.
    mags: Vec<f32>,
    /// Reused tie-index list (threshold boundary fill).
    ties: Vec<u32>,
}

impl Selector {
    /// Instantiate the stage (seeded for the sampled strategy).
    pub fn new(cfg: SelectorCfg, seed: u64) -> Selector {
        Selector { cfg, rng: Rng::new(seed), mags: Vec::new(), ties: Vec::new() }
    }

    /// The build-time configuration this stage was constructed from.
    pub fn cfg(&self) -> SelectorCfg {
        self.cfg
    }

    /// The RNG cursor (for checkpointing; only the sampled strategy
    /// draws from it, but capturing it is always safe).
    pub fn rng_state(&self) -> [u64; 4] {
        self.rng.state()
    }

    /// Restore the RNG cursor captured by [`Selector::rng_state`].
    pub fn restore_rng_state(&mut self, s: [u64; 4]) {
        self.rng = Rng::from_state(s);
    }

    /// Select surviving positions of segment `x` into `idx` (cleared
    /// first; left empty for [`Support::All`]).
    pub fn select(&mut self, x: &[f32], idx: &mut Vec<u32>) -> Support {
        idx.clear();
        match self.cfg {
            SelectorCfg::Dense => Support::All,
            SelectorCfg::TopK { p, strategy } => {
                let k = frac_k(p, x.len());
                match strategy {
                    Selection::Exact => self.topk_exact(x, k, idx),
                    Selection::Sampled(sample) => {
                        idx.extend(topk::topk_sampled(x, k, sample, &mut self.rng))
                    }
                    Selection::Hist => magnitude_hist(x, k as u32, idx),
                }
                Support::Sparse
            }
            SelectorCfg::TwoSided { p, strategy } => {
                let k = frac_k(p, x.len());
                match strategy {
                    Selection::Exact => self.two_sided_exact(x, k, idx),
                    Selection::Sampled(sample) => {
                        // DGC-style: magnitude top-2k from a subsample,
                        // zeros dropped (they belong to neither side)
                        for i in topk::topk_sampled(x, 2 * k, sample, &mut self.rng) {
                            if x[i as usize] != 0.0 {
                                idx.push(i);
                            }
                        }
                    }
                    Selection::Hist => two_sided_hist(x, k as u32, idx),
                }
                Support::Sparse
            }
        }
    }

    /// Exact top-k by magnitude on reused scratch (same semantics as
    /// [`topk::topk_exact`]).
    fn topk_exact(&mut self, x: &[f32], k: usize, out: &mut Vec<u32>) {
        let k = k.min(x.len());
        if k == 0 {
            return;
        }
        if k == x.len() {
            out.extend(0..x.len() as u32);
            return;
        }
        self.mags.clear();
        self.mags.extend(x.iter().map(|v| v.abs()));
        let kth = {
            // total_cmp, not partial_cmp: NaN magnitudes (poisoned
            // gradients) must order deterministically instead of
            // panicking mid-round. NaN sorts above +inf here.
            let (_, kth, _) = self.mags.select_nth_unstable_by(k - 1, |a, b| b.total_cmp(a));
            *kth
        };
        self.ties.clear();
        for (i, v) in x.iter().enumerate() {
            let m = v.abs();
            if m > kth {
                out.push(i as u32);
            } else if m == kth {
                self.ties.push(i as u32);
            }
        }
        for &t in &self.ties {
            if out.len() >= k {
                break;
            }
            out.push(t);
        }
        out.sort_unstable();
    }

    /// Exact per-side top-k: k largest positive values and k most
    /// negative, merged into one sorted index list.
    ///
    /// Two-phase per side for speed: quickselect the k-th value on a
    /// contiguous f32 copy (cache-friendly, no indirect compares), then
    /// one scan collects the indices at/above the threshold.
    fn two_sided_exact(&mut self, x: &[f32], k: usize, out: &mut Vec<u32>) {
        for sign in [1.0f32, -1.0] {
            let start = out.len();
            self.mags.clear();
            self.mags.extend(x.iter().filter_map(|&v| {
                let s = sign * v;
                if s > 0.0 {
                    Some(s)
                } else {
                    None
                }
            }));
            let k2 = k.min(self.mags.len());
            if k2 == 0 {
                continue;
            }
            let thr = if k2 < self.mags.len() {
                let (_, kth, _) = self.mags.select_nth_unstable_by(k2 - 1, |a, b| b.total_cmp(a));
                *kth
            } else {
                0.0 // keep every element of this side
            };
            self.ties.clear();
            for (i, &v) in x.iter().enumerate() {
                let s = sign * v;
                if s > thr {
                    out.push(i as u32);
                } else if s == thr && s > 0.0 {
                    self.ties.push(i as u32);
                }
            }
            for &t in &self.ties {
                if out.len() - start >= k2 {
                    break;
                }
                out.push(t);
            }
        }
        out.sort_unstable();
    }
}

/// Per-side k for fractional sparsity `p` over a segment of `n` elements.
/// Clamped to `[1, n]`: `p` at or above 1.0 must select the whole segment,
/// not index out of bounds in quickselect.
fn frac_k(p: f64, n: usize) -> usize {
    ((p * n as f64).round() as usize).clamp(1, n.max(1))
}

/// Histogram-threshold selection, both sides merged (mirrors the Pallas
/// compress graph's threshold stage): at least k per side survive.
fn two_sided_hist(x: &[f32], k: u32, out: &mut Vec<u32>) {
    let (tp, tn, _am) = hist_thresholds(x, k);
    for (i, &v) in x.iter().enumerate() {
        if (v > 0.0 && v >= tp) || (v < 0.0 && -v >= tn) {
            out.push(i as u32);
        }
    }
}

/// Histogram-threshold *magnitude* selection for [`SelectorCfg::TopK`]:
/// one threshold over |x| (both sign histograms summed), keeping at
/// least k entries total — not k per side, which would double the
/// configured sparsity.
fn magnitude_hist(x: &[f32], k: u32, out: &mut Vec<u32>) {
    let (mut hist, hneg, absmax) = topk::signed_histograms(x);
    for (h, n) in hist.iter_mut().zip(&hneg) {
        *h += n;
    }
    let t = topk::threshold_from_hist(&hist, k, absmax);
    for (i, &v) in x.iter().enumerate() {
        if v != 0.0 && v.abs() >= t {
            out.push(i as u32);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn heavy(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() * rng.next_f32().powi(3)).collect()
    }

    #[test]
    fn dense_selects_all() {
        let mut s = Selector::new(SelectorCfg::Dense, 0);
        let mut idx = vec![9u32];
        assert_eq!(s.select(&[1.0, 2.0], &mut idx), Support::All);
        assert!(idx.is_empty());
    }

    #[test]
    fn topk_exact_magnitudes() {
        let x = vec![0.0f32, -3.0, 0.5, 2.0, -0.1];
        let mut s = Selector::new(SelectorCfg::TopK { p: 0.4, strategy: Selection::Exact }, 0);
        let mut idx = Vec::new();
        assert_eq!(s.select(&x, &mut idx), Support::Sparse);
        assert_eq!(idx, vec![1, 3]);
    }

    #[test]
    fn topk_matches_free_function() {
        let x = heavy(10_000, 3);
        for p in [0.001, 0.01, 0.2] {
            let mut s = Selector::new(SelectorCfg::TopK { p, strategy: Selection::Exact }, 0);
            let mut idx = Vec::new();
            s.select(&x, &mut idx);
            let k = ((p * x.len() as f64).round() as usize).max(1);
            assert_eq!(idx, topk::topk_exact(&x, k), "p={p}");
        }
    }

    #[test]
    fn two_sided_keeps_k_per_side() {
        // top-2 positives are {0,1}; top-2 negatives are {3,6}
        let x = vec![5.0f32, 4.0, -0.1, -0.2, 0.0, 3.0, -0.3, 0.05];
        let mut s =
            Selector::new(SelectorCfg::TwoSided { p: 0.25, strategy: Selection::Exact }, 0);
        let mut idx = Vec::new();
        s.select(&x, &mut idx);
        assert_eq!(idx, vec![0, 1, 3, 6]);
    }

    #[test]
    fn two_sided_respects_sparsity() {
        let x = heavy(100_000, 7);
        let mut s =
            Selector::new(SelectorCfg::TwoSided { p: 0.01, strategy: Selection::Exact }, 0);
        let mut idx = Vec::new();
        s.select(&x, &mut idx);
        assert_eq!(idx.len(), 2_000); // k per side
        assert!(idx.windows(2).all(|w| w[0] < w[1]), "sorted unique");
    }

    #[test]
    fn two_sided_one_sided_input() {
        // every entry negative: positive side contributes nothing
        let x: Vec<f32> = heavy(10_000, 10).iter().map(|v| -v.abs() - 1e-6).collect();
        let mut s =
            Selector::new(SelectorCfg::TwoSided { p: 0.01, strategy: Selection::Exact }, 0);
        let mut idx = Vec::new();
        s.select(&x, &mut idx);
        assert_eq!(idx.len(), 100);
        assert!(idx.iter().all(|&i| x[i as usize] < 0.0));
    }

    #[test]
    fn two_sided_all_zero_segment() {
        let x = vec![0.0f32; 1000];
        let mut s =
            Selector::new(SelectorCfg::TwoSided { p: 0.01, strategy: Selection::Exact }, 0);
        let mut idx = Vec::new();
        s.select(&x, &mut idx);
        assert!(idx.is_empty());
    }

    #[test]
    fn hist_close_to_exact() {
        let x = heavy(100_000, 8);
        let mut idx_e = Vec::new();
        let mut idx_h = Vec::new();
        Selector::new(SelectorCfg::TwoSided { p: 0.01, strategy: Selection::Exact }, 0)
            .select(&x, &mut idx_e);
        Selector::new(SelectorCfg::TwoSided { p: 0.01, strategy: Selection::Hist }, 0)
            .select(&x, &mut idx_h);
        // the histogram threshold never undershoots and overshoots by at
        // most the boundary bin
        assert!(idx_h.len() >= idx_e.len());
        assert!(idx_h.len() <= idx_e.len() + idx_e.len() / 8 + 128);
    }

    #[test]
    fn topk_hist_keeps_about_k_total() {
        // one magnitude threshold: ~k kept in total, not ~k per side
        let x = heavy(100_000, 12);
        let mut s = Selector::new(SelectorCfg::TopK { p: 0.01, strategy: Selection::Hist }, 0);
        let mut idx = Vec::new();
        s.select(&x, &mut idx);
        assert!(idx.len() >= 1000, "{}", idx.len());
        // bin-granularity overshoot only — far below the ~2k a per-side
        // threshold would keep
        assert!(idx.len() <= 1500, "{}", idx.len());
    }

    #[test]
    fn ties_fill_to_exactly_k() {
        let x = [1.0f32; 10];
        let mut s = Selector::new(SelectorCfg::TopK { p: 0.3, strategy: Selection::Exact }, 0);
        let mut idx = Vec::new();
        s.select(&x, &mut idx);
        assert_eq!(idx.len(), 3);
    }

    #[test]
    fn topk_full_and_oversubscribed_p_select_everything() {
        // regression: p >= 1.0 used to panic out of bounds in quickselect
        let x = heavy(100, 21);
        for p in [1.0f64, 1.5] {
            let mut s = Selector::new(SelectorCfg::TopK { p, strategy: Selection::Exact }, 0);
            let mut idx = Vec::new();
            assert_eq!(s.select(&x, &mut idx), Support::Sparse, "p={p}");
            assert_eq!(idx, (0..x.len() as u32).collect::<Vec<_>>(), "p={p}");
        }
    }

    #[test]
    fn two_sided_full_and_oversubscribed_p_select_all_nonzero() {
        let mut x = heavy(100, 22);
        x[7] = 0.0; // zeros belong to neither side
        for p in [1.0f64, 1.5] {
            let mut s = Selector::new(SelectorCfg::TwoSided { p, strategy: Selection::Exact }, 0);
            let mut idx = Vec::new();
            s.select(&x, &mut idx);
            let nonzero: Vec<u32> = x
                .iter()
                .enumerate()
                .filter(|(_, &v)| v != 0.0)
                .map(|(i, _)| i as u32)
                .collect();
            assert_eq!(idx, nonzero, "p={p}");
        }
    }

    #[test]
    fn nan_magnitudes_select_deterministically_without_panic() {
        let mut x = heavy(200, 23);
        x[3] = f32::NAN;
        x[50] = f32::INFINITY;
        x[51] = f32::NEG_INFINITY;
        for cfg in [
            SelectorCfg::TopK { p: 0.1, strategy: Selection::Exact },
            SelectorCfg::TwoSided { p: 0.1, strategy: Selection::Exact },
        ] {
            let mut a = Vec::new();
            let mut b = Vec::new();
            Selector::new(cfg, 0).select(&x, &mut a);
            Selector::new(cfg, 0).select(&x, &mut b);
            assert_eq!(a, b, "{cfg:?}");
            assert!(!a.is_empty(), "{cfg:?}");
        }
    }

    #[test]
    fn scratch_is_cleared_between_calls() {
        let x = vec![1.0f32, -1.0];
        let mut s = Selector::new(SelectorCfg::TopK { p: 0.5, strategy: Selection::Exact }, 0);
        let mut idx = Vec::new();
        s.select(&x, &mut idx);
        let first = idx.clone();
        s.select(&x, &mut idx);
        assert_eq!(idx, first);
    }
}
