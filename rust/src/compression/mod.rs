//! Weight-update compression as a staged pipeline.
//!
//! The paper's core observation is that every compression method is a
//! *composition*: communication delay (coordinator) × a sparsity
//! **selector** × a value **quantizer** × a position **wire codec**. This
//! module exposes exactly those stages:
//!
//! * [`select::Selector`] — which coordinates of a segment survive
//!   (dense passthrough, magnitude top-p, SBC's per-side top-p);
//! * [`quantize::Quantizer`] — what is transmitted for the survivors
//!   (full f32, one binary mean, signs, ternary, QSGD levels, 1-bit
//!   sign+means);
//! * [`crate::codec::message::WireCodec`] — how positions and values are
//!   serialized bit-exactly (Golomb / fixed-16 / Elias positions).
//!
//! A [`pipeline::Pipeline`] composes the first two over per-tensor
//! **segment views** (zero-copy slices of the flat update vector) and
//! writes into caller-owned scratch ([`Pipeline::compress_into`]), so the
//! coordinator's hot loop performs no per-round heap allocation. The
//! [`registry::MethodConfig`] builder names the compositions; every
//! method the paper compares against is a preset.
//!
//! [`Pipeline::compress_into`]: pipeline::Pipeline::compress_into

pub mod momentum_mask;
pub mod pipeline;
pub mod quantize;
pub mod registry;
pub mod residual;
pub mod select;
pub mod topk;

pub use pipeline::Pipeline;
pub use quantize::QuantizerCfg;
pub use select::{Selection, SelectorCfg};

use anyhow::{ensure, Result};

use crate::model::TensorLayout;

/// One tensor's compressed update, aligned with the model's tensor layout
/// (or a single whole-vector segment when granularity is global).
#[derive(Clone, Debug, PartialEq)]
pub enum TensorUpdate {
    /// Dense f32 — the baseline and Federated Averaging.
    Dense(Vec<f32>),
    /// Sparse with full-precision values (Gradient Dropping / DGC).
    SparseF32 {
        /// Sorted surviving positions.
        idx: Vec<u32>,
        /// Their full-precision values, aligned with `idx`.
        val: Vec<f32>,
    },
    /// Sparse binary (SBC, paper Alg. 2): positions + one mean; the sign
    /// is carried by `side_pos`.
    SparseBinary {
        /// Sorted surviving positions (all on the winning side).
        idx: Vec<u32>,
        /// Mean magnitude of the winning side.
        mu: f32,
        /// Whether the winning side is positive.
        side_pos: bool,
    },
    /// Dense sign quantization (signSGD): one bit per element.
    Sign {
        /// One sign bit per segment element (`true` = positive).
        signs: Vec<bool>,
    },
    /// Dense 1-bit quantization with per-segment means (1-bit SGD): sign
    /// bit per element, plus the positive-side and negative-side means.
    SignMeans {
        /// One sign bit per segment element.
        signs: Vec<bool>,
        /// Mean of the non-negative entries.
        mu_pos: f32,
        /// Mean of the negative entries (≤ 0).
        mu_neg: f32,
    },
    /// Dense stochastic ternary (TernGrad): scale plus {-1,0,+1}.
    Ternary {
        /// Per-segment scale (max |x|).
        scale: f32,
        /// Ternary codes, one per element.
        vals: Vec<i8>,
    },
    /// QSGD stochastic uniform quantization: per-tensor scale, signed
    /// integer levels in [-s, s].
    Quantized {
        /// Per-segment L2 scale.
        scale: f32,
        /// Quantization level count `s`.
        levels: u8,
        /// Signed levels in `[-s, s]`, one per element.
        vals: Vec<i8>,
    },
}

impl TensorUpdate {
    /// Number of elements this update transmits values for. Sparse
    /// variants count their index lists; `Dense`, `Ternary` and
    /// `Quantized` count entries that densify to a non-zero contribution.
    /// Note the dense 1-bit variants (`Sign`, `SignMeans`) count *all*
    /// elements of the segment — every coordinate carries a sign bit, so
    /// nothing about them is "non-zero" in the sparse sense.
    pub fn nonzeros(&self) -> usize {
        match self {
            TensorUpdate::Dense(v) => v.iter().filter(|x| **x != 0.0).count(),
            TensorUpdate::SparseF32 { idx, .. } => idx.len(),
            TensorUpdate::SparseBinary { idx, .. } => idx.len(),
            TensorUpdate::Sign { signs } => signs.len(),
            TensorUpdate::SignMeans { signs, .. } => signs.len(),
            TensorUpdate::Ternary { vals, .. } => vals.iter().filter(|v| **v != 0).count(),
            TensorUpdate::Quantized { vals, .. } => vals.iter().filter(|v| **v != 0).count(),
        }
    }

    /// Densify into `out` (adds into the buffer; caller zeroes it).
    pub fn add_into(&self, out: &mut [f32], sign_scale: f32) {
        match self {
            TensorUpdate::Dense(v) => {
                for (o, x) in out.iter_mut().zip(v) {
                    *o += x;
                }
            }
            TensorUpdate::SparseF32 { idx, val } => {
                for (&i, &v) in idx.iter().zip(val) {
                    out[i as usize] += v;
                }
            }
            TensorUpdate::SparseBinary { idx, mu, side_pos } => {
                let v = if *side_pos { *mu } else { -*mu };
                for &i in idx {
                    out[i as usize] += v;
                }
            }
            TensorUpdate::Sign { signs } => {
                for (o, s) in out.iter_mut().zip(signs) {
                    *o += if *s { sign_scale } else { -sign_scale };
                }
            }
            TensorUpdate::SignMeans { signs, mu_pos, mu_neg } => {
                for (o, s) in out.iter_mut().zip(signs) {
                    *o += if *s { *mu_pos } else { *mu_neg };
                }
            }
            TensorUpdate::Ternary { scale, vals } => {
                for (o, v) in out.iter_mut().zip(vals) {
                    *o += *v as f32 * scale;
                }
            }
            TensorUpdate::Quantized { scale, levels, vals } => {
                let s = *levels as f32;
                for (o, v) in out.iter_mut().zip(vals) {
                    *o += *v as f32 / s * scale;
                }
            }
        }
    }

    /// A cheap placeholder slot (used when growing scratch messages).
    pub fn placeholder() -> TensorUpdate {
        TensorUpdate::Dense(Vec::new())
    }

    // --- scratch-slot accessors -----------------------------------------
    //
    // Reset this slot to the given variant and hand out its fields,
    // reusing the existing buffers when the variant already matches (the
    // allocation-free steady state). Shared by the quantizer stage
    // (compress side) and the wire decoder (decode side) so the
    // reset-or-replace logic exists exactly once per variant.

    pub(crate) fn dense_slot(&mut self) -> &mut Vec<f32> {
        if !matches!(self, TensorUpdate::Dense(_)) {
            *self = TensorUpdate::Dense(Vec::new());
        }
        match self {
            TensorUpdate::Dense(v) => {
                v.clear();
                v
            }
            _ => unreachable!(),
        }
    }

    pub(crate) fn sparse_f32_slot(&mut self) -> (&mut Vec<u32>, &mut Vec<f32>) {
        if !matches!(self, TensorUpdate::SparseF32 { .. }) {
            *self = TensorUpdate::SparseF32 { idx: Vec::new(), val: Vec::new() };
        }
        match self {
            TensorUpdate::SparseF32 { idx, val } => {
                idx.clear();
                val.clear();
                (idx, val)
            }
            _ => unreachable!(),
        }
    }

    pub(crate) fn sparse_binary_slot(&mut self) -> (&mut Vec<u32>, &mut f32, &mut bool) {
        if !matches!(self, TensorUpdate::SparseBinary { .. }) {
            *self = TensorUpdate::SparseBinary { idx: Vec::new(), mu: 0.0, side_pos: true };
        }
        match self {
            TensorUpdate::SparseBinary { idx, mu, side_pos } => {
                idx.clear();
                *mu = 0.0;
                *side_pos = true;
                (idx, mu, side_pos)
            }
            _ => unreachable!(),
        }
    }

    pub(crate) fn sign_slot(&mut self) -> &mut Vec<bool> {
        if !matches!(self, TensorUpdate::Sign { .. }) {
            *self = TensorUpdate::Sign { signs: Vec::new() };
        }
        match self {
            TensorUpdate::Sign { signs } => {
                signs.clear();
                signs
            }
            _ => unreachable!(),
        }
    }

    pub(crate) fn sign_means_slot(&mut self) -> (&mut Vec<bool>, &mut f32, &mut f32) {
        if !matches!(self, TensorUpdate::SignMeans { .. }) {
            *self = TensorUpdate::SignMeans { signs: Vec::new(), mu_pos: 0.0, mu_neg: 0.0 };
        }
        match self {
            TensorUpdate::SignMeans { signs, mu_pos, mu_neg } => {
                signs.clear();
                (signs, mu_pos, mu_neg)
            }
            _ => unreachable!(),
        }
    }

    pub(crate) fn ternary_slot(&mut self) -> (&mut f32, &mut Vec<i8>) {
        if !matches!(self, TensorUpdate::Ternary { .. }) {
            *self = TensorUpdate::Ternary { scale: 0.0, vals: Vec::new() };
        }
        match self {
            TensorUpdate::Ternary { scale, vals } => {
                vals.clear();
                (scale, vals)
            }
            _ => unreachable!(),
        }
    }

    pub(crate) fn quantized_slot(&mut self) -> (&mut f32, &mut u8, &mut Vec<i8>) {
        if !matches!(self, TensorUpdate::Quantized { .. }) {
            *self = TensorUpdate::Quantized { scale: 0.0, levels: 1, vals: Vec::new() };
        }
        match self {
            TensorUpdate::Quantized { scale, levels, vals } => {
                vals.clear();
                (scale, levels, vals)
            }
            _ => unreachable!(),
        }
    }
}

/// A full update message: one [`TensorUpdate`] per segment. Used in both
/// directions — client→server (compressed accumulated updates) and
/// server→client (the broadcast aggregate).
#[derive(Clone, Debug, PartialEq)]
pub struct UpdateMsg {
    /// Communication round this message belongs to.
    pub round: u32,
    /// One update per segment (layout tensor, or one global segment).
    pub tensors: Vec<TensorUpdate>,
}

impl UpdateMsg {
    /// An empty message suitable as reusable scratch for
    /// `compress_into`/`decode_into`.
    pub fn scratch() -> UpdateMsg {
        UpdateMsg { round: 0, tensors: Vec::new() }
    }

    /// Densify into `out` (zeroed first), mapping tensor `i` onto the
    /// segment given by `granularity` over `layout`. This is the
    /// allocation-free replacement for [`UpdateMsg::to_dense`]: the
    /// caller owns `out` and reuses it across rounds.
    pub fn densify_into(
        &self,
        layout: &TensorLayout,
        granularity: Granularity,
        sign_scale: f32,
        out: &mut [f32],
    ) {
        // ntensors comes off the wire (u16) — never trust it to match
        // the segmentation, or a corrupt-but-parseable message would
        // overlap-add tensors over the same range in release builds
        assert_eq!(
            self.tensors.len(),
            granularity.n_segments(layout),
            "message tensor count does not match the {granularity:?} segmentation"
        );
        out.fill(0.0);
        for (i, tu) in self.tensors.iter().enumerate() {
            tu.add_into(&mut out[granularity.segment(layout, i)], sign_scale);
        }
    }

    /// Check that a decoded message is structurally sound against the
    /// model's segmentation before it touches any indexed buffer: tensor
    /// count matches the granularity, dense variants carry exactly one
    /// value per segment element, and sparse index lists are strictly
    /// increasing within segment bounds. The federated server runs this
    /// on every network-decoded update so a corrupt-but-parseable message
    /// becomes a typed error instead of a panic (or a silent
    /// overlap-add) inside [`UpdateMsg::densify_into`].
    pub fn validate(&self, layout: &TensorLayout, granularity: Granularity) -> Result<()> {
        ensure!(
            self.tensors.len() == granularity.n_segments(layout),
            "message has {} tensors, segmentation expects {}",
            self.tensors.len(),
            granularity.n_segments(layout)
        );
        for (i, t) in self.tensors.iter().enumerate() {
            let seg_len = granularity.segment(layout, i).len();
            let check_idx = |idx: &[u32]| -> Result<()> {
                for w in idx.windows(2) {
                    ensure!(w[0] < w[1], "tensor {i}: positions not strictly increasing");
                }
                if let Some(&last) = idx.last() {
                    ensure!(
                        (last as usize) < seg_len,
                        "tensor {i}: position {last} outside segment of {seg_len}"
                    );
                }
                Ok(())
            };
            match t {
                TensorUpdate::Dense(v) => {
                    ensure!(v.len() == seg_len, "tensor {i}: dense length {}", v.len())
                }
                TensorUpdate::SparseF32 { idx, val } => {
                    ensure!(idx.len() == val.len(), "tensor {i}: idx/val length mismatch");
                    check_idx(idx)?;
                }
                TensorUpdate::SparseBinary { idx, .. } => check_idx(idx)?,
                TensorUpdate::Sign { signs } => {
                    ensure!(signs.len() == seg_len, "tensor {i}: sign length {}", signs.len())
                }
                TensorUpdate::SignMeans { signs, .. } => {
                    ensure!(signs.len() == seg_len, "tensor {i}: sign length {}", signs.len())
                }
                TensorUpdate::Ternary { vals, .. } => {
                    ensure!(vals.len() == seg_len, "tensor {i}: ternary length {}", vals.len())
                }
                TensorUpdate::Quantized { vals, .. } => {
                    ensure!(vals.len() == seg_len, "tensor {i}: quantized length {}", vals.len())
                }
            }
        }
        Ok(())
    }

    /// Densify the whole message into a fresh flat vector of length
    /// `layout.total`, one tensor per layout segment (allocating
    /// convenience for tests and cold paths).
    pub fn to_dense(&self, layout: &TensorLayout, sign_scale: f32) -> Vec<f32> {
        let mut out = vec![0.0f32; layout.total];
        self.densify_into(layout, Granularity::PerTensor, sign_scale, &mut out);
        out
    }
}

/// Compression granularity (paper compresses per tensor: one μ per tensor).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    /// One segment per layout tensor (paper default).
    PerTensor,
    /// One whole-vector segment.
    Global,
}

impl Granularity {
    /// How many segments an update splits into under this granularity.
    pub fn n_segments(&self, layout: &TensorLayout) -> usize {
        match self {
            Granularity::PerTensor => layout.len(),
            Granularity::Global => 1,
        }
    }

    /// The flat-vector range of segment `i`.
    pub fn segment(&self, layout: &TensorLayout, i: usize) -> std::ops::Range<usize> {
        match self {
            Granularity::PerTensor => layout.range(i),
            Granularity::Global => {
                debug_assert_eq!(i, 0);
                0..layout.total
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TensorLayout;

    fn layout2() -> TensorLayout {
        TensorLayout::new(vec![("a".into(), vec![4]), ("b".into(), vec![2, 3])])
    }

    #[test]
    fn densify_sparse_binary() {
        let layout = layout2();
        let msg = UpdateMsg {
            round: 0,
            tensors: vec![
                TensorUpdate::SparseBinary { idx: vec![1, 3], mu: 0.5, side_pos: false },
                TensorUpdate::SparseF32 { idx: vec![0, 5], val: vec![1.0, -2.0] },
            ],
        };
        let dense = msg.to_dense(&layout, 1.0);
        assert_eq!(dense, vec![0.0, -0.5, 0.0, -0.5, 1.0, 0.0, 0.0, 0.0, 0.0, -2.0]);
    }

    #[test]
    fn densify_quantized_and_ternary() {
        let layout = TensorLayout::new(vec![("a".into(), vec![3])]);
        let t = TensorUpdate::Ternary { scale: 2.0, vals: vec![-1, 0, 1] };
        let mut out = vec![0.0; 3];
        t.add_into(&mut out, 1.0);
        assert_eq!(out, vec![-2.0, 0.0, 2.0]);
        let q = TensorUpdate::Quantized { scale: 4.0, levels: 4, vals: vec![2, -4, 0] };
        let dense = UpdateMsg { round: 0, tensors: vec![q] }.to_dense(&layout, 1.0);
        assert_eq!(dense, vec![2.0, -4.0, 0.0]);
    }

    #[test]
    fn densify_sign_means() {
        let layout = TensorLayout::flat(4);
        let t = TensorUpdate::SignMeans {
            signs: vec![true, false, true, false],
            mu_pos: 2.0,
            mu_neg: -3.0,
        };
        let dense = UpdateMsg { round: 0, tensors: vec![t] }.to_dense(&layout, 1.0);
        assert_eq!(dense, vec![2.0, -3.0, 2.0, -3.0]);
    }

    #[test]
    fn densify_into_reuses_buffer_and_zeroes() {
        let layout = layout2();
        let msg = UpdateMsg {
            round: 0,
            tensors: vec![TensorUpdate::SparseBinary { idx: vec![3], mu: 1.0, side_pos: true }],
        };
        let mut out = vec![7.0f32; layout.total];
        msg.densify_into(&layout, Granularity::Global, 1.0, &mut out);
        assert_eq!(out, vec![0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn nonzeros() {
        assert_eq!(TensorUpdate::Dense(vec![0.0, 1.0]).nonzeros(), 1);
        assert_eq!(
            TensorUpdate::SparseBinary { idx: vec![1, 2, 3], mu: 0.1, side_pos: true }.nonzeros(),
            3
        );
        // dense 1-bit variants count every element, not non-zeros
        assert_eq!(TensorUpdate::Sign { signs: vec![true, false] }.nonzeros(), 2);
        assert_eq!(
            TensorUpdate::SignMeans { signs: vec![true, false, true], mu_pos: 0.0, mu_neg: 0.0 }
                .nonzeros(),
            3
        );
    }

    #[test]
    fn granularity_segments() {
        let layout = layout2();
        assert_eq!(Granularity::PerTensor.n_segments(&layout), 2);
        assert_eq!(Granularity::Global.n_segments(&layout), 1);
        assert_eq!(Granularity::PerTensor.segment(&layout, 1), 4..10);
        assert_eq!(Granularity::Global.segment(&layout, 0), 0..10);
    }
}
