//! Weight-update compression framework.
//!
//! A [`Compressor`] turns an accumulated weight-update (residual + fresh
//! delta, paper eq. 2) into a [`UpdateMsg`] — the exact object that goes on
//! the wire — plus the dense approximation needed for residual bookkeeping.
//! Compression and encoding are separate stages: compressors produce
//! structured updates; `codec::message` serializes them bit-exactly.

pub mod fedavg;
pub mod gradient_dropping;
pub mod momentum_mask;
pub mod onebit;
pub mod qsgd;
pub mod registry;
pub mod residual;
pub mod sbc;
pub mod signsgd;
pub mod terngrad;
pub mod topk;

use crate::model::TensorLayout;

/// One tensor's compressed update, aligned with the model's tensor layout
/// (or a single whole-vector segment when granularity is global).
#[derive(Clone, Debug, PartialEq)]
pub enum TensorUpdate {
    /// Dense f32 — the baseline and Federated Averaging.
    Dense(Vec<f32>),
    /// Sparse with full-precision values (Gradient Dropping / DGC).
    SparseF32 { idx: Vec<u32>, val: Vec<f32> },
    /// Sparse binary (SBC, paper Alg. 2): positions + one mean; the sign
    /// is carried by `side_pos`.
    SparseBinary { idx: Vec<u32>, mu: f32, side_pos: bool },
    /// Dense sign quantization (signSGD): one bit per element.
    Sign { signs: Vec<bool> },
    /// Dense stochastic ternary (TernGrad): scale plus {-1,0,+1}.
    Ternary { scale: f32, vals: Vec<i8> },
    /// QSGD stochastic uniform quantization: per-tensor scale, signed
    /// integer levels in [-s, s].
    Quantized { scale: f32, levels: u8, vals: Vec<i8> },
}

impl TensorUpdate {
    /// Number of elements the update covers when densified to length `n`.
    pub fn nonzeros(&self) -> usize {
        match self {
            TensorUpdate::Dense(v) => v.iter().filter(|x| **x != 0.0).count(),
            TensorUpdate::SparseF32 { idx, .. } => idx.len(),
            TensorUpdate::SparseBinary { idx, .. } => idx.len(),
            TensorUpdate::Sign { signs } => signs.len(),
            TensorUpdate::Ternary { vals, .. } => vals.iter().filter(|v| **v != 0).count(),
            TensorUpdate::Quantized { vals, .. } => vals.iter().filter(|v| **v != 0).count(),
        }
    }

    /// Densify into `out` (adds into the buffer; caller zeroes it).
    pub fn add_into(&self, out: &mut [f32], sign_scale: f32) {
        match self {
            TensorUpdate::Dense(v) => {
                for (o, x) in out.iter_mut().zip(v) {
                    *o += x;
                }
            }
            TensorUpdate::SparseF32 { idx, val } => {
                for (&i, &v) in idx.iter().zip(val) {
                    out[i as usize] += v;
                }
            }
            TensorUpdate::SparseBinary { idx, mu, side_pos } => {
                let v = if *side_pos { *mu } else { -*mu };
                for &i in idx {
                    out[i as usize] += v;
                }
            }
            TensorUpdate::Sign { signs } => {
                for (o, s) in out.iter_mut().zip(signs) {
                    *o += if *s { sign_scale } else { -sign_scale };
                }
            }
            TensorUpdate::Ternary { scale, vals } => {
                for (o, v) in out.iter_mut().zip(vals) {
                    *o += *v as f32 * scale;
                }
            }
            TensorUpdate::Quantized { scale, levels, vals } => {
                let s = *levels as f32;
                for (o, v) in out.iter_mut().zip(vals) {
                    *o += *v as f32 / s * scale;
                }
            }
        }
    }
}

/// A full client→server message: one update per layout segment.
#[derive(Clone, Debug, PartialEq)]
pub struct UpdateMsg {
    pub round: u32,
    pub tensors: Vec<TensorUpdate>,
}

impl UpdateMsg {
    /// Densify the whole message into a flat vector of length `layout.total`.
    pub fn to_dense(&self, layout: &TensorLayout, sign_scale: f32) -> Vec<f32> {
        let mut out = vec![0.0f32; layout.total];
        for (seg, tu) in layout.segments().zip(&self.tensors) {
            tu.add_into(&mut out[seg.clone()], sign_scale);
        }
        out
    }
}

/// Compression granularity (paper compresses per tensor: one μ per tensor).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    PerTensor,
    Global,
}

/// A gradient compressor. Stateless w.r.t. clients — residuals and momentum
/// live in the coordinator's per-client state; compressors may carry
/// method-level state (e.g. QSGD rng) via `&mut self`.
pub trait Compressor: Send {
    fn name(&self) -> &'static str;

    /// Compress the accumulated update `acc` (layout-segmented). Returns the
    /// message; the caller reconstructs the dense approximation via
    /// `UpdateMsg::to_dense` for residual accounting.
    fn compress(&mut self, acc: &[f32], layout: &TensorLayout, round: u32) -> UpdateMsg;

    /// Whether this method uses residual accumulation (error feedback).
    fn uses_residual(&self) -> bool {
        true
    }

    /// Scale applied when densifying `Sign` updates (signSGD semantics).
    fn sign_scale(&self) -> f32 {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TensorLayout;

    fn layout2() -> TensorLayout {
        TensorLayout::new(vec![("a".into(), vec![4]), ("b".into(), vec![2, 3])])
    }

    #[test]
    fn densify_sparse_binary() {
        let layout = layout2();
        let msg = UpdateMsg {
            round: 0,
            tensors: vec![
                TensorUpdate::SparseBinary { idx: vec![1, 3], mu: 0.5, side_pos: false },
                TensorUpdate::SparseF32 { idx: vec![0, 5], val: vec![1.0, -2.0] },
            ],
        };
        let dense = msg.to_dense(&layout, 1.0);
        assert_eq!(dense, vec![0.0, -0.5, 0.0, -0.5, 1.0, 0.0, 0.0, 0.0, 0.0, -2.0]);
    }

    #[test]
    fn densify_quantized_and_ternary() {
        let layout = TensorLayout::new(vec![("a".into(), vec![3])]);
        let t = TensorUpdate::Ternary { scale: 2.0, vals: vec![-1, 0, 1] };
        let mut out = vec![0.0; 3];
        t.add_into(&mut out, 1.0);
        assert_eq!(out, vec![-2.0, 0.0, 2.0]);
        let q = TensorUpdate::Quantized { scale: 4.0, levels: 4, vals: vec![2, -4, 0] };
        let dense = UpdateMsg { round: 0, tensors: vec![q] }.to_dense(&layout, 1.0);
        assert_eq!(dense, vec![2.0, -4.0, 0.0]);
    }

    #[test]
    fn nonzeros() {
        assert_eq!(TensorUpdate::Dense(vec![0.0, 1.0]).nonzeros(), 1);
        assert_eq!(
            TensorUpdate::SparseBinary { idx: vec![1, 2, 3], mu: 0.1, side_pos: true }.nonzeros(),
            3
        );
        assert_eq!(TensorUpdate::Sign { signs: vec![true, false] }.nonzeros(), 2);
    }
}
