//! Top-k magnitude selection — three ablated strategies:
//!
//! * `exact`: Floyd-Rivest-style quickselect on magnitudes, O(n);
//! * `sampled`: DGC-style threshold estimated from a random subsample;
//! * `hist`: the bit-pattern histogram quantile — a faithful Rust
//!   replication of the L1 Pallas kernel (same bins, same tie handling),
//!   used to cross-validate the PJRT compress path bit-for-bit.

use crate::util::rng::Rng;

/// Select the indices of the k largest-magnitude entries (any order).
/// O(n) average via quickselect on a scratch copy.
pub fn topk_exact(x: &[f32], k: usize) -> Vec<u32> {
    let k = k.min(x.len());
    if k == 0 {
        return vec![];
    }
    if k == x.len() {
        return (0..x.len() as u32).collect();
    }
    let mut mags: Vec<f32> = x.iter().map(|v| v.abs()).collect();
    let kth = {
        // total_cmp: NaN magnitudes order deterministically (above +inf)
        // instead of panicking in the comparator.
        let (_, kth, _) = mags.select_nth_unstable_by(k - 1, |a, b| b.total_cmp(a));
        *kth
    };
    // collect everything strictly above, then fill ties up to k
    let mut out = Vec::with_capacity(k);
    let mut ties = Vec::new();
    for (i, v) in x.iter().enumerate() {
        let m = v.abs();
        if m > kth {
            out.push(i as u32);
        } else if m == kth {
            ties.push(i as u32);
        }
    }
    for t in ties {
        if out.len() >= k {
            break;
        }
        out.push(t);
    }
    out.sort_unstable();
    out
}

/// DGC-style sampled threshold: estimate the k-th magnitude from a random
/// subsample of `sample` elements, then take everything above it.
pub fn topk_sampled(x: &[f32], k: usize, sample: usize, rng: &mut Rng) -> Vec<u32> {
    if x.is_empty() || k == 0 {
        return vec![];
    }
    let sample = sample.clamp(1, x.len());
    let mut mags: Vec<f32> = (0..sample).map(|_| x[rng.below(x.len())].abs()).collect();
    let frac = k as f64 / x.len() as f64;
    let ks = ((frac * sample as f64).round() as usize).clamp(1, sample);
    let thr = {
        let (_, kth, _) = mags.select_nth_unstable_by(ks - 1, |a, b| b.total_cmp(a));
        *kth
    };
    let mut out: Vec<u32> =
        x.iter().enumerate().filter(|(_, v)| v.abs() >= thr).map(|(i, _)| i as u32).collect();
    out.sort_unstable();
    out
}

// --- bit-pattern histogram (mirror of python/compile/kernels) -------------

/// Exponent octaves the histogram spans below the max magnitude.
pub const OCTAVES: i32 = 16;
/// Mantissa sub-bins per octave (top 6 mantissa bits).
pub const SUBBINS: i32 = 64;
/// Total histogram bins (matches the Pallas kernel exactly).
pub const NBINS: usize = ((OCTAVES + 1) * SUBBINS) as usize; // 1088

#[inline]
fn exp_base(absmax: f32) -> i32 {
    let emax = (absmax.to_bits() >> 23) as i32;
    (emax - OCTAVES).max(1)
}

#[inline]
fn bin_index(mag: f32, base: i32) -> usize {
    let bits = mag.to_bits() as i32;
    let e = bits >> 23;
    let sub = (bits >> 17) & (SUBBINS - 1);
    let erel = e - base;
    if erel < 0 {
        0
    } else {
        ((erel * SUBBINS + sub).min(NBINS as i32 - 1)) as usize
    }
}

#[inline]
fn bin_lower_edge(idx: usize, base: i32) -> f32 {
    let e = base + idx as i32 / SUBBINS;
    let sub = idx as i32 % SUBBINS;
    f32::from_bits(((e << 23) | (sub << 17)) as u32)
}

/// Signed histograms over a slice: (pos_hist, neg_hist, absmax).
pub fn signed_histograms(x: &[f32]) -> (Vec<u32>, Vec<u32>, f32) {
    let absmax = x.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    let base = exp_base(absmax);
    let mut hpos = vec![0u32; NBINS];
    let mut hneg = vec![0u32; NBINS];
    for &v in x {
        if v > 0.0 {
            hpos[bin_index(v, base)] += 1;
        } else if v < 0.0 {
            hneg[bin_index(-v, base)] += 1;
        }
    }
    (hpos, hneg, absmax)
}

/// Threshold (bin lower edge) such that count(value >= t) >= k, ignoring
/// the noise bucket (bin 0) — exact mirror of `ref.threshold_from_hist`.
pub fn threshold_from_hist(hist: &[u32], k: u32, absmax: f32) -> f32 {
    let base = exp_base(absmax);
    let mut tail = 0u64;
    let mut idx = 1usize; // fallback: lowest non-noise bin
    let mut found = false;
    // scan from the top; the *largest* i with tail(i) >= k
    for i in (1..NBINS).rev() {
        tail += hist[i] as u64;
        if tail >= k as u64 {
            idx = i;
            found = true;
            break;
        }
    }
    if !found {
        idx = 1;
    }
    bin_lower_edge(idx, base)
}

/// Histogram-based top-k thresholds for both sides (mirrors the Pallas
/// compress graph's threshold stage). Returns (t_pos, t_neg, absmax).
pub fn hist_thresholds(x: &[f32], k: u32) -> (f32, f32, f32) {
    let (hpos, hneg, absmax) = signed_histograms(x);
    (threshold_from_hist(&hpos, k, absmax), threshold_from_hist(&hneg, k, absmax), absmax)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn heavy(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() * rng.next_f32().powi(4)).collect()
    }

    #[test]
    fn exact_selects_largest() {
        let x = [0.1f32, -5.0, 0.2, 3.0, -0.05];
        let idx = topk_exact(&x, 2);
        assert_eq!(idx, vec![1, 3]);
        assert_eq!(topk_exact(&x, 0), Vec::<u32>::new());
        assert_eq!(topk_exact(&x, 5).len(), 5);
        assert_eq!(topk_exact(&x, 99).len(), 5);
    }

    #[test]
    fn exact_handles_ties() {
        let x = [1.0f32; 10];
        let idx = topk_exact(&x, 3);
        assert_eq!(idx.len(), 3);
    }

    #[test]
    fn exact_matches_sort_reference() {
        let x = heavy(10_000, 3);
        for k in [1usize, 10, 100, 5000] {
            let got = topk_exact(&x, k);
            assert_eq!(got.len(), k);
            // reference: sort by magnitude
            let mut order: Vec<usize> = (0..x.len()).collect();
            order.sort_by(|&a, &b| x[b].abs().total_cmp(&x[a].abs()));
            let min_kept: f32 = got.iter().map(|&i| x[i as usize].abs()).fold(f32::MAX, f32::min);
            let kth = x[order[k - 1]].abs();
            assert_eq!(min_kept, kth, "k={k}");
        }
    }

    #[test]
    fn sampled_close_to_exact() {
        let x = heavy(50_000, 4);
        let k = 500;
        let mut rng = Rng::new(9);
        let idx = topk_sampled(&x, k, 5_000, &mut rng);
        // sampled keeps roughly k elements (within 3x either way)
        assert!(idx.len() >= k / 3 && idx.len() <= k * 3, "{}", idx.len());
    }

    #[test]
    fn hist_threshold_keeps_at_least_k() {
        let x = heavy(100_000, 5);
        for k in [10u32, 100, 1000] {
            let (tp, tn, _) = hist_thresholds(&x, k);
            let np = x.iter().filter(|&&v| v > 0.0 && v >= tp).count() as u32;
            let nn = x.iter().filter(|&&v| v < 0.0 && -v >= tn).count() as u32;
            assert!(np >= k, "pos {np} < {k}");
            assert!(nn >= k, "neg {nn} < {k}");
            // overshoot bounded by boundary bin (~a few % at these ks)
            assert!(np <= k + k / 4 + 64, "pos overshoot {np} vs {k}");
            assert!(nn <= k + k / 4 + 64, "neg overshoot {nn} vs {k}");
        }
    }

    #[test]
    fn bin_edge_is_exact_inverse() {
        let base = exp_base(1.0);
        for idx in 1..NBINS {
            let edge = bin_lower_edge(idx, base);
            assert_eq!(bin_index(edge, base), idx, "idx {idx}");
            // the float just below the edge falls in a lower bin
            let below = f32::from_bits(edge.to_bits() - 1);
            assert!(bin_index(below, base) < idx);
        }
    }

    #[test]
    fn all_zero_input() {
        let x = vec![0.0f32; 1000];
        let (tp, _tn, am) = hist_thresholds(&x, 10);
        assert_eq!(am, 0.0);
        assert!(x.iter().all(|&v| !(v > 0.0 && v >= tp)));
    }
}
