//! Quantizer stage: what value representation the selected coordinates
//! are transmitted with.
//!
//! Each quantizer writes one [`TensorUpdate`] per segment, *reusing* the
//! output slot's buffers (the slot keeps its allocation when the variant
//! matches from the previous round), so the compress hot path performs no
//! steady-state heap allocation.
//!
//! The paper's methods map to:
//! * [`QuantizerCfg::F32`] — full precision (Baseline, FedAvg, GradDrop);
//! * [`QuantizerCfg::BinaryMean`] — paper Alg. 2 lines 2-6: average each
//!   sign's candidates, keep the stronger side, binarize to its mean;
//! * [`QuantizerCfg::Sign`] — signSGD (scale applied at densify time);
//! * [`QuantizerCfg::Ternary`] — TernGrad stochastic ternarization;
//! * [`QuantizerCfg::Qsgd`] — QSGD stochastic uniform quantization;
//! * [`QuantizerCfg::SignMeans`] — 1-bit SGD (signs + per-side means).

use crate::compression::select::Support;
use crate::compression::TensorUpdate;
use crate::util::rng::Rng;
use crate::util::tensor;

/// Quantizer configuration — the build-time description of the stage.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum QuantizerCfg {
    /// Transmit selected values in full precision.
    F32,
    /// One mean for the winning sign side (SBC).
    BinaryMean,
    /// One bit per element; `scale` is applied when densifying.
    Sign {
        /// Server step size per sign (signSGD hyperparameter).
        scale: f32,
    },
    /// Stochastic {-s, 0, +s} with s = max |x| (TernGrad).
    Ternary,
    /// Stochastic uniform levels with per-segment L2 scale (QSGD).
    Qsgd {
        /// Level count `s` (values quantize to `[-s, s]`); must be
        /// in `1..=127`.
        levels: u8,
    },
    /// One bit per element plus per-side means (1-bit SGD).
    SignMeans,
}

/// The stateful quantizer stage (owns the RNG for stochastic methods).
pub struct Quantizer {
    cfg: QuantizerCfg,
    rng: Rng,
}

impl Quantizer {
    /// Instantiate the stage (seeded for the stochastic quantizers).
    pub fn new(cfg: QuantizerCfg, seed: u64) -> Quantizer {
        if let QuantizerCfg::Qsgd { levels } = cfg {
            // levels ride in an i8 on the wire; 128 would wrap to -128
            // and negate with overflow for negative inputs
            assert!((1..=127).contains(&levels), "QSGD levels must be in 1..=127");
        }
        Quantizer { cfg, rng: Rng::new(seed) }
    }

    /// The build-time configuration this stage was constructed from.
    pub fn cfg(&self) -> QuantizerCfg {
        self.cfg
    }

    /// The RNG cursor (for checkpointing; only the stochastic quantizers
    /// draw from it, but capturing it is always safe).
    pub fn rng_state(&self) -> [u64; 4] {
        self.rng.state()
    }

    /// Restore the RNG cursor captured by [`Quantizer::rng_state`].
    pub fn restore_rng_state(&mut self, s: [u64; 4]) {
        self.rng = Rng::from_state(s);
    }

    /// Quantize segment `x` with the selector's support into `out`,
    /// reusing `out`'s buffers where the variant matches.
    pub fn quantize(&mut self, x: &[f32], support: Support, idx: &[u32], out: &mut TensorUpdate) {
        match (self.cfg, support) {
            (QuantizerCfg::F32, Support::All) => {
                let v = out.dense_slot();
                v.extend_from_slice(x);
            }
            (QuantizerCfg::F32, Support::Sparse) => {
                let (oi, ov) = out.sparse_f32_slot();
                oi.extend_from_slice(idx);
                ov.extend(idx.iter().map(|&i| x[i as usize]));
            }
            (QuantizerCfg::BinaryMean, _) => binary_mean(x, support, idx, out),
            (QuantizerCfg::Sign { .. }, Support::All) => {
                let signs = out.sign_slot();
                signs.extend(x.iter().map(|&v| v >= 0.0));
            }
            (QuantizerCfg::Ternary, Support::All) => self.ternary(x, out),
            (QuantizerCfg::Qsgd { levels }, Support::All) => self.qsgd(x, levels, out),
            (QuantizerCfg::SignMeans, Support::All) => sign_means(x, out),
            (cfg, Support::Sparse) => {
                // sbc-lint: allow(no-panic) -- construction-time config validation
                panic!("{cfg:?} is a dense quantizer; pair it with SelectorCfg::Dense")
            }
        }
    }

    /// TernGrad (Wen et al.): each coordinate becomes s·sign(x) with
    /// probability |x|/s (s = max |x| per segment), else 0. Unbiased.
    fn ternary(&mut self, x: &[f32], out: &mut TensorUpdate) {
        let (scale, vals) = out.ternary_slot();
        let s = tensor::abs_max(x);
        *scale = s;
        if s == 0.0 {
            vals.resize(x.len(), 0);
            return;
        }
        vals.extend(x.iter().map(|&v| {
            let p = (v.abs() / s) as f64;
            if self.rng.next_f64() < p {
                if v >= 0.0 {
                    1i8
                } else {
                    -1
                }
            } else {
                0
            }
        }));
    }

    /// QSGD (Alistarh et al.): stochastic uniform quantization to
    /// `levels` levels with per-segment L2 scale. Unbiased.
    fn qsgd(&mut self, x: &[f32], levels: u8, out: &mut TensorUpdate) {
        let (scale, lv, vals) = out.quantized_slot();
        *lv = levels;
        let norm = tensor::l2_norm(x);
        *scale = norm;
        if norm == 0.0 {
            vals.resize(x.len(), 0);
            return;
        }
        let s = levels as f32;
        vals.extend(x.iter().map(|&v| {
            let r = v.abs() / norm * s; // in [0, s]
            let lo = r.floor();
            let level = lo as i32 + if self.rng.next_f32() < r - lo { 1 } else { 0 };
            let level = level.clamp(0, s as i32) as i8;
            if v < 0.0 {
                -level
            } else {
                level
            }
        }));
    }
}

/// SBC binarization (paper Alg. 2 lines 2-6): partition the candidate set
/// by sign, average each side, keep the stronger side at its mean. Ties
/// resolve to the positive side (matches the kernel's `mupos >= muneg`).
fn binary_mean(x: &[f32], support: Support, idx: &[u32], out: &mut TensorUpdate) {
    let (oi, mu, side_pos) = out.sparse_binary_slot();
    let (mut sp, mut np, mut sn, mut nn) = (0.0f64, 0usize, 0.0f64, 0usize);
    let mut each = |v: f32| {
        if v > 0.0 {
            sp += v as f64;
            np += 1;
        } else if v < 0.0 {
            sn += v as f64;
            nn += 1;
        }
    };
    match support {
        Support::All => {
            for &v in x {
                each(v);
            }
        }
        Support::Sparse => {
            for &i in idx {
                each(x[i as usize]);
            }
        }
    }
    let mu_pos = if np > 0 { (sp / np as f64) as f32 } else { 0.0 };
    let mu_neg = if nn > 0 { (-sn / nn as f64) as f32 } else { 0.0 };
    let pos = mu_pos >= mu_neg;
    *mu = if pos { mu_pos } else { mu_neg };
    *side_pos = pos;
    let keep = |v: f32| if pos { v > 0.0 } else { v < 0.0 };
    match support {
        Support::All => {
            oi.extend(x.iter().enumerate().filter(|(_, &v)| keep(v)).map(|(i, _)| i as u32))
        }
        Support::Sparse => oi.extend(idx.iter().copied().filter(|&i| keep(x[i as usize]))),
    }
}

/// 1-bit SGD (Seide et al.): positive entries map to the positive mean,
/// negative to the negative mean; the quantization error goes to the
/// residual (this quantizer's defining feature is error feedback).
fn sign_means(x: &[f32], out: &mut TensorUpdate) {
    let (signs, mu_pos, mu_neg) = out.sign_means_slot();
    let (mut sp, mut np, mut sn, mut nn) = (0.0f64, 0u32, 0.0f64, 0u32);
    for &v in x {
        if v >= 0.0 {
            sp += v as f64;
            np += 1;
        } else {
            sn += v as f64;
            nn += 1;
        }
    }
    *mu_pos = if np > 0 { (sp / np as f64) as f32 } else { 0.0 };
    *mu_neg = if nn > 0 { (sn / nn as f64) as f32 } else { 0.0 };
    signs.extend(x.iter().map(|&v| v >= 0.0));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TensorLayout;
    use crate::compression::UpdateMsg;

    fn quantize_fresh(q: &mut Quantizer, x: &[f32], support: Support, idx: &[u32]) -> TensorUpdate {
        let mut out = TensorUpdate::placeholder();
        q.quantize(x, support, idx, &mut out);
        out
    }

    #[test]
    fn f32_dense_and_sparse() {
        let x = [1.0f32, -2.0, 3.5];
        let mut q = Quantizer::new(QuantizerCfg::F32, 0);
        assert_eq!(
            quantize_fresh(&mut q, &x, Support::All, &[]),
            TensorUpdate::Dense(vec![1.0, -2.0, 3.5])
        );
        assert_eq!(
            quantize_fresh(&mut q, &x, Support::Sparse, &[0, 2]),
            TensorUpdate::SparseF32 { idx: vec![0, 2], val: vec![1.0, 3.5] }
        );
    }

    #[test]
    fn binary_mean_positive_side() {
        // candidates: top-2 per side of a positives-dominated segment
        let x = vec![5.0f32, 4.0, -0.1, -0.2, 0.0, 3.0, -0.3, 0.05];
        let mut q = Quantizer::new(QuantizerCfg::BinaryMean, 0);
        match quantize_fresh(&mut q, &x, Support::Sparse, &[0, 1, 3, 6]) {
            TensorUpdate::SparseBinary { idx, mu, side_pos } => {
                assert!(side_pos);
                assert_eq!(idx, vec![0, 1]);
                assert!((mu - 4.5).abs() < 1e-6);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn binary_mean_negative_side() {
        let x = vec![0.1f32, -5.0, 0.2, -4.0, 0.0, -3.0, 0.3, 0.05];
        let mut q = Quantizer::new(QuantizerCfg::BinaryMean, 0);
        match quantize_fresh(&mut q, &x, Support::Sparse, &[1, 2, 3, 6]) {
            TensorUpdate::SparseBinary { idx, mu, side_pos } => {
                assert!(!side_pos);
                assert_eq!(idx, vec![1, 3]);
                assert!((mu - 4.5).abs() < 1e-6);
                let mut out = vec![0.0f32; 8];
                TensorUpdate::SparseBinary { idx, mu, side_pos }.add_into(&mut out, 1.0);
                assert_eq!(out[1], -4.5);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn binary_mean_empty_candidates() {
        let x = vec![0.0f32; 16];
        let mut q = Quantizer::new(QuantizerCfg::BinaryMean, 0);
        match quantize_fresh(&mut q, &x, Support::Sparse, &[]) {
            TensorUpdate::SparseBinary { idx, mu, .. } => {
                assert!(idx.is_empty());
                assert_eq!(mu, 0.0);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn signs_match_signsgd() {
        let x = vec![0.5f32, -0.1, 0.0, -7.0];
        let mut q = Quantizer::new(QuantizerCfg::Sign { scale: 0.01 }, 0);
        match quantize_fresh(&mut q, &x, Support::All, &[]) {
            TensorUpdate::Sign { signs } => assert_eq!(signs, vec![true, false, true, false]),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn ternary_unbiased_in_expectation() {
        let x = vec![0.5f32, -0.25, 0.0, 1.0];
        let layout = TensorLayout::flat(4);
        let mut q = Quantizer::new(QuantizerCfg::Ternary, 3);
        let trials = 4000;
        let mut sum = vec![0.0f64; 4];
        for _ in 0..trials {
            let tu = quantize_fresh(&mut q, &x, Support::All, &[]);
            let dense = UpdateMsg { round: 0, tensors: vec![tu] }.to_dense(&layout, 1.0);
            for i in 0..4 {
                sum[i] += dense[i] as f64;
            }
        }
        for i in 0..4 {
            let mean = sum[i] / trials as f64;
            assert!((mean - x[i] as f64).abs() < 0.05, "i={i}: {mean} vs {}", x[i]);
        }
    }

    #[test]
    fn ternary_max_element_always_kept_and_zero_segment() {
        let mut q = Quantizer::new(QuantizerCfg::Ternary, 4);
        match quantize_fresh(&mut q, &[0.1, -2.0, 0.3], Support::All, &[]) {
            TensorUpdate::Ternary { scale, vals } => {
                assert_eq!(scale, 2.0);
                assert_eq!(vals[1], -1); // p = 1 for the absmax element
            }
            other => panic!("{other:?}"),
        }
        match quantize_fresh(&mut q, &[0.0; 10], Support::All, &[]) {
            TensorUpdate::Ternary { scale, vals } => {
                assert_eq!(scale, 0.0);
                assert!(vals.iter().all(|&v| v == 0));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn qsgd_unbiased_in_expectation() {
        let x = vec![0.3f32, -0.4, 0.0, 0.866];
        let layout = TensorLayout::flat(4);
        let mut q = Quantizer::new(QuantizerCfg::Qsgd { levels: 4 }, 7);
        let trials = 4000;
        let mut sum = vec![0.0f64; 4];
        for _ in 0..trials {
            let tu = quantize_fresh(&mut q, &x, Support::All, &[]);
            let dense = UpdateMsg { round: 0, tensors: vec![tu] }.to_dense(&layout, 1.0);
            for i in 0..4 {
                sum[i] += dense[i] as f64;
            }
        }
        for i in 0..4 {
            let mean = sum[i] / trials as f64;
            assert!((mean - x[i] as f64).abs() < 0.05, "i={i}: {mean} vs {}", x[i]);
        }
    }

    #[test]
    fn qsgd_levels_bounded() {
        let mut rng = Rng::new(8);
        let x: Vec<f32> = (0..1000).map(|_| rng.normal()).collect();
        let mut q = Quantizer::new(QuantizerCfg::Qsgd { levels: 8 }, 9);
        match quantize_fresh(&mut q, &x, Support::All, &[]) {
            TensorUpdate::Quantized { levels, vals, .. } => {
                assert!(vals.iter().all(|&v| v.unsigned_abs() <= levels));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn sign_means_partition() {
        let x = vec![1.0f32, 3.0, -2.0, -4.0];
        let mut q = Quantizer::new(QuantizerCfg::SignMeans, 0);
        match quantize_fresh(&mut q, &x, Support::All, &[]) {
            TensorUpdate::SignMeans { signs, mu_pos, mu_neg } => {
                assert_eq!(signs, vec![true, true, false, false]);
                assert_eq!(mu_pos, 2.0);
                assert_eq!(mu_neg, -3.0);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn slots_reuse_matching_variant() {
        let mut out = TensorUpdate::SparseF32 { idx: vec![1, 2, 3], val: vec![0.5; 3] };
        let mut q = Quantizer::new(QuantizerCfg::F32, 0);
        q.quantize(&[7.0, 8.0], Support::Sparse, &[1], &mut out);
        assert_eq!(out, TensorUpdate::SparseF32 { idx: vec![1], val: vec![8.0] });
        // variant switch replaces the slot
        q.quantize(&[7.0, 8.0], Support::All, &[], &mut out);
        assert_eq!(out, TensorUpdate::Dense(vec![7.0, 8.0]));
    }

    #[test]
    #[should_panic(expected = "dense quantizer")]
    fn dense_quantizer_rejects_sparse_support() {
        let mut q = Quantizer::new(QuantizerCfg::Ternary, 0);
        quantize_fresh(&mut q, &[1.0], Support::Sparse, &[0]);
    }
}
