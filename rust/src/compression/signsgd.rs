//! signSGD (Bernstein et al.) — dense 1-bit sign quantization. The server
//! aggregates by majority vote (handled in `coordinator::aggregation`);
//! the client-side sign scale is the configured server step size.

use crate::compression::{Compressor, Granularity, TensorUpdate, UpdateMsg};
use crate::model::TensorLayout;

pub struct SignSgd {
    pub granularity: Granularity,
    /// Magnitude applied per sign on densify (server lr in the paper).
    pub scale: f32,
}

impl SignSgd {
    pub fn new(scale: f32) -> Self {
        SignSgd { granularity: Granularity::Global, scale }
    }

    fn compress_segment(&self, x: &[f32]) -> TensorUpdate {
        TensorUpdate::Sign { signs: x.iter().map(|&v| v >= 0.0).collect() }
    }
}

impl Compressor for SignSgd {
    fn name(&self) -> &'static str {
        "signsgd"
    }

    fn compress(&mut self, acc: &[f32], layout: &TensorLayout, round: u32) -> UpdateMsg {
        let tensors = match self.granularity {
            Granularity::Global => vec![self.compress_segment(acc)],
            Granularity::PerTensor => {
                layout.segments().map(|seg| self.compress_segment(&acc[seg])).collect()
            }
        };
        UpdateMsg { round, tensors }
    }

    // signSGD does not use error feedback in its published form.
    fn uses_residual(&self) -> bool {
        false
    }

    fn sign_scale(&self) -> f32 {
        self.scale
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn signs_and_scale() {
        let x = vec![0.5f32, -0.1, 0.0, -7.0];
        let layout = TensorLayout::flat(4);
        let mut c = SignSgd::new(0.01);
        let msg = c.compress(&x, &layout, 0);
        let dense = msg.to_dense(&layout, c.sign_scale());
        assert_eq!(dense, vec![0.01, -0.01, 0.01, -0.01]);
    }
}
