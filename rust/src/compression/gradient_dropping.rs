//! Gradient Dropping / Deep Gradient Compression (Aji & Heafield; Lin et
//! al.) — the paper's main sparse baseline: top-p by magnitude with
//! full-precision (32-bit) values. Momentum correction is implicit in the
//! delayed-update formulation; momentum factor masking is applied by the
//! coordinator (see `momentum_mask.rs`) when enabled.

use crate::compression::topk;
use crate::compression::{Compressor, Granularity, TensorUpdate, UpdateMsg};
use crate::model::TensorLayout;

pub struct GradientDropping {
    pub p: f64,
    pub granularity: Granularity,
}

impl GradientDropping {
    pub fn new(p: f64, granularity: Granularity) -> Self {
        GradientDropping { p, granularity }
    }

    fn compress_segment(&self, x: &[f32]) -> TensorUpdate {
        let k = ((self.p * x.len() as f64).round() as usize).max(1);
        let idx = topk::topk_exact(x, k);
        let val = idx.iter().map(|&i| x[i as usize]).collect();
        TensorUpdate::SparseF32 { idx, val }
    }
}

impl Compressor for GradientDropping {
    fn name(&self) -> &'static str {
        "gradient_dropping"
    }

    fn compress(&mut self, acc: &[f32], layout: &TensorLayout, round: u32) -> UpdateMsg {
        let tensors = match self.granularity {
            Granularity::Global => vec![self.compress_segment(acc)],
            Granularity::PerTensor => {
                layout.segments().map(|seg| self.compress_segment(&acc[seg])).collect()
            }
        };
        UpdateMsg { round, tensors }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn keeps_exact_values() {
        let x = vec![0.0f32, -3.0, 0.5, 2.0, -0.1];
        let mut c = GradientDropping::new(0.4, Granularity::Global);
        let msg = c.compress(&x, &TensorLayout::flat(5), 0);
        match &msg.tensors[0] {
            TensorUpdate::SparseF32 { idx, val } => {
                assert_eq!(idx, &vec![1, 3]);
                assert_eq!(val, &vec![-3.0, 2.0]);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn densify_reconstructs_topk() {
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..10_000).map(|_| rng.normal()).collect();
        let mut c = GradientDropping::new(0.001, Granularity::Global);
        let layout = TensorLayout::flat(x.len());
        let dense = c.compress(&x, &layout, 0).to_dense(&layout, 1.0);
        let kept = dense.iter().filter(|v| **v != 0.0).count();
        assert_eq!(kept, 10);
        for (a, b) in dense.iter().zip(&x) {
            assert!(*a == 0.0 || a == b);
        }
    }
}
