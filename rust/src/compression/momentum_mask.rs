//! Momentum factor masking (Lin et al. / DGC, adopted by the paper).
//!
//! After a round transmits certain coordinates, the local optimizer
//! momentum at those coordinates is stale (it pushed toward an update that
//! has now been applied globally); DGC zeroes it to avoid carrying the
//! optimization in a wrong direction. The coordinator applies this to the
//! flat optimizer state returned by the L2 step graph.

/// Zero the optimizer state at the transmitted coordinates.
/// `opt` may be a multiple of `n_params` long (momentum: 1x, Adam: 2x) —
/// every segment is masked at the same offsets.
pub fn mask_momentum(opt: &mut [f32], n_params: usize, transmitted_idx: &[u32]) {
    if opt.is_empty() || n_params == 0 {
        return;
    }
    let segments = opt.len() / n_params;
    for s in 0..segments {
        let off = s * n_params;
        for &i in transmitted_idx {
            opt[off + i as usize] = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masks_all_segments() {
        let mut opt = vec![1.0f32; 8]; // 2 segments of 4 (Adam-like)
        mask_momentum(&mut opt, 4, &[1, 3]);
        assert_eq!(opt, vec![1.0, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn empty_cases() {
        let mut opt: Vec<f32> = vec![];
        mask_momentum(&mut opt, 0, &[0]);
        let mut opt2 = vec![1.0f32; 3]; // opt smaller than n_params segment
        mask_momentum(&mut opt2, 4, &[0]);
        assert_eq!(opt2, vec![1.0; 3]); // 3/4 = 0 segments -> untouched
    }
}
