//! TernGrad (Wen et al.) — stochastic ternarization: each coordinate
//! becomes s_t * sign(x) with probability |x| / s_t (s_t = max |x| per
//! segment), else 0. Unbiased: E[out] = x.

use crate::compression::{Compressor, Granularity, TensorUpdate, UpdateMsg};
use crate::model::TensorLayout;
use crate::util::rng::Rng;
use crate::util::tensor;

pub struct TernGrad {
    pub granularity: Granularity,
    rng: Rng,
}

impl TernGrad {
    pub fn new(seed: u64) -> Self {
        TernGrad { granularity: Granularity::PerTensor, rng: Rng::new(seed) }
    }

    fn compress_segment(&mut self, x: &[f32]) -> TensorUpdate {
        let s = tensor::abs_max(x);
        if s == 0.0 {
            return TensorUpdate::Ternary { scale: 0.0, vals: vec![0; x.len()] };
        }
        let vals = x
            .iter()
            .map(|&v| {
                let p = (v.abs() / s) as f64;
                if (self.rng.next_f64()) < p {
                    if v >= 0.0 {
                        1i8
                    } else {
                        -1
                    }
                } else {
                    0
                }
            })
            .collect();
        TensorUpdate::Ternary { scale: s, vals }
    }
}

impl Compressor for TernGrad {
    fn name(&self) -> &'static str {
        "terngrad"
    }

    fn compress(&mut self, acc: &[f32], layout: &TensorLayout, round: u32) -> UpdateMsg {
        let tensors = match self.granularity {
            Granularity::Global => vec![self.compress_segment(acc)],
            Granularity::PerTensor => {
                let segs: Vec<_> = layout.segments().collect();
                segs.into_iter().map(|seg| self.compress_segment(&acc[seg])).collect()
            }
        };
        UpdateMsg { round, tensors }
    }

    // published TernGrad is unbiased and does not use error feedback
    fn uses_residual(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unbiased_in_expectation() {
        let x = vec![0.5f32, -0.25, 0.0, 1.0];
        let layout = TensorLayout::flat(4);
        let mut c = TernGrad::new(3);
        let trials = 4000;
        let mut sum = vec![0.0f64; 4];
        for r in 0..trials {
            let dense = c.compress(&x, &layout, r).to_dense(&layout, 1.0);
            for i in 0..4 {
                sum[i] += dense[i] as f64;
            }
        }
        for i in 0..4 {
            let mean = sum[i] / trials as f64;
            assert!((mean - x[i] as f64).abs() < 0.05, "i={i}: {mean} vs {}", x[i]);
        }
    }

    #[test]
    fn max_element_always_kept() {
        let x = vec![0.1f32, -2.0, 0.3];
        let mut c = TernGrad::new(4);
        match c.compress_segment(&x) {
            TensorUpdate::Ternary { scale, vals } => {
                assert_eq!(scale, 2.0);
                assert_eq!(vals[1], -1); // p = 1 for the absmax element
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn zero_segment() {
        let mut c = TernGrad::new(5);
        match c.compress_segment(&[0.0; 10]) {
            TensorUpdate::Ternary { scale, vals } => {
                assert_eq!(scale, 0.0);
                assert!(vals.iter().all(|&v| v == 0));
            }
            other => panic!("{other:?}"),
        }
    }
}
