//! Sparse Binary Compression — paper Algorithm 2 (the native Rust path).
//!
//! Per segment (tensor or whole vector, by granularity): keep the fraction
//! `p` largest positive and `p` most negative entries, average each side,
//! drop the weaker side, binarize the stronger side to its mean. Combined
//! with communication delay (coordinator), residual accumulation
//! (`residual.rs`) and Golomb position coding (`codec::message`), this is
//! the full SBC pipeline.
//!
//! Selection strategy is pluggable ([`Selection`]): `Exact` quickselect,
//! DGC-style `Sampled`, or `Hist` — the bit-exact mirror of the L1 Pallas
//! kernel, used to cross-validate the PJRT compress path.

use crate::compression::topk::{self, hist_thresholds};
use crate::compression::{Compressor, Granularity, TensorUpdate, UpdateMsg};
use crate::model::TensorLayout;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Selection {
    Exact,
    /// Threshold estimated from a subsample of this many elements.
    Sampled(usize),
    /// Bit-pattern histogram quantile (kernel mirror).
    Hist,
}

pub struct SbcCompressor {
    pub p: f64,
    pub granularity: Granularity,
    pub selection: Selection,
    rng: Rng,
}

impl SbcCompressor {
    pub fn new(p: f64, granularity: Granularity, selection: Selection, seed: u64) -> Self {
        SbcCompressor { p, granularity, selection, rng: Rng::new(seed) }
    }

    /// Compress one segment (paper Alg. 2). Public so tests and the PJRT
    /// cross-validation can call it directly.
    pub fn compress_segment(&mut self, x: &[f32]) -> TensorUpdate {
        let n = x.len();
        let k = ((self.p * n as f64).round() as usize).max(1);

        let (pos_idx, neg_idx) = match self.selection {
            Selection::Exact => select_exact(x, k),
            Selection::Sampled(sample) => select_sampled(x, k, sample, &mut self.rng),
            Selection::Hist => select_hist(x, k as u32),
        };

        let (mu_pos, mu_neg) = (mean_at(x, &pos_idx), -mean_at(x, &neg_idx));
        // paper: if mu+ > mu- keep positives; ties resolve to the positive
        // side (matches the kernel's `mupos >= muneg`)
        if mu_pos >= mu_neg {
            TensorUpdate::SparseBinary { idx: pos_idx, mu: mu_pos, side_pos: true }
        } else {
            TensorUpdate::SparseBinary { idx: neg_idx, mu: mu_neg, side_pos: false }
        }
    }
}

fn mean_at(x: &[f32], idx: &[u32]) -> f32 {
    if idx.is_empty() {
        return 0.0;
    }
    (idx.iter().map(|&i| x[i as usize] as f64).sum::<f64>() / idx.len() as f64) as f32
}

/// Exact per-side top-k: k largest positive values, k most negative.
///
/// Two-phase for speed (perf pass, EXPERIMENTS.md §Perf): quickselect the
/// k-th value on a contiguous f32 copy (cache-friendly, no indirect
/// compares), then one scan collects the indices at/above the threshold.
fn select_exact(x: &[f32], k: usize) -> (Vec<u32>, Vec<u32>) {
    let take_side = |sign: f32| -> Vec<u32> {
        let mut vals: Vec<f32> = x
            .iter()
            .filter_map(|&v| {
                let s = sign * v;
                if s > 0.0 {
                    Some(s)
                } else {
                    None
                }
            })
            .collect();
        let k2 = k.min(vals.len());
        if k2 == 0 {
            return vec![];
        }
        let thr = if k2 < vals.len() {
            let (_, kth, _) =
                vals.select_nth_unstable_by(k2 - 1, |a, b| b.partial_cmp(a).unwrap());
            *kth
        } else {
            0.0 // keep every element of this side
        };
        let mut out = Vec::with_capacity(k2 + 8);
        let mut ties = Vec::new();
        for (i, &v) in x.iter().enumerate() {
            let s = sign * v;
            if s > thr {
                out.push(i as u32);
            } else if s == thr && s > 0.0 {
                ties.push(i as u32);
            }
        }
        for t in ties {
            if out.len() >= k2 {
                break;
            }
            out.push(t);
        }
        out.sort_unstable();
        out
    };
    (take_side(1.0), take_side(-1.0))
}

fn select_sampled(x: &[f32], k: usize, sample: usize, rng: &mut Rng) -> (Vec<u32>, Vec<u32>) {
    // Estimate per-side thresholds from a magnitude subsample of each side.
    let idx = topk::topk_sampled(x, 2 * k, sample, rng);
    let mut pos = Vec::new();
    let mut neg = Vec::new();
    for i in idx {
        if x[i as usize] > 0.0 {
            pos.push(i);
        } else if x[i as usize] < 0.0 {
            neg.push(i);
        }
    }
    (pos, neg)
}

fn select_hist(x: &[f32], k: u32) -> (Vec<u32>, Vec<u32>) {
    let (tp, tn, _am) = hist_thresholds(x, k);
    let mut pos = Vec::new();
    let mut neg = Vec::new();
    for (i, &v) in x.iter().enumerate() {
        if v > 0.0 && v >= tp {
            pos.push(i as u32);
        } else if v < 0.0 && -v >= tn {
            neg.push(i as u32);
        }
    }
    (pos, neg)
}

impl Compressor for SbcCompressor {
    fn name(&self) -> &'static str {
        "sbc"
    }

    fn compress(&mut self, acc: &[f32], layout: &TensorLayout, round: u32) -> UpdateMsg {
        let tensors = match self.granularity {
            Granularity::Global => vec![self.compress_segment(acc)],
            Granularity::PerTensor => {
                layout.segments().map(|seg| self.compress_segment(&acc[seg])).collect()
            }
        };
        UpdateMsg { round, tensors }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn heavy(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() * rng.next_f32().powi(3)).collect()
    }

    #[test]
    fn algorithm2_semantics_positive_side() {
        // handcrafted: positives clearly stronger
        let x = vec![5.0f32, 4.0, -0.1, -0.2, 0.0, 3.0, -0.3, 0.05];
        let mut c = SbcCompressor::new(0.25, Granularity::Global, Selection::Exact, 0);
        match c.compress_segment(&x) {
            TensorUpdate::SparseBinary { idx, mu, side_pos } => {
                assert!(side_pos);
                assert_eq!(idx, vec![0, 1]); // top-2 positives (k = 2)
                assert!((mu - 4.5).abs() < 1e-6);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn algorithm2_semantics_negative_side() {
        let x = vec![0.1f32, -5.0, 0.2, -4.0, 0.0, -3.0, 0.3, 0.05];
        let mut c = SbcCompressor::new(0.25, Granularity::Global, Selection::Exact, 0);
        match c.compress_segment(&x) {
            TensorUpdate::SparseBinary { idx, mu, side_pos } => {
                assert!(!side_pos);
                assert_eq!(idx, vec![1, 3]);
                assert!((mu - 4.5).abs() < 1e-6);
                // densified: -mu at idx
                let mut out = vec![0.0f32; 8];
                TensorUpdate::SparseBinary { idx, mu, side_pos }.add_into(&mut out, 1.0);
                assert_eq!(out[1], -4.5);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn sparsity_is_respected() {
        let x = heavy(100_000, 7);
        let mut c = SbcCompressor::new(0.01, Granularity::Global, Selection::Exact, 0);
        let tu = c.compress_segment(&x);
        let k = 1000;
        assert_eq!(tu.nonzeros(), k);
    }

    #[test]
    fn hist_selection_close_to_exact() {
        let x = heavy(100_000, 8);
        let mut ce = SbcCompressor::new(0.01, Granularity::Global, Selection::Exact, 0);
        let mut ch = SbcCompressor::new(0.01, Granularity::Global, Selection::Hist, 0);
        let (te, th) = (ce.compress_segment(&x), ch.compress_segment(&x));
        let (TensorUpdate::SparseBinary { idx: ie, mu: me, side_pos: se },
             TensorUpdate::SparseBinary { idx: ih, mu: mh, side_pos: sh }) = (te, th)
        else {
            panic!()
        };
        // With near-symmetric data mu+ ~ mu- and the side choice can flip
        // between selection strategies; either way the transmitted means
        // must be close and the kept count within histogram-bin overshoot.
        assert!((me - mh).abs() / me.max(1e-9) < 0.05, "mu {me} vs {mh}");
        if se == sh {
            assert!(ih.len() >= ie.len());
            assert!(ih.len() <= ie.len() + ie.len() / 8 + 64);
        }
    }

    #[test]
    fn per_tensor_granularity_one_mu_per_tensor() {
        let layout = TensorLayout::new(vec![("a".into(), vec![1000]), ("b".into(), vec![500])]);
        let x = heavy(1500, 9);
        let mut c = SbcCompressor::new(0.02, Granularity::PerTensor, Selection::Exact, 0);
        let msg = c.compress(&x, &layout, 3);
        assert_eq!(msg.tensors.len(), 2);
        assert_eq!(msg.round, 3);
        for t in &msg.tensors {
            assert!(matches!(t, TensorUpdate::SparseBinary { .. }));
        }
    }

    #[test]
    fn all_zero_segment() {
        let x = vec![0.0f32; 1000];
        let mut c = SbcCompressor::new(0.01, Granularity::Global, Selection::Exact, 0);
        match c.compress_segment(&x) {
            TensorUpdate::SparseBinary { idx, mu, .. } => {
                assert!(idx.is_empty());
                assert_eq!(mu, 0.0);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn one_sided_input() {
        // every entry negative: positive side empty, negative side chosen
        let x: Vec<f32> = heavy(10_000, 10).iter().map(|v| -v.abs() - 1e-6).collect();
        let mut c = SbcCompressor::new(0.01, Granularity::Global, Selection::Exact, 0);
        match c.compress_segment(&x) {
            TensorUpdate::SparseBinary { idx, mu, side_pos } => {
                assert!(!side_pos);
                assert_eq!(idx.len(), 100);
                assert!(mu > 0.0);
            }
            other => panic!("{other:?}"),
        }
    }
}
