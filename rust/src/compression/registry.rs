//! Method registry: configuration → boxed compressor + coordinator knobs.
//!
//! A [`MethodConfig`] fully describes one compression scheme including the
//! coordinator-level settings (communication delay, residual, momentum
//! masking); the paper's named configurations (Table II columns) are
//! provided as constructors.

use crate::compression::fedavg::DenseCompressor;
use crate::compression::gradient_dropping::GradientDropping;
use crate::compression::onebit::OneBitSgd;
use crate::compression::qsgd::Qsgd;
use crate::compression::sbc::{SbcCompressor, Selection};
use crate::compression::signsgd::SignSgd;
use crate::compression::terngrad::TernGrad;
use crate::compression::{Compressor, Granularity};

#[derive(Clone, Debug, PartialEq)]
pub enum Method {
    /// Dense every round (DSGD baseline when delay = 1).
    Baseline,
    /// Dense with communication delay (McMahan et al.).
    FedAvg,
    /// Top-p sparsification, f32 values (Aji & Heafield / Lin et al.).
    GradientDropping { p: f64 },
    /// Sparse Binary Compression (this paper).
    Sbc { p: f64, selection: SelectionCfg },
    SignSgd { scale: f32 },
    TernGrad,
    Qsgd { levels: u8 },
    OneBit,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SelectionCfg {
    Exact,
    Sampled(usize),
    Hist,
}

impl From<SelectionCfg> for Selection {
    fn from(c: SelectionCfg) -> Selection {
        match c {
            SelectionCfg::Exact => Selection::Exact,
            SelectionCfg::Sampled(s) => Selection::Sampled(s),
            SelectionCfg::Hist => Selection::Hist,
        }
    }
}

/// Full per-run compression configuration.
#[derive(Clone, Debug)]
pub struct MethodConfig {
    pub method: Method,
    /// Local iterations per communication round (n in the paper; 1 = DSGD).
    pub delay: usize,
    /// Momentum factor masking (Lin et al.), applied by the coordinator.
    pub momentum_masking: bool,
    /// Error feedback on/off (ablation; methods have sane defaults).
    pub residual: Option<bool>,
    pub granularity: Granularity,
}

impl MethodConfig {
    pub fn baseline() -> Self {
        Self::of(Method::Baseline, 1)
    }

    /// SBC (1): no delay, 0.1% gradient sparsity (paper §IV-B).
    pub fn sbc1() -> Self {
        Self::of(Method::Sbc { p: 0.001, selection: SelectionCfg::Exact }, 1)
    }

    /// SBC (2): delay 10, 1% sparsity.
    pub fn sbc2() -> Self {
        Self::of(Method::Sbc { p: 0.01, selection: SelectionCfg::Exact }, 10)
    }

    /// SBC (3): delay 100, 1% sparsity.
    pub fn sbc3() -> Self {
        Self::of(Method::Sbc { p: 0.01, selection: SelectionCfg::Exact }, 100)
    }

    /// Gradient Dropping at the paper's p = 0.1%.
    pub fn gradient_dropping() -> Self {
        let mut c = Self::of(Method::GradientDropping { p: 0.001 }, 1);
        c.momentum_masking = true;
        c
    }

    /// Federated Averaging at delay n.
    pub fn fedavg(n: usize) -> Self {
        Self::of(Method::FedAvg, n)
    }

    pub fn of(method: Method, delay: usize) -> Self {
        MethodConfig {
            method,
            delay: delay.max(1),
            momentum_masking: false,
            residual: None,
            granularity: Granularity::PerTensor,
        }
    }

    /// Human-readable label for tables.
    pub fn label(&self) -> String {
        match &self.method {
            Method::Baseline => "Baseline".into(),
            Method::FedAvg => format!("FedAvg(n={})", self.delay),
            Method::GradientDropping { p } => format!("GradDrop(p={p})"),
            Method::Sbc { p, .. } => format!("SBC(p={p},n={})", self.delay),
            Method::SignSgd { .. } => "signSGD".into(),
            Method::TernGrad => "TernGrad".into(),
            Method::Qsgd { levels } => format!("QSGD({levels})"),
            Method::OneBit => "1bitSGD".into(),
        }
    }

    /// Instantiate the compressor (seeded for stochastic methods).
    pub fn build(&self, seed: u64) -> Box<dyn Compressor> {
        let g = self.granularity;
        match &self.method {
            Method::Baseline | Method::FedAvg => Box::new(DenseCompressor { granularity: g }),
            Method::GradientDropping { p } => Box::new(GradientDropping::new(*p, g)),
            Method::Sbc { p, selection } => {
                Box::new(SbcCompressor::new(*p, g, (*selection).into(), seed))
            }
            Method::SignSgd { scale } => Box::new(SignSgd::new(*scale)),
            Method::TernGrad => {
                let mut t = TernGrad::new(seed);
                t.granularity = g;
                Box::new(t)
            }
            Method::Qsgd { levels } => {
                let mut q = Qsgd::new(*levels, seed);
                q.granularity = g;
                Box::new(q)
            }
            Method::OneBit => {
                let mut o = OneBitSgd::new();
                o.granularity = g;
                Box::new(o)
            }
        }
    }

    /// Residual on/off, resolving the ablation override.
    pub fn use_residual(&self, compressor_default: bool) -> bool {
        self.residual.unwrap_or(compressor_default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper() {
        assert_eq!(MethodConfig::sbc1().delay, 1);
        assert_eq!(MethodConfig::sbc2().delay, 10);
        assert_eq!(MethodConfig::sbc3().delay, 100);
        match MethodConfig::sbc1().method {
            Method::Sbc { p, .. } => assert_eq!(p, 0.001),
            _ => panic!(),
        }
        assert!(MethodConfig::gradient_dropping().momentum_masking);
    }

    #[test]
    fn build_all() {
        for cfg in [
            MethodConfig::baseline(),
            MethodConfig::fedavg(100),
            MethodConfig::gradient_dropping(),
            MethodConfig::sbc1(),
            MethodConfig::of(Method::SignSgd { scale: 0.01 }, 1),
            MethodConfig::of(Method::TernGrad, 1),
            MethodConfig::of(Method::Qsgd { levels: 4 }, 1),
            MethodConfig::of(Method::OneBit, 1),
        ] {
            let c = cfg.build(0);
            assert!(!c.name().is_empty());
            assert!(!cfg.label().is_empty());
        }
    }

    #[test]
    fn residual_override() {
        let mut cfg = MethodConfig::sbc1();
        assert!(cfg.use_residual(true));
        cfg.residual = Some(false);
        assert!(!cfg.use_residual(true));
    }
}
