//! Method registry: stage compositions → pipelines + coordinator knobs.
//!
//! A [`MethodConfig`] names one compression scheme as an explicit
//! Select → Quantize composition plus the coordinator-level settings
//! (communication delay, residual, momentum masking). The paper's named
//! configurations (Table II columns) are presets; arbitrary compositions
//! are assembled with the fluent [`MethodConfig::builder`]. Building the
//! runtime [`Pipeline`] happens exactly once per client —
//! [`MethodConfig::build`] passes granularity and seeds into the stage
//! constructors and never mutates a constructed stage.

use crate::compression::pipeline::Pipeline;
use crate::compression::quantize::{Quantizer, QuantizerCfg};
use crate::compression::select::{Selection, Selector, SelectorCfg};
use crate::compression::Granularity;

/// Full per-run compression configuration: the stage composition plus
/// coordinator knobs.
#[derive(Clone, Debug, PartialEq)]
pub struct MethodConfig {
    /// Which coordinates survive (stage 1).
    pub selector: SelectorCfg,
    /// How surviving values are represented (stage 2).
    pub quantizer: QuantizerCfg,
    /// Per-tensor (paper default: one μ per tensor) or whole-vector.
    pub granularity: Granularity,
    /// Local iterations per communication round (n in the paper; 1 = DSGD).
    pub delay: usize,
    /// Momentum factor masking (Lin et al.), applied by the coordinator.
    pub momentum_masking: bool,
    /// Error feedback override (ablation; `None` = method default).
    pub residual: Option<bool>,
}

/// Fluent builder for arbitrary stage compositions.
#[derive(Clone, Debug)]
pub struct MethodBuilder {
    cfg: MethodConfig,
}

impl MethodBuilder {
    /// Set the selection stage (which coordinates survive).
    pub fn select(mut self, selector: SelectorCfg) -> Self {
        self.cfg.selector = selector;
        self
    }

    /// Set the quantization stage (how survivors are represented).
    pub fn quantize(mut self, quantizer: QuantizerCfg) -> Self {
        self.cfg.quantizer = quantizer;
        self
    }

    /// Set the segmentation (per-tensor or whole-vector).
    pub fn granularity(mut self, granularity: Granularity) -> Self {
        self.cfg.granularity = granularity;
        self
    }

    /// Set the communication delay (local iterations per round, ≥ 1).
    pub fn delay(mut self, delay: usize) -> Self {
        self.cfg.delay = delay.max(1);
        self
    }

    /// Enable/disable DGC momentum factor masking.
    pub fn momentum_masking(mut self, on: bool) -> Self {
        self.cfg.momentum_masking = on;
        self
    }

    /// Override the residual (error feedback) default.
    pub fn residual(mut self, on: bool) -> Self {
        self.cfg.residual = Some(on);
        self
    }

    /// Validate the composition and produce the config. Panics on
    /// stage pairings with no defined wire semantics (dense quantizers
    /// over a sparse support).
    pub fn build(self) -> MethodConfig {
        let cfg = self.cfg;
        let dense_sel = matches!(cfg.selector, SelectorCfg::Dense);
        match cfg.quantizer {
            QuantizerCfg::Sign { .. } | QuantizerCfg::Ternary | QuantizerCfg::Qsgd { .. }
            | QuantizerCfg::SignMeans => {
                assert!(
                    dense_sel,
                    "{:?} is a dense quantizer; pair it with SelectorCfg::Dense",
                    cfg.quantizer
                );
            }
            QuantizerCfg::BinaryMean => {
                assert!(
                    !dense_sel,
                    "BinaryMean needs a sparse selector (TwoSided for paper-faithful SBC)"
                );
            }
            QuantizerCfg::F32 => {}
        }
        cfg
    }
}

impl MethodConfig {
    /// Start a builder: dense f32, per-tensor, delay 1 (the baseline).
    pub fn builder() -> MethodBuilder {
        MethodBuilder {
            cfg: MethodConfig {
                selector: SelectorCfg::Dense,
                quantizer: QuantizerCfg::F32,
                granularity: Granularity::PerTensor,
                delay: 1,
                momentum_masking: false,
                residual: None,
            },
        }
    }

    // --- paper presets (Table I / Table II columns) ---------------------

    /// Dense every round (DSGD baseline).
    ///
    /// ```
    /// use sbc::compression::registry::MethodConfig;
    /// let cfg = MethodConfig::baseline();
    /// assert_eq!(cfg.label(), "Baseline");
    /// assert_eq!(cfg.delay, 1);
    /// assert!(!cfg.use_residual()); // nothing is lost, nothing to feed back
    /// ```
    pub fn baseline() -> Self {
        Self::builder().build()
    }

    /// Federated Averaging at delay n (McMahan et al.).
    ///
    /// ```
    /// use sbc::compression::registry::MethodConfig;
    /// let cfg = MethodConfig::fedavg(100);
    /// assert_eq!(cfg.label(), "FedAvg(n=100)");
    /// assert_eq!(cfg.delay, 100); // dense updates, 1 round per 100 iters
    /// ```
    pub fn fedavg(n: usize) -> Self {
        Self::builder().delay(n).build()
    }

    /// Gradient Dropping at the paper's p = 0.1% (Aji & Heafield), with
    /// DGC momentum masking (Lin et al.).
    ///
    /// ```
    /// use sbc::compression::registry::MethodConfig;
    /// let cfg = MethodConfig::gradient_dropping();
    /// assert_eq!(cfg.label(), "GradDrop(p=0.001)");
    /// assert!(cfg.momentum_masking && cfg.use_residual());
    /// ```
    pub fn gradient_dropping() -> Self {
        Self::builder()
            .select(SelectorCfg::TopK { p: 0.001, strategy: Selection::Exact })
            .momentum_masking(true)
            .build()
    }

    /// Sparse Binary Compression at sparsity `p` and delay `n`.
    ///
    /// ```
    /// use sbc::compression::registry::MethodConfig;
    /// use sbc::compression::TensorUpdate;
    /// use sbc::model::TensorLayout;
    ///
    /// let cfg = MethodConfig::sbc(0.25, 4);
    /// assert_eq!(cfg.sbc_p(), Some(0.25));
    /// // the built pipeline emits the SparseBinary wire variant
    /// let mut pipeline = cfg.build(7);
    /// let msg = pipeline.compress(&[1.0, -0.5, 3.0, 0.25], &TensorLayout::flat(4), 0);
    /// assert!(matches!(msg.tensors[0], TensorUpdate::SparseBinary { .. }));
    /// ```
    pub fn sbc(p: f64, delay: usize) -> Self {
        Self::builder()
            .select(SelectorCfg::TwoSided { p, strategy: Selection::Exact })
            .quantize(QuantizerCfg::BinaryMean)
            .delay(delay)
            .build()
    }

    /// SBC (1): no delay, 0.1% gradient sparsity (paper §IV-B).
    ///
    /// ```
    /// # use sbc::compression::registry::MethodConfig;
    /// assert_eq!(MethodConfig::sbc1().label(), "SBC(p=0.001,n=1)");
    /// ```
    pub fn sbc1() -> Self {
        Self::sbc(0.001, 1)
    }

    /// SBC (2): delay 10, 1% sparsity.
    ///
    /// ```
    /// # use sbc::compression::registry::MethodConfig;
    /// assert_eq!(MethodConfig::sbc2().label(), "SBC(p=0.01,n=10)");
    /// ```
    pub fn sbc2() -> Self {
        Self::sbc(0.01, 10)
    }

    /// SBC (3): delay 100, 1% sparsity.
    ///
    /// ```
    /// # use sbc::compression::registry::MethodConfig;
    /// assert_eq!(MethodConfig::sbc3().label(), "SBC(p=0.01,n=100)");
    /// ```
    pub fn sbc3() -> Self {
        Self::sbc(0.01, 100)
    }

    /// signSGD (Bernstein et al.); `scale` is the server step size
    /// applied per sign on densify.
    ///
    /// ```
    /// use sbc::compression::registry::MethodConfig;
    /// use sbc::model::TensorLayout;
    ///
    /// let cfg = MethodConfig::signsgd(0.01);
    /// assert_eq!(cfg.sign_scale(), 0.01);
    /// // one bit per coordinate; densify applies ±scale
    /// let msg = cfg.build(0).compress(&[0.5, -2.0], &TensorLayout::flat(2), 0);
    /// assert_eq!(msg.to_dense(&TensorLayout::flat(2), cfg.sign_scale()), vec![0.01, -0.01]);
    /// ```
    pub fn signsgd(scale: f32) -> Self {
        Self::builder()
            .quantize(QuantizerCfg::Sign { scale })
            .granularity(Granularity::Global)
            .build()
    }

    /// TernGrad (Wen et al.).
    ///
    /// ```
    /// use sbc::compression::registry::MethodConfig;
    /// let cfg = MethodConfig::terngrad();
    /// assert_eq!(cfg.label(), "TernGrad");
    /// assert!(!cfg.use_residual()); // unbiased quantizer: no error feedback
    /// ```
    pub fn terngrad() -> Self {
        Self::builder().quantize(QuantizerCfg::Ternary).build()
    }

    /// QSGD (Alistarh et al.) with `levels` quantization levels.
    ///
    /// ```
    /// use sbc::compression::registry::MethodConfig;
    /// assert_eq!(MethodConfig::qsgd(4).label(), "QSGD(4)");
    /// ```
    pub fn qsgd(levels: u8) -> Self {
        Self::builder().quantize(QuantizerCfg::Qsgd { levels }).build()
    }

    /// 1-bit SGD (Seide et al.).
    ///
    /// ```
    /// use sbc::compression::registry::MethodConfig;
    /// let cfg = MethodConfig::onebit();
    /// assert_eq!(cfg.label(), "1bitSGD");
    /// assert!(cfg.use_residual()); // error feedback is its defining feature
    /// ```
    pub fn onebit() -> Self {
        Self::builder().quantize(QuantizerCfg::SignMeans).build()
    }

    /// Chainable granularity override.
    pub fn with_granularity(mut self, granularity: Granularity) -> Self {
        self.granularity = granularity;
        self
    }

    // --- derived properties --------------------------------------------

    /// Human-readable label for tables, derived from the composition.
    pub fn label(&self) -> String {
        match (self.selector, self.quantizer) {
            (SelectorCfg::Dense, QuantizerCfg::F32) => {
                if self.delay > 1 {
                    format!("FedAvg(n={})", self.delay)
                } else {
                    "Baseline".into()
                }
            }
            (SelectorCfg::TopK { p, .. }, QuantizerCfg::F32) => format!("GradDrop(p={p})"),
            (SelectorCfg::TwoSided { p, .. }, QuantizerCfg::BinaryMean)
            | (SelectorCfg::TopK { p, .. }, QuantizerCfg::BinaryMean) => {
                format!("SBC(p={p},n={})", self.delay)
            }
            (SelectorCfg::Dense, QuantizerCfg::Sign { .. }) => "signSGD".into(),
            (SelectorCfg::Dense, QuantizerCfg::Ternary) => "TernGrad".into(),
            (SelectorCfg::Dense, QuantizerCfg::Qsgd { levels }) => format!("QSGD({levels})"),
            (SelectorCfg::Dense, QuantizerCfg::SignMeans) => "1bitSGD".into(),
            (sel, q) => format!("{sel:?}+{q:?}(n={})", self.delay),
        }
    }

    /// Instantiate the pipeline (seeded for stochastic stages). Stage
    /// construction is final: granularity and strategy are constructor
    /// arguments, never post-construction mutation.
    pub fn build(&self, seed: u64) -> Pipeline {
        Pipeline::new(
            Selector::new(self.selector, seed),
            Quantizer::new(self.quantizer, seed),
            self.granularity,
        )
    }

    /// Whether this method uses residual accumulation (error feedback),
    /// resolving the ablation override against the composition default:
    /// sparse selectors and 1-bit SGD correct their error; dense unbiased
    /// quantizers do not.
    pub fn use_residual(&self) -> bool {
        let default = match (self.selector, self.quantizer) {
            (SelectorCfg::TopK { .. } | SelectorCfg::TwoSided { .. }, _) => true,
            (SelectorCfg::Dense, QuantizerCfg::SignMeans) => true,
            (SelectorCfg::Dense, _) => false,
        };
        self.residual.unwrap_or(default)
    }

    /// Scale applied when densifying `Sign` updates (signSGD semantics).
    pub fn sign_scale(&self) -> f32 {
        match self.quantizer {
            QuantizerCfg::Sign { scale } => scale,
            _ => 1.0,
        }
    }

    /// The SBC sparsity, when this config is an SBC composition (used to
    /// route through the AOT Pallas compress graph).
    pub fn sbc_p(&self) -> Option<f64> {
        match (self.selector, self.quantizer) {
            (SelectorCfg::TwoSided { p, .. }, QuantizerCfg::BinaryMean) => Some(p),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper() {
        assert_eq!(MethodConfig::sbc1().delay, 1);
        assert_eq!(MethodConfig::sbc2().delay, 10);
        assert_eq!(MethodConfig::sbc3().delay, 100);
        assert_eq!(MethodConfig::sbc1().sbc_p(), Some(0.001));
        assert_eq!(MethodConfig::sbc2().sbc_p(), Some(0.01));
        assert!(MethodConfig::gradient_dropping().momentum_masking);
        assert!(matches!(
            MethodConfig::gradient_dropping().selector,
            SelectorCfg::TopK { p, strategy: Selection::Exact } if p == 0.001
        ));
    }

    #[test]
    fn build_all_paper_methods() {
        for cfg in [
            MethodConfig::baseline(),
            MethodConfig::fedavg(100),
            MethodConfig::gradient_dropping(),
            MethodConfig::sbc1(),
            MethodConfig::signsgd(0.01),
            MethodConfig::terngrad(),
            MethodConfig::qsgd(4),
            MethodConfig::onebit(),
        ] {
            let p = cfg.build(0);
            assert!(!p.name().is_empty());
            assert!(!cfg.label().is_empty());
            assert_eq!(p.granularity(), cfg.granularity);
        }
    }

    #[test]
    fn labels_are_stable() {
        assert_eq!(MethodConfig::baseline().label(), "Baseline");
        assert_eq!(MethodConfig::fedavg(100).label(), "FedAvg(n=100)");
        assert_eq!(MethodConfig::gradient_dropping().label(), "GradDrop(p=0.001)");
        assert_eq!(MethodConfig::sbc2().label(), "SBC(p=0.01,n=10)");
        assert_eq!(MethodConfig::signsgd(1e-3).label(), "signSGD");
        assert_eq!(MethodConfig::terngrad().label(), "TernGrad");
        assert_eq!(MethodConfig::qsgd(4).label(), "QSGD(4)");
        assert_eq!(MethodConfig::onebit().label(), "1bitSGD");
    }

    #[test]
    fn residual_defaults_and_override() {
        assert!(MethodConfig::sbc1().use_residual());
        assert!(MethodConfig::gradient_dropping().use_residual());
        assert!(MethodConfig::onebit().use_residual());
        assert!(!MethodConfig::baseline().use_residual());
        assert!(!MethodConfig::signsgd(0.01).use_residual());
        assert!(!MethodConfig::terngrad().use_residual());
        assert!(!MethodConfig::qsgd(4).use_residual());
        let mut cfg = MethodConfig::sbc1();
        cfg.residual = Some(false);
        assert!(!cfg.use_residual());
    }

    #[test]
    fn builder_composes_novel_methods() {
        // top-p selection with QSGD-style values is NOT a paper method —
        // the builder rejects undefined pairings but accepts sparse+f32
        let cfg = MethodConfig::builder()
            .select(SelectorCfg::TopK { p: 0.01, strategy: Selection::Hist })
            .quantize(QuantizerCfg::F32)
            .delay(5)
            .build();
        assert_eq!(cfg.delay, 5);
        assert!(cfg.use_residual());
        assert!(!cfg.label().is_empty());
    }

    #[test]
    #[should_panic(expected = "dense quantizer")]
    fn builder_rejects_sparse_ternary() {
        MethodConfig::builder()
            .select(SelectorCfg::TopK { p: 0.01, strategy: Selection::Exact })
            .quantize(QuantizerCfg::Ternary)
            .build();
    }

    #[test]
    #[should_panic(expected = "sparse selector")]
    fn builder_rejects_dense_binary_mean() {
        MethodConfig::builder().quantize(QuantizerCfg::BinaryMean).build();
    }

    #[test]
    fn sign_scale_and_sbc_p() {
        assert_eq!(MethodConfig::signsgd(0.5).sign_scale(), 0.5);
        assert_eq!(MethodConfig::baseline().sign_scale(), 1.0);
        assert_eq!(MethodConfig::baseline().sbc_p(), None);
        assert_eq!(MethodConfig::sbc(0.02, 7).sbc_p(), Some(0.02));
    }
}
