//! Model metadata: tensor layouts and specs loaded from the AOT manifest.
//!
//! The Rust side never re-derives model structure; it reads exactly what
//! `python/compile/aot.py` exported, so L2 and L3 can never disagree about
//! shapes or flat-vector offsets.

pub mod manifest;

use std::ops::Range;

/// Named tensor segments of the flat parameter vector. Order matters: it
/// is the flat layout the L2 graphs use.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorLayout {
    tensors: Vec<(String, Vec<usize>)>,
    offsets: Vec<usize>,
    /// Total element count across all tensors (flat-vector length).
    pub total: usize,
}

impl TensorLayout {
    /// Build a layout from `(name, shape)` pairs in flat-vector order.
    pub fn new(tensors: Vec<(String, Vec<usize>)>) -> Self {
        let mut offsets = Vec::with_capacity(tensors.len() + 1);
        let mut off = 0;
        offsets.push(0);
        for (_, shape) in &tensors {
            off += shape.iter().product::<usize>();
            offsets.push(off);
        }
        TensorLayout { tensors, offsets, total: off }
    }

    /// A single-segment layout covering `n` elements (global granularity).
    pub fn flat(n: usize) -> Self {
        TensorLayout::new(vec![("flat".into(), vec![n])])
    }

    /// Number of tensors.
    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    /// Whether the layout has no tensors.
    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Name of tensor `i`.
    pub fn name(&self, i: usize) -> &str {
        &self.tensors[i].0
    }

    /// Shape of tensor `i`.
    pub fn shape(&self, i: usize) -> &[usize] {
        &self.tensors[i].1
    }

    /// Flat-vector range of tensor `i`.
    pub fn range(&self, i: usize) -> Range<usize> {
        self.offsets[i]..self.offsets[i + 1]
    }

    /// All tensor ranges in layout order.
    pub fn segments(&self) -> impl Iterator<Item = Range<usize>> + '_ {
        (0..self.len()).map(|i| self.range(i))
    }

    /// Which tensor a flat index belongs to (binary search).
    pub fn tensor_of(&self, flat_idx: usize) -> usize {
        debug_assert!(flat_idx < self.total);
        match self.offsets.binary_search(&flat_idx) {
            Ok(i) if i < self.len() => i,
            Ok(i) => i - 1,
            Err(i) => i - 1,
        }
    }
}

/// Everything the coordinator needs to know about one model.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    /// Model name (manifest key).
    pub name: String,
    /// Flat parameter count.
    pub n_params: usize,
    /// Flat optimizer-state length.
    pub opt_size: usize,
    /// Optimizer name ("sgd", "momentum", "adam").
    pub optimizer: String,
    /// Classification or language modeling.
    pub task: Task,
    /// Input tensor shape (leading dim = batch).
    pub x_shape: Vec<usize>,
    /// Input element type.
    pub x_dtype: Dtype,
    /// Label tensor shape.
    pub y_shape: Vec<usize>,
    /// Label element type.
    pub y_dtype: Dtype,
    /// Paper/Table-III default learning rate.
    pub default_lr: f32,
    /// Vocabulary size (LM models; 0 otherwise).
    pub vocab: usize,
    /// Class count (classifiers; 0 otherwise).
    pub classes: usize,
    /// Flat tensor layout shared with the L2 graphs.
    pub layout: TensorLayout,
    /// Artifact file names keyed by graph ("init", "step", "eval", "compress").
    pub graphs: std::collections::BTreeMap<String, String>,
}

/// What kind of task a model optimizes (decides the reported metric).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    /// Accuracy-metric classification.
    Classification,
    /// Perplexity-metric language modeling.
    Lm,
}

/// Element types the AOT graphs exchange.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    /// 32-bit float.
    F32,
    /// 32-bit signed integer (token ids, labels).
    I32,
}

impl ModelSpec {
    /// Batch size = leading dim of x.
    pub fn batch(&self) -> usize {
        self.x_shape[0]
    }

    /// Tokens (or samples) consumed per step.
    pub fn items_per_step(&self) -> usize {
        self.x_shape.iter().product::<usize>() / if self.task == Task::Lm { 1 } else { self.x_shape[1..].iter().product::<usize>().max(1) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_offsets() {
        let l = TensorLayout::new(vec![
            ("a".into(), vec![2, 3]),
            ("b".into(), vec![4]),
            ("c".into(), vec![1]),
        ]);
        assert_eq!(l.total, 11);
        assert_eq!(l.range(0), 0..6);
        assert_eq!(l.range(1), 6..10);
        assert_eq!(l.range(2), 10..11);
        assert_eq!(l.tensor_of(0), 0);
        assert_eq!(l.tensor_of(5), 0);
        assert_eq!(l.tensor_of(6), 1);
        assert_eq!(l.tensor_of(10), 2);
        let segs: Vec<_> = l.segments().collect();
        assert_eq!(segs.len(), 3);
    }

    #[test]
    fn flat_layout() {
        let l = TensorLayout::flat(100);
        assert_eq!(l.len(), 1);
        assert_eq!(l.total, 100);
        assert_eq!(l.range(0), 0..100);
    }
}
