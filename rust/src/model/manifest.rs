//! `artifacts/manifest.json` loader.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::formats::json::Json;
use crate::model::{Dtype, ModelSpec, Task, TensorLayout};

/// All models exported by the AOT step.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Artifact directory the manifest was loaded from.
    pub dir: String,
    /// Model specs keyed by model name.
    pub models: BTreeMap<String, ModelSpec>,
}

impl Manifest {
    /// Read and parse `<dir>/manifest.json`.
    pub fn load(dir: &str) -> Result<Manifest> {
        let path = Path::new(dir).join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        Self::from_json(dir, &json)
    }

    /// Build a manifest from already-parsed JSON (tests, embedding).
    pub fn from_json(dir: &str, json: &Json) -> Result<Manifest> {
        let models_json =
            json.get("models").and_then(Json::as_obj).ok_or_else(|| anyhow!("no models key"))?;
        let mut models = BTreeMap::new();
        for (name, m) in models_json {
            models.insert(name.clone(), parse_model(name, m)?);
        }
        Ok(Manifest { dir: dir.to_string(), models })
    }

    /// Look up one model's spec by name.
    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models.get(name).ok_or_else(|| {
            anyhow!("model '{name}' not in manifest (have: {:?})", self.models.keys().collect::<Vec<_>>())
        })
    }

    /// Absolute path of one graph artifact.
    pub fn graph_path(&self, model: &str, graph: &str) -> Result<String> {
        let spec = self.model(model)?;
        let file = spec
            .graphs
            .get(graph)
            .ok_or_else(|| anyhow!("model '{model}' has no '{graph}' graph"))?;
        Ok(Path::new(&self.dir).join(file).to_string_lossy().into_owned())
    }
}

fn parse_dtype(s: &str) -> Result<Dtype> {
    match s {
        "f32" => Ok(Dtype::F32),
        "i32" => Ok(Dtype::I32),
        other => bail!("unknown dtype {other}"),
    }
}

fn usize_arr(j: &Json) -> Vec<usize> {
    j.as_arr().map(|a| a.iter().filter_map(Json::as_usize).collect()).unwrap_or_default()
}

fn parse_model(name: &str, m: &Json) -> Result<ModelSpec> {
    let get = |k: &str| m.get(k).ok_or_else(|| anyhow!("model {name}: missing {k}"));
    let tensors = get("tensors")?
        .as_arr()
        .ok_or_else(|| anyhow!("tensors not array"))?
        .iter()
        .map(|t| {
            let tname = t.get("name").and_then(Json::as_str).unwrap_or("?").to_string();
            let shape = usize_arr(t.get("shape").unwrap_or(&Json::Null));
            (tname, shape)
        })
        .collect::<Vec<_>>();
    let layout = TensorLayout::new(tensors);
    let n_params = get("n_params")?.as_usize().unwrap_or(0);
    if layout.total != n_params {
        bail!("model {name}: layout total {} != n_params {}", layout.total, n_params);
    }
    let meta = m.get("meta");
    let meta_f = |k: &str| meta.and_then(|mm| mm.get(k)).and_then(Json::as_f64);
    let graphs = get("graphs")?
        .as_obj()
        .ok_or_else(|| anyhow!("graphs not object"))?
        .iter()
        .map(|(k, v)| (k.clone(), v.as_str().unwrap_or("").to_string()))
        .collect();
    Ok(ModelSpec {
        name: name.to_string(),
        n_params,
        opt_size: get("opt_size")?.as_usize().unwrap_or(0),
        optimizer: get("optimizer")?.as_str().unwrap_or("sgd").to_string(),
        task: match get("task")?.as_str() {
            Some("lm") => Task::Lm,
            _ => Task::Classification,
        },
        x_shape: usize_arr(get("x_shape")?),
        x_dtype: parse_dtype(get("x_dtype")?.as_str().unwrap_or("f32"))?,
        y_shape: usize_arr(get("y_shape")?),
        y_dtype: parse_dtype(get("y_dtype")?.as_str().unwrap_or("i32"))?,
        default_lr: meta_f("default_lr").unwrap_or(0.01) as f32,
        vocab: meta_f("vocab").unwrap_or(0.0) as usize,
        classes: meta_f("classes").unwrap_or(0.0) as usize,
        layout,
        graphs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1,
      "models": {
        "mlp": {
          "n_params": 10,
          "opt_size": 10,
          "optimizer": "momentum",
          "task": "classification",
          "x_shape": [4, 2],
          "x_dtype": "f32",
          "y_shape": [4],
          "y_dtype": "i32",
          "meta": {"classes": 10, "default_lr": 0.1},
          "tensors": [
            {"name": "w", "shape": [2, 4]},
            {"name": "b", "shape": [2]}
          ],
          "graphs": {"init": "mlp.init.hlo.txt", "step": "mlp.step.hlo.txt"}
        }
      }
    }"#;

    #[test]
    fn parse_sample() {
        let json = Json::parse(SAMPLE).unwrap();
        let m = Manifest::from_json("/tmp/a", &json).unwrap();
        let spec = m.model("mlp").unwrap();
        assert_eq!(spec.n_params, 10);
        assert_eq!(spec.layout.len(), 2);
        assert_eq!(spec.layout.range(1), 8..10);
        assert_eq!(spec.default_lr, 0.1);
        assert_eq!(spec.task, Task::Classification);
        assert_eq!(spec.batch(), 4);
        assert!(m.graph_path("mlp", "step").unwrap().ends_with("mlp.step.hlo.txt"));
        assert!(m.graph_path("mlp", "compress").is_err());
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn rejects_mismatched_layout() {
        let bad = SAMPLE.replace("\"n_params\": 10", "\"n_params\": 11");
        let json = Json::parse(&bad).unwrap();
        assert!(Manifest::from_json("/tmp/a", &json).is_err());
    }
}
