//! Structured-event observability for the federation stack.
//!
//! The repo's accounting ([`crate::codec::accounting::CommStats`],
//! [`crate::netsim::NetSim`], [`crate::metrics::RunLog`]) answers "what
//! did the run cost" only *after* it finishes. This module answers "what
//! is the run doing" *while* it runs: a lightweight, std-only
//! structured-event subsystem threaded through the coordinator round
//! loop, the compression pipeline's stage boundaries, the federation
//! transport (`transport::{session,server}`), and the deterministic
//! simulator (`simnet`).
//!
//! The pieces:
//!
//! - [`Event`] — the closed event taxonomy (round lifecycle, per-stage
//!   timings, frame byte counts, connect/retry, fault injections keyed
//!   by their replay-stable RNG key).
//! - [`Recorder`] — the sink trait. [`NullRecorder`] (the default) is a
//!   compiled-out no-op; [`JsonlRecorder`] appends one JSON line per
//!   event; [`RingRecorder`] keeps the last N events in memory for tests
//!   and programmatic inspection.
//! - [`Trace`] — the cheap cloneable handle call sites hold
//!   ([`crate::coordinator::trainer::TrainConfig::trace`]). Its
//!   [`Trace::emit`] stamps each event with a monotonic timestamp from
//!   the caller's [`Clock`], so the same recorder works under
//!   [`crate::simnet::RealClock`] and [`crate::simnet::SimClock`].
//! - [`StageProfile`] — p50/p95/max aggregation of [`Event::Stage`]
//!   timings, exposed on
//!   [`crate::coordinator::trainer::TrainResult::stage_profile`] and
//!   rendered as a table at end of run (`sbc-train --trace`).
//!
//! # Determinism invariant
//!
//! Tracing is **provably inert**: weight digests are bit-identical with
//! tracing on or off at any `parallelism`, and with the default
//! [`NullRecorder`] every call site reduces to one branch on
//! [`Recorder::enabled`] — no event is constructed, no clock is read, no
//! allocation happens (pinned by the alloc counters in
//! `benches/hotpath.rs` and by `rust/tests/trace.rs`). Events produced
//! by pool workers are buffered per client and funneled back in
//! client-index order, so a traced pooled run emits the same
//! client-major event order as a serial run.

use std::collections::{BTreeMap, VecDeque};
use std::fmt::Write as _;
use std::io::Write as _;
use std::sync::{Arc, Mutex};

use crate::simnet::clock::Clock;

/// Sentinel client id for server-side (non-per-client) [`Event::Stage`]
/// observations.
pub const SERVER: u32 = u32::MAX;

/// One structured observation from the training/federation stack.
///
/// String fields use a small closed vocabulary (stage names match the
/// `util::timer` span names; `dir` is `"up"`/`"down"`; `role` is
/// `"server"` or `"client"`) but are carried as `String` so traces
/// round-trip through [`Event::from_jsonl`].
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// A communication round began on the aggregating side.
    RoundStart {
        /// Round index.
        round: u32,
    },
    /// A named stage of the round completed.
    Stage {
        /// Round index.
        round: u32,
        /// Client the stage ran for, or [`SERVER`] for server-side
        /// stages (aggregate, encode_down, evaluate).
        client: u32,
        /// Stage name — same vocabulary as the `util::timer` spans
        /// (`local_steps`, `compress`, `select`, `quantize`, `encode`,
        /// `decode`, `densify`, `aggregate`, `encode_down`, `evaluate`).
        stage: String,
        /// Stage duration in nanoseconds.
        nanos: u64,
    },
    /// A framed message was accounted, with its exact wire bit counts.
    ///
    /// Emitted once per *accepted* message (retries emit [`Event::Retry`]
    /// instead), on the side named by `role` — so summing the events of
    /// one role reconciles exactly with that side's
    /// [`CommStats`](crate::codec::accounting::CommStats) /
    /// [`NetSim`](crate::netsim::NetSim) totals.
    Frame {
        /// Which side accounted the frame: `"server"` (the coordinator /
        /// federated server) or `"client"` (a transport session).
        role: String,
        /// Direction over the wire: `"up"` (client→server) or `"down"`.
        dir: String,
        /// Frame kind label: `"update"`, `"broadcast"`, `"hello"`,
        /// `"helloack"`, `"done"`.
        kind: String,
        /// Client the frame belongs to.
        client: u32,
        /// Round the frame belongs to.
        round: u32,
        /// Exact payload bits (the compressed message).
        payload_bits: u64,
        /// Framing overhead bits for this payload
        /// ([`crate::transport::frame::overhead_bits`]).
        overhead_bits: u64,
    },
    /// A transport session completed its connect + handshake.
    Connect {
        /// Client id.
        client: u32,
        /// Connection attempt index (0 = first connect).
        attempt: u32,
    },
    /// A retryable transport error scheduled a reconnect backoff.
    Retry {
        /// Client id.
        client: u32,
        /// Attempt that failed (0-based).
        attempt: u32,
        /// Backoff that will be slept before the next attempt, ns.
        backoff_ns: u64,
        /// Display of the retryable error.
        error: String,
    },
    /// Round finished on the aggregating side: aggregate applied,
    /// broadcast encoded.
    RoundEnd {
        /// Round index.
        round: u32,
        /// Mean train loss across clients this round.
        train_loss: f32,
        /// Total upstream payload bits this round (all clients).
        up_bits: u64,
        /// Broadcast payload bits this round.
        down_bits: u64,
    },
    /// An evaluation point.
    Eval {
        /// Round index.
        round: u32,
        /// Held-out loss.
        loss: f32,
        /// Task metric (accuracy or perplexity).
        metric: f32,
    },
    /// A seeded fault-injection decision in the deterministic simulator,
    /// annotated with the full RNG key `(seed, client, attempt, seq,
    /// dir)` that makes it replay-stable — the same key the schedule's
    /// [`AppliedFault`](crate::simnet::fault::AppliedFault) records.
    Fault {
        /// Simulation seed.
        seed: u64,
        /// Client id (RNG key).
        client: u32,
        /// Connection attempt (RNG key).
        attempt: u32,
        /// Per-connection frame sequence number (RNG key).
        seq: u64,
        /// Direction: `"up"` or `"down"` (RNG key).
        dir: String,
        /// Display of the injected
        /// [`FaultAction`](crate::simnet::fault::FaultAction).
        action: String,
    },
    /// A durable checkpoint generation was persisted at a round barrier
    /// ([`crate::persist`]).
    Snapshot {
        /// Which process snapshotted: `"trainer"` (in-process run),
        /// `"server"` or `"client"`.
        role: String,
        /// Client id, or [`SERVER`] for the server/trainer side.
        client: u32,
        /// The round barrier the snapshot represents (the next round the
        /// restored state would run).
        round: u32,
        /// Size of the persisted server-snapshot file in bytes.
        bytes: u64,
    },
    /// State was restored from a checkpoint at process start.
    Restore {
        /// `"trainer"`, `"server"` or `"client"`.
        role: String,
        /// Client id, or [`SERVER`] for the server/trainer side.
        client: u32,
        /// The round barrier restored to.
        round: u32,
    },
    /// A client was re-admitted at the server's resume round through the
    /// extended Hello/HelloAck handshake.
    Resume {
        /// Client id.
        client: u32,
        /// The round the handshake resumed at.
        round: u32,
    },
}

// ---------------------------------------------------------------------
// JSONL serialization (hand-rolled: the dependency set is std-only)
// ---------------------------------------------------------------------

fn esc(s: &str, out: &mut String) {
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
}

fn unesc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut it = s.chars();
    while let Some(c) = it.next() {
        if c == '\\' {
            match it.next() {
                Some('n') => out.push('\n'),
                Some(other) => out.push(other),
                None => {}
            }
        } else {
            out.push(c);
        }
    }
    out
}

/// Raw text of `"key":<value>` in `line`, or `None` if absent.
fn raw_field<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{key}\":");
    let at = line.find(&pat)? + pat.len();
    let rest = &line[at..];
    if let Some(stripped) = rest.strip_prefix('"') {
        // string value: scan to the closing unescaped quote
        let mut end = 0;
        let bytes = stripped.as_bytes();
        while end < bytes.len() {
            match bytes[end] {
                b'\\' => end += 2,
                b'"' => return Some(&stripped[..end]),
                _ => end += 1,
            }
        }
        None
    } else {
        let end = rest.find([',', '}'])?;
        Some(&rest[..end])
    }
}

fn str_field(line: &str, key: &str) -> Option<String> {
    raw_field(line, key).map(unesc)
}

fn u64_field(line: &str, key: &str) -> Option<u64> {
    raw_field(line, key)?.trim().parse().ok()
}

fn u32_field(line: &str, key: &str) -> Option<u32> {
    raw_field(line, key)?.trim().parse().ok()
}

fn f32_field(line: &str, key: &str) -> Option<f32> {
    raw_field(line, key)?.trim().parse().ok()
}

impl Event {
    /// Serialize as one JSON line (no trailing newline), with the event
    /// timestamp `t_ns` as the first field. Floats use Rust's
    /// shortest-roundtrip formatting, so [`Event::from_jsonl`] parses the
    /// exact value back.
    pub fn to_jsonl(&self, t_ns: u64) -> String {
        let mut s = String::with_capacity(96);
        let _ = write!(s, "{{\"t_ns\":{t_ns},\"ev\":");
        match self {
            Event::RoundStart { round } => {
                let _ = write!(s, "\"round_start\",\"round\":{round}");
            }
            Event::Stage { round, client, stage, nanos } => {
                let _ = write!(s, "\"stage\",\"round\":{round},\"client\":{client},\"stage\":\"");
                esc(stage, &mut s);
                let _ = write!(s, "\",\"nanos\":{nanos}");
            }
            Event::Frame { role, dir, kind, client, round, payload_bits, overhead_bits } => {
                let _ = write!(s, "\"frame\",\"role\":\"");
                esc(role, &mut s);
                let _ = write!(s, "\",\"dir\":\"");
                esc(dir, &mut s);
                let _ = write!(s, "\",\"kind\":\"");
                esc(kind, &mut s);
                let _ = write!(
                    s,
                    "\",\"client\":{client},\"round\":{round},\"payload_bits\":{payload_bits},\
                     \"overhead_bits\":{overhead_bits}"
                );
            }
            Event::Connect { client, attempt } => {
                let _ = write!(s, "\"connect\",\"client\":{client},\"attempt\":{attempt}");
            }
            Event::Retry { client, attempt, backoff_ns, error } => {
                let _ = write!(
                    s,
                    "\"retry\",\"client\":{client},\"attempt\":{attempt},\
                     \"backoff_ns\":{backoff_ns},\"error\":\""
                );
                esc(error, &mut s);
                s.push('"');
            }
            Event::RoundEnd { round, train_loss, up_bits, down_bits } => {
                let _ = write!(
                    s,
                    "\"round_end\",\"round\":{round},\"train_loss\":{train_loss},\
                     \"up_bits\":{up_bits},\"down_bits\":{down_bits}"
                );
            }
            Event::Eval { round, loss, metric } => {
                let _ =
                    write!(s, "\"eval\",\"round\":{round},\"loss\":{loss},\"metric\":{metric}");
            }
            Event::Fault { seed, client, attempt, seq, dir, action } => {
                let _ = write!(
                    s,
                    "\"fault\",\"seed\":{seed},\"client\":{client},\"attempt\":{attempt},\
                     \"seq\":{seq},\"dir\":\""
                );
                esc(dir, &mut s);
                let _ = write!(s, "\",\"action\":\"");
                esc(action, &mut s);
                s.push('"');
            }
            Event::Snapshot { role, client, round, bytes } => {
                let _ = write!(s, "\"snapshot\",\"role\":\"");
                esc(role, &mut s);
                let _ = write!(s, "\",\"client\":{client},\"round\":{round},\"bytes\":{bytes}");
            }
            Event::Restore { role, client, round } => {
                let _ = write!(s, "\"restore\",\"role\":\"");
                esc(role, &mut s);
                let _ = write!(s, "\",\"client\":{client},\"round\":{round}");
            }
            Event::Resume { client, round } => {
                let _ = write!(s, "\"resume\",\"client\":{client},\"round\":{round}");
            }
        }
        s.push('}');
        s
    }

    /// Parse one line produced by [`Event::to_jsonl`] back into
    /// `(t_ns, Event)`. Returns `None` for malformed or unknown lines
    /// (forward compatibility: readers skip what they don't know).
    pub fn from_jsonl(line: &str) -> Option<(u64, Event)> {
        let t_ns = u64_field(line, "t_ns")?;
        let ev = match str_field(line, "ev")?.as_str() {
            "round_start" => Event::RoundStart { round: u32_field(line, "round")? },
            "stage" => Event::Stage {
                round: u32_field(line, "round")?,
                client: u32_field(line, "client")?,
                stage: str_field(line, "stage")?,
                nanos: u64_field(line, "nanos")?,
            },
            "frame" => Event::Frame {
                role: str_field(line, "role")?,
                dir: str_field(line, "dir")?,
                kind: str_field(line, "kind")?,
                client: u32_field(line, "client")?,
                round: u32_field(line, "round")?,
                payload_bits: u64_field(line, "payload_bits")?,
                overhead_bits: u64_field(line, "overhead_bits")?,
            },
            "connect" => Event::Connect {
                client: u32_field(line, "client")?,
                attempt: u32_field(line, "attempt")?,
            },
            "retry" => Event::Retry {
                client: u32_field(line, "client")?,
                attempt: u32_field(line, "attempt")?,
                backoff_ns: u64_field(line, "backoff_ns")?,
                error: str_field(line, "error")?,
            },
            "round_end" => Event::RoundEnd {
                round: u32_field(line, "round")?,
                train_loss: f32_field(line, "train_loss")?,
                up_bits: u64_field(line, "up_bits")?,
                down_bits: u64_field(line, "down_bits")?,
            },
            "eval" => Event::Eval {
                round: u32_field(line, "round")?,
                loss: f32_field(line, "loss")?,
                metric: f32_field(line, "metric")?,
            },
            "fault" => Event::Fault {
                seed: u64_field(line, "seed")?,
                client: u32_field(line, "client")?,
                attempt: u32_field(line, "attempt")?,
                seq: u64_field(line, "seq")?,
                dir: str_field(line, "dir")?,
                action: str_field(line, "action")?,
            },
            "snapshot" => Event::Snapshot {
                role: str_field(line, "role")?,
                client: u32_field(line, "client")?,
                round: u32_field(line, "round")?,
                bytes: u64_field(line, "bytes")?,
            },
            "restore" => Event::Restore {
                role: str_field(line, "role")?,
                client: u32_field(line, "client")?,
                round: u32_field(line, "round")?,
            },
            "resume" => Event::Resume {
                client: u32_field(line, "client")?,
                round: u32_field(line, "round")?,
            },
            _ => return None,
        };
        Some((t_ns, ev))
    }
}

// ---------------------------------------------------------------------
// Recorders
// ---------------------------------------------------------------------

/// An event sink. Implementations must be `Send + Sync`: one recorder is
/// shared by the coordinator, its pool workers, and (in federated runs)
/// the server plus every client session thread.
pub trait Recorder: Send + Sync {
    /// Whether events should be constructed at all. Call sites guard on
    /// this *before* building an [`Event`] or reading a clock, which is
    /// what makes the [`NullRecorder`] path allocation-free.
    fn enabled(&self) -> bool {
        true
    }

    /// Record one event with its clock timestamp (nanoseconds since the
    /// recording clock's epoch).
    fn record(&self, t_ns: u64, event: Event);

    /// Flush any buffered output (no-op for in-memory recorders).
    fn flush(&self) {}
}

/// The default sink: records nothing. [`Recorder::enabled`] returns
/// `false`, so guarded call sites skip event construction entirely — the
/// hot path stays allocation-free (pinned by `benches/hotpath.rs`).
#[derive(Clone, Copy, Debug, Default)]
pub struct NullRecorder;

impl Recorder for NullRecorder {
    fn enabled(&self) -> bool {
        false
    }

    fn record(&self, _t_ns: u64, _event: Event) {}
}

/// Appends one JSON line per event to a file (see [`Event::to_jsonl`]).
/// Writes are buffered; call [`Recorder::flush`] (or drop the recorder)
/// before reading the file back.
pub struct JsonlRecorder {
    w: Mutex<std::io::BufWriter<std::fs::File>>,
}

impl JsonlRecorder {
    /// Create (truncate) `path` and record into it.
    pub fn create(path: &std::path::Path) -> std::io::Result<JsonlRecorder> {
        let f = std::fs::File::create(path)?;
        Ok(JsonlRecorder { w: Mutex::new(std::io::BufWriter::new(f)) })
    }

    /// Open `path` for appending (shared by every run in a process, e.g.
    /// under the `SBC_TRACE=jsonl` test-suite sweep).
    pub fn append(path: &std::path::Path) -> std::io::Result<JsonlRecorder> {
        let f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        Ok(JsonlRecorder { w: Mutex::new(std::io::BufWriter::new(f)) })
    }
}

impl Recorder for JsonlRecorder {
    fn record(&self, t_ns: u64, event: Event) {
        let line = event.to_jsonl(t_ns);
        let mut w = self.w.lock().unwrap_or_else(|p| p.into_inner());
        let _ = w.write_all(line.as_bytes());
        let _ = w.write_all(b"\n");
    }

    fn flush(&self) {
        let mut w = self.w.lock().unwrap_or_else(|p| p.into_inner());
        let _ = w.flush();
        // push the bytes to disk, not just to the OS: a process killed
        // right after a snapshot barrier must leave a readable trace up
        // to and including the Snapshot event
        let _ = w.get_ref().sync_data();
    }
}

impl Drop for JsonlRecorder {
    fn drop(&mut self) {
        self.flush();
    }
}

/// Keeps the most recent `cap` events in memory — the programmatic sink
/// for tests and live inspection.
pub struct RingRecorder {
    cap: usize,
    buf: Mutex<VecDeque<(u64, Event)>>,
}

impl RingRecorder {
    /// A ring holding at most `cap` events (oldest evicted first).
    pub fn new(cap: usize) -> RingRecorder {
        RingRecorder { cap: cap.max(1), buf: Mutex::new(VecDeque::new()) }
    }

    /// Snapshot of the buffered `(t_ns, event)` pairs, oldest first.
    pub fn events(&self) -> Vec<(u64, Event)> {
        self.buf.lock().unwrap_or_else(|p| p.into_inner()).iter().cloned().collect()
    }

    /// Number of events currently buffered.
    pub fn len(&self) -> usize {
        self.buf.lock().unwrap_or_else(|p| p.into_inner()).len()
    }

    /// Whether no events have been recorded (or all were evicted).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Recorder for RingRecorder {
    fn record(&self, t_ns: u64, event: Event) {
        let mut buf = self.buf.lock().unwrap_or_else(|p| p.into_inner());
        if buf.len() == self.cap {
            buf.pop_front();
        }
        buf.push_back((t_ns, event));
    }
}

// ---------------------------------------------------------------------
// The handle call sites hold
// ---------------------------------------------------------------------

/// Cheap cloneable handle to a [`Recorder`], carried by
/// [`crate::coordinator::trainer::TrainConfig::trace`] into every layer.
/// The default ([`Trace::disabled`]) wraps a [`NullRecorder`].
#[derive(Clone)]
pub struct Trace {
    rec: Arc<dyn Recorder>,
}

impl std::fmt::Debug for Trace {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Trace").field("enabled", &self.enabled()).finish()
    }
}

impl Default for Trace {
    fn default() -> Self {
        Trace::disabled()
    }
}

impl Trace {
    /// The inert default: every emit is a single `false` branch.
    pub fn disabled() -> Trace {
        Trace { rec: Arc::new(NullRecorder) }
    }

    /// Trace into an arbitrary recorder.
    pub fn with(rec: Arc<dyn Recorder>) -> Trace {
        Trace { rec }
    }

    /// Trace into a fresh JSONL file at `path` (truncating).
    pub fn jsonl(path: &std::path::Path) -> std::io::Result<Trace> {
        Ok(Trace { rec: Arc::new(JsonlRecorder::create(path)?) })
    }

    /// Trace into an in-memory ring of `cap` events; returns the handle
    /// plus the recorder for later inspection.
    pub fn ring(cap: usize) -> (Trace, Arc<RingRecorder>) {
        let rec = Arc::new(RingRecorder::new(cap));
        (Trace { rec: rec.clone() }, rec)
    }

    /// Build from the `SBC_TRACE` environment variable: unset/empty →
    /// disabled; `jsonl` → append to `sbc-trace-<pid>.jsonl` in the OS
    /// temp dir; `jsonl:<path>` → append to `<path>`. Used by
    /// `TrainConfig::new` so a whole test-suite run can be swept under
    /// tracing (`SBC_TRACE=jsonl cargo test`) to prove inertness.
    /// Falls back to disabled if the file cannot be opened.
    pub fn from_env() -> Trace {
        let Ok(v) = std::env::var("SBC_TRACE") else { return Trace::disabled() };
        let path = match v.as_str() {
            "" => return Trace::disabled(),
            "jsonl" => {
                std::env::temp_dir().join(format!("sbc-trace-{}.jsonl", std::process::id()))
            }
            other => match other.strip_prefix("jsonl:") {
                Some(p) => std::path::PathBuf::from(p),
                None => return Trace::disabled(),
            },
        };
        match JsonlRecorder::append(&path) {
            Ok(rec) => Trace { rec: Arc::new(rec) },
            Err(_) => Trace::disabled(),
        }
    }

    /// Whether emits reach a real sink. Guard any non-trivial event
    /// preparation (buffers, string building) on this.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.rec.enabled()
    }

    /// Emit an event stamped with `clock.now()`. The closure runs only
    /// when the recorder is enabled, so disabled tracing constructs
    /// nothing and reads no clock.
    #[inline]
    pub fn emit<F: FnOnce() -> Event>(&self, clock: &dyn Clock, f: F) {
        if self.rec.enabled() {
            self.rec.record(clock.now().as_nanos() as u64, f());
        }
    }

    /// Emit an event with a caller-supplied timestamp (used when
    /// funneling buffered pool-worker events in client order).
    #[inline]
    pub fn emit_at<F: FnOnce() -> Event>(&self, t_ns: u64, f: F) {
        if self.rec.enabled() {
            self.rec.record(t_ns, f());
        }
    }

    /// Flush the underlying recorder.
    pub fn flush(&self) {
        self.rec.flush();
    }
}

// ---------------------------------------------------------------------
// Stage profiling
// ---------------------------------------------------------------------

/// Timing summary for one stage across a run.
#[derive(Clone, Debug, PartialEq)]
pub struct StageStats {
    /// Stage name (see [`Event::Stage`]).
    pub stage: String,
    /// Number of observations.
    pub count: u64,
    /// Median observation, nanoseconds.
    pub p50_ns: u64,
    /// 95th-percentile observation, nanoseconds.
    pub p95_ns: u64,
    /// Largest observation, nanoseconds.
    pub max_ns: u64,
    /// Sum of all observations, nanoseconds.
    pub total_ns: u64,
}

/// Per-stage p50/p95/max timing profile of a traced run, aggregated from
/// [`Event::Stage`] observations and exposed on
/// [`crate::coordinator::trainer::TrainResult::stage_profile`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StageProfile {
    /// Communication rounds the profile covers.
    pub rounds: u32,
    /// One summary per observed stage, in first-observation order.
    pub stages: Vec<StageStats>,
}

impl StageProfile {
    /// Render the profile with [`crate::metrics::render_table`]
    /// (millisecond columns; `ms/round` divides by [`StageProfile::rounds`]).
    pub fn render_table(&self) -> String {
        let ms = |ns: u64| format!("{:.3}", ns as f64 / 1e6);
        let rows: Vec<Vec<String>> = self
            .stages
            .iter()
            .map(|s| {
                vec![
                    s.stage.clone(),
                    s.count.to_string(),
                    ms(s.p50_ns),
                    ms(s.p95_ns),
                    ms(s.max_ns),
                    format!("{:.3}", s.total_ns as f64 / 1e6 / self.rounds.max(1) as f64),
                ]
            })
            .collect();
        crate::metrics::render_table(
            &["stage", "count", "p50 ms", "p95 ms", "max ms", "ms/round"],
            &rows,
        )
    }
}

/// Accumulates [`Event::Stage`] observations into a [`StageProfile`].
#[derive(Debug, Default)]
pub struct StageProfileBuilder {
    order: Vec<String>,
    samples: BTreeMap<String, Vec<u64>>,
}

impl StageProfileBuilder {
    /// An empty builder.
    pub fn new() -> StageProfileBuilder {
        StageProfileBuilder::default()
    }

    /// Record one observation of `stage` taking `nanos`.
    pub fn observe(&mut self, stage: &str, nanos: u64) {
        if !self.samples.contains_key(stage) {
            self.order.push(stage.to_string());
        }
        self.samples.entry(stage.to_string()).or_default().push(nanos);
    }

    /// Finalize into a [`StageProfile`] covering `rounds` rounds.
    pub fn finish(self, rounds: u32) -> StageProfile {
        let pct = |sorted: &[u64], q: usize| sorted[(sorted.len() - 1) * q / 100];
        let stages = self
            .order
            .iter()
            .map(|name| {
                let mut xs = self.samples[name].clone();
                xs.sort_unstable();
                StageStats {
                    stage: name.clone(),
                    count: xs.len() as u64,
                    p50_ns: pct(&xs, 50),
                    p95_ns: pct(&xs, 95),
                    max_ns: *xs.last().unwrap(),
                    total_ns: xs.iter().sum(),
                }
            })
            .collect();
        StageProfile { rounds, stages }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::clock::{Clock, SimClock};

    fn sample_events() -> Vec<Event> {
        vec![
            Event::RoundStart { round: 3 },
            Event::Stage { round: 3, client: 1, stage: "compress".into(), nanos: 12_345 },
            Event::Stage { round: 3, client: SERVER, stage: "aggregate".into(), nanos: 99 },
            Event::Frame {
                role: "server".into(),
                dir: "up".into(),
                kind: "update".into(),
                client: 2,
                round: 3,
                payload_bits: 12_007,
                overhead_bits: 217,
            },
            Event::Connect { client: 0, attempt: 2 },
            Event::Retry {
                client: 1,
                attempt: 0,
                backoff_ns: 50_000_000,
                error: "io: connection \"refused\"\nretrying".into(),
            },
            Event::RoundEnd { round: 3, train_loss: 0.125, up_bits: 48_028, down_bits: 4_096 },
            Event::Eval { round: 3, loss: f32::NAN, metric: 0.875 },
            Event::Fault {
                seed: 77,
                client: 3,
                attempt: 1,
                seq: 42,
                dir: "down".into(),
                action: "delay(700ms)".into(),
            },
            Event::Snapshot { role: "server".into(), client: SERVER, round: 7, bytes: 78_212 },
            Event::Restore { role: "client".into(), client: 2, round: 7 },
            Event::Resume { client: 2, round: 7 },
        ]
    }

    #[test]
    fn jsonl_roundtrips_every_variant() {
        for (i, ev) in sample_events().into_iter().enumerate() {
            let line = ev.to_jsonl(1_000 + i as u64);
            let (t, back) = Event::from_jsonl(&line).unwrap_or_else(|| panic!("parse: {line}"));
            assert_eq!(t, 1_000 + i as u64, "{line}");
            // NaN != NaN: compare through re-serialization for the Eval case
            assert_eq!(back.to_jsonl(t), line);
            if !matches!(ev, Event::Eval { .. }) {
                assert_eq!(back, ev, "{line}");
            }
        }
    }

    #[test]
    fn from_jsonl_rejects_garbage_and_unknown_events() {
        assert!(Event::from_jsonl("").is_none());
        assert!(Event::from_jsonl("not json at all").is_none());
        assert!(Event::from_jsonl("{\"t_ns\":5,\"ev\":\"warp_drive\",\"round\":1}").is_none());
        // missing required field
        assert!(Event::from_jsonl("{\"t_ns\":5,\"ev\":\"round_start\"}").is_none());
    }

    #[test]
    fn null_recorder_is_disabled_and_skips_event_construction() {
        let trace = Trace::disabled();
        assert!(!trace.enabled());
        let clock = SimClock::new();
        let mut built = false;
        trace.emit(&clock, || {
            built = true;
            Event::RoundStart { round: 0 }
        });
        trace.emit_at(7, || {
            built = true;
            Event::RoundStart { round: 0 }
        });
        assert!(!built, "disabled trace must not construct events");
    }

    #[test]
    fn ring_recorder_caps_and_orders() {
        let (trace, ring) = Trace::ring(3);
        assert!(trace.enabled());
        assert!(ring.is_empty());
        for r in 0..5u32 {
            trace.emit_at(r as u64, || Event::RoundStart { round: r });
        }
        let evs = ring.events();
        assert_eq!(ring.len(), 3);
        assert_eq!(
            evs,
            vec![
                (2, Event::RoundStart { round: 2 }),
                (3, Event::RoundStart { round: 3 }),
                (4, Event::RoundStart { round: 4 }),
            ]
        );
    }

    #[test]
    fn emit_stamps_clock_time() {
        let clock = SimClock::new();
        let _me = clock.actor();
        clock.sleep(std::time::Duration::from_millis(5));
        let (trace, ring) = Trace::ring(8);
        trace.emit(&clock, || Event::RoundStart { round: 1 });
        assert_eq!(ring.events(), vec![(5_000_000, Event::RoundStart { round: 1 })]);
    }

    #[test]
    fn stage_profile_percentiles_and_render() {
        let mut b = StageProfileBuilder::new();
        for ns in 1..=100u64 {
            b.observe("compress", ns);
        }
        b.observe("encode", 7);
        let p = b.finish(10);
        assert_eq!(p.stages.len(), 2);
        let c = &p.stages[0];
        assert_eq!((c.stage.as_str(), c.count), ("compress", 100));
        assert_eq!((c.p50_ns, c.p95_ns, c.max_ns), (50, 95, 100));
        assert_eq!(c.total_ns, 5050);
        let e = &p.stages[1];
        assert_eq!((e.stage.as_str(), e.count, e.max_ns), ("encode", 1, 7));
        let table = p.render_table();
        assert!(table.contains("compress"), "{table}");
        assert!(table.contains("p95 ms"), "{table}");
        assert_eq!(table.lines().count(), 4, "{table}");
    }
}
