//! `sbc-lint` — the repo's own static analyzer (see
//! `ARCHITECTURE.md` §9 and [`sbc::analysis`]).
//!
//! ```text
//! sbc-lint [--root DIR] [--json]
//! ```
//!
//! Walks `DIR` (default `rust/src`) and prints one diagnostic per line
//! as `file:line rule message`, or a JSON array with `--json`. Exit
//! codes: 0 clean, 1 findings, 2 usage or I/O error.

use std::path::PathBuf;
use std::process::ExitCode;

use sbc::analysis::{lint_tree, render_json, render_text};

const USAGE: &str = "usage: sbc-lint [--root DIR] [--json]";

fn main() -> ExitCode {
    let mut root = PathBuf::from("rust/src");
    let mut json = false;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--json" => json = true,
            "--root" => match args.next() {
                Some(dir) => root = PathBuf::from(dir),
                None => {
                    eprintln!("sbc-lint: --root needs a directory\n{USAGE}");
                    return ExitCode::from(2);
                }
            },
            "--help" | "-h" => {
                println!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("sbc-lint: unknown argument `{other}`\n{USAGE}");
                return ExitCode::from(2);
            }
        }
    }
    let findings = match lint_tree(&root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("sbc-lint: {e}");
            return ExitCode::from(2);
        }
    };
    if json {
        print!("{}", render_json(&findings));
    } else {
        print!("{}", render_text(&findings));
        eprintln!("sbc-lint: {} finding(s) in {}", findings.len(), root.display());
    }
    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}
