//! Pure-Rust training substrate: flat-vector optimizers and a small MLP
//! with hand-written backprop.
//!
//! This backend exists for two reasons: (a) the grid experiments (paper
//! Fig. 3/4/9) run *hundreds* of complete distributed trainings — far more
//! than the PJRT path needs to prove; a native f32 MLP makes those sweeps
//! cheap; (b) it lets the whole coordinator stack (rounds, residuals,
//! codecs, aggregation) be unit/property-tested without artifacts.

pub mod mlp;
pub mod optimizer;

pub use mlp::NativeMlpBackend;
pub use optimizer::Optimizer;
