//! Pure-Rust MLP backend: hand-written forward/backward over the
//! teacher-image dataset. Drives the large experiment grids (Fig. 3/4)
//! and all coordinator tests without touching PJRT.

use crate::coordinator::{EvalOut, TrainBackend, WorkerBackend};
use crate::data::synth_images::SynthImages;
use crate::data::Dataset;
use crate::model::TensorLayout;
use crate::sgd::optimizer::{OptKind, Optimizer};
use crate::util::rng::Rng;

/// Pure-Rust training substrate over the synthetic image task.
///
/// `Clone` exists so the coordinator can fork one backend per pool worker
/// ([`TrainBackend::fork`]): the dataset is deterministic and replicated,
/// the scratch buffers are private per clone, so a fork's
/// [`WorkerBackend::local_steps`] is bit-identical to the original's.
#[derive(Clone)]
pub struct NativeMlpBackend {
    /// Layer widths, e.g. `[256, 64, 10]`.
    pub dims: Vec<usize>,
    /// Mini-batch size.
    pub batch: usize,
    layout: TensorLayout,
    opt: Optimizer,
    data: SynthImages,
    // scratch buffers reused across steps (no allocation on the hot path)
    acts: Vec<Vec<f32>>,   // activations per layer, batch-major
    deltas: Vec<Vec<f32>>, // gradients w.r.t. pre-activations
    grad: Vec<f32>,
}

impl NativeMlpBackend {
    /// Build an MLP backend over `data` with the given layer widths.
    pub fn new(dims: Vec<usize>, batch: usize, data: SynthImages, opt_kind: OptKind) -> Self {
        assert!(dims.len() >= 2);
        assert_eq!(dims[0], data.h * data.w * data.c, "input dim must match images");
        let mut tensors = Vec::new();
        for i in 0..dims.len() - 1 {
            tensors.push((format!("w{i}"), vec![dims[i], dims[i + 1]]));
            tensors.push((format!("b{i}"), vec![dims[i + 1]]));
        }
        let layout = TensorLayout::new(tensors);
        let acts = dims.iter().map(|&d| vec![0.0; batch * d]).collect();
        let deltas = dims.iter().map(|&d| vec![0.0; batch * d]).collect();
        let n = layout.total;
        NativeMlpBackend {
            dims,
            batch,
            layout,
            opt: Optimizer::new(opt_kind),
            data,
            acts,
            deltas,
            grad: vec![0.0; n],
        }
    }

    /// Small 16x16 single-channel digits task — the sweep workhorse
    /// (~19k params, hundreds of full trainings per minute).
    pub fn digits_small(clients: usize, seed: u64) -> Self {
        let data = SynthImages::with_dims(16, 16, 1, 10, clients, 0.7, seed);
        Self::new(vec![256, 64, 10], 32, data, OptKind::Momentum)
    }

    /// Paper-scale MNIST-like MLP (784-300-100-10, ~266k params).
    pub fn mnist_mlp(clients: usize, seed: u64) -> Self {
        let data = SynthImages::new("mnist", clients, seed);
        Self::new(vec![784, 300, 100, 10], 32, data, OptKind::Momentum)
    }

    /// Forward + backward on one batch; accumulates into self.grad and
    /// returns the mean loss. `params` is the flat vector.
    fn fwd_bwd(&mut self, params: &[f32], x: &[f32], y: &[i32]) -> f32 {
        let b = self.batch;
        let nl = self.dims.len();
        self.acts[0][..x.len()].copy_from_slice(x);
        // forward
        for l in 0..nl - 1 {
            let (din, dout) = (self.dims[l], self.dims[l + 1]);
            let w = &params[self.layout.range(2 * l)];
            let bias = &params[self.layout.range(2 * l + 1)];
            let (prev, next) = {
                let (a, bnext) = self.acts.split_at_mut(l + 1);
                (&a[l], &mut bnext[0])
            };
            for s in 0..b {
                let xi = &prev[s * din..(s + 1) * din];
                let out = &mut next[s * dout..(s + 1) * dout];
                out.copy_from_slice(bias);
                for i in 0..din {
                    let xv = xi[i];
                    if xv == 0.0 {
                        continue;
                    }
                    let wrow = &w[i * dout..(i + 1) * dout];
                    for j in 0..dout {
                        out[j] += xv * wrow[j];
                    }
                }
                if l + 1 < nl - 1 {
                    for v in out.iter_mut() {
                        *v = v.max(0.0); // relu
                    }
                }
            }
        }
        // softmax CE on the last layer
        let classes = self.dims[nl - 1];
        let mut loss = 0.0f32;
        {
            let logits = &self.acts[nl - 1];
            let dlast = &mut self.deltas[nl - 1];
            for s in 0..b {
                let lo = &logits[s * classes..(s + 1) * classes];
                let dl = &mut dlast[s * classes..(s + 1) * classes];
                let maxv = lo.iter().fold(f32::MIN, |m, &v| m.max(v));
                let mut z = 0.0f32;
                for j in 0..classes {
                    dl[j] = (lo[j] - maxv).exp();
                    z += dl[j];
                }
                let label = y[s] as usize;
                loss += -(dl[label] / z).max(1e-12).ln();
                for j in 0..classes {
                    dl[j] = (dl[j] / z - if j == label { 1.0 } else { 0.0 }) / b as f32;
                }
            }
        }
        loss /= b as f32;
        // backward
        for l in (0..nl - 1).rev() {
            let (din, dout) = (self.dims[l], self.dims[l + 1]);
            let w = &params[self.layout.range(2 * l)];
            let gw_range = self.layout.range(2 * l);
            let gb_range = self.layout.range(2 * l + 1);
            for s in 0..b {
                let xi = &self.acts[l][s * din..(s + 1) * din];
                let dl = &self.deltas[l + 1][s * dout..(s + 1) * dout];
                // bias grad
                {
                    let gb = &mut self.grad[gb_range.clone()];
                    for j in 0..dout {
                        gb[j] += dl[j];
                    }
                }
                // weight grad
                {
                    let gw = &mut self.grad[gw_range.clone()];
                    for i in 0..din {
                        let xv = xi[i];
                        if xv == 0.0 {
                            continue;
                        }
                        let grow = &mut gw[i * dout..(i + 1) * dout];
                        for j in 0..dout {
                            grow[j] += xv * dl[j];
                        }
                    }
                }
            }
            if l > 0 {
                // delta for previous layer (through relu)
                let (dprev_all, dnext_all) = self.deltas.split_at_mut(l + 1);
                for s in 0..b {
                    let dl = &dnext_all[0][s * dout..(s + 1) * dout];
                    let prev_act = &self.acts[l][s * din..(s + 1) * din];
                    let dprev = &mut dprev_all[l][s * din..(s + 1) * din];
                    for i in 0..din {
                        if prev_act[i] <= 0.0 {
                            dprev[i] = 0.0;
                            continue;
                        }
                        let wrow = &w[i * dout..(i + 1) * dout];
                        let mut acc = 0.0f32;
                        for j in 0..dout {
                            acc += wrow[j] * dl[j];
                        }
                        dprev[i] = acc;
                    }
                }
            }
        }
        loss
    }
}

impl TrainBackend for NativeMlpBackend {
    fn n_params(&self) -> usize {
        self.layout.total
    }

    fn opt_size(&self) -> usize {
        self.opt.kind.state_size(self.layout.total)
    }

    fn layout(&self) -> &TensorLayout {
        &self.layout
    }

    fn is_lm(&self) -> bool {
        false
    }

    fn init_params(&mut self, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed ^ 0xD1E7);
        let mut out = vec![0.0f32; self.layout.total];
        for l in 0..self.dims.len() - 1 {
            let (din, dout) = (self.dims[l], self.dims[l + 1]);
            let lim = (6.0 / (din + dout) as f32).sqrt();
            for v in &mut out[self.layout.range(2 * l)] {
                *v = (rng.next_f32() * 2.0 - 1.0) * lim;
            }
            // biases stay zero
        }
        out
    }

    fn local_steps(
        &mut self,
        params: &[f32],
        opt: &mut [f32],
        steps: usize,
        lr: f32,
        t0: usize,
        client: usize,
        rng: &mut Rng,
    ) -> (Vec<f32>, f32) {
        let mut w = params.to_vec();
        let mut loss_sum = 0.0f32;
        for s in 0..steps {
            let batch = self.data.train_batch(client, rng, self.batch);
            self.grad.iter_mut().for_each(|g| *g = 0.0);
            loss_sum += self.fwd_bwd(&w, &batch.xf, &batch.y);
            let mut grad = std::mem::take(&mut self.grad);
            self.opt.step(&mut w, opt, &mut grad, lr, t0 + s);
            self.grad = grad;
        }
        (w, loss_sum / steps as f32)
    }

    fn fork(&self) -> Option<Box<dyn WorkerBackend>> {
        Some(Box::new(self.clone()))
    }

    fn evaluate(&mut self, params: &[f32], max_batches: usize) -> EvalOut {
        let nb = self.data.eval_batches(self.batch).min(max_batches.max(1));
        let classes = *self.dims.last().unwrap();
        let nl = self.dims.len();
        let mut loss_sum = 0.0f64;
        let mut correct = 0usize;
        let mut total = 0usize;
        for bi in 0..nb {
            let batch = self.data.eval_batch(bi, self.batch);
            // forward only (reuse fwd_bwd's forward by zeroing grads after;
            // cheaper: run fwd_bwd and discard grads — loss is what we need)
            self.grad.iter_mut().for_each(|g| *g = 0.0);
            let loss = self.fwd_bwd(params, &batch.xf, &batch.y);
            loss_sum += loss as f64;
            let logits = &self.acts[nl - 1];
            for s in 0..self.batch {
                let lo = &logits[s * classes..(s + 1) * classes];
                let pred = lo
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                correct += (pred as i32 == batch.y[s]) as usize;
                total += 1;
            }
        }
        EvalOut { loss: (loss_sum / nb as f64) as f32, metric: correct as f32 / total as f32 }
    }
}

impl WorkerBackend for NativeMlpBackend {
    #[allow(clippy::too_many_arguments)]
    fn local_steps(
        &mut self,
        params: &[f32],
        opt: &mut [f32],
        steps: usize,
        lr: f32,
        t0: usize,
        client: usize,
        rng: &mut Rng,
    ) -> (Vec<f32>, f32) {
        TrainBackend::local_steps(self, params, opt, steps, lr, t0, client, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fork_is_bit_identical() {
        let mut be = NativeMlpBackend::digits_small(2, 3);
        let params = be.init_params(1);
        let mut fork = be.fork().expect("native backend forks");
        let (mut opt_a, mut opt_b) = (vec![0.0f32; be.opt_size()], vec![0.0f32; be.opt_size()]);
        let (mut rng_a, mut rng_b) = (Rng::new(9), Rng::new(9));
        let (wa, la) = TrainBackend::local_steps(&mut be, &params, &mut opt_a, 5, 0.1, 0, 1, &mut rng_a);
        let (wb, lb) = fork.local_steps(&params, &mut opt_b, 5, 0.1, 0, 1, &mut rng_b);
        assert_eq!(wa, wb);
        assert_eq!(la.to_bits(), lb.to_bits());
        assert_eq!(opt_a, opt_b);
    }

    #[test]
    fn gradcheck_against_finite_differences() {
        let mut be = NativeMlpBackend::digits_small(1, 3);
        let params = be.init_params(1);
        let mut rng = Rng::new(5);
        let batch = be.data.train_batch(0, &mut rng, be.batch);
        be.grad.iter_mut().for_each(|g| *g = 0.0);
        let _loss0 = be.fwd_bwd(&params, &batch.xf, &batch.y);
        let analytic = be.grad.clone();
        let mut check_rng = Rng::new(7);
        // f32 loss has ~1e-7 resolution, and perturbations can cross ReLU
        // kinks: individual coordinates are noisy, so check each loosely
        // and the median tightly.
        let eps = 1e-2f32;
        let mut rels = Vec::new();
        while rels.len() < 16 {
            let i = check_rng.below(params.len());
            if analytic[i].abs() < 1e-3 {
                continue; // skip tiny gradients for fd stability
            }
            let mut p2 = params.clone();
            p2[i] += eps;
            be.grad.iter_mut().for_each(|g| *g = 0.0);
            let loss_plus = be.fwd_bwd(&p2, &batch.xf, &batch.y);
            p2[i] = params[i] - eps;
            be.grad.iter_mut().for_each(|g| *g = 0.0);
            let loss_minus = be.fwd_bwd(&p2, &batch.xf, &batch.y);
            let fd = (loss_plus - loss_minus) / (2.0 * eps);
            let rel = (fd - analytic[i]).abs() / analytic[i].abs().max(1e-4) as f32;
            assert!(rel < 0.25, "param {i}: fd {fd} vs analytic {}", analytic[i]);
            rels.push(rel as f64);
        }
        rels.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = rels[rels.len() / 2];
        assert!(median < 0.05, "median fd error {median} (all: {rels:?})");
    }

    #[test]
    fn single_client_training_reaches_high_accuracy() {
        let mut be = NativeMlpBackend::digits_small(1, 4);
        let params = be.init_params(2);
        let mut opt = vec![0.0f32; be.opt_size()];
        let mut rng = Rng::new(1);
        let (w, _loss) = be.local_steps(&params, &mut opt, 150, 0.1, 0, 0, &mut rng);
        let ev = be.evaluate(&w, 8);
        assert!(ev.metric > 0.8, "accuracy {}", ev.metric);
    }

    #[test]
    fn init_is_deterministic() {
        let mut be = NativeMlpBackend::digits_small(2, 5);
        assert_eq!(be.init_params(9), be.init_params(9));
        assert_ne!(be.init_params(9), be.init_params(10));
    }

    #[test]
    fn layout_matches_dims() {
        let be = NativeMlpBackend::digits_small(1, 0);
        assert_eq!(be.n_params(), 256 * 64 + 64 + 64 * 10 + 10);
        assert_eq!(be.layout().len(), 4);
    }
}
