//! Flat-vector optimizers mirroring the L2 graph semantics exactly
//! (same update equations as `python/compile/models/common.py`), so the
//! native backend and the PJRT backend are interchangeable in the
//! coordinator.

/// Which optimizer update rule a backend runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptKind {
    /// Plain SGD (with the Zaremba global-norm clip).
    Sgd,
    /// Heavy-ball momentum (paper default for the image models).
    Momentum,
    /// Adam with bias correction (LM models).
    Adam,
}

impl OptKind {
    /// Parse the manifest's optimizer name (unknown names → SGD).
    pub fn from_name(name: &str) -> OptKind {
        match name {
            "momentum" => OptKind::Momentum,
            "adam" => OptKind::Adam,
            _ => OptKind::Sgd,
        }
    }

    /// Flat state-vector length for `n_params` parameters.
    pub fn state_size(&self, n_params: usize) -> usize {
        match self {
            OptKind::Sgd => 1,
            OptKind::Momentum => n_params,
            OptKind::Adam => 2 * n_params,
        }
    }
}

/// A flat-vector optimizer (update rule + hyperparameters).
#[derive(Clone, Debug)]
pub struct Optimizer {
    /// The update rule.
    pub kind: OptKind,
    /// Momentum factor (momentum kind only).
    pub momentum: f32,
    /// Optional global-norm gradient clip.
    pub clip: Option<f32>,
}

impl Optimizer {
    /// An optimizer with the L2 graphs' default hyperparameters.
    pub fn new(kind: OptKind) -> Self {
        Optimizer {
            kind,
            momentum: 0.9,
            // plain SGD gets the Zaremba global-norm clip like the L2 graphs
            clip: if kind == OptKind::Sgd { Some(5.0) } else { None },
        }
    }

    /// In-place update: params/opt modified, grad consumed as scratch.
    /// `t` is the global step index (Adam bias correction).
    pub fn step(&self, params: &mut [f32], opt: &mut [f32], grad: &mut [f32], lr: f32, t: usize) {
        if let Some(clip) = self.clip {
            let norm = crate::util::tensor::l2_norm(grad);
            if norm > clip {
                crate::util::tensor::scale(grad, clip / norm);
            }
        }
        match self.kind {
            OptKind::Sgd => {
                for i in 0..params.len() {
                    params[i] -= lr * grad[i];
                }
            }
            OptKind::Momentum => {
                let m = self.momentum;
                for i in 0..params.len() {
                    opt[i] = m * opt[i] + grad[i];
                    params[i] -= lr * opt[i];
                }
            }
            OptKind::Adam => {
                let n = params.len();
                let (b1, b2, eps) = (0.9f32, 0.999f32, 1e-8f32);
                let bc1 = 1.0 - b1.powi(t as i32 + 1);
                let bc2 = 1.0 - b2.powi(t as i32 + 1);
                let (mvec, vvec) = opt.split_at_mut(n);
                for i in 0..n {
                    mvec[i] = b1 * mvec[i] + (1.0 - b1) * grad[i];
                    vvec[i] = b2 * vvec[i] + (1.0 - b2) * grad[i] * grad[i];
                    let mhat = mvec[i] / bc1;
                    let vhat = vvec[i] / bc2;
                    params[i] -= lr * mhat / (vhat.sqrt() + eps);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn momentum_matches_formula() {
        let opt = Optimizer::new(OptKind::Momentum);
        let mut p = vec![1.0f32, 2.0];
        let mut state = vec![0.5f32, 0.0];
        let mut g = vec![0.1f32, -0.2];
        opt.step(&mut p, &mut state, &mut g, 0.1, 0);
        // v = 0.9*0.5 + 0.1 = 0.55 ; p = 1 - 0.055
        assert!((state[0] - 0.55).abs() < 1e-6);
        assert!((p[0] - 0.945).abs() < 1e-6);
    }

    #[test]
    fn adam_first_step_size() {
        // with bias correction, the first Adam step is ~lr regardless of g
        let opt = Optimizer::new(OptKind::Adam);
        let mut p = vec![0.0f32];
        let mut state = vec![0.0f32; 2];
        let mut g = vec![1e-3f32];
        opt.step(&mut p, &mut state, &mut g, 0.01, 0);
        assert!((p[0] + 0.01).abs() < 1e-3, "{}", p[0]);
    }

    #[test]
    fn sgd_clips_global_norm() {
        let opt = Optimizer::new(OptKind::Sgd);
        let mut p = vec![0.0f32; 2];
        let mut state = vec![0.0f32];
        let mut g = vec![30.0f32, 40.0]; // norm 50 -> scaled to 5
        opt.step(&mut p, &mut state, &mut g, 1.0, 0);
        assert!((p[0] + 3.0).abs() < 1e-5);
        assert!((p[1] + 4.0).abs() < 1e-5);
    }

    #[test]
    fn state_sizes() {
        assert_eq!(OptKind::Sgd.state_size(10), 1);
        assert_eq!(OptKind::Momentum.state_size(10), 10);
        assert_eq!(OptKind::Adam.state_size(10), 20);
        assert_eq!(OptKind::from_name("adam"), OptKind::Adam);
        assert_eq!(OptKind::from_name("momentum"), OptKind::Momentum);
        assert_eq!(OptKind::from_name("sgd"), OptKind::Sgd);
    }
}
