//! Panic-free big-endian field readers for length-checked byte slices.
//!
//! The wire-frame and snapshot decoders read fixed-width integers out of
//! buffers whose length was already validated. The obvious
//! `slice.try_into().unwrap()` idiom compiles to the same code but puts a
//! literal `unwrap` in the decode path, which `sbc-lint`'s `no-panic`
//! rule (and the repo invariant it mechanizes: corrupt input must fail
//! typed, never panic) forbids. These helpers centralize the pattern;
//! callers must have bounds-checked `off + width` themselves, exactly as
//! they had to for the `try_into` form.

/// Big-endian `u16` from `b[off..off + 2]`.
#[inline]
pub fn be_u16(b: &[u8], off: usize) -> u16 {
    u16::from_be_bytes([b[off], b[off + 1]])
}

/// Big-endian `u32` from `b[off..off + 4]`.
#[inline]
pub fn be_u32(b: &[u8], off: usize) -> u32 {
    u32::from_be_bytes([b[off], b[off + 1], b[off + 2], b[off + 3]])
}

/// Big-endian `u64` from `b[off..off + 8]`.
#[inline]
pub fn be_u64(b: &[u8], off: usize) -> u64 {
    let mut v = 0u64;
    for i in 0..8 {
        v = (v << 8) | b[off + i] as u64;
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_against_to_be_bytes() {
        let u = 0x0123_4567_89AB_CDEFu64;
        let b = u.to_be_bytes();
        assert_eq!(be_u64(&b, 0), u);
        assert_eq!(be_u32(&b, 0), 0x0123_4567);
        assert_eq!(be_u32(&b, 4), 0x89AB_CDEF);
        assert_eq!(be_u16(&b, 2), 0x4567);
    }

    #[test]
    fn offsets_in_longer_buffers() {
        let mut b = vec![0xFFu8; 3];
        b.extend_from_slice(&0xDEAD_BEEFu32.to_be_bytes());
        assert_eq!(be_u32(&b, 3), 0xDEAD_BEEF);
    }
}
