//! Lightweight scoped timing + aggregate counters for the perf pass.

use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};
use std::time::Duration;

use crate::simnet::clock::{Clock, RealClock};

/// Process-wide wall clock for spans. Timers aggregate real elapsed time
/// by design (they feed the perf report, not training results), so this
/// is the one sanctioned consumer of [`RealClock`] outside the round
/// loop; everything else threads a `&dyn Clock`.
fn wall() -> &'static RealClock {
    static WALL: OnceLock<RealClock> = OnceLock::new();
    WALL.get_or_init(RealClock::new)
}

/// Global (process-wide) phase timer registry. Cheap enough to leave on:
/// one mutex lock per recorded span, and spans are per-round, not per-step.
pub static TIMERS: Timers = Timers { inner: Mutex::new(None) };

/// Aggregated (count, total seconds) per phase name. Thread-safe: pool
/// workers record spans concurrently through one mutex-guarded map.
pub struct Timers {
    inner: Mutex<Option<BTreeMap<&'static str, (u64, f64)>>>,
}

impl Timers {
    /// Add one span observation to `name`'s aggregate.
    pub fn record(&self, name: &'static str, secs: f64) {
        let mut g = self.inner.lock().unwrap();
        let map = g.get_or_insert_with(BTreeMap::new);
        let e = map.entry(name).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += secs;
    }

    /// Copy out `(name, calls, total_s)` rows, sorted by name.
    pub fn snapshot(&self) -> Vec<(String, u64, f64)> {
        let g = self.inner.lock().unwrap();
        g.as_ref()
            .map(|m| m.iter().map(|(k, (n, s))| (k.to_string(), *n, *s)).collect())
            .unwrap_or_default()
    }

    /// Clear all aggregates.
    pub fn reset(&self) {
        *self.inner.lock().unwrap() = None;
    }

    /// Render the aggregates as an aligned text table.
    pub fn report(&self) -> String {
        let mut out = String::from("phase                          calls     total_s      avg_ms\n");
        for (name, n, s) in self.snapshot() {
            out.push_str(&format!("{name:<30} {n:>6} {s:>11.3} {:>11.3}\n", s * 1e3 / n as f64));
        }
        out
    }
}

/// RAII span: `let _t = span("encode");`
pub struct Span {
    name: &'static str,
    start: Duration,
}

/// Start a span that records into [`TIMERS`] when dropped.
pub fn span(name: &'static str) -> Span {
    Span { name, start: wall().now() }
}

impl Drop for Span {
    fn drop(&mut self) {
        TIMERS.record(self.name, wall().now().saturating_sub(self.start).as_secs_f64());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_accumulate() {
        TIMERS.reset();
        {
            let _a = span("unit_test_phase");
        }
        {
            let _a = span("unit_test_phase");
        }
        let snap = TIMERS.snapshot();
        let e = snap.iter().find(|(n, _, _)| n == "unit_test_phase").unwrap();
        assert_eq!(e.1, 2);
        assert!(TIMERS.report().contains("unit_test_phase"));
    }
}
