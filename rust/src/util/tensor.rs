//! Flat f32 vector math used throughout the coordinator hot path.
//!
//! Everything here operates on plain `&[f32]`/`&mut [f32]` slices — the
//! coordinator's canonical parameter representation — and is written to
//! auto-vectorize (simple indexed loops, no bounds checks in the kernel
//! bodies thanks to equal-length asserts hoisted to the top).

/// y += x
pub fn add_assign(y: &mut [f32], x: &[f32]) {
    assert_eq!(y.len(), x.len());
    for i in 0..y.len() {
        y[i] += x[i];
    }
}

/// y -= x
pub fn sub_assign(y: &mut [f32], x: &[f32]) {
    assert_eq!(y.len(), x.len());
    for i in 0..y.len() {
        y[i] -= x[i];
    }
}

/// y += a * x
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len());
    for i in 0..y.len() {
        y[i] += a * x[i];
    }
}

/// y *= a
pub fn scale(y: &mut [f32], a: f32) {
    for v in y.iter_mut() {
        *v *= a;
    }
}

/// out = a - b (allocating)
pub fn sub(a: &[f32], b: &[f32]) -> Vec<f32> {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

/// out = a - b written into `out`
pub fn sub_into(out: &mut [f32], a: &[f32], b: &[f32]) {
    assert_eq!(a.len(), b.len());
    assert_eq!(out.len(), a.len());
    for i in 0..out.len() {
        out[i] = a[i] - b[i];
    }
}

/// Euclidean norm (f64 accumulation).
pub fn l2_norm(x: &[f32]) -> f32 {
    x.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>().sqrt() as f32
}

/// Dot product (f64 accumulation).
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (*x as f64) * (*y as f64)).sum::<f64>() as f32
}

/// Largest absolute value (0 for an empty slice).
pub fn abs_max(x: &[f32]) -> f32 {
    x.iter().fold(0.0f32, |m, v| m.max(v.abs()))
}

/// Arithmetic mean (0 for an empty slice).
pub fn mean(x: &[f32]) -> f32 {
    if x.is_empty() {
        return 0.0;
    }
    (x.iter().map(|v| *v as f64).sum::<f64>() / x.len() as f64) as f32
}

/// Number of non-zero entries.
pub fn count_nonzero(x: &[f32]) -> usize {
    x.iter().filter(|v| **v != 0.0).count()
}

/// Collect the indices of non-zero entries into `out` (cleared first) —
/// the shared support scan behind momentum masking and sparse wire
/// messages; one definition so the sites cannot drift.
pub fn nonzero_indices_into(x: &[f32], out: &mut Vec<u32>) {
    out.clear();
    for (i, &v) in x.iter().enumerate() {
        if v != 0.0 {
            out.push(i as u32);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_and_friends() {
        let mut y = vec![1.0, 2.0, 3.0];
        axpy(&mut y, 2.0, &[1.0, 1.0, 1.0]);
        assert_eq!(y, vec![3.0, 4.0, 5.0]);
        sub_assign(&mut y, &[1.0, 1.0, 1.0]);
        assert_eq!(y, vec![2.0, 3.0, 4.0]);
        add_assign(&mut y, &[1.0, 0.0, 0.0]);
        assert_eq!(y, vec![3.0, 3.0, 4.0]);
        scale(&mut y, 0.5);
        assert_eq!(y, vec![1.5, 1.5, 2.0]);
    }

    #[test]
    fn norms() {
        assert_eq!(l2_norm(&[3.0, 4.0]), 5.0);
        assert_eq!(abs_max(&[-7.0, 2.0]), 7.0);
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert_eq!(mean(&[1.0, 3.0]), 2.0);
        assert_eq!(count_nonzero(&[0.0, 1.0, 0.0, -2.0]), 2);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn sub_into_matches_sub() {
        let a = [5.0, 7.0];
        let b = [1.0, 2.0];
        let mut out = [0.0; 2];
        sub_into(&mut out, &a, &b);
        assert_eq!(out.to_vec(), sub(&a, &b));
    }
}
