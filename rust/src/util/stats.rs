//! Small statistics helpers for experiment summaries.

/// Online mean/variance (Welford).
#[derive(Clone, Debug, Default)]
pub struct Running {
    /// Samples seen.
    pub n: u64,
    mean: f64,
    m2: f64,
    /// Smallest sample seen.
    pub min: f64,
    /// Largest sample seen.
    pub max: f64,
}

impl Running {
    /// An empty accumulator.
    pub fn new() -> Self {
        Running { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Add one sample.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Sample mean.
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Unbiased sample variance.
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Exact quantile of a small sample (copies + sorts).
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (pos - lo as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_closed_form() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        assert!((r.mean() - 3.0).abs() < 1e-12);
        assert!((r.var() - 2.5).abs() < 1e-12);
        assert_eq!(r.min, 1.0);
        assert_eq!(r.max, 5.0);
    }

    #[test]
    fn quantiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert_eq!(quantile(&xs, 0.5), 2.5);
    }
}
