//! Deterministic, splittable PRNG (xoshiro256**) — the repo-wide source of
//! randomness. All experiments are exactly reproducible from one seed: the
//! coordinator derives per-client, per-round streams via `child`.

/// xoshiro256** by Blackman & Vigna (public domain reference constants).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed a generator (splitmix64-expanded state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// The raw xoshiro256** state words, for checkpointing. Restoring
    /// with [`Rng::from_state`] continues the stream exactly where it
    /// left off — the property crash-recovery bit-identity rests on.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a state captured by [`Rng::state`].
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    /// Derive an independent child stream (for a client id, round, etc.).
    pub fn child(&self, stream: u64) -> Rng {
        // Mix the stream id through splitmix so children are decorrelated.
        let mut sm = self.s[0] ^ stream.wrapping_mul(0xA24BAED4963EE407);
        Rng::new(splitmix64(&mut sm))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next 32-bit output (high bits of [`Rng::next_u64`]).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here
        // (bias < 2^-32 for all n we use).
        ((self.next_u32() as u64 * n as u64) >> 32) as usize
    }

    /// Standard normal via Box-Muller (cached second value dropped for
    /// simplicity — generation is not on the hot path).
    pub fn normal(&mut self) -> f32 {
        let u1 = (self.next_f64()).max(1e-300);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k << n assumed).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all.sort_unstable();
            return all;
        }
        let mut set = std::collections::HashSet::with_capacity(k * 2);
        while set.len() < k {
            set.insert(self.below(n));
        }
        let mut v: Vec<usize> = set.into_iter().collect();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_roundtrip_resumes_stream() {
        let mut a = Rng::new(99);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn children_are_decorrelated() {
        let root = Rng::new(7);
        let mut c0 = root.child(0);
        let mut c1 = root.child(1);
        let same = (0..64).filter(|_| c0.next_u64() == c1.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(2);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(4);
        let idx = r.sample_indices(1000, 50);
        assert_eq!(idx.len(), 50);
        assert!(idx.windows(2).all(|w| w[0] < w[1]));
        let idx2 = r.sample_indices(10, 8); // dense branch
        assert_eq!(idx2.len(), 8);
        assert!(idx2.windows(2).all(|w| w[0] < w[1]));
    }
}
