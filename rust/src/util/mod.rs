//! Small shared substrates: deterministic RNG, flat-tensor math, timers.

pub mod bytes;
pub mod rng;
pub mod stats;
pub mod tensor;
pub mod timer;

/// Global bench scale factor from `SBC_BENCH_SCALE` (default 1.0). The
/// experiment harnesses multiply their iteration budgets by this, so
/// `SBC_BENCH_SCALE=10 cargo bench` runs the paper-faithful budgets while
/// the default stays laptop-sized.
pub fn bench_scale() -> f64 {
    std::env::var("SBC_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(1.0)
}

/// `n` scaled by [`bench_scale`], with a floor.
pub fn scaled(n: usize, floor: usize) -> usize {
    ((n as f64 * bench_scale()) as usize).max(floor)
}
