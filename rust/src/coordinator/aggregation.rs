//! Server-side aggregation of decoded client updates (Alg. 1 lines 16-19).

use crate::compression::onebit::onebit_to_dense;
use crate::compression::registry::{Method, MethodConfig};
use crate::compression::{Granularity, UpdateMsg};
use crate::model::TensorLayout;

/// How the server combines client updates.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AggRule {
    /// Plain averaging (paper Alg. 1: ΔW = mean of client updates).
    Mean,
    /// signSGD majority vote: sign of the summed signs, times `scale`.
    MajoritySign { scale: f32 },
}

impl AggRule {
    pub fn for_method(cfg: &MethodConfig) -> AggRule {
        match cfg.method {
            Method::SignSgd { scale } => AggRule::MajoritySign { scale },
            _ => AggRule::Mean,
        }
    }
}

/// Densify one decoded message according to the method's wire layout.
pub fn densify(
    msg: &UpdateMsg,
    cfg: &MethodConfig,
    layout: &TensorLayout,
    sign_scale: f32,
) -> Vec<f32> {
    match cfg.method {
        Method::OneBit => onebit_to_dense(msg, layout, cfg.granularity),
        _ => {
            // Global granularity wraps the whole vector in one segment.
            match cfg.granularity {
                Granularity::Global => msg.to_dense(&TensorLayout::flat(layout.total), sign_scale),
                Granularity::PerTensor => msg.to_dense(layout, sign_scale),
            }
        }
    }
}

/// Aggregate densified updates into the master delta.
pub fn aggregate(updates: &[Vec<f32>], rule: AggRule) -> Vec<f32> {
    assert!(!updates.is_empty());
    let n = updates[0].len();
    let mut out = vec![0.0f32; n];
    for u in updates {
        assert_eq!(u.len(), n);
        for i in 0..n {
            out[i] += u[i];
        }
    }
    match rule {
        AggRule::Mean => {
            let inv = 1.0 / updates.len() as f32;
            for v in out.iter_mut() {
                *v *= inv;
            }
        }
        AggRule::MajoritySign { scale } => {
            for v in out.iter_mut() {
                *v = if *v > 0.0 {
                    scale
                } else if *v < 0.0 {
                    -scale
                } else {
                    0.0
                };
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::TensorUpdate;

    #[test]
    fn mean_aggregation() {
        let got = aggregate(&[vec![1.0, 2.0], vec![3.0, -2.0]], AggRule::Mean);
        assert_eq!(got, vec![2.0, 0.0]);
    }

    #[test]
    fn majority_vote() {
        let got = aggregate(
            &[vec![0.1, -0.1, 0.0], vec![0.1, -0.1, 0.0], vec![-0.1, 0.1, 0.0]],
            AggRule::MajoritySign { scale: 0.5 },
        );
        assert_eq!(got, vec![0.5, -0.5, 0.0]);
    }

    #[test]
    fn densify_respects_granularity() {
        let layout = TensorLayout::new(vec![("a".into(), vec![2]), ("b".into(), vec![2])]);
        let mut cfg = MethodConfig::sbc1();
        cfg.granularity = Granularity::Global;
        let msg = UpdateMsg {
            round: 0,
            tensors: vec![TensorUpdate::SparseBinary { idx: vec![3], mu: 1.0, side_pos: true }],
        };
        let dense = densify(&msg, &cfg, &layout, 1.0);
        assert_eq!(dense, vec![0.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn rule_for_method() {
        assert_eq!(AggRule::for_method(&MethodConfig::sbc1()), AggRule::Mean);
        let s = MethodConfig::of(Method::SignSgd { scale: 0.01 }, 1);
        assert_eq!(AggRule::for_method(&s), AggRule::MajoritySign { scale: 0.01 });
    }
}
