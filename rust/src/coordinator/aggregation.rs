//! Server-side aggregation of decoded client updates (Alg. 1 lines 16-19).

use crate::compression::quantize::QuantizerCfg;
use crate::compression::registry::MethodConfig;

/// How the server combines client updates.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AggRule {
    /// Plain averaging (paper Alg. 1: ΔW = mean of client updates).
    Mean,
    /// signSGD majority vote: sign of the summed signs, times `scale`.
    MajoritySign { scale: f32 },
}

impl AggRule {
    pub fn for_method(cfg: &MethodConfig) -> AggRule {
        match cfg.quantizer {
            QuantizerCfg::Sign { scale } => AggRule::MajoritySign { scale },
            _ => AggRule::Mean,
        }
    }
}

/// Aggregate densified updates into `out` (zeroed first) without
/// allocating — the hot-path form; `updates` yields one dense slice per
/// client.
pub fn aggregate_into<'a, I>(updates: I, rule: AggRule, out: &mut [f32])
where
    I: IntoIterator<Item = &'a [f32]>,
{
    out.fill(0.0);
    let mut count = 0usize;
    for u in updates {
        assert_eq!(u.len(), out.len());
        for i in 0..out.len() {
            out[i] += u[i];
        }
        count += 1;
    }
    assert!(count > 0, "aggregate of zero updates");
    match rule {
        AggRule::Mean => {
            let inv = 1.0 / count as f32;
            for v in out.iter_mut() {
                *v *= inv;
            }
        }
        AggRule::MajoritySign { scale } => {
            for v in out.iter_mut() {
                *v = if *v > 0.0 {
                    scale
                } else if *v < 0.0 {
                    -scale
                } else {
                    0.0
                };
            }
        }
    }
}

/// Allocating convenience over [`aggregate_into`].
pub fn aggregate(updates: &[Vec<f32>], rule: AggRule) -> Vec<f32> {
    assert!(!updates.is_empty());
    let mut out = vec![0.0f32; updates[0].len()];
    aggregate_into(updates.iter().map(|u| u.as_slice()), rule, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_aggregation() {
        let got = aggregate(&[vec![1.0, 2.0], vec![3.0, -2.0]], AggRule::Mean);
        assert_eq!(got, vec![2.0, 0.0]);
    }

    #[test]
    fn majority_vote() {
        let got = aggregate(
            &[vec![0.1, -0.1, 0.0], vec![0.1, -0.1, 0.0], vec![-0.1, 0.1, 0.0]],
            AggRule::MajoritySign { scale: 0.5 },
        );
        assert_eq!(got, vec![0.5, -0.5, 0.0]);
    }

    #[test]
    fn aggregate_into_reuses_buffer() {
        let mut out = vec![9.0f32; 2];
        let a = [1.0f32, 2.0];
        let b = [3.0f32, -2.0];
        aggregate_into([&a[..], &b[..]], AggRule::Mean, &mut out);
        assert_eq!(out, vec![2.0, 0.0]);
    }

    #[test]
    fn rule_for_method() {
        assert_eq!(AggRule::for_method(&MethodConfig::sbc1()), AggRule::Mean);
        let s = MethodConfig::signsgd(0.01);
        assert_eq!(AggRule::for_method(&s), AggRule::MajoritySign { scale: 0.01 });
    }
}
