//! Server-side aggregation of decoded client updates (Alg. 1 lines 16-19),
//! serial and sharded-parallel.
//!
//! # Determinism invariant
//!
//! The reduction order of floating-point sums is part of this module's
//! contract: element `i` of the aggregate is always accumulated over
//! clients in **client-index order** (`c = 0, 1, 2, …`), never in thread
//! or arrival order. [`aggregate_sharded`] parallelizes over *parameter
//! ranges* — each shard performs exactly the serial per-element fold on a
//! disjoint slice of the output — so its result is bit-identical to
//! [`aggregate_into`] at any thread count. The `prop_sharded_aggregate_*`
//! proptests and the coordinator's `SBC_PARALLELISM` CI run enforce this
//! bit-for-bit.

use crate::compression::quantize::QuantizerCfg;
use crate::compression::registry::MethodConfig;
use crate::coordinator::pool::WorkerPool;

/// How the server combines client updates.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AggRule {
    /// Plain averaging (paper Alg. 1: ΔW = mean of client updates).
    Mean,
    /// signSGD majority vote: sign of the summed signs, times `scale`.
    MajoritySign {
        /// Server step size applied per aggregated sign.
        scale: f32,
    },
}

impl AggRule {
    /// The aggregation rule a method's stage composition calls for
    /// (majority vote for sign quantizers, mean otherwise).
    pub fn for_method(cfg: &MethodConfig) -> AggRule {
        match cfg.quantizer {
            QuantizerCfg::Sign { scale } => AggRule::MajoritySign { scale },
            _ => AggRule::Mean,
        }
    }
}

/// Apply the post-sum reduction (mean scaling / majority sign) in place.
fn apply_rule(rule: AggRule, count: usize, out: &mut [f32]) {
    match rule {
        AggRule::Mean => {
            let inv = 1.0 / count as f32;
            for v in out.iter_mut() {
                *v *= inv;
            }
        }
        AggRule::MajoritySign { scale } => {
            for v in out.iter_mut() {
                *v = if *v > 0.0 {
                    scale
                } else if *v < 0.0 {
                    -scale
                } else {
                    0.0
                };
            }
        }
    }
}

/// Aggregate densified updates into `out` (zeroed first) without
/// allocating — the serial reference path; `updates` yields one dense
/// slice per client, in client-index order.
pub fn aggregate_into<'a, I>(updates: I, rule: AggRule, out: &mut [f32])
where
    I: IntoIterator<Item = &'a [f32]>,
{
    out.fill(0.0);
    let mut count = 0usize;
    for u in updates {
        assert_eq!(u.len(), out.len());
        for i in 0..out.len() {
            out[i] += u[i];
        }
        count += 1;
    }
    assert!(count > 0, "aggregate of zero updates");
    apply_rule(rule, count, out);
}

/// Indexed access to the round's densified client updates, `Sync` so
/// shard workers can read any client's slice concurrently. Implemented
/// for plain slice-of-slices (tests, benches) and by the trainer over its
/// client list, which avoids collecting a per-round vector of references.
pub trait UpdateSource: Sync {
    /// Number of client updates this round.
    fn count(&self) -> usize;

    /// Client `i`'s densified update (same length for every client).
    fn update(&self, i: usize) -> &[f32];
}

impl<'a> UpdateSource for [&'a [f32]] {
    fn count(&self) -> usize {
        self.len()
    }

    fn update(&self, i: usize) -> &[f32] {
        self[i]
    }
}

impl UpdateSource for [Vec<f32>] {
    fn count(&self) -> usize {
        self.len()
    }

    fn update(&self, i: usize) -> &[f32] {
        &self[i]
    }
}

/// Sharded tree aggregation: the pool splits the parameter range into
/// disjoint contiguous shards (one per worker), each worker reduces every
/// client's slice of its shard into the shard's partial sum, and the
/// partials merge into `out` by construction (disjoint writes, position =
/// shard offset).
///
/// Within a shard, clients are folded in client-index order — the exact
/// order [`aggregate_into`] uses — so the result is **bit-identical to
/// the serial path at any thread count**: shard boundaries change which
/// worker computes an element, never the order of the additions that
/// produce it.
pub fn aggregate_sharded<U>(updates: &U, rule: AggRule, pool: &WorkerPool, out: &mut [f32])
where
    U: UpdateSource + ?Sized,
{
    let count = updates.count();
    assert!(count > 0, "aggregate of zero updates");
    for c in 0..count {
        assert_eq!(updates.update(c).len(), out.len(), "client {c} update length mismatch");
    }
    pool.run_shards(out, |range, shard| {
        shard.fill(0.0);
        for c in 0..count {
            let u = &updates.update(c)[range.clone()];
            for (o, &v) in shard.iter_mut().zip(u) {
                *o += v;
            }
        }
        apply_rule(rule, count, shard);
    });
}

/// Allocating convenience over [`aggregate_into`].
pub fn aggregate(updates: &[Vec<f32>], rule: AggRule) -> Vec<f32> {
    assert!(!updates.is_empty());
    let mut out = vec![0.0f32; updates[0].len()];
    aggregate_into(updates.iter().map(|u| u.as_slice()), rule, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn mean_aggregation() {
        let got = aggregate(&[vec![1.0, 2.0], vec![3.0, -2.0]], AggRule::Mean);
        assert_eq!(got, vec![2.0, 0.0]);
    }

    #[test]
    fn majority_vote() {
        let got = aggregate(
            &[vec![0.1, -0.1, 0.0], vec![0.1, -0.1, 0.0], vec![-0.1, 0.1, 0.0]],
            AggRule::MajoritySign { scale: 0.5 },
        );
        assert_eq!(got, vec![0.5, -0.5, 0.0]);
    }

    #[test]
    fn aggregate_into_reuses_buffer() {
        let mut out = vec![9.0f32; 2];
        let a = [1.0f32, 2.0];
        let b = [3.0f32, -2.0];
        aggregate_into([&a[..], &b[..]], AggRule::Mean, &mut out);
        assert_eq!(out, vec![2.0, 0.0]);
    }

    #[test]
    fn rule_for_method() {
        assert_eq!(AggRule::for_method(&MethodConfig::sbc1()), AggRule::Mean);
        let s = MethodConfig::signsgd(0.01);
        assert_eq!(AggRule::for_method(&s), AggRule::MajoritySign { scale: 0.01 });
    }

    #[test]
    fn sharded_matches_serial_bit_for_bit() {
        // adversarial values: wide magnitude spread so any reordering of
        // the fold would actually flip low-order bits
        let mut rng = Rng::new(0xA55);
        for &(clients, n) in &[(1usize, 17usize), (3, 257), (7, 1000), (16, 64)] {
            let updates: Vec<Vec<f32>> = (0..clients)
                .map(|_| (0..n).map(|_| rng.normal() * 10f32.powi(rng.below(9) as i32 - 4)).collect())
                .collect();
            for rule in [AggRule::Mean, AggRule::MajoritySign { scale: 0.25 }] {
                let mut serial = vec![0.0f32; n];
                aggregate_into(updates.iter().map(|u| u.as_slice()), rule, &mut serial);
                for threads in [1usize, 2, 3, 8, 64] {
                    let pool = WorkerPool::new(threads);
                    let mut parallel = vec![1.0f32; n]; // dirty buffer on purpose
                    aggregate_sharded(&updates[..], rule, &pool, &mut parallel);
                    let a: Vec<u32> = serial.iter().map(|v| v.to_bits()).collect();
                    let b: Vec<u32> = parallel.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(a, b, "clients={clients} n={n} threads={threads} rule={rule:?}");
                    // the slice-of-slices UpdateSource must agree too
                    let refs: Vec<&[f32]> = updates.iter().map(|u| u.as_slice()).collect();
                    let mut via_refs = vec![f32::NAN; n];
                    aggregate_sharded(&refs[..], rule, &pool, &mut via_refs);
                    let c: Vec<u32> = via_refs.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(a, c, "slice-of-slices source diverged");
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "zero updates")]
    fn sharded_rejects_empty() {
        let pool = WorkerPool::new(2);
        let updates: Vec<Vec<f32>> = vec![];
        aggregate_sharded(&updates[..], AggRule::Mean, &pool, &mut [0.0f32; 4]);
    }
}
