//! DSGD coordinator (paper Algorithm 1): synchronous rounds with
//! communication delay, per-client residuals and momentum, the staged
//! compression pipeline over bit-true wire encode/decode in both
//! directions (client updates up, broadcast aggregate down), server
//! aggregation, evaluation and logging.

pub mod aggregation;
pub mod client;
pub mod schedule;
pub mod trainer;

use crate::model::TensorLayout;
use crate::util::rng::Rng;

/// Evaluation output: mean loss plus the task metric (accuracy for
/// classifiers, perplexity for LMs — see [`crate::model::Task`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalOut {
    pub loss: f32,
    pub metric: f32,
}

/// A training substrate the coordinator can drive: either the PJRT
/// runtime executing AOT artifacts ([`crate::runtime::PjrtBackend`]) or
/// the pure-Rust MLP ([`crate::sgd::NativeMlpBackend`]).
///
/// The backend owns the dataset (shards + held-out eval split); the
/// coordinator owns all distributed state (master weights, residuals,
/// per-client optimizer state, compression, accounting).
pub trait TrainBackend {
    fn n_params(&self) -> usize;
    fn opt_size(&self) -> usize;
    fn layout(&self) -> &TensorLayout;
    /// Accuracy-type or perplexity-type metric?
    fn is_lm(&self) -> bool;

    /// Deterministic initial parameters.
    fn init_params(&mut self, seed: u64) -> Vec<f32>;

    /// Run `steps` local SGD iterations for `client` starting from
    /// `params`, updating `opt` in place. Returns (new_params, mean loss).
    /// `t0` is the client's global iteration count (Adam bias correction).
    #[allow(clippy::too_many_arguments)]
    fn local_steps(
        &mut self,
        params: &[f32],
        opt: &mut [f32],
        steps: usize,
        lr: f32,
        t0: usize,
        client: usize,
        rng: &mut Rng,
    ) -> (Vec<f32>, f32);

    /// Evaluate on up to `max_batches` held-out batches.
    fn evaluate(&mut self, params: &[f32], max_batches: usize) -> EvalOut;

    /// Compress through the AOT Pallas graph, if this backend has one.
    /// Returns (dense binarized update, threshold, mu, side_pos).
    fn compress_pjrt(&mut self, _delta: &[f32], _p: f32) -> Option<(Vec<f32>, f32, f32, bool)> {
        None
    }
}
