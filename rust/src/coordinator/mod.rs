//! DSGD coordinator (paper Algorithm 1): synchronous rounds with
//! communication delay, per-client residuals and momentum, the staged
//! compression pipeline over bit-true wire encode/decode in both
//! directions (client updates up, broadcast aggregate down), sharded
//! server aggregation, evaluation and logging.
//!
//! The round loop is **thread-pooled**: with
//! [`trainer::TrainConfig::parallelism`] > 1, per-client work (local
//! steps → compress → wire encode/decode → densify → residual) runs on a
//! scoped worker pool ([`pool::WorkerPool`]), each worker owning a forked
//! backend ([`TrainBackend::fork`]) and its own accumulator scratch, and
//! the server reduces decoded updates with sharded aggregation
//! ([`aggregation::aggregate_sharded`]). Results are bit-identical to the
//! serial loop at any thread count — see `ARCHITECTURE.md` §Determinism
//! for the invariants that make that hold.

pub mod aggregation;
pub mod client;
pub mod pool;
pub mod schedule;
pub mod trainer;

use crate::model::TensorLayout;
use crate::util::rng::Rng;

/// Evaluation output: mean loss plus the task metric (accuracy for
/// classifiers, perplexity for LMs — see [`crate::model::Task`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalOut {
    /// Mean held-out loss.
    pub loss: f32,
    /// Accuracy for classifiers, perplexity for LMs.
    pub metric: f32,
}

/// A training substrate the coordinator can drive: either the PJRT
/// runtime executing AOT artifacts ([`crate::runtime::PjrtBackend`]) or
/// the pure-Rust MLP ([`crate::sgd::NativeMlpBackend`]).
///
/// The backend owns the dataset (shards + held-out eval split); the
/// coordinator owns all distributed state (master weights, residuals,
/// per-client optimizer state, compression, accounting).
pub trait TrainBackend {
    /// Flat parameter-vector length.
    fn n_params(&self) -> usize;
    /// Flat optimizer-state length (see [`crate::sgd::Optimizer`]).
    fn opt_size(&self) -> usize;
    /// Tensor layout of the flat parameter vector.
    fn layout(&self) -> &TensorLayout;
    /// Accuracy-type or perplexity-type metric?
    fn is_lm(&self) -> bool;

    /// Deterministic initial parameters.
    fn init_params(&mut self, seed: u64) -> Vec<f32>;

    /// Run `steps` local SGD iterations for `client` starting from
    /// `params`, updating `opt` in place. Returns (new_params, mean loss).
    /// `t0` is the client's global iteration count (Adam bias correction).
    #[allow(clippy::too_many_arguments)]
    fn local_steps(
        &mut self,
        params: &[f32],
        opt: &mut [f32],
        steps: usize,
        lr: f32,
        t0: usize,
        client: usize,
        rng: &mut Rng,
    ) -> (Vec<f32>, f32);

    /// Evaluate on up to `max_batches` held-out batches.
    fn evaluate(&mut self, params: &[f32], max_batches: usize) -> EvalOut;

    /// Compress through the AOT Pallas graph, if this backend has one.
    /// Returns (dense binarized update, threshold, mu, side_pos).
    fn compress_pjrt(&mut self, _delta: &[f32], _p: f32) -> Option<(Vec<f32>, f32, f32, bool)> {
        None
    }

    /// Fork an independent worker instance for thread-pooled client
    /// rounds ([`trainer::TrainConfig::parallelism`]).
    ///
    /// A fork must produce bit-identical [`WorkerBackend::local_steps`]
    /// results to `self` for the same inputs: the dataset and model
    /// definition are shared (or deterministically replicated), while
    /// internal scratch is private to the fork. Backends that cannot be
    /// replicated — e.g. a backend bound to a single PJRT device — keep
    /// the default `None`, and the coordinator falls back to the serial
    /// loop.
    fn fork(&self) -> Option<Box<dyn WorkerBackend>> {
        None
    }
}

/// The slice of [`TrainBackend`] a pool worker needs: local training
/// only. Compression, wire coding and densification live in per-client
/// state ([`client::ClientState`]) and need no backend. `Send` because
/// forks move onto scoped worker threads; the coordinator never shares
/// one fork between two workers.
pub trait WorkerBackend: Send {
    /// Same contract as [`TrainBackend::local_steps`].
    #[allow(clippy::too_many_arguments)]
    fn local_steps(
        &mut self,
        params: &[f32],
        opt: &mut [f32],
        steps: usize,
        lr: f32,
        t0: usize,
        client: usize,
        rng: &mut Rng,
    ) -> (Vec<f32>, f32);
}
