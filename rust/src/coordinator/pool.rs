//! Fork-join worker pool for the coordinator's round loop.
//!
//! [`WorkerPool`] is a *scoped* pool built on [`std::thread::scope`]: each
//! parallel region spawns its workers, joins them before returning, and
//! borrows the data it operates on directly — no `Arc`, no channels, no
//! `'static` bounds, no dependencies beyond `std`. With `parallelism = 1`
//! (the default) every region runs inline on the caller's thread, so the
//! serial path is the parallel path with one worker rather than a separate
//! code path.
//!
//! # Determinism invariants
//!
//! Nothing observable may depend on the thread schedule. The two region
//! shapes below guarantee that structurally:
//!
//! * [`WorkerPool::for_each`] gives each job exclusive `&mut` access to
//!   its own state. Jobs share no mutable state, so the schedule cannot
//!   influence any result; the caller reads the outputs back in job-index
//!   order after the join.
//! * [`WorkerPool::run_shards`] splits one output slice into disjoint
//!   contiguous shards. Shard boundaries are a pure function of the slice
//!   length and the worker count, and each element is written by exactly
//!   one worker — so as long as the per-element computation itself is
//!   deterministic (see [`crate::coordinator::aggregation`], which reduces
//!   every element in client-index order), the result is bit-identical at
//!   any thread count.

/// A scoped fork-join thread pool of fixed width.
///
/// Construction is free (no threads are kept alive between regions); each
/// call to [`WorkerPool::for_each`] / [`WorkerPool::run_shards`] spawns at
/// most `parallelism` scoped threads and joins them before returning.
#[derive(Clone, Copy, Debug)]
pub struct WorkerPool {
    parallelism: usize,
}

impl WorkerPool {
    /// A pool running `parallelism` concurrent workers per region
    /// (clamped to at least 1; 1 means strictly inline execution).
    pub fn new(parallelism: usize) -> WorkerPool {
        WorkerPool { parallelism: parallelism.max(1) }
    }

    /// Worker count per parallel region.
    pub fn parallelism(&self) -> usize {
        self.parallelism
    }

    /// Whether this pool runs everything inline on the caller's thread.
    pub fn is_serial(&self) -> bool {
        self.parallelism == 1
    }

    /// Contiguous chunk length that splits `len` items into at most
    /// `parallelism` chunks (the chunking used by the trainer to assign
    /// clients to workers).
    pub fn chunk_len(&self, len: usize) -> usize {
        len.div_ceil(self.parallelism).max(1)
    }

    /// Run `f(job_index, job)` for every job, concurrently when the pool
    /// is parallel. Callers pass at most one job per worker (see
    /// [`WorkerPool::chunk_len`]); each job owns its state exclusively,
    /// which is what makes the schedule unobservable.
    pub fn for_each<J, F>(&self, jobs: &mut [J], f: F)
    where
        J: Send,
        F: Fn(usize, &mut J) + Sync,
    {
        if self.parallelism == 1 || jobs.len() <= 1 {
            for (i, job) in jobs.iter_mut().enumerate() {
                f(i, job);
            }
            return;
        }
        debug_assert!(
            jobs.len() <= self.parallelism,
            "for_each spawns one thread per job: pass at most `parallelism` jobs \
             (chunk the work with chunk_len), got {} jobs for {} workers",
            jobs.len(),
            self.parallelism
        );
        std::thread::scope(|s| {
            for (i, job) in jobs.iter_mut().enumerate() {
                let f = &f;
                s.spawn(move || f(i, job));
            }
        });
    }

    /// Split `out` into at most `parallelism` disjoint contiguous shards
    /// and run `f(global_range, shard)` on each, concurrently when the
    /// pool is parallel. Shard boundaries depend only on `out.len()` and
    /// the worker count — and since every element belongs to exactly one
    /// shard, a deterministic `f` yields bit-identical output at any
    /// parallelism.
    pub fn run_shards<F>(&self, out: &mut [f32], f: F)
    where
        F: Fn(std::ops::Range<usize>, &mut [f32]) + Sync,
    {
        let n = out.len();
        if self.parallelism == 1 || n <= 1 {
            f(0..n, out);
            return;
        }
        let shard_len = n.div_ceil(self.parallelism);
        std::thread::scope(|s| {
            for (i, shard) in out.chunks_mut(shard_len).enumerate() {
                let f = &f;
                let start = i * shard_len;
                s.spawn(move || f(start..start + shard.len(), shard));
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_pool_runs_inline() {
        let pool = WorkerPool::new(1);
        assert!(pool.is_serial());
        let main_thread = std::thread::current().id();
        let mut jobs = vec![0usize; 4];
        pool.for_each(&mut jobs, |i, j| {
            assert_eq!(std::thread::current().id(), main_thread);
            *j = i + 1;
        });
        assert_eq!(jobs, vec![1, 2, 3, 4]);
    }

    #[test]
    fn parallel_for_each_reaches_every_job() {
        let pool = WorkerPool::new(4);
        let mut jobs: Vec<usize> = vec![0; 7];
        pool.for_each(&mut jobs, |i, j| *j = i * 10);
        assert_eq!(jobs, vec![0, 10, 20, 30, 40, 50, 60]);
    }

    #[test]
    fn run_shards_covers_whole_range_once() {
        for threads in [1, 2, 3, 8] {
            let pool = WorkerPool::new(threads);
            let mut out = vec![0.0f32; 37];
            pool.run_shards(&mut out, |range, shard| {
                assert_eq!(range.len(), shard.len());
                for (off, v) in shard.iter_mut().enumerate() {
                    // each element written exactly once with its own index
                    assert_eq!(*v, 0.0);
                    *v = (range.start + off) as f32;
                }
            });
            let want: Vec<f32> = (0..37).map(|i| i as f32).collect();
            assert_eq!(out, want, "threads={threads}");
        }
    }

    #[test]
    fn run_shards_empty_slice() {
        let pool = WorkerPool::new(8);
        let mut out: Vec<f32> = vec![];
        pool.run_shards(&mut out, |range, shard| {
            assert_eq!(range, 0..0);
            assert!(shard.is_empty());
        });
    }

    #[test]
    fn chunk_len_bounds_worker_count() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.chunk_len(8), 2);
        assert_eq!(pool.chunk_len(9), 3);
        assert_eq!(pool.chunk_len(3), 1);
        assert_eq!(pool.chunk_len(0), 1);
        // at most `parallelism` chunks for any length
        for len in 1..64usize {
            assert!(len.div_ceil(pool.chunk_len(len)) <= 4, "len={len}");
        }
    }
}
