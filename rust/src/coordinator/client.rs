//! Per-client distributed-training state (paper Alg. 1 lines 6-14).

use crate::compression::residual::Residual;
use crate::compression::Compressor;
use crate::util::rng::Rng;

pub struct ClientState {
    pub id: usize,
    /// Flat optimizer state, layout identical to the L2 graphs'.
    pub opt: Vec<f32>,
    /// Error-feedback residual (paper eq. 2).
    pub residual: Residual,
    /// This client's compressor instance (stateful for stochastic methods).
    pub compressor: Box<dyn Compressor>,
    /// Local iteration counter (Adam bias correction, schedules).
    pub iterations: usize,
    /// Client-local RNG stream (data sampling).
    pub rng: Rng,
    /// Cumulative upstream bits this client has sent.
    pub up_bits: u64,
}

impl ClientState {
    pub fn new(
        id: usize,
        n_params: usize,
        opt_size: usize,
        residual_enabled: bool,
        compressor: Box<dyn Compressor>,
        root_rng: &Rng,
    ) -> Self {
        ClientState {
            id,
            opt: vec![0.0; opt_size],
            residual: Residual::new(n_params, residual_enabled),
            compressor,
            iterations: 0,
            rng: root_rng.child(0x1000 + id as u64),
            up_bits: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::registry::MethodConfig;

    #[test]
    fn construction() {
        let root = Rng::new(1);
        let cfg = MethodConfig::sbc1();
        let c = ClientState::new(2, 100, 100, true, cfg.build(7), &root);
        assert_eq!(c.id, 2);
        assert_eq!(c.opt.len(), 100);
        assert!(c.residual.enabled());
        assert_eq!(c.compressor.name(), "sbc");
    }

    #[test]
    fn distinct_rng_streams() {
        let root = Rng::new(1);
        let cfg = MethodConfig::baseline();
        let mut a = ClientState::new(0, 4, 1, false, cfg.build(0), &root);
        let mut b = ClientState::new(1, 4, 1, false, cfg.build(0), &root);
        assert_ne!(a.rng.next_u64(), b.rng.next_u64());
    }
}
