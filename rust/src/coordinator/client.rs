//! Per-client distributed-training state (paper Alg. 1 lines 6-14),
//! including the per-client scratch the hot loop reuses across rounds so
//! compression, wire encode/decode and residual densification perform no
//! steady-state heap allocation.
//!
//! A `ClientState` is self-contained and `Send`: under a pooled round
//! loop ([`crate::coordinator::pool::WorkerPool`]) each worker takes
//! exclusive `&mut` access to its chunk of clients, and the coordinator
//! reads the per-round outputs (`round_loss` / `round_bits` /
//! `round_nnz`) back on the main thread in client-index order, which
//! keeps accounting and logging deterministic at any parallelism.

use crate::codec::message::{PosCodec, WireCodec};
use crate::compression::residual::Residual;
use crate::compression::{Pipeline, UpdateMsg};
use crate::coordinator::trainer::TrainConfig;
use crate::persist::ClientSnapshot;
use crate::util::rng::Rng;

/// All state one simulated client owns across a training run.
pub struct ClientState {
    /// Stable client index (shard selection, RNG stream derivation).
    pub id: usize,
    /// Flat optimizer state, layout identical to the L2 graphs'.
    pub opt: Vec<f32>,
    /// Error-feedback residual (paper eq. 2).
    pub residual: Residual,
    /// This client's compression pipeline (stateful for stochastic stages).
    pub pipeline: Pipeline,
    /// Wire codec with its reusable encode buffer.
    pub wire: WireCodec,
    /// Reused outgoing-message scratch (compress_into target).
    pub msg: UpdateMsg,
    /// Reused server-side decode scratch (bit-true wire path).
    pub decoded: UpdateMsg,
    /// Reused densified update — one buffer per client across all rounds
    /// (residual accounting and aggregation read from it).
    pub dense: Vec<f32>,
    /// Reused transmitted-index scratch for momentum masking.
    pub mask_idx: Vec<u32>,
    /// Local iteration counter (Adam bias correction, schedules).
    pub iterations: usize,
    /// Client-local RNG stream (data sampling).
    pub rng: Rng,
    /// Cumulative upstream bits this client has sent.
    pub up_bits: u64,
    /// Mean training loss of the most recent round (worker output; read
    /// back by the coordinator in client-index order).
    pub round_loss: f32,
    /// Wire bits this client sent in the most recent round.
    pub round_bits: u64,
    /// Non-zero elements this client transmitted in the most recent round.
    pub round_nnz: u64,
    /// Buffered `(stage, nanos)` trace observations of the most recent
    /// round. Pool workers only push here; the coordinator drains in
    /// client-index order and emits [`crate::trace::Event::Stage`], so a
    /// traced pooled run records the same event order as a serial run.
    /// Always empty when tracing is disabled.
    pub trace_buf: Vec<(&'static str, u64)>,
}

impl ClientState {
    /// Build the state for client `id`, deriving its RNG stream from the
    /// run's root RNG.
    pub fn new(
        id: usize,
        n_params: usize,
        opt_size: usize,
        residual_enabled: bool,
        pipeline: Pipeline,
        pos_codec: PosCodec,
        root_rng: &Rng,
    ) -> Self {
        ClientState {
            id,
            opt: vec![0.0; opt_size],
            residual: Residual::new(n_params, residual_enabled),
            pipeline,
            wire: WireCodec::new(pos_codec),
            msg: UpdateMsg::scratch(),
            decoded: UpdateMsg::scratch(),
            dense: vec![0.0; n_params],
            mask_idx: Vec::new(),
            iterations: 0,
            rng: root_rng.child(0x1000 + id as u64),
            up_bits: 0,
            round_loss: 0.0,
            round_bits: 0,
            round_nnz: 0,
            trace_buf: Vec::new(),
        }
    }

    /// Build client `id`'s state straight from a training config — the
    /// single construction shared by the in-process trainer and the
    /// remote federated session ([`crate::transport::session`]), so both
    /// derive identical pipelines, pipeline seeds and RNG streams (a
    /// prerequisite for the bit-identical federated weight digest).
    pub fn for_config(cfg: &TrainConfig, id: usize, n_params: usize, opt_size: usize) -> Self {
        let root = Rng::new(cfg.seed);
        ClientState::new(
            id,
            n_params,
            opt_size,
            cfg.method.use_residual(),
            cfg.method.build(cfg.seed ^ (0xC11E + id as u64)),
            cfg.pos_codec,
            &root,
        )
    }

    /// Capture everything convergence-relevant into a checkpoint payload:
    /// optimizer moments, the error-feedback residual, the iteration
    /// counter and all three RNG cursors. `round` is the next round this
    /// state will run; `weights` is the session's local model copy (empty
    /// in the in-process trainer, which shares one master vector).
    pub fn snapshot(&self, round: u32, weights: &[f32]) -> ClientSnapshot {
        let (selector_rng, quantizer_rng) = self.pipeline.rng_states();
        ClientSnapshot {
            client: self.id as u32,
            round,
            weights: weights.to_vec(),
            opt: self.opt.clone(),
            residual: self.residual.as_slice().to_vec(),
            residual_enabled: self.residual.enabled(),
            iterations: self.iterations as u64,
            up_bits: self.up_bits,
            rng: self.rng.state(),
            selector_rng,
            quantizer_rng,
        }
    }

    /// Restore the state captured by [`ClientState::snapshot`]. The
    /// snapshot must come from the same `(config, client id)` — the
    /// store's digest check enforces that before this runs.
    pub fn restore(&mut self, snap: &ClientSnapshot) {
        assert_eq!(snap.client as usize, self.id, "client id mismatch on restore");
        assert_eq!(snap.opt.len(), self.opt.len(), "optimizer size mismatch on restore");
        self.opt.copy_from_slice(&snap.opt);
        self.residual.restore(&snap.residual);
        self.iterations = snap.iterations as usize;
        self.up_bits = snap.up_bits;
        self.rng = Rng::from_state(snap.rng);
        self.pipeline.restore_rng_states(snap.selector_rng, snap.quantizer_rng);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::registry::MethodConfig;

    #[test]
    fn construction() {
        let root = Rng::new(1);
        let cfg = MethodConfig::sbc1();
        let c = ClientState::new(2, 100, 100, true, cfg.build(7), PosCodec::Golomb, &root);
        assert_eq!(c.id, 2);
        assert_eq!(c.opt.len(), 100);
        assert_eq!(c.dense.len(), 100);
        assert!(c.residual.enabled());
        assert_eq!(c.pipeline.name(), "sbc");
        assert_eq!(c.wire.pos_codec(), PosCodec::Golomb);
        assert_eq!((c.round_loss, c.round_bits, c.round_nnz), (0.0, 0, 0));
    }

    #[test]
    fn distinct_rng_streams() {
        let root = Rng::new(1);
        let cfg = MethodConfig::baseline();
        let mut a = ClientState::new(0, 4, 1, false, cfg.build(0), PosCodec::Golomb, &root);
        let mut b = ClientState::new(1, 4, 1, false, cfg.build(0), PosCodec::Golomb, &root);
        assert_ne!(a.rng.next_u64(), b.rng.next_u64());
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let root = Rng::new(1);
        let cfg = MethodConfig::sbc(0.1, 4);
        let mut c = ClientState::new(2, 32, 32, true, cfg.build(7), PosCodec::Golomb, &root);
        c.iterations = 12;
        c.up_bits = 777;
        c.rng.next_u64();
        let snap = c.snapshot(3, &[]);
        let mut fresh = ClientState::new(2, 32, 32, true, cfg.build(7), PosCodec::Golomb, &root);
        fresh.restore(&snap);
        assert_eq!(fresh.iterations, 12);
        assert_eq!(fresh.up_bits, 777);
        assert_eq!(fresh.rng.state(), c.rng.state());
        assert_eq!(fresh.pipeline.rng_states(), c.pipeline.rng_states());
        assert_eq!(fresh.snapshot(3, &[]), snap);
    }

    #[test]
    fn client_state_is_send() {
        fn assert_send<T: Send>() {}
        assert_send::<ClientState>();
    }
}
