//! Learning-rate schedules (paper Table III: stepwise decay at fixed
//! iteration milestones). Schedules are evaluated on *local iterations*,
//! matching the paper's iteration-count axis.

/// Stepwise learning-rate decay schedule.
#[derive(Clone, Debug)]
pub struct LrSchedule {
    /// Learning rate before the first milestone.
    pub base: f32,
    /// Multiplicative factor applied at each milestone.
    pub decay: f32,
    /// Iteration milestones (sorted).
    pub milestones: Vec<usize>,
}

impl LrSchedule {
    /// A constant learning rate.
    pub fn constant(base: f32) -> Self {
        LrSchedule { base, decay: 1.0, milestones: vec![] }
    }

    /// `base`, multiplied by `decay` at each milestone iteration.
    pub fn step(base: f32, decay: f32, milestones: Vec<usize>) -> Self {
        LrSchedule { base, decay, milestones }
    }

    /// The learning rate at a local-iteration count.
    pub fn at(&self, iteration: usize) -> f32 {
        let hits = self.milestones.iter().filter(|&&m| iteration >= m).count();
        self.base * self.decay.powi(hits as i32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_decay() {
        let s = LrSchedule::step(0.1, 0.1, vec![100, 200]);
        assert_eq!(s.at(0), 0.1);
        assert_eq!(s.at(99), 0.1);
        assert!((s.at(100) - 0.01).abs() < 1e-9);
        assert!((s.at(250) - 0.001).abs() < 1e-9);
    }

    #[test]
    fn constant() {
        let s = LrSchedule::constant(0.5);
        assert_eq!(s.at(0), 0.5);
        assert_eq!(s.at(10_000), 0.5);
    }
}
