//! The distributed training driver — paper Algorithm 1 end to end.
//!
//! One [`Trainer::run`] call executes a full DSGD training: every round,
//! every participating client runs `delay` local iterations on its shard,
//! forms the accumulated update (residual + fresh delta), runs the staged
//! compression pipeline (Select → Quantize → Encode), puts the message
//! *on the wire* (bit-exact encode), the server decodes and aggregates,
//! re-encodes the aggregate for the downstream broadcast, and everyone
//! synchronizes. All reported bits — upstream *and* downstream — are
//! measured on the encoded messages.
//!
//! # Thread-pooled rounds
//!
//! With [`TrainConfig::parallelism`] > 1 the per-client phase (local
//! steps → compress → wire → densify → residual) runs on a scoped
//! [`WorkerPool`]: clients are split into contiguous chunks, each chunk
//! is driven by one worker owning a forked backend
//! ([`TrainBackend::fork`]) and a private accumulator, and the server
//! reduces the decoded updates with sharded aggregation
//! ([`aggregate_sharded`]). Per-client outputs (loss, wire bits,
//! non-zeros) are written into each [`ClientState`] and read back on the
//! main thread in client-index order, so accounting, logging and the
//! float reductions are **bit-identical to the serial loop at any thread
//! count**. Backends that cannot fork (single PJRT device, or the
//! `--pjrt-compress` kernel route) fall back to the serial path.
//!
//! The round loop is allocation-free in steady state on the per-client
//! path: each client owns reusable scratch (message, decode target,
//! densified update, encode buffer — see [`ClientState`]), each worker
//! owns its accumulator, and the server reuses its aggregate,
//! broadcast-message and broadcast-decode buffers across rounds. (The
//! pooled path allocates one small job vector per round — worker-count
//! entries, not parameter-sized.)

use std::time::Duration;

use crate::codec::accounting::CommStats;
use crate::codec::message::{self, PosCodec, WireCodec};
use crate::compression::momentum_mask::mask_momentum;
use crate::compression::pipeline::compress_broadcast_into;
use crate::compression::registry::MethodConfig;
use crate::compression::{Granularity, TensorUpdate, UpdateMsg};
use crate::coordinator::aggregation::{aggregate_sharded, AggRule, UpdateSource};
use crate::coordinator::client::ClientState;
use crate::coordinator::pool::WorkerPool;
use crate::coordinator::schedule::LrSchedule;
use crate::coordinator::{TrainBackend, WorkerBackend};
use crate::metrics::{CurvePoint, RunLog};
use crate::model::{Task, TensorLayout};
use crate::netsim::{Link, NetSim};
use crate::persist::{CheckpointStore, ClientSnapshot, PersistError, ServerSnapshot};
use crate::simnet::clock::{Clock, RealClock};
use crate::trace::{Event, StageProfile, StageProfileBuilder, Trace, SERVER};
use crate::transport::{frame, TransportCfg};
use crate::util::rng::Rng;
use crate::util::tensor;
use crate::util::timer::span;

/// Default round-loop parallelism: the `SBC_PARALLELISM` environment
/// variable when set to a positive integer, else 1 (serial). The env
/// override lets CI run the entire unchanged test suite through the
/// pooled path — results are bit-identical by construction.
fn default_parallelism() -> usize {
    std::env::var("SBC_PARALLELISM")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&p| p >= 1)
        .unwrap_or(1)
}

/// Durable-checkpoint knobs ([`crate::persist`], `ARCHITECTURE.md` §8).
/// Checkpointing is off unless `dir` is set; it never changes the
/// trained bits — a checkpointed run and an untouched run produce
/// identical weight digests, and a resumed run is bit-identical to one
/// that never crashed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CheckpointCfg {
    /// Snapshot directory. `None` disables checkpointing entirely.
    pub dir: Option<String>,
    /// Snapshot at every Nth round barrier (values < 1 behave as 1).
    pub every_rounds: usize,
    /// Generations retained per role (`0` = keep everything).
    pub keep: usize,
    /// On start, load the newest generation from `dir` and continue from
    /// its round instead of training from fresh initialization.
    pub resume: bool,
}

impl Default for CheckpointCfg {
    fn default() -> Self {
        CheckpointCfg { dir: None, every_rounds: 1, keep: 2, resume: false }
    }
}

impl CheckpointCfg {
    /// The snapshot cadence with the `< 1` guard applied.
    pub fn every(&self) -> usize {
        self.every_rounds.max(1)
    }
}

/// Everything one training run needs to know (model, method, schedule,
/// clients, links, knobs). Built directly, via
/// [`crate::config::train_config_from_doc`] (TOML), or from
/// [`crate::config::presets`].
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Model name (artifact lookup for PJRT, label for logs).
    pub model: String,
    /// The compression method (stage composition + coordinator knobs).
    pub method: MethodConfig,
    /// Number of simulated clients.
    pub clients: usize,
    /// Total local iterations per client (paper's x-axis). Rounds =
    /// iterations / delay.
    pub iterations: usize,
    /// Learning-rate schedule, evaluated on local iterations.
    pub lr: LrSchedule,
    /// Evaluate every this many *rounds* (also logs a curve point).
    pub eval_every_rounds: usize,
    /// Held-out batches per evaluation.
    pub eval_batches: usize,
    /// Root seed: init, data order, stochastic stages all derive from it.
    pub seed: u64,
    /// Position-list codec for sparse tensors on the wire.
    pub pos_codec: PosCodec,
    /// Route SBC compression through the AOT Pallas graph when available.
    pub use_pjrt_compress: bool,
    /// Client→server link model.
    pub uplink: Link,
    /// Server→client link model.
    pub downlink: Link,
    /// Print per-eval progress lines to stderr.
    pub verbose: bool,
    /// Worker threads for the round loop (1 = serial). Any value yields
    /// bit-identical results — see the module docs and
    /// `ARCHITECTURE.md` §Determinism. Defaults to `SBC_PARALLELISM`
    /// from the environment, else 1.
    pub parallelism: usize,
    /// Transport knobs (timeouts, retry budget) for the federated path
    /// ([`crate::transport`]); also sets the framing-overhead model the
    /// in-process trainer charges to [`CommStats`] and [`NetSim`].
    pub transport: TransportCfg,
    /// Structured-event sink ([`crate::trace`]): disabled by default
    /// (inert `NullRecorder`), settable via `--trace` / `[trace]` TOML /
    /// the `SBC_TRACE` env var. Never affects training results — digests
    /// are bit-identical with tracing on or off.
    pub trace: Trace,
    /// Durable checkpoint/resume policy ([`crate::persist`]). Off by
    /// default; excluded from [`crate::transport::config_digest`] because
    /// it cannot change the trained bits.
    pub checkpoint: CheckpointCfg,
}

impl TrainConfig {
    /// A config with the paper's defaults (4 clients, WiFi links, Golomb
    /// positions, eval every 10 rounds).
    pub fn new(model: &str, method: MethodConfig, iterations: usize, lr: LrSchedule) -> Self {
        TrainConfig {
            model: model.to_string(),
            method,
            clients: 4, // the paper fixes 4 clients throughout
            iterations,
            lr,
            eval_every_rounds: 10,
            eval_batches: 4,
            seed: 42,
            pos_codec: PosCodec::Golomb,
            use_pjrt_compress: false,
            uplink: Link::wifi(),
            downlink: Link::wifi(),
            verbose: false,
            parallelism: default_parallelism(),
            transport: TransportCfg::default(),
            trace: Trace::from_env(),
            checkpoint: CheckpointCfg::default(),
        }
    }
}

/// Result of one training run.
pub struct TrainResult {
    /// The training curve plus summary fields.
    pub log: RunLog,
    /// Measured communication counters (wire bits, messages, baseline).
    pub comm: CommStats,
    /// Per-client simulated network totals.
    pub net: NetSim,
    /// Final master weights.
    pub final_params: Vec<f32>,
    /// Per-stage p50/p95/max timing profile — `Some` iff the run was
    /// traced ([`TrainConfig::trace`] enabled).
    pub stage_profile: Option<StageProfile>,
}

/// Drives one full distributed training over a [`TrainBackend`].
pub struct Trainer<'a, B: TrainBackend> {
    /// The training substrate (dataset + model execution).
    pub backend: &'a mut B,
    /// The run configuration.
    pub cfg: TrainConfig,
}

/// Round-constant context shared (immutably) by the serial loop and all
/// pool workers.
#[derive(Clone, Copy)]
struct RoundCtx<'a> {
    layout: &'a TensorLayout,
    master: &'a [f32],
    round: u32,
    lr: f32,
    delay: usize,
    densify_gran: Granularity,
    sign_scale: f32,
    momentum_masking: bool,
    majority_vote: bool,
    /// Whether stage timings are buffered into `ClientState::trace_buf`.
    trace_on: bool,
    /// Time source for stage marks (always real time in-process; the
    /// simulator drives its own [`crate::simnet::clock::SimClock`]).
    clock: &'a dyn Clock,
}

/// Start a stage timing mark iff the round is traced — the untraced hot
/// path never reads the clock.
#[inline]
fn mark(on: bool, clock: &dyn Clock) -> Option<Duration> {
    if on {
        Some(clock.now())
    } else {
        None
    }
}

/// Close a [`mark`] into a buffered `(stage, nanos)` observation.
#[inline]
fn observe(
    buf: &mut Vec<(&'static str, u64)>,
    stage: &'static str,
    t0: Option<Duration>,
    clock: &dyn Clock,
) {
    if let Some(t0) = t0 {
        buf.push((stage, clock.now().saturating_sub(t0).as_nanos() as u64));
    }
}

/// Close a [`mark`] on a server-side stage: record it into the profile
/// and emit the [`Event::Stage`] with the [`SERVER`] client sentinel.
fn server_stage(
    trace: &Trace,
    clock: &dyn Clock,
    profile: &mut Option<StageProfileBuilder>,
    round: u32,
    stage: &'static str,
    t0: Option<Duration>,
) {
    if let (Some(p), Some(t0)) = (profile.as_mut(), t0) {
        let nanos = clock.now().saturating_sub(t0).as_nanos() as u64;
        p.observe(stage, nanos);
        trace.emit(clock, || Event::Stage {
            round,
            client: SERVER,
            stage: stage.to_string(),
            nanos,
        });
    }
}

/// One pool worker: a forked backend plus the accumulator scratch that
/// replaces the serial loop's shared buffer.
struct PoolWorker {
    backend: Box<dyn WorkerBackend>,
    acc: Vec<f32>,
}

/// A decoded checkpoint generation: the server snapshot plus one client
/// snapshot per client, all at the same round barrier.
struct ResumeState {
    server: ServerSnapshot,
    clients: Vec<ClientSnapshot>,
}

/// The trainer's zero-copy view of the round's densified client updates
/// for sharded aggregation.
struct ClientUpdates<'a>(&'a [ClientState]);

impl UpdateSource for ClientUpdates<'_> {
    fn count(&self) -> usize {
        self.0.len()
    }

    fn update(&self, i: usize) -> &[f32] {
        &self.0[i].dense
    }
}

/// One client's complete round, given a way to run its local steps
/// (`local_steps(c, master)` → (new_params, loss)): local training,
/// accumulate (residual + fresh delta), compress through the pipeline,
/// then [`finish_client_round`]. Shared by the serial branch and every
/// pool worker so the two phase-1 paths cannot drift — the PJRT
/// kernel-compress route is the one remaining serial-only body.
fn run_client_round(
    ctx: &RoundCtx,
    c: &mut ClientState,
    acc: &mut [f32],
    local_steps: &mut dyn FnMut(&mut ClientState, &[f32]) -> (Vec<f32>, f32),
) {
    let t_local = mark(ctx.trace_on, ctx.clock);
    let (w_new, loss) = {
        let _t = span("local_steps");
        local_steps(c, ctx.master)
    };
    observe(&mut c.trace_buf, "local_steps", t_local, ctx.clock);
    c.iterations += ctx.delay;
    {
        let _t = span("compress");
        let t_compress = mark(ctx.trace_on, ctx.clock);
        tensor::sub_into(acc, &w_new, ctx.master);
        c.residual.accumulate_into(acc);
        if ctx.trace_on {
            c.pipeline.compress_into_observed(
                acc,
                ctx.layout,
                ctx.round,
                &mut c.msg,
                ctx.clock,
                &mut |stage, nanos| c.trace_buf.push((stage, nanos)),
            );
        } else {
            c.pipeline.compress_into(acc, ctx.layout, ctx.round, &mut c.msg);
        }
        observe(&mut c.trace_buf, "compress", t_compress, ctx.clock);
    }
    finish_client_round(ctx, c, acc, loss);
}

/// Everything after a client's message is in `c.msg`: wire encode +
/// decode (the bits that actually cross), server-side densify into the
/// client's reusable buffer, residual update against exactly what was
/// decoded, momentum masking, and the majority-vote sign reduction.
/// Writes the round outputs (`round_loss`/`round_bits`/`round_nnz`) into
/// `c`; the coordinator reads them back in client-index order.
fn finish_client_round(ctx: &RoundCtx, c: &mut ClientState, acc: &[f32], loss: f32) {
    let nnz: usize = c.msg.tensors.iter().map(|t| t.nonzeros()).sum();
    let bits = {
        let t_encode = mark(ctx.trace_on, ctx.clock);
        let (bytes, bits) = {
            let _t = span("encode");
            c.wire.encode(&c.msg)
        };
        observe(&mut c.trace_buf, "encode", t_encode, ctx.clock);
        let _t = span("decode");
        let t_decode = mark(ctx.trace_on, ctx.clock);
        message::decode_into(bytes, bits, &mut c.decoded).expect("wire roundtrip failed");
        observe(&mut c.trace_buf, "decode", t_decode, ctx.clock);
        bits
    };
    c.up_bits += bits;
    c.round_bits = bits;
    c.round_nnz = nnz as u64;
    c.round_loss = loss;

    {
        let _t = span("densify");
        let t_densify = mark(ctx.trace_on, ctx.clock);
        c.decoded.densify_into(ctx.layout, ctx.densify_gran, ctx.sign_scale, &mut c.dense);
        observe(&mut c.trace_buf, "densify", t_densify, ctx.clock);
    }
    c.residual.update(acc, &c.dense);

    if ctx.momentum_masking {
        tensor::nonzero_indices_into(&c.dense, &mut c.mask_idx);
        mask_momentum(&mut c.opt, acc.len(), &c.mask_idx);
    }
    if ctx.majority_vote {
        // majority vote wants raw ±1 votes, not ±scale
        for v in c.dense.iter_mut() {
            *v = v.signum();
        }
    }
}

impl<'a, B: TrainBackend> Trainer<'a, B> {
    /// Pair a backend with a config.
    pub fn new(backend: &'a mut B, cfg: TrainConfig) -> Self {
        Trainer { backend, cfg }
    }

    /// Run the full training from freshly initialized parameters.
    pub fn run(&mut self) -> TrainResult {
        let seed = self.cfg.seed;
        let init = self.backend.init_params(seed);
        self.run_from(init)
    }

    /// Run from explicit initial master weights (warm start — used by the
    /// adaptive-sparsity schedule to chain phases).
    pub fn run_from(&mut self, initial: Vec<f32>) -> TrainResult {
        self.run_inner(initial, None)
    }

    /// Resume from the newest checkpoint generation in
    /// `cfg.checkpoint.dir`, continuing the round loop exactly where the
    /// snapshot left off — the result is bit-identical to a run that
    /// never stopped. Falls back to a fresh run when the directory holds
    /// no snapshot yet; damaged or mismatched snapshots are typed
    /// [`PersistError`]s, never a silent restart.
    pub fn resume(&mut self) -> Result<TrainResult, PersistError> {
        let ck = self.cfg.checkpoint.clone();
        let dir = ck.dir.as_deref().expect("resume requires checkpoint.dir to be set");
        let store = CheckpointStore::open(dir, ck.keep)?;
        let digest = crate::transport::config_digest(&self.cfg);
        let Some(server) = store.load_latest_server(digest)? else {
            let init = self.backend.init_params(self.cfg.seed);
            return Ok(self.run_inner(init, None));
        };
        let mut snaps = Vec::with_capacity(self.cfg.clients);
        for id in 0..self.cfg.clients {
            let snap = store.load_client_at(id as u32, server.round, digest)?.ok_or(
                PersistError::Corrupt("server snapshot has no matching client snapshot"),
            )?;
            snaps.push(snap);
        }
        let initial = server.master.clone();
        Ok(self.run_inner(initial, Some(ResumeState { server, clients: snaps })))
    }

    fn run_inner(&mut self, initial: Vec<f32>, resumed: Option<ResumeState>) -> TrainResult {
        let cfg = self.cfg.clone();
        let n = self.backend.n_params();
        let layout = self.backend.layout().clone();
        let opt_size = self.backend.opt_size();
        // monotonic timestamps for emitted events and stage marks; the
        // in-process trainer always runs on wall time (simnet traces via
        // its own SimClock)
        let clock = RealClock::new();
        let started = clock.now();
        let trace_on = cfg.trace.enabled();
        let mut profile = trace_on.then(StageProfileBuilder::new);

        assert_eq!(initial.len(), n, "initial params length mismatch");
        let mut master = initial;
        let mut clients: Vec<ClientState> =
            (0..cfg.clients).map(|i| ClientState::for_config(&cfg, i, n, opt_size)).collect();

        let agg_rule = AggRule::for_method(&cfg.method);
        let majority_vote = matches!(agg_rule, AggRule::MajoritySign { .. });
        let sign_scale = cfg.method.sign_scale();
        let delay = cfg.method.delay;
        let rounds = (cfg.iterations / delay).max(1);
        let mut comm = CommStats::default();
        let mut net = NetSim::new(cfg.uplink, cfg.downlink, cfg.clients);
        let mut log = RunLog {
            model: cfg.model.clone(),
            method: cfg.method.label(),
            seed: cfg.seed,
            ..Default::default()
        };

        let is_sbc_pjrt = cfg.use_pjrt_compress && cfg.method.sbc_p().is_some();
        // the PJRT compress graph emits one whole-vector tensor
        let densify_gran =
            if is_sbc_pjrt { Granularity::Global } else { cfg.method.granularity };

        // the worker pool: clients split into at most `parallelism`
        // chunks, each driven by a backend fork; empty `workers` means
        // the serial path (parallelism 1, un-forkable backend, or the
        // PJRT kernel-compress route, which is bound to the main backend)
        let pool = WorkerPool::new(cfg.parallelism.min(cfg.clients.max(1)));
        let mut workers: Vec<PoolWorker> = Vec::new();
        if !pool.is_serial() && !is_sbc_pjrt {
            for _ in 0..pool.parallelism() {
                match self.backend.fork() {
                    Some(backend) => workers.push(PoolWorker { backend, acc: vec![0.0f32; n] }),
                    None => {
                        workers.clear();
                        break;
                    }
                }
            }
            if workers.is_empty() && cfg.verbose {
                eprintln!(
                    "[{}] backend cannot fork; running the round loop serially",
                    cfg.method.label()
                );
            }
        }
        // aggregation shards with the same pool — unless phase 1 fell
        // back to serial, or the model is small enough that per-round
        // thread spawns cost more than the reduction itself. The result
        // is bit-identical either way (same per-element fold); this is
        // spawn cost only.
        const SHARDING_MIN_PARAMS: usize = 1 << 14;
        let agg_pool = if workers.is_empty() || n < SHARDING_MIN_PARAMS {
            WorkerPool::new(1)
        } else {
            pool
        };

        // round-persistent scratch: client accumulator (serial path),
        // server aggregate, broadcast wire buffers — allocated once,
        // reused every round
        let mut acc = vec![0.0f32; n];
        let mut delta = vec![0.0f32; n];
        let mut delta_rx = vec![0.0f32; n];
        let mut round_up_bits = vec![0u64; cfg.clients];
        let mut down_wire = WireCodec::new(cfg.pos_codec);
        let mut down_msg = UpdateMsg::scratch();
        let mut down_decoded = UpdateMsg::scratch();

        // durable checkpointing: open the store once; snapshots land at
        // round barriers every `checkpoint.every()` rounds (§8)
        let store = cfg.checkpoint.dir.as_ref().map(|d| {
            CheckpointStore::open(d.as_str(), cfg.checkpoint.keep)
                .expect("cannot open checkpoint directory")
        });
        let ckpt_digest = crate::transport::config_digest(&cfg);

        // resuming: overwrite the freshly built accounting and client
        // state with the checkpointed values, then start the round loop
        // at the snapshot's barrier
        let mut start_round = 0usize;
        if let Some(rs) = &resumed {
            start_round = rs.server.round as usize;
            comm.upstream_bits = rs.server.comm[0];
            comm.messages = rs.server.comm[1];
            comm.nonzeros = rs.server.comm[2];
            comm.baseline_bits = rs.server.comm[3];
            comm.frame_overhead_bits = rs.server.comm[4];
            for (c, &(ub, db, ut, dt, ms)) in net.clients.iter_mut().zip(&rs.server.net_clients) {
                c.up_bits = ub;
                c.down_bits = db;
                c.up_time_s = f64::from_bits(ut);
                c.down_time_s = f64::from_bits(dt);
                c.messages = ms;
            }
            net.total_comm_time_s = f64::from_bits(rs.server.net_total_time_bits);
            for (c, snap) in clients.iter_mut().zip(&rs.clients) {
                c.restore(snap);
            }
            cfg.trace.emit(&clock, || Event::Restore {
                role: "trainer".into(),
                client: SERVER,
                round: rs.server.round,
            });
        }

        for round in start_round..rounds {
            let lr = cfg.lr.at(round * delay);
            cfg.trace.emit(&clock, || Event::RoundStart { round: round as u32 });

            // --- phase 1: per-client local training + compress + wire ---
            {
                let ctx = RoundCtx {
                    layout: &layout,
                    master: &master,
                    round: round as u32,
                    lr,
                    delay,
                    densify_gran,
                    sign_scale,
                    momentum_masking: cfg.method.momentum_masking,
                    majority_vote,
                    trace_on,
                    clock: &clock,
                };
                if workers.is_empty() && is_sbc_pjrt {
                    // serial-only: SBC through the AOT Pallas kernel
                    // graph, which is bound to the main backend
                    for c in clients.iter_mut() {
                        let t_local = mark(trace_on, &clock);
                        let (w_new, loss) = {
                            let _t = span("local_steps");
                            self.backend.local_steps(
                                ctx.master,
                                &mut c.opt,
                                delay,
                                lr,
                                c.iterations,
                                c.id,
                                &mut c.rng,
                            )
                        };
                        observe(&mut c.trace_buf, "local_steps", t_local, &clock);
                        c.iterations += delay;
                        {
                            let _t = span("compress");
                            tensor::sub_into(&mut acc, &w_new, ctx.master);
                            c.residual.accumulate_into(&mut acc);
                        }
                        let p = cfg.method.sbc_p().unwrap() as f32;
                        {
                            let _t = span("compress_pjrt");
                            let t_pjrt = mark(trace_on, &clock);
                            let (dense, _thr, mu, side_pos) = self
                                .backend
                                .compress_pjrt(&acc, p)
                                .expect("backend has no pjrt compress graph");
                            c.msg.round = round as u32;
                            c.msg.tensors.truncate(1);
                            if c.msg.tensors.is_empty() {
                                c.msg.tensors.push(TensorUpdate::placeholder());
                            }
                            let (idx, mu_slot, side) = c.msg.tensors[0].sparse_binary_slot();
                            tensor::nonzero_indices_into(&dense, idx);
                            *mu_slot = mu.abs();
                            *side = side_pos;
                            observe(&mut c.trace_buf, "compress_pjrt", t_pjrt, &clock);
                        }
                        finish_client_round(&ctx, c, &acc, loss);
                    }
                } else if workers.is_empty() {
                    let backend = &mut *self.backend;
                    for c in clients.iter_mut() {
                        run_client_round(&ctx, c, &mut acc, &mut |c, master| {
                            backend.local_steps(
                                master,
                                &mut c.opt,
                                delay,
                                lr,
                                c.iterations,
                                c.id,
                                &mut c.rng,
                            )
                        });
                    }
                } else {
                    let chunk_len = pool.chunk_len(clients.len());
                    let mut jobs: Vec<(&mut [ClientState], &mut PoolWorker)> =
                        clients.chunks_mut(chunk_len).zip(workers.iter_mut()).collect();
                    pool.for_each(&mut jobs, |_, (chunk, w)| {
                        let PoolWorker { backend, acc } = &mut **w;
                        for c in chunk.iter_mut() {
                            run_client_round(&ctx, c, acc, &mut |c, master| {
                                backend.local_steps(
                                    master,
                                    &mut c.opt,
                                    ctx.delay,
                                    ctx.lr,
                                    c.iterations,
                                    c.id,
                                    &mut c.rng,
                                )
                            });
                        }
                    });
                }
            }

            // --- deterministic read-back: accounting in client order ----
            let mut train_loss = 0.0f32;
            for (ci, c) in clients.iter_mut().enumerate() {
                for _ in 0..delay {
                    comm.record_baseline_iter(n);
                }
                comm.record_message(c.round_bits, c.round_nnz);
                comm.record_frame_overhead(frame::overhead_bits(c.round_bits));
                round_up_bits[ci] = c.round_bits + frame::overhead_bits(c.round_bits);
                train_loss += c.round_loss;
                // funnel buffered worker observations back in client-index
                // order (same event order as a serial run), and emit the
                // upstream Frame event at exactly the accounting point so
                // trace totals reconcile with CommStats/NetSim
                if let Some(p) = profile.as_mut() {
                    let t_now = clock.now().as_nanos() as u64;
                    for (stage, nanos) in c.trace_buf.drain(..) {
                        p.observe(stage, nanos);
                        cfg.trace.emit_at(t_now, || Event::Stage {
                            round: round as u32,
                            client: ci as u32,
                            stage: stage.to_string(),
                            nanos,
                        });
                    }
                    let (pb, ob) = (c.round_bits, frame::overhead_bits(c.round_bits));
                    cfg.trace.emit_at(t_now, || Event::Frame {
                        role: "server".into(),
                        dir: "up".into(),
                        kind: "update".into(),
                        client: ci as u32,
                        round: round as u32,
                        payload_bits: pb,
                        overhead_bits: ob,
                    });
                }
            }

            // --- phase 2: sharded server aggregation --------------------
            {
                let _t = span("aggregate");
                let t_agg = mark(trace_on, &clock);
                aggregate_sharded(&ClientUpdates(&clients), agg_rule, &agg_pool, &mut delta);
                server_stage(&cfg.trace, &clock, &mut profile, round as u32, "aggregate", t_agg);
            }
            // downstream: re-encode the aggregate exactly as it goes on
            // the wire (sparse when the union support is small, dense
            // otherwise), decode it back, and apply the decoded update —
            // down_bits is the measured broadcast size, not an estimate.
            let down_bits = {
                let _t = span("encode_down");
                let t_down = mark(trace_on, &clock);
                compress_broadcast_into(&delta, round as u32, &mut down_msg);
                let (bytes, bits) = down_wire.encode(&down_msg);
                message::decode_into(bytes, bits, &mut down_decoded)
                    .expect("downstream roundtrip failed");
                server_stage(&cfg.trace, &clock, &mut profile, round as u32, "encode_down", t_down);
                bits
            };
            down_decoded.densify_into(&layout, Granularity::Global, 1.0, &mut delta_rx);
            tensor::add_assign(&mut master, &delta_rx);
            // links carry frames, not bare payloads: netsim costs include
            // the per-frame header/padding overhead in both directions
            comm.record_frame_overhead(frame::overhead_bits(down_bits) * cfg.clients as u64);
            net.round(&round_up_bits, down_bits + frame::overhead_bits(down_bits));
            if trace_on {
                // one broadcast Frame per client: NetSim charges the same
                // down_bits + overhead to every client's downlink
                let oh = frame::overhead_bits(down_bits);
                for ci in 0..cfg.clients {
                    cfg.trace.emit(&clock, || Event::Frame {
                        role: "server".into(),
                        dir: "down".into(),
                        kind: "broadcast".into(),
                        client: ci as u32,
                        round: round as u32,
                        payload_bits: down_bits,
                        overhead_bits: oh,
                    });
                }
                let up_total: u64 = clients.iter().map(|c| c.round_bits).sum();
                let mean_loss = train_loss / cfg.clients as f32;
                cfg.trace.emit(&clock, || Event::RoundEnd {
                    round: round as u32,
                    train_loss: mean_loss,
                    up_bits: up_total,
                    down_bits,
                });
            }

            // --- evaluation ------------------------------------------
            let last = round + 1 == rounds;
            if round % cfg.eval_every_rounds == 0 || last {
                let _t = span("evaluate");
                let t_eval = mark(trace_on, &clock);
                let ev = self.backend.evaluate(&master, cfg.eval_batches);
                server_stage(&cfg.trace, &clock, &mut profile, round as u32, "evaluate", t_eval);
                let metric = if self.backend.is_lm() { ev.loss.exp() } else { ev.metric };
                cfg.trace.emit(&clock, || Event::Eval {
                    round: round as u32,
                    loss: ev.loss,
                    metric,
                });
                let point = CurvePoint {
                    round,
                    iterations: (round + 1) * delay,
                    client_up_bits: clients[0].up_bits,
                    train_loss: train_loss / cfg.clients as f32,
                    eval_loss: ev.loss,
                    metric,
                };
                if cfg.verbose {
                    eprintln!(
                        "[{}] round {round:5} it {:6} lr {lr:.4} loss {:.4} eval {:.4} metric {:.4} upMB {:.3}",
                        cfg.method.label(),
                        point.iterations,
                        point.train_loss,
                        point.eval_loss,
                        point.metric,
                        clients[0].up_bits as f64 / 8e6,
                    );
                }
                log.push(point);
            }

            // --- durable checkpoint at the round barrier ----------------
            if let Some(store) = &store {
                if (round + 1) % cfg.checkpoint.every() == 0 || last {
                    let barrier = (round + 1) as u32;
                    let snap = ServerSnapshot {
                        round: barrier,
                        master: master.clone(),
                        comm: [
                            comm.upstream_bits,
                            comm.messages,
                            comm.nonzeros,
                            comm.baseline_bits,
                            comm.frame_overhead_bits,
                        ],
                        net_clients: net
                            .clients
                            .iter()
                            .map(|c| {
                                (
                                    c.up_bits,
                                    c.down_bits,
                                    c.up_time_s.to_bits(),
                                    c.down_time_s.to_bits(),
                                    c.messages,
                                )
                            })
                            .collect(),
                        net_total_time_bits: net.total_comm_time_s.to_bits(),
                        ledger: vec![round as u32; cfg.clients],
                        cache: None,
                    };
                    let path =
                        store.save_server(&snap, ckpt_digest).expect("checkpoint write failed");
                    let bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
                    for c in clients.iter() {
                        store
                            .save_client(&c.snapshot(barrier, &[]), ckpt_digest)
                            .expect("checkpoint write failed");
                    }
                    cfg.trace.emit(&clock, || Event::Snapshot {
                        role: "trainer".into(),
                        client: SERVER,
                        round: barrier,
                        bytes,
                    });
                    // a kill right after the barrier must still leave a
                    // readable trace up to the snapshot event
                    cfg.trace.flush();
                }
            }
        }

        log.compression = comm.compression_rate();
        log.final_metric = log.points.last().map(|p| p.metric).unwrap_or(f32::NAN);
        log.wall_s = clock.now().saturating_sub(started).as_secs_f64();
        let stage_profile = profile.map(|p| p.finish(rounds as u32));
        cfg.trace.flush();
        TrainResult { log, comm, net, final_params: master, stage_profile }
    }
}

/// Task-appropriate "higher is better" comparison helper for tables.
pub fn better(task: Task, a: f32, b: f32) -> bool {
    match task {
        Task::Classification => a > b,
        Task::Lm => a < b,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::EvalOut;
    use crate::sgd::NativeMlpBackend;

    fn tiny_backend() -> NativeMlpBackend {
        NativeMlpBackend::digits_small(4, 1)
    }

    fn run(method: MethodConfig, iters: usize) -> TrainResult {
        run_par(method, iters, 1)
    }

    fn run_par(method: MethodConfig, iters: usize, parallelism: usize) -> TrainResult {
        let mut be = tiny_backend();
        let mut cfg = TrainConfig::new("mlp-small", method, iters, LrSchedule::constant(0.1));
        cfg.eval_every_rounds = 50;
        cfg.eval_batches = 2;
        cfg.parallelism = parallelism;
        Trainer::new(&mut be, cfg).run()
    }

    #[test]
    fn baseline_learns() {
        let r = run(MethodConfig::baseline(), 60);
        let first = r.log.points.first().unwrap();
        let last = r.log.points.last().unwrap();
        assert!(last.metric > first.metric, "acc {} -> {}", first.metric, last.metric);
        assert!(last.metric > 0.5, "final acc {}", last.metric);
        // dense every iteration: compression ~1 (message overhead only)
        assert!(r.log.compression < 1.05 && r.log.compression > 0.8, "{}", r.log.compression);
    }

    #[test]
    fn sbc_learns_with_huge_compression() {
        let r = run(MethodConfig::sbc2(), 200);
        let last = r.log.points.last().unwrap();
        assert!(last.metric > 0.5, "final acc {}", last.metric);
        assert!(r.log.compression > 500.0, "compression {}", r.log.compression);
    }

    #[test]
    fn fedavg_counts_delay() {
        let r = run(MethodConfig::fedavg(10), 100);
        // 10 rounds of dense messages vs 100 baseline iterations -> ~x10
        assert!(r.log.compression > 8.0 && r.log.compression < 12.0, "{}", r.log.compression);
        assert_eq!(r.comm.messages, 4 * 10);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(MethodConfig::sbc1(), 30);
        let b = run(MethodConfig::sbc1(), 30);
        assert_eq!(a.final_params, b.final_params);
        assert_eq!(a.comm.upstream_bits, b.comm.upstream_bits);
    }

    #[test]
    fn netsim_tracks_rounds() {
        let r = run(MethodConfig::fedavg(10), 100);
        assert_eq!(r.net.clients.len(), 4);
        assert!(r.net.total_comm_time_s > 0.0);
        assert_eq!(r.net.clients[0].messages, 10);
    }

    #[test]
    fn downstream_bits_are_measured_not_estimated() {
        // the broadcast is re-encoded on the wire every round: a sparse
        // method's union support must cost a small fraction of a dense
        // method's block, and every round must broadcast something
        let sparse = run(MethodConfig::sbc1(), 30);
        let dense = run(MethodConfig::baseline(), 30);
        let sparse_down = sparse.net.clients[0].down_bits;
        let dense_down = dense.net.clients[0].down_bits;
        assert!(sparse_down > 0 && dense_down > 0);
        assert!(
            sparse_down < dense_down / 4,
            "sparse broadcast {sparse_down} vs dense {dense_down}"
        );
    }

    /// The tentpole invariant: pooled rounds + sharded aggregation are
    /// bit-identical to the serial loop, for methods covering mean and
    /// majority-vote aggregation, residuals, momentum masking and delay.
    #[test]
    fn parallel_rounds_bit_identical_to_serial() {
        for method in [
            MethodConfig::sbc2(),
            MethodConfig::signsgd(1e-3),
            MethodConfig::gradient_dropping(),
        ] {
            let serial = run_par(method.clone(), 40, 1);
            for threads in [2usize, 3, 8] {
                let par = run_par(method.clone(), 40, threads);
                let a: Vec<u32> = serial.final_params.iter().map(|v| v.to_bits()).collect();
                let b: Vec<u32> = par.final_params.iter().map(|v| v.to_bits()).collect();
                assert_eq!(a, b, "{} threads={threads}", method.label());
                assert_eq!(serial.comm.upstream_bits, par.comm.upstream_bits);
                assert_eq!(serial.comm.nonzeros, par.comm.nonzeros);
                assert_eq!(serial.net.total_up_bits(), par.net.total_up_bits());
                for (ps, pp) in serial.log.points.iter().zip(&par.log.points) {
                    assert_eq!(ps.train_loss.to_bits(), pp.train_loss.to_bits());
                    assert_eq!(ps.metric.to_bits(), pp.metric.to_bits());
                }
            }
        }
    }

    /// Checkpoint/resume invariant: a run that snapshots every barrier is
    /// bit-identical to an untouched run, and a run resumed from a
    /// mid-run generation finishes bit-identical to one that never
    /// stopped — weights and accounting both.
    #[test]
    fn checkpoint_resume_is_bit_identical() {
        let dir =
            std::env::temp_dir().join(format!("sbc-trainer-resume-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mk = |ck: CheckpointCfg| {
            let mut cfg = TrainConfig::new(
                "mlp-small",
                MethodConfig::sbc(0.1, 4),
                40,
                LrSchedule::constant(0.1),
            );
            cfg.eval_every_rounds = 50;
            cfg.eval_batches = 2;
            cfg.checkpoint = ck;
            cfg
        };
        let mut be = tiny_backend();
        let full = Trainer::new(&mut be, mk(CheckpointCfg::default())).run();

        let ck = CheckpointCfg {
            dir: Some(dir.to_string_lossy().into_owned()),
            every_rounds: 1,
            keep: 0,
            resume: false,
        };
        let mut be = tiny_backend();
        let checkpointed = Trainer::new(&mut be, mk(ck.clone())).run();
        assert_eq!(full.final_params, checkpointed.final_params);

        // strip everything after the round-3 barrier so the newest
        // surviving generation is mid-run, then resume against the oracle
        for r in 4..=10u32 {
            let _ = std::fs::remove_file(dir.join(format!("server-r{r:08}.ckpt")));
            for c in 0..4u32 {
                let _ = std::fs::remove_file(dir.join(format!("client{c:04}-r{r:08}.ckpt")));
            }
        }
        let mut be = tiny_backend();
        let resumed =
            Trainer::new(&mut be, mk(CheckpointCfg { resume: true, ..ck })).resume().unwrap();
        let a: Vec<u32> = full.final_params.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = resumed.final_params.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b);
        assert_eq!(full.comm.upstream_bits, resumed.comm.upstream_bits);
        assert_eq!(full.comm.messages, resumed.comm.messages);
        assert_eq!(full.comm.nonzeros, resumed.comm.nonzeros);
        assert_eq!(full.comm.baseline_bits, resumed.comm.baseline_bits);
        assert_eq!(full.comm.frame_overhead_bits, resumed.comm.frame_overhead_bits);
        assert_eq!(
            full.net.total_comm_time_s.to_bits(),
            resumed.net.total_comm_time_s.to_bits()
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn parallelism_beyond_client_count_is_clamped() {
        let serial = run_par(MethodConfig::sbc1(), 20, 1);
        let par = run_par(MethodConfig::sbc1(), 20, 64); // 4 clients only
        assert_eq!(serial.final_params, par.final_params);
    }

    /// A backend that refuses to fork must fall back to the serial loop
    /// (and still produce identical results).
    struct NoFork(NativeMlpBackend);

    impl TrainBackend for NoFork {
        fn n_params(&self) -> usize {
            self.0.n_params()
        }
        fn opt_size(&self) -> usize {
            self.0.opt_size()
        }
        fn layout(&self) -> &TensorLayout {
            self.0.layout()
        }
        fn is_lm(&self) -> bool {
            self.0.is_lm()
        }
        fn init_params(&mut self, seed: u64) -> Vec<f32> {
            self.0.init_params(seed)
        }
        #[allow(clippy::too_many_arguments)]
        fn local_steps(
            &mut self,
            params: &[f32],
            opt: &mut [f32],
            steps: usize,
            lr: f32,
            t0: usize,
            client: usize,
            rng: &mut Rng,
        ) -> (Vec<f32>, f32) {
            self.0.local_steps(params, opt, steps, lr, t0, client, rng)
        }
        fn evaluate(&mut self, params: &[f32], max_batches: usize) -> EvalOut {
            self.0.evaluate(params, max_batches)
        }
    }

    #[test]
    fn unforkable_backend_falls_back_to_serial() {
        let mut cfg = TrainConfig::new(
            "mlp-small",
            MethodConfig::sbc1(),
            20,
            LrSchedule::constant(0.1),
        );
        cfg.eval_every_rounds = 50;
        cfg.eval_batches = 2;
        cfg.parallelism = 4;
        let mut be = NoFork(tiny_backend());
        let r = Trainer::new(&mut be, cfg).run();
        let serial = run_par(MethodConfig::sbc1(), 20, 1);
        assert_eq!(r.final_params, serial.final_params);
    }
}
