//! The distributed training driver — paper Algorithm 1 end to end.
//!
//! One [`Trainer::run`] call executes a full DSGD training: every round,
//! every participating client runs `delay` local iterations on its shard,
//! forms the accumulated update (residual + delta), compresses it, puts
//! the message *on the wire* (bit-exact encode), the server decodes and
//! aggregates, and everyone synchronizes. All reported bits are measured
//! on the encoded messages.

use std::time::Instant;

use crate::codec::accounting::CommStats;
use crate::codec::message::{self, PosCodec};
use crate::compression::momentum_mask::mask_momentum;
use crate::compression::registry::{Method, MethodConfig};
use crate::compression::TensorUpdate;
use crate::coordinator::aggregation::{aggregate, densify, AggRule};
use crate::coordinator::client::ClientState;
use crate::coordinator::schedule::LrSchedule;
use crate::coordinator::TrainBackend;
use crate::metrics::{CurvePoint, RunLog};
use crate::model::Task;
use crate::netsim::{Link, NetSim};
use crate::util::rng::Rng;
use crate::util::tensor;
use crate::util::timer::span;

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub model: String,
    pub method: MethodConfig,
    pub clients: usize,
    /// Total local iterations per client (paper's x-axis). Rounds =
    /// iterations / delay.
    pub iterations: usize,
    pub lr: LrSchedule,
    /// Evaluate every this many *rounds* (also logs a curve point).
    pub eval_every_rounds: usize,
    pub eval_batches: usize,
    pub seed: u64,
    pub pos_codec: PosCodec,
    /// Route SBC compression through the AOT Pallas graph when available.
    pub use_pjrt_compress: bool,
    pub uplink: Link,
    pub downlink: Link,
    pub verbose: bool,
}

impl TrainConfig {
    pub fn new(model: &str, method: MethodConfig, iterations: usize, lr: LrSchedule) -> Self {
        TrainConfig {
            model: model.to_string(),
            method,
            clients: 4, // the paper fixes 4 clients throughout
            iterations,
            lr,
            eval_every_rounds: 10,
            eval_batches: 4,
            seed: 42,
            pos_codec: PosCodec::Golomb,
            use_pjrt_compress: false,
            uplink: Link::wifi(),
            downlink: Link::wifi(),
            verbose: false,
        }
    }
}

/// Result of one training run.
pub struct TrainResult {
    pub log: RunLog,
    pub comm: CommStats,
    pub net: NetSim,
    pub final_params: Vec<f32>,
}

pub struct Trainer<'a, B: TrainBackend> {
    pub backend: &'a mut B,
    pub cfg: TrainConfig,
}

impl<'a, B: TrainBackend> Trainer<'a, B> {
    pub fn new(backend: &'a mut B, cfg: TrainConfig) -> Self {
        Trainer { backend, cfg }
    }

    pub fn run(&mut self) -> TrainResult {
        let seed = self.cfg.seed;
        let init = self.backend.init_params(seed);
        self.run_from(init)
    }

    /// Run from explicit initial master weights (warm start — used by the
    /// adaptive-sparsity schedule to chain phases).
    pub fn run_from(&mut self, initial: Vec<f32>) -> TrainResult {
        let cfg = self.cfg.clone();
        let n = self.backend.n_params();
        let layout = self.backend.layout().clone();
        let opt_size = self.backend.opt_size();
        let root = Rng::new(cfg.seed);
        let started = Instant::now();

        assert_eq!(initial.len(), n, "initial params length mismatch");
        let mut master = initial;
        let default_residual = cfg.method.build(0).uses_residual();
        let use_residual = cfg.method.use_residual(default_residual);
        let mut clients: Vec<ClientState> = (0..cfg.clients)
            .map(|i| {
                ClientState::new(
                    i,
                    n,
                    opt_size,
                    use_residual,
                    cfg.method.build(cfg.seed ^ (0xC11E + i as u64)),
                    &root,
                )
            })
            .collect();

        let agg_rule = AggRule::for_method(&cfg.method);
        let sign_scale = cfg.method.build(0).sign_scale();
        let delay = cfg.method.delay;
        let rounds = (cfg.iterations / delay).max(1);
        let mut comm = CommStats::default();
        let mut net = NetSim::new(cfg.uplink, cfg.downlink, cfg.clients);
        let mut log = RunLog {
            model: cfg.model.clone(),
            method: cfg.method.label(),
            seed: cfg.seed,
            ..Default::default()
        };

        let is_sbc_pjrt = cfg.use_pjrt_compress
            && matches!(cfg.method.method, Method::Sbc { .. });

        let mut acc = vec![0.0f32; n];
        for round in 0..rounds {
            let lr = cfg.lr.at(round * delay);
            let mut updates: Vec<Vec<f32>> = Vec::with_capacity(cfg.clients);
            let mut round_up_bits = vec![0u64; cfg.clients];
            let mut train_loss = 0.0f32;

            for ci in 0..cfg.clients {
                // --- local training ---------------------------------
                let (w_new, loss) = {
                    let _t = span("local_steps");
                    let c = &mut clients[ci];
                    self.backend.local_steps(
                        &master,
                        &mut c.opt,
                        delay,
                        lr,
                        c.iterations,
                        ci,
                        &mut c.rng,
                    )
                };
                train_loss += loss;
                let c = &mut clients[ci];
                c.iterations += delay;
                for _ in 0..delay {
                    comm.record_baseline_iter(n);
                }

                // --- accumulate + compress --------------------------
                {
                    let _t = span("compress");
                    tensor::sub_into(&mut acc, &w_new, &master);
                    c.residual.accumulate_into(&mut acc);
                }
                let msg = if is_sbc_pjrt {
                    // route through the AOT Pallas kernel graph
                    let p = match cfg.method.method {
                        Method::Sbc { p, .. } => p as f32,
                        _ => unreachable!(),
                    };
                    let _t = span("compress_pjrt");
                    let (dense, _t_thr, mu, side_pos) = self
                        .backend
                        .compress_pjrt(&acc, p)
                        .expect("backend has no pjrt compress graph");
                    let idx: Vec<u32> = dense
                        .iter()
                        .enumerate()
                        .filter(|(_, v)| **v != 0.0)
                        .map(|(i, _)| i as u32)
                        .collect();
                    crate::compression::UpdateMsg {
                        round: round as u32,
                        tensors: vec![TensorUpdate::SparseBinary { idx, mu: mu.abs(), side_pos }],
                    }
                } else {
                    let _t = span("compress");
                    c.compressor.compress(&acc, &layout, round as u32)
                };

                // --- encode: the bits that actually cross the wire ---
                let (bytes, bits) = {
                    let _t = span("encode");
                    message::encode(&msg, cfg.pos_codec)
                };
                let nnz: usize = msg.tensors.iter().map(|t| t.nonzeros()).sum();
                comm.record_message(bits, nnz as u64);
                c.up_bits += bits;
                round_up_bits[ci] = bits;

                // --- server-side decode (bit-true path) --------------
                let decoded = {
                    let _t = span("decode");
                    message::decode(&bytes, bits).expect("wire roundtrip failed")
                };
                let mut dense = {
                    let _t = span("densify");
                    if is_sbc_pjrt {
                        decoded.to_dense(&crate::model::TensorLayout::flat(n), sign_scale)
                    } else {
                        densify(&decoded, &cfg.method, &layout, sign_scale)
                    }
                };
                // keep exactly what was decoded; residual vs transmitted
                c.residual.update(&acc, &dense);

                if cfg.method.momentum_masking {
                    let idx: Vec<u32> = dense
                        .iter()
                        .enumerate()
                        .filter(|(_, v)| **v != 0.0)
                        .map(|(i, _)| i as u32)
                        .collect();
                    mask_momentum(&mut c.opt, n, &idx);
                }
                if matches!(agg_rule, AggRule::MajoritySign { .. }) {
                    // majority vote wants raw ±1 votes, not ±scale
                    for v in dense.iter_mut() {
                        *v = v.signum();
                    }
                }
                updates.push(dense);
            }

            // --- server aggregation + broadcast ----------------------
            let delta = {
                let _t = span("aggregate");
                aggregate(&updates, agg_rule)
            };
            tensor::add_assign(&mut master, &delta);
            // downstream: the server re-encodes the aggregated update —
            // sparse (union of client supports) when that is cheaper than
            // a dense broadcast, exactly as it would go on the wire.
            let down_bits = {
                let _t = span("encode_down");
                let nnz = delta.iter().filter(|v| **v != 0.0).count();
                let sparse_estimate = nnz as u64 * (32 + 16) + 64;
                if sparse_estimate < 32 * n as u64 {
                    let idx: Vec<u32> = delta
                        .iter()
                        .enumerate()
                        .filter(|(_, v)| **v != 0.0)
                        .map(|(i, _)| i as u32)
                        .collect();
                    let val: Vec<f32> = idx.iter().map(|&i| delta[i as usize]).collect();
                    let down_msg = crate::compression::UpdateMsg {
                        round: round as u32,
                        tensors: vec![TensorUpdate::SparseF32 { idx, val }],
                    };
                    message::encode(&down_msg, cfg.pos_codec).1
                } else {
                    32 * n as u64
                }
            };
            net.round(&round_up_bits, down_bits);

            // --- evaluation ------------------------------------------
            let last = round + 1 == rounds;
            if round % cfg.eval_every_rounds == 0 || last {
                let _t = span("evaluate");
                let ev = self.backend.evaluate(&master, cfg.eval_batches);
                let metric = if self.backend.is_lm() { ev.loss.exp() } else { ev.metric };
                let point = CurvePoint {
                    round,
                    iterations: (round + 1) * delay,
                    client_up_bits: clients[0].up_bits,
                    train_loss: train_loss / cfg.clients as f32,
                    eval_loss: ev.loss,
                    metric,
                };
                if cfg.verbose {
                    eprintln!(
                        "[{}] round {round:5} it {:6} lr {lr:.4} loss {:.4} eval {:.4} metric {:.4} upMB {:.3}",
                        cfg.method.label(),
                        point.iterations,
                        point.train_loss,
                        point.eval_loss,
                        point.metric,
                        clients[0].up_bits as f64 / 8e6,
                    );
                }
                log.push(point);
            }
        }

        log.compression = comm.compression_rate();
        log.final_metric = log.points.last().map(|p| p.metric).unwrap_or(f32::NAN);
        log.wall_s = started.elapsed().as_secs_f64();
        TrainResult { log, comm, net, final_params: master }
    }
}

/// Task-appropriate "higher is better" comparison helper for tables.
pub fn better(task: Task, a: f32, b: f32) -> bool {
    match task {
        Task::Classification => a > b,
        Task::Lm => a < b,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sgd::NativeMlpBackend;

    fn tiny_backend() -> NativeMlpBackend {
        NativeMlpBackend::digits_small(4, 1)
    }

    fn run(method: MethodConfig, iters: usize) -> TrainResult {
        let mut be = tiny_backend();
        let mut cfg = TrainConfig::new("mlp-small", method, iters, LrSchedule::constant(0.1));
        cfg.eval_every_rounds = 50;
        cfg.eval_batches = 2;
        Trainer::new(&mut be, cfg).run()
    }

    #[test]
    fn baseline_learns() {
        let r = run(MethodConfig::baseline(), 60);
        let first = r.log.points.first().unwrap();
        let last = r.log.points.last().unwrap();
        assert!(last.metric > first.metric, "acc {} -> {}", first.metric, last.metric);
        assert!(last.metric > 0.5, "final acc {}", last.metric);
        // dense every iteration: compression ~1 (message overhead only)
        assert!(r.log.compression < 1.05 && r.log.compression > 0.8, "{}", r.log.compression);
    }

    #[test]
    fn sbc_learns_with_huge_compression() {
        let r = run(MethodConfig::sbc2(), 200);
        let last = r.log.points.last().unwrap();
        assert!(last.metric > 0.5, "final acc {}", last.metric);
        assert!(r.log.compression > 500.0, "compression {}", r.log.compression);
    }

    #[test]
    fn fedavg_counts_delay() {
        let r = run(MethodConfig::fedavg(10), 100);
        // 10 rounds of dense messages vs 100 baseline iterations -> ~x10
        assert!(r.log.compression > 8.0 && r.log.compression < 12.0, "{}", r.log.compression);
        assert_eq!(r.comm.messages, 4 * 10);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(MethodConfig::sbc1(), 30);
        let b = run(MethodConfig::sbc1(), 30);
        assert_eq!(a.final_params, b.final_params);
        assert_eq!(a.comm.upstream_bits, b.comm.upstream_bits);
    }

    #[test]
    fn netsim_tracks_rounds() {
        let r = run(MethodConfig::fedavg(10), 100);
        assert_eq!(r.net.clients.len(), 4);
        assert!(r.net.total_comm_time_s > 0.0);
        assert_eq!(r.net.clients[0].messages, 10);
    }
}
