//! The distributed training driver — paper Algorithm 1 end to end.
//!
//! One [`Trainer::run`] call executes a full DSGD training: every round,
//! every participating client runs `delay` local iterations on its shard,
//! forms the accumulated update (residual + fresh delta), runs the staged
//! compression pipeline (Select → Quantize → Encode), puts the message
//! *on the wire* (bit-exact encode), the server decodes and aggregates,
//! re-encodes the aggregate for the downstream broadcast, and everyone
//! synchronizes. All reported bits — upstream *and* downstream — are
//! measured on the encoded messages.
//!
//! The round loop is allocation-free in steady state: each client owns
//! reusable scratch (message, decode target, densified update, encode
//! buffer — see [`ClientState`]), and the server reuses its aggregate,
//! broadcast-message and broadcast-decode buffers across rounds.

use std::time::Instant;

use crate::codec::accounting::CommStats;
use crate::codec::message::{self, PosCodec, WireCodec};
use crate::compression::momentum_mask::mask_momentum;
use crate::compression::pipeline::compress_broadcast_into;
use crate::compression::registry::MethodConfig;
use crate::compression::{Granularity, TensorUpdate, UpdateMsg};
use crate::coordinator::aggregation::{aggregate_into, AggRule};
use crate::coordinator::client::ClientState;
use crate::coordinator::schedule::LrSchedule;
use crate::coordinator::TrainBackend;
use crate::metrics::{CurvePoint, RunLog};
use crate::model::Task;
use crate::netsim::{Link, NetSim};
use crate::util::rng::Rng;
use crate::util::tensor;
use crate::util::timer::span;

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub model: String,
    pub method: MethodConfig,
    pub clients: usize,
    /// Total local iterations per client (paper's x-axis). Rounds =
    /// iterations / delay.
    pub iterations: usize,
    pub lr: LrSchedule,
    /// Evaluate every this many *rounds* (also logs a curve point).
    pub eval_every_rounds: usize,
    pub eval_batches: usize,
    pub seed: u64,
    pub pos_codec: PosCodec,
    /// Route SBC compression through the AOT Pallas graph when available.
    pub use_pjrt_compress: bool,
    pub uplink: Link,
    pub downlink: Link,
    pub verbose: bool,
}

impl TrainConfig {
    pub fn new(model: &str, method: MethodConfig, iterations: usize, lr: LrSchedule) -> Self {
        TrainConfig {
            model: model.to_string(),
            method,
            clients: 4, // the paper fixes 4 clients throughout
            iterations,
            lr,
            eval_every_rounds: 10,
            eval_batches: 4,
            seed: 42,
            pos_codec: PosCodec::Golomb,
            use_pjrt_compress: false,
            uplink: Link::wifi(),
            downlink: Link::wifi(),
            verbose: false,
        }
    }
}

/// Result of one training run.
pub struct TrainResult {
    pub log: RunLog,
    pub comm: CommStats,
    pub net: NetSim,
    pub final_params: Vec<f32>,
}

pub struct Trainer<'a, B: TrainBackend> {
    pub backend: &'a mut B,
    pub cfg: TrainConfig,
}

impl<'a, B: TrainBackend> Trainer<'a, B> {
    pub fn new(backend: &'a mut B, cfg: TrainConfig) -> Self {
        Trainer { backend, cfg }
    }

    pub fn run(&mut self) -> TrainResult {
        let seed = self.cfg.seed;
        let init = self.backend.init_params(seed);
        self.run_from(init)
    }

    /// Run from explicit initial master weights (warm start — used by the
    /// adaptive-sparsity schedule to chain phases).
    pub fn run_from(&mut self, initial: Vec<f32>) -> TrainResult {
        let cfg = self.cfg.clone();
        let n = self.backend.n_params();
        let layout = self.backend.layout().clone();
        let opt_size = self.backend.opt_size();
        let root = Rng::new(cfg.seed);
        let started = Instant::now();

        assert_eq!(initial.len(), n, "initial params length mismatch");
        let mut master = initial;
        let use_residual = cfg.method.use_residual();
        let mut clients: Vec<ClientState> = (0..cfg.clients)
            .map(|i| {
                ClientState::new(
                    i,
                    n,
                    opt_size,
                    use_residual,
                    cfg.method.build(cfg.seed ^ (0xC11E + i as u64)),
                    cfg.pos_codec,
                    &root,
                )
            })
            .collect();

        let agg_rule = AggRule::for_method(&cfg.method);
        let sign_scale = cfg.method.sign_scale();
        let delay = cfg.method.delay;
        let rounds = (cfg.iterations / delay).max(1);
        let mut comm = CommStats::default();
        let mut net = NetSim::new(cfg.uplink, cfg.downlink, cfg.clients);
        let mut log = RunLog {
            model: cfg.model.clone(),
            method: cfg.method.label(),
            seed: cfg.seed,
            ..Default::default()
        };

        let is_sbc_pjrt = cfg.use_pjrt_compress && cfg.method.sbc_p().is_some();
        // the PJRT compress graph emits one whole-vector tensor
        let densify_gran =
            if is_sbc_pjrt { Granularity::Global } else { cfg.method.granularity };

        // round-persistent scratch: client accumulator, server aggregate,
        // broadcast wire buffers — allocated once, reused every round
        let mut acc = vec![0.0f32; n];
        let mut delta = vec![0.0f32; n];
        let mut delta_rx = vec![0.0f32; n];
        let mut round_up_bits = vec![0u64; cfg.clients];
        let mut down_wire = WireCodec::new(cfg.pos_codec);
        let mut down_msg = UpdateMsg::scratch();
        let mut down_decoded = UpdateMsg::scratch();

        for round in 0..rounds {
            let lr = cfg.lr.at(round * delay);
            let mut train_loss = 0.0f32;

            for ci in 0..cfg.clients {
                // --- local training ---------------------------------
                let (w_new, loss) = {
                    let _t = span("local_steps");
                    let c = &mut clients[ci];
                    self.backend.local_steps(
                        &master,
                        &mut c.opt,
                        delay,
                        lr,
                        c.iterations,
                        ci,
                        &mut c.rng,
                    )
                };
                train_loss += loss;
                let c = &mut clients[ci];
                c.iterations += delay;
                for _ in 0..delay {
                    comm.record_baseline_iter(n);
                }

                // --- accumulate + compress --------------------------
                {
                    let _t = span("compress");
                    tensor::sub_into(&mut acc, &w_new, &master);
                    c.residual.accumulate_into(&mut acc);
                }
                if is_sbc_pjrt {
                    // route through the AOT Pallas kernel graph
                    let p = cfg.method.sbc_p().unwrap() as f32;
                    let _t = span("compress_pjrt");
                    let (dense, _thr, mu, side_pos) = self
                        .backend
                        .compress_pjrt(&acc, p)
                        .expect("backend has no pjrt compress graph");
                    c.msg.round = round as u32;
                    c.msg.tensors.truncate(1);
                    if c.msg.tensors.is_empty() {
                        c.msg.tensors.push(TensorUpdate::placeholder());
                    }
                    let (idx, mu_slot, side) = c.msg.tensors[0].sparse_binary_slot();
                    tensor::nonzero_indices_into(&dense, idx);
                    *mu_slot = mu.abs();
                    *side = side_pos;
                } else {
                    let _t = span("compress");
                    c.pipeline.compress_into(&acc, &layout, round as u32, &mut c.msg);
                }

                // --- wire: the bits that actually cross, both ways ---
                let nnz: usize = c.msg.tensors.iter().map(|t| t.nonzeros()).sum();
                let bits = {
                    let (bytes, bits) = {
                        let _t = span("encode");
                        c.wire.encode(&c.msg)
                    };
                    let _t = span("decode");
                    message::decode_into(bytes, bits, &mut c.decoded)
                        .expect("wire roundtrip failed");
                    bits
                };
                comm.record_message(bits, nnz as u64);
                c.up_bits += bits;
                round_up_bits[ci] = bits;

                // --- server-side densify into the client's reusable
                // buffer; residual vs exactly what was decoded ---------
                {
                    let _t = span("densify");
                    c.decoded.densify_into(&layout, densify_gran, sign_scale, &mut c.dense);
                }
                c.residual.update(&acc, &c.dense);

                if cfg.method.momentum_masking {
                    tensor::nonzero_indices_into(&c.dense, &mut c.mask_idx);
                    mask_momentum(&mut c.opt, n, &c.mask_idx);
                }
                if matches!(agg_rule, AggRule::MajoritySign { .. }) {
                    // majority vote wants raw ±1 votes, not ±scale
                    for v in c.dense.iter_mut() {
                        *v = v.signum();
                    }
                }
            }

            // --- server aggregation + bit-true broadcast --------------
            {
                let _t = span("aggregate");
                aggregate_into(clients.iter().map(|c| c.dense.as_slice()), agg_rule, &mut delta);
            }
            // downstream: re-encode the aggregate exactly as it goes on
            // the wire (sparse when the union support is small, dense
            // otherwise), decode it back, and apply the decoded update —
            // down_bits is the measured broadcast size, not an estimate.
            let down_bits = {
                let _t = span("encode_down");
                compress_broadcast_into(&delta, round as u32, &mut down_msg);
                let (bytes, bits) = down_wire.encode(&down_msg);
                message::decode_into(bytes, bits, &mut down_decoded)
                    .expect("downstream roundtrip failed");
                bits
            };
            down_decoded.densify_into(&layout, Granularity::Global, 1.0, &mut delta_rx);
            tensor::add_assign(&mut master, &delta_rx);
            net.round(&round_up_bits, down_bits);

            // --- evaluation ------------------------------------------
            let last = round + 1 == rounds;
            if round % cfg.eval_every_rounds == 0 || last {
                let _t = span("evaluate");
                let ev = self.backend.evaluate(&master, cfg.eval_batches);
                let metric = if self.backend.is_lm() { ev.loss.exp() } else { ev.metric };
                let point = CurvePoint {
                    round,
                    iterations: (round + 1) * delay,
                    client_up_bits: clients[0].up_bits,
                    train_loss: train_loss / cfg.clients as f32,
                    eval_loss: ev.loss,
                    metric,
                };
                if cfg.verbose {
                    eprintln!(
                        "[{}] round {round:5} it {:6} lr {lr:.4} loss {:.4} eval {:.4} metric {:.4} upMB {:.3}",
                        cfg.method.label(),
                        point.iterations,
                        point.train_loss,
                        point.eval_loss,
                        point.metric,
                        clients[0].up_bits as f64 / 8e6,
                    );
                }
                log.push(point);
            }
        }

        log.compression = comm.compression_rate();
        log.final_metric = log.points.last().map(|p| p.metric).unwrap_or(f32::NAN);
        log.wall_s = started.elapsed().as_secs_f64();
        TrainResult { log, comm, net, final_params: master }
    }
}

/// Task-appropriate "higher is better" comparison helper for tables.
pub fn better(task: Task, a: f32, b: f32) -> bool {
    match task {
        Task::Classification => a > b,
        Task::Lm => a < b,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sgd::NativeMlpBackend;

    fn tiny_backend() -> NativeMlpBackend {
        NativeMlpBackend::digits_small(4, 1)
    }

    fn run(method: MethodConfig, iters: usize) -> TrainResult {
        let mut be = tiny_backend();
        let mut cfg = TrainConfig::new("mlp-small", method, iters, LrSchedule::constant(0.1));
        cfg.eval_every_rounds = 50;
        cfg.eval_batches = 2;
        Trainer::new(&mut be, cfg).run()
    }

    #[test]
    fn baseline_learns() {
        let r = run(MethodConfig::baseline(), 60);
        let first = r.log.points.first().unwrap();
        let last = r.log.points.last().unwrap();
        assert!(last.metric > first.metric, "acc {} -> {}", first.metric, last.metric);
        assert!(last.metric > 0.5, "final acc {}", last.metric);
        // dense every iteration: compression ~1 (message overhead only)
        assert!(r.log.compression < 1.05 && r.log.compression > 0.8, "{}", r.log.compression);
    }

    #[test]
    fn sbc_learns_with_huge_compression() {
        let r = run(MethodConfig::sbc2(), 200);
        let last = r.log.points.last().unwrap();
        assert!(last.metric > 0.5, "final acc {}", last.metric);
        assert!(r.log.compression > 500.0, "compression {}", r.log.compression);
    }

    #[test]
    fn fedavg_counts_delay() {
        let r = run(MethodConfig::fedavg(10), 100);
        // 10 rounds of dense messages vs 100 baseline iterations -> ~x10
        assert!(r.log.compression > 8.0 && r.log.compression < 12.0, "{}", r.log.compression);
        assert_eq!(r.comm.messages, 4 * 10);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(MethodConfig::sbc1(), 30);
        let b = run(MethodConfig::sbc1(), 30);
        assert_eq!(a.final_params, b.final_params);
        assert_eq!(a.comm.upstream_bits, b.comm.upstream_bits);
    }

    #[test]
    fn netsim_tracks_rounds() {
        let r = run(MethodConfig::fedavg(10), 100);
        assert_eq!(r.net.clients.len(), 4);
        assert!(r.net.total_comm_time_s > 0.0);
        assert_eq!(r.net.clients[0].messages, 10);
    }

    #[test]
    fn downstream_bits_are_measured_not_estimated() {
        // the broadcast is re-encoded on the wire every round: a sparse
        // method's union support must cost a small fraction of a dense
        // method's block, and every round must broadcast something
        let sparse = run(MethodConfig::sbc1(), 30);
        let dense = run(MethodConfig::baseline(), 30);
        let sparse_down = sparse.net.clients[0].down_bits;
        let dense_down = dense.net.clients[0].down_bits;
        assert!(sparse_down > 0 && dense_down > 0);
        assert!(
            sparse_down < dense_down / 4,
            "sparse broadcast {sparse_down} vs dense {dense_down}"
        );
    }
}
