//! Real federation transport: framed connections carrying wire-format-v2
//! messages between separate processes (or threads), so SBC's bit counts
//! correspond to bytes that genuinely cross a socket.
//!
//! Layering (bottom-up):
//!
//! * [`frame`] — length-prefixed, CRC-checked frames around the payload
//!   bits produced by [`crate::codec::message`];
//! * [`Transport`] / [`Acceptor`] / [`Connector`] — the connection
//!   abstraction, with two std-only implementations:
//!   [`loopback::LoopbackHub`] (deterministic in-memory pipes with byte
//!   counters and a fault hook) and [`tcp`] (`std::net`);
//! * [`server::FederatedServer`] — accept loop + synchronous round
//!   aggregation reusing [`crate::coordinator::aggregation`];
//! * [`session`] — the remote client loop (bit-identical to the
//!   in-process trainer's client phase) with bounded retry-with-backoff,
//!   plus the [`session::run_federated`] driver.
//!
//! See `ARCHITECTURE.md` §Transport for the frame layout and the
//! handshake/retry state machines.

pub mod frame;
pub mod loopback;
pub mod server;
pub mod session;
pub mod tcp;

use std::fmt;
use std::io;
use std::time::Duration;

use crate::coordinator::trainer::TrainConfig;
use frame::{read_frame, write_frame, FrameBuf};

/// The wire-format version this build encodes and the handshake
/// advertises ([`crate::codec::message`] v2). The golden-bytes regression
/// test pins the actual encoding to this constant so the two cannot
/// silently drift.
pub use crate::codec::message::WIRE_VERSION;

/// Everything that can go wrong on a federation connection. Every
/// malformed or hostile input from the peer maps to one of these — no
/// socket input can panic the process.
#[derive(Debug)]
pub enum TransportError {
    /// Underlying I/O failure (connect refused, reset, timeout, EOF).
    Io(io::Error),
    /// A frame failed structural validation (magic, length bounds, CRC).
    BadFrame(String),
    /// The peer speaks a different frame-protocol version.
    VersionMismatch {
        /// Our protocol version.
        ours: u8,
        /// The version in the incoming frame.
        theirs: u8,
    },
    /// The server refused the handshake (config/wire/id mismatch).
    Rejected(String),
    /// The peer violated the federation protocol (unexpected frame kind,
    /// undecodable payload, inconsistent round).
    Protocol(String),
    /// The retry budget was exhausted without completing the exchange.
    RetriesExhausted {
        /// Connection attempts made (initial try + retries).
        attempts: u32,
        /// The error that ended the final attempt.
        last: Box<TransportError>,
    },
    /// The endpoint was shut down (acceptor closed, hub drained).
    Closed,
    /// Waited longer than the configured round timeout for a peer.
    Timeout(String),
    /// The process was killed at a scheduled crash point (simulation-
    /// injected; the supervisor's cue to restart-and-resume from the
    /// last checkpoint). Carries the round the kill fired at.
    Killed(u32),
    /// A durable checkpoint could not be written or restored
    /// ([`crate::persist`]).
    Persist(crate::persist::PersistError),
}

impl fmt::Display for TransportError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TransportError::Io(e) => write!(f, "i/o: {e}"),
            TransportError::BadFrame(m) => write!(f, "bad frame: {m}"),
            TransportError::VersionMismatch { ours, theirs } => {
                write!(f, "protocol version mismatch: ours {ours}, theirs {theirs}")
            }
            TransportError::Rejected(m) => write!(f, "rejected by server: {m}"),
            TransportError::Protocol(m) => write!(f, "protocol violation: {m}"),
            TransportError::RetriesExhausted { attempts, last } => {
                write!(f, "retries exhausted after {attempts} attempts (last: {last})")
            }
            TransportError::Closed => write!(f, "endpoint closed"),
            TransportError::Timeout(m) => write!(f, "timed out: {m}"),
            TransportError::Killed(r) => write!(f, "killed at round {r} (scheduled crash)"),
            TransportError::Persist(e) => write!(f, "checkpoint: {e}"),
        }
    }
}

impl std::error::Error for TransportError {}

impl From<io::Error> for TransportError {
    fn from(e: io::Error) -> Self {
        TransportError::Io(e)
    }
}

impl From<crate::persist::PersistError> for TransportError {
    fn from(e: crate::persist::PersistError) -> Self {
        TransportError::Persist(e)
    }
}

impl TransportError {
    /// Whether retrying on a fresh connection could help. Handshake
    /// rejections and protocol violations are deterministic — retrying
    /// them would loop forever — while I/O failures and corrupt frames
    /// are transient.
    pub fn is_retryable(&self) -> bool {
        matches!(
            self,
            TransportError::Io(_) | TransportError::BadFrame(_) | TransportError::Closed
        )
    }
}

/// One established, framed, bidirectional connection.
pub trait Transport: Send {
    /// Write one frame (blocking, flushed).
    fn send(&mut self, f: &FrameBuf) -> Result<(), TransportError>;
    /// Read one frame into `into` (blocking, honors the read timeout).
    fn recv(&mut self, into: &mut FrameBuf) -> Result<(), TransportError>;
    /// Human-readable peer label for errors and logs.
    fn peer(&self) -> String;
}

/// Server side of connection establishment.
pub trait Acceptor: Send + Sync {
    /// Block until the next inbound connection (or shutdown).
    fn accept(&self) -> Result<Box<dyn Transport>, TransportError>;
    /// Unblock pending accepts; subsequent accepts fail with
    /// [`TransportError::Closed`].
    fn shutdown(&self);
}

/// Client side of connection establishment. `Sync` so one connector can
/// serve a client across reconnects from its session thread.
pub trait Connector: Send + Sync {
    /// Establish a fresh connection (honoring the connect timeout).
    fn connect(&self) -> Result<Box<dyn Transport>, TransportError>;
}

/// Timeouts and retry budget for federation connections — carried in
/// [`TrainConfig`] so TOML configs and the CLI can set them.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TransportCfg {
    /// TCP connect timeout per attempt.
    pub connect_timeout: Duration,
    /// Blocking-read timeout on established connections.
    pub read_timeout: Duration,
    /// Reconnect attempts per round exchange after the initial try.
    pub max_retries: u32,
    /// Backoff before the first retry; doubles each subsequent retry.
    pub retry_backoff: Duration,
    /// How long the server waits for a round's worth of client updates.
    pub round_timeout: Duration,
}

impl Default for TransportCfg {
    fn default() -> Self {
        TransportCfg {
            connect_timeout: Duration::from_secs(5),
            read_timeout: Duration::from_secs(30),
            max_retries: 3,
            retry_backoff: Duration::from_millis(50),
            round_timeout: Duration::from_secs(60),
        }
    }
}

/// A [`Transport`] over any blocking byte stream (TCP socket, loopback
/// pipe): frames go through [`frame::write_frame`] / [`frame::read_frame`]
/// unchanged, so both implementations share one wire layout.
pub struct FramedConn<S: io::Read + io::Write + Send> {
    stream: S,
    peer: String,
}

impl<S: io::Read + io::Write + Send> FramedConn<S> {
    /// Wrap a connected stream.
    pub fn new(stream: S, peer: String) -> Self {
        FramedConn { stream, peer }
    }
}

impl<S: io::Read + io::Write + Send> Transport for FramedConn<S> {
    fn send(&mut self, f: &FrameBuf) -> Result<(), TransportError> {
        write_frame(&mut self.stream, f)
    }

    fn recv(&mut self, into: &mut FrameBuf) -> Result<(), TransportError> {
        read_frame(&mut self.stream, into)
    }

    fn peer(&self) -> String {
        self.peer.clone()
    }
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv1a(h: u64, bytes: &[u8]) -> u64 {
    let mut h = h;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

/// FNV-1a digest over the exact bit patterns of a weight vector — the
/// bit-identity check between federated and in-process training (equal
/// digests ⇒ equal `f32::to_bits` sequences, NaN payloads included).
pub fn weight_digest(w: &[f32]) -> u64 {
    let mut h = FNV_OFFSET;
    for &x in w {
        h = fnv1a(h, &x.to_bits().to_le_bytes());
    }
    h
}

/// Digest of everything both sides must agree on for the run to be
/// bit-identical: method composition, seed, fleet size, iteration budget,
/// position codec and learning-rate schedule. Exchanged in the handshake
/// so a misconfigured client is rejected up front instead of silently
/// producing a diverged model.
pub fn config_digest(cfg: &TrainConfig) -> u64 {
    let canon = format!(
        "{:?}|{}|{}|{}|{:?}|{:?}",
        cfg.method, cfg.seed, cfg.clients, cfg.iterations, cfg.pos_codec, cfg.lr
    );
    fnv1a(FNV_OFFSET, canon.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::registry::MethodConfig;
    use crate::coordinator::schedule::LrSchedule;

    #[test]
    fn weight_digest_is_bit_sensitive() {
        let a = weight_digest(&[1.0, 2.0, 3.0]);
        assert_eq!(a, weight_digest(&[1.0, 2.0, 3.0]));
        assert_ne!(a, weight_digest(&[1.0, 2.0, 3.0000001]));
        assert_ne!(a, weight_digest(&[1.0, 2.0]));
        // -0.0 and 0.0 compare equal as floats but differ on the wire
        assert_ne!(weight_digest(&[0.0]), weight_digest(&[-0.0]));
    }

    #[test]
    fn config_digest_tracks_training_relevant_fields() {
        let base = TrainConfig::new("m", MethodConfig::sbc2(), 100, LrSchedule::constant(0.1));
        let d = config_digest(&base);
        assert_eq!(d, config_digest(&base.clone()));
        let mut seed = base.clone();
        seed.seed ^= 1;
        assert_ne!(d, config_digest(&seed));
        let mut method = base.clone();
        method.method = MethodConfig::signsgd(1e-3);
        assert_ne!(d, config_digest(&method));
        // verbosity / parallelism must NOT change the digest: they do not
        // affect the trained bits
        let mut cosmetic = base.clone();
        cosmetic.verbose = true;
        cosmetic.parallelism = 8;
        assert_eq!(d, config_digest(&cosmetic));
    }

    #[test]
    fn retryability_split() {
        assert!(TransportError::Io(io::Error::from(io::ErrorKind::ConnectionReset)).is_retryable());
        assert!(TransportError::BadFrame("x".into()).is_retryable());
        assert!(!TransportError::Rejected("x".into()).is_retryable());
        assert!(!TransportError::Protocol("x".into()).is_retryable());
        assert!(!TransportError::VersionMismatch { ours: 1, theirs: 2 }.is_retryable());
        // a scheduled kill must surface to the supervisor, not be retried
        // away inside the session
        assert!(!TransportError::Killed(3).is_retryable());
        // a damaged checkpoint is deterministic: retrying cannot help
        assert!(!TransportError::Persist(crate::persist::PersistError::Truncated).is_retryable());
    }
}
