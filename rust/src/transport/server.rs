//! The federation server: an accept loop feeding framed client updates
//! into the existing sharded aggregation, round by round.
//!
//! Protocol (client-driven synchronous rounds):
//!
//! 1. connection → `Hello` / `HelloAck` handshake (protocol version is
//!    checked at the frame layer; wire version, config digest, fleet
//!    size, parameter count and client id here);
//! 2. each round, every client sends one `Update` frame and blocks on
//!    the matching `Broadcast`;
//! 3. after the final broadcast the server sends `Done` carrying the
//!    master-weight digest.
//!
//! Per-connection handler threads only parse frames and relay them to
//! the round loop over a channel; the round loop performs decode →
//! validate → densify → [`aggregate_sharded`] in **client-index order**,
//! exactly like the in-process trainer, which is what makes the
//! federated weight digest bit-identical to [`crate::coordinator::trainer::Trainer`].
//! A reconnecting client may re-send the previous round's update; the
//! server answers it from a depth-1 broadcast cache.

use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::Duration;

use crate::codec::accounting::CommStats;
use crate::codec::message::{self, WireCodec, WIRE_VERSION};
use crate::compression::pipeline::compress_broadcast_into;
use crate::compression::{Granularity, UpdateMsg};
use crate::coordinator::aggregation::{aggregate_sharded, AggRule};
use crate::coordinator::pool::WorkerPool;
use crate::coordinator::trainer::TrainConfig;
use crate::model::TensorLayout;
use crate::netsim::NetSim;
use crate::persist::{CachedReply, CheckpointStore, PersistError, ServerSnapshot};
use crate::simnet::clock::{Clock, RealClock};
use crate::trace::{Event, SERVER};
use crate::transport::frame::{
    self, encode_done, encode_error, FrameBuf, FrameKind, Hello, HelloAck,
};
use crate::transport::{config_digest, weight_digest, Acceptor, Transport, TransportError};
use crate::util::tensor;

/// What the server hands back after a completed federated run.
#[derive(Clone, Debug)]
pub struct FederatedResult {
    /// Final master weights.
    pub final_params: Vec<f32>,
    /// FNV digest of the final weights (what `Done` carried).
    pub digest: u64,
    /// Measured communication counters — payload bits *and* framing
    /// overhead, field-for-field comparable to the in-process trainer's.
    pub comm: CommStats,
    /// Per-client simulated link totals over the framed byte counts.
    pub net: NetSim,
    /// Rounds executed.
    pub rounds: usize,
}

/// One relayed client update awaiting aggregation.
struct Packet {
    client: usize,
    round: u32,
    payload: Vec<u8>,
    bits: u64,
    reply: mpsc::Sender<Reply>,
}

/// The round loop's answer to a handler: the broadcast for `round`, plus
/// the final digest when training just finished.
#[derive(Clone)]
struct Reply {
    round: u32,
    bytes: Arc<Vec<u8>>,
    bits: u64,
    done: Option<u64>,
}

/// Handshake state shared between the accept/handler threads and the
/// round loop.
struct Shared {
    stop: AtomicBool,
    round: AtomicU32,
    clients: u32,
    n_params: u64,
    cfg_digest: u64,
    /// The checkpoint round this server resumed from, or
    /// [`HelloAck::NO_RESUME`] on a fresh start — advertised in every
    /// handshake so resumed clients can sanity-check their own state.
    resume_round: u32,
}

/// Accept loop + synchronous round aggregation over any [`Acceptor`].
pub struct FederatedServer {
    cfg: TrainConfig,
    layout: TensorLayout,
    initial: Vec<f32>,
    kill_at: Option<u32>,
}

impl FederatedServer {
    /// A server that starts from `initial` master weights (must equal the
    /// clients' `init_params(cfg.seed)` for bit-identity).
    pub fn new(cfg: TrainConfig, layout: TensorLayout, initial: Vec<f32>) -> FederatedServer {
        assert_eq!(initial.len(), layout.total, "initial params length mismatch");
        FederatedServer { cfg, layout, initial, kill_at: None }
    }

    /// Schedule a simulated crash: the round loop returns
    /// [`TransportError::Killed`] at the top of `round`, without
    /// snapshotting or notifying clients — exactly what a `SIGKILL` at
    /// that point leaves behind. The supervisor restarts a fresh server
    /// which resumes from the last durable barrier.
    pub fn kill_at(&mut self, round: u32) {
        self.kill_at = Some(round);
    }

    /// Run the full federated training: accept `cfg.clients` sessions,
    /// aggregate every round, broadcast, and return the final weights.
    /// Typed error if a round cannot be completed within the retry/
    /// timeout budget.
    pub fn run(&mut self, acceptor: Arc<dyn Acceptor>) -> Result<FederatedResult, TransportError> {
        self.run_with_clock(acceptor, Arc::new(RealClock::new()))
    }

    /// [`FederatedServer::run`] with an explicit [`Clock`]: every wait
    /// (round collection, handler replies, accept backoff) parks on it,
    /// so the deterministic simulator can run this exact server on
    /// virtual time. Threads spawned here register as clock actors
    /// *before* they start, which is what lets a [`SimClock`] account for
    /// them in its quiescence rule.
    ///
    /// [`SimClock`]: crate::simnet::clock::SimClock
    pub fn run_with_clock(
        &mut self,
        acceptor: Arc<dyn Acceptor>,
        clock: Arc<dyn Clock>,
    ) -> Result<FederatedResult, TransportError> {
        // open the checkpoint store and decode the newest generation
        // *before* admitting anyone: every handshake advertises the
        // resume round, and a damaged snapshot must fail typed up front
        let store = match &self.cfg.checkpoint.dir {
            Some(d) => Some(CheckpointStore::open(d.as_str(), self.cfg.checkpoint.keep)?),
            None => None,
        };
        let resumed: Option<ServerSnapshot> = if self.cfg.checkpoint.resume {
            match &store {
                Some(s) => s.load_latest_server(config_digest(&self.cfg))?,
                None => None,
            }
        } else {
            None
        };
        let shared = Arc::new(Shared {
            stop: AtomicBool::new(false),
            round: AtomicU32::new(resumed.as_ref().map(|s| s.round).unwrap_or(0)),
            clients: self.cfg.clients as u32,
            n_params: self.layout.total as u64,
            cfg_digest: config_digest(&self.cfg),
            resume_round: resumed.as_ref().map(|s| s.round).unwrap_or(HelloAck::NO_RESUME),
        });
        let (tx, rx) = mpsc::channel::<Packet>();

        let accept_thread = {
            let acceptor = acceptor.clone();
            let shared = shared.clone();
            let clock = clock.clone();
            let round_timeout = self.cfg.transport.round_timeout;
            let accept_actor = clock.actor();
            thread::spawn(move || {
                let _actor = accept_actor;
                loop {
                    match acceptor.accept() {
                        Ok(conn) => {
                            let tx = tx.clone();
                            let shared = shared.clone();
                            let clock = clock.clone();
                            let handler_actor = clock.actor();
                            thread::spawn(move || {
                                let _actor = handler_actor;
                                handle_connection(conn, tx, shared, round_timeout, &*clock)
                            });
                        }
                        Err(_) => {
                            if shared.stop.load(Ordering::SeqCst) {
                                return;
                            }
                            // transient accept failure: keep listening
                            clock.sleep(Duration::from_millis(10));
                        }
                    }
                }
            })
        };

        let result = self.round_loop(&rx, &shared, &*clock, store.as_ref(), resumed);
        shared.stop.store(true, Ordering::SeqCst);
        acceptor.shutdown();
        let _ = accept_thread.join();
        result
    }

    /// The synchronous round loop: mirror of the in-process trainer's
    /// accounting + aggregation, fed by the handler channel.
    fn round_loop(
        &mut self,
        rx: &mpsc::Receiver<Packet>,
        shared: &Shared,
        clock: &dyn Clock,
        store: Option<&CheckpointStore>,
        resumed: Option<ServerSnapshot>,
    ) -> Result<FederatedResult, TransportError> {
        let cfg = &self.cfg;
        let n = self.layout.total;
        let nclients = cfg.clients;
        let agg_rule = AggRule::for_method(&cfg.method);
        let majority_vote = matches!(agg_rule, AggRule::MajoritySign { .. });
        let sign_scale = cfg.method.sign_scale();
        let gran = cfg.method.granularity;
        let delay = cfg.method.delay;
        let rounds = (cfg.iterations / delay).max(1);

        let mut master = self.initial.clone();
        let mut comm = CommStats::default();
        let mut net = NetSim::new(cfg.uplink, cfg.downlink, nclients);
        let pool = WorkerPool::new(cfg.parallelism.min(nclients.max(1)));

        let mut slots: Vec<Option<Packet>> = (0..nclients).map(|_| None).collect();
        let mut decoded: Vec<UpdateMsg> = (0..nclients).map(|_| UpdateMsg::scratch()).collect();
        let mut denses: Vec<Vec<f32>> = (0..nclients).map(|_| vec![0.0f32; n]).collect();
        let mut round_up_bits = vec![0u64; nclients];
        let mut delta = vec![0.0f32; n];
        let mut delta_rx = vec![0.0f32; n];
        let mut down_wire = WireCodec::new(cfg.pos_codec);
        let mut down_msg = UpdateMsg::scratch();
        let mut down_decoded = UpdateMsg::scratch();
        let mut cached: Option<Reply> = None;

        // resuming: overwrite the fresh state with the checkpointed
        // values (weights, accounting, the cached previous broadcast for
        // clients still waiting on it) and start at the snapshot barrier
        let mut start_round = 0usize;
        if let Some(snap) = resumed {
            if snap.master.len() != n {
                return Err(PersistError::Corrupt("snapshot parameter count mismatch").into());
            }
            start_round = snap.round as usize;
            master.copy_from_slice(&snap.master);
            comm.upstream_bits = snap.comm[0];
            comm.messages = snap.comm[1];
            comm.nonzeros = snap.comm[2];
            comm.baseline_bits = snap.comm[3];
            comm.frame_overhead_bits = snap.comm[4];
            for (c, &(ub, db, ut, dt, ms)) in net.clients.iter_mut().zip(&snap.net_clients) {
                c.up_bits = ub;
                c.down_bits = db;
                c.up_time_s = f64::from_bits(ut);
                c.down_time_s = f64::from_bits(dt);
                c.messages = ms;
            }
            net.total_comm_time_s = f64::from_bits(snap.net_total_time_bits);
            cached = snap.cache.map(|c| Reply {
                round: c.round,
                bytes: Arc::new(c.bytes),
                bits: c.bits,
                done: c.done,
            });
            cfg.trace.emit(clock, || Event::Restore {
                role: "server".into(),
                client: SERVER,
                round: start_round as u32,
            });
        }

        for round in start_round..rounds {
            if self.kill_at == Some(round as u32) {
                // scheduled crash: drop everything on the floor like a
                // real SIGKILL — no snapshot, no goodbye to clients
                return Err(TransportError::Killed(round as u32));
            }
            shared.round.store(round as u32, Ordering::SeqCst);
            cfg.trace.emit(clock, || Event::RoundStart { round: round as u32 });

            // collect one update per client for this round
            let mut have = 0usize;
            while have < nclients {
                let pkt =
                    recv_with_clock(rx, clock, cfg.transport.round_timeout).ok_or_else(|| {
                        TransportError::Timeout(format!(
                            "round {round}: got {have}/{nclients} client updates"
                        ))
                    })?;
                if pkt.round == round as u32 {
                    if slots[pkt.client].is_none() {
                        have += 1;
                    }
                    // a duplicate replaces the stale copy: the old reply
                    // sender is dropped, which unblocks (and ends) the
                    // dead handler it belonged to
                    slots[pkt.client] = Some(pkt);
                } else if let Some(c) = cached.as_ref().filter(|c| c.round == pkt.round) {
                    // a reconnecting client re-sent the previous round's
                    // update: answer from the broadcast cache
                    let _ = pkt.reply.send(c.clone());
                    clock.wake_all();
                } else if pkt.round < round as u32 {
                    // a stale duplicate from a round no longer covered by
                    // the depth-1 cache (a delayed or duplicated frame):
                    // drop it — its client already got that broadcast,
                    // and the handler that relayed it winds down on its
                    // reply timeout
                } else {
                    return Err(TransportError::Protocol(format!(
                        "client {} sent round {} while server is at {round}",
                        pkt.client, pkt.round
                    )));
                }
            }

            // decode + account in client-index order, exactly like the
            // in-process read-back
            for ci in 0..nclients {
                let Some(pkt) = slots[ci].as_ref() else {
                    return Err(TransportError::Protocol(format!(
                        "internal: client {ci} slot empty after barrier"
                    )));
                };
                message::decode_into(&pkt.payload, pkt.bits, &mut decoded[ci]).map_err(|e| {
                    TransportError::Protocol(format!("client {ci} update undecodable: {e}"))
                })?;
                decoded[ci].validate(&self.layout, gran).map_err(|e| {
                    TransportError::Protocol(format!("client {ci} update invalid: {e}"))
                })?;
                for _ in 0..delay {
                    comm.record_baseline_iter(n);
                }
                let nnz: usize = decoded[ci].tensors.iter().map(|t| t.nonzeros()).sum();
                comm.record_message(pkt.bits, nnz as u64);
                comm.record_frame_overhead(frame::overhead_bits(pkt.bits));
                round_up_bits[ci] = pkt.bits + frame::overhead_bits(pkt.bits);
                // the upstream Frame event fires at exactly the accounting
                // point, so server-role trace totals reconcile field-for-
                // field with CommStats/NetSim
                let (pb, ob) = (pkt.bits, frame::overhead_bits(pkt.bits));
                cfg.trace.emit(clock, || Event::Frame {
                    role: "server".into(),
                    dir: "up".into(),
                    kind: "update".into(),
                    client: ci as u32,
                    round: round as u32,
                    payload_bits: pb,
                    overhead_bits: ob,
                });
                decoded[ci].densify_into(&self.layout, gran, sign_scale, &mut denses[ci]);
                if majority_vote {
                    for v in denses[ci].iter_mut() {
                        *v = v.signum();
                    }
                }
            }

            aggregate_sharded(&denses[..], agg_rule, &pool, &mut delta);

            compress_broadcast_into(&delta, round as u32, &mut down_msg);
            let (bytes, bits) = down_wire.encode(&down_msg);
            message::decode_into(bytes, bits, &mut down_decoded).map_err(|e| {
                TransportError::Protocol(format!("downstream self-roundtrip failed: {e}"))
            })?;
            let bytes = Arc::new(bytes.to_vec());
            down_decoded.densify_into(&self.layout, Granularity::Global, 1.0, &mut delta_rx);
            tensor::add_assign(&mut master, &delta_rx);
            comm.record_frame_overhead(frame::overhead_bits(bits) * nclients as u64);
            net.round(&round_up_bits, bits + frame::overhead_bits(bits));
            if cfg.trace.enabled() {
                let oh = frame::overhead_bits(bits);
                for ci in 0..nclients {
                    cfg.trace.emit(clock, || Event::Frame {
                        role: "server".into(),
                        dir: "down".into(),
                        kind: "broadcast".into(),
                        client: ci as u32,
                        round: round as u32,
                        payload_bits: bits,
                        overhead_bits: oh,
                    });
                }
            }

            let last = round + 1 == rounds;
            let done = if last { Some(weight_digest(&master)) } else { None };
            let reply = Reply { round: round as u32, bytes, bits, done };
            // --- durable checkpoint at the barrier, *before* any reply
            // leaves: a crash on either side of the write is recoverable
            // (before: clients re-send this round; after: the persisted
            // cache answers their re-sends) ------------------------------
            if let Some(store) = store {
                if (round + 1) % cfg.checkpoint.every() == 0 || last {
                    let snap = ServerSnapshot {
                        round: (round + 1) as u32,
                        master: master.clone(),
                        comm: [
                            comm.upstream_bits,
                            comm.messages,
                            comm.nonzeros,
                            comm.baseline_bits,
                            comm.frame_overhead_bits,
                        ],
                        net_clients: net
                            .clients
                            .iter()
                            .map(|c| {
                                (
                                    c.up_bits,
                                    c.down_bits,
                                    c.up_time_s.to_bits(),
                                    c.down_time_s.to_bits(),
                                    c.messages,
                                )
                            })
                            .collect(),
                        net_total_time_bits: net.total_comm_time_s.to_bits(),
                        ledger: vec![round as u32; nclients],
                        cache: Some(CachedReply {
                            round: reply.round,
                            bytes: reply.bytes.as_ref().clone(),
                            bits: reply.bits,
                            done: reply.done,
                        }),
                    };
                    let path = store.save_server(&snap, shared.cfg_digest)?;
                    let sz = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
                    cfg.trace.emit(clock, || Event::Snapshot {
                        role: "server".into(),
                        client: SERVER,
                        round: (round + 1) as u32,
                        bytes: sz,
                    });
                    cfg.trace.flush();
                }
            }
            for slot in slots.iter_mut() {
                let Some(pkt) = slot.take() else {
                    return Err(TransportError::Protocol(
                        "internal: client slot empty after barrier".into(),
                    ));
                };
                // a send failure means that handler died; its client will
                // reconnect and be served from the cache
                let _ = pkt.reply.send(reply.clone());
            }
            clock.wake_all();
            cached = Some(reply);
        }

        let digest = weight_digest(&master);
        cfg.trace.flush();
        Ok(FederatedResult { final_params: master, digest, comm, net, rounds })
    }
}

/// Poll-and-park replacement for `Receiver::recv_timeout` that waits on
/// the [`Clock`] instead of wall time (a virtual clock can then jump
/// straight over the wait). `None` means timeout or disconnection. The
/// epoch is read *before* the poll so a send+wake between poll and park
/// is never lost.
fn recv_with_clock<T>(
    rx: &mpsc::Receiver<T>,
    clock: &dyn Clock,
    timeout: Duration,
) -> Option<T> {
    let deadline = clock.now().checked_add(timeout).unwrap_or(Duration::MAX);
    loop {
        let seen = clock.epoch();
        match rx.try_recv() {
            Ok(v) => return Some(v),
            Err(mpsc::TryRecvError::Disconnected) => return None,
            Err(mpsc::TryRecvError::Empty) => {}
        }
        let now = clock.now();
        if now >= deadline {
            return None;
        }
        clock.park(seen, deadline - now);
    }
}

/// Per-connection handler: handshake, then relay Update frames to the
/// round loop and write its replies back to the socket. Any protocol or
/// I/O failure simply ends the connection — recovery is the client's
/// reconnect-and-retry loop.
fn handle_connection(
    mut conn: Box<dyn Transport>,
    tx: mpsc::Sender<Packet>,
    shared: Arc<Shared>,
    round_timeout: Duration,
    clock: &dyn Clock,
) {
    let mut buf = FrameBuf::default();
    if conn.recv(&mut buf).is_err() || buf.kind != FrameKind::Hello {
        return;
    }
    let hello = match Hello::decode(&buf.payload) {
        Ok(h) => h,
        Err(_) => return,
    };
    if let Some(reason) = reject_reason(&hello, &shared) {
        let payload = encode_error(&reason);
        buf.set(FrameKind::Error, 0, hello.client, &payload, payload.len() as u64 * 8);
        let _ = conn.send(&buf);
        return;
    }
    let ack = HelloAck {
        round: shared.round.load(Ordering::SeqCst),
        wire_version: WIRE_VERSION,
        resume_round: shared.resume_round,
    };
    let payload = ack.encode();
    buf.set(FrameKind::HelloAck, ack.round, hello.client, &payload, payload.len() as u64 * 8);
    if conn.send(&buf).is_err() {
        return;
    }

    loop {
        if conn.recv(&mut buf).is_err() {
            return; // EOF / reset / timeout: client reconnects if it cares
        }
        if buf.kind != FrameKind::Update || buf.client != hello.client {
            return;
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        let pkt = Packet {
            client: hello.client as usize,
            round: buf.round,
            payload: buf.payload[..buf.payload_bytes()].to_vec(),
            bits: buf.payload_bits as u64,
            reply: reply_tx,
        };
        if tx.send(pkt).is_err() {
            return; // round loop ended
        }
        clock.wake_all();
        let reply = match recv_with_clock(&reply_rx, clock, round_timeout) {
            Some(r) => r,
            None => return, // superseded by a reconnect, or server error
        };
        buf.set(FrameKind::Broadcast, reply.round, hello.client, &reply.bytes, reply.bits);
        if conn.send(&buf).is_err() {
            return;
        }
        if let Some(digest) = reply.done {
            let payload = encode_done(digest);
            buf.set(FrameKind::Done, reply.round, hello.client, &payload, 64);
            let _ = conn.send(&buf);
            return;
        }
    }
}

fn reject_reason(hello: &Hello, shared: &Shared) -> Option<String> {
    if hello.wire_version != WIRE_VERSION {
        return Some(format!(
            "wire version mismatch: client {}, server {WIRE_VERSION}",
            hello.wire_version
        ));
    }
    if hello.clients != shared.clients {
        return Some(format!(
            "fleet size mismatch: client expects {}, server runs {}",
            hello.clients, shared.clients
        ));
    }
    if hello.client >= shared.clients {
        return Some(format!("client id {} out of range (fleet {})", hello.client, shared.clients));
    }
    if hello.n_params != shared.n_params {
        return Some(format!(
            "parameter count mismatch: client {}, server {}",
            hello.n_params, shared.n_params
        ));
    }
    if hello.config_digest != shared.cfg_digest {
        return Some("training config digest mismatch (method/seed/schedule differ)".into());
    }
    None
}
