//! `std::net` TCP transport: the same framed protocol as loopback over
//! real sockets. Connect/read timeouts come from
//! [`crate::transport::TransportCfg`]; Nagle is disabled because every
//! frame is a complete protocol step that the peer is blocked on.

use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

use crate::transport::{Acceptor, Connector, FramedConn, Transport, TransportCfg, TransportError};

fn configure(stream: &TcpStream, read_timeout: Duration) -> std::io::Result<()> {
    stream.set_nodelay(true)?;
    // set_read_timeout rejects Some(ZERO); our ZERO means "no timeout"
    let t = if read_timeout.is_zero() { None } else { Some(read_timeout) };
    stream.set_read_timeout(t)
}

/// Accepts framed connections on a bound [`TcpListener`].
pub struct TcpAcceptor {
    listener: TcpListener,
    addr: SocketAddr,
    read_timeout: Duration,
    stopped: AtomicBool,
}

impl TcpAcceptor {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral test port).
    pub fn bind(addr: impl ToSocketAddrs, cfg: &TransportCfg) -> Result<TcpAcceptor, TransportError> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        Ok(TcpAcceptor { listener, addr, read_timeout: cfg.read_timeout, stopped: AtomicBool::new(false) })
    }

    /// The bound address (resolves port 0 to the actual ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Acceptor for TcpAcceptor {
    fn accept(&self) -> Result<Box<dyn Transport>, TransportError> {
        loop {
            if self.stopped.load(Ordering::SeqCst) {
                return Err(TransportError::Closed);
            }
            let (stream, peer) = self.listener.accept()?;
            if self.stopped.load(Ordering::SeqCst) {
                return Err(TransportError::Closed);
            }
            configure(&stream, self.read_timeout)?;
            return Ok(Box::new(FramedConn::new(stream, peer.to_string())));
        }
    }

    fn shutdown(&self) {
        self.stopped.store(true, Ordering::SeqCst);
        // wake a blocked accept() with a throwaway connection
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_millis(200));
    }
}

/// Connects framed sessions to a [`TcpAcceptor`] (or any server speaking
/// the frame protocol).
pub struct TcpConnector {
    addr: SocketAddr,
    cfg: TransportCfg,
}

impl TcpConnector {
    /// A connector for `addr` using `cfg`'s connect/read timeouts.
    pub fn new(addr: SocketAddr, cfg: &TransportCfg) -> TcpConnector {
        TcpConnector { addr, cfg: *cfg }
    }
}

impl Connector for TcpConnector {
    fn connect(&self) -> Result<Box<dyn Transport>, TransportError> {
        let stream = TcpStream::connect_timeout(&self.addr, self.cfg.connect_timeout)?;
        configure(&stream, self.cfg.read_timeout)?;
        Ok(Box::new(FramedConn::new(stream, self.addr.to_string())))
    }
}
