//! The remote client session: one federated participant running its
//! local training against a [`FederatedServer`] over any [`Connector`],
//! plus [`run_federated`] — the in-process driver that runs a server and
//! all client sessions over a transport and returns both sides' results.
//!
//! A session replicates the in-process trainer's client loop *exactly* —
//! same [`ClientState`] construction, same RNG streams, same residual /
//! momentum-mask updates against its own decoded bytes — so the master
//! weights it converges to are bit-identical to [`Trainer::run`]'s.
//!
//! Fault tolerance: every frame exchange runs under a bounded
//! retry-with-exponential-backoff loop. A dropped connection, truncated
//! frame or timeout tears the connection down and reconnects (the
//! handshake re-runs, the *same* encoded update is re-sent — local
//! training is never repeated, so the RNG streams stay aligned); a
//! rejection or protocol violation is fatal immediately. When the retry
//! budget is spent the session fails with
//! [`TransportError::RetriesExhausted`] carrying the last cause.
//!
//! [`FederatedServer`]: crate::transport::server::FederatedServer
//! [`Trainer::run`]: crate::coordinator::trainer::Trainer::run

use std::sync::Arc;
use std::thread;
use std::time::Duration;

use crate::codec::message::{self, WIRE_VERSION};
use crate::compression::momentum_mask::mask_momentum;
use crate::compression::{Granularity, UpdateMsg};
use crate::coordinator::client::ClientState;
use crate::coordinator::pool::WorkerPool;
use crate::coordinator::trainer::TrainConfig;
use crate::coordinator::TrainBackend;
use crate::persist::{CheckpointStore, PersistError};
use crate::simnet::clock::{Clock, RealClock};
use crate::trace::Event;
use crate::transport::frame::{
    decode_done, decode_error, overhead_bits, FrameBuf, FrameKind, Hello, HelloAck,
};
use crate::transport::server::{FederatedResult, FederatedServer};
use crate::transport::{
    config_digest, weight_digest, Acceptor, Connector, Transport, TransportError,
};
use crate::util::tensor;

/// Ceiling for the exponential reconnect backoff. Without it,
/// `retry_backoff * 2^attempt` can overflow `Duration` for large
/// configured backoffs, which panics; the schedule saturates here
/// instead (pinned by `huge_retry_backoff_saturates_at_cap` in
/// `rust/tests/sim_federation.rs`).
pub const BACKOFF_CAP: Duration = Duration::from_secs(60);

/// What one client session hands back after a completed federated run.
#[derive(Clone, Debug)]
pub struct ClientOutcome {
    /// This client's converged master weights.
    pub final_params: Vec<f32>,
    /// FNV digest of the final weights.
    pub digest: u64,
    /// Cumulative upstream payload bits this client sent (excluding
    /// framing — comparable to the in-process `ClientState::up_bits`).
    pub up_bits: u64,
    /// Reconnect attempts this session performed across all rounds.
    pub retries: u32,
    /// The digest the server announced in its `Done` frame.
    pub server_digest: u64,
}

/// One client's connection state: lazily (re)established, torn down on
/// any retryable failure so the next exchange reconnects and re-runs the
/// handshake.
struct Session<'a> {
    connector: &'a dyn Connector,
    cfg: &'a TrainConfig,
    clock: &'a dyn Clock,
    hello: Hello,
    conn: Option<Box<dyn Transport>>,
    retries: u32,
    /// The round this client resumed from (0 = fresh start) — checked
    /// against the server's handshake state to fail fast when the client
    /// checkpoint is ahead of anything the server can serve.
    resume_from: u32,
}

impl<'a> Session<'a> {
    fn new(
        cfg: &'a TrainConfig,
        id: usize,
        n_params: usize,
        connector: &'a dyn Connector,
        clock: &'a dyn Clock,
    ) -> Self {
        let hello = Hello {
            client: id as u32,
            clients: cfg.clients as u32,
            n_params: n_params as u64,
            wire_version: WIRE_VERSION,
            config_digest: config_digest(cfg),
        };
        Session { connector, cfg, clock, hello, conn: None, retries: 0, resume_from: 0 }
    }

    /// Connect + handshake if there is no live connection.
    fn ensure_conn(&mut self, scratch: &mut FrameBuf) -> Result<(), TransportError> {
        if self.conn.is_some() {
            return Ok(());
        }
        let mut conn = self.connector.connect()?;
        let payload = self.hello.encode();
        scratch.set(FrameKind::Hello, 0, self.hello.client, &payload, payload.len() as u64 * 8);
        conn.send(scratch)?;
        conn.recv(scratch)?;
        match scratch.kind {
            FrameKind::HelloAck => {
                let ack = HelloAck::decode(&scratch.payload)?;
                if ack.wire_version != WIRE_VERSION {
                    return Err(TransportError::VersionMismatch {
                        ours: WIRE_VERSION,
                        theirs: ack.wire_version,
                    });
                }
                // a client checkpoint ahead of the server is
                // unrecoverable (the server would see a future round):
                // fail fast and typed instead of burning the retry
                // budget. `ack.round + 1` allows the benign race where
                // the server has replied for `resume_from - 1` but not
                // yet bumped its round counter.
                if self.resume_from > ack.round.saturating_add(1) {
                    return Err(TransportError::Rejected(format!(
                        "client resumed at round {} but server is at round {}",
                        self.resume_from, ack.round
                    )));
                }
                if ack.resume_round != HelloAck::NO_RESUME {
                    let (client, round) = (self.hello.client, ack.resume_round);
                    self.cfg.trace.emit(self.clock, || Event::Resume { client, round });
                }
            }
            FrameKind::Error => {
                return Err(TransportError::Rejected(decode_error(
                    &scratch.payload[..scratch.payload_bytes()],
                )));
            }
            k => {
                return Err(TransportError::Protocol(format!(
                    "expected HelloAck, got {k:?} frame"
                )))
            }
        }
        self.conn = Some(conn);
        let (client, attempt) = (self.hello.client, self.retries);
        self.cfg.trace.emit(self.clock, || Event::Connect { client, attempt });
        Ok(())
    }

    /// Send this round's update and receive the matching broadcast, under
    /// the retry budget. `update` is re-sent verbatim on reconnect —
    /// local training is NOT repeated.
    fn exchange(
        &mut self,
        update: &FrameBuf,
        reply: &mut FrameBuf,
    ) -> Result<(), TransportError> {
        let mut attempt: u32 = 0;
        loop {
            match self.try_exchange(update, reply) {
                Ok(()) => return Ok(()),
                Err(e) if e.is_retryable() => {
                    self.conn = None;
                    self.retries += 1;
                    if attempt >= self.cfg.transport.max_retries {
                        return Err(TransportError::RetriesExhausted {
                            attempts: attempt + 1,
                            last: Box::new(e),
                        });
                    }
                    // checked: `retry_backoff << attempt` overflows
                    // Duration for large configured backoffs
                    let backoff = self
                        .cfg
                        .transport
                        .retry_backoff
                        .checked_mul(1 << attempt.min(16))
                        .map(|d| d.min(BACKOFF_CAP))
                        .unwrap_or(BACKOFF_CAP);
                    let client = self.hello.client;
                    self.cfg.trace.emit(self.clock, || Event::Retry {
                        client,
                        attempt,
                        backoff_ns: backoff.as_nanos() as u64,
                        error: e.to_string(),
                    });
                    self.clock.sleep(backoff);
                    attempt += 1;
                }
                Err(e) => return Err(e),
            }
        }
    }

    fn try_exchange(
        &mut self,
        update: &FrameBuf,
        reply: &mut FrameBuf,
    ) -> Result<(), TransportError> {
        self.ensure_conn(reply)?;
        let Some(conn) = self.conn.as_mut() else { return Err(TransportError::Closed) };
        conn.send(update)?;
        loop {
            conn.recv(reply)?;
            match reply.kind {
                FrameKind::Broadcast if reply.round == update.round => return Ok(()),
                // a reconnect can replay the previous round's broadcast
                // out of the server cache: skip anything stale
                FrameKind::Broadcast if reply.round < update.round => continue,
                FrameKind::Done => continue,     // stale final marker
                FrameKind::HelloAck => continue, // duplicated handshake ack
                FrameKind::Error => {
                    return Err(TransportError::Rejected(decode_error(
                        &reply.payload[..reply.payload_bytes()],
                    )))
                }
                k => {
                    return Err(TransportError::Protocol(format!(
                        "expected Broadcast round {}, got {k:?} round {}",
                        update.round, reply.round
                    )))
                }
            }
        }
    }

    /// Read the server's `Done` digest after the final broadcast,
    /// skipping any duplicated broadcast/ack frames still in flight.
    fn read_done(&mut self, scratch: &mut FrameBuf) -> Result<u64, TransportError> {
        let conn = self.conn.as_mut().ok_or(TransportError::Closed)?;
        loop {
            conn.recv(scratch)?;
            match scratch.kind {
                FrameKind::Done => {
                    return decode_done(&scratch.payload[..scratch.payload_bytes()])
                }
                FrameKind::Broadcast | FrameKind::HelloAck => continue,
                k => {
                    return Err(TransportError::Protocol(format!(
                        "expected Done, got {k:?} frame"
                    )))
                }
            }
        }
    }
}

/// Run one client's full federated training against a server reachable
/// through `connector`. Bit-identical to the same client's role in the
/// in-process [`Trainer`](crate::coordinator::trainer::Trainer) run.
pub fn run_client<B: TrainBackend>(
    cfg: &TrainConfig,
    id: usize,
    connector: &dyn Connector,
    backend: &mut B,
) -> Result<ClientOutcome, TransportError> {
    run_client_with_clock(cfg, id, connector, backend, &RealClock::new())
}

/// [`run_client`] with an explicit [`Clock`]: the retry backoff waits on
/// it, so the deterministic simulator can drive the identical session
/// code on virtual time.
pub fn run_client_with_clock<B: TrainBackend>(
    cfg: &TrainConfig,
    id: usize,
    connector: &dyn Connector,
    backend: &mut B,
    clock: &dyn Clock,
) -> Result<ClientOutcome, TransportError> {
    run_client_resumable(cfg, id, connector, backend, clock, None)
}

/// [`run_client_with_clock`] plus crash-recovery controls. Checkpoint
/// persistence and resume follow `cfg.checkpoint` (each completed round
/// snapshots the client's weights, optimizer, residual and RNG cursors;
/// on resume the session continues from the newest generation instead of
/// re-training from initialization). `kill_at` schedules a simulated
/// crash — the session returns [`TransportError::Killed`] at the top of
/// that round, leaving exactly what a `SIGKILL` would: the last durable
/// snapshot and nothing else.
pub fn run_client_resumable<B: TrainBackend>(
    cfg: &TrainConfig,
    id: usize,
    connector: &dyn Connector,
    backend: &mut B,
    clock: &dyn Clock,
    kill_at: Option<u32>,
) -> Result<ClientOutcome, TransportError> {
    let n = backend.n_params();
    let layout = backend.layout().clone();
    let opt_size = backend.opt_size();
    let mut master = backend.init_params(cfg.seed);
    let mut c = ClientState::for_config(cfg, id, n, opt_size);

    let store = match &cfg.checkpoint.dir {
        Some(d) => Some(CheckpointStore::open(d.as_str(), cfg.checkpoint.keep)?),
        None => None,
    };
    let mut start_round = 0usize;
    if cfg.checkpoint.resume {
        if let Some(store) = &store {
            if let Some(snap) = store.load_latest_client(id as u32, config_digest(cfg))? {
                if snap.weights.len() != n {
                    return Err(
                        PersistError::Corrupt("snapshot parameter count mismatch").into()
                    );
                }
                master.copy_from_slice(&snap.weights);
                c.restore(&snap);
                start_round = snap.round as usize;
                cfg.trace.emit(clock, || Event::Restore {
                    role: "client".into(),
                    client: id as u32,
                    round: snap.round,
                });
            }
        }
    }

    let gran = cfg.method.granularity;
    let sign_scale = cfg.method.sign_scale();
    let momentum_masking = cfg.method.momentum_masking;
    let delay = cfg.method.delay;
    let rounds = (cfg.iterations / delay).max(1);

    let mut acc = vec![0.0f32; n];
    let mut delta_rx = vec![0.0f32; n];
    let mut down_decoded = UpdateMsg::scratch();
    let mut update = FrameBuf::default();
    let mut reply = FrameBuf::default();
    let mut session = Session::new(cfg, id, n, connector, clock);
    session.resume_from = start_round as u32;

    for round in start_round..rounds {
        if kill_at == Some(round as u32) {
            // scheduled crash: no snapshot, no goodbye — the supervisor
            // restarts a fresh session that resumes from the last barrier
            return Err(TransportError::Killed(round as u32));
        }
        let lr = cfg.lr.at(round * delay);

        // local training + compress + wire encode: the exact in-process
        // client phase (see trainer::run_client_round)
        let (w_new, _loss) =
            backend.local_steps(&master, &mut c.opt, delay, lr, c.iterations, id, &mut c.rng);
        c.iterations += delay;
        tensor::sub_into(&mut acc, &w_new, &master);
        c.residual.accumulate_into(&mut acc);
        c.pipeline.compress_into(&acc, &layout, round as u32, &mut c.msg);
        let (bytes, bits) = c.wire.encode(&c.msg);
        update.set(FrameKind::Update, round as u32, id as u32, bytes, bits);
        message::decode_into(bytes, bits, &mut c.decoded).map_err(|e| {
            TransportError::Protocol(format!("client {id} self-roundtrip failed: {e}"))
        })?;
        c.up_bits += bits;

        session.exchange(&update, &mut reply)?;

        // one Frame event per *accepted* exchange (retries surface as
        // Event::Retry), so client-role totals reconcile with CommStats
        cfg.trace.emit(clock, || Event::Frame {
            role: "client".into(),
            dir: "up".into(),
            kind: "update".into(),
            client: id as u32,
            round: round as u32,
            payload_bits: bits,
            overhead_bits: overhead_bits(bits),
        });
        let down_bits = reply.payload_bits as u64;
        cfg.trace.emit(clock, || Event::Frame {
            role: "client".into(),
            dir: "down".into(),
            kind: "broadcast".into(),
            client: id as u32,
            round: round as u32,
            payload_bits: down_bits,
            overhead_bits: overhead_bits(down_bits),
        });

        // client-side bookkeeping against its own decoded bytes — the
        // residual and momentum mask see exactly what the server decoded
        c.decoded.densify_into(&layout, gran, sign_scale, &mut c.dense);
        c.residual.update(&acc, &c.dense);
        if momentum_masking {
            tensor::nonzero_indices_into(&c.dense, &mut c.mask_idx);
            mask_momentum(&mut c.opt, n, &c.mask_idx);
        }

        // apply the broadcast aggregate
        message::decode_into(
            &reply.payload[..reply.payload_bytes()],
            reply.payload_bits as u64,
            &mut down_decoded,
        )
        .map_err(|e| TransportError::Protocol(format!("broadcast undecodable: {e}")))?;
        down_decoded
            .validate(&layout, Granularity::Global)
            .map_err(|e| TransportError::Protocol(format!("broadcast invalid: {e}")))?;
        down_decoded.densify_into(&layout, Granularity::Global, 1.0, &mut delta_rx);
        tensor::add_assign(&mut master, &delta_rx);

        // --- durable checkpoint at the round barrier -------------------
        if let Some(store) = &store {
            if (round + 1) % cfg.checkpoint.every() == 0 || round + 1 == rounds {
                let barrier = (round + 1) as u32;
                let snap = c.snapshot(barrier, &master);
                let path = store.save_client(&snap, session.hello.config_digest)?;
                let sz = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
                cfg.trace.emit(clock, || Event::Snapshot {
                    role: "client".into(),
                    client: id as u32,
                    round: barrier,
                    bytes: sz,
                });
                // a kill right after the barrier must still leave a
                // readable trace up to the snapshot event
                cfg.trace.flush();
            }
        }
    }

    let server_digest = session.read_done(&mut reply)?;
    let digest = weight_digest(&master);
    if server_digest != digest {
        return Err(TransportError::Protocol(format!(
            "weight digest diverged: client {digest:016x}, server {server_digest:016x}"
        )));
    }
    Ok(ClientOutcome {
        final_params: master,
        digest,
        up_bits: c.up_bits,
        retries: session.retries,
        server_digest,
    })
}

/// Drive a complete federated run in one process: a [`FederatedServer`]
/// on its own thread, plus `cfg.clients` client sessions on a
/// [`WorkerPool`], each with its own backend from `make_backend(id)` and
/// its own connection from `connectors[id]`. Client errors take
/// precedence over the server's (a dead client is the root cause of the
/// server's round timeout).
pub fn run_federated<B, F>(
    cfg: &TrainConfig,
    acceptor: Arc<dyn Acceptor>,
    connectors: Vec<Box<dyn Connector>>,
    make_backend: F,
) -> Result<(FederatedResult, Vec<ClientOutcome>), TransportError>
where
    B: TrainBackend,
    F: Fn(usize) -> B + Sync,
{
    assert_eq!(connectors.len(), cfg.clients, "one connector per client");
    let (layout, initial) = {
        let mut probe = make_backend(0);
        let init = probe.init_params(cfg.seed);
        (probe.layout().clone(), init)
    };
    let mut server = FederatedServer::new(cfg.clone(), layout, initial);

    struct Job {
        id: usize,
        connector: Box<dyn Connector>,
        out: Option<Result<ClientOutcome, TransportError>>,
    }

    let mut jobs: Vec<Job> = connectors
        .into_iter()
        .enumerate()
        .map(|(id, connector)| Job { id, connector, out: None })
        .collect();

    let server_result = thread::scope(|s| {
        let server_thread = s.spawn(move || server.run(acceptor));
        let pool = WorkerPool::new(cfg.clients);
        pool.for_each(&mut jobs, |_, job| {
            let mut backend = make_backend(job.id);
            job.out = Some(run_client(cfg, job.id, &*job.connector, &mut backend));
        });
        match server_thread.join() {
            Ok(r) => r,
            Err(_) => Err(TransportError::Protocol("server thread panicked".into())),
        }
    });

    let mut outcomes = Vec::with_capacity(jobs.len());
    for job in jobs {
        let Some(out) = job.out else {
            return Err(TransportError::Protocol(format!("client {} job never ran", job.id)));
        };
        outcomes.push(out?);
    }
    Ok((server_result?, outcomes))
}
