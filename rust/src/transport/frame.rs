//! The framed byte layer: length-prefixed, CRC-checked frames that carry
//! wire-format-v2 payloads ([`crate::codec::message`]) across real
//! connections.
//!
//! Layout (big-endian, byte-aligned — see `ARCHITECTURE.md` §Transport):
//!
//! ```text
//! frame := len:u32            # bytes after this field (header + payload)
//!          magic:u16 = 0xFE5B
//!          protocol:u8 = 1    # transport protocol version
//!          kind:u8            # FrameKind discriminant
//!          round:u32
//!          client:u32
//!          payload_bits:u32   # exact bit length of the payload
//!          crc:u32            # CRC-32 (IEEE) of magic..payload inclusive
//!          payload:[u8; ceil(payload_bits / 8)]
//! ```
//!
//! Every field a receiver trusts is covered by either the CRC or a hard
//! bound: `len` is cross-checked against `payload_bits`, payload size is
//! capped by [`MAX_PAYLOAD_BYTES`], and any mismatch is a typed
//! [`TransportError`], never a panic.

use std::io::{Read, Write};

use crate::transport::TransportError;
use crate::util::bytes::{be_u32, be_u64};

/// Frame magic (distinct from the payload codec's 0x5BC0 so a desynced
/// stream cannot be mistaken for a frame boundary).
pub const MAGIC: u16 = 0xFE5B;

/// Transport protocol version (frame layout + handshake semantics).
pub const PROTOCOL_VERSION: u8 = 1;

/// Total framing bytes around a payload: 4 (length prefix) + 16 (header)
/// + 4 (CRC).
pub const HEADER_BYTES: u64 = 24;

/// Hard cap on a single frame's payload (defense against corrupt or
/// hostile length fields — nothing in this repo sends messages near it).
pub const MAX_PAYLOAD_BYTES: u64 = 1 << 30;

/// What a frame carries (the federation protocol's message kinds).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum FrameKind {
    /// Client → server: identity + version/config negotiation.
    #[default]
    Hello,
    /// Server → client: handshake accepted; carries the current round.
    HelloAck,
    /// Client → server: one encoded [`crate::compression::UpdateMsg`].
    Update,
    /// Server → client: the encoded broadcast aggregate for a round.
    Broadcast,
    /// Server → client: training finished; carries the weight digest.
    Done,
    /// Server → client: handshake or protocol rejection (code + text).
    Error,
}

impl FrameKind {
    fn tag(self) -> u8 {
        match self {
            FrameKind::Hello => 0,
            FrameKind::HelloAck => 1,
            FrameKind::Update => 2,
            FrameKind::Broadcast => 3,
            FrameKind::Done => 4,
            FrameKind::Error => 5,
        }
    }

    fn from_tag(t: u8) -> Result<Self, TransportError> {
        Ok(match t {
            0 => FrameKind::Hello,
            1 => FrameKind::HelloAck,
            2 => FrameKind::Update,
            3 => FrameKind::Broadcast,
            4 => FrameKind::Done,
            5 => FrameKind::Error,
            _ => return Err(TransportError::BadFrame(format!("unknown frame kind {t}"))),
        })
    }
}

/// One frame, owned — reusable as receive scratch (the payload buffer is
/// kept across [`read_frame`] calls).
#[derive(Clone, Debug, Default)]
pub struct FrameBuf {
    /// Message kind.
    pub kind: FrameKind,
    /// Communication round this frame belongs to (0 for handshake).
    pub round: u32,
    /// Sending (or addressed) client index.
    pub client: u32,
    /// Exact bit length of `payload` (the codec's bit count).
    pub payload_bits: u32,
    /// Payload bytes (`ceil(payload_bits / 8)` of them are meaningful).
    pub payload: Vec<u8>,
}

impl FrameBuf {
    /// Fill this frame in place (reusing the payload allocation).
    pub fn set(&mut self, kind: FrameKind, round: u32, client: u32, payload: &[u8], bits: u64) {
        debug_assert!(bits.div_ceil(8) <= payload.len() as u64);
        debug_assert!(bits <= u32::MAX as u64);
        self.kind = kind;
        self.round = round;
        self.client = client;
        self.payload_bits = bits as u32;
        self.payload.clear();
        self.payload.extend_from_slice(&payload[..bits.div_ceil(8) as usize]);
    }

    /// Payload length in bytes implied by `payload_bits`.
    pub fn payload_bytes(&self) -> usize {
        (self.payload_bits as u64).div_ceil(8) as usize
    }
}

/// Framing overhead in bits for a payload of `payload_bits`: header/CRC
/// bytes plus the padding that byte-aligns the payload on the socket.
/// By construction `payload_bits + overhead_bits(payload_bits)` equals
/// `8 * frame_wire_bytes(payload_bits)` exactly — the reconciliation
/// identity the federation tests assert against measured socket bytes.
pub fn overhead_bits(payload_bits: u64) -> u64 {
    HEADER_BYTES * 8 + (payload_bits.div_ceil(8) * 8 - payload_bits)
}

/// Total bytes a frame with `payload_bits` of payload occupies on the
/// wire, length prefix included.
pub fn frame_wire_bytes(payload_bits: u64) -> u64 {
    HEADER_BYTES + payload_bits.div_ceil(8)
}

// --- CRC-32 (IEEE 802.3, reflected) -----------------------------------

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc32_table();

/// CRC-32 (IEEE) over `chunks`, in order.
pub fn crc32(chunks: &[&[u8]]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for chunk in chunks {
        for &b in *chunk {
            c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
    }
    !c
}

// --- frame codec -------------------------------------------------------

const INNER_HEADER: usize = 16; // magic..payload_bits
const CRC_BYTES: usize = 4;

/// Serialize one frame to `w` (a single header write + payload write).
pub fn write_frame(w: &mut impl Write, f: &FrameBuf) -> Result<(), TransportError> {
    let payload = &f.payload[..f.payload_bytes()];
    let mut head = [0u8; 4 + INNER_HEADER + CRC_BYTES];
    let len = (INNER_HEADER + CRC_BYTES + payload.len()) as u32;
    head[0..4].copy_from_slice(&len.to_be_bytes());
    head[4..6].copy_from_slice(&MAGIC.to_be_bytes());
    head[6] = PROTOCOL_VERSION;
    head[7] = f.kind.tag();
    head[8..12].copy_from_slice(&f.round.to_be_bytes());
    head[12..16].copy_from_slice(&f.client.to_be_bytes());
    head[16..20].copy_from_slice(&f.payload_bits.to_be_bytes());
    let crc = crc32(&[&head[4..20], payload]);
    head[20..24].copy_from_slice(&crc.to_be_bytes());
    w.write_all(&head)?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame from `r` into `f` (reusing `f.payload`). Every
/// malformed input — bad magic, wrong protocol version, inconsistent
/// lengths, CRC mismatch, truncation — is a typed error; no input can
/// panic or trigger an unbounded allocation.
pub fn read_frame(r: &mut impl Read, f: &mut FrameBuf) -> Result<(), TransportError> {
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4)?;
    let len = u32::from_be_bytes(len4) as u64;
    if len < (INNER_HEADER + CRC_BYTES) as u64 {
        return Err(TransportError::BadFrame(format!("frame length {len} below header size")));
    }
    if len > INNER_HEADER as u64 + CRC_BYTES as u64 + MAX_PAYLOAD_BYTES {
        return Err(TransportError::BadFrame(format!("frame length {len} exceeds cap")));
    }
    let mut head = [0u8; INNER_HEADER + CRC_BYTES];
    r.read_exact(&mut head)?;
    if head[0..2] != MAGIC.to_be_bytes() {
        return Err(TransportError::BadFrame("bad frame magic".into()));
    }
    if head[2] != PROTOCOL_VERSION {
        return Err(TransportError::VersionMismatch { ours: PROTOCOL_VERSION, theirs: head[2] });
    }
    let kind = FrameKind::from_tag(head[3])?;
    let round = be_u32(&head, 8 - 4);
    let client = be_u32(&head, 12 - 4);
    let payload_bits = be_u32(&head, 16 - 4);
    let crc_wire = be_u32(&head, 20 - 4);
    let payload_len = len - (INNER_HEADER + CRC_BYTES) as u64;
    if payload_len != (payload_bits as u64).div_ceil(8) {
        return Err(TransportError::BadFrame(format!(
            "frame length {payload_len} inconsistent with payload_bits {payload_bits}"
        )));
    }
    // Grow the payload buffer only as bytes actually arrive (≤ 64 KiB at
    // a time): a hostile length field can then waste at most one chunk of
    // allocation before the read fails, instead of reserving the full
    // claimed size (up to MAX_PAYLOAD_BYTES) up front.
    const READ_CHUNK: usize = 64 * 1024;
    f.payload.clear();
    let mut remaining = payload_len as usize;
    while remaining > 0 {
        let take = remaining.min(READ_CHUNK);
        let start = f.payload.len();
        f.payload.resize(start + take, 0);
        r.read_exact(&mut f.payload[start..])?;
        remaining -= take;
    }
    let crc = crc32(&[&head[..INNER_HEADER], &f.payload]);
    if crc != crc_wire {
        return Err(TransportError::BadFrame(format!(
            "CRC mismatch: computed {crc:08x}, frame carries {crc_wire:08x}"
        )));
    }
    f.kind = kind;
    f.round = round;
    f.client = client;
    f.payload_bits = payload_bits;
    Ok(())
}

// --- handshake / control payloads --------------------------------------

/// `Hello` payload: everything the server validates before admitting a
/// client into the round loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Hello {
    /// Client index in `[0, clients)`.
    pub client: u32,
    /// The client's view of the fleet size.
    pub clients: u32,
    /// The client's flat parameter count.
    pub n_params: u64,
    /// Wire-format version the client encodes
    /// ([`crate::codec::message::WIRE_VERSION`]).
    pub wire_version: u8,
    /// Digest of the training configuration (method, seed, schedule…).
    pub config_digest: u64,
}

impl Hello {
    const LEN: usize = 4 + 4 + 8 + 1 + 8;

    /// Serialize to the fixed-size payload.
    pub fn encode(&self) -> Vec<u8> {
        let mut b = vec![0u8; Self::LEN];
        b[0..4].copy_from_slice(&self.client.to_be_bytes());
        b[4..8].copy_from_slice(&self.clients.to_be_bytes());
        b[8..16].copy_from_slice(&self.n_params.to_be_bytes());
        b[16] = self.wire_version;
        b[17..25].copy_from_slice(&self.config_digest.to_be_bytes());
        b
    }

    /// Parse from a frame payload.
    pub fn decode(b: &[u8]) -> Result<Hello, TransportError> {
        if b.len() < Self::LEN {
            return Err(TransportError::BadFrame(format!("hello payload {} bytes", b.len())));
        }
        Ok(Hello {
            client: be_u32(b, 0),
            clients: be_u32(b, 4),
            n_params: be_u64(b, 8),
            wire_version: b[16],
            config_digest: be_u64(b, 17),
        })
    }

    /// On-the-wire bits of a full `Hello` frame (for byte reconciliation).
    pub fn frame_bits() -> u64 {
        frame_wire_bytes(Self::LEN as u64 * 8) * 8
    }
}

/// `HelloAck` payload: the server's accepted-handshake reply.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HelloAck {
    /// The round the server is currently collecting (resync point for
    /// reconnecting clients).
    pub round: u32,
    /// Wire-format version the server speaks.
    pub wire_version: u8,
    /// Checkpoint round the server resumed from, or
    /// [`NO_RESUME`](HelloAck::NO_RESUME) for a fresh start. A client
    /// whose own checkpoint is newer than this fails fast (typed) rather
    /// than silently replaying rounds the server has forgotten.
    pub resume_round: u32,
}

impl HelloAck {
    const LEN: usize = 9;

    /// `resume_round` sentinel: the server started fresh (no checkpoint).
    pub const NO_RESUME: u32 = u32::MAX;

    /// Serialize to the fixed-size payload.
    pub fn encode(&self) -> Vec<u8> {
        let mut b = vec![0u8; Self::LEN];
        b[0..4].copy_from_slice(&self.round.to_be_bytes());
        b[4] = self.wire_version;
        b[5..9].copy_from_slice(&self.resume_round.to_be_bytes());
        b
    }

    /// Parse from a frame payload.
    pub fn decode(b: &[u8]) -> Result<HelloAck, TransportError> {
        if b.len() < Self::LEN {
            return Err(TransportError::BadFrame(format!("hello-ack payload {} bytes", b.len())));
        }
        Ok(HelloAck {
            round: be_u32(b, 0),
            wire_version: b[4],
            resume_round: be_u32(b, 5),
        })
    }

    /// On-the-wire bits of a full `HelloAck` frame.
    pub fn frame_bits() -> u64 {
        frame_wire_bytes(Self::LEN as u64 * 8) * 8
    }
}

/// Encode a `Done` payload (the final master-weight digest).
pub fn encode_done(digest: u64) -> Vec<u8> {
    digest.to_be_bytes().to_vec()
}

/// Parse a `Done` payload.
pub fn decode_done(b: &[u8]) -> Result<u64, TransportError> {
    if b.len() < 8 {
        return Err(TransportError::BadFrame(format!("done payload {} bytes", b.len())));
    }
    Ok(be_u64(b, 0))
}

/// On-the-wire bits of a full `Done` frame.
pub fn done_frame_bits() -> u64 {
    frame_wire_bytes(64) * 8
}

/// Encode an `Error` payload (rejection reason).
pub fn encode_error(msg: &str) -> Vec<u8> {
    msg.as_bytes().to_vec()
}

/// Parse an `Error` payload.
pub fn decode_error(b: &[u8]) -> String {
    String::from_utf8_lossy(b).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn frame(kind: FrameKind, payload: &[u8], bits: u64) -> FrameBuf {
        let mut f = FrameBuf::default();
        f.set(kind, 7, 3, payload, bits);
        f
    }

    #[test]
    fn crc32_reference_vector() {
        // the canonical IEEE check value
        assert_eq!(crc32(&[b"123456789"]), 0xCBF4_3926);
        assert_eq!(crc32(&[b"1234", b"56789"]), 0xCBF4_3926);
    }

    #[test]
    fn roundtrip_with_unaligned_bits() {
        let f = frame(FrameKind::Update, &[0xAB, 0xC0], 11);
        let mut buf = Vec::new();
        write_frame(&mut buf, &f).unwrap();
        assert_eq!(buf.len() as u64, frame_wire_bytes(11));
        // dirty reused scratch
        let mut got = frame(FrameKind::Done, &[1, 2, 3, 4], 32);
        read_frame(&mut Cursor::new(&buf), &mut got).unwrap();
        assert_eq!(got.kind, FrameKind::Update);
        assert_eq!((got.round, got.client, got.payload_bits), (7, 3, 11));
        assert_eq!(&got.payload[..], &[0xAB, 0xC0]);
    }

    #[test]
    fn overhead_reconciles_exactly() {
        for bits in [0u64, 1, 7, 8, 9, 1000, 4096, 12345] {
            assert_eq!(bits + overhead_bits(bits), frame_wire_bytes(bits) * 8, "{bits}");
        }
    }

    #[test]
    fn every_single_byte_corruption_is_rejected() {
        let f = frame(FrameKind::Broadcast, &[1, 2, 3, 4, 5], 40);
        let mut buf = Vec::new();
        write_frame(&mut buf, &f).unwrap();
        for i in 0..buf.len() {
            let mut bad = buf.clone();
            bad[i] ^= 0x40;
            let mut out = FrameBuf::default();
            assert!(
                read_frame(&mut Cursor::new(&bad), &mut out).is_err(),
                "flip at byte {i} accepted"
            );
        }
        // truncation at every boundary
        for cut in 0..buf.len() {
            let mut out = FrameBuf::default();
            assert!(read_frame(&mut Cursor::new(&buf[..cut]), &mut out).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn oversized_length_is_bounded() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &frame(FrameKind::Hello, &[], 0)).unwrap();
        buf[0..4].copy_from_slice(&u32::MAX.to_be_bytes());
        let mut out = FrameBuf::default();
        let err = read_frame(&mut Cursor::new(&buf), &mut out).unwrap_err();
        assert!(matches!(err, TransportError::BadFrame(_)), "{err}");
    }

    #[test]
    fn handshake_payloads_roundtrip() {
        let h = Hello { client: 2, clients: 4, n_params: 9999, wire_version: 2, config_digest: 0xDEAD_BEEF };
        assert_eq!(Hello::decode(&h.encode()).unwrap(), h);
        let a = HelloAck { round: 12, wire_version: 2, resume_round: HelloAck::NO_RESUME };
        assert_eq!(HelloAck::decode(&a.encode()).unwrap(), a);
        let resumed = HelloAck { round: 12, wire_version: 2, resume_round: 12 };
        assert_eq!(HelloAck::decode(&resumed.encode()).unwrap(), resumed);
        assert_eq!(decode_done(&encode_done(42)).unwrap(), 42);
        assert!(Hello::decode(&[0u8; 3]).is_err());
        assert!(HelloAck::decode(&[]).is_err());
        assert!(decode_done(&[1]).is_err());
    }
}
