//! In-memory loopback transport: deterministic byte pipes with the exact
//! blocking semantics of a socket (EOF on peer drop, read timeouts),
//! plus the two instruments the federation tests need — per-direction
//! byte counters (socket-bytes ↔ accounting reconciliation) and a fault
//! hook that kills a chosen send to exercise the retry path.

use std::collections::VecDeque;
use std::io::{self, Read, Write};
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Duration;

use crate::transport::frame::FrameBuf;
use crate::transport::{Acceptor, Connector, FramedConn, Transport, TransportCfg, TransportError};

/// A peer thread that panicked mid-round poisons the shared mutexes; a
/// dead peer must look like a dead socket (typed error), never propagate
/// the panic into this thread. The queue state itself stays coherent
/// under poison — writers mutate it only through single non-panicking
/// statements — so recovering the guard is safe.
fn lock_ignore_poison<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

fn peer_died() -> io::Error {
    io::Error::new(io::ErrorKind::BrokenPipe, "loopback peer thread died")
}

/// One direction of a connection: a byte queue with socket semantics.
struct Pipe {
    state: Mutex<PipeState>,
    cv: Condvar,
}

struct PipeState {
    buf: VecDeque<u8>,
    closed: bool,
}

impl Pipe {
    fn new() -> Arc<Pipe> {
        Arc::new(Pipe { state: Mutex::new(PipeState { buf: VecDeque::new(), closed: false }), cv: Condvar::new() })
    }

    fn close(&self) {
        lock_ignore_poison(&self.state).closed = true;
        self.cv.notify_all();
    }
}

/// One endpoint of a loopback connection (a reader pipe + a writer pipe).
/// Dropping it closes both directions, so the peer observes EOF exactly
/// like a closed socket.
pub struct LoopbackStream {
    rx: Arc<Pipe>,
    tx: Arc<Pipe>,
    sent: Arc<AtomicU64>,
    read_timeout: Duration,
}

impl Read for LoopbackStream {
    fn read(&mut self, out: &mut [u8]) -> io::Result<usize> {
        if out.is_empty() {
            return Ok(0);
        }
        let Ok(mut st) = self.rx.state.lock() else { return Err(peer_died()) };
        while st.buf.is_empty() {
            if st.closed {
                return Ok(0); // EOF
            }
            let Ok((next, timed_out)) = self.rx.cv.wait_timeout(st, self.read_timeout) else {
                return Err(peer_died());
            };
            st = next;
            if timed_out.timed_out() && st.buf.is_empty() && !st.closed {
                return Err(io::Error::new(io::ErrorKind::TimedOut, "loopback read timed out"));
            }
        }
        let n = out.len().min(st.buf.len());
        for (slot, byte) in out.iter_mut().zip(st.buf.drain(..n)) {
            *slot = byte;
        }
        Ok(n)
    }
}

impl Write for LoopbackStream {
    fn write(&mut self, data: &[u8]) -> io::Result<usize> {
        let Ok(mut st) = self.tx.state.lock() else { return Err(peer_died()) };
        if st.closed {
            return Err(io::Error::new(io::ErrorKind::BrokenPipe, "loopback peer closed"));
        }
        st.buf.extend(data.iter().copied());
        self.sent.fetch_add(data.len() as u64, Ordering::Relaxed);
        self.tx.cv.notify_all();
        Ok(data.len())
    }

    fn flush(&mut self) -> io::Result<()> {
        Ok(())
    }
}

impl Drop for LoopbackStream {
    fn drop(&mut self) {
        self.rx.close();
        self.tx.close();
    }
}

struct HubState {
    pending: VecDeque<Box<dyn Transport>>,
    closed: bool,
}

struct HubInner {
    state: Mutex<HubState>,
    cv: Condvar,
    /// Bytes written by clients toward the server (shared with streams).
    to_server: Arc<AtomicU64>,
    /// Bytes written by the server toward clients (shared with streams).
    to_clients: Arc<AtomicU64>,
    read_timeout: Duration,
}

/// An in-memory "listener": connectors enqueue fully-formed server-side
/// connections, [`Acceptor::accept`] dequeues them. Cloning shares the
/// hub.
#[derive(Clone)]
pub struct LoopbackHub(Arc<HubInner>);

impl LoopbackHub {
    /// A fresh hub whose streams use `cfg.read_timeout` for blocking
    /// reads.
    pub fn new(cfg: &TransportCfg) -> LoopbackHub {
        LoopbackHub(Arc::new(HubInner {
            state: Mutex::new(HubState { pending: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
            to_server: Arc::new(AtomicU64::new(0)),
            to_clients: Arc::new(AtomicU64::new(0)),
            read_timeout: cfg.read_timeout,
        }))
    }

    /// A clean connector for one client.
    pub fn connector(&self) -> LoopbackConnector {
        LoopbackConnector { hub: self.clone(), fault: None }
    }

    /// A connector whose `n`-th successful frame send (1-based, handshake
    /// included, counted across reconnects) fails with a connection
    /// reset — the deterministic mid-round drop the retry tests use.
    pub fn faulty_connector(&self, fail_at_send: u64) -> LoopbackConnector {
        LoopbackConnector { hub: self.clone(), fault: Some(Arc::new(AtomicI64::new(fail_at_send as i64))) }
    }

    /// Total bytes clients have written toward the server.
    pub fn bytes_to_server(&self) -> u64 {
        self.0.to_server.load(Ordering::Relaxed)
    }

    /// Total bytes the server has written toward clients.
    pub fn bytes_to_clients(&self) -> u64 {
        self.0.to_clients.load(Ordering::Relaxed)
    }

    fn connect(&self) -> Result<Box<dyn Transport>, TransportError> {
        let inner = &self.0;
        let a = Pipe::new(); // client -> server
        let b = Pipe::new(); // server -> client
        let client = LoopbackStream {
            rx: b.clone(),
            tx: a.clone(),
            sent: inner.to_server.clone(),
            read_timeout: inner.read_timeout,
        };
        let server = LoopbackStream {
            rx: a,
            tx: b,
            sent: inner.to_clients.clone(),
            read_timeout: inner.read_timeout,
        };
        let mut st = inner.state.lock().map_err(|_| TransportError::Closed)?;
        if st.closed {
            return Err(TransportError::Closed);
        }
        st.pending.push_back(Box::new(FramedConn::new(server, "loopback-client".into())));
        inner.cv.notify_all();
        drop(st);
        Ok(Box::new(FramedConn::new(client, "loopback-server".into())))
    }
}

impl Acceptor for LoopbackHub {
    fn accept(&self) -> Result<Box<dyn Transport>, TransportError> {
        let inner = &self.0;
        let mut st = inner.state.lock().map_err(|_| TransportError::Closed)?;
        loop {
            if let Some(conn) = st.pending.pop_front() {
                return Ok(conn);
            }
            if st.closed {
                return Err(TransportError::Closed);
            }
            st = inner.cv.wait(st).map_err(|_| TransportError::Closed)?;
        }
    }

    fn shutdown(&self) {
        lock_ignore_poison(&self.0.state).closed = true;
        self.0.cv.notify_all();
    }
}

/// [`Connector`] for a [`LoopbackHub`], optionally carrying a fault plan.
pub struct LoopbackConnector {
    hub: LoopbackHub,
    fault: Option<Arc<AtomicI64>>,
}

impl Connector for LoopbackConnector {
    fn connect(&self) -> Result<Box<dyn Transport>, TransportError> {
        let conn = self.hub.connect()?;
        match &self.fault {
            None => Ok(conn),
            Some(countdown) => Ok(Box::new(FaultyConn { inner: conn, countdown: countdown.clone() })),
        }
    }
}

/// Transport wrapper that fails exactly one send (when the shared
/// countdown hits zero), simulating a connection dropped mid-round. The
/// countdown is shared across reconnects from the same connector, so the
/// retried exchange goes through cleanly.
struct FaultyConn {
    inner: Box<dyn Transport>,
    countdown: Arc<AtomicI64>,
}

impl Transport for FaultyConn {
    fn send(&mut self, f: &FrameBuf) -> Result<(), TransportError> {
        if self.countdown.fetch_sub(1, Ordering::SeqCst) == 1 {
            return Err(TransportError::Io(io::Error::new(
                io::ErrorKind::ConnectionReset,
                "injected fault: connection dropped mid-send",
            )));
        }
        self.inner.send(f)
    }

    fn recv(&mut self, into: &mut FrameBuf) -> Result<(), TransportError> {
        self.inner.recv(into)
    }

    fn peer(&self) -> String {
        self.inner.peer()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    /// Poison `run`'s mutex the only way possible: panic while holding it.
    fn poison_by_panicking_while_locked(f: impl FnOnce() + Send + 'static) {
        thread::spawn(f).join().unwrap_err();
    }

    #[test]
    fn read_on_poisoned_pipe_errors_instead_of_panicking() {
        let rx = Pipe::new();
        let tx = Pipe::new();
        {
            let rx = rx.clone();
            poison_by_panicking_while_locked(move || {
                let _g = rx.state.lock().unwrap();
                panic!("peer dies holding the pipe lock");
            });
        }
        let mut stream = LoopbackStream {
            rx,
            tx,
            sent: Arc::new(AtomicU64::new(0)),
            read_timeout: Duration::from_millis(50),
        };
        let err = stream.read(&mut [0u8; 4]).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::BrokenPipe, "{err}");
    }

    #[test]
    fn write_on_poisoned_pipe_errors_instead_of_panicking() {
        let rx = Pipe::new();
        let tx = Pipe::new();
        {
            let tx = tx.clone();
            poison_by_panicking_while_locked(move || {
                let _g = tx.state.lock().unwrap();
                panic!("peer dies holding the pipe lock");
            });
        }
        let mut stream = LoopbackStream {
            rx,
            tx,
            sent: Arc::new(AtomicU64::new(0)),
            read_timeout: Duration::from_millis(50),
        };
        let err = stream.write(&[1, 2, 3]).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::BrokenPipe, "{err}");
        // dropping the stream closes both pipes through the poisoned lock
        // without panicking
        drop(stream);
    }

    #[test]
    fn hub_with_poisoned_state_surfaces_closed() {
        let hub = LoopbackHub::new(&TransportCfg::default());
        {
            let hub = hub.clone();
            poison_by_panicking_while_locked(move || {
                let _g = hub.0.state.lock().unwrap();
                panic!("accept-side thread dies holding the hub lock");
            });
        }
        assert!(matches!(hub.accept(), Err(TransportError::Closed)));
        assert!(matches!(hub.connector().connect(), Err(TransportError::Closed)));
        hub.shutdown(); // must not panic either
    }
}
