//! Network simulator: converts measured message bits into wall-clock and
//! monetary cost under configurable link models (paper §I/§III motivation:
//! datacenter NICs vs. mobile clients on metered plans).
//!
//! The coordinator feeds every encoded message through a [`NetSim`]; the
//! examples report end-to-end communication time/cost per method.

/// A link profile for one direction.
#[derive(Clone, Copy, Debug)]
pub struct Link {
    /// Sustained bandwidth, bits per second.
    pub bandwidth_bps: f64,
    /// Per-message latency, seconds.
    pub latency_s: f64,
    /// Cost per transferred megabyte (e.g. mobile data plan), $.
    pub usd_per_mb: f64,
}

impl Link {
    /// Datacenter fabric: 10 Gb/s line rate (IEEE 802.3ae 10GBASE NICs,
    /// standard for the intra-rack links the paper's §I "cluster"
    /// scenario assumes), 50 µs per-message latency (one intra-datacenter
    /// RTT — sub-100 µs is typical for a single switch hop), free.
    pub fn datacenter_10g() -> Link {
        Link { bandwidth_bps: 10e9, latency_s: 50e-6, usd_per_mb: 0.0 }
    }

    /// Home/office WiFi: 100 Mb/s sustained throughput — the realistic
    /// TCP goodput of an 802.11n/ac link (well below PHY rates) — and
    /// 3 ms latency, a typical single-AP wireless RTT. Unmetered.
    pub fn wifi() -> Link {
        Link { bandwidth_bps: 100e6, latency_s: 3e-3, usd_per_mb: 0.0 }
    }

    /// Mobile LTE **uplink**: 12 Mb/s (LTE UE category 4/6 uplink
    /// measured averages in the 2018-era reports, e.g. OpenSignal "State
    /// of LTE", Feb 2018 — upload is several times slower than the
    /// headline downlink), 40 ms RTT (typical measured LTE latency), at
    /// $5/GB — a round mid-2018 metered mobile-data price used for the
    /// paper's §I "on-device" cost motivation.
    pub fn mobile_lte() -> Link {
        Link { bandwidth_bps: 12e6, latency_s: 40e-3, usd_per_mb: 0.005 }
    }

    /// Rural/congested 3G: 1 Mb/s uplink (HSPA real-world uplink
    /// throughput; ITU IMT-2000 class), 150 ms RTT (3G control-plane
    /// latency), at $20/GB (metered prepaid rates in low-connectivity
    /// markets — the worst case for federated clients).
    pub fn rural_3g() -> Link {
        Link { bandwidth_bps: 1e6, latency_s: 150e-3, usd_per_mb: 0.02 }
    }

    /// Transfer time for a message of `bits`.
    pub fn transfer_time(&self, bits: u64) -> f64 {
        self.latency_s + bits as f64 / self.bandwidth_bps
    }
}

/// Per-client accumulated communication totals.
#[derive(Clone, Debug, Default)]
pub struct ClientComm {
    /// Total bits this client uploaded.
    pub up_bits: u64,
    /// Total broadcast bits this client received.
    pub down_bits: u64,
    /// Wall-clock spent uploading.
    pub up_time_s: f64,
    /// Wall-clock spent receiving broadcasts.
    pub down_time_s: f64,
    /// Messages sent (one per participating round).
    pub messages: u64,
}

/// Synchronous-round network model: per round, all clients upload in
/// parallel (round time = slowest client) and the server broadcasts back.
#[derive(Clone, Debug)]
pub struct NetSim {
    /// Client→server link model.
    pub up: Link,
    /// Server→client link model.
    pub down: Link,
    /// Per-client accumulated totals.
    pub clients: Vec<ClientComm>,
    /// Wall-clock spent in communication across all rounds.
    pub total_comm_time_s: f64,
}

impl NetSim {
    /// A simulator over `n_clients` with asymmetric links.
    pub fn new(up: Link, down: Link, n_clients: usize) -> Self {
        NetSim { up, down, clients: vec![ClientComm::default(); n_clients], total_comm_time_s: 0.0 }
    }

    /// A simulator whose up- and downlink share one profile.
    pub fn symmetric(link: Link, n_clients: usize) -> Self {
        Self::new(link, link, n_clients)
    }

    /// Record one synchronous round: `up_bits[i]` is client i's upload,
    /// `down_bits` the broadcast size. Returns the round's comm time.
    pub fn round(&mut self, up_bits: &[u64], down_bits: u64) -> f64 {
        let mut slowest_up = 0.0f64;
        for (c, &bits) in self.clients.iter_mut().zip(up_bits) {
            let t = self.up.transfer_time(bits);
            c.up_bits += bits;
            c.up_time_s += t;
            c.messages += 1;
            slowest_up = slowest_up.max(t);
        }
        let t_down = self.down.transfer_time(down_bits);
        for c in self.clients.iter_mut() {
            c.down_bits += down_bits;
            c.down_time_s += t_down;
        }
        let round_time = slowest_up + t_down;
        self.total_comm_time_s += round_time;
        round_time
    }

    /// Total upstream monetary cost across clients.
    pub fn upstream_cost_usd(&self) -> f64 {
        self.clients.iter().map(|c| c.up_bits as f64 / 8e6 * self.up.usd_per_mb).sum()
    }

    /// Total upstream bits across all clients.
    pub fn total_up_bits(&self) -> u64 {
        self.clients.iter().map(|c| c.up_bits).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_scales() {
        let l = Link::mobile_lte();
        let t1 = l.transfer_time(12_000_000); // 1s of payload
        assert!((t1 - 1.04).abs() < 1e-9);
        assert!(l.transfer_time(0) == l.latency_s);
    }

    #[test]
    fn round_takes_slowest_client() {
        let mut net = NetSim::symmetric(Link { bandwidth_bps: 1e6, latency_s: 0.0, usd_per_mb: 0.0 }, 3);
        let t = net.round(&[1_000_000, 2_000_000, 500_000], 1_000_000);
        assert!((t - 3.0).abs() < 1e-9); // 2s slowest up + 1s down
        assert_eq!(net.total_up_bits(), 3_500_000);
        assert_eq!(net.clients[0].down_bits, 1_000_000);
    }

    #[test]
    fn metered_cost() {
        let mut net = NetSim::symmetric(Link::rural_3g(), 2);
        net.round(&[8e6 as u64, 8e6 as u64], 0); // 1 MB each
        assert!((net.upstream_cost_usd() - 0.04).abs() < 1e-9);
    }
}
