//! The fault-schedule DSL: which frames the simulator drops, duplicates,
//! corrupts, delays or uses to kill a connection.
//!
//! Faults are decided **per frame send**, from two layers:
//!
//! 1. an explicit [`FaultPlan`] — ordered [`FaultRule`]s whose [`When`]
//!    predicates match on the frame's [`FrameCtx`] (client, connection
//!    attempt, per-connection sequence number, direction, kind, round,
//!    n-th match); first matching rule wins;
//! 2. a probabilistic [`SimProfile`] — per-frame fault sampling from an
//!    RNG stream keyed by `(seed, client, attempt, seq, dir)`, so every
//!    decision is a pure function of the seed and the frame's identity,
//!    independent of thread timing.
//!
//! Every fault the simulator *applies* is recorded as an
//! [`AppliedFault`]; the shrinker suppresses subsets of those records
//! (via [`FaultPlan::suppress`]) to find a minimal reproducing schedule,
//! then re-expresses the survivors as exact [`FaultRule`]s
//! ([`AppliedFault::to_rule`]) and a copy-pastable test case.

use std::collections::HashSet;
use std::fmt;

use crate::transport::frame::FrameKind;
use crate::util::rng::Rng;

/// Direction of a frame on a simulated connection.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Dir {
    /// Client → server.
    Up,
    /// Server → client.
    Down,
}

impl fmt::Display for Dir {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Dir::Up => write!(f, "up"),
            Dir::Down => write!(f, "down"),
        }
    }
}

/// Identity of one frame send, as seen by the fault layer. `(client,
/// attempt, seq, dir)` is unique per simulation and deterministic across
/// replays: each side of each connection numbers its own sends.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FrameCtx {
    /// Owning client id (both directions of that client's connections).
    pub client: u32,
    /// 0-based connection attempt for this client (bumped on reconnect).
    pub attempt: u32,
    /// 0-based send sequence number within `(client, attempt, dir)`.
    pub seq: u64,
    /// Frame direction.
    pub dir: Dir,
    /// Frame kind (handshake frames are faultable too).
    pub kind: FrameKind,
    /// Protocol round the frame carries.
    pub round: u32,
}

/// Unique, replay-stable key of one frame send (the [`FrameCtx`] minus
/// the descriptive fields).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FaultKey {
    /// Owning client id.
    pub client: u32,
    /// Connection attempt.
    pub attempt: u32,
    /// Per-`(client, attempt, dir)` send sequence number.
    pub seq: u64,
    /// Frame direction.
    pub dir: Dir,
}

impl FrameCtx {
    /// The replay-stable key of this send.
    pub fn key(&self) -> FaultKey {
        FaultKey { client: self.client, attempt: self.attempt, seq: self.seq, dir: self.dir }
    }
}

/// What to do to a matched frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// Silently discard the frame (the peer sees nothing).
    Drop,
    /// Deliver the frame twice (the copy trails by one jitter draw).
    Duplicate,
    /// Flip one bit of the serialized frame (position = value mod bits).
    CorruptBit(u32),
    /// Hold the frame for an extra `ms` before delivery (straggler pause
    /// when it exceeds the server's round timeout).
    DelayMs(u64),
    /// Tear the connection down (both directions, in-flight frames lost)
    /// — the simulator's client crash/restart point: the session's
    /// reconnect path is the restart.
    KillConn,
}

impl fmt::Display for FaultAction {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultAction::Drop => write!(f, "drop"),
            FaultAction::Duplicate => write!(f, "dup"),
            FaultAction::CorruptBit(b) => write!(f, "corrupt(bit {b})"),
            FaultAction::DelayMs(ms) => write!(f, "delay({ms}ms)"),
            FaultAction::KillConn => write!(f, "kill"),
        }
    }
}

/// Predicate over [`FrameCtx`] — every field is optional; `When::any()`
/// matches everything, and each setter narrows the match.
#[derive(Clone, Debug, Default)]
pub struct When {
    clients: Option<Vec<u32>>,
    rounds: Option<(u32, u32)>,
    kinds: Option<Vec<FrameKind>>,
    dir: Option<Dir>,
    attempt: Option<u32>,
    seq: Option<u64>,
    nth: Option<u64>,
}

impl When {
    /// Match every frame.
    pub fn any() -> When {
        When::default()
    }

    /// Restrict to one client id.
    pub fn client(mut self, c: u32) -> When {
        self.clients.get_or_insert_with(Vec::new).push(c);
        self
    }

    /// Restrict to rounds in `[lo, hi]` (inclusive).
    pub fn rounds(mut self, lo: u32, hi: u32) -> When {
        self.rounds = Some((lo, hi));
        self
    }

    /// Restrict to one round.
    pub fn round(self, r: u32) -> When {
        self.rounds(r, r)
    }

    /// Restrict to one frame kind.
    pub fn kind(mut self, k: FrameKind) -> When {
        self.kinds.get_or_insert_with(Vec::new).push(k);
        self
    }

    /// Restrict to one direction.
    pub fn dir(mut self, d: Dir) -> When {
        self.dir = Some(d);
        self
    }

    /// Restrict to one connection attempt.
    pub fn attempt(mut self, a: u32) -> When {
        self.attempt = Some(a);
        self
    }

    /// Restrict to one per-connection send sequence number.
    pub fn seq(mut self, s: u64) -> When {
        self.seq = Some(s);
        self
    }

    /// Fire only on the n-th (1-based) frame this rule matches.
    pub fn nth(mut self, n: u64) -> When {
        self.nth = Some(n);
        self
    }

    fn matches(&self, ctx: &FrameCtx) -> bool {
        if let Some(cs) = &self.clients {
            if !cs.contains(&ctx.client) {
                return false;
            }
        }
        if let Some((lo, hi)) = self.rounds {
            if ctx.round < lo || ctx.round > hi {
                return false;
            }
        }
        if let Some(ks) = &self.kinds {
            if !ks.contains(&ctx.kind) {
                return false;
            }
        }
        if self.dir.is_some_and(|d| d != ctx.dir) {
            return false;
        }
        if self.attempt.is_some_and(|a| a != ctx.attempt) {
            return false;
        }
        if self.seq.is_some_and(|s| s != ctx.seq) {
            return false;
        }
        true
    }
}

/// One `when → action` entry of a [`FaultPlan`].
#[derive(Clone, Debug)]
pub struct FaultRule {
    /// The predicate.
    pub when: When,
    /// The fault to apply to matching frames.
    pub action: FaultAction,
}

/// Background per-frame fault probabilities, sampled from a seeded RNG
/// stream per frame (see module docs). All default to 0 (no faults).
#[derive(Clone, Copy, Debug, Default)]
pub struct SimProfile {
    /// P(drop) per frame.
    pub drop_p: f64,
    /// P(duplicate) per frame.
    pub dup_p: f64,
    /// P(single-bit corruption) per frame.
    pub corrupt_p: f64,
    /// P(connection kill) per frame send.
    pub kill_p: f64,
    /// P(straggler pause) per frame.
    pub straggle_p: f64,
    /// Straggler pause length, milliseconds.
    pub straggle_ms: u64,
}

impl SimProfile {
    /// A mild chaos profile: occasional drops/dups/corruption/kills and
    /// sub-round-timeout straggler pauses — most schedules should still
    /// complete, exercising every recovery path.
    pub fn light() -> SimProfile {
        SimProfile {
            drop_p: 0.02,
            dup_p: 0.02,
            corrupt_p: 0.02,
            kill_p: 0.01,
            straggle_p: 0.02,
            straggle_ms: 40,
        }
    }

    /// A harsh profile: frequent faults and pauses long enough to blow
    /// round timeouts — many schedules end in typed errors.
    pub fn harsh() -> SimProfile {
        SimProfile {
            drop_p: 0.08,
            dup_p: 0.06,
            corrupt_p: 0.06,
            kill_p: 0.04,
            straggle_p: 0.05,
            straggle_ms: 900,
        }
    }
}

/// An ordered set of explicit fault rules plus a suppression set used by
/// the shrinker to subtract individual applied faults from a schedule.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    rules: Vec<FaultRule>,
    suppress: HashSet<FaultKey>,
}

/// Per-run mutable state for a plan's `nth` counters (owned by the
/// simulator, one per run, so a [`FaultPlan`] itself stays immutable and
/// reusable across replays).
#[derive(Debug, Default)]
pub struct PlanCounters {
    matched: Vec<u64>,
}

impl FaultPlan {
    /// The empty plan (profile faults only).
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Append `when → action`; earlier rules take precedence.
    pub fn rule(mut self, when: When, action: FaultAction) -> FaultPlan {
        self.rules.push(FaultRule { when, action });
        self
    }

    /// A plan that replays exactly the given applied faults (used by the
    /// shrinker's standalone repro).
    pub fn exact(events: &[AppliedFault]) -> FaultPlan {
        let mut plan = FaultPlan::new();
        for ev in events {
            plan.rules.push(ev.to_rule());
        }
        plan
    }

    /// Suppress one applied fault by its replay-stable key: the decision
    /// layer re-derives the same fault and then skips it. This is how the
    /// shrinker removes events without perturbing the rest of the
    /// schedule (RNG draws and jitter are keyed per frame, so skipping
    /// one fault cannot shift any other decision).
    pub fn suppress(mut self, key: FaultKey) -> FaultPlan {
        self.suppress.insert(key);
        self
    }

    /// Number of explicit rules.
    pub fn len(&self) -> usize {
        self.rules.len()
    }

    /// Whether the plan has no explicit rules.
    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }

    /// Fresh `nth` counters for one run.
    pub fn counters(&self) -> PlanCounters {
        PlanCounters { matched: vec![0; self.rules.len()] }
    }

    /// Decide the fault (if any) for one frame send. `seed` is the
    /// simulation seed; the probabilistic layer only fires when no
    /// explicit rule matches.
    pub fn decide(
        &self,
        seed: u64,
        profile: &SimProfile,
        counters: &mut PlanCounters,
        ctx: &FrameCtx,
    ) -> Option<FaultAction> {
        let mut decided = None;
        for (i, r) in self.rules.iter().enumerate() {
            if r.when.matches(ctx) {
                counters.matched[i] += 1;
                if let Some(n) = r.when.nth {
                    if counters.matched[i] != n {
                        continue;
                    }
                }
                decided = Some(r.action);
                break;
            }
        }
        if decided.is_none() {
            decided = sample_profile(seed, profile, ctx);
        }
        decided.filter(|_| !self.suppress.contains(&ctx.key()))
    }
}

/// RNG stream for one frame's fault decision: a pure function of the
/// seed and the frame key, so decisions survive replay and suppression.
fn frame_rng(seed: u64, salt: u64, key: &FaultKey) -> Rng {
    let mix = seed
        ^ salt
        ^ (key.client as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (key.attempt as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F)
        ^ key.seq.wrapping_mul(0x1656_67B1_9E37_79F9)
        ^ match key.dir {
            Dir::Up => 0x5851_F42D_4C95_7F2D,
            Dir::Down => 0x1405_7B7E_F767_814F,
        };
    Rng::new(mix)
}

/// Jitter stream — salted differently from the fault stream so zeroing
/// fault probabilities (the shrinker's standalone replay) leaves every
/// delivery jitter untouched.
pub(crate) fn jitter_rng(seed: u64, key: &FaultKey) -> Rng {
    frame_rng(seed, 0x6A09_E667_F3BC_C909, key)
}

fn sample_profile(seed: u64, p: &SimProfile, ctx: &FrameCtx) -> Option<FaultAction> {
    let mut rng = frame_rng(seed, 0xBB67_AE85_84CA_A73B, &ctx.key());
    // fixed draw order: each fault type consumes exactly one draw, so a
    // probability of 0 changes nothing downstream
    let kill = rng.next_f64() < p.kill_p;
    let drop = rng.next_f64() < p.drop_p;
    let dup = rng.next_f64() < p.dup_p;
    let corrupt = rng.next_f64() < p.corrupt_p;
    let straggle = rng.next_f64() < p.straggle_p;
    let corrupt_bit = rng.next_u32();
    if kill {
        Some(FaultAction::KillConn)
    } else if drop {
        Some(FaultAction::Drop)
    } else if dup {
        Some(FaultAction::Duplicate)
    } else if corrupt {
        Some(FaultAction::CorruptBit(corrupt_bit))
    } else if straggle {
        Some(FaultAction::DelayMs(p.straggle_ms))
    } else {
        None
    }
}

/// One fault the simulator actually applied: the frame's full context
/// plus the action. The transcript lists these; the shrinker minimizes
/// over them.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AppliedFault {
    /// The frame the fault hit.
    pub ctx: FrameCtx,
    /// What was done to it.
    pub action: FaultAction,
}

impl AppliedFault {
    /// An exact rule that re-applies this fault and nothing else.
    pub fn to_rule(&self) -> FaultRule {
        let mut when = When::any()
            .client(self.ctx.client)
            .attempt(self.ctx.attempt)
            .seq(self.ctx.seq)
            .dir(self.ctx.dir);
        when.kinds = Some(vec![self.ctx.kind]);
        FaultRule { when, action: self.action }
    }

    /// Render as a copy-pastable `FaultPlan` builder call.
    pub fn render(&self) -> String {
        let action = match self.action {
            FaultAction::Drop => "FaultAction::Drop".into(),
            FaultAction::Duplicate => "FaultAction::Duplicate".into(),
            FaultAction::CorruptBit(b) => format!("FaultAction::CorruptBit({b})"),
            FaultAction::DelayMs(ms) => format!("FaultAction::DelayMs({ms})"),
            FaultAction::KillConn => "FaultAction::KillConn".into(),
        };
        format!(
            ".rule(When::any().client({}).attempt({}).seq({}).dir(Dir::{:?}), {})  // {:?} round {}",
            self.ctx.client, self.ctx.attempt, self.ctx.seq, self.ctx.dir, action, self.ctx.kind, self.ctx.round
        )
    }
}

/// Render a minimal schedule as a ready-to-paste test-case snippet.
pub fn render_repro(seed: u64, events: &[AppliedFault]) -> String {
    let mut s = format!(
        "// minimal reproducing schedule (seed {seed}, {} fault{}):\nlet plan = FaultPlan::new()\n",
        events.len(),
        if events.len() == 1 { "" } else { "s" },
    );
    for ev in events {
        s.push_str("    ");
        s.push_str(&ev.render());
        s.push('\n');
    }
    s.push_str(";\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(client: u32, seq: u64, dir: Dir, kind: FrameKind, round: u32) -> FrameCtx {
        FrameCtx { client, attempt: 0, seq, dir, kind, round }
    }

    #[test]
    fn rule_precedence_and_predicates() {
        let plan = FaultPlan::new()
            .rule(When::any().client(1).kind(FrameKind::Update).round(2), FaultAction::Drop)
            .rule(When::any().client(1), FaultAction::Duplicate);
        let profile = SimProfile::default();
        let mut c = plan.counters();
        // first rule wins where it matches
        assert_eq!(
            plan.decide(0, &profile, &mut c, &ctx(1, 0, Dir::Up, FrameKind::Update, 2)),
            Some(FaultAction::Drop)
        );
        // falls through to the second rule
        assert_eq!(
            plan.decide(0, &profile, &mut c, &ctx(1, 0, Dir::Up, FrameKind::Hello, 0)),
            Some(FaultAction::Duplicate)
        );
        // no rule, zero profile: clean
        assert_eq!(plan.decide(0, &profile, &mut c, &ctx(2, 0, Dir::Up, FrameKind::Update, 2)), None);
    }

    #[test]
    fn nth_counts_matches_not_frames() {
        let plan = FaultPlan::new()
            .rule(When::any().kind(FrameKind::Update).nth(2), FaultAction::KillConn);
        let mut c = plan.counters();
        let profile = SimProfile::default();
        assert_eq!(plan.decide(0, &profile, &mut c, &ctx(0, 0, Dir::Up, FrameKind::Update, 0)), None);
        assert_eq!(
            plan.decide(0, &profile, &mut c, &ctx(0, 1, Dir::Up, FrameKind::Update, 0)),
            Some(FaultAction::KillConn)
        );
        assert_eq!(plan.decide(0, &profile, &mut c, &ctx(0, 2, Dir::Up, FrameKind::Update, 0)), None);
    }

    #[test]
    fn profile_sampling_is_replay_stable_and_suppressible() {
        let profile = SimProfile::harsh();
        let plan = FaultPlan::new();
        // find a frame the profile faults
        let mut hit = None;
        for seq in 0..500u64 {
            let ctx = ctx(3, seq, Dir::Up, FrameKind::Update, 1);
            let mut c = plan.counters();
            if let Some(a) = plan.decide(7, &profile, &mut c, &ctx) {
                hit = Some((ctx, a));
                break;
            }
        }
        let (ctx, action) = hit.expect("harsh profile fired at least once in 500 frames");
        // identical decision on replay
        let mut c = plan.counters();
        assert_eq!(plan.decide(7, &profile, &mut c, &ctx), Some(action));
        // suppressed by key, without touching any other frame
        let sup = plan.clone().suppress(ctx.key());
        let mut c = sup.counters();
        assert_eq!(sup.decide(7, &profile, &mut c, &ctx), None);
    }

    #[test]
    fn exact_plan_reapplies_only_listed_events() {
        let ev = AppliedFault {
            ctx: ctx(2, 5, Dir::Down, FrameKind::Broadcast, 3),
            action: FaultAction::CorruptBit(77),
        };
        let plan = FaultPlan::exact(&[ev]);
        let profile = SimProfile::default();
        let mut c = plan.counters();
        assert_eq!(
            plan.decide(0, &profile, &mut c, &ev.ctx),
            Some(FaultAction::CorruptBit(77))
        );
        // same client, different seq: clean
        assert_eq!(
            plan.decide(0, &profile, &mut c, &ctx(2, 6, Dir::Down, FrameKind::Broadcast, 3)),
            None
        );
        assert!(ev.render().contains("CorruptBit(77)"));
        assert!(render_repro(9, &[ev]).contains("seed 9"));
    }
}
