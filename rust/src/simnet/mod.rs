//! Deterministic federation simulator.
//!
//! FoundationDB-style simulation testing for the SBC federation stack:
//! the real [`FederatedServer`](crate::transport::server::FederatedServer)
//! and real client sessions run on OS threads, but **all** time and all
//! nondeterminism — message delivery order, per-link delays, drops,
//! duplicates, corruption, connection kills, stragglers — derive from a
//! single seed on a virtual clock. Any failing run replays bit-for-bit
//! from `(seed, SimConfig)` alone.
//!
//! The pieces:
//!
//! - [`clock`] — the [`Clock`](clock::Clock) trait with a wall-clock
//!   impl for production ([`RealClock`](clock::RealClock)) and a
//!   quiescence-driven virtual impl ([`SimClock`](clock::SimClock))
//!   that advances only when every registered actor is parked, and
//!   panics on simulated deadlock instead of hanging.
//! - [`fault`] — the fault-schedule DSL: [`FaultPlan`](fault::FaultPlan)
//!   rules over per-frame predicates ([`When`](fault::When)), seeded
//!   background probabilities ([`SimProfile`](fault::SimProfile)), and
//!   replay-stable [`AppliedFault`](fault::AppliedFault) records.
//! - [`net`] — the simulated fabric: [`SimNet`](net::SimNet) implements
//!   the transport's `Acceptor`/`Connector`/`Transport` traits, carries
//!   frames as real wire bytes through the real codec, and delivers
//!   them FIFO per direction with [`Link`](crate::netsim::Link)-derived
//!   delays plus seeded jitter.
//! - [`harness`] — [`run_schedule`](harness::run_schedule) executes one
//!   full federated training under a schedule and
//!   [`check_run`](harness::check_run) classifies it against the serial
//!   trainer oracle: bit-identical completion, typed failure, or
//!   invariant [`Violation`](harness::Verdict::Violation).
//!   [`run_schedule_with_recovery`](harness::run_schedule_with_recovery)
//!   adds kill/restart supervision: scheduled `SIGKILL`-style crashes
//!   of the server or individual clients, each restarted to resume from
//!   its last durable [`persist`](crate::persist) barrier.
//! - [`shrink`] — [`ddmin`](shrink::ddmin) delta-debugging that reduces
//!   a failing fault schedule to a minimal exact plan and renders it as
//!   a copy-pastable test case.

pub mod clock;
pub mod fault;
pub mod harness;
pub mod net;
pub mod shrink;

pub use clock::{Clock, RealClock, SimClock};
pub use fault::{AppliedFault, Dir, FaultAction, FaultPlan, SimProfile, When};
pub use harness::{
    check_run, run_schedule, run_schedule_with_recovery, RecoverySchedule, SimConfig, SimRun,
    Verdict,
};
pub use net::SimNet;
pub use shrink::{ddmin, shrink_schedule, Shrunk};
