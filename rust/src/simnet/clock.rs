//! The clock abstraction that makes the federation stack simulatable:
//! every wall-clock wait in [`crate::transport`] (retry backoff, round
//! timeouts, blocking reads) goes through a [`Clock`], so the same
//! server/session code runs either against real time ([`RealClock`]) or
//! against a deterministic **virtual clock** ([`SimClock`]) owned by the
//! simulator.
//!
//! # The waiting protocol
//!
//! Blocking code never sleeps on a condition directly; it polls:
//!
//! ```text
//! loop {
//!     let e = clock.epoch();          // wake generation, read FIRST
//!     if condition_holds() { break }  // poll shared state
//!     if clock.now() >= deadline { /* timed out */ }
//!     clock.park(e, deadline - now);  // returns on wake_all() or deadline
//! }
//! ```
//!
//! Reading the epoch *before* polling closes the lost-wakeup race: a
//! state change + [`Clock::wake_all`] between the poll and the park bumps
//! the epoch, so the park returns immediately and the condition is
//! re-checked.
//!
//! # Virtual time
//!
//! [`SimClock`] runs real threads on fake time. Every simulated thread
//! registers as an **actor** ([`Clock::actor`]); computation takes zero
//! virtual time, and the clock only advances when *every* registered
//! actor is parked — at that quiescent point the clock jumps straight to
//! the earliest parked deadline and wakes everyone. Because nothing else
//! can move time forward, all virtual timestamps are a pure function of
//! the event graph and the seed, not of OS scheduling or host speed: the
//! property that makes failing schedules replayable from `(seed, config)`
//! alone.
//!
//! A quiescent state in which no actor holds a finite deadline is a
//! genuine distributed deadlock; [`SimClock`] panics with an actor dump
//! instead of hanging, which turns "the test hung" into an attributable
//! failure.

use std::collections::BTreeMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Sentinel deadline for "park until woken" (no timeout).
const FOREVER: u64 = u64::MAX;

/// Cap a single real-clock park slice; callers loop, so waking early is
/// only a spurious re-poll (and keeps `wait_timeout` far from overflow).
const REAL_PARK_CAP: Duration = Duration::from_secs(3600);

fn sat_add(now_ns: u64, d: Duration) -> u64 {
    now_ns.saturating_add(u64::try_from(d.as_nanos()).unwrap_or(FOREVER))
}

/// A source of time plus a park/wake rendezvous — see the module docs
/// for the polling protocol every user must follow.
pub trait Clock: Send + Sync {
    /// Monotonic time since this clock's epoch (process start for the
    /// real clock, simulation start for the virtual one).
    fn now(&self) -> Duration;

    /// Block the calling thread for `d` (of this clock's time).
    fn sleep(&self, d: Duration);

    /// Current wake generation. Read it *before* polling shared state,
    /// then pass it to [`Clock::park`].
    fn epoch(&self) -> u64;

    /// Park until [`Clock::wake_all`] bumps the epoch past `seen` or
    /// `timeout` elapses; returns `true` if the timeout elapsed. A
    /// `timeout` of [`Duration::MAX`] parks until woken.
    fn park(&self, seen: u64, timeout: Duration) -> bool;

    /// Wake every parked thread (call after any state change that could
    /// unblock a waiter).
    fn wake_all(&self);

    /// Register the calling context as a simulated actor for the guard's
    /// lifetime. A no-op on the real clock; on [`SimClock`] the virtual
    /// time cannot advance while any registered actor is runnable, so
    /// **every** thread participating in a simulation must hold a guard
    /// (create it *before* spawning the thread to avoid a registration
    /// race).
    fn actor(&self) -> ActorGuard;
}

// ---------------------------------------------------------------------
// Real clock
// ---------------------------------------------------------------------

/// Wall-clock [`Clock`]: `now` is process uptime, `sleep` is
/// [`std::thread::sleep`], park/wake is a plain condvar. Used by the TCP
/// and loopback federation paths.
pub struct RealClock {
    start: Instant,
    epoch: Mutex<u64>,
    cv: Condvar,
}

impl RealClock {
    /// A fresh wall clock (epoch = now).
    pub fn new() -> RealClock {
        RealClock { start: Instant::now(), epoch: Mutex::new(0), cv: Condvar::new() }
    }
}

impl Default for RealClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for RealClock {
    fn now(&self) -> Duration {
        self.start.elapsed()
    }

    fn sleep(&self, d: Duration) {
        std::thread::sleep(d);
    }

    fn epoch(&self) -> u64 {
        *self.epoch.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn park(&self, seen: u64, timeout: Duration) -> bool {
        let deadline = Instant::now().checked_add(timeout);
        let mut e = self.epoch.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            if *e != seen {
                return false;
            }
            let left = match deadline {
                // `None` (overflowed Instant) means effectively forever
                None => REAL_PARK_CAP,
                Some(d) => match d.checked_duration_since(Instant::now()) {
                    Some(left) if !left.is_zero() => left.min(REAL_PARK_CAP),
                    _ => return true,
                },
            };
            let (next, _timed_out) =
                self.cv.wait_timeout(e, left).unwrap_or_else(|p| p.into_inner());
            e = next;
        }
    }

    fn wake_all(&self) {
        *self.epoch.lock().unwrap_or_else(|e| e.into_inner()) += 1;
        self.cv.notify_all();
    }

    fn actor(&self) -> ActorGuard {
        ActorGuard { sim: None }
    }
}

// ---------------------------------------------------------------------
// Virtual clock
// ---------------------------------------------------------------------

struct SimState {
    /// Virtual nanoseconds since simulation start.
    now_ns: u64,
    /// Wake generation.
    epoch: u64,
    /// Registered actors (threads the quiescence rule waits for).
    actors: usize,
    /// Parked actors' deadlines, keyed by a unique park token.
    waiters: BTreeMap<u64, u64>,
    next_token: u64,
    /// Set when quiescence is reached with no finite deadline (a genuine
    /// distributed deadlock). Every parked thread observes it and panics
    /// on its *own* stack — the detector must not panic while holding the
    /// state lock, or the other parked threads would never wake and the
    /// "deadlock detected" path would itself hang the test binary.
    dead: bool,
}

struct SimInner {
    state: Mutex<SimState>,
    cv: Condvar,
}

/// Lock the sim state tolerating poison: once one thread panics (e.g. on
/// deadlock detection), the survivors must still be able to wake up and
/// report, not cascade into lost wakeups.
fn lock_sim(inner: &SimInner) -> std::sync::MutexGuard<'_, SimState> {
    inner.state.lock().unwrap_or_else(|p| p.into_inner())
}

impl SimInner {
    /// If every registered actor is parked, advance virtual time to the
    /// earliest parked deadline and wake everyone. Called with the state
    /// lock held, at every transition that could complete quiescence.
    /// Deliberately panic-free (it runs inside `ActorGuard::drop`, which
    /// may execute during an unwind).
    fn maybe_advance(&self, st: &mut SimState) {
        if st.dead || st.actors == 0 || st.waiters.len() < st.actors {
            return;
        }
        let min = st.waiters.values().copied().min().unwrap_or(FOREVER);
        if min == FOREVER {
            st.dead = true;
            st.epoch += 1;
            self.cv.notify_all();
            return;
        }
        if min > st.now_ns {
            st.now_ns = min;
        }
        st.epoch += 1;
        self.cv.notify_all();
    }
}

/// Deterministic virtual clock for simulation runs — see the module docs
/// for the advancement rule. Clones share one timeline.
#[derive(Clone)]
pub struct SimClock {
    inner: Arc<SimInner>,
}

impl SimClock {
    /// A virtual clock at t = 0 with no registered actors.
    pub fn new() -> SimClock {
        SimClock {
            inner: Arc::new(SimInner {
                state: Mutex::new(SimState {
                    now_ns: 0,
                    epoch: 0,
                    actors: 0,
                    waiters: BTreeMap::new(),
                    next_token: 0,
                    dead: false,
                }),
                cv: Condvar::new(),
            }),
        }
    }
}

impl Default for SimClock {
    fn default() -> Self {
        Self::new()
    }
}

impl SimClock {
    fn die_if_dead(st: &SimState) {
        if st.dead {
            panic!(
                "simulated deadlock: all {} actors are parked with no finite deadline \
                 at t={}ns — some wait is missing a timeout",
                st.actors, st.now_ns
            );
        }
    }
}

impl Clock for SimClock {
    fn now(&self) -> Duration {
        Duration::from_nanos(lock_sim(&self.inner).now_ns)
    }

    fn sleep(&self, d: Duration) {
        let deadline = sat_add(lock_sim(&self.inner).now_ns, d);
        loop {
            let st = lock_sim(&self.inner);
            if st.now_ns >= deadline {
                return;
            }
            let seen = st.epoch;
            let left = Duration::from_nanos(deadline - st.now_ns);
            drop(st);
            self.park(seen, left);
        }
    }

    fn epoch(&self) -> u64 {
        lock_sim(&self.inner).epoch
    }

    fn park(&self, seen: u64, timeout: Duration) -> bool {
        let mut st = lock_sim(&self.inner);
        Self::die_if_dead(&st);
        if st.epoch != seen {
            return false;
        }
        let deadline =
            if timeout == Duration::MAX { FOREVER } else { sat_add(st.now_ns, timeout) };
        let token = st.next_token;
        st.next_token += 1;
        st.waiters.insert(token, deadline);
        self.inner.maybe_advance(&mut st);
        while st.epoch == seen && st.now_ns < deadline {
            st = self.inner.cv.wait(st).unwrap_or_else(|p| p.into_inner());
        }
        let timed_out = st.now_ns >= deadline;
        st.waiters.remove(&token);
        Self::die_if_dead(&st);
        timed_out
    }

    fn wake_all(&self) {
        let mut st = lock_sim(&self.inner);
        st.epoch += 1;
        self.inner.cv.notify_all();
    }

    fn actor(&self) -> ActorGuard {
        let mut st = lock_sim(&self.inner);
        st.actors += 1;
        ActorGuard { sim: Some(self.inner.clone()) }
    }
}

/// Registration handle from [`Clock::actor`]; deregisters on drop (which
/// may itself complete quiescence and advance the virtual clock).
pub struct ActorGuard {
    sim: Option<Arc<SimInner>>,
}

impl Drop for ActorGuard {
    fn drop(&mut self) {
        if let Some(sim) = self.sim.take() {
            let mut st = lock_sim(&sim);
            st.actors -= 1;
            sim.maybe_advance(&mut st);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn real_clock_park_times_out() {
        let c = RealClock::new();
        let e = c.epoch();
        let t0 = Instant::now();
        assert!(c.park(e, Duration::from_millis(5)));
        assert!(t0.elapsed() >= Duration::from_millis(5));
    }

    #[test]
    fn real_clock_stale_epoch_returns_immediately() {
        let c = RealClock::new();
        let e = c.epoch();
        c.wake_all();
        let t0 = Instant::now();
        assert!(!c.park(e, Duration::from_secs(10)));
        assert!(t0.elapsed() < Duration::from_secs(1));
    }

    #[test]
    fn sim_single_actor_sleep_advances_instantly() {
        let c = SimClock::new();
        let _me = c.actor();
        c.sleep(Duration::from_secs(3600));
        assert_eq!(c.now(), Duration::from_secs(3600));
        c.sleep(Duration::from_millis(1));
        assert_eq!(c.now(), Duration::from_secs(3600) + Duration::from_millis(1));
    }

    #[test]
    fn sim_two_actors_wake_in_deadline_order() {
        // two sleepers with different deadlines: virtual time must visit
        // both deadlines in order, and the earlier sleeper wakes first
        let c = SimClock::new();
        let log = Arc::new(AtomicU64::new(0));
        let tokens: Vec<ActorGuard> = (0..2).map(|_| c.actor()).collect();
        let mut handles = Vec::new();
        for (i, tok) in tokens.into_iter().enumerate() {
            let c = c.clone();
            let log = log.clone();
            handles.push(std::thread::spawn(move || {
                let _tok = tok;
                let d = Duration::from_millis(if i == 0 { 10 } else { 25 });
                c.sleep(d);
                // record wake time in ms in decimal digit slots
                let slot = if i == 0 { 1 } else { 1000 };
                log.fetch_add(c.now().as_millis() as u64 * slot, Ordering::SeqCst);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(log.load(Ordering::SeqCst), 25_000 + 10);
    }

    #[test]
    #[should_panic(expected = "simulated deadlock")]
    fn sim_detects_deadlock() {
        let c = SimClock::new();
        let _me = c.actor();
        let e = c.epoch();
        c.park(e, Duration::MAX); // sole actor parks forever
    }
}
