//! The simulated network: an in-process [`Acceptor`]/[`Connector`]/
//! [`Transport`] implementation whose frames travel through virtual-time
//! delivery queues owned by a [`SimClock`], with every delay and fault
//! decided by the seeded fault layer ([`crate::simnet::fault`]).
//!
//! Fidelity choices:
//!
//! * frames are stored **serialized** (via the real [`write_frame`]) and
//!   re-parsed on receive (via the real [`read_frame`]), so an injected
//!   bit flip exercises the production CRC/validation path;
//! * each connection direction is FIFO (`deliver = max(previous
//!   delivery, send + delay)`), like a TCP stream — reordering happens
//!   across connections, not within one;
//! * base delay comes from the repo's [`Link`] models
//!   ([`Link::transfer_time`] over the actual wire bytes) plus a seeded
//!   jitter draw, so schedule exploration perturbs *timing*, not just
//!   failures.
//!
//! Every send is logged; [`SimNet::transcript`] renders the log sorted
//! by the replay-stable key `(t_send, client, attempt, dir, seq)`, so
//! two runs of the same `(seed, config)` produce byte-identical
//! transcripts regardless of OS thread scheduling.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::netsim::Link;
use crate::simnet::clock::{Clock, SimClock};
use crate::simnet::fault::{
    jitter_rng, AppliedFault, Dir, FaultAction, FaultPlan, FrameCtx, PlanCounters, SimProfile,
};
use crate::trace::{Event, Trace};
use crate::transport::frame::{read_frame, write_frame, FrameBuf};
use crate::transport::{Acceptor, Connector, Transport, TransportError};

/// Uniform jitter added to every delivery, drawn from the seeded jitter
/// stream: up to 200 µs, enough to vary cross-client arrival order
/// between seeds without drowning the [`Link`] base delays.
const JITTER_NS: u64 = 200_000;

/// One logged frame send.
#[derive(Clone, Copy, Debug)]
struct SimEvent {
    t_send_ns: u64,
    ctx: FrameCtx,
    wire_bytes: usize,
    /// Scheduled delivery (`None` for dropped/killed frames).
    deliver_ns: Option<u64>,
    /// The duplicate copy's delivery, when the fault was [`FaultAction::Duplicate`].
    deliver2_ns: Option<u64>,
    fault: Option<FaultAction>,
}

/// One direction of one simulated connection: serialized frames tagged
/// with their virtual delivery time. FIFO by construction.
struct Chan {
    state: Mutex<ChanState>,
}

struct ChanState {
    frames: VecDeque<(u64, Vec<u8>)>,
    closed: bool,
    last_deliver_ns: u64,
}

impl Chan {
    fn new() -> Arc<Chan> {
        Arc::new(Chan {
            state: Mutex::new(ChanState {
                frames: VecDeque::new(),
                closed: false,
                last_deliver_ns: 0,
            }),
        })
    }

    fn close(&self) {
        self.state.lock().unwrap_or_else(|p| p.into_inner()).closed = true;
    }
}

struct NetState {
    counters: PlanCounters,
    pending: VecDeque<Box<dyn Transport>>,
    closed: bool,
    events: Vec<SimEvent>,
    applied: Vec<AppliedFault>,
}

struct NetInner {
    clock: SimClock,
    seed: u64,
    profile: SimProfile,
    plan: FaultPlan,
    up_link: Link,
    down_link: Link,
    read_timeout: Duration,
    trace: Trace,
    state: Mutex<NetState>,
}

/// The simulated network fabric: hands out per-client [`Connector`]s and
/// acts as the server's [`Acceptor`]. Clones share one fabric.
#[derive(Clone)]
pub struct SimNet {
    inner: Arc<NetInner>,
}

impl SimNet {
    /// A fabric on `clock` where every fault/jitter decision derives from
    /// `seed`, `plan` and `profile` alone. `read_timeout` bounds every
    /// blocking [`Transport::recv`] in virtual time.
    pub fn new(
        clock: SimClock,
        seed: u64,
        plan: FaultPlan,
        profile: SimProfile,
        up_link: Link,
        down_link: Link,
        read_timeout: Duration,
    ) -> SimNet {
        let counters = plan.counters();
        SimNet {
            inner: Arc::new(NetInner {
                clock,
                seed,
                profile,
                plan,
                up_link,
                down_link,
                read_timeout,
                trace: Trace::disabled(),
                state: Mutex::new(NetState {
                    counters,
                    pending: VecDeque::new(),
                    closed: false,
                    events: Vec::new(),
                    applied: Vec::new(),
                }),
            }),
        }
    }

    /// Attach a structured-event sink: every fault-injection decision
    /// then emits an [`Event::Fault`] annotated with its replay-stable
    /// `(seed, client, attempt, seq, dir)` RNG key, timestamped on the
    /// fabric's virtual clock. Must be called before the fabric is
    /// cloned or shared.
    pub fn with_trace(mut self, trace: Trace) -> SimNet {
        Arc::get_mut(&mut self.inner).expect("with_trace before sharing the fabric").trace =
            trace;
        self
    }

    /// The connector for client `client` — each [`Connector::connect`] is
    /// a new connection attempt with its own fault/jitter RNG streams.
    pub fn connector(&self, client: u32) -> SimConnector {
        SimConnector { net: self.inner.clone(), client, attempts: AtomicU32::new(0) }
    }

    /// Every fault the fabric actually applied, sorted by the
    /// replay-stable frame key.
    pub fn applied_faults(&self) -> Vec<AppliedFault> {
        let st = self.inner.state.lock().unwrap();
        let mut faults = st.applied.clone();
        faults.sort_by_key(|f| f.ctx.key());
        faults
    }

    /// The full event log rendered deterministically: same `(seed,
    /// config)` ⇒ byte-identical transcript, independent of thread
    /// scheduling.
    pub fn transcript(&self) -> String {
        let st = self.inner.state.lock().unwrap();
        let mut events = st.events.clone();
        drop(st);
        events.sort_by_key(|e| (e.t_send_ns, e.ctx.key()));
        let mut out = String::new();
        for e in &events {
            let deliver = match e.deliver_ns {
                Some(d) => format!("{d}"),
                None => "lost".into(),
            };
            out.push_str(&format!(
                "t={} c{} a{} {} seq={} {:?} r{} {}B -> {}",
                e.t_send_ns,
                e.ctx.client,
                e.ctx.attempt,
                e.ctx.dir,
                e.ctx.seq,
                e.ctx.kind,
                e.ctx.round,
                e.wire_bytes,
                deliver
            ));
            if let Some(d2) = e.deliver2_ns {
                out.push_str(&format!(" +dup@{d2}"));
            }
            if let Some(fault) = e.fault {
                out.push_str(&format!(" [{fault}]"));
            }
            out.push('\n');
        }
        out
    }
}

impl Acceptor for SimNet {
    fn accept(&self) -> Result<Box<dyn Transport>, TransportError> {
        loop {
            let seen = self.inner.clock.epoch();
            {
                let mut st = self.inner.state.lock().unwrap();
                if let Some(conn) = st.pending.pop_front() {
                    return Ok(conn);
                }
                if st.closed {
                    return Err(TransportError::Closed);
                }
            }
            // no deadline: an idle listener must not drive virtual time
            self.inner.clock.park(seen, Duration::MAX);
        }
    }

    fn shutdown(&self) {
        self.inner.state.lock().unwrap().closed = true;
        self.inner.clock.wake_all();
    }
}

impl SimNet {
    /// Reverse a [`Acceptor::shutdown`]: clear the closed flag and
    /// discard connections left pending when the previous server
    /// generation died (their client ends observe EOF and reconnect).
    /// This is what lets a recovery supervisor restart a server on the
    /// *same* fabric — per-client attempt counters and the fault/jitter
    /// streams keyed on them carry across the restart, keeping fault
    /// decisions replay-stable through a kill.
    pub fn reopen(&self) {
        {
            let mut st = self.inner.state.lock().unwrap();
            st.closed = false;
            st.pending.clear();
        }
        self.inner.clock.wake_all();
    }
}

/// [`Connector`] for one simulated client (from [`SimNet::connector`]).
pub struct SimConnector {
    net: Arc<NetInner>,
    client: u32,
    attempts: AtomicU32,
}

impl Connector for SimConnector {
    fn connect(&self) -> Result<Box<dyn Transport>, TransportError> {
        let attempt = self.attempts.fetch_add(1, Ordering::SeqCst);
        let up = Chan::new(); // client -> server
        let down = Chan::new(); // server -> client
        {
            let mut st = self.net.state.lock().unwrap();
            if st.closed {
                return Err(TransportError::Closed);
            }
            st.pending.push_back(Box::new(SimConn {
                net: self.net.clone(),
                send_ch: down.clone(),
                recv_ch: up.clone(),
                client: self.client,
                attempt,
                dir: Dir::Down,
                send_seq: 0,
            }));
        }
        self.net.clock.wake_all();
        Ok(Box::new(SimConn {
            net: self.net.clone(),
            send_ch: up,
            recv_ch: down,
            client: self.client,
            attempt,
            dir: Dir::Up,
            send_seq: 0,
        }))
    }
}

/// One endpoint of a simulated connection.
struct SimConn {
    net: Arc<NetInner>,
    send_ch: Arc<Chan>,
    recv_ch: Arc<Chan>,
    client: u32,
    attempt: u32,
    /// Direction of frames *sent* from this end.
    dir: Dir,
    send_seq: u64,
}

impl SimConn {
    fn now_ns(&self) -> u64 {
        self.net.clock.now().as_nanos() as u64
    }

    /// Schedule `bytes` on `self.send_ch`, preserving per-direction FIFO.
    fn enqueue(&self, bytes: Vec<u8>, earliest_ns: u64) -> Result<u64, TransportError> {
        let mut cs = self.send_ch.state.lock().unwrap();
        if cs.closed {
            return Err(TransportError::Io(std::io::Error::new(
                std::io::ErrorKind::BrokenPipe,
                "simulated connection closed",
            )));
        }
        let deliver = earliest_ns.max(cs.last_deliver_ns);
        cs.last_deliver_ns = deliver;
        cs.frames.push_back((deliver, bytes));
        Ok(deliver)
    }
}

impl Transport for SimConn {
    fn send(&mut self, f: &FrameBuf) -> Result<(), TransportError> {
        let ctx = FrameCtx {
            client: self.client,
            attempt: self.attempt,
            seq: self.send_seq,
            dir: self.dir,
            kind: f.kind,
            round: f.round,
        };
        self.send_seq += 1;

        let mut bytes = Vec::new();
        write_frame(&mut bytes, f)?;
        let wire_bytes = bytes.len();

        let fault = {
            let mut st = self.net.state.lock().unwrap();
            let fault =
                self.net.plan.decide(self.net.seed, &self.net.profile, &mut st.counters, &ctx);
            if let Some(action) = fault {
                st.applied.push(AppliedFault { ctx, action });
            }
            fault
        };
        if let Some(action) = fault {
            let net = &*self.net;
            net.trace.emit(&net.clock, || Event::Fault {
                seed: net.seed,
                client: ctx.client,
                attempt: ctx.attempt,
                seq: ctx.seq,
                dir: ctx.dir.to_string(),
                action: action.to_string(),
            });
        }

        let link = match self.dir {
            Dir::Up => &self.net.up_link,
            Dir::Down => &self.net.down_link,
        };
        let base_ns = (link.transfer_time(wire_bytes as u64 * 8) * 1e9) as u64;
        let mut jr = jitter_rng(self.net.seed, &ctx.key());
        let jitter = jr.below(JITTER_NS as usize) as u64;
        let t_send = self.now_ns();
        let earliest = t_send + base_ns + jitter;

        let mut event = SimEvent {
            t_send_ns: t_send,
            ctx,
            wire_bytes,
            deliver_ns: None,
            deliver2_ns: None,
            fault,
        };
        let result = match fault {
            Some(FaultAction::Drop) => Ok(()),
            Some(FaultAction::KillConn) => {
                self.send_ch.close();
                self.recv_ch.close();
                Err(TransportError::Io(std::io::Error::new(
                    std::io::ErrorKind::ConnectionReset,
                    "injected fault: connection killed",
                )))
            }
            Some(FaultAction::CorruptBit(b)) => {
                let mut bad = bytes;
                let bit = b as usize % (bad.len() * 8);
                bad[bit / 8] ^= 1 << (bit % 8);
                event.deliver_ns = Some(self.enqueue(bad, earliest)?);
                Ok(())
            }
            Some(FaultAction::DelayMs(ms)) => {
                event.deliver_ns = Some(self.enqueue(bytes, earliest + ms * 1_000_000)?);
                Ok(())
            }
            Some(FaultAction::Duplicate) => {
                let copy = bytes.clone();
                let first = self.enqueue(bytes, earliest)?;
                let gap = jr.below(JITTER_NS as usize) as u64;
                event.deliver_ns = Some(first);
                event.deliver2_ns = Some(self.enqueue(copy, first + 1 + gap)?);
                Ok(())
            }
            None => {
                event.deliver_ns = Some(self.enqueue(bytes, earliest)?);
                Ok(())
            }
        };
        self.net.state.lock().unwrap().events.push(event);
        self.net.clock.wake_all();
        result
    }

    fn recv(&mut self, into: &mut FrameBuf) -> Result<(), TransportError> {
        let clock = &self.net.clock;
        let deadline = clock.now().checked_add(self.net.read_timeout).unwrap_or(Duration::MAX);
        loop {
            let seen = clock.epoch();
            let now = clock.now();
            let now_ns = now.as_nanos() as u64;
            // next instant worth re-polling at (delivery or timeout)
            let wait_until_ns;
            {
                let mut cs = self.recv_ch.state.lock().unwrap();
                match cs.frames.front() {
                    Some(&(deliver, _)) if deliver <= now_ns => {
                        let (_, bytes) = cs.frames.pop_front().expect("front exists");
                        drop(cs);
                        return read_frame(&mut &bytes[..], into);
                    }
                    Some(&(deliver, _)) => wait_until_ns = deliver,
                    None => {
                        if cs.closed {
                            return Err(TransportError::Io(std::io::Error::new(
                                std::io::ErrorKind::UnexpectedEof,
                                "simulated connection closed",
                            )));
                        }
                        wait_until_ns = u64::MAX;
                    }
                }
            }
            if now >= deadline {
                return Err(TransportError::Io(std::io::Error::new(
                    std::io::ErrorKind::TimedOut,
                    "simulated read timed out",
                )));
            }
            let until = Duration::from_nanos(wait_until_ns.saturating_sub(now_ns))
                .min(deadline - now);
            clock.park(seen, until);
        }
    }

    fn peer(&self) -> String {
        format!("sim:c{}:a{}:{}", self.client, self.attempt, self.dir)
    }
}

impl Drop for SimConn {
    fn drop(&mut self) {
        self.send_ch.close();
        self.recv_ch.close();
        self.net.clock.wake_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::fault::When;
    use crate::transport::frame::FrameKind;
    use crate::transport::TransportCfg;

    fn pairs(net: &SimNet) -> (Box<dyn Transport>, Box<dyn Transport>) {
        let connector = net.connector(0);
        let client = connector.connect().unwrap();
        let server = net.accept().unwrap();
        (client, server)
    }

    fn sim(plan: FaultPlan, profile: SimProfile) -> (SimClock, SimNet) {
        let clock = SimClock::new();
        let net = SimNet::new(
            clock.clone(),
            42,
            plan,
            profile,
            Link::wifi(),
            Link::wifi(),
            TransportCfg::default().read_timeout,
        );
        (clock, net)
    }

    fn update(round: u32, payload: &[u8]) -> FrameBuf {
        let mut f = FrameBuf::default();
        f.set(FrameKind::Update, round, 0, payload, payload.len() as u64 * 8);
        f
    }

    #[test]
    fn frames_survive_the_fabric_in_fifo_order() {
        let (clock, net) = sim(FaultPlan::new(), SimProfile::default());
        let _actor = clock.actor();
        let (mut client, mut server) = pairs(&net);
        client.send(&update(1, &[1])).unwrap();
        client.send(&update(2, &[2])).unwrap();
        let mut got = FrameBuf::default();
        server.recv(&mut got).unwrap();
        assert_eq!((got.round, &got.payload[..]), (1, &[1][..]));
        server.recv(&mut got).unwrap();
        assert_eq!((got.round, &got.payload[..]), (2, &[2][..]));
        assert!(clock.now() > Duration::ZERO, "delivery consumed virtual time");
    }

    #[test]
    fn corrupt_frames_hit_the_real_crc_check() {
        let plan = FaultPlan::new().rule(When::any(), FaultAction::CorruptBit(123));
        let (clock, net) = sim(plan, SimProfile::default());
        let _actor = clock.actor();
        let (mut client, mut server) = pairs(&net);
        client.send(&update(1, &[1, 2, 3, 4])).unwrap();
        let err = server.recv(&mut FrameBuf::default()).unwrap_err();
        assert!(err.is_retryable(), "corruption must be retryable, got {err}");
    }

    #[test]
    fn dropped_frames_time_out_and_kill_errors_the_sender() {
        let plan = FaultPlan::new()
            .rule(When::any().seq(0), FaultAction::Drop)
            .rule(When::any().seq(1), FaultAction::KillConn);
        let (clock, net) = sim(plan, SimProfile::default());
        let _actor = clock.actor();
        let (mut client, mut server) = pairs(&net);
        client.send(&update(1, &[9])).unwrap(); // dropped silently
        let err = server.recv(&mut FrameBuf::default()).unwrap_err();
        assert!(matches!(&err, TransportError::Io(e) if e.kind() == std::io::ErrorKind::TimedOut));
        let err = client.send(&update(2, &[9])).unwrap_err();
        assert!(err.is_retryable(), "{err}");
        // the kill closed both directions
        assert!(client.recv(&mut FrameBuf::default()).is_err());
        let faults = net.applied_faults();
        assert_eq!(faults.len(), 2);
    }

    #[test]
    fn duplicate_delivers_twice_and_transcript_is_stable() {
        let plan = FaultPlan::new()
            .rule(When::any().kind(FrameKind::Update).seq(0), FaultAction::Duplicate);
        let (clock, net) = sim(plan.clone(), SimProfile::default());
        let _actor = clock.actor();
        let (mut client, mut server) = pairs(&net);
        client.send(&update(3, &[7, 7])).unwrap();
        let mut got = FrameBuf::default();
        server.recv(&mut got).unwrap();
        assert_eq!(got.round, 3);
        server.recv(&mut got).unwrap();
        assert_eq!(got.round, 3, "duplicate copy delivered");
        let t1 = net.transcript();
        assert!(t1.contains("+dup@"), "{t1}");

        // identical run ⇒ byte-identical transcript
        let (clock2, net2) = sim(plan, SimProfile::default());
        let _actor2 = clock2.actor();
        let (mut client2, mut server2) = pairs(&net2);
        client2.send(&update(3, &[7, 7])).unwrap();
        server2.recv(&mut got).unwrap();
        server2.recv(&mut got).unwrap();
        assert_eq!(t1, net2.transcript());
    }
}
