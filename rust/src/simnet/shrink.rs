//! Failure shrinking: reduce a failing fault schedule to a minimal
//! reproducing subset.
//!
//! A failing simulated run yields the exact list of [`AppliedFault`]s
//! the fabric injected. [`ddmin`] bisects that list — delta debugging
//! with a final 1-minimality pass — against a caller-supplied
//! `still_fails` predicate, and [`shrink_schedule`] wires the predicate
//! to a real re-run: replay the same `(seed, TrainConfig)` with the
//! candidate subset pinned as an exact [`FaultPlan`] and the background
//! probabilities zeroed. Because jitter and fault decisions draw from
//! independently salted RNG streams, removing faults never perturbs the
//! timing of the frames that remain, so the subset either reproduces
//! the failure or genuinely wasn't needed.
//!
//! The result renders as a copy-pastable `FaultPlan` via
//! [`render_repro`](crate::simnet::fault::render_repro).

use crate::simnet::fault::{render_repro, AppliedFault, FaultPlan, SimProfile};

/// Delta-debugging minimisation (Zeller's ddmin) over a fault list.
///
/// `still_fails` must return `true` when re-running with exactly the
/// given subset of faults still reproduces the failure. The input list
/// itself is assumed to fail (callers should verify this first; see
/// [`shrink_schedule`]). Returns a subset that still fails and is
/// 1-minimal: removing any single remaining event makes the failure
/// disappear.
pub fn ddmin(
    events: &[AppliedFault],
    mut still_fails: impl FnMut(&[AppliedFault]) -> bool,
) -> Vec<AppliedFault> {
    let mut current: Vec<AppliedFault> = events.to_vec();
    let mut granularity = 2usize;

    while current.len() >= 2 {
        let chunk = current.len().div_ceil(granularity);
        let mut reduced = false;
        let mut start = 0;
        while start < current.len() {
            let end = (start + chunk).min(current.len());
            // Try the complement of current[start..end].
            let candidate: Vec<AppliedFault> = current[..start]
                .iter()
                .chain(&current[end..])
                .cloned()
                .collect();
            if !candidate.is_empty() && still_fails(&candidate) {
                current = candidate;
                granularity = granularity.saturating_sub(1).max(2);
                reduced = true;
                break;
            }
            start = end;
        }
        if !reduced {
            if granularity >= current.len() {
                break;
            }
            granularity = (granularity * 2).min(current.len());
        }
    }

    // 1-minimality pass: drop single events while any single drop still fails.
    let mut i = 0;
    while current.len() > 1 && i < current.len() {
        let mut candidate = current.clone();
        candidate.remove(i);
        if still_fails(&candidate) {
            current = candidate;
            i = 0;
        } else {
            i += 1;
        }
    }
    current
}

/// The outcome of shrinking one failing schedule.
#[derive(Debug)]
pub struct Shrunk {
    /// The minimal fault subset that still reproduces the failure.
    pub events: Vec<AppliedFault>,
    /// How many candidate re-runs the shrink consumed.
    pub runs: usize,
    /// A copy-pastable test-case snippet reproducing the failure.
    pub repro: String,
}

/// Shrink a failing schedule to a minimal exact fault plan.
///
/// `applied` is the fault list recorded by the failing run (from
/// [`SimRun::applied`](crate::simnet::harness::SimRun)); `fails` re-runs
/// the same `(seed, TrainConfig)` with the given *exact* plan —
/// explicit faults only, probabilities zeroed — and reports whether the
/// failure reproduces. Returns `Err` with a diagnostic if the full
/// exact replay does not reproduce the failure (a nondeterminism bug
/// worth knowing about), otherwise the minimal subset plus its rendered
/// repro snippet.
pub fn shrink_schedule(
    seed: u64,
    applied: &[AppliedFault],
    mut fails: impl FnMut(&FaultPlan) -> bool,
) -> Result<Shrunk, String> {
    let mut runs = 0usize;
    let mut fails_with = |events: &[AppliedFault]| {
        runs += 1;
        fails(&FaultPlan::exact(events))
    };

    if !fails_with(applied) {
        return Err(format!(
            "exact replay of all {} applied faults (profile zeroed) did not reproduce \
             the failure — the failure depends on something outside the fault schedule",
            applied.len()
        ));
    }
    let events = ddmin(applied, &mut fails_with);
    let repro = render_repro(seed, &events);
    Ok(Shrunk { events, runs, repro })
}

/// The zeroed profile shrinking replays under: all probabilistic faults
/// off, so only the exact plan injects anything.
pub fn zeroed_profile() -> SimProfile {
    SimProfile::default()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::fault::{Dir, FaultAction, FaultKey, FrameCtx};
    use crate::transport::frame::FrameKind;

    fn fake_event(seq: u64) -> AppliedFault {
        AppliedFault {
            ctx: FrameCtx {
                client: 0,
                attempt: 1,
                seq,
                dir: Dir::Up,
                kind: FrameKind::Update,
                round: 0,
            },
            action: FaultAction::Drop,
        }
    }

    fn has(events: &[AppliedFault], seq: u64) -> bool {
        events.iter().any(|e| e.ctx.seq == seq)
    }

    #[test]
    fn ddmin_finds_single_culprit() {
        let events: Vec<_> = (0..16).map(fake_event).collect();
        // Failure iff event seq=11 is present.
        let min = ddmin(&events, |c| has(c, 11));
        assert_eq!(min.len(), 1);
        assert_eq!(min[0].ctx.seq, 11);
    }

    #[test]
    fn ddmin_finds_conjunction() {
        let events: Vec<_> = (0..10).map(fake_event).collect();
        // Failure needs BOTH seq=2 and seq=7.
        let min = ddmin(&events, |c| has(c, 2) && has(c, 7));
        assert_eq!(min.len(), 2);
        assert!(has(&min, 2) && has(&min, 7));
    }

    #[test]
    fn shrink_schedule_reports_unreproducible() {
        let events: Vec<_> = (0..4).map(fake_event).collect();
        let err = shrink_schedule(7, &events, |_| false).unwrap_err();
        assert!(err.contains("did not reproduce"));
    }

    #[test]
    fn shrink_schedule_renders_repro() {
        let events: Vec<_> = (0..6).map(fake_event).collect();
        let shrunk = shrink_schedule(42, &events, |plan| {
            // Reproduce iff the plan would fire on the seq=3 frame.
            let mut counters = plan.counters();
            let ctx = fake_event(3).ctx;
            plan.decide(42, &zeroed_profile(), &mut counters, &ctx).is_some()
        })
        .unwrap();
        assert_eq!(shrunk.events.len(), 1);
        assert_eq!(shrunk.events[0].ctx.seq, 3);
        assert!(shrunk.repro.contains("seed 42"), "repro:\n{}", shrunk.repro);
        assert!(shrunk.repro.contains("FaultAction::Drop"), "repro:\n{}", shrunk.repro);
        assert!(shrunk.runs >= 2);
    }

    #[test]
    fn fault_key_orders_events() {
        let a = fake_event(1).ctx.key();
        let b = fake_event(2).ctx.key();
        assert!(a < b);
        assert_eq!(a, FaultKey { client: 0, attempt: 1, seq: 1, dir: Dir::Up });
    }
}
