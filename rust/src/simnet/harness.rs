//! The simulation harness: run one full federated training —
//! [`FederatedServer`] plus `cfg.clients` real client sessions — on a
//! [`SimClock`] over a [`SimNet`], entirely from `(seed, SimConfig)`,
//! and check the paper-level invariant against a serial-trainer oracle:
//!
//! > under **every** fault schedule the run either completes with weight
//! > digests bit-identical to the serial trainer and exact `CommStats`
//! > reconciliation, or fails with a typed [`TransportError`] — never a
//! > hang, panic, or silent divergence.
//!
//! Hangs are impossible by construction ([`SimClock`] panics on
//! quiescent deadlock instead of blocking); panics and divergence are
//! classified by [`check_run`] as [`Verdict::Violation`].

use std::sync::Arc;
use std::thread;
use std::time::Duration;

use crate::coordinator::trainer::{CheckpointCfg, TrainConfig, TrainResult};
use crate::coordinator::TrainBackend;
use crate::netsim::Link;
use crate::simnet::clock::{Clock, SimClock};
use crate::simnet::fault::{AppliedFault, FaultPlan, SimProfile};
use crate::simnet::net::SimNet;
use crate::transport::server::{FederatedResult, FederatedServer};
use crate::transport::session::{run_client_resumable, run_client_with_clock, ClientOutcome};
use crate::transport::{weight_digest, Acceptor, TransportError};

/// Everything one simulated schedule needs beyond the [`TrainConfig`]:
/// the seed owning all nondeterminism, the explicit fault plan, the
/// background fault profile, and the link models providing base delays.
#[derive(Clone)]
pub struct SimConfig {
    /// Master seed for every fault, jitter and scheduling decision.
    pub seed: u64,
    /// Explicit fault rules (first match wins; see [`FaultPlan`]).
    pub plan: FaultPlan,
    /// Background per-frame fault probabilities.
    pub profile: SimProfile,
    /// Client → server link model.
    pub up_link: Link,
    /// Server → client link model.
    pub down_link: Link,
}

impl SimConfig {
    /// A clean schedule on `seed`: no explicit faults, zero fault
    /// probabilities, WiFi links.
    pub fn new(seed: u64) -> SimConfig {
        SimConfig {
            seed,
            plan: FaultPlan::new(),
            profile: SimProfile::default(),
            up_link: Link::wifi(),
            down_link: Link::wifi(),
        }
    }
}

/// How one thread of a simulated run ended.
#[derive(Debug)]
pub enum SimEnd<T> {
    /// Completed normally.
    Ok(T),
    /// Failed with a typed transport error (acceptable under faults).
    Err(TransportError),
    /// Panicked — always an invariant violation.
    Panic(String),
}

impl<T> SimEnd<T> {
    fn from_join(r: thread::Result<Result<T, TransportError>>) -> SimEnd<T> {
        match r {
            Ok(Ok(v)) => SimEnd::Ok(v),
            Ok(Err(e)) => SimEnd::Err(e),
            Err(payload) => {
                let msg = payload
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| payload.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "<non-string panic payload>".into());
                SimEnd::Panic(msg)
            }
        }
    }

    /// The completed value, if any.
    pub fn ok(&self) -> Option<&T> {
        match self {
            SimEnd::Ok(v) => Some(v),
            _ => None,
        }
    }

    /// The typed error, if any.
    pub fn err(&self) -> Option<&TransportError> {
        match self {
            SimEnd::Err(e) => Some(e),
            _ => None,
        }
    }
}

/// Everything one simulated schedule produced.
#[derive(Debug)]
pub struct SimRun {
    /// How the server ended.
    pub server: SimEnd<FederatedResult>,
    /// How each client session ended (index = client id).
    pub clients: Vec<SimEnd<ClientOutcome>>,
    /// Deterministic event transcript (see [`SimNet::transcript`]).
    pub transcript: String,
    /// Every fault the fabric applied, in replay-stable order.
    pub applied: Vec<AppliedFault>,
    /// Virtual time the whole run consumed.
    pub virtual_time: Duration,
}

impl SimRun {
    /// Whether every side completed.
    pub fn completed(&self) -> bool {
        self.server.ok().is_some() && self.clients.iter().all(|c| c.ok().is_some())
    }

    /// The first failure (server first, then clients by id), if any.
    pub fn first_failure(&self) -> Option<String> {
        if let SimEnd::Err(e) = &self.server {
            return Some(format!("server: {e}"));
        }
        if let SimEnd::Panic(m) = &self.server {
            return Some(format!("server panicked: {m}"));
        }
        for (i, c) in self.clients.iter().enumerate() {
            match c {
                SimEnd::Err(e) => return Some(format!("client {i}: {e}")),
                SimEnd::Panic(m) => return Some(format!("client {i} panicked: {m}")),
                SimEnd::Ok(_) => {}
            }
        }
        None
    }
}

/// Run one complete federated training under the simulator. Every
/// nondeterministic decision — delivery timing, faults, crash points —
/// derives from `(sim.seed, sim.plan, sim.profile, cfg)`, so calling
/// this twice with equal inputs replays the identical schedule (equal
/// transcripts, equal outcomes).
pub fn run_schedule<B, F>(cfg: &TrainConfig, sim: &SimConfig, make_backend: F) -> SimRun
where
    B: TrainBackend,
    F: Fn(usize) -> B + Sync,
{
    let clock = SimClock::new();
    let net = SimNet::new(
        clock.clone(),
        sim.seed,
        sim.plan.clone(),
        sim.profile,
        sim.up_link,
        sim.down_link,
        cfg.transport.read_timeout,
    )
    .with_trace(cfg.trace.clone());

    let (layout, initial) = {
        let mut probe = make_backend(0);
        let init = probe.init_params(cfg.seed);
        (probe.layout().clone(), init)
    };
    let mut server = FederatedServer::new(cfg.clone(), layout, initial);

    let (server_end, client_ends) = thread::scope(|s| {
        let server_handle = {
            let acceptor: Arc<dyn Acceptor> = Arc::new(net.clone());
            let server_clock: Arc<dyn Clock> = Arc::new(clock.clone());
            let actor = clock.actor();
            let server = &mut server;
            s.spawn(move || {
                let _actor = actor;
                server.run_with_clock(acceptor, server_clock)
            })
        };
        let client_handles: Vec<_> = (0..cfg.clients)
            .map(|id| {
                let connector = net.connector(id as u32);
                let client_clock = clock.clone();
                let actor = clock.actor();
                let make_backend = &make_backend;
                s.spawn(move || {
                    let _actor = actor;
                    let mut backend = make_backend(id);
                    run_client_with_clock(cfg, id, &connector, &mut backend, &client_clock)
                })
            })
            .collect();
        let clients: Vec<_> =
            client_handles.into_iter().map(|h| SimEnd::from_join(h.join())).collect();
        (SimEnd::from_join(server_handle.join()), clients)
    });

    SimRun {
        server: server_end,
        clients: client_ends,
        transcript: net.transcript(),
        applied: net.applied_faults(),
        virtual_time: clock.now(),
    }
}

/// Virtual-round crash points for a recovery run: each entry kills its
/// victim (`SIGKILL` semantics — no snapshot, no goodbye) at the top of
/// that round, and the supervisor immediately restarts a fresh process
/// image that resumes from the last durable checkpoint barrier.
#[derive(Clone, Debug, Default)]
pub struct RecoverySchedule {
    /// Rounds at whose top the server is killed, in firing order.
    pub server_kills: Vec<u32>,
    /// `(client id, round)` kill points for client sessions; each
    /// client's rounds fire in the order listed.
    pub client_kills: Vec<(usize, u32)>,
}

impl RecoverySchedule {
    /// No kills — a recovery run that should behave exactly like
    /// [`run_schedule`] with checkpointing enabled.
    pub fn none() -> RecoverySchedule {
        RecoverySchedule::default()
    }
}

/// [`run_schedule`] with kill/restart supervision: the server and every
/// client run inside a supervisor loop that catches
/// [`TransportError::Killed`] at each scheduled crash point and restarts
/// the victim, which resumes from its newest snapshot in `dir`. Any
/// other outcome (success or a different typed error) ends that
/// participant as usual, so [`check_run`] applies unchanged — a
/// crashed-and-recovered run on a clean fabric must still verdict
/// [`Verdict::Completed`], bit-identical to the serial oracle.
///
/// Each client's [`crate::simnet::net::SimConnector`] is created once,
/// *outside* its restart loop: connection-attempt counters keep
/// increasing across generations, so fault-RNG keys never repeat and the
/// schedule stays replay-stable through kills.
pub fn run_schedule_with_recovery<B, F>(
    cfg: &TrainConfig,
    sim: &SimConfig,
    recovery: &RecoverySchedule,
    dir: &str,
    make_backend: F,
) -> SimRun
where
    B: TrainBackend,
    F: Fn(usize) -> B + Sync,
{
    // every generation resumes: an empty store falls through to a fresh
    // start, so the first generation needs no special casing. Barriers
    // must land every round or a kill could strand the server behind
    // clients it can no longer serve from the depth-1 reply cache.
    let mut cfg = cfg.clone();
    cfg.checkpoint =
        CheckpointCfg { dir: Some(dir.to_string()), every_rounds: 1, keep: 0, resume: true };
    let cfg = &cfg;

    let clock = SimClock::new();
    let net = SimNet::new(
        clock.clone(),
        sim.seed,
        sim.plan.clone(),
        sim.profile,
        sim.up_link,
        sim.down_link,
        cfg.transport.read_timeout,
    )
    .with_trace(cfg.trace.clone());

    let (layout, initial) = {
        let mut probe = make_backend(0);
        let init = probe.init_params(cfg.seed);
        (probe.layout().clone(), init)
    };

    let (server_end, client_ends) = thread::scope(|s| {
        let server_handle = {
            let acceptor: Arc<dyn Acceptor> = Arc::new(net.clone());
            let server_clock: Arc<dyn Clock> = Arc::new(clock.clone());
            let actor = clock.actor();
            let net = net.clone();
            let layout = layout.clone();
            let initial = initial.clone();
            let kills = recovery.server_kills.clone();
            s.spawn(move || {
                let _actor = actor;
                let mut kills = kills.into_iter();
                let mut next_kill = kills.next();
                loop {
                    let mut server =
                        FederatedServer::new(cfg.clone(), layout.clone(), initial.clone());
                    if let Some(k) = next_kill {
                        server.kill_at(k);
                    }
                    match server.run_with_clock(acceptor.clone(), server_clock.clone()) {
                        Err(TransportError::Killed(_)) => {
                            // the dead generation shut the acceptor on
                            // its way out; reopen the fabric so the
                            // restarted listener can admit reconnects
                            net.reopen();
                            next_kill = kills.next();
                        }
                        other => return other,
                    }
                }
            })
        };
        let client_handles: Vec<_> = (0..cfg.clients)
            .map(|id| {
                let connector = net.connector(id as u32);
                let client_clock = clock.clone();
                let actor = clock.actor();
                let make_backend = &make_backend;
                let kills: Vec<u32> = recovery
                    .client_kills
                    .iter()
                    .filter(|(c, _)| *c == id)
                    .map(|(_, r)| *r)
                    .collect();
                s.spawn(move || {
                    let _actor = actor;
                    let mut backend = make_backend(id);
                    let mut kills = kills.into_iter();
                    let mut next_kill = kills.next();
                    loop {
                        let r = run_client_resumable(
                            cfg,
                            id,
                            &connector,
                            &mut backend,
                            &client_clock,
                            next_kill,
                        );
                        match r {
                            Err(TransportError::Killed(_)) => next_kill = kills.next(),
                            other => return other,
                        }
                    }
                })
            })
            .collect();
        let clients: Vec<_> =
            client_handles.into_iter().map(|h| SimEnd::from_join(h.join())).collect();
        (SimEnd::from_join(server_handle.join()), clients)
    });

    SimRun {
        server: server_end,
        clients: client_ends,
        transcript: net.transcript(),
        applied: net.applied_faults(),
        virtual_time: clock.now(),
    }
}

/// The invariant checker's classification of one schedule.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Verdict {
    /// Completed bit-identical to the serial trainer with exact
    /// communication accounting.
    Completed,
    /// Failed, but with typed errors only — acceptable under faults.
    TypedFailure(String),
    /// Invariant violation: a panic, a digest divergence, or an
    /// accounting mismatch on a completed run.
    Violation(String),
}

/// Check one schedule against the serial-trainer oracle (the
/// `Trainer::run` result for the same [`TrainConfig`]).
pub fn check_run(serial: &TrainResult, run: &SimRun) -> Verdict {
    let want = weight_digest(&serial.final_params);

    if let SimEnd::Panic(m) = &run.server {
        return Verdict::Violation(format!("server panicked: {m}"));
    }
    for (i, c) in run.clients.iter().enumerate() {
        if let SimEnd::Panic(m) = c {
            return Verdict::Violation(format!("client {i} panicked: {m}"));
        }
    }

    if let Some(res) = run.server.ok() {
        if res.digest != want {
            return Verdict::Violation(format!(
                "server completed with digest {:016x}, serial trainer has {want:016x}",
                res.digest
            ));
        }
        if let Some(m) = accounting_mismatch(serial, res) {
            return Verdict::Violation(m);
        }
    }
    for (i, c) in run.clients.iter().enumerate() {
        if let Some(out) = c.ok() {
            if out.digest != want || out.server_digest != want {
                return Verdict::Violation(format!(
                    "client {i} completed with digest {:016x}/{:016x}, serial has {want:016x}",
                    out.digest, out.server_digest
                ));
            }
        }
        if let Some(e) = c.err() {
            if e.to_string().contains("diverged") {
                return Verdict::Violation(format!("client {i}: {e}"));
            }
        }
    }

    match run.first_failure() {
        None => Verdict::Completed,
        Some(m) => Verdict::TypedFailure(m),
    }
}

/// Field-for-field `CommStats` + `NetSim` comparison between the serial
/// trainer and a completed federated run — faults, retries and
/// duplicates must leave the accounting *exactly* unchanged, because the
/// server accounts each client's update once per round regardless of how
/// many times the bytes crossed the fabric.
fn accounting_mismatch(serial: &TrainResult, fed: &FederatedResult) -> Option<String> {
    macro_rules! want_eq {
        ($a:expr, $b:expr, $what:literal) => {
            if $a != $b {
                return Some(format!(
                    "accounting mismatch in {}: federated {:?}, serial {:?}",
                    $what, $a, $b
                ));
            }
        };
    }
    want_eq!(fed.comm.upstream_bits, serial.comm.upstream_bits, "comm.upstream_bits");
    want_eq!(fed.comm.messages, serial.comm.messages, "comm.messages");
    want_eq!(fed.comm.nonzeros, serial.comm.nonzeros, "comm.nonzeros");
    want_eq!(fed.comm.baseline_bits, serial.comm.baseline_bits, "comm.baseline_bits");
    want_eq!(
        fed.comm.frame_overhead_bits,
        serial.comm.frame_overhead_bits,
        "comm.frame_overhead_bits"
    );
    want_eq!(fed.net.total_up_bits(), serial.net.total_up_bits(), "net.total_up_bits");
    want_eq!(fed.net.clients.len(), serial.net.clients.len(), "net.clients.len");
    for (i, (fc, sc)) in fed.net.clients.iter().zip(&serial.net.clients).enumerate() {
        if (fc.up_bits, fc.down_bits, fc.messages) != (sc.up_bits, sc.down_bits, sc.messages) {
            return Some(format!(
                "accounting mismatch in net.clients[{i}]: federated {:?}, serial {:?}",
                (fc.up_bits, fc.down_bits, fc.messages),
                (sc.up_bits, sc.down_bits, sc.messages)
            ));
        }
    }
    want_eq!(
        fed.net.total_comm_time_s.to_bits(),
        serial.net.total_comm_time_s.to_bits(),
        "net.total_comm_time_s"
    );
    None
}
