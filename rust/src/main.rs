//! `sbc-train` — the launcher for distributed-training experiments.
//!
//! Subcommands:
//!   train    run one distributed training (native or PJRT backend)
//!   table1   print the theoretical compression-rate table (paper Table I)
//!   inspect  summarize the AOT artifact manifest
//!   golomb   print eq.-5 position-bit costs for a sparsity sweep
//!
//! Examples:
//!   sbc-train train --model lenet --method sbc2 --iterations 400 --verbose
//!   sbc-train train --backend native --method sbc3 --iterations 2000
//!   sbc-train train --config configs/lenet_sbc2.toml

use anyhow::{anyhow, bail, Result};

use sbc::codec::accounting::table1_rows;
use sbc::codec::golomb;
use sbc::config::{self, presets};
use sbc::coordinator::trainer::{TrainConfig, Trainer};
use sbc::metrics::render_table;
use sbc::model::manifest::Manifest;
use sbc::runtime::PjrtBackend;
use sbc::sgd::NativeMlpBackend;
use sbc::transport::server::FederatedServer;
use sbc::transport::session::run_client;
use sbc::transport::tcp::{TcpAcceptor, TcpConnector};
use sbc::util::timer::TIMERS;

/// Minimal flag parser: --key value / --flag.
struct Args {
    cmd: String,
    kv: std::collections::BTreeMap<String, String>,
}

impl Args {
    fn parse() -> Args {
        let mut it = std::env::args().skip(1);
        let cmd = it.next().unwrap_or_else(|| "help".into());
        let mut kv = std::collections::BTreeMap::new();
        let rest: Vec<String> = it.collect();
        let mut i = 0;
        while i < rest.len() {
            let k = rest[i].trim_start_matches("--").to_string();
            if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                kv.insert(k, rest[i + 1].clone());
                i += 2;
            } else {
                kv.insert(k, "true".into());
                i += 1;
            }
        }
        Args { cmd, kv }
    }

    fn get(&self, k: &str) -> Option<&str> {
        self.kv.get(k).map(|s| s.as_str())
    }

    fn get_or(&self, k: &str, d: &str) -> String {
        self.get(k).unwrap_or(d).to_string()
    }

    fn flag(&self, k: &str) -> bool {
        self.get(k) == Some("true")
    }
}

fn main() {
    let args = Args::parse();
    let result = match args.cmd.as_str() {
        "train" => cmd_train(&args),
        "table1" => cmd_table1(),
        "inspect" => cmd_inspect(&args),
        "golomb" => cmd_golomb(),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => Err(anyhow!("unknown command '{other}' (try: sbc-train help)")),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "sbc-train — Sparse Binary Compression distributed training\n\
         \n\
         USAGE: sbc-train <command> [--flags]\n\
         \n\
         COMMANDS:\n\
           train    --model <m> --method <name> [--iterations N] [--backend pjrt|native]\n\
                    [--config file.toml] [--seed N] [--p F] [--delay N] [--verbose]\n\
                    [--csv results/run.csv] [--pjrt-compress] [--parallelism N]\n\
                    (--parallelism N pools the round loop over N threads;\n\
                     results are bit-identical at any N)\n\
                    [--listen ADDR]                serve federated rounds over TCP\n\
                    [--connect ADDR --client-id K] join as federated client K\n\
                    (federated runs use the native backend and produce\n\
                     bit-identical weights to the in-process trainer)\n\
                    [--trace out.jsonl]            write structured events (JSONL) and\n\
                    print a per-stage latency profile; SBC_TRACE=jsonl\n\
                    or a [trace] TOML section work too\n\
                    [--simulate] [--schedules N] [--sim-profile none|light|harsh|mixed]\n\
                    sweep N seeded fault schedules of the federation\n\
                    protocol on a virtual clock (deterministic: any\n\
                    failure replays from --seed alone); exits nonzero\n\
                    on invariant violations\n\
                    [--checkpoint-dir DIR] [--checkpoint-every N] [--checkpoint-keep K]\n\
                    write a durable snapshot every N rounds (keep K\n\
                    newest generations, 0 = all); [--resume] restarts\n\
                    from the newest snapshot, bit-identical to a run\n\
                    that was never interrupted\n\
           table1   print theoretical compression rates (paper Table I)\n\
           inspect  [--artifacts DIR] summarize the AOT manifest\n\
           golomb   print eq.-5 optimal position-bit table\n\
         \n\
         METHODS: baseline fedavg gd sbc sbc1 sbc2 sbc3 signsgd terngrad qsgd onebit"
    );
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg: TrainConfig = if let Some(path) = args.get("config") {
        config::load_train_config(path)?
    } else {
        let model = args.get_or("model", "lenet");
        let method = config::parse_method(
            &args.get_or("method", "sbc2"),
            args.get_or("p", "0.01").parse()?,
            args.get_or("delay", "100").parse()?,
        )?;
        presets::preset(&model, method)
    };
    if let Some(it) = args.get("iterations") {
        cfg.iterations = it.parse()?;
        cfg.lr = presets::lr_schedule(&cfg.model, cfg.iterations);
        cfg.eval_every_rounds = (cfg.iterations / cfg.method.delay / 20).max(1);
    }
    if let Some(seed) = args.get("seed") {
        cfg.seed = seed.parse()?;
    }
    if let Some(par) = args.get("parallelism") {
        cfg.parallelism = par.parse::<usize>()?.max(1);
    }
    if args.flag("verbose") {
        cfg.verbose = true;
    }
    if args.flag("pjrt-compress") {
        cfg.use_pjrt_compress = true;
    }
    // structured-event tracing: `[trace] path` from the TOML (if any),
    // then the --trace flag overrides; both beat the SBC_TRACE env var
    // already resolved by TrainConfig::new / the config loader
    if let Some(path) = args.get("config") {
        if let Some(p) = config::load_trace_settings(path)?.path {
            cfg.trace = sbc::trace::Trace::jsonl(std::path::Path::new(&p))?;
            println!("# tracing events to {p}");
        }
    }
    if let Some(p) = args.get("trace") {
        cfg.trace = sbc::trace::Trace::jsonl(std::path::Path::new(p))?;
        println!("# tracing events to {p}");
    }
    // durable checkpoints: `[checkpoint]` TOML keys come in via the
    // config loader; CLI flags override. --resume additionally asks the
    // run (trainer, server or client) to restart from the newest
    // snapshot generation instead of from scratch.
    if let Some(d) = args.get("checkpoint-dir") {
        cfg.checkpoint.dir = Some(d.to_string());
    }
    if let Some(n) = args.get("checkpoint-every") {
        cfg.checkpoint.every_rounds = n.parse::<usize>()?.max(1);
    }
    if let Some(k) = args.get("checkpoint-keep") {
        cfg.checkpoint.keep = k.parse()?;
    }
    if args.flag("resume") {
        if cfg.checkpoint.dir.is_none() {
            bail!("--resume requires --checkpoint-dir (or a [checkpoint] dir in the TOML)");
        }
        cfg.checkpoint.resume = true;
    }

    // deterministic simulation: the full federation protocol on a
    // virtual clock under seeded fault schedules (ARCHITECTURE.md §6)
    if args.flag("simulate") {
        return cmd_simulate(cfg, args);
    }

    // federated paths: real sockets, native backend (see README
    // §Federated training for the per-process quickstart)
    if let Some(addr) = args.get("listen") {
        return cmd_serve(cfg, addr);
    }
    if let Some(addr) = args.get("connect") {
        let id: usize = args
            .get("client-id")
            .ok_or_else(|| anyhow!("--connect requires --client-id <0..clients>"))?
            .parse()?;
        return cmd_client(cfg, addr, id);
    }

    let backend_kind = args.get_or("backend", "pjrt");
    let result = match backend_kind.as_str() {
        "native" => {
            let mut be = NativeMlpBackend::mnist_mlp(cfg.clients, cfg.seed);
            cfg.model = "mlp-native".into();
            let mut trainer = Trainer::new(&mut be, cfg.clone());
            if cfg.checkpoint.resume {
                trainer.resume().map_err(|e| anyhow!("resume failed: {e}"))?
            } else {
                trainer.run()
            }
        }
        "pjrt" => {
            let manifest = Manifest::load(&args.get_or("artifacts", "artifacts"))?;
            let mut be = PjrtBackend::load(&manifest, &cfg.model, cfg.clients, cfg.seed)?;
            println!("# platform: {}  model: {} ({} params)", be.platform(), cfg.model, be.spec.n_params);
            let mut trainer = Trainer::new(&mut be, cfg.clone());
            if cfg.checkpoint.resume {
                trainer.resume().map_err(|e| anyhow!("resume failed: {e}"))?
            } else {
                trainer.run()
            }
        }
        other => bail!("unknown backend '{other}'"),
    };

    println!(
        "# {} on {}: final metric {:.4}, compression x{:.0}, upstream {:.3} MB/client \
         (+{:.4} MB framing total), comm time {:.2}s",
        cfg.method.label(),
        cfg.model,
        result.log.final_metric,
        result.log.compression,
        result.comm.upstream_bits as f64 / 8e6 / cfg.clients as f64,
        result.comm.frame_overhead_bits as f64 / 8e6,
        result.net.total_comm_time_s,
    );
    if let Some(profile) = &result.stage_profile {
        println!("{}", profile.render_table());
    }
    if let Some(csv) = args.get("csv") {
        result.log.append_csv(csv)?;
        println!("# appended curve to {csv}");
    }
    if args.flag("timers") {
        eprint!("{}", TIMERS.report());
    }
    Ok(())
}

/// `train --simulate`: sweep seeded fault schedules of the full
/// federation protocol — real server, real client sessions — on a
/// virtual clock, checking every schedule against the in-process serial
/// trainer. Exits nonzero on any invariant violation (a panic, weight
/// divergence, or accounting drift).
fn cmd_simulate(mut cfg: TrainConfig, args: &Args) -> Result<()> {
    use sbc::simnet::fault::render_repro;
    use sbc::simnet::{check_run, run_schedule, SimConfig, SimProfile, Verdict};

    fn profile_for(name: &str, i: u64) -> Result<SimProfile> {
        Ok(match name {
            "none" | "clean" => SimProfile::default(),
            "light" => SimProfile::light(),
            "harsh" => SimProfile::harsh(),
            "mixed" => {
                if i % 2 == 0 {
                    SimProfile::light()
                } else {
                    SimProfile::harsh()
                }
            }
            other => bail!("unknown sim profile '{other}' (none|light|harsh|mixed)"),
        })
    }

    let mut sim = if let Some(path) = args.get("config") {
        config::load_sim_settings(path)?
    } else {
        config::SimSettings::default()
    };
    if let Some(n) = args.get("schedules") {
        sim.schedules = n.parse::<u64>()?.max(1);
    }
    if let Some(p) = args.get("sim-profile") {
        sim.profile = p.to_string();
    }
    if let Some(seed) = args.get("seed") {
        sim.seed = seed.parse()?;
    }
    profile_for(&sim.profile, 0)?; // validate the name up front

    cfg.model = "mlp-native".into();
    println!(
        "# [{}] simulating {} schedule(s) from seed {} ({} profile), {} clients",
        cfg.method.label(),
        sim.schedules,
        sim.seed,
        sim.profile,
        cfg.clients,
    );
    let serial = {
        let mut be = NativeMlpBackend::mnist_mlp(cfg.clients, cfg.seed);
        Trainer::new(&mut be, cfg.clone()).run()
    };

    let (mut completed, mut failed, mut violations) = (0u64, 0u64, 0u64);
    for i in 0..sim.schedules {
        let seed = sim.seed.wrapping_add(i);
        let mut sc = SimConfig::new(seed);
        sc.profile = profile_for(&sim.profile, i)?;
        let run = run_schedule(&cfg, &sc, |_| NativeMlpBackend::mnist_mlp(cfg.clients, cfg.seed));
        match check_run(&serial, &run) {
            Verdict::Completed => {
                completed += 1;
                println!(
                    "# seed {seed}: completed bit-identical ({} faults, {:?} virtual)",
                    run.applied.len(),
                    run.virtual_time,
                );
            }
            Verdict::TypedFailure(m) => {
                failed += 1;
                println!("# seed {seed}: typed failure ({} faults): {m}", run.applied.len());
            }
            Verdict::Violation(m) => {
                violations += 1;
                eprintln!(
                    "seed {seed}: INVARIANT VIOLATION: {m}\n{}",
                    render_repro(seed, &run.applied),
                );
            }
        }
    }
    println!(
        "# sweep done: {completed} completed, {failed} typed failures, {violations} violations"
    );
    if violations > 0 {
        bail!(
            "{violations} invariant violation(s) — replay any seed with \
             --simulate --seed <s> --schedules 1"
        );
    }
    Ok(())
}

/// `train --listen ADDR`: run the federation server over TCP with the
/// native backend, blocking until all `cfg.clients` sessions complete.
fn cmd_serve(mut cfg: TrainConfig, addr: &str) -> Result<()> {
    use sbc::coordinator::TrainBackend;
    let mut be = NativeMlpBackend::mnist_mlp(cfg.clients, cfg.seed);
    cfg.model = "mlp-native".into();
    let layout = be.layout().clone();
    let initial = be.init_params(cfg.seed);
    let acceptor = std::sync::Arc::new(TcpAcceptor::bind(addr, &cfg.transport)?);
    println!(
        "# [{}] listening on {} for {} clients, {} rounds",
        cfg.method.label(),
        acceptor.local_addr(),
        cfg.clients,
        (cfg.iterations / cfg.method.delay).max(1),
    );
    let mut server = FederatedServer::new(cfg.clone(), layout, initial);
    let res = server.run(acceptor)?;
    println!(
        "# federated {} done: digest {:016x}, {} rounds, compression x{:.0}, \
         wire {:.3} MB up ({:.4} MB framing), comm time {:.2}s",
        cfg.method.label(),
        res.digest,
        res.rounds,
        res.comm.compression_rate(),
        res.comm.upstream_bits as f64 / 8e6,
        res.comm.frame_overhead_bits as f64 / 8e6,
        res.net.total_comm_time_s,
    );
    Ok(())
}

/// `train --connect ADDR --client-id K`: run one federated client session
/// over TCP with the native backend.
fn cmd_client(mut cfg: TrainConfig, addr: &str, id: usize) -> Result<()> {
    use std::net::ToSocketAddrs;
    let addr = addr
        .to_socket_addrs()?
        .next()
        .ok_or_else(|| anyhow!("'{addr}' resolves to no address"))?;
    let mut be = NativeMlpBackend::mnist_mlp(cfg.clients, cfg.seed);
    cfg.model = "mlp-native".into();
    let connector = TcpConnector::new(addr, &cfg.transport);
    let out = run_client(&cfg, id, &connector, &mut be)?;
    println!(
        "# client {id} done: digest {:016x} (server agrees), {:.3} MB payload up, {} reconnects",
        out.digest,
        out.up_bits as f64 / 8e6,
        out.retries,
    );
    Ok(())
}

fn cmd_table1() -> Result<()> {
    let rows: Vec<Vec<String>> = table1_rows()
        .iter()
        .map(|r| {
            vec![
                r.name.to_string(),
                format!("{:.4}", r.temporal),
                format!("{:.4}", r.gradient_sparsity),
                format!("{:.1}", r.value_bits),
                format!("{:.1}", r.position_bits),
                format!("x{:.0}", r.compression_rate()),
            ]
        })
        .collect();
    println!(
        "{}",
        render_table(
            &["method", "temporal", "grad sparsity", "value bits", "pos bits", "compression"],
            &rows
        )
    );
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let manifest = Manifest::load(&args.get_or("artifacts", "artifacts"))?;
    let rows: Vec<Vec<String>> = manifest
        .models
        .values()
        .map(|m| {
            vec![
                m.name.clone(),
                format!("{}", m.n_params),
                format!("{}", m.opt_size),
                m.optimizer.clone(),
                format!("{:?}", m.x_shape),
                format!("{}", m.layout.len()),
                format!("{}", m.graphs.len()),
            ]
        })
        .collect();
    println!(
        "{}",
        render_table(&["model", "params", "opt", "optimizer", "x shape", "tensors", "graphs"], &rows)
    );
    Ok(())
}

fn cmd_golomb() -> Result<()> {
    let rows: Vec<Vec<String>> = [0.0001, 0.0005, 0.001, 0.005, 0.01, 0.05, 0.1]
        .iter()
        .map(|&p| {
            vec![
                format!("{p}"),
                format!("{}", golomb::optimal_b(p)),
                format!("{:.2}", golomb::expected_bits_per_position(p)),
                format!("x{:.2}", 16.0 / golomb::expected_bits_per_position(p)),
            ]
        })
        .collect();
    println!("{}", render_table(&["p", "b*", "bits/pos (eq.5)", "vs fixed-16"], &rows));
    Ok(())
}
