//! Integration tests for the structured-event trace subsystem
//! (`rust/src/trace`): tracing must be provably inert (bit-identical
//! digests with tracing on or off, at any parallelism), its Frame events
//! must reconcile field-for-field with `CommStats`/`NetSim` accounting,
//! the JSONL file format must round-trip, and under the deterministic
//! simulator every applied fault must surface as a `Fault` event
//! annotated with its replay-stable `(seed, client, attempt, seq, dir)`
//! RNG key.

use std::time::Duration;

use sbc::codec::accounting::CommStats;
use sbc::compression::registry::MethodConfig;
use sbc::coordinator::trainer::{TrainConfig, TrainResult, Trainer};
use sbc::coordinator::schedule::LrSchedule;
use sbc::netsim::NetSim;
use sbc::sgd::NativeMlpBackend;
use sbc::simnet::{run_schedule, SimConfig, SimProfile};
use sbc::trace::{Event, Trace};

fn backend() -> NativeMlpBackend {
    NativeMlpBackend::digits_small(4, 1)
}

/// A small training config with tracing explicitly disabled (so an
/// ambient `SBC_TRACE` sweep cannot leak into these tests' sinks).
fn train_cfg(iterations: usize) -> TrainConfig {
    let mut cfg = TrainConfig::new(
        "mlp-small",
        MethodConfig::sbc2(),
        iterations,
        LrSchedule::constant(0.1),
    );
    cfg.eval_every_rounds = 5;
    cfg.eval_batches = 2;
    cfg.parallelism = 1;
    cfg.trace = Trace::disabled();
    cfg
}

fn run(cfg: &TrainConfig) -> TrainResult {
    let mut be = backend();
    Trainer::new(&mut be, cfg.clone()).run()
}

/// The reconciliation identity pinned by ISSUE acceptance: summing the
/// server-role Frame events reproduces `CommStats` (payload and framing
/// bits) and every client's `NetSim` link totals exactly.
fn check_frame_reconciliation(
    events: &[Event],
    comm: &CommStats,
    net: &NetSim,
    nclients: usize,
) {
    let mut up_payload = 0u64;
    let mut overhead = 0u64;
    let mut per_client_up = vec![0u64; nclients];
    let mut per_client_down = vec![0u64; nclients];
    for e in events {
        if let Event::Frame { role, dir, client, payload_bits, overhead_bits, .. } = e {
            if role != "server" {
                continue;
            }
            match dir.as_str() {
                "up" => {
                    up_payload += payload_bits;
                    overhead += overhead_bits;
                    per_client_up[*client as usize] += payload_bits + overhead_bits;
                }
                "down" => {
                    overhead += overhead_bits;
                    per_client_down[*client as usize] += payload_bits + overhead_bits;
                }
                other => panic!("unexpected frame dir {other:?}"),
            }
        }
    }
    assert_eq!(up_payload, comm.upstream_bits, "up-frame payload sum vs CommStats");
    assert_eq!(overhead, comm.frame_overhead_bits, "frame overhead sum vs CommStats");
    assert_eq!(net.clients.len(), nclients);
    for (i, c) in net.clients.iter().enumerate() {
        assert_eq!(per_client_up[i], c.up_bits, "client {i} uplink vs NetSim");
        assert_eq!(per_client_down[i], c.down_bits, "client {i} downlink vs NetSim");
    }
}

/// The determinism invariant: a traced run (RingRecorder) produces
/// bit-identical weights and accounting to an untraced run, under both
/// the serial and the pooled round loop — and only the traced run
/// carries a stage profile covering every hot-path stage.
#[test]
fn tracing_never_changes_results() {
    for par in [1usize, 8] {
        let mut plain_cfg = train_cfg(30);
        plain_cfg.parallelism = par;
        let plain = run(&plain_cfg);
        assert!(plain.stage_profile.is_none(), "untraced run must not profile");

        let (trace, ring) = Trace::ring(1_000_000);
        let mut traced_cfg = plain_cfg.clone();
        traced_cfg.trace = trace;
        let traced = run(&traced_cfg);

        let a: Vec<u32> = plain.final_params.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = traced.final_params.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b, "weights must be bit-identical (parallelism={par})");
        assert_eq!(plain.comm.upstream_bits, traced.comm.upstream_bits);
        assert_eq!(plain.comm.frame_overhead_bits, traced.comm.frame_overhead_bits);
        assert_eq!(plain.net.total_up_bits(), traced.net.total_up_bits());

        assert!(!ring.is_empty(), "traced run must record events");
        let profile = traced.stage_profile.expect("traced run must profile");
        assert!(profile.rounds > 0);
        let names: Vec<&str> = profile.stages.iter().map(|s| s.stage.as_str()).collect();
        for want in [
            "local_steps",
            "compress",
            "select",
            "quantize",
            "encode",
            "decode",
            "densify",
            "aggregate",
            "encode_down",
            "evaluate",
        ] {
            assert!(names.contains(&want), "missing stage {want} in {names:?}");
        }
        assert!(profile.render_table().contains("ms/round"));
    }
}

/// Trainer-emitted Frame events reconcile with `CommStats`/`NetSim`, and
/// the round structure is well-formed (one RoundStart/RoundEnd pair per
/// round, evals present).
#[test]
fn trainer_trace_reconciles_with_accounting() {
    let (trace, ring) = Trace::ring(1_000_000);
    let mut cfg = train_cfg(30);
    cfg.trace = trace;
    let r = run(&cfg);

    let events: Vec<Event> = ring.events().into_iter().map(|(_, e)| e).collect();
    let starts = events.iter().filter(|e| matches!(e, Event::RoundStart { .. })).count();
    let ends = events.iter().filter(|e| matches!(e, Event::RoundEnd { .. })).count();
    assert!(starts > 0 && starts == ends, "round events: {starts} starts, {ends} ends");
    assert!(events.iter().any(|e| matches!(e, Event::Eval { .. })));
    check_frame_reconciliation(&events, &r.comm, &r.net, cfg.clients);
}

/// The JSONL sink: every line a traced run writes parses back through
/// `Event::from_jsonl` with monotonically plausible timestamps, and the
/// parsed events satisfy the same reconciliation identity.
#[test]
fn jsonl_file_roundtrips_and_reconciles() {
    let path = std::env::temp_dir().join(format!("sbc-trace-test-{}.jsonl", std::process::id()));
    let mut cfg = train_cfg(20);
    cfg.trace = Trace::jsonl(&path).expect("create trace file");
    let r = run(&cfg);

    let text = std::fs::read_to_string(&path).expect("trace file readable");
    let _ = std::fs::remove_file(&path);
    let mut events = Vec::new();
    for line in text.lines() {
        let (_t, e) = Event::from_jsonl(line)
            .unwrap_or_else(|| panic!("unparseable trace line: {line}"));
        events.push(e);
    }
    assert!(!events.is_empty(), "traced run must write events");
    assert!(events.iter().any(|e| matches!(e, Event::RoundStart { .. })));
    assert!(events.iter().any(|e| matches!(e, Event::Stage { .. })));
    check_frame_reconciliation(&events, &r.comm, &r.net, cfg.clients);
}

/// Under the deterministic simulator with the harsh fault profile, every
/// fault the fabric applies must surface as exactly one `Fault` event
/// carrying its replay-stable RNG key — and for completed schedules the
/// server-role Frame events reconcile with the federated accounting.
#[test]
fn sim_fault_events_match_schedule_and_frames_reconcile() {
    let mut base = train_cfg(30);
    base.transport.retry_backoff = Duration::from_millis(2);
    base.transport.read_timeout = Duration::from_millis(300);
    base.transport.round_timeout = Duration::from_millis(600);

    let mut completed = 0u64;
    let mut total_faults = 0usize;
    for i in 0..20u64 {
        let seed = 1 + i;
        let (trace, ring) = Trace::ring(1_000_000);
        let mut cfg = base.clone();
        cfg.trace = trace;
        let mut sim = SimConfig::new(seed);
        sim.profile = SimProfile::harsh();
        let run = run_schedule(&cfg, &sim, |_| backend());

        let events: Vec<Event> = ring.events().into_iter().map(|(_, e)| e).collect();
        let mut traced: Vec<(u32, u32, u64, String, String)> = events
            .iter()
            .filter_map(|e| match e {
                Event::Fault { seed: s, client, attempt, seq, dir, action } => {
                    assert_eq!(*s, seed, "fault event must carry the schedule seed");
                    Some((*client, *attempt, *seq, dir.clone(), action.clone()))
                }
                _ => None,
            })
            .collect();
        let mut applied: Vec<(u32, u32, u64, String, String)> = run
            .applied
            .iter()
            .map(|f| {
                (
                    f.ctx.client,
                    f.ctx.attempt,
                    f.ctx.seq,
                    f.ctx.dir.to_string(),
                    f.action.to_string(),
                )
            })
            .collect();
        traced.sort();
        applied.sort();
        assert_eq!(traced, applied, "seed {seed}: Fault events vs applied schedule");
        total_faults += applied.len();

        if run.completed() {
            completed += 1;
            let res = run.server.ok().expect("completed run has a server result");
            check_frame_reconciliation(&events, &res.comm, &res.net, cfg.clients);
        }
    }
    assert!(completed > 0, "no harsh schedule completed");
    assert!(total_faults > 0, "harsh profile applied no faults");
}
