//! Property-based tests (hand-rolled harness: proptest is not in the
//! vendored dependency set) over coordinator/codec/compression invariants.
//! Each property runs across a seeded family of random cases; failures
//! print the seed for exact reproduction.

use sbc::codec::bitio::{BitReader, BitWriter};
use sbc::codec::golomb;
use sbc::codec::message::{PosCodec, WireCodec};
use sbc::compression::registry::MethodConfig;
use sbc::compression::residual::Residual;
use sbc::compression::topk;
use sbc::compression::{Granularity, Selection, SelectorCfg, TensorUpdate, UpdateMsg};
use sbc::coordinator::aggregation::{aggregate_into, aggregate_sharded, AggRule};
use sbc::coordinator::pool::WorkerPool;
use sbc::model::TensorLayout;
use sbc::util::rng::Rng;

/// Run `prop` over `cases` seeded random instances.
fn forall(cases: u64, prop: impl Fn(&mut Rng, u64)) {
    for seed in 0..cases {
        let mut rng = Rng::new(0x9E37 + seed * 7919);
        prop(&mut rng, seed);
    }
}

fn random_delta(rng: &mut Rng, n: usize) -> Vec<f32> {
    let shape = rng.below(4);
    (0..n)
        .map(|_| match shape {
            0 => rng.normal(),
            1 => rng.normal() * rng.next_f32().powi(4),
            2 => rng.normal().abs(),
            _ => -rng.normal().abs() * rng.next_f32(),
        })
        .collect()
}

/// A paper-faithful SBC pipeline over the whole vector.
fn sbc_pipeline(p: f64, strategy: Selection, seed: u64) -> sbc::compression::Pipeline {
    MethodConfig::builder()
        .select(SelectorCfg::TwoSided { p, strategy })
        .quantize(sbc::compression::QuantizerCfg::BinaryMean)
        .granularity(Granularity::Global)
        .build()
        .build(seed)
}

#[test]
fn prop_golomb_roundtrip_any_positions() {
    forall(40, |rng, seed| {
        let n = 100 + rng.below(100_000);
        let p = [0.0005, 0.005, 0.05, 0.3][rng.below(4)];
        let mut positions: Vec<u32> = Vec::new();
        for i in 0..n {
            if rng.next_f64() < p {
                positions.push(i as u32);
            }
        }
        let b = golomb::optimal_b(p);
        let mut w = BitWriter::new();
        golomb::encode_positions(&mut w, &positions, b);
        let (bytes, bits) = w.finish();
        let mut r = BitReader::new(&bytes, bits);
        let got = golomb::decode_positions(&mut r, positions.len(), b).unwrap();
        assert_eq!(got, positions, "seed {seed}");
        assert_eq!(bits, golomb::measure_positions_bits(&positions, b), "seed {seed}");
    });
}

/// Random instances of every `TensorUpdate` variant, biased toward the
/// edge cases the wire format must survive: empty index lists and
/// single-element tensors.
fn random_tensor_update(rng: &mut Rng, variant: usize) -> TensorUpdate {
    let n = match rng.below(4) {
        0 => 0usize, // empty
        1 => 1,      // single element
        _ => 2 + rng.below(600),
    };
    let sparse_idx = |rng: &mut Rng, n: usize| -> Vec<u32> {
        let mut idx: Vec<u32> = (0..n as u32).filter(|_| rng.next_f64() < 0.3).collect();
        idx.dedup();
        idx
    };
    match variant {
        0 => TensorUpdate::Dense((0..n).map(|_| rng.normal()).collect()),
        1 => {
            let idx = sparse_idx(rng, n);
            let val = idx.iter().map(|_| rng.normal()).collect();
            TensorUpdate::SparseF32 { idx, val }
        }
        2 => TensorUpdate::SparseBinary {
            idx: sparse_idx(rng, n),
            mu: rng.normal().abs(),
            side_pos: rng.below(2) == 0,
        },
        3 => TensorUpdate::Sign { signs: (0..n).map(|_| rng.below(2) == 0).collect() },
        4 => TensorUpdate::SignMeans {
            signs: (0..n).map(|_| rng.below(2) == 0).collect(),
            mu_pos: rng.normal().abs(),
            mu_neg: -rng.normal().abs(),
        },
        5 => TensorUpdate::Ternary {
            scale: rng.normal().abs(),
            vals: (0..n).map(|_| [0i8, 1, -1][rng.below(3)]).collect(),
        },
        _ => TensorUpdate::Quantized {
            scale: rng.normal().abs(),
            levels: 1 + rng.below(100) as u8,
            vals: (0..n).map(|_| rng.below(9) as i8 - 4).collect(),
        },
    }
}

#[test]
fn prop_every_variant_roundtrips_through_every_pos_codec() {
    // satellite coverage: TensorUpdate variants x PosCodecs through the
    // WireCodec stage, bit-exact, including empty-index and
    // single-element tensors, decoded into dirty reused scratch
    forall(60, |rng, seed| {
        let msg = UpdateMsg {
            round: rng.below(10_000) as u32,
            tensors: (0..7).map(|v| random_tensor_update(rng, v)).collect(),
        };
        for codec in [PosCodec::Golomb, PosCodec::Fixed16, PosCodec::Elias] {
            let mut wire = WireCodec::new(codec);
            // scratch starts dirty with mismatched variants: slot reuse
            // must replace them and still decode bit-exactly
            let mut scratch = UpdateMsg {
                round: 7,
                tensors: vec![TensorUpdate::Dense(vec![9.0; 8]); 3],
            };
            for pass in 0..2 {
                let (bytes, bits) = wire.encode(&msg);
                let bytes = bytes.to_vec();
                sbc::codec::message::decode_into(&bytes, bits, &mut scratch)
                    .unwrap_or_else(|e| panic!("seed {seed} {codec:?} pass {pass}: {e}"));
                assert_eq!(scratch, msg, "seed {seed} {codec:?} pass {pass}");
            }
        }
    });
}

#[test]
fn prop_message_roundtrip_every_pipeline() {
    forall(30, |rng, seed| {
        let n = 500 + rng.below(5_000);
        let layout =
            TensorLayout::new(vec![("a".into(), vec![n / 3]), ("b".into(), vec![n - n / 3])]);
        let delta = random_delta(rng, layout.total);
        let configs = [
            MethodConfig::baseline(),
            MethodConfig::gradient_dropping(),
            MethodConfig::sbc2(),
            MethodConfig::qsgd(4),
            MethodConfig::terngrad(),
            MethodConfig::onebit(),
            MethodConfig::signsgd(0.5),
        ];
        for cfg in configs {
            let mut pipeline = cfg.build(seed);
            let msg = pipeline.compress(&delta, &layout, 3);
            for codec in [PosCodec::Golomb, PosCodec::Fixed16, PosCodec::Elias] {
                let mut wire = WireCodec::new(codec);
                let (bytes, bits) = wire.encode(&msg);
                let got = sbc::codec::message::decode(bytes, bits)
                    .unwrap_or_else(|e| panic!("seed {seed} {}: {e}", pipeline.name()));
                assert_eq!(got, msg, "seed {seed} {} {codec:?}", pipeline.name());
            }
        }
    });
}

#[test]
fn prop_sbc_transmitted_value_is_mean_of_kept() {
    forall(30, |rng, seed| {
        let n = 1_000 + rng.below(50_000);
        let delta = random_delta(rng, n);
        let p = [0.001, 0.01, 0.05][rng.below(3)];
        let mut pipeline = sbc_pipeline(p, Selection::Exact, seed);
        match pipeline.compress_segment(&delta) {
            TensorUpdate::SparseBinary { idx, mu, side_pos } => {
                if idx.is_empty() {
                    return;
                }
                let vals: Vec<f32> = idx.iter().map(|&i| delta[i as usize]).collect();
                // all kept entries share the winning sign
                if side_pos {
                    assert!(vals.iter().all(|&v| v > 0.0), "seed {seed}");
                } else {
                    assert!(vals.iter().all(|&v| v < 0.0), "seed {seed}");
                }
                // mu is their mean magnitude
                let mean = vals.iter().map(|v| v.abs() as f64).sum::<f64>() / vals.len() as f64;
                assert!(
                    (mu as f64 - mean).abs() <= 1e-5 * mean.max(1.0),
                    "seed {seed}: mu {mu} vs mean {mean}"
                );
            }
            other => panic!("{other:?}"),
        }
    });
}

#[test]
fn prop_sbc_error_never_exceeds_input_norm() {
    // ||acc - transmitted|| <= ||acc|| (projection property, Thm II.1)
    forall(25, |rng, seed| {
        let n = 1_000 + rng.below(20_000);
        let delta = random_delta(rng, n);
        let mut pipeline = sbc_pipeline(0.01, Selection::Exact, seed);
        let tu = pipeline.compress_segment(&delta);
        let mut dense = vec![0.0f32; n];
        tu.add_into(&mut dense, 1.0);
        let err: f64 = delta
            .iter()
            .zip(&dense)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        let norm: f64 = delta.iter().map(|a| (*a as f64).powi(2)).sum::<f64>().sqrt();
        // binarization is not an exact projection, but must stay bounded
        assert!(err <= norm * 1.0001, "seed {seed}: err {err} > norm {norm}");
    });
}

#[test]
fn prop_residual_conservation_through_pipeline() {
    // sum(delta_t) = sum(tx_t) + R_T for any pipeline with residual
    forall(15, |rng, seed| {
        let n = 2_000;
        let layout = TensorLayout::flat(n);
        let mut pipeline = sbc_pipeline(0.02, Selection::Exact, seed);
        let mut res = Residual::new(n, true);
        let mut sum_delta = vec![0.0f64; n];
        let mut sum_tx = vec![0.0f64; n];
        for round in 0..12 {
            let delta = random_delta(rng, n);
            for i in 0..n {
                sum_delta[i] += delta[i] as f64;
            }
            let mut acc = delta.clone();
            res.accumulate_into(&mut acc);
            let msg = pipeline.compress(&acc, &layout, round);
            let dense = msg.to_dense(&layout, 1.0);
            res.update(&acc, &dense);
            for i in 0..n {
                sum_tx[i] += dense[i] as f64;
            }
        }
        let mut max_err = 0.0f64;
        for i in 0..n {
            let e = (sum_delta[i] - sum_tx[i] - res.as_slice()[i] as f64).abs();
            max_err = max_err.max(e);
        }
        assert!(max_err < 1e-2, "seed {seed}: conservation violated by {max_err}");
    });
}

#[test]
fn prop_topk_exact_count_and_magnitudes() {
    forall(30, |rng, seed| {
        let n = 100 + rng.below(30_000);
        let x = random_delta(rng, n);
        let k = 1 + rng.below(n.min(500));
        let idx = topk::topk_exact(&x, k);
        assert_eq!(idx.len(), k, "seed {seed}");
        // sorted, unique
        assert!(idx.windows(2).all(|w| w[0] < w[1]), "seed {seed}");
        // min kept magnitude >= max dropped magnitude
        let kept: std::collections::HashSet<u32> = idx.iter().copied().collect();
        let min_kept = idx.iter().map(|&i| x[i as usize].abs()).fold(f32::MAX, f32::min);
        let max_dropped = (0..n as u32)
            .filter(|i| !kept.contains(i))
            .map(|i| x[i as usize].abs())
            .fold(0.0f32, f32::max);
        assert!(min_kept >= max_dropped, "seed {seed}: {min_kept} < {max_dropped}");
    });
}

#[test]
fn prop_hist_threshold_never_undershoots() {
    forall(30, |rng, seed| {
        let n = 1_000 + rng.below(100_000);
        let x = random_delta(rng, n);
        let k = 1 + rng.below(n / 20 + 1) as u32;
        let (tp, tn, _) = topk::hist_thresholds(&x, k);
        let np = x.iter().filter(|&&v| v > 0.0 && v >= tp).count() as u32;
        let nn = x.iter().filter(|&&v| v < 0.0 && -v >= tn).count() as u32;
        let total_pos = x.iter().filter(|&&v| v > 0.0).count() as u32;
        let total_neg = x.iter().filter(|&&v| v < 0.0).count() as u32;
        assert!(np >= k.min(total_pos), "seed {seed}: pos {np} < {k}");
        assert!(nn >= k.min(total_neg), "seed {seed}: neg {nn} < {k}");
    });
}

/// The eight paper method presets (Table I / II columns).
fn paper_presets() -> [MethodConfig; 8] {
    [
        MethodConfig::baseline(),
        MethodConfig::fedavg(10),
        MethodConfig::gradient_dropping(),
        MethodConfig::sbc2(),
        MethodConfig::signsgd(1e-3),
        MethodConfig::terngrad(),
        MethodConfig::qsgd(4),
        MethodConfig::onebit(),
    ]
}

#[test]
fn prop_nan_inf_gradients_never_panic_and_stay_deterministic() {
    // bugfix regression: the magnitude sorts used partial_cmp().unwrap(),
    // which panicked on NaN gradients (and NaN ordering made selection
    // nondeterministic). With total_cmp, NaN has a fixed sort position:
    // poisoned inputs must compress without panicking, bit-identically
    // across same-seed pipelines, and survive the full wire round trip,
    // for every paper preset.
    forall(12, |rng, seed| {
        let n = 500 + rng.below(3_000);
        let layout =
            TensorLayout::new(vec![("a".into(), vec![n / 3]), ("b".into(), vec![n - n / 3])]);
        let mut delta = random_delta(rng, layout.total);
        let poison = [f32::NAN, f32::INFINITY, f32::NEG_INFINITY];
        for _ in 0..1 + layout.total / 20 {
            let at = rng.below(layout.total);
            delta[at] = poison[rng.below(3)];
        }
        for cfg in paper_presets() {
            let mut a = cfg.build(seed);
            let mut b = cfg.build(seed);
            let msg_a = a.compress(&delta, &layout, 0);
            let msg_b = b.compress(&delta, &layout, 0);
            let mut wire = WireCodec::new(PosCodec::Golomb);
            let (bytes_a, bits_a) = wire.encode(&msg_a);
            let bytes_a = bytes_a.to_vec();
            let (bytes_b, bits_b) = wire.encode(&msg_b);
            // byte-level comparison sidesteps NaN != NaN
            assert_eq!(
                (&bytes_a[..], bits_a),
                (bytes_b, bits_b),
                "seed {seed} {}: same-seed pipelines diverged on poisoned input",
                a.name()
            );
            let decoded = sbc::codec::message::decode(&bytes_a, bits_a)
                .unwrap_or_else(|e| panic!("seed {seed} {}: {e}", a.name()));
            let mut dense = vec![0.0f32; layout.total];
            decoded.densify_into(&layout, cfg.granularity, cfg.sign_scale(), &mut dense);
        }
    });
}

#[test]
fn prop_sharded_aggregate_bit_identical_to_serial() {
    // the tentpole determinism invariant: sharded parallel aggregation
    // equals the serial fold bit-for-bit across thread counts, client
    // counts, and the densified update shapes of all eight paper
    // presets (each preset exercises a different TensorUpdate variant
    // and aggregation rule)
    forall(6, |rng, seed| {
        let n = 500 + rng.below(4_000);
        let layout =
            TensorLayout::new(vec![("a".into(), vec![n / 3]), ("b".into(), vec![n - n / 3])]);
        for cfg in paper_presets() {
            let rule = AggRule::for_method(&cfg);
            let clients = [1usize, 2, 5, 16][rng.below(4)];
            // realistic per-client updates: run each client's delta
            // through the preset's actual pipeline and densify
            let updates: Vec<Vec<f32>> = (0..clients)
                .map(|c| {
                    let mut pipeline = cfg.build(seed ^ c as u64);
                    let delta = random_delta(rng, layout.total);
                    let msg = pipeline.compress(&delta, &layout, 0);
                    let mut dense = vec![0.0f32; layout.total];
                    msg.densify_into(&layout, cfg.granularity, cfg.sign_scale(), &mut dense);
                    if matches!(rule, AggRule::MajoritySign { .. }) {
                        for v in dense.iter_mut() {
                            *v = v.signum();
                        }
                    }
                    dense
                })
                .collect();
            let mut serial = vec![0.0f32; layout.total];
            aggregate_into(updates.iter().map(|u| u.as_slice()), rule, &mut serial);
            for threads in [1usize, 2, 3, 7, 32] {
                let pool = WorkerPool::new(threads);
                let mut parallel = vec![f32::NAN; layout.total]; // dirty buffer
                aggregate_sharded(&updates[..], rule, &pool, &mut parallel);
                let a: Vec<u32> = serial.iter().map(|v| v.to_bits()).collect();
                let b: Vec<u32> = parallel.iter().map(|v| v.to_bits()).collect();
                assert_eq!(
                    a, b,
                    "seed {seed} {} clients={clients} threads={threads}",
                    cfg.label()
                );
            }
        }
    });
}

#[test]
fn prop_compress_into_is_deterministic_across_buffer_reuse() {
    // the scratch-reusing path must produce exactly what a fresh
    // allocation would, for every deterministic stage composition
    forall(10, |rng, seed| {
        let n = 500 + rng.below(3_000);
        let layout =
            TensorLayout::new(vec![("a".into(), vec![n / 2]), ("b".into(), vec![n - n / 2])]);
        let configs = [
            MethodConfig::baseline(),
            MethodConfig::gradient_dropping(),
            MethodConfig::sbc(0.01, 1),
            MethodConfig::onebit(),
            MethodConfig::signsgd(0.5),
        ];
        for cfg in configs {
            let mut fresh = cfg.build(seed);
            let mut reused = cfg.build(seed);
            let mut scratch = UpdateMsg::scratch();
            for round in 0..4 {
                let delta = random_delta(rng, layout.total);
                let want = fresh.compress(&delta, &layout, round);
                reused.compress_into(&delta, &layout, round, &mut scratch);
                assert_eq!(scratch, want, "seed {seed} round {round} {}", fresh.name());
            }
        }
    });
}

#[test]
fn prop_truncated_messages_always_error() {
    // transport satellite: decode consumes exactly the encoded bit count,
    // so *any* strict-prefix truncation of a valid message must surface
    // as a typed error — never a panic, never a silent short decode
    forall(40, |rng, seed| {
        let msg = UpdateMsg {
            round: rng.below(10_000) as u32,
            tensors: (0..7).map(|v| random_tensor_update(rng, v)).collect(),
        };
        for codec in [PosCodec::Golomb, PosCodec::Fixed16, PosCodec::Elias] {
            let mut wire = WireCodec::new(codec);
            let (bytes, bits) = wire.encode(&msg);
            let bytes = bytes.to_vec();
            let mut out = UpdateMsg::scratch();
            for _ in 0..16 {
                let cut = rng.below(bits as usize) as u64;
                let cut_bytes = cut.div_ceil(8) as usize;
                let res = sbc::codec::message::decode_into(&bytes[..cut_bytes], cut, &mut out);
                assert!(res.is_err(), "seed {seed} {codec:?}: cut {cut}/{bits} bits decoded");
            }
        }
    });
}

#[test]
fn prop_bit_flipped_messages_never_panic() {
    // the frame CRC rejects corruption before the codec normally sees it,
    // but defense in depth demands the payload decoder itself survive
    // arbitrary flips: it may Err, or decode to some other valid message,
    // but it must never panic or drive an unbounded allocation
    forall(40, |rng, _seed| {
        let msg = UpdateMsg {
            round: rng.below(10_000) as u32,
            tensors: (0..7).map(|v| random_tensor_update(rng, v)).collect(),
        };
        for codec in [PosCodec::Golomb, PosCodec::Fixed16, PosCodec::Elias] {
            let mut wire = WireCodec::new(codec);
            let (bytes, bits) = wire.encode(&msg);
            let clean = bytes.to_vec();
            let mut out = UpdateMsg::scratch();
            for _ in 0..24 {
                let mut bad = clean.clone();
                for _ in 0..1 + rng.below(4) {
                    let at = rng.below(bad.len() * 8);
                    bad[at / 8] ^= 1 << (7 - (at % 8));
                }
                let _ = sbc::codec::message::decode_into(&bad, bits, &mut out);
            }
        }
    });
}

#[test]
fn prop_frame_counter_wraparound_roundtrips() {
    use sbc::transport::frame::{read_frame, write_frame, FrameBuf, FrameKind};
    use std::io::Cursor;
    // header counters at the u32 boundary and empty payloads must survive
    // the wire bit-exactly for every frame kind (reconnecting clients can
    // legitimately carry large round counters)
    let kinds = [
        FrameKind::Hello,
        FrameKind::HelloAck,
        FrameKind::Update,
        FrameKind::Broadcast,
        FrameKind::Done,
        FrameKind::Error,
    ];
    forall(30, |rng, seed| {
        let round = [0u32, 1, u32::MAX - 1, u32::MAX][rng.below(4)];
        let client = [0u32, 1, u32::MAX][rng.below(3)];
        let kind = kinds[rng.below(6)];
        let payload: Vec<u8> = (0..rng.below(3)).map(|_| rng.below(256) as u8).collect();
        let bits = payload.len() as u64 * 8;
        let mut f = FrameBuf::default();
        f.set(kind, round, client, &payload, bits);
        let mut wire = Vec::new();
        write_frame(&mut wire, &f).unwrap();
        let mut out = FrameBuf::default();
        read_frame(&mut Cursor::new(&wire[..]), &mut out).unwrap();
        assert_eq!((out.kind, out.round, out.client), (kind, round, client), "seed {seed}");
        assert_eq!(out.payload_bits as u64, bits, "seed {seed}");
        assert_eq!(&out.payload[..out.payload_bytes()], &payload[..], "seed {seed}");
    });
}

#[test]
fn prop_frame_unknown_kind_and_hostile_bits_are_typed_errors() {
    use sbc::transport::frame::{crc32, read_frame, FrameBuf, MAGIC, PROTOCOL_VERSION};
    use sbc::transport::TransportError;
    use std::io::Cursor;

    // hand-assemble a frame whose CRC is *valid* for arbitrary header
    // fields, so the tests below exercise semantic validation rather than
    // the checksum
    fn raw_frame(kind_tag: u8, payload_bits: u32, payload: &[u8], claim: Option<u64>) -> Vec<u8> {
        let mut inner = Vec::with_capacity(16 + payload.len());
        inner.extend_from_slice(&MAGIC.to_be_bytes());
        inner.push(PROTOCOL_VERSION);
        inner.push(kind_tag);
        inner.extend_from_slice(&7u32.to_be_bytes()); // round
        inner.extend_from_slice(&3u32.to_be_bytes()); // client
        inner.extend_from_slice(&payload_bits.to_be_bytes());
        let crc = crc32(&[&inner[..], payload]);
        let claimed = claim.unwrap_or(payload.len() as u64);
        let mut wire = Vec::new();
        wire.extend_from_slice(&((20 + claimed) as u32).to_be_bytes());
        wire.extend_from_slice(&inner);
        wire.extend_from_slice(&crc.to_be_bytes());
        wire.extend_from_slice(payload);
        wire
    }

    // a checksum-valid frame with an unknown kind tag (a future protocol
    // speaking to us) must be a typed BadFrame, never a panic
    forall(40, |rng, seed| {
        let tag = 6 + rng.below(250) as u8;
        let wire = raw_frame(tag, 8, &[0xAA], None);
        let mut out = FrameBuf::default();
        let err = read_frame(&mut Cursor::new(&wire[..]), &mut out).unwrap_err();
        assert!(
            matches!(&err, TransportError::BadFrame(m) if m.contains("unknown frame kind")),
            "seed {seed} tag {tag}: {err}"
        );
    });

    // payload_bits = u32::MAX with a *consistent* length prefix: the
    // claimed half-gigabyte passes the size cap, but the chunked reader
    // must fail with a typed error after at most one 64 KiB chunk of
    // allocation — never reserve the full claim up front
    let claimed = (u32::MAX as u64).div_ceil(8);
    let mut out = FrameBuf::default();
    let wire = raw_frame(2, u32::MAX, &[0u8; 100], Some(claimed));
    let err = read_frame(&mut Cursor::new(&wire[..]), &mut out).unwrap_err();
    assert!(matches!(err, TransportError::Io(_)), "{err}");
    assert!(
        out.payload.capacity() <= 128 * 1024,
        "hostile payload_bits claim reserved {} bytes",
        out.payload.capacity()
    );

    // payload_bits = u32::MAX with the actual (tiny) length prefix:
    // rejected up front by the length cross-check
    let wire = raw_frame(2, u32::MAX, &[0u8; 4], None);
    let err = read_frame(&mut Cursor::new(&wire[..]), &mut out).unwrap_err();
    assert!(matches!(&err, TransportError::BadFrame(m) if m.contains("inconsistent")), "{err}");

    // payload_bits = 0 against a nonzero length prefix: same cross-check
    let wire = raw_frame(2, 0, &[0u8; 1], None);
    let err = read_frame(&mut Cursor::new(&wire[..]), &mut out).unwrap_err();
    assert!(matches!(&err, TransportError::BadFrame(m) if m.contains("inconsistent")), "{err}");

    // payload_bits = 0 with an empty payload is a legal frame
    let wire = raw_frame(4, 0, &[], None);
    read_frame(&mut Cursor::new(&wire[..]), &mut out).expect("zero-bit frame is valid");
    assert_eq!(out.payload_bits, 0);
    assert_eq!(out.payload_bytes(), 0);
}

#[test]
fn prop_corrupt_frames_rejected_no_panic() {
    use sbc::transport::frame::{read_frame, write_frame, FrameBuf, FrameKind};
    use std::io::Cursor;
    // frames off the socket: every single-bit flip lands in CRC-covered
    // bytes or contradicts the CRC-covered payload_bits via the length
    // prefix, so it must be rejected; every truncation must be an error;
    // nothing read from the wire may panic the receiver
    forall(60, |rng, seed| {
        let nbytes = rng.below(200);
        let payload: Vec<u8> = (0..nbytes).map(|_| rng.below(256) as u8).collect();
        let bits = if nbytes == 0 { 0 } else { nbytes as u64 * 8 - rng.below(8) as u64 };
        let kinds = [
            FrameKind::Hello,
            FrameKind::HelloAck,
            FrameKind::Update,
            FrameKind::Broadcast,
            FrameKind::Done,
            FrameKind::Error,
        ];
        let mut f = FrameBuf::default();
        f.set(kinds[rng.below(6)], rng.below(1 << 20) as u32, rng.below(64) as u32, &payload, bits);
        let mut wire = Vec::new();
        write_frame(&mut wire, &f).expect("write to vec");
        let mut out = FrameBuf::default();
        read_frame(&mut Cursor::new(&wire[..]), &mut out).expect("clean frame must parse");
        assert_eq!(out.payload_bits as u64, bits, "seed {seed}");
        assert_eq!(out.kind, f.kind, "seed {seed}");
        for _ in 0..24 {
            let mut bad = wire.clone();
            let at = rng.below(bad.len() * 8);
            bad[at / 8] ^= 1 << (7 - (at % 8));
            let got = read_frame(&mut Cursor::new(&bad[..]), &mut out);
            assert!(got.is_err(), "seed {seed}: flipped bit {at} accepted");
        }
        for _ in 0..8 {
            let cut = rng.below(wire.len());
            let got = read_frame(&mut Cursor::new(&wire[..cut]), &mut out);
            assert!(got.is_err(), "seed {seed}: truncation to {cut} bytes accepted");
        }
    });
}
