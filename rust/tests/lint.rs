//! `sbc-lint` end-to-end: golden diagnostics on the seeded fixture
//! corpus, zero findings on the real tree, suppression hygiene, and the
//! CLI contract — including proof that the two legacy CI grep gates
//! (`partial_cmp` in compression/, `File::create` in persist/) are
//! subsumed: the fixtures contain those exact patterns and the lint
//! flags them.

use std::path::{Path, PathBuf};
use std::process::Command;

use sbc::analysis::{lint_tree, render_text};

fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn fixture(name: &str) -> PathBuf {
    repo_root().join("rust/tests/lint_fixtures").join(name)
}

fn lint_text(root: &Path) -> String {
    render_text(&lint_tree(root).expect("lint walks the tree"))
}

#[test]
fn violations_fixture_matches_golden_diagnostics() {
    let root = fixture("violations");
    let expected =
        std::fs::read_to_string(root.join("expected.txt")).expect("golden file exists");
    let actual = lint_text(&root);
    assert_eq!(actual, expected, "fixture diagnostics drifted from expected.txt");
    // every rule is represented in the corpus
    for rule in sbc::analysis::rules::RULE_IDS {
        assert!(actual.contains(&format!(" {rule} ")), "no fixture coverage for rule {rule}");
    }
}

#[test]
fn real_tree_is_clean() {
    let findings = lint_tree(&repo_root().join("rust/src")).expect("lint walks rust/src");
    assert!(
        findings.is_empty(),
        "rust/src must lint clean; found:\n{}",
        render_text(&findings)
    );
}

#[test]
fn clean_fixture_with_lexer_traps_yields_nothing() {
    let out = lint_text(&fixture("clean"));
    assert_eq!(out, "", "clean fixture tree (strings/comments/used allow) must yield nothing");
}

#[test]
fn stale_and_malformed_suppressions_are_errors() {
    let findings = lint_tree(&fixture("unused_allow")).expect("lint walks the tree");
    let rules: Vec<&str> = findings.iter().map(|f| f.rule.as_str()).collect();
    assert_eq!(rules, ["unused-allow", "bad-allow"], "{findings:?}");
    assert_eq!(findings[0].line, 6);
    assert_eq!(findings[1].line, 11);
}

#[test]
fn legacy_grep_gates_are_subsumed() {
    // the repo's CI used to grep for these two exact substrings; prove
    // the fixtures carry them and the lint reports those very lines
    let select = std::fs::read_to_string(fixture("violations/compression/select.rs")).unwrap();
    assert!(select.contains("partial_cmp("), "fixture lost the legacy grep pattern");
    let format = std::fs::read_to_string(fixture("violations/persist/format.rs")).unwrap();
    assert!(format.contains("File::create("), "fixture lost the legacy grep pattern");

    let out = lint_text(&fixture("violations"));
    assert!(out.contains("compression/select.rs:7 no-panic `partial_cmp`"), "{out}");
    assert!(out.contains("persist/format.rs:13 durability `File::create`"), "{out}");
}

#[test]
fn cli_exit_codes_text_and_json() {
    let bin = env!("CARGO_BIN_EXE_sbc-lint");

    let dirty = Command::new(bin)
        .args(["--root", fixture("violations").to_str().unwrap()])
        .output()
        .expect("run sbc-lint");
    assert_eq!(dirty.status.code(), Some(1), "findings must exit 1");
    let expected =
        std::fs::read_to_string(fixture("violations/expected.txt")).expect("golden file");
    assert_eq!(String::from_utf8_lossy(&dirty.stdout), expected);

    let clean = Command::new(bin)
        .args(["--root", fixture("clean").to_str().unwrap()])
        .output()
        .expect("run sbc-lint");
    assert_eq!(clean.status.code(), Some(0), "clean tree must exit 0");
    assert_eq!(String::from_utf8_lossy(&clean.stdout), "");

    let json = Command::new(bin)
        .args(["--json", "--root", fixture("violations").to_str().unwrap()])
        .output()
        .expect("run sbc-lint --json");
    assert_eq!(json.status.code(), Some(1));
    let body = String::from_utf8_lossy(&json.stdout);
    assert!(body.trim_start().starts_with('['), "{body}");
    assert!(body.trim_end().ends_with(']'), "{body}");
    assert!(body.contains("\"rule\": \"no-panic\""), "{body}");
    assert_eq!(body.matches("\"file\":").count(), expected.lines().count());

    let bad = Command::new(bin).arg("--bogus").output().expect("run sbc-lint --bogus");
    assert_eq!(bad.status.code(), Some(2), "usage errors must exit 2");
}
