//! Wire-format v2 golden-bytes regression tests.
//!
//! Round-trip tests prove encode/decode agree with *each other*; they
//! cannot catch a change that alters the on-wire layout on both sides at
//! once. These tests pin the actual bytes two ways: an independent
//! reference bit-writer that re-implements the documented layout (so the
//! library encoder must match a second implementation, not itself), and
//! hand-computed literal byte snapshots. If any of them breaks, the wire
//! format changed: bump `WIRE_VERSION` and regenerate deliberately.

use sbc::codec::message::{encode, PosCodec, WIRE_VERSION};
use sbc::compression::{TensorUpdate, UpdateMsg};

/// Independent MSB-first bit writer following the layout documented in
/// `codec::message` — deliberately *not* built on `codec::bitio`.
#[derive(Default)]
struct RefWriter {
    buf: Vec<u8>,
    nbits: u64,
}

impl RefWriter {
    fn bit(&mut self, b: bool) {
        let byte = (self.nbits / 8) as usize;
        if byte == self.buf.len() {
            self.buf.push(0);
        }
        if b {
            self.buf[byte] |= 1 << (7 - (self.nbits % 8));
        }
        self.nbits += 1;
    }

    fn put(&mut self, v: u64, n: u32) {
        for i in (0..n).rev() {
            self.bit((v >> i) & 1 == 1);
        }
    }

    fn f32(&mut self, x: f32) {
        self.put(x.to_bits() as u64, 32);
    }

    fn unary(&mut self, q: u64) {
        for _ in 0..q {
            self.bit(true);
        }
        self.bit(false);
    }

    /// Elias gamma: (bitlen-1) zeros, then `x` in bitlen bits. `x >= 1`.
    fn gamma(&mut self, x: u64) {
        let nbits = 64 - x.leading_zeros();
        self.put(0, nbits - 1);
        self.put(x, nbits);
    }
}

/// The position block: n (u32), codec tag (u2), count (u32), then the
/// gap-coded positions. `golomb_b` is the *expected* Golomb parameter —
/// hardcoded by each test so a change to the b-derivation breaks golden.
fn ref_positions(w: &mut RefWriter, idx: &[u32], codec: PosCodec, golomb_b: u32) {
    let n = idx.iter().map(|&i| i as u64 + 1).max().unwrap_or(1);
    w.put(n, 32);
    let tag = match codec {
        PosCodec::Golomb => 0u64,
        PosCodec::Fixed16 => 1,
        PosCodec::Elias => 2,
    };
    w.put(tag, 2);
    w.put(idx.len() as u64, 32);
    let mut prev: i64 = -1;
    match codec {
        PosCodec::Golomb => {
            w.put(golomb_b as u64, 6);
            for &pos in idx {
                let v = (pos as i64 - prev - 1) as u64;
                w.unary(v >> golomb_b);
                w.put(v & ((1u64 << golomb_b) - 1), golomb_b);
                prev = pos as i64;
            }
        }
        PosCodec::Fixed16 => {
            for &pos in idx {
                let v = (pos as i64 - prev - 1) as u64;
                if v >= 0xFFFF {
                    w.put(0xFFFF, 16);
                    w.put(v, 32);
                } else {
                    w.put(v, 16);
                }
                prev = pos as i64;
            }
        }
        PosCodec::Elias => {
            for &pos in idx {
                w.gamma((pos as i64 - prev) as u64);
                prev = pos as i64;
            }
        }
    }
}

/// One tensor: tag (u4) then the variant payload.
fn ref_tensor(w: &mut RefWriter, t: &TensorUpdate, codec: PosCodec, golomb_b: u32) {
    match t {
        TensorUpdate::Dense(v) => {
            w.put(0, 4);
            w.put(v.len() as u64, 32);
            for &x in v {
                w.f32(x);
            }
        }
        TensorUpdate::SparseF32 { idx, val } => {
            w.put(1, 4);
            ref_positions(w, idx, codec, golomb_b);
            for &x in val {
                w.f32(x);
            }
        }
        TensorUpdate::SparseBinary { idx, mu, side_pos } => {
            w.put(2, 4);
            ref_positions(w, idx, codec, golomb_b);
            w.f32(*mu);
            w.bit(*side_pos);
        }
        TensorUpdate::Sign { signs } => {
            w.put(3, 4);
            w.put(signs.len() as u64, 32);
            for &s in signs {
                w.bit(s);
            }
        }
        TensorUpdate::Ternary { scale, vals } => {
            w.put(4, 4);
            w.put(vals.len() as u64, 32);
            w.f32(*scale);
            for &v in vals {
                w.put(
                    match v {
                        0 => 0,
                        1 => 1,
                        _ => 2,
                    },
                    2,
                );
            }
        }
        TensorUpdate::Quantized { scale, levels, vals } => {
            w.put(5, 4);
            w.put(vals.len() as u64, 32);
            w.f32(*scale);
            w.put(*levels as u64, 8);
            for &v in vals {
                w.bit(v < 0);
                w.gamma(v.unsigned_abs() as u64 + 1);
            }
        }
        TensorUpdate::SignMeans { signs, mu_pos, mu_neg } => {
            w.put(6, 4);
            w.put(signs.len() as u64, 32);
            w.f32(*mu_pos);
            w.f32(*mu_neg);
            for &s in signs {
                w.bit(s);
            }
        }
    }
}

/// Reference message encoding; `golomb_bs` lists the expected Golomb b
/// for each sparse tensor in order of appearance.
fn ref_encode(msg: &UpdateMsg, codec: PosCodec, golomb_bs: &[u32]) -> (Vec<u8>, u64) {
    let mut w = RefWriter::default();
    w.put(0x5BC0, 16); // magic
    w.put(2, 4); // wire format v2
    w.put(msg.round as u64, 32);
    w.put(msg.tensors.len() as u64, 16);
    let mut sparse = 0usize;
    for t in &msg.tensors {
        let b = match t {
            TensorUpdate::SparseF32 { .. } | TensorUpdate::SparseBinary { .. } => {
                sparse += 1;
                golomb_bs[sparse - 1]
            }
            _ => 0,
        };
        ref_tensor(&mut w, t, codec, b);
    }
    (w.buf, w.nbits)
}

fn hex(bytes: &[u8]) -> String {
    bytes.iter().map(|b| format!("{b:02x}")).collect()
}

#[test]
fn wire_version_is_pinned() {
    // bump this assertion together with a deliberate format change
    assert_eq!(WIRE_VERSION, 2);
}

/// Every variant through every position codec must match the independent
/// reference encoder byte for byte.
///
/// The Golomb parameters are hand-derived from eq. 5 and hardcoded:
/// idx [3, 9, 100] gives n = 101, p ≈ 0.0297, b = 4; idx [0, 5, 6, 1000]
/// gives n = 1001, p ≈ 0.004, b = 7. If `optimal_b` changes, this test
/// fails — that is a wire-format change.
#[test]
fn every_variant_matches_reference_encoder() {
    let msg = UpdateMsg {
        round: 3,
        tensors: vec![
            TensorUpdate::Dense(vec![1.0, -2.5, 0.0]),
            TensorUpdate::SparseF32 { idx: vec![3, 9, 100], val: vec![0.5, -0.25, 7.0] },
            TensorUpdate::SparseBinary { idx: vec![0, 5, 6, 1000], mu: 0.125, side_pos: false },
            TensorUpdate::Sign { signs: vec![true, false, true] },
            TensorUpdate::SignMeans { signs: vec![false, true, true], mu_pos: 0.5, mu_neg: -1.5 },
            TensorUpdate::Ternary { scale: 0.3, vals: vec![-1, 0, 1, 1, 0] },
            TensorUpdate::Quantized { scale: 1.5, levels: 8, vals: vec![-8, 0, 3, 8] },
        ],
    };
    for codec in [PosCodec::Golomb, PosCodec::Fixed16, PosCodec::Elias] {
        let (got, got_bits) = encode(&msg, codec);
        let (want, want_bits) = ref_encode(&msg, codec, &[4, 7]);
        assert_eq!(got_bits, want_bits, "{codec:?}");
        assert_eq!(hex(&got), hex(&want), "{codec:?}");
    }
}

/// Empty sparse tensors pin the `n = 1` fallback and the sparsity clamp
/// in the Golomb parameter (p clamped to 1e-9 gives b = 29).
#[test]
fn empty_sparse_tensors_match_reference_encoder() {
    let msg = UpdateMsg {
        round: 0,
        tensors: vec![
            TensorUpdate::SparseF32 { idx: vec![], val: vec![] },
            TensorUpdate::SparseBinary { idx: vec![], mu: 0.0, side_pos: true },
        ],
    };
    for codec in [PosCodec::Golomb, PosCodec::Fixed16, PosCodec::Elias] {
        let (got, got_bits) = encode(&msg, codec);
        let (want, want_bits) = ref_encode(&msg, codec, &[29, 29]);
        assert_eq!(got_bits, want_bits, "{codec:?}");
        assert_eq!(hex(&got), hex(&want), "{codec:?}");
    }
}

/// Fully hand-computed snapshots: literal bytes worked out on paper from
/// the layout doc, with no code (library or reference) in the loop.
#[test]
fn hand_computed_byte_snapshots() {
    // magic 0x5BC0 | ver 0010 | round u32 = 1 | ntensors u16 = 1 |
    // tag 0011 (Sign) | len u32 = 3 | bits 101 | zero padding
    let sign = UpdateMsg {
        round: 1,
        tensors: vec![TensorUpdate::Sign { signs: vec![true, false, true] }],
    };
    for codec in [PosCodec::Golomb, PosCodec::Fixed16, PosCodec::Elias] {
        let (bytes, bits) = encode(&sign, codec);
        assert_eq!(bits, 107, "{codec:?}");
        assert_eq!(hex(&bytes), "5bc02000000010001300000003a0", "{codec:?}");
    }

    // magic | ver | round = 2 | ntensors = 1 | tag 0100 (Ternary) |
    // len u32 = 3 | scale f32 1.0 = 0x3F800000 | codes 01 10 00 | padding
    let tern = UpdateMsg {
        round: 2,
        tensors: vec![TensorUpdate::Ternary { scale: 1.0, vals: vec![1, -1, 0] }],
    };
    for codec in [PosCodec::Golomb, PosCodec::Fixed16, PosCodec::Elias] {
        let (bytes, bits) = encode(&tern, codec);
        assert_eq!(bits, 142, "{codec:?}");
        assert_eq!(hex(&bytes), "5bc020000000200014000000033f80000060", "{codec:?}");
    }
}
