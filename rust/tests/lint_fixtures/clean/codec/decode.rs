//! Lint fixture: a tree that must produce ZERO findings. Every line
//! here is a trap a grep gate would trip over — banned words inside
//! strings, raw strings, comments, char literals — plus one real
//! violation covered by a used suppression, and test-only code.

pub fn describe() -> &'static str {
    // unwrap() and panic! in a comment are not code
    "corrupt input must not panic!: no .unwrap() in decode paths"
}

pub fn raw_doc() -> &'static str {
    r#"grep would flag this .unwrap() and File::create( and partial_cmp( — the lexer must not"#
}

pub fn bytes_doc() -> &'static [u8] {
    b"Instant::now() and HashMap inside a byte string"
}

pub fn punctuation_chars() -> (char, char, char) {
    // a lexer that mis-parses '(' as an opening paren desyncs here
    ('(', '"', '\'')
}

pub fn lifetime_soup<'a>(x: &'a str) -> &'a str {
    x
}

pub fn sanctioned(v: Option<u32>) -> u32 {
    // sbc-lint: allow(no-panic) -- fixture: exercising a *used* suppression
    v.unwrap()
}

/* block comments can nest /* .unwrap() */ and still close cleanly */
pub fn after_block() -> u32 {
    0x5BC0
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_unwrap_and_panic() {
        let v: Option<u32> = Some(1);
        assert_eq!(v.unwrap(), 1);
        if false {
            panic!("tests are exempt");
        }
    }
}
