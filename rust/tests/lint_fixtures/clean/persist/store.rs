//! Lint fixture (clean tree): the sanctioned durability sequence —
//! create-new, write, `sync_all`, then rename — produces no findings.

use std::fs::OpenOptions;

pub fn atomic_write(tmp: &str, final_path: &str, bytes: &[u8]) -> std::io::Result<()> {
    let mut f = OpenOptions::new().write(true).create_new(true).open(tmp)?;
    f.write_all(bytes)?;
    f.sync_all()?;
    std::fs::rename(tmp, final_path)?;
    Ok(())
}
