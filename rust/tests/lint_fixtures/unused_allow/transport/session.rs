//! Lint fixture: suppressions that rot must become errors. The first
//! allow targets a line with no finding (`unused-allow`); the second is
//! malformed — no reason (`bad-allow`).

pub fn fine() -> u32 {
    // sbc-lint: allow(no-panic) -- stale: the unwrap below was removed
    1 + 2
}

pub fn also_fine() -> u32 {
    // sbc-lint: allow(no-panic)
    3
}
