//! Lint fixture: a wall-clock read outside `simnet/clock.rs`
//! (`clock-discipline`).

use std::time::Instant;

pub fn stamp() -> Instant {
    Instant::now()
}
