//! Lint fixture: seeded `no-panic` violations in a compression/ path.
//! Never compiled — scanned by `sbc-lint` in `rust/tests/lint.rs`.

pub fn top_k(x: &[f32], k: usize) -> f32 {
    let mut v = x.to_vec();
    // the exact pattern the legacy CI grep gate matched:
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[k]
}

pub fn threshold(x: &[f32]) -> f32 {
    if x.is_empty() {
        panic!("empty segment");
    }
    unsafe { *x.get_unchecked(0) }
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_is_exempt() {
        let v = vec![1.0f32];
        assert_eq!(v.first().unwrap(), &1.0);
    }
}
