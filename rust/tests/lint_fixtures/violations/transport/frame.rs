//! Lint fixture: a frozen constant with the wrong value (`wire-freeze`
//! mismatch) and an unfinished decode path (`no-panic`).

pub const MAGIC: u16 = 0xDEAD;
pub const PROTOCOL_VERSION: u8 = 1;

pub fn decode_frame(_b: &[u8]) -> Frame {
    todo!("frame decoding")
}
