//! Lint fixture: a `HashSet` in the aggregation path (`determinism` —
//! iteration order would feed the float reduction).

use std::collections::HashSet;

pub fn seen_clients() -> HashSet<u32> {
    HashSet::new()
}
