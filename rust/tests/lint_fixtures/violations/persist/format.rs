//! Lint fixture: `persist/` violations — missing frozen `VERSION`
//! (`wire-freeze`), a bare `File::create` and a `rename` with no
//! preceding `sync_all` (`durability`), a `HashMap` (`determinism`) and
//! an `.unwrap()` (`no-panic`).

use std::collections::HashMap;
use std::fs::File;

pub const MAGIC: u32 = 0x5342_434B;

pub fn save(path: &str, bytes: &[u8]) {
    // the exact pattern the legacy CI grep gate matched:
    let mut f = File::create(path).unwrap();
    f.write_all(bytes);
    std::fs::rename(path, "final.bin");
}

pub fn index() -> HashMap<String, u32> {
    HashMap::new()
}
