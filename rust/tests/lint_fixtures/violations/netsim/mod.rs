//! Lint fixture: a watched wire-constant name defined outside its
//! registered home (`wire-freeze`) — a second `MAGIC` elsewhere is how
//! encode/decode drift starts.

pub const MAGIC: u8 = 3;
