//! Lint fixture: correct frozen wire constants, except `TAG_DENSE` is
//! defined twice (a `wire-freeze` duplicate) — plus a stray `.expect()`.

const MAGIC: u64 = 0x5BC0;
pub const WIRE_VERSION: u8 = 2;
const TAG_DENSE: u64 = 0;
const TAG_SPARSE_F32: u64 = 1;
const TAG_SPARSE_BINARY: u64 = 2;
const TAG_SIGN: u64 = 3;
const TAG_TERNARY: u64 = 4;
const TAG_QUANTIZED: u64 = 5;
const TAG_SIGN_MEANS: u64 = 6;

// a second definition of a frozen constant must be flagged even though
// the value matches: two sites can drift independently later
const TAG_DENSE: u64 = 0;

pub fn decode(b: &[u8]) -> u64 {
    u64::from_be_bytes(b[0..8].try_into().expect("8 bytes"))
}
