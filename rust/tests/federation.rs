//! End-to-end federation transport tests: client sessions over the
//! loopback and TCP transports against a `FederatedServer`, asserting
//! the headline invariant — the federated weight digest is bit-identical
//! to the in-process trainer, serial *and* pooled — plus byte-level
//! reconciliation between measured socket traffic and the accounting /
//! netsim counters, retry-with-backoff under an injected connection
//! drop, a typed error when the retry budget is spent, and handshake
//! rejection of misconfigured clients.

use std::sync::Arc;
use std::thread;
use std::time::Duration;

use sbc::compression::registry::MethodConfig;
use sbc::coordinator::schedule::LrSchedule;
use sbc::coordinator::trainer::{TrainConfig, TrainResult, Trainer};
use sbc::coordinator::TrainBackend;
use sbc::sgd::NativeMlpBackend;
use sbc::transport::frame::{done_frame_bits, Hello, HelloAck};
use sbc::transport::loopback::LoopbackHub;
use sbc::transport::server::{FederatedResult, FederatedServer};
use sbc::transport::session::{run_client, run_federated, ClientOutcome};
use sbc::transport::tcp::{TcpAcceptor, TcpConnector};
use sbc::transport::{weight_digest, Acceptor, Connector, Transport, TransportError};

fn backend() -> NativeMlpBackend {
    NativeMlpBackend::digits_small(4, 1)
}

fn fed_cfg(method: MethodConfig, iterations: usize) -> TrainConfig {
    let mut cfg = TrainConfig::new("mlp-small", method, iterations, LrSchedule::constant(0.1));
    cfg.eval_every_rounds = 50;
    cfg.eval_batches = 2;
    cfg.transport.retry_backoff = Duration::from_millis(2);
    cfg
}

fn in_process(cfg: &TrainConfig, parallelism: usize) -> TrainResult {
    let mut cfg = cfg.clone();
    cfg.parallelism = parallelism;
    let mut be = backend();
    Trainer::new(&mut be, cfg).run()
}

fn loopback_run(cfg: &TrainConfig) -> (FederatedResult, Vec<ClientOutcome>, LoopbackHub) {
    let hub = LoopbackHub::new(&cfg.transport);
    let connectors: Vec<Box<dyn Connector>> =
        (0..cfg.clients).map(|_| Box::new(hub.connector()) as Box<dyn Connector>).collect();
    let (fed, outs) = run_federated(cfg, Arc::new(hub.clone()), connectors, |_| backend())
        .expect("federated loopback run");
    (fed, outs, hub)
}

/// The tentpole invariant, on two presets covering sparse + delayed
/// (SBC) and dense-sign + majority-vote (signSGD) dataflows: training
/// over real framed connections produces master weights bit-identical to
/// the in-process trainer (serial and pooled), with field-for-field
/// equal communication accounting, and the measured socket bytes
/// reconcile exactly with the accounted bits.
#[test]
fn loopback_matches_in_process_trainer_bit_for_bit() {
    for (method, iters) in [(MethodConfig::sbc2(), 60), (MethodConfig::signsgd(1e-3), 20)] {
        let cfg = fed_cfg(method, iters);
        let serial = in_process(&cfg, 1);
        let pooled = in_process(&cfg, 4);
        let (fed, outs, hub) = loopback_run(&cfg);
        let label = cfg.method.label();

        let want: Vec<u32> = serial.final_params.iter().map(|v| v.to_bits()).collect();
        let got: Vec<u32> = fed.final_params.iter().map(|v| v.to_bits()).collect();
        assert_eq!(got, want, "{label}");
        assert_eq!(fed.digest, weight_digest(&serial.final_params), "{label}");
        assert_eq!(fed.digest, weight_digest(&pooled.final_params), "{label}");
        assert_eq!(outs.len(), cfg.clients);
        for out in &outs {
            assert_eq!(out.digest, fed.digest, "{label}");
            assert_eq!(out.server_digest, fed.digest, "{label}");
            assert_eq!(out.retries, 0, "{label}");
        }

        // accounting parity, field for field
        assert_eq!(fed.comm.upstream_bits, serial.comm.upstream_bits, "{label}");
        assert_eq!(fed.comm.messages, serial.comm.messages, "{label}");
        assert_eq!(fed.comm.nonzeros, serial.comm.nonzeros, "{label}");
        assert_eq!(fed.comm.baseline_bits, serial.comm.baseline_bits, "{label}");
        assert_eq!(fed.comm.frame_overhead_bits, serial.comm.frame_overhead_bits, "{label}");
        assert_eq!(fed.net.total_up_bits(), serial.net.total_up_bits(), "{label}");
        for (fc, sc) in fed.net.clients.iter().zip(&serial.net.clients) {
            assert_eq!(fc.up_bits, sc.up_bits, "{label}");
            assert_eq!(fc.down_bits, sc.down_bits, "{label}");
            assert_eq!(fc.messages, sc.messages, "{label}");
        }
        let (ft, st) = (fed.net.total_comm_time_s, serial.net.total_comm_time_s);
        assert_eq!(ft.to_bits(), st.to_bits(), "{label}");

        // measured socket bytes reconcile exactly with the bit counters:
        // upstream is every framed Update (payload + frame overhead, all
        // in netsim's up bits) plus one Hello frame per session;
        // downstream is every framed Broadcast plus one HelloAck and one
        // Done per session
        let c = cfg.clients as u64;
        let up = fed.net.total_up_bits() + c * Hello::frame_bits();
        assert_eq!(hub.bytes_to_server() * 8, up, "{label}");
        let down: u64 = fed.net.clients.iter().map(|cl| cl.down_bits).sum();
        let down = down + c * (HelloAck::frame_bits() + done_frame_bits());
        assert_eq!(hub.bytes_to_clients() * 8, down, "{label}");
    }
}

/// Same invariant over real sockets: four clients against a server on an
/// ephemeral 127.0.0.1 port.
#[test]
fn tcp_four_clients_match_in_process_digest() {
    let cfg = fed_cfg(MethodConfig::sbc2(), 40);
    let serial = in_process(&cfg, 1);
    let acceptor = Arc::new(TcpAcceptor::bind("127.0.0.1:0", &cfg.transport).expect("bind"));
    let addr = acceptor.local_addr();
    let connectors: Vec<Box<dyn Connector>> = (0..cfg.clients)
        .map(|_| Box::new(TcpConnector::new(addr, &cfg.transport)) as Box<dyn Connector>)
        .collect();
    let (fed, outs) =
        run_federated(&cfg, acceptor, connectors, |_| backend()).expect("federated tcp run");
    assert_eq!(fed.digest, weight_digest(&serial.final_params));
    assert_eq!(fed.rounds, 4);
    assert_eq!(outs.iter().map(|o| o.up_bits).sum::<u64>(), serial.comm.upstream_bits);
    for out in &outs {
        assert_eq!(out.digest, fed.digest);
    }
}

/// The loopback fault hook kills client 2's third frame send (Hello,
/// Update round 0, then Update round 1 dies mid-flight): the session
/// must reconnect with backoff, re-handshake, re-send the *same* encoded
/// update, and the run must still converge to the bit-identical digest.
#[test]
fn dropped_connection_is_retried_and_stays_bit_identical() {
    let cfg = fed_cfg(MethodConfig::sbc2(), 60);
    let serial = in_process(&cfg, 1);
    let hub = LoopbackHub::new(&cfg.transport);
    let mut connectors: Vec<Box<dyn Connector>> =
        (0..cfg.clients).map(|_| Box::new(hub.connector()) as Box<dyn Connector>).collect();
    connectors[2] = Box::new(hub.faulty_connector(3));
    let (fed, outs) = run_federated(&cfg, Arc::new(hub.clone()), connectors, |_| backend())
        .expect("run recovers from the injected drop");
    assert_eq!(fed.digest, weight_digest(&serial.final_params));
    assert!(outs[2].retries >= 1, "the fault was never exercised");
    assert_eq!(outs[0].retries, 0);
    for out in &outs {
        assert_eq!(out.digest, fed.digest);
    }
}

/// A connector that never reaches a server.
struct NeverConnect;

impl Connector for NeverConnect {
    fn connect(&self) -> Result<Box<dyn Transport>, TransportError> {
        Err(TransportError::Io(std::io::Error::new(
            std::io::ErrorKind::ConnectionRefused,
            "nobody listening",
        )))
    }
}

/// When the retry budget is spent the session fails with the typed
/// `RetriesExhausted` error carrying the attempt count and last cause —
/// and the server's round loop times out instead of hanging.
#[test]
fn retry_budget_exhaustion_is_a_typed_error() {
    let mut cfg = fed_cfg(MethodConfig::sbc2(), 20);
    cfg.transport.max_retries = 2;
    cfg.transport.retry_backoff = Duration::from_millis(1);
    cfg.transport.round_timeout = Duration::from_millis(800);
    let hub = LoopbackHub::new(&cfg.transport);
    let connectors: Vec<Box<dyn Connector>> =
        (0..cfg.clients).map(|_| Box::new(NeverConnect) as Box<dyn Connector>).collect();
    let err = run_federated(&cfg, Arc::new(hub), connectors, |_| backend())
        .expect_err("no client could ever connect");
    match err {
        TransportError::RetriesExhausted { attempts, last } => {
            assert_eq!(attempts, cfg.transport.max_retries + 1);
            assert!(matches!(*last, TransportError::Io(_)), "last cause: {last}");
        }
        other => panic!("expected RetriesExhausted, got {other}"),
    }
}

/// A client whose training config digest disagrees with the server's is
/// rejected at the handshake (fatal, not retried), and the server keeps
/// its typed-timeout behavior instead of hanging on the half-empty round.
#[test]
fn misconfigured_client_is_rejected_at_handshake() {
    let mut server_cfg = fed_cfg(MethodConfig::sbc2(), 20);
    server_cfg.transport.round_timeout = Duration::from_millis(400);
    let (layout, initial) = {
        let mut probe = backend();
        let initial = probe.init_params(server_cfg.seed);
        (probe.layout().clone(), initial)
    };
    let hub = LoopbackHub::new(&server_cfg.transport);
    let acceptor: Arc<dyn Acceptor> = Arc::new(hub.clone());
    let mut server = FederatedServer::new(server_cfg.clone(), layout, initial);
    let server_thread = thread::spawn(move || server.run(acceptor));

    let mut client_cfg = server_cfg.clone();
    client_cfg.seed ^= 1; // diverging config digest
    let connector = hub.connector();
    let err =
        run_client(&client_cfg, 0, &connector, &mut backend()).expect_err("must be rejected");
    assert!(matches!(err, TransportError::Rejected(_)), "got {err}");

    let server_err = server_thread.join().expect("server thread").expect_err("no valid clients");
    assert!(matches!(server_err, TransportError::Timeout(_)), "got {server_err}");
}
