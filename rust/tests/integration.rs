//! Cross-module integration tests over the native backend: full DSGD
//! trainings with every stage composition, wire-format fidelity inside
//! the training loop, residual bookkeeping, and ablation arms. (PJRT-path
//! integration lives in `tests/pjrt.rs` and requires `make artifacts`.)

use sbc::compression::registry::MethodConfig;
use sbc::compression::{Granularity, Selection, SelectorCfg};
use sbc::coordinator::schedule::LrSchedule;
use sbc::coordinator::trainer::{TrainConfig, Trainer};
use sbc::sgd::NativeMlpBackend;

fn run_cfg(mut cfg: TrainConfig) -> sbc::coordinator::trainer::TrainResult {
    let mut be = NativeMlpBackend::digits_small(cfg.clients, cfg.seed);
    cfg.eval_every_rounds = 1000; // final point only (tests assert on it)
    cfg.eval_batches = 4;
    Trainer::new(&mut be, cfg).run()
}

fn run(method: MethodConfig, iters: usize) -> sbc::coordinator::trainer::TrainResult {
    run_cfg(TrainConfig::new("digits", method, iters, LrSchedule::constant(0.1)))
}

#[test]
fn every_method_trains_above_chance() {
    // chance = 10%; every method must clear 40% on the small digits task
    let methods = vec![
        MethodConfig::baseline(),
        MethodConfig::fedavg(10),
        MethodConfig::gradient_dropping(),
        MethodConfig::sbc1(),
        MethodConfig::sbc2(),
        MethodConfig::qsgd(4),
        MethodConfig::terngrad(),
        MethodConfig::onebit(),
        MethodConfig::signsgd(1e-3),
    ];
    for m in methods {
        let label = m.label();
        let r = run(m, 150);
        assert!(
            r.log.final_metric > 0.4,
            "{label}: accuracy {} too low",
            r.log.final_metric
        );
    }
}

#[test]
fn compression_ordering_matches_table1() {
    // measured compression must follow the theoretical ordering:
    // baseline < signSGD < GD < SBC1 < SBC2 < SBC3
    let b = run(MethodConfig::baseline(), 100).log.compression;
    let s = run(MethodConfig::signsgd(1e-3), 100).log.compression;
    let g = run(MethodConfig::gradient_dropping(), 100).log.compression;
    let s1 = run(MethodConfig::sbc1(), 100).log.compression;
    let s2 = run(MethodConfig::sbc2(), 100).log.compression;
    let s3 = run(MethodConfig::sbc3(), 200).log.compression;
    assert!(b < s && s < g && g < s1 && s1 < s2 && s2 < s3, "{b} {s} {g} {s1} {s2} {s3}");
    // magnitudes in the right ballpark (paper Table I)
    assert!((25.0..40.0).contains(&s), "signSGD {s}");
    assert!(g > 300.0, "GD {g}");
    assert!(s3 > 20_000.0, "SBC3 {s3}");
}

#[test]
fn residual_ablation_hurts_sparse_methods() {
    // without error feedback, aggressive sparsification loses information
    let mut with = MethodConfig::sbc1();
    with.residual = Some(true);
    let mut without = MethodConfig::sbc1();
    without.residual = Some(false);
    let a = run(with, 150).log.final_metric;
    let b = run(without, 150).log.final_metric;
    assert!(a >= b - 0.02, "residual on {a} vs off {b}");
}

#[test]
fn granularity_global_vs_per_tensor_both_work() {
    for g in [Granularity::Global, Granularity::PerTensor] {
        let m = MethodConfig::sbc2().with_granularity(g);
        let r = run(m, 100);
        assert!(r.log.final_metric > 0.4, "{g:?}: {}", r.log.final_metric);
    }
}

#[test]
fn selection_strategies_agree() {
    let mk = |strategy| {
        MethodConfig::builder()
            .select(SelectorCfg::TwoSided { p: 0.01, strategy })
            .quantize(sbc::compression::QuantizerCfg::BinaryMean)
            .delay(10)
            .granularity(Granularity::Global)
            .build()
    };
    let e = run(mk(Selection::Exact), 150).log.final_metric;
    let h = run(mk(Selection::Hist), 150).log.final_metric;
    let s = run(mk(Selection::Sampled(2000)), 150).log.final_metric;
    assert!((e - h).abs() < 0.15, "exact {e} vs hist {h}");
    assert!((e - s).abs() < 0.2, "exact {e} vs sampled {s}");
}

#[test]
fn delay_sweep_trades_compression_for_rounds() {
    // higher delay -> fewer messages -> more compression
    let mut last = 0.0;
    for delay in [1usize, 5, 25] {
        let m = MethodConfig::fedavg(delay.max(1));
        let r = run(m, 100);
        assert!(r.log.compression > last, "delay {delay}");
        last = r.log.compression;
    }
}

#[test]
fn curve_points_are_monotone_in_bits() {
    let mut cfg = TrainConfig::new("digits", MethodConfig::sbc2(), 200, LrSchedule::constant(0.1));
    cfg.eval_every_rounds = 2;
    let mut be = NativeMlpBackend::digits_small(4, 3);
    let r = Trainer::new(&mut be, cfg).run();
    assert!(r.log.points.len() >= 5);
    for w in r.log.points.windows(2) {
        assert!(w[1].client_up_bits > w[0].client_up_bits);
        assert!(w[1].iterations > w[0].iterations);
    }
}

#[test]
fn momentum_masking_runs_and_learns() {
    let mut m = MethodConfig::sbc2();
    m.momentum_masking = true;
    let r = run(m, 150);
    assert!(r.log.final_metric > 0.4, "{}", r.log.final_metric);
}

#[test]
fn csv_log_write() {
    let r = run(MethodConfig::sbc2(), 50);
    let path = std::env::temp_dir().join("sbc_test_log.csv");
    let path_s = path.to_string_lossy().to_string();
    let _ = std::fs::remove_file(&path);
    r.log.append_csv(&path_s).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.starts_with("model,method"));
    assert!(text.lines().count() >= 2);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn clients_scale() {
    for clients in [1usize, 2, 8] {
        let mut cfg =
            TrainConfig::new("digits", MethodConfig::sbc2(), 60, LrSchedule::constant(0.1));
        cfg.clients = clients;
        let r = run_cfg(cfg);
        assert_eq!(r.net.clients.len(), clients);
        assert!(r.log.final_metric > 0.3, "clients={clients}: {}", r.log.final_metric);
    }
}

#[test]
fn downstream_traffic_tracks_method_sparsity() {
    // the broadcast is re-encoded per round: a sparse method's union
    // support must broadcast far fewer bits than a dense method's block
    let sparse = run(MethodConfig::sbc1(), 60);
    let dense = run(MethodConfig::fedavg(2), 60);
    let per_round_sparse =
        sparse.net.clients[0].down_bits as f64 / sparse.net.clients[0].messages as f64;
    let per_round_dense =
        dense.net.clients[0].down_bits as f64 / dense.net.clients[0].messages as f64;
    assert!(
        per_round_sparse < per_round_dense / 4.0,
        "sparse {per_round_sparse} vs dense {per_round_dense}"
    );
}
