//! Durable checkpoint & crash-recovery tests (ARCHITECTURE.md §8).
//!
//! The invariant under test, end to end: a run that crashes at any
//! snapshot barrier — in-process trainer, federated server, or a client
//! session — and resumes from disk produces weight digests
//! **bit-identical** to the uninterrupted run, with `CommStats`/`NetSim`
//! accounting reconciling exactly. Damaged snapshots (every single-byte
//! truncation, every single-bit flip, config or version mismatches) must
//! always fail with a typed [`PersistError`] — never a panic or a silent
//! fresh start.
//!
//! Environment knobs (for CI matrices):
//! - `SBC_RECOVERY_SEED`: base seed for the kill/restart sweep (default 1)
//! - `SBC_RECOVERY_SWEEP`: number of schedules to sweep (default 50)

use std::path::{Path, PathBuf};
use std::time::Duration;

use sbc::compression::registry::MethodConfig;
use sbc::coordinator::schedule::LrSchedule;
use sbc::coordinator::trainer::{CheckpointCfg, TrainConfig, TrainResult, Trainer};
use sbc::persist::{
    decode_client, decode_server, encode_client, encode_server, CheckpointStore, PersistError,
};
use sbc::sgd::NativeMlpBackend;
use sbc::simnet::{
    check_run, run_schedule_with_recovery, RecoverySchedule, SimConfig, SimProfile, Verdict,
};
use sbc::transport::config_digest;

fn backend() -> NativeMlpBackend {
    NativeMlpBackend::digits_small(4, 1)
}

/// A fresh, unique checkpoint directory under the system temp dir.
fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sbc-recovery-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn train_cfg(method: MethodConfig, iterations: usize) -> TrainConfig {
    let mut cfg = TrainConfig::new("mlp-small", method, iterations, LrSchedule::constant(0.1));
    cfg.eval_every_rounds = 50;
    cfg.eval_batches = 2;
    cfg.parallelism = 1;
    cfg.transport.retry_backoff = Duration::from_millis(2);
    cfg.transport.read_timeout = Duration::from_millis(300);
    cfg.transport.round_timeout = Duration::from_millis(600);
    cfg
}

fn serial_oracle(cfg: &TrainConfig) -> TrainResult {
    let mut cfg = cfg.clone();
    cfg.checkpoint = CheckpointCfg::default();
    let mut be = backend();
    Trainer::new(&mut be, cfg).run()
}

fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// Server snapshot rounds present in a checkpoint dir, ascending.
fn server_rounds(dir: &Path) -> Vec<u32> {
    let mut rounds: Vec<u32> = std::fs::read_dir(dir)
        .unwrap()
        .filter_map(|e| {
            let name = e.unwrap().file_name().to_string_lossy().into_owned();
            let rest = name.strip_prefix("server-r")?.strip_suffix(".ckpt")?;
            rest.parse().ok()
        })
        .collect();
    rounds.sort_unstable();
    rounds
}

/// In-process trainer: checkpoint every 2 rounds, delete the newer
/// generations, resume from an *earlier* barrier — the re-run rounds are
/// deterministic, so the final weights and every accounting field must
/// be bit-identical to the uninterrupted oracle.
#[test]
fn trainer_resumes_bit_identical_from_any_barrier() {
    let dir = tmpdir("trainer-barrier");
    let mut cfg = train_cfg(MethodConfig::sbc(0.1, 4), 40); // 10 rounds
    let oracle = serial_oracle(&cfg);

    cfg.checkpoint = CheckpointCfg {
        dir: Some(dir.to_string_lossy().into_owned()),
        every_rounds: 2,
        keep: 0,
        resume: false,
    };
    let full = {
        let mut be = backend();
        Trainer::new(&mut be, cfg.clone()).run()
    };
    assert_eq!(full.final_params, oracle.final_params, "checkpointing must not change bits");

    let rounds = server_rounds(&dir);
    assert!(rounds.contains(&2) && rounds.contains(&10), "barriers every 2 rounds: {rounds:?}");
    // crash "back in time": drop every generation newer than barrier 4
    for &r in rounds.iter().filter(|&&r| r > 4) {
        std::fs::remove_file(dir.join(format!("server-r{r:08}.ckpt"))).unwrap();
        for c in 0..cfg.clients {
            std::fs::remove_file(dir.join(format!("client{c:04}-r{r:08}.ckpt"))).unwrap();
        }
    }

    cfg.checkpoint.resume = true;
    let resumed = {
        let mut be = backend();
        Trainer::new(&mut be, cfg.clone()).resume().expect("resume from barrier 4")
    };
    assert_eq!(resumed.final_params, oracle.final_params, "resume must be bit-identical");
    assert_eq!(resumed.comm.upstream_bits, oracle.comm.upstream_bits);
    assert_eq!(resumed.comm.messages, oracle.comm.messages);
    assert_eq!(resumed.comm.nonzeros, oracle.comm.nonzeros);
    assert_eq!(resumed.comm.baseline_bits, oracle.comm.baseline_bits);
    assert_eq!(resumed.comm.frame_overhead_bits, oracle.comm.frame_overhead_bits);
    assert_eq!(
        resumed.net.total_comm_time_s.to_bits(),
        oracle.net.total_comm_time_s.to_bits(),
        "virtual comm time must reconcile exactly"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Every `MethodConfig` preset: run a short checkpointed training, pick
/// a (seeded-random) barrier, and require decode→re-encode to reproduce
/// the on-disk snapshot byte-for-byte, for the server and every client.
#[test]
fn every_preset_snapshot_roundtrips_bit_identical() {
    let presets: Vec<(&str, MethodConfig)> = vec![
        ("baseline", MethodConfig::baseline()),
        ("fedavg", MethodConfig::fedavg(10)),
        ("sbc1", MethodConfig::sbc1()),
        ("sbc2", MethodConfig::sbc2()),
        ("sbc3", MethodConfig::sbc3()),
        ("signsgd", MethodConfig::signsgd(1e-3)),
        ("terngrad", MethodConfig::terngrad()),
        ("qsgd", MethodConfig::qsgd(4)),
        ("onebit", MethodConfig::onebit()),
    ];
    for (i, (name, method)) in presets.into_iter().enumerate() {
        let dir = tmpdir(&format!("roundtrip-{name}"));
        let iterations = method.delay * 3; // three rounds for every delay
        let mut cfg = train_cfg(method, iterations);
        cfg.checkpoint = CheckpointCfg {
            dir: Some(dir.to_string_lossy().into_owned()),
            every_rounds: 1,
            keep: 0,
            resume: false,
        };
        let mut be = backend();
        let _ = Trainer::new(&mut be, cfg.clone()).run();
        let digest = config_digest(&cfg);

        let rounds = server_rounds(&dir);
        assert!(!rounds.is_empty(), "{name}: no snapshots written");
        // a seeded-"random" barrier, different per preset, stable in CI
        let r = rounds[(i * 2654435761) % rounds.len()];

        let bytes = std::fs::read(dir.join(format!("server-r{r:08}.ckpt"))).unwrap();
        let snap = decode_server(&bytes, digest).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(snap.round, r);
        assert_eq!(encode_server(&snap, digest), bytes, "{name}: server snapshot not canonical");

        for c in 0..cfg.clients {
            let path = dir.join(format!("client{c:04}-r{r:08}.ckpt"));
            let bytes = std::fs::read(path).unwrap();
            let snap = decode_client(&bytes, c as u32, digest)
                .unwrap_or_else(|e| panic!("{name} client {c}: {e}"));
            assert_eq!((snap.client, snap.round), (c as u32, r));
            assert_eq!(
                encode_client(&snap, digest),
                bytes,
                "{name}: client snapshot not canonical"
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Every single-byte truncation and every single-bit flip of real
/// on-disk snapshots (server and client) must fail with a typed
/// [`PersistError`] — the CRC guards every byte, the header guards the
/// rest — and must never decode to a different snapshot.
#[test]
fn every_truncation_and_bitflip_fails_typed() {
    let dir = tmpdir("damage");
    let mut cfg = train_cfg(MethodConfig::sbc2(), 30);
    cfg.checkpoint = CheckpointCfg {
        dir: Some(dir.to_string_lossy().into_owned()),
        every_rounds: 1,
        keep: 1,
        resume: false,
    };
    let mut be = backend();
    let _ = Trainer::new(&mut be, cfg.clone()).run();
    let digest = config_digest(&cfg);
    let r = *server_rounds(&dir).last().unwrap();

    let server_bytes = std::fs::read(dir.join(format!("server-r{r:08}.ckpt"))).unwrap();
    let client_bytes = std::fs::read(dir.join(format!("client0000-r{r:08}.ckpt"))).unwrap();
    assert!(decode_server(&server_bytes, digest).is_ok());
    assert!(decode_client(&client_bytes, 0, digest).is_ok());

    for len in 0..server_bytes.len() {
        assert!(
            decode_server(&server_bytes[..len], digest).is_err(),
            "server snapshot truncated to {len} bytes must not decode"
        );
    }
    for len in 0..client_bytes.len() {
        assert!(
            decode_client(&client_bytes[..len], 0, digest).is_err(),
            "client snapshot truncated to {len} bytes must not decode"
        );
    }

    let mut buf = server_bytes.clone();
    for bit in 0..server_bytes.len() * 8 {
        buf[bit / 8] ^= 1 << (bit % 8);
        assert!(
            decode_server(&buf, digest).is_err(),
            "server snapshot with bit {bit} flipped must not decode"
        );
        buf[bit / 8] ^= 1 << (bit % 8);
    }
    let mut buf = client_bytes.clone();
    for bit in 0..client_bytes.len() * 8 {
        buf[bit / 8] ^= 1 << (bit % 8);
        assert!(
            decode_client(&buf, 0, digest).is_err(),
            "client snapshot with bit {bit} flipped must not decode"
        );
        buf[bit / 8] ^= 1 << (bit % 8);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Config mismatches fail typed at every level: the raw decoder, the
/// store, and `Trainer::resume` on a config whose training-relevant
/// fields changed since the snapshot was written.
#[test]
fn config_mismatch_fails_typed_not_silent() {
    let dir = tmpdir("mismatch");
    let mut cfg = train_cfg(MethodConfig::sbc2(), 30);
    cfg.checkpoint = CheckpointCfg {
        dir: Some(dir.to_string_lossy().into_owned()),
        every_rounds: 1,
        keep: 0,
        resume: true,
    };
    let mut be = backend();
    let _ = Trainer::new(&mut be, cfg.clone()).run();

    let digest = config_digest(&cfg);
    let store = CheckpointStore::open(dir.clone(), 0).unwrap();
    match store.load_latest_server(digest ^ 1) {
        Err(PersistError::ConfigMismatch { expected, found }) => {
            assert_eq!(expected, digest ^ 1);
            assert_eq!(found, digest);
        }
        other => panic!("expected ConfigMismatch, got {other:?}"),
    }

    let mut other = cfg.clone();
    other.seed ^= 1; // a training-relevant change
    let mut be = backend();
    match Trainer::new(&mut be, other).resume() {
        Err(PersistError::ConfigMismatch { .. }) => {}
        Err(e) => panic!("expected ConfigMismatch, got {e}"),
        Ok(_) => panic!("resume with a changed config must fail typed, not run"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Clean fabric, scheduled kills: the server killed mid-run (twice),
/// clients SIGKILLed at round boundaries, everything restarted from
/// checkpoints — each schedule must complete **bit-identical** to the
/// serial trainer with exact accounting, i.e. verdict `Completed`, not
/// merely "no violation".
#[test]
fn clean_kill_restart_schedules_complete_bit_identical() {
    let cfg = train_cfg(MethodConfig::sbc2(), 60); // 6 rounds
    let serial = serial_oracle(&cfg);

    let schedules: Vec<(&str, RecoverySchedule)> = vec![
        ("no-kills", RecoverySchedule::none()),
        ("server-mid", RecoverySchedule { server_kills: vec![3], client_kills: vec![] }),
        ("server-twice", RecoverySchedule { server_kills: vec![2, 4], client_kills: vec![] }),
        ("server-last", RecoverySchedule { server_kills: vec![5], client_kills: vec![] }),
        ("client-mid", RecoverySchedule { server_kills: vec![], client_kills: vec![(1, 3)] }),
        (
            "clients-staggered",
            RecoverySchedule {
                server_kills: vec![],
                client_kills: vec![(0, 1), (2, 3), (3, 5)],
            },
        ),
        (
            "server-and-clients",
            RecoverySchedule { server_kills: vec![3], client_kills: vec![(0, 2), (1, 4)] },
        ),
    ];
    for (name, rec) in schedules {
        let dir = tmpdir(&format!("clean-{name}"));
        let run = run_schedule_with_recovery(
            &cfg,
            &SimConfig::new(7),
            &rec,
            &dir.to_string_lossy(),
            |_| backend(),
        );
        let verdict = check_run(&serial, &run);
        assert_eq!(
            verdict,
            Verdict::Completed,
            "schedule '{name}' must recover bit-identical; failure: {:?}\ntranscript:\n{}",
            run.first_failure(),
            run.transcript
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// The recovery sweep: ≥ 50 seeded schedules mixing light/harsh fault
/// profiles *with* scheduled server and client kills. Every schedule
/// must classify as Completed (bit-exact vs the serial trainer) or
/// TypedFailure — never a Violation — and kills must demonstrably
/// recover: some schedule with kills must still complete.
#[test]
fn kill_restart_sweep_never_violates() {
    let cfg = train_cfg(MethodConfig::sbc2(), 30); // 3 rounds
    let serial = serial_oracle(&cfg);
    let base = env_u64("SBC_RECOVERY_SEED", 1);
    let count = env_u64("SBC_RECOVERY_SWEEP", 50);

    let (mut completed, mut failed) = (0u64, 0u64);
    for i in 0..count {
        let seed = base.wrapping_add(i);
        let mut sim = SimConfig::new(seed);
        sim.profile = if i % 2 == 0 { SimProfile::light() } else { SimProfile::harsh() };

        let srv_round = 1 + (seed % 2) as u32;
        let cli = (seed % 4) as usize;
        let cli_round = 1 + ((seed / 2) % 2) as u32;
        let rec = match seed % 3 {
            0 => RecoverySchedule { server_kills: vec![srv_round], client_kills: vec![] },
            1 => RecoverySchedule { server_kills: vec![], client_kills: vec![(cli, cli_round)] },
            _ => RecoverySchedule {
                server_kills: vec![srv_round],
                client_kills: vec![(cli, cli_round)],
            },
        };

        let dir = tmpdir(&format!("sweep-{seed}"));
        let run =
            run_schedule_with_recovery(&cfg, &sim, &rec, &dir.to_string_lossy(), |_| backend());
        match check_run(&serial, &run) {
            Verdict::Completed => completed += 1,
            Verdict::TypedFailure(_) => failed += 1,
            Verdict::Violation(why) => panic!(
                "seed {seed}: INVARIANT VIOLATION under kill/restart: {why}\n\
                 schedule: {rec:?}\ntranscript:\n{}",
                run.transcript
            ),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
    eprintln!(
        "recovery sweep: {count} schedules from seed {base}: \
         {completed} completed despite kills, {failed} typed failures"
    );
    // every schedule in this sweep kills something, so any completion is
    // a demonstrated crash-and-recover
    assert!(completed > 0, "no killed-and-restarted schedule recovered to completion");
}
