//! PJRT-path integration tests: require `make artifacts` (skipped with a
//! message otherwise). These exercise the production stack end to end on
//! the smallest model (mlp) plus the L1-kernel cross-validation: the
//! compiled Pallas compress graph against the bit-identical Rust mirror.

use sbc::compression::registry::MethodConfig;
use sbc::compression::{Granularity, QuantizerCfg, Selection, SelectorCfg, TensorUpdate};
use sbc::coordinator::schedule::LrSchedule;
use sbc::coordinator::trainer::{TrainConfig, Trainer};
use sbc::coordinator::TrainBackend;
use sbc::model::manifest::Manifest;
use sbc::runtime::PjrtBackend;
use sbc::util::rng::Rng;

fn manifest() -> Option<Manifest> {
    if !cfg!(feature = "pjrt") {
        eprintln!("skipping pjrt tests: built without the `pjrt` feature");
        return None;
    }
    match Manifest::load("artifacts") {
        Ok(m) => Some(m),
        Err(_) => {
            eprintln!("skipping pjrt tests: run `make artifacts` first");
            None
        }
    }
}

#[test]
fn mlp_trains_through_pjrt_with_sbc() {
    let Some(manifest) = manifest() else { return };
    let mut be = PjrtBackend::load(&manifest, "mlp", 4, 42).unwrap();
    let mut cfg =
        TrainConfig::new("mlp", MethodConfig::sbc2(), 60, LrSchedule::constant(0.1));
    cfg.eval_every_rounds = 3;
    cfg.eval_batches = 2;
    let r = Trainer::new(&mut be, cfg).run();
    let first = r.log.points.first().unwrap();
    let last = r.log.points.last().unwrap();
    assert!(last.metric > first.metric, "{} -> {}", first.metric, last.metric);
    assert!(last.metric > 0.6, "final accuracy {}", last.metric);
    assert!(r.log.compression > 1000.0, "compression {}", r.log.compression);
}

#[test]
fn pjrt_compress_graph_matches_rust_hist_mirror() {
    let Some(manifest) = manifest() else { return };
    let mut be = PjrtBackend::load(&manifest, "mlp", 1, 0).unwrap();
    let n = be.n_params();
    let mut rng = Rng::new(11);
    let delta: Vec<f32> = (0..n).map(|_| rng.normal() * rng.next_f32().powi(3)).collect();
    for p in [0.001f32, 0.01, 0.05] {
        let (dense, t, mu, side) =
            be.compress_pjrt(&delta, p).expect("compress graph missing");
        // Rust mirror of the kernel math (bit-pattern histogram selection)
        let mut mirror = MethodConfig::builder()
            .select(SelectorCfg::TwoSided { p: p as f64, strategy: Selection::Hist })
            .quantize(QuantizerCfg::BinaryMean)
            .granularity(Granularity::Global)
            .build()
            .build(0);
        let TensorUpdate::SparseBinary { idx, mu: mu_r, side_pos } =
            mirror.compress_segment(&delta)
        else {
            panic!()
        };
        assert_eq!(side, side_pos, "p={p}: side mismatch");
        // identical threshold selection -> identical support
        let kernel_idx: Vec<u32> = dense
            .iter()
            .enumerate()
            .filter(|(_, v)| **v != 0.0)
            .map(|(i, _)| i as u32)
            .collect();
        assert_eq!(kernel_idx, idx, "p={p}: support mismatch");
        // means agree to f32 reduction tolerance
        assert!(
            (mu.abs() - mu_r).abs() <= 1e-5 * mu_r.max(1.0),
            "p={p}: mu {mu} vs {mu_r}"
        );
        assert!(t > 0.0);
    }
}

#[test]
fn pjrt_init_deterministic_and_eval_sane() {
    let Some(manifest) = manifest() else { return };
    let mut be = PjrtBackend::load(&manifest, "mlp", 2, 1).unwrap();
    let a = be.init_params(5);
    let b = be.init_params(5);
    assert_eq!(a, b);
    let ev = be.evaluate(&a, 2);
    assert!(ev.loss > 1.5 && ev.loss < 3.5, "untrained CE loss {}", ev.loss);
    assert!(ev.metric < 0.35, "untrained accuracy {}", ev.metric);
}

#[test]
fn pjrt_local_steps_reduce_loss() {
    let Some(manifest) = manifest() else { return };
    let mut be = PjrtBackend::load(&manifest, "mlp", 1, 3).unwrap();
    let params = be.init_params(3);
    let mut opt = vec![0.0f32; be.opt_size()];
    let mut rng = Rng::new(4);
    let (_, l1) = be.local_steps(&params, &mut opt, 5, 0.1, 0, 0, &mut rng);
    let (w2, _) = be.local_steps(&params, &mut opt, 25, 0.1, 0, 0, &mut rng);
    let ev = be.evaluate(&w2, 2);
    assert!(ev.loss < l1, "eval {} vs first-steps loss {l1}", ev.loss);
}
