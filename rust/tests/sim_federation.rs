//! Deterministic-simulation tests for the federation stack: the real
//! `FederatedServer` and real client sessions run on a virtual clock
//! over a seeded fault-injecting fabric, sweeping many schedules of
//! drops, delays, duplicates, corruption, connection kills and
//! stragglers. The invariant checked for *every* schedule: the run
//! either completes with weights bit-identical to the serial trainer
//! and exact communication accounting, or fails with a typed error —
//! never a hang, panic, or silent divergence. Plus: byte-identical
//! replay from `(seed, config)`, exact virtual-time retry backoff, and
//! a shrinker demo that reduces an injected regression to a minimal
//! one-fault schedule.
//!
//! Environment knobs (for CI matrices):
//! - `SBC_SIM_SEED`: base seed for the sweep (default 1)
//! - `SBC_SIM_SWEEP`: number of schedules to sweep (default 100)

use std::sync::Mutex;
use std::time::Duration;

use sbc::compression::registry::MethodConfig;
use sbc::coordinator::schedule::LrSchedule;
use sbc::coordinator::trainer::{TrainConfig, TrainResult, Trainer};
use sbc::sgd::NativeMlpBackend;
use sbc::simnet::fault::render_repro;
use sbc::simnet::{
    check_run, run_schedule, shrink_schedule, Clock, Dir, FaultAction, FaultPlan, SimClock,
    SimConfig, SimProfile, Verdict, When,
};
use sbc::transport::frame::FrameKind;
use sbc::transport::session::{run_client_with_clock, BACKOFF_CAP};
use sbc::transport::{Connector, Transport, TransportError};

fn backend() -> NativeMlpBackend {
    NativeMlpBackend::digits_small(4, 1)
}

/// The sim training config: small (3 rounds of SBC), serial aggregation,
/// and *virtual* timeouts tightened so a harsh straggler pause (900 ms)
/// genuinely blows the round budget while light pauses (40 ms) recover.
fn sim_train_cfg(iterations: usize) -> TrainConfig {
    let mut cfg =
        TrainConfig::new("mlp-small", MethodConfig::sbc2(), iterations, LrSchedule::constant(0.1));
    cfg.eval_every_rounds = 50;
    cfg.eval_batches = 2;
    cfg.parallelism = 1;
    cfg.transport.retry_backoff = Duration::from_millis(2);
    cfg.transport.read_timeout = Duration::from_millis(300);
    cfg.transport.round_timeout = Duration::from_millis(600);
    cfg
}

fn serial_oracle(cfg: &TrainConfig) -> TrainResult {
    let mut cfg = cfg.clone();
    cfg.parallelism = 1;
    let mut be = backend();
    Trainer::new(&mut be, cfg).run()
}

fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// A clean schedule (no faults) must complete bit-identical to the
/// serial trainer with exact `CommStats`/`NetSim` reconciliation, while
/// consuming virtual — not wall — time.
#[test]
fn clean_schedule_is_bit_identical_to_serial_trainer() {
    let cfg = sim_train_cfg(30);
    let serial = serial_oracle(&cfg);
    let run = run_schedule(&cfg, &SimConfig::new(1), |_| backend());
    assert!(run.completed(), "clean run must complete: {:?}", run.first_failure());
    assert_eq!(check_run(&serial, &run), Verdict::Completed);
    assert!(run.applied.is_empty(), "clean profile must inject nothing");
    assert!(run.virtual_time > Duration::ZERO, "delivery must consume virtual time");
    assert!(!run.transcript.is_empty());
}

/// The tentpole sweep: ≥ 100 seeded schedules mixing the light and harsh
/// fault profiles. Every schedule must classify as Completed (digest +
/// accounting bit-exact vs the serial trainer) or TypedFailure — a
/// Violation (panic, divergence, accounting drift) fails the test with a
/// replayable repro. The sweep must also exercise every fault kind and
/// complete at least once *with* faults applied.
#[test]
fn seeded_schedule_sweep_never_violates() {
    let cfg = sim_train_cfg(30);
    let serial = serial_oracle(&cfg);
    let base = env_u64("SBC_SIM_SEED", 1);
    let count = env_u64("SBC_SIM_SWEEP", 100);

    let mut completed = 0u64;
    let mut failed = 0u64;
    let mut faulty_completed = 0u64;
    let (mut drops, mut dups, mut corrupts, mut delays, mut kills) = (0u64, 0, 0, 0, 0);

    for i in 0..count {
        let seed = base.wrapping_add(i);
        let mut sim = SimConfig::new(seed);
        sim.profile = if i % 2 == 0 { SimProfile::light() } else { SimProfile::harsh() };
        let run = run_schedule(&cfg, &sim, |_| backend());
        for f in &run.applied {
            match f.action {
                FaultAction::Drop => drops += 1,
                FaultAction::Duplicate => dups += 1,
                FaultAction::CorruptBit(_) => corrupts += 1,
                FaultAction::DelayMs(_) => delays += 1,
                FaultAction::KillConn => kills += 1,
            }
        }
        match check_run(&serial, &run) {
            Verdict::Completed => {
                completed += 1;
                if !run.applied.is_empty() {
                    faulty_completed += 1;
                }
            }
            Verdict::TypedFailure(_) => failed += 1,
            Verdict::Violation(why) => panic!(
                "seed {seed}: INVARIANT VIOLATION: {why}\n{}\ntranscript:\n{}",
                render_repro(seed, &run.applied),
                run.transcript
            ),
        }
    }

    eprintln!(
        "sim sweep: {count} schedules from seed {base}: {completed} completed \
         ({faulty_completed} despite faults), {failed} typed failures; \
         faults applied: {drops} drops, {dups} dups, {corrupts} corruptions, \
         {delays} delays, {kills} kills"
    );
    assert!(completed > 0, "no schedule completed");
    assert!(faulty_completed > 0, "no schedule completed with faults applied");
    if count >= 50 {
        assert!(failed > 0, "harsh profile never produced a typed failure");
        assert!(
            drops > 0 && dups > 0 && corrupts > 0 && delays > 0 && kills > 0,
            "sweep must exercise every fault kind \
             (drops={drops} dups={dups} corrupts={corrupts} delays={delays} kills={kills})"
        );
    }
}

/// Replay: the same `(seed, config)` produces a byte-identical event
/// transcript and the same verdict; a different seed produces a
/// different schedule.
#[test]
fn same_seed_replays_byte_identical_transcript() {
    let cfg = sim_train_cfg(30);
    let serial = serial_oracle(&cfg);
    let mut sim = SimConfig::new(11);
    sim.profile = SimProfile::harsh();

    let a = run_schedule(&cfg, &sim, |_| backend());
    let b = run_schedule(&cfg, &sim, |_| backend());
    assert!(!a.transcript.is_empty());
    assert_eq!(a.transcript, b.transcript, "same seed must replay byte-identically");
    assert_eq!(a.applied, b.applied);
    assert_eq!(a.virtual_time, b.virtual_time);
    assert_eq!(check_run(&serial, &a), check_run(&serial, &b));

    let mut other = sim.clone();
    other.seed = 12;
    let c = run_schedule(&cfg, &other, |_| backend());
    assert_ne!(a.transcript, c.transcript, "different seed must explore a different schedule");
}

/// Shrinker demo: inject a regression (a straggler pause longer than the
/// round timeout on one specific Update) buried among decoy faults, then
/// shrink the failing schedule down to the single event that matters and
/// render it as a copy-pastable repro.
#[test]
fn shrinker_reduces_injected_regression_to_one_event() {
    let cfg = sim_train_cfg(30);
    let seed = 5;
    let lethal_ms = 700; // > round_timeout (600 ms)

    let plan = FaultPlan::new()
        // decoys: all individually recoverable
        .rule(When::any().client(0).kind(FrameKind::Update).round(0), FaultAction::Duplicate)
        .rule(When::any().client(2).kind(FrameKind::Update).round(0), FaultAction::DelayMs(1))
        .rule(
            When::any().client(3).kind(FrameKind::Update).round(2).nth(1),
            FaultAction::CorruptBit(9),
        )
        // the regression under test
        .rule(
            When::any().client(1).kind(FrameKind::Update).round(1).nth(1),
            FaultAction::DelayMs(lethal_ms),
        );

    let mut sim = SimConfig::new(seed);
    sim.plan = plan;
    let run = run_schedule(&cfg, &sim, |_| backend());
    assert!(run.first_failure().is_some(), "the injected regression must fail the run");
    assert!(run.applied.len() >= 3, "decoys must fire too, got {:?}", run.applied);

    let shrunk = shrink_schedule(seed, &run.applied, |candidate| {
        let mut sim = SimConfig::new(seed);
        sim.plan = candidate.clone();
        run_schedule(&cfg, &sim, |_| backend()).first_failure().is_some()
    })
    .expect("exact replay reproduces the failure");

    assert_eq!(
        shrunk.events.len(),
        1,
        "minimal schedule should be the single lethal delay, got:\n{}",
        shrunk.repro
    );
    let ev = &shrunk.events[0];
    assert_eq!(ev.action, FaultAction::DelayMs(lethal_ms));
    assert_eq!((ev.ctx.client, ev.ctx.dir), (1, Dir::Up));
    assert_eq!(ev.ctx.kind, FrameKind::Update);
    assert!(shrunk.repro.contains("DelayMs(700)"), "repro:\n{}", shrunk.repro);
    assert!(shrunk.runs >= 2);
}

/// A connector that never reaches a server but records the virtual time
/// of every attempt.
struct RecordingConnector {
    clock: SimClock,
    attempts: Mutex<Vec<Duration>>,
}

impl Connector for RecordingConnector {
    fn connect(&self) -> Result<Box<dyn Transport>, TransportError> {
        self.attempts.lock().unwrap().push(self.clock.now());
        Err(TransportError::Io(std::io::Error::new(
            std::io::ErrorKind::ConnectionRefused,
            "nobody listening",
        )))
    }
}

/// Retry backoff timing, exactly: with backoff b, connection attempts
/// must land at virtual times 0, b, 3b, 7b (b·(2^k − 1)), and the
/// session must fail with `RetriesExhausted{attempts = max_retries + 1}`
/// at exactly b·(2^max_retries − 1) — no wall-clock sleeps involved.
#[test]
fn retry_backoff_follows_exact_virtual_schedule() {
    let mut cfg = sim_train_cfg(10);
    let b = Duration::from_millis(50);
    cfg.transport.retry_backoff = b;
    cfg.transport.max_retries = 3;

    let clock = SimClock::new();
    let _actor = clock.actor();
    let connector = RecordingConnector { clock: clock.clone(), attempts: Mutex::new(Vec::new()) };
    let err = run_client_with_clock(&cfg, 0, &connector, &mut backend(), &clock)
        .expect_err("no server to reach");
    match err {
        TransportError::RetriesExhausted { attempts, last } => {
            assert_eq!(attempts, cfg.transport.max_retries + 1);
            assert!(matches!(*last, TransportError::Io(_)), "last cause: {last}");
        }
        other => panic!("expected RetriesExhausted, got {other}"),
    }

    let times = connector.attempts.lock().unwrap().clone();
    assert_eq!(times, vec![Duration::ZERO, b, 3 * b, 7 * b]);
    assert_eq!(clock.now(), 7 * b, "failure must land at b·(2^max_retries − 1)");
}

/// A huge configured backoff must not overflow `Duration` (which would
/// panic mid-retry): every retry's wait saturates at [`BACKOFF_CAP`], so
/// connection attempts land at exact multiples of the cap.
#[test]
fn huge_retry_backoff_saturates_at_cap() {
    let mut cfg = sim_train_cfg(10);
    cfg.transport.retry_backoff = Duration::MAX;
    cfg.transport.max_retries = 3;

    let clock = SimClock::new();
    let _actor = clock.actor();
    let connector = RecordingConnector { clock: clock.clone(), attempts: Mutex::new(Vec::new()) };
    let err = run_client_with_clock(&cfg, 0, &connector, &mut backend(), &clock)
        .expect_err("no server to reach");
    assert!(
        matches!(err, TransportError::RetriesExhausted { attempts: 4, .. }),
        "expected RetriesExhausted after 4 attempts, got {err}"
    );

    let times = connector.attempts.lock().unwrap().clone();
    assert_eq!(times, vec![Duration::ZERO, BACKOFF_CAP, 2 * BACKOFF_CAP, 3 * BACKOFF_CAP]);
    assert_eq!(clock.now(), 3 * BACKOFF_CAP, "every retry must wait exactly the cap");
}
