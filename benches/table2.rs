//! Paper Table II — final accuracy/perplexity + measured compression for
//! {Baseline, Gradient Dropping, FedAvg, SBC(1), SBC(2), SBC(3)} across
//! the benchmark models, through the full PJRT stack.
//!
//! Iteration budgets are sandbox-scaled (DESIGN.md §2); multiply with
//! SBC_BENCH_SCALE for longer runs. Results are appended to
//! results/table2.csv.
//!
//!     cargo bench --bench table2
//!     SBC_BENCH_SCALE=5 SBC_TABLE2_MODELS=lenet,cifarcnn cargo bench --bench table2

use sbc::config::presets;
use sbc::coordinator::trainer::Trainer;
use sbc::metrics::render_table;
use sbc::model::manifest::Manifest;
use sbc::model::Task;
use sbc::runtime::PjrtBackend;
use sbc::util::scaled;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load("artifacts")?;
    let models: Vec<String> = std::env::var("SBC_TABLE2_MODELS")
        .unwrap_or_else(|_| "lenet,cifarcnn,charlm,wordlm".into())
        .split(',')
        .map(|s| s.to_string())
        .collect();
    // sandbox budgets (paper budgets: lenet 2000, cifar 60000, lms 16-60k);
    // delay-100 methods run at least one full round of 100 local iterations
    let budget = |m: &str| match m {
        "lenet" => scaled(120, 100),
        "cifarcnn" => scaled(100, 100),
        "charlm" => scaled(100, 100),
        "wordlm" => scaled(60, 60),
        _ => scaled(100, 100),
    };

    println!("== Table II: final metric + measured compression (PJRT stack) ==");
    println!("   budgets: {:?}\n", models.iter().map(|m| (m.as_str(), budget(m))).collect::<Vec<_>>());

    let mut rows = Vec::new();
    for model in &models {
        let spec = manifest.model(model)?;
        let is_lm = spec.task == Task::Lm;
        let iterations = budget(model);
        // compile the model's graphs once; reuse across all six methods
        let mut backend = PjrtBackend::load(&manifest, model, 4, 42)?;
        for method in presets::table2_methods() {
            let label = method.label();
            let mut cfg = presets::preset(model, method);
            cfg.iterations = iterations;
            cfg.eval_every_rounds = 1_000_000; // final eval only
            cfg.eval_batches = 4;
            let r = Trainer::new(&mut backend, cfg).run();
            eprintln!(
                "  {model:9} {label:22} metric {:8.4} compression x{:<9.0} ({:.0}s)",
                r.log.final_metric, r.log.compression, r.log.wall_s
            );
            rows.push(vec![
                model.clone(),
                label,
                if is_lm { "ppl".into() } else { "acc".into() },
                format!("{:.4}", r.log.final_metric),
                format!("x{:.0}", r.log.compression),
                format!("{:.3}", r.comm.upstream_bits as f64 / 8e6 / 4.0),
            ]);
            r.log.append_csv("results/table2.csv")?;
        }
    }
    println!(
        "\n{}",
        render_table(&["model", "method", "metric", "final", "compression", "up MB/client"], &rows)
    );
    println!("(paper shape: all methods within ~1% of baseline accuracy; compression\n ordering GD < SBC(1) < SBC(2) < SBC(3), with SBC(3) in the x10^4 band)");
    Ok(())
}
