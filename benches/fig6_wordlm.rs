//! Paper Fig. 6 (WordLSTM@PTB) and Fig. 8 (CharLSTM@Shakespeare, supp.):
//! perplexity vs iterations and vs transferred bits for all six methods,
//! through the PJRT stack. Series go to results/fig6_<model>.csv.
//!
//!     cargo bench --bench fig6_wordlm
//!     SBC_FIG6_MODEL=charlm cargo bench --bench fig6_wordlm

use sbc::config::presets;
use sbc::coordinator::trainer::Trainer;
use sbc::metrics::{render_table, RunLog};
use sbc::model::manifest::Manifest;
use sbc::runtime::PjrtBackend;
use sbc::util::scaled;

fn main() -> anyhow::Result<()> {
    let model = std::env::var("SBC_FIG6_MODEL").unwrap_or_else(|_| "wordlm".into());
    let iterations = scaled(60, 60);
    let manifest = Manifest::load("artifacts")?;

    println!("== Fig. 6/8: perplexity vs iterations and vs bits — {model} ==\n");
    let mut backend = PjrtBackend::load(&manifest, &model, 4, 42)?;
    let mut logs: Vec<RunLog> = Vec::new();
    for method in presets::table2_methods() {
        let mut cfg = presets::preset(&model, method);
        cfg.iterations = iterations;
        cfg.eval_every_rounds = (iterations / cfg.method.delay / 10).max(1);
        cfg.eval_batches = 4;
        let r = Trainer::new(&mut backend, cfg).run();
        eprintln!(
            "  {:22} final ppl {:.2} x{:.0} ({:.0}s)",
            r.log.method, r.log.final_metric, r.log.compression, r.log.wall_s
        );
        r.log.append_csv(&format!("results/fig6_{model}.csv"))?;
        logs.push(r.log);
    }

    let mut rows = Vec::new();
    for log in &logs {
        for p in &log.points {
            rows.push(vec![
                log.method.clone(),
                format!("{}", p.iterations),
                format!("{:.2}", p.metric),
                format!("{:.1}", p.client_up_bits as f64 / 8e3),
            ]);
        }
    }
    println!(
        "{}",
        render_table(&["method", "iterations", "perplexity", "client upstream KB"], &rows)
    );
    println!("wrote results/fig6_{model}.csv");
    println!("(paper shape: FedAvg/SBC(3) converge slower per iteration early on but\n all methods meet at similar perplexity; bits axis separates them by 10^3-10^4)");
    Ok(())
}
