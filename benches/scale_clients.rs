//! Round-loop scaling sweep: clients × worker threads.
//!
//! Measures full-training wall time for 1→512 simulated clients at
//! 1/4/8 pool workers, checks every pooled run is bit-identical to its
//! serial twin (a digest of the final master weights), prints a table,
//! and emits machine-readable `BENCH_scale.json` at the repo root
//! (shared schema: `sbc::metrics::bench`).
//!
//!     cargo bench --bench scale_clients
//!     SBC_SCALE_FULL=1 cargo bench --bench scale_clients   # adds 512 clients
//!
//! The acceptance bar for the pooled coordinator is ≥3x speedup at
//! 8 threads / 256 clients on an 8-core host (the sweep is
//! local-step-dominated, so the measured speedup tracks the physical
//! core count on smaller machines).

use std::time::Instant;

use sbc::compression::registry::MethodConfig;
use sbc::coordinator::schedule::LrSchedule;
use sbc::coordinator::trainer::{TrainConfig, Trainer};
use sbc::metrics::bench::{BenchArtifact, BenchRow};
use sbc::metrics::render_table;
use sbc::sgd::NativeMlpBackend;

/// FNV-1a over the bit patterns of the final weights: a stable digest
/// for cross-thread-count bit-identity checks.
fn digest(params: &[f32]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for v in params {
        for b in v.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

struct Row {
    clients: usize,
    threads: usize,
    rounds: usize,
    wall_s: f64,
    speedup: f64,
    digest: u64,
    up_bits: u64,
}

fn run_once(clients: usize, threads: usize, iterations: usize) -> (f64, usize, u64, u64) {
    let method = MethodConfig::sbc(0.01, 5);
    let mut cfg = TrainConfig::new("digits16", method, iterations, LrSchedule::constant(0.1));
    cfg.clients = clients;
    cfg.parallelism = threads;
    cfg.eval_every_rounds = 1_000_000; // final eval only
    cfg.eval_batches = 1;
    let mut backend = NativeMlpBackend::digits_small(clients, cfg.seed);
    let start = Instant::now();
    let r = Trainer::new(&mut backend, cfg.clone()).run();
    (
        start.elapsed().as_secs_f64(),
        cfg.iterations / cfg.method.delay,
        digest(&r.final_params),
        r.comm.upstream_bits,
    )
}

fn main() {
    let full = std::env::var("SBC_SCALE_FULL").is_ok();
    let mut client_counts = vec![1usize, 4, 16, 64, 256];
    if full {
        client_counts.push(512);
    }
    let thread_counts = [1usize, 4, 8];
    let iterations = 25; // 5 rounds at delay 5

    let mut rows: Vec<Row> = Vec::new();
    for &clients in &client_counts {
        let mut serial_wall = 0.0f64;
        let mut serial_digest = 0u64;
        for &threads in &thread_counts {
            let (wall_s, rounds, d, up_bits) = run_once(clients, threads, iterations);
            if threads == 1 {
                serial_wall = wall_s;
                serial_digest = d;
            } else {
                assert_eq!(
                    d, serial_digest,
                    "pooled run diverged from serial at {clients} clients / {threads} threads"
                );
            }
            rows.push(Row {
                clients,
                threads,
                rounds,
                wall_s,
                speedup: serial_wall / wall_s.max(1e-12),
                digest: d,
                up_bits,
            });
            eprintln!(
                "clients {clients:4}  threads {threads}  wall {wall_s:8.3}s  x{:.2}",
                serial_wall / wall_s.max(1e-12)
            );
        }
    }

    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                format!("{}", r.clients),
                format!("{}", r.threads),
                format!("{}", r.rounds),
                format!("{:.3}", r.wall_s),
                format!("x{:.2}", r.speedup),
                format!("{:016x}", r.digest),
            ]
        })
        .collect();
    println!(
        "{}",
        render_table(
            &["clients", "threads", "rounds", "wall s", "speedup", "weights digest"],
            &table
        )
    );
    println!("(digest column: identical per clients row == pooled rounds are bit-identical)");

    let mut art = BenchArtifact::new(
        "scale",
        format!("sbc(p=0.01,n=5), {iterations} iterations, clients x threads sweep"),
    );
    for r in &rows {
        art.push(
            BenchRow::new(
                format!("{} clients / {} threads", r.clients, r.threads),
                (r.wall_s * 1e9) as u64,
                r.up_bits,
                r.digest,
            )
            .field("clients", r.clients.to_string())
            .field("threads", r.threads.to_string())
            .field("rounds", r.rounds.to_string())
            .field("speedup_vs_serial", format!("{:.4}", r.speedup)),
        );
    }
    let path = art.write().expect("write bench artifact");
    println!("wrote {} ({} configs)", path.display(), rows.len());
}
