//! Codec microbenchmarks: Golomb encode/decode throughput, eq.-5 analytic
//! vs measured bits/position across sparsity levels, and the L3 perf
//! target (DESIGN.md §8: >= 100 Mbit/s Golomb encode on one core).
//!
//!     cargo bench --bench codec_micro

use std::time::Instant;

use sbc::codec::bitio::{BitReader, BitWriter};
use sbc::codec::golomb;
use sbc::metrics::render_table;
use sbc::util::rng::Rng;

fn random_positions(n: usize, p: f64, seed: u64) -> Vec<u32> {
    let mut rng = Rng::new(seed);
    (0..n).filter(|_| rng.next_f64() < p).map(|i| i as u32).collect()
}

fn main() {
    println!("== Golomb codec: eq. 5 analytic vs measured ==\n");
    let n = 4_000_000;
    let mut rows = Vec::new();
    for &p in &[0.0005, 0.001, 0.005, 0.01, 0.05] {
        let positions = random_positions(n, p, 17);
        let b = golomb::optimal_b(p);
        let mut w = BitWriter::with_capacity(n / 64);
        golomb::encode_positions(&mut w, &positions, b);
        let (bytes, bits) = w.finish();
        let measured = bits as f64 / positions.len() as f64;
        let analytic = golomb::expected_bits_per_position(p);

        // throughput
        let t0 = Instant::now();
        let reps = 5;
        for _ in 0..reps {
            let mut w = BitWriter::with_capacity(n / 64);
            golomb::encode_positions(&mut w, &positions, b);
            std::hint::black_box(&w);
        }
        let enc_s = t0.elapsed().as_secs_f64() / reps as f64;
        let t0 = Instant::now();
        for _ in 0..reps {
            let mut r = BitReader::new(&bytes, bits);
            let got = golomb::decode_positions(&mut r, positions.len(), b).unwrap();
            std::hint::black_box(&got);
        }
        let dec_s = t0.elapsed().as_secs_f64() / reps as f64;
        rows.push(vec![
            format!("{p}"),
            format!("{b}"),
            format!("{analytic:.2}"),
            format!("{measured:.2}"),
            format!("{:.0}", bits as f64 / enc_s / 1e6),
            format!("{:.0}", bits as f64 / dec_s / 1e6),
        ]);
    }
    println!(
        "{}",
        render_table(
            &["p", "b*", "bits/pos eq.5", "measured", "enc Mbit/s", "dec Mbit/s"],
            &rows
        )
    );
    println!("(L3 perf target: encode >= 100 Mbit/s single-core — DESIGN.md §8)");

    println!("\n== top-k selection strategies (1M elements, k = 10k) ==\n");
    let mut rng = Rng::new(5);
    let x: Vec<f32> = (0..1_000_000).map(|_| rng.normal() * rng.next_f32().powi(4)).collect();
    let k = 10_000;
    let mut rows = Vec::new();
    let time_it = |f: &mut dyn FnMut() -> usize| {
        let t0 = Instant::now();
        let mut kept = 0;
        for _ in 0..3 {
            kept = f();
        }
        (t0.elapsed().as_secs_f64() / 3.0 * 1e3, kept)
    };
    let (t_exact, k_exact) = time_it(&mut || sbc::compression::topk::topk_exact(&x, k).len());
    let (t_hist, k_hist) = time_it(&mut || {
        let (tp, tn, _) = sbc::compression::topk::hist_thresholds(&x, k as u32);
        x.iter().filter(|&&v| (v > 0.0 && v >= tp) || (v < 0.0 && -v >= tn)).count()
    });
    let mut srng = Rng::new(6);
    let (t_samp, k_samp) =
        time_it(&mut || sbc::compression::topk::topk_sampled(&x, k, 10_000, &mut srng).len());
    rows.push(vec!["exact quickselect".into(), format!("{t_exact:.1}"), format!("{k_exact}")]);
    rows.push(vec!["bit-pattern hist".into(), format!("{t_hist:.1}"), format!("{k_hist}")]);
    rows.push(vec!["sampled (DGC)".into(), format!("{t_samp:.1}"), format!("{k_samp}")]);
    println!("{}", render_table(&["strategy", "ms", "kept"], &rows));
}
