//! Ablations over the design choices DESIGN.md §7 calls out:
//!   1. position codec: Golomb vs fixed-16 vs Elias-gamma (wire bits)
//!   2. binarization on/off: SBC vs top-p + 32-bit values (accuracy+bits)
//!   3. residual accumulation on/off
//!   4. momentum masking on/off
//!   5. per-tensor vs global granularity
//!   6. top-k selection: exact vs histogram vs sampled
//!
//!     cargo bench --bench ablations

use sbc::codec::message::{self, PosCodec};
use sbc::compression::registry::MethodConfig;
use sbc::compression::{Granularity, QuantizerCfg, Selection, SelectorCfg};
use sbc::coordinator::schedule::LrSchedule;
use sbc::coordinator::trainer::{TrainConfig, Trainer};
use sbc::metrics::render_table;
use sbc::model::TensorLayout;
use sbc::sgd::NativeMlpBackend;
use sbc::util::rng::Rng;
use sbc::util::scaled;

fn run(method: MethodConfig, iterations: usize, codec: PosCodec) -> (f32, f64) {
    let mut cfg = TrainConfig::new(
        "digits16",
        method,
        iterations,
        LrSchedule::step(0.1, 0.1, vec![iterations / 2]),
    );
    cfg.pos_codec = codec;
    cfg.eval_every_rounds = 1_000_000;
    cfg.eval_batches = 8;
    let mut backend = NativeMlpBackend::digits_small(cfg.clients, cfg.seed);
    let r = Trainer::new(&mut backend, cfg).run();
    (r.log.final_metric, r.log.compression)
}

fn main() {
    let iterations = scaled(300, 200);
    println!("== Ablations (native backend, {iterations} iterations) ==\n");

    // 1. position codec on a fixed synthetic update -------------------------
    println!("-- 1. position codec (1M params, p = 1%) --");
    let n = 1_000_000;
    let mut rng = Rng::new(3);
    let delta: Vec<f32> = (0..n).map(|_| rng.normal() * rng.next_f32().powi(4)).collect();
    let mut sbc = MethodConfig::sbc2().build(0);
    let msg = sbc.compress(&delta, &TensorLayout::flat(n), 0);
    let mut rows = Vec::new();
    let golomb_bits = message::encode(&msg, PosCodec::Golomb).1;
    for codec in [PosCodec::Golomb, PosCodec::Fixed16, PosCodec::Elias] {
        let (_, bits) = message::encode(&msg, codec);
        rows.push(vec![
            format!("{codec:?}"),
            format!("{}", bits / 8 / 1024),
            format!("x{:.2}", bits as f64 / golomb_bits as f64),
        ]);
    }
    println!("{}", render_table(&["pos codec", "message KiB", "vs golomb"], &rows));

    // 2-6: training ablations ----------------------------------------------
    let mut rows = Vec::new();
    let mut add = |name: &str, m: MethodConfig, codec: PosCodec| {
        let label = m.label();
        let (acc, comp) = run(m, iterations, codec);
        rows.push(vec![
            name.to_string(),
            label,
            format!("{acc:.3}"),
            format!("x{comp:.0}"),
        ]);
    };

    // binarization: SBC(1) vs GradientDropping at the same p
    add("binarize ON (SBC)", MethodConfig::sbc1(), PosCodec::Golomb);
    add("binarize OFF (top-p f32)", MethodConfig::gradient_dropping(), PosCodec::Golomb);

    // residual
    let mut m = MethodConfig::sbc1();
    m.residual = Some(true);
    add("residual ON", m, PosCodec::Golomb);
    let mut m = MethodConfig::sbc1();
    m.residual = Some(false);
    add("residual OFF", m, PosCodec::Golomb);

    // momentum masking
    let mut m = MethodConfig::sbc2();
    m.momentum_masking = true;
    add("momentum mask ON", m, PosCodec::Golomb);
    add("momentum mask OFF", MethodConfig::sbc2(), PosCodec::Golomb);

    // granularity
    let mut m = MethodConfig::sbc2();
    m.granularity = Granularity::PerTensor;
    add("per-tensor", m, PosCodec::Golomb);
    let mut m = MethodConfig::sbc2();
    m.granularity = Granularity::Global;
    add("global", m, PosCodec::Golomb);

    // selection strategy
    for (name, strategy) in [
        ("select exact", Selection::Exact),
        ("select hist", Selection::Hist),
        ("select sampled-2k", Selection::Sampled(2000)),
    ] {
        let m = MethodConfig::builder()
            .select(SelectorCfg::TwoSided { p: 0.01, strategy })
            .quantize(QuantizerCfg::BinaryMean)
            .delay(10)
            .build();
        add(name, m, PosCodec::Golomb);
    }

    // pos codec, end to end
    add("golomb wire", MethodConfig::sbc2(), PosCodec::Golomb);
    add("fixed16 wire", MethodConfig::sbc2(), PosCodec::Fixed16);
    add("elias wire", MethodConfig::sbc2(), PosCodec::Elias);

    println!("\n-- 2-6. training ablations --");
    println!("{}", render_table(&["arm", "method", "accuracy", "compression"], &rows));
    println!("(expected: binarization costs ~nothing in accuracy and wins ~x4 bits;\n residual OFF hurts; golomb beats fixed16 by ~x1.5-2 on positions)");
}
