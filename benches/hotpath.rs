//! L3 hot-path profile: per-stage cost of one coordinator round at
//! paper-scale parameter counts (compress -> encode -> decode -> densify
//! -> aggregate), plus heap-allocation accounting for the full
//! client-round (the numbers behind EXPERIMENTS.md §Perf and the
//! zero-alloc scratch-buffer claim).
//!
//!     cargo bench --bench hotpath

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use sbc::codec::message::{self, PosCodec, WireCodec};
use sbc::compression::registry::MethodConfig;
use sbc::compression::UpdateMsg;
use sbc::coordinator::aggregation::{aggregate_into, AggRule};
use sbc::metrics::render_table;
use sbc::model::TensorLayout;
use sbc::util::rng::Rng;

/// Counting allocator: tracks bytes and call counts so the bench can
/// report allocations per client-round for the legacy allocating path vs
/// the scratch-buffer path.
struct CountingAlloc;

static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);
static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn counters() -> (u64, u64) {
    (ALLOC_BYTES.load(Ordering::Relaxed), ALLOC_CALLS.load(Ordering::Relaxed))
}

/// Run `f` and return (bytes allocated, allocation calls).
fn count_allocs(mut f: impl FnMut()) -> (u64, u64) {
    let (b0, c0) = counters();
    f();
    let (b1, c1) = counters();
    (b1 - b0, c1 - c0)
}

fn stage_timings() {
    println!("== coordinator hot path: per-stage cost per client round ==\n");
    let mut rows = Vec::new();
    for &n in &[266_610usize, 1_304_552, 9_968_000] {
        let mut rng = Rng::new(9);
        let delta: Vec<f32> = (0..n).map(|_| rng.normal() * rng.next_f32().powi(4)).collect();
        let layout = TensorLayout::flat(n);
        let mut pipeline = MethodConfig::sbc2().build(0);
        let mut wire = WireCodec::new(PosCodec::Golomb);
        let mut msg = UpdateMsg::scratch();
        let mut decoded = UpdateMsg::scratch();
        let mut dense = vec![0.0f32; n];
        let mut agg = vec![0.0f32; n];

        let reps = if n > 5_000_000 { 3 } else { 10 };
        let time = |f: &mut dyn FnMut()| {
            let t0 = Instant::now();
            for _ in 0..reps {
                f();
            }
            t0.elapsed().as_secs_f64() / reps as f64 * 1e3
        };

        let t_compress = time(&mut || {
            pipeline.compress_into(&delta, &layout, 0, &mut msg);
        });
        let mut bits = 0u64;
        let t_encode = time(&mut || {
            bits = wire.encode(&msg).1;
        });
        let bytes = wire.encode(&msg).0.to_vec();
        let t_decode = time(&mut || {
            message::decode_into(&bytes, bits, &mut decoded).unwrap();
        });
        let t_densify = time(&mut || {
            decoded.densify_into(
                &layout,
                sbc::compression::Granularity::Global,
                1.0,
                &mut dense,
            );
        });
        let updates = [dense.as_slice(), dense.as_slice(), dense.as_slice(), dense.as_slice()];
        let t_agg = time(&mut || {
            aggregate_into(updates.iter().copied(), AggRule::Mean, &mut agg);
            std::hint::black_box(&agg);
        });

        rows.push(vec![
            format!("{:.1}M", n as f64 / 1e6),
            format!("{t_compress:.2}"),
            format!("{t_encode:.2}"),
            format!("{t_decode:.2}"),
            format!("{t_densify:.2}"),
            format!("{t_agg:.2}"),
            format!("{:.2}", t_compress + t_encode + t_decode + t_densify + t_agg / 4.0),
        ]);
    }
    println!(
        "{}",
        render_table(
            &[
                "params",
                "compress ms",
                "encode ms",
                "decode ms",
                "densify ms",
                "agg(4) ms",
                "total/client ms"
            ],
            &rows
        )
    );
    println!(
        "\n(target: coordinator overhead < 10% of a training step — steps run\n \
         100-1000 ms at these scales on this host, so total/client must stay <~20 ms)"
    );
}

/// Compress -> encode -> decode -> densify, allocating path vs the
/// scratch-buffer path, measured in bytes allocated per client-round.
fn alloc_accounting() {
    println!("\n== allocation per client-round: legacy allocating vs scratch path ==\n");
    let n = 1_304_552usize;
    let mut rng = Rng::new(9);
    let delta: Vec<f32> = (0..n).map(|_| rng.normal() * rng.next_f32().powi(4)).collect();
    let layout = TensorLayout::flat(n);
    let rounds = 10u64;

    // legacy path: every stage allocates fresh buffers
    let mut legacy_pipeline = MethodConfig::sbc2().build(0);
    let (legacy_bytes, legacy_calls) = count_allocs(|| {
        for round in 0..rounds {
            let msg = legacy_pipeline.compress(&delta, &layout, round as u32);
            let (bytes, bits) = message::encode(&msg, PosCodec::Golomb);
            let decoded = message::decode(&bytes, bits).unwrap();
            let dense = decoded.to_dense(&layout, 1.0);
            std::hint::black_box(&dense);
        }
    });

    // scratch path: one warm-up round populates the buffers, then
    // steady-state rounds reuse them
    let mut pipeline = MethodConfig::sbc2().build(0);
    let mut wire = WireCodec::new(PosCodec::Golomb);
    let mut msg = UpdateMsg::scratch();
    let mut decoded = UpdateMsg::scratch();
    let mut dense = vec![0.0f32; n];
    let mut one_round = |round: u32| {
        pipeline.compress_into(&delta, &layout, round, &mut msg);
        let (bytes, bits) = wire.encode(&msg);
        message::decode_into(bytes, bits, &mut decoded).unwrap();
        decoded.densify_into(&layout, sbc::compression::Granularity::Global, 1.0, &mut dense);
        std::hint::black_box(&dense);
    };
    one_round(0); // warm up scratch capacity
    let (scratch_bytes, scratch_calls) = count_allocs(|| {
        for round in 1..=rounds {
            one_round(round as u32);
        }
    });

    // same steady-state loop with disabled tracing: the NullRecorder
    // must be inert — emit takes the event as a closure, so the String
    // the Stage event would allocate is never constructed
    let trace = sbc::trace::Trace::disabled();
    let clock = sbc::simnet::clock::RealClock::new();
    let (traced_bytes, traced_calls) = count_allocs(|| {
        for round in 1..=rounds {
            one_round(round as u32);
            trace.emit(&clock, || sbc::trace::Event::Stage {
                round: round as u32,
                client: 0,
                stage: "compress".to_string(),
                nanos: 0,
            });
        }
    });

    // densification alone — the acceptance-criterion stage — must be
    // allocation-free in steady state
    let (densify_bytes, _) = count_allocs(|| {
        for _ in 0..rounds {
            decoded.densify_into(&layout, sbc::compression::Granularity::Global, 1.0, &mut dense);
            std::hint::black_box(&dense);
        }
    });

    let rows = vec![
        vec![
            "legacy (compress/encode/decode/to_dense)".to_string(),
            format!("{}", legacy_bytes / rounds),
            format!("{:.1}", legacy_calls as f64 / rounds as f64),
        ],
        vec![
            "scratch (compress_into/decode_into/densify_into)".to_string(),
            format!("{}", scratch_bytes / rounds),
            format!("{:.1}", scratch_calls as f64 / rounds as f64),
        ],
        vec![
            "scratch + disabled trace (NullRecorder)".to_string(),
            format!("{}", traced_bytes / rounds),
            format!("{:.1}", traced_calls as f64 / rounds as f64),
        ],
        vec![
            "densify_into alone".to_string(),
            format!("{}", densify_bytes / rounds),
            "0.0".to_string(),
        ],
    ];
    println!("{}", render_table(&["path", "bytes/round", "allocs/round"], &rows));

    assert_eq!(
        densify_bytes, 0,
        "residual densification must be allocation-free in steady state"
    );
    assert_eq!(
        scratch_bytes, 0,
        "scratch round (compress_into -> encode -> decode_into -> densify_into) \
         must be allocation-free in steady state"
    );
    assert_eq!(
        traced_bytes, 0,
        "disabled tracing must add zero steady-state allocations to the hot path"
    );
    println!("\n(scratch path steady state: 0 bytes/round — the residual-densify\n hot loop never touches the heap; legacy reallocated every stage)");
}

fn main() {
    stage_timings();
    alloc_accounting();
}
