//! L3 hot-path profile: per-stage cost of one coordinator round at
//! paper-scale parameter counts (compress -> encode -> decode -> densify
//! -> aggregate), the numbers behind EXPERIMENTS.md §Perf.
//!
//!     cargo bench --bench hotpath

use std::time::Instant;

use sbc::codec::message::{self, PosCodec};
use sbc::compression::registry::MethodConfig;
use sbc::coordinator::aggregation::{aggregate, AggRule};
use sbc::metrics::render_table;
use sbc::model::TensorLayout;
use sbc::util::rng::Rng;

fn main() {
    println!("== coordinator hot path: per-stage cost per client round ==\n");
    let mut rows = Vec::new();
    for &n in &[266_610usize, 1_304_552, 9_968_000] {
        let mut rng = Rng::new(9);
        let delta: Vec<f32> = (0..n).map(|_| rng.normal() * rng.next_f32().powi(4)).collect();
        let layout = TensorLayout::flat(n);
        let mut compressor = MethodConfig::sbc2().build(0);

        let reps = if n > 5_000_000 { 3 } else { 10 };
        let time = |f: &mut dyn FnMut()| {
            let t0 = Instant::now();
            for _ in 0..reps {
                f();
            }
            t0.elapsed().as_secs_f64() / reps as f64 * 1e3
        };

        let mut msg = None;
        let t_compress = time(&mut || {
            msg = Some(compressor.compress(&delta, &layout, 0));
        });
        let msg = msg.unwrap();
        let mut enc = None;
        let t_encode = time(&mut || {
            enc = Some(message::encode(&msg, PosCodec::Golomb));
        });
        let (bytes, bits) = enc.unwrap();
        let mut dec = None;
        let t_decode = time(&mut || {
            dec = Some(message::decode(&bytes, bits).unwrap());
        });
        let decoded = dec.unwrap();
        let mut dense = None;
        let t_densify = time(&mut || {
            dense = Some(decoded.to_dense(&layout, 1.0));
        });
        let d = dense.unwrap();
        let updates = vec![d.clone(), d.clone(), d.clone(), d];
        let t_agg = time(&mut || {
            std::hint::black_box(aggregate(&updates, AggRule::Mean));
        });

        rows.push(vec![
            format!("{:.1}M", n as f64 / 1e6),
            format!("{t_compress:.2}"),
            format!("{t_encode:.2}"),
            format!("{t_decode:.2}"),
            format!("{t_densify:.2}"),
            format!("{t_agg:.2}"),
            format!("{:.2}", t_compress + t_encode + t_decode + t_densify + t_agg / 4.0),
        ]);
    }
    println!(
        "{}",
        render_table(
            &["params", "compress ms", "encode ms", "decode ms", "densify ms", "agg(4) ms", "total/client ms"],
            &rows
        )
    );
    println!("\n(target: coordinator overhead < 10% of a training step — steps run\n 100-1000 ms at these scales on this host, so total/client must stay <~20 ms)");
}
