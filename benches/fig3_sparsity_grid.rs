//! Paper Fig. 3 (and Fig. 9's grid): validation error over the 2-D space
//! of temporal sparsity (communication delay n) × gradient sparsity (p),
//! at a fixed iteration budget. The paper's observation: error is roughly
//! constant along the off-diagonals (constant total sparsity n/p product),
//! forming a triangular feasible region.
//!
//! Runs on the native backend (hundreds of full trainings).
//!
//!     cargo bench --bench fig3_sparsity_grid
//!     env: SBC_BENCH_SCALE, SBC_FIG3_SEEDS (default 2)

use sbc::compression::registry::MethodConfig;
use sbc::coordinator::schedule::LrSchedule;
use sbc::coordinator::trainer::{TrainConfig, Trainer};
use sbc::sgd::NativeMlpBackend;
use sbc::util::scaled;
use std::fmt::Write as _;

fn main() {
    let delays = [1usize, 3, 10, 30, 100];
    let ps = [1.0f64, 0.1, 0.01, 0.001, 0.0003];
    let iterations = scaled(300, 200);
    let seeds: u64 =
        std::env::var("SBC_FIG3_SEEDS").ok().and_then(|s| s.parse().ok()).unwrap_or(2);

    println!("== Fig. 3: error over temporal (rows) x gradient (cols) sparsity ==");
    println!("   iterations {iterations}, {seeds} seeds, native digits backend\n");

    let mut csv = String::from("delay,p,total_sparsity,error\n");
    println!(
        "{:>8} | {}",
        "delay\\p",
        ps.iter().map(|p| format!("{:>8}", p)).collect::<Vec<_>>().join(" ")
    );
    println!("{}", "-".repeat(10 + ps.len() * 9));
    for &delay in &delays {
        let mut cells = Vec::new();
        for &p in &ps {
            let mut err_sum = 0.0f64;
            for seed in 0..seeds {
                let mc = if p >= 1.0 {
                    MethodConfig::fedavg(delay)
                } else {
                    MethodConfig::sbc(p, delay)
                };
                let mut cfg = TrainConfig::new(
                    "digits16",
                    mc,
                    iterations,
                    LrSchedule::step(0.1, 0.1, vec![iterations / 2]),
                );
                cfg.seed = 42 + seed;
                cfg.eval_every_rounds = 1_000_000;
                cfg.eval_batches = 8;
                let mut backend = NativeMlpBackend::digits_small(cfg.clients, cfg.seed);
                let r = Trainer::new(&mut backend, cfg).run();
                err_sum += 1.0 - r.log.final_metric as f64;
            }
            let err = err_sum / seeds as f64;
            let _ = writeln!(csv, "{delay},{p},{},{err:.4}", p / delay as f64);
            cells.push(format!("{:>8.3}", err));
        }
        println!("{:>8} | {}", delay, cells.join(" "));
    }
    std::fs::create_dir_all("results").unwrap();
    std::fs::write("results/fig3_grid.csv", csv).unwrap();
    println!("\nwrote results/fig3_grid.csv");
    println!("(paper shape: near-constant error along off-diagonals; the top-left\n triangle — low total sparsity — is the feasible region)");
}
