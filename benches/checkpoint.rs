//! Checkpoint snapshot I/O latency vs model size.
//!
//! Measures the durable-write (encode + atomic write-rename + fsync)
//! and load (read + CRC + decode) latency of server and client
//! snapshots across model sizes, prints a table, and emits
//! `BENCH_checkpoint.json` at the repo root (shared schema:
//! `sbc::metrics::bench`).
//!
//!     cargo bench --bench checkpoint

use std::time::Instant;

use sbc::metrics::bench::{BenchArtifact, BenchRow};
use sbc::metrics::render_table;
use sbc::persist::{CheckpointStore, ClientSnapshot, ServerSnapshot};
use sbc::transport::weight_digest;

const DIGEST: u64 = 0xbe5c_0f1e_5bc0_ffee;

fn synth_weights(n: usize) -> Vec<f32> {
    (0..n).map(|i| ((i as f32) * 0.001).sin()).collect()
}

fn server_snap(n: usize, clients: usize) -> ServerSnapshot {
    ServerSnapshot {
        round: 7,
        master: synth_weights(n),
        comm: [1, 2, 3, 4, 5],
        net_clients: (0..clients as u64).map(|c| (c, c + 1, c + 2, c + 3, c + 4)).collect(),
        net_total_time_bits: 0f64.to_bits(),
        ledger: vec![6; clients],
        cache: None,
    }
}

fn client_snap(n: usize) -> ClientSnapshot {
    ClientSnapshot {
        client: 0,
        round: 7,
        weights: synth_weights(n),
        opt: synth_weights(n),
        residual: synth_weights(n),
        residual_enabled: true,
        iterations: 70,
        up_bits: 12_345,
        rng: [1, 2, 3, 4],
        selector_rng: [5, 6, 7, 8],
        quantizer_rng: [9, 10, 11, 12],
    }
}

fn main() {
    let dir = std::env::temp_dir().join(format!("sbc-bench-checkpoint-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = CheckpointStore::open(dir.clone(), 1).expect("open store");

    let sizes = [1_000usize, 10_000, 100_000, 1_000_000];
    let clients = 8;
    let mut art = BenchArtifact::new(
        "checkpoint",
        format!("snapshot save/load latency, {clients} clients, sizes {sizes:?}"),
    );
    let mut table: Vec<Vec<String>> = Vec::new();

    for &n in &sizes {
        let reps = (2_000_000 / n).clamp(3, 200) as u32;
        let digest = weight_digest(&synth_weights(n));

        let snap = server_snap(n, clients);
        let start = Instant::now();
        for _ in 0..reps {
            store.save_server(&snap, DIGEST).expect("save server snapshot");
        }
        let save_ns = (start.elapsed().as_nanos() / reps as u128) as u64;
        let bits = 8 * std::fs::metadata(dir.join("server-r00000007.ckpt")).unwrap().len();
        let start = Instant::now();
        for _ in 0..reps {
            let loaded = store.load_latest_server(DIGEST).expect("load").expect("snapshot");
            assert_eq!(loaded.master.len(), n);
        }
        let load_ns = (start.elapsed().as_nanos() / reps as u128) as u64;
        art.push(
            BenchRow::new(format!("server n={n} save"), save_ns, bits, digest)
                .field("n_params", n.to_string()),
        );
        art.push(
            BenchRow::new(format!("server n={n} load"), load_ns, bits, digest)
                .field("n_params", n.to_string()),
        );
        table.push(vec![
            "server".into(),
            format!("{n}"),
            format!("{}", bits / 8),
            format!("{:.3}", save_ns as f64 / 1e6),
            format!("{:.3}", load_ns as f64 / 1e6),
        ]);

        let snap = client_snap(n);
        let start = Instant::now();
        for _ in 0..reps {
            store.save_client(&snap, DIGEST).expect("save client snapshot");
        }
        let save_ns = (start.elapsed().as_nanos() / reps as u128) as u64;
        let bits = 8 * std::fs::metadata(dir.join("client0000-r00000007.ckpt")).unwrap().len();
        let start = Instant::now();
        for _ in 0..reps {
            let loaded = store.load_latest_client(0, DIGEST).expect("load").expect("snapshot");
            assert_eq!(loaded.weights.len(), n);
        }
        let load_ns = (start.elapsed().as_nanos() / reps as u128) as u64;
        art.push(
            BenchRow::new(format!("client n={n} save"), save_ns, bits, digest)
                .field("n_params", n.to_string()),
        );
        art.push(
            BenchRow::new(format!("client n={n} load"), load_ns, bits, digest)
                .field("n_params", n.to_string()),
        );
        table.push(vec![
            "client".into(),
            format!("{n}"),
            format!("{}", bits / 8),
            format!("{:.3}", save_ns as f64 / 1e6),
            format!("{:.3}", load_ns as f64 / 1e6),
        ]);
    }

    println!(
        "{}",
        render_table(&["role", "params", "snapshot bytes", "save ms", "load ms"], &table)
    );
    let path = art.write().expect("write bench artifact");
    println!("wrote {}", path.display());
    let _ = std::fs::remove_dir_all(&dir);
}
