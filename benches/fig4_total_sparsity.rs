//! Paper Fig. 4 — classification error at different levels of *total*
//! sparsity (= temporal × gradient) and different training stages. Purely
//! temporal (FedAvg-style), purely gradient (GD-style with binarization)
//! and the balanced hybrid are compared at equal total sparsity.
//!
//! Paper shape: early in training (high LR) temporal sparsification wins;
//! after LR decay gradient sparsification wins.
//!
//!     cargo bench --bench fig4_total_sparsity

use sbc::compression::registry::MethodConfig;
use sbc::coordinator::schedule::LrSchedule;
use sbc::coordinator::trainer::{TrainConfig, Trainer};
use sbc::metrics::render_table;
use sbc::sgd::NativeMlpBackend;
use sbc::util::scaled;
use std::fmt::Write as _;

fn run_curve(method: MethodConfig, iterations: usize, seed: u64) -> Vec<(usize, f32)> {
    let mut cfg = TrainConfig::new(
        "digits16",
        method,
        iterations,
        LrSchedule::step(0.1, 0.1, vec![iterations / 2]),
    );
    cfg.seed = seed;
    cfg.eval_every_rounds = 1;
    cfg.eval_batches = 8;
    let mut backend = NativeMlpBackend::digits_small(cfg.clients, cfg.seed);
    let r = Trainer::new(&mut backend, cfg).run();
    r.log.points.iter().map(|p| (p.iterations, 1.0 - p.metric)).collect()
}

fn error_at(curve: &[(usize, f32)], iter: usize) -> f32 {
    curve
        .iter()
        .filter(|(i, _)| *i <= iter)
        .last()
        .or_else(|| curve.first())
        .map(|(_, e)| *e)
        .unwrap_or(1.0)
}

fn main() {
    let iterations = scaled(300, 200);
    let stages = [iterations / 4, iterations / 2, iterations];
    // total sparsity levels: 1/16, 1/64, 1/256
    let levels: &[(usize, f64)] = &[(16, 1.0 / 16.0), (64, 1.0 / 64.0), (256, 1.0 / 256.0)];

    println!("== Fig. 4: error vs total sparsity at different training stages ==");
    println!("   iterations {iterations}, LR decay x0.1 at {}\n", iterations / 2);

    let mut rows = Vec::new();
    let mut csv = String::from("total_sparsity,kind,stage_iters,error\n");
    for &(k, total) in levels {
        // purely temporal: delay k, dense
        let temporal = run_curve(MethodConfig::fedavg(k), iterations, 42);
        // purely gradient: delay 1, p = 1/k (SBC binarized)
        let gradient = run_curve(MethodConfig::sbc(total, 1), iterations, 42);
        // hybrid: delay sqrt(k), p = 1/sqrt(k)
        let h = (k as f64).sqrt().round() as usize;
        let hybrid = run_curve(MethodConfig::sbc(1.0 / h as f64, h), iterations, 42);
        for (name, curve) in
            [("temporal", &temporal), ("gradient", &gradient), ("hybrid", &hybrid)]
        {
            let mut row = vec![format!("1/{k}"), name.to_string()];
            for &s in &stages {
                let e = error_at(curve, s);
                row.push(format!("{e:.3}"));
                let _ = writeln!(csv, "{total},{name},{s},{e:.4}");
            }
            rows.push(row);
        }
    }
    let headers: Vec<String> = ["total sparsity", "kind"]
        .iter()
        .map(|s| s.to_string())
        .chain(stages.iter().map(|s| format!("err@{s}")))
        .collect();
    let h: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    println!("{}", render_table(&h, &rows));
    std::fs::create_dir_all("results").unwrap();
    std::fs::write("results/fig4_total_sparsity.csv", csv).unwrap();
    println!("wrote results/fig4_total_sparsity.csv");
    println!("(paper shape: temporal <= gradient error before the LR decay;\n the ordering flips at the final stage)");
}
