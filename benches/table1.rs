//! Paper Table I — theoretical asymptotic compression rates per method,
//! cross-checked against *measured* wire sizes of real encoded messages.
//!
//!     cargo bench --bench table1

use sbc::codec::accounting::table1_rows;
use sbc::codec::message::{self, PosCodec};
use sbc::compression::registry::MethodConfig;
use sbc::metrics::render_table;
use sbc::model::TensorLayout;
use sbc::util::rng::Rng;

fn heavy(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal() * rng.next_f32().powi(4)).collect()
}

fn main() {
    println!("== Table I (theoretical): bits breakdown and compression rate ==\n");
    let rows: Vec<Vec<String>> = table1_rows()
        .iter()
        .map(|r| {
            vec![
                r.name.to_string(),
                format!("{:.1}%", r.temporal * 100.0),
                format!("{:.2}%", r.gradient_sparsity * 100.0),
                format!("{:.1}", r.value_bits),
                format!("{:.1}", r.position_bits),
                format!("x{:.0}", r.compression_rate()),
            ]
        })
        .collect();
    println!(
        "{}",
        render_table(
            &["method", "temporal", "grad sparsity", "value bits", "pos bits", "compression"],
            &rows
        )
    );

    println!("\n== Table I (measured): wire bits of one encoded update, 1M params ==\n");
    let n = 1_000_000;
    let layout = TensorLayout::flat(n);
    let delta = heavy(n, 7);
    let dense_bits = 32.0 * n as f64;
    let configs: Vec<(MethodConfig, f64)> = vec![
        (MethodConfig::baseline(), 1.0),
        (MethodConfig::signsgd(1e-3), 1.0),
        (MethodConfig::terngrad(), 1.0),
        (MethodConfig::qsgd(4), 1.0),
        (MethodConfig::onebit(), 1.0),
        (MethodConfig::gradient_dropping(), 1.0),
        // delayed methods amortize their message over `delay` iterations
        (MethodConfig::fedavg(100), 100.0),
        (MethodConfig::sbc1(), 1.0),
        (MethodConfig::sbc2(), 10.0),
        (MethodConfig::sbc3(), 100.0),
    ];
    let mut rows = Vec::new();
    for (cfg, amortize) in configs {
        let mut pipeline = cfg.build(1);
        let msg = pipeline.compress(&delta, &layout, 0);
        let (_, bits) = message::encode(&msg, PosCodec::Golomb);
        let eff = bits as f64 / amortize;
        rows.push(vec![
            cfg.label(),
            format!("{}", bits / 8 / 1024),
            format!("x{:.0}", dense_bits / eff),
        ]);
    }
    println!("{}", render_table(&["method", "message KiB", "measured compression"], &rows));
    println!("\n(the measured column reproduces Table I's theoretical rates on a\n real heavy-tailed update; SBC(3) lands in the x30000-x45000 band)");
}
