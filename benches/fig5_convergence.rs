//! Paper Fig. 5 (ResNet50@ImageNet) and Fig. 7 (ResNet32@CIFAR, supp.):
//! convergence in terms of iterations (left panels) and transferred bits
//! (right panels) for all six methods, on the conv benchmark through the
//! PJRT stack. Series go to results/fig5_<model>.csv; the console prints
//! both panels as aligned series.
//!
//!     cargo bench --bench fig5_convergence
//!     SBC_FIG5_MODEL=lenet cargo bench --bench fig5_convergence

use sbc::config::presets;
use sbc::coordinator::trainer::Trainer;
use sbc::metrics::{render_table, RunLog};
use sbc::model::manifest::Manifest;
use sbc::runtime::PjrtBackend;
use sbc::util::scaled;

fn main() -> anyhow::Result<()> {
    let model = std::env::var("SBC_FIG5_MODEL").unwrap_or_else(|_| "cifarcnn".into());
    let iterations = scaled(100, 100);
    let manifest = Manifest::load("artifacts")?;

    println!("== Fig. 5/7: convergence vs iterations and vs bits — {model} ==\n");
    let mut backend = PjrtBackend::load(&manifest, &model, 4, 42)?;
    let mut logs: Vec<RunLog> = Vec::new();
    for method in presets::table2_methods() {
        let mut cfg = presets::preset(&model, method);
        cfg.iterations = iterations;
        // curve resolution: ~10 points per run
        cfg.eval_every_rounds = (iterations / cfg.method.delay / 10).max(1);
        cfg.eval_batches = 4;
        let r = Trainer::new(&mut backend, cfg).run();
        eprintln!(
            "  {:22} final {:.4} x{:.0} ({:.0}s)",
            r.log.method, r.log.final_metric, r.log.compression, r.log.wall_s
        );
        r.log.append_csv(&format!("results/fig5_{model}.csv"))?;
        logs.push(r.log);
    }

    // left panel: metric vs iterations
    let mut rows = Vec::new();
    for log in &logs {
        for p in &log.points {
            rows.push(vec![
                log.method.clone(),
                format!("{}", p.iterations),
                format!("{:.4}", p.metric),
                format!("{:.1}", p.client_up_bits as f64 / 8e3),
            ]);
        }
    }
    println!(
        "{}",
        render_table(&["method", "iterations", "metric", "client upstream KB"], &rows)
    );
    println!("wrote results/fig5_{model}.csv");
    println!("(paper shape, left: all methods track the baseline per iteration;\n right: SBC curves sit 3-4 decades left of the baseline on the bits axis)");
    Ok(())
}
